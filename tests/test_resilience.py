"""Resilience layer: primitives, fault-plan engine, service integration.

Three tiers, mirroring how the layer is built:

* the jax-free primitives (`repro.serve.resilience`) driven with fake
  clocks — token bucket, backoff jitter, circuit-breaker state machine,
  degradation hysteresis — exact, no sleeps;
* the deterministic fault-plan engine (`repro.serve.chaos`, re-exported
  as `tests.helpers.faults`) — same (plan, seed) must inject the same
  events at the same engine-call indices;
* the asyncio service with resilience enabled — retries, timeouts,
  breaker trips/recovery, corruption quarantine, worker death, graceful
  degradation, and the close()-never-dangles guarantee, all under the
  conservation invariant submitted == served + rejected + failed.
"""

from __future__ import annotations

import asyncio
import math

import numpy as np
import pytest

from repro.serve import admission, resilience
from repro.serve.admission import RejectedError, ServiceClosed
from repro.serve.queueing import BatchPlanner
from repro.serve.resilience import (BreakerConfig, CircuitBreaker,
                                    CircuitOpen, DegradationController,
                                    DegradeConfig, ResilienceConfig,
                                    RetryPolicy, TokenBucket)
from repro.serve.service import (CodecService, EngineFailure,
                                 EngineTimeout, PayloadCorrupt,
                                 ServiceConfig)
from tests.helpers.faults import (ChaosEngine, FaultPhase, FaultPlan,
                                  InjectedFault, WorkerKilled)
from tests.helpers.flaky import EchoEngine

import random


def run(coro):
    return asyncio.run(coro)


def fast_config(**kw) -> ServiceConfig:
    defaults = dict(max_batch=4, max_wait_s=0.002, max_queue_depth=32,
                    initial_step_s=0.001, cache_entries=0)
    defaults.update(kw)
    return ServiceConfig(**defaults)


def assert_conserved(svc: CodecService):
    s = svc.stats
    assert s.submitted == s.served + s.total_rejected + s.failed
    assert s.degraded_served <= s.served
    assert s.unhandled == 0


# ---------------------------------------------------------------------------
# Primitives (fake clocks, no asyncio)
# ---------------------------------------------------------------------------

class TestTokenBucket:
    def test_burst_then_empty(self):
        b = TokenBucket(rate=0.0, burst=2)
        assert b.take(0.0) and b.take(0.0)
        assert not b.take(0.0)
        assert not b.take(100.0)        # rate 0: never refills

    def test_refills_at_rate_up_to_burst(self):
        b = TokenBucket(rate=2.0, burst=4)
        for _ in range(4):
            assert b.take(0.0)
        assert not b.take(0.0)
        assert b.take(0.5)              # 0.5s * 2/s = 1 token back
        assert not b.take(0.5)
        assert b.available(1000.0) == 4  # capped at burst

    def test_negative_burst_rejected(self):
        with pytest.raises(ValueError):
            TokenBucket(rate=1.0, burst=-1)


class TestRetryPolicy:
    def test_backoff_is_decorrelated_jitter_in_bounds(self):
        pol = RetryPolicy(max_attempts=4, backoff_base_s=0.01,
                          backoff_cap_s=0.5)
        rng = random.Random(7)
        prev = 0.0
        for _ in range(200):
            d = pol.backoff_s(prev, rng)
            assert pol.backoff_base_s <= d <= pol.backoff_cap_s
            assert d <= max(pol.backoff_base_s, 3.0 * prev) + 1e-12
            prev = d

    def test_validation(self):
        with pytest.raises(ValueError):
            RetryPolicy(max_attempts=0)
        with pytest.raises(ValueError):
            RetryPolicy(backoff_base_s=1.0, backoff_cap_s=0.1)
        assert not RetryPolicy(max_attempts=1).enabled
        assert RetryPolicy(max_attempts=2).enabled


class TestCircuitBreaker:
    def cfg(self, **kw):
        d = dict(window=8, min_calls=3, failure_threshold=0.5,
                 reset_timeout_s=1.0, half_open_max_calls=1,
                 half_open_successes=2)
        d.update(kw)
        return BreakerConfig(**d)

    def test_trips_only_past_min_calls_and_threshold(self):
        br = CircuitBreaker(self.cfg())
        br.record_failure(0.0)
        br.record_failure(0.1)           # 2 < min_calls: still closed
        assert br.state(0.1) == resilience.CLOSED
        br.record_success(0.2)
        br.record_failure(0.3)           # 3/4 failed >= 0.5 -> open
        assert br.state(0.3) == resilience.OPEN
        assert br.transitions == [(0.3, resilience.CLOSED,
                                   resilience.OPEN)]

    def test_successes_keep_it_closed(self):
        br = CircuitBreaker(self.cfg())
        for t in range(20):
            br.record_success(float(t))
        br.record_failure(20.0)          # 1/8 window: below threshold
        assert br.state(20.0) == resilience.CLOSED

    def test_open_blocks_admission_and_dispatch_until_reset(self):
        br = CircuitBreaker(self.cfg())
        for t in range(3):
            br.record_failure(float(t))
        assert not br.admission_open(2.5)
        assert br.dispatch_budget(2.5) == 0
        assert br.retry_after_s(2.5) == pytest.approx(0.5)
        # reset_timeout elapses -> half-open probes
        assert br.state(3.0) == resilience.HALF_OPEN
        assert br.admission_open(3.0)
        assert br.dispatch_budget(3.0) == 1

    def test_half_open_probe_failure_reopens(self):
        br = CircuitBreaker(self.cfg())
        for t in range(3):
            br.record_failure(float(t))
        assert br.state(3.5) == resilience.HALF_OPEN
        br.on_dispatch(3.5)
        assert br.dispatch_budget(3.5) == 0   # probe slot consumed
        br.record_failure(3.6)
        assert br.state(3.6) == resilience.OPEN
        # and the new open period starts at the re-open time
        assert br.retry_after_s(3.7) == pytest.approx(0.9)

    def test_half_open_consecutive_successes_close(self):
        br = CircuitBreaker(self.cfg())
        for t in range(3):
            br.record_failure(float(t))
        assert br.state(3.5) == resilience.HALF_OPEN
        br.on_dispatch(3.5)
        br.record_success(3.6)
        assert br.state(3.6) == resilience.HALF_OPEN  # needs 2
        br.on_dispatch(3.7)
        br.record_success(3.8)
        assert br.state(3.8) == resilience.CLOSED
        states = [(f, t_) for _, f, t_ in br.transitions]
        assert states == [(resilience.CLOSED, resilience.OPEN),
                          (resilience.OPEN, resilience.HALF_OPEN),
                          (resilience.HALF_OPEN, resilience.CLOSED)]

    def test_window_slides(self):
        br = CircuitBreaker(self.cfg(window=4, min_calls=4))
        for t in range(4):
            br.record_failure(float(t))   # trips at the 4th
        assert br.state(4.0) != resilience.CLOSED
        br2 = CircuitBreaker(self.cfg(window=4, min_calls=4))
        for t in range(10):
            br2.record_success(float(t))
        br2.record_failure(10.0)
        br2.record_failure(11.0)          # window [S,S,F,F] = 0.5: trips
        assert br2.state(11.0) == resilience.OPEN

    def test_validation(self):
        with pytest.raises(ValueError):
            BreakerConfig(failure_threshold=0.0)
        with pytest.raises(ValueError):
            BreakerConfig(window=0)


class TestDegradationController:
    def cfg(self, **kw):
        d = dict(quality_caps=(100, 60, 35),
                 urgent_batch_caps=(None, 4, 2),
                 enter_pressure=0.75, exit_pressure=0.25,
                 sustain_s=1.0, cool_s=2.0)
        d.update(kw)
        return DegradeConfig(**d)

    def test_escalates_only_after_sustained_pressure(self):
        c = DegradationController(self.cfg())
        assert c.observe(0.0, 0.9) == 0      # hot, but not yet sustained
        assert c.observe(0.5, 0.9) == 0
        assert c.observe(1.0, 0.9) == 1      # 1.0s sustained
        assert c.quality_cap() == 60 and c.urgent_cap() == 4
        assert c.observe(1.5, 0.9) == 1      # next level needs own dwell
        assert c.observe(2.0, 0.9) == 2
        assert c.observe(9.0, 0.9) == 2      # capped at max level

    def test_burst_does_not_escalate(self):
        c = DegradationController(self.cfg())
        c.observe(0.0, 0.9)
        c.observe(0.5, 0.1)                  # pressure fell: reset dwell
        assert c.observe(1.5, 0.9) == 0

    def test_hysteresis_band_holds_level(self):
        c = DegradationController(self.cfg())
        c.observe(0.0, 0.9)
        c.observe(1.0, 0.9)
        assert c.level == 1
        for t in range(2, 20):
            assert c.observe(float(t), 0.5) == 1   # mid-band: hold

    def test_cools_down_after_quiet_period(self):
        c = DegradationController(self.cfg())
        c.observe(0.0, 0.9)
        c.observe(1.0, 0.9)
        assert c.level == 1
        assert c.observe(2.0, 0.1) == 1
        assert c.observe(3.9, 0.1) == 1      # 1.9s < cool_s
        assert c.observe(4.0, 0.1) == 0      # 2.0s quiet: decay

    def test_validation(self):
        with pytest.raises(ValueError):
            DegradeConfig(quality_caps=(90, 50),
                          urgent_batch_caps=(None, 2))
        with pytest.raises(ValueError):
            DegradeConfig(quality_caps=(100,), urgent_batch_caps=(None,),
                          enter_pressure=0.2, exit_pressure=0.5)


class TestPlannerResilienceHooks:
    def test_urgent_cap_shrinks_only_urgency_dispatches(self):
        p = BatchPlanner(max_batch=8, max_wait_s=10.0, safety=1.5,
                         initial_step_s=1.0)
        for _ in range(5):
            p.admit((64, 64), 50, "t", now=0.0, deadline=2.0)
        # deadline-urgent (0.6 >= 2.0 - 1.5*1.0) yet still feasible
        # (0.6 + 1.0 <= 2.0); not full, timer far off
        poll = p.poll(0.6, urgent_cap=2)
        assert poll.batches and not poll.rejects
        assert all(len(b.requests) <= 2 for b in poll.batches)
        assert sum(len(b.requests) for b in poll.batches) == 5

    def test_full_bucket_ignores_urgent_cap(self):
        p = BatchPlanner(max_batch=4, max_wait_s=10.0)
        for _ in range(4):
            p.admit((64, 64), 50, "t", now=0.0)
        poll = p.poll(0.0, urgent_cap=1)
        assert [len(b.requests) for b in poll.batches] == [4]

    def test_readmit_keeps_identity_and_applies_depth_bound(self):
        p = BatchPlanner(max_batch=2, max_wait_s=10.0, max_queue_depth=2)
        r = p.admit((64, 64), 50, "t", now=0.0, deadline=math.inf)
        batch = p.poll(100.0).batches[0]
        assert batch.requests == [r]
        p.readmit(r)
        again = p.poll(200.0).batches[0].requests[0]
        assert again.req_id == r.req_id and again.arrival == 0.0
        p.readmit(r)
        p.readmit(r)
        with pytest.raises(RejectedError) as ei:
            p.readmit(r)
        assert ei.value.reason == admission.QUEUE_FULL

    def test_pressure_is_fullest_bucket_fraction(self):
        p = BatchPlanner(max_batch=4, max_queue_depth=10)
        assert p.pressure() == 0.0
        for _ in range(5):
            p.admit((64, 64), 50, "t", now=0.0)
        p.admit((128, 128), 50, "t", now=0.0)
        assert p.pressure() == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# Fault-plan engine
# ---------------------------------------------------------------------------

def echo_blobs(images, quality):
    return EchoEngine()(images, quality)


class TestChaosEngine:
    def test_phases_select_by_call_index(self):
        plan = FaultPlan(phases=(
            FaultPhase(start=1, stop=3, fail_rate=1.0),
        ), seed=0)
        eng = ChaosEngine(echo_blobs, plan)
        img = np.zeros((8, 8), np.uint8)
        assert eng([img], 50)                     # call 0: clean
        for _ in range(2):                        # calls 1, 2: scripted
            with pytest.raises(InjectedFault):
                eng([img], 50)
        assert eng([img], 50)                     # call 3: clean again
        assert eng.events == [(1, "fail"), (2, "fail")]
        assert eng.event_counts() == {"fail": 2}

    def test_same_plan_same_seed_is_reproducible(self):
        plan = FaultPlan(phases=(
            FaultPhase(start=0, stop=50, fail_rate=0.3, corrupt_rate=0.3),
        ), seed=13)
        img = np.zeros((8, 8), np.uint8)
        logs = []
        for _ in range(2):
            eng = ChaosEngine(echo_blobs, plan)
            for _ in range(50):
                try:
                    eng([img, img], 50)
                except InjectedFault:
                    pass
            logs.append(list(eng.events))
        assert logs[0] == logs[1] and logs[0]

    def test_corruption_flips_exactly_one_byte(self):
        plan = FaultPlan(phases=(
            FaultPhase(start=0, corrupt_rate=1.0),), seed=3)
        img = np.arange(64, dtype=np.uint8).reshape(8, 8)
        clean = echo_blobs([img], 50)[0]
        dirty = ChaosEngine(echo_blobs, plan)([img], 50)[0]
        assert len(clean) == len(dirty)
        assert sum(a != b for a, b in zip(clean, dirty)) == 1

    def test_worker_kill_is_base_exception(self):
        plan = FaultPlan(phases=(
            FaultPhase(start=0, kill_rate=1.0),), seed=0)
        eng = ChaosEngine(echo_blobs, plan)
        with pytest.raises(WorkerKilled):
            eng([np.zeros((8, 8), np.uint8)], 50)
        assert issubclass(WorkerKilled, SystemExit)
        assert not issubclass(WorkerKilled, Exception)

    def test_phase_validation(self):
        with pytest.raises(ValueError):
            FaultPhase(start=5, stop=2)
        with pytest.raises(ValueError):
            FaultPhase(start=0, fail_rate=1.5)

    def test_dctz_crc_validator_catches_byte_flip(self):
        from repro.core.entropy import encode_zigzag_host
        from repro.serve.chaos import dctz_crc_ok
        z = np.zeros((4, 64), np.int64)
        z[:, 0] = np.arange(4) * 3
        z[:, 1] = -2
        blob = encode_zigzag_host(z, 50, "exact", (16, 16))
        assert dctz_crc_ok(blob)
        flipped = bytearray(blob)
        flipped[len(flipped) // 2] ^= 0xFF
        assert not dctz_crc_ok(bytes(flipped))
        assert not dctz_crc_ok(b"not a stream")
        assert not dctz_crc_ok(None)


# ---------------------------------------------------------------------------
# Service integration
# ---------------------------------------------------------------------------

IMG = np.arange(64 * 64, dtype=np.uint8).reshape(64, 64) % 251


class TestServiceRetries:
    def test_transient_failure_is_retried_to_success(self):
        plan = FaultPlan(phases=(FaultPhase(start=0, stop=1,
                                            fail_rate=1.0),), seed=0)
        eng = ChaosEngine(echo_blobs, plan)
        cfg = fast_config(resilience=ResilienceConfig(
            retry=RetryPolicy(max_attempts=3, backoff_base_s=0.001,
                              backoff_cap_s=0.005)))

        async def main():
            async with CodecService(cfg, engine=eng) as svc:
                resp = await svc.submit(IMG, quality=50)
                assert resp.attempts == 2
                assert svc.stats.retries == 1
                assert svc.stats.served == 1 and svc.stats.failed == 0
                assert_conserved(svc)
        run(main())

    def test_exhausted_attempts_fail_with_cause(self):
        plan = FaultPlan(phases=(FaultPhase(start=0,
                                            fail_rate=1.0),), seed=0)
        eng = ChaosEngine(echo_blobs, plan)
        cfg = fast_config(resilience=ResilienceConfig(
            retry=RetryPolicy(max_attempts=3, backoff_base_s=0.001,
                              backoff_cap_s=0.005)))

        async def main():
            async with CodecService(cfg, engine=eng) as svc:
                with pytest.raises(EngineFailure) as ei:
                    await svc.submit(IMG, quality=50)
                assert isinstance(ei.value.__cause__, InjectedFault)
                assert svc.stats.retries == 2
                assert svc.stats.failed == 1
                assert eng.calls == 3
                assert_conserved(svc)
        run(main())

    def test_empty_retry_budget_fails_fast(self):
        plan = FaultPlan(phases=(FaultPhase(start=0,
                                            fail_rate=1.0),), seed=0)
        eng = ChaosEngine(echo_blobs, plan)
        cfg = fast_config(resilience=ResilienceConfig(
            retry=RetryPolicy(max_attempts=5, backoff_base_s=0.001,
                              backoff_cap_s=0.005, budget_rate=0.0,
                              budget_burst=0.0)))

        async def main():
            async with CodecService(cfg, engine=eng) as svc:
                with pytest.raises(EngineFailure):
                    await svc.submit(IMG, quality=50)
                assert svc.stats.retries == 0
                assert svc.stats.retry_budget_exhausted == 1
                assert eng.calls == 1
                assert_conserved(svc)
        run(main())

    def test_retries_off_by_default(self):
        eng = ChaosEngine(echo_blobs, FaultPlan(phases=(
            FaultPhase(start=0, stop=1, fail_rate=1.0),), seed=0))

        async def main():
            async with CodecService(fast_config(), engine=eng) as svc:
                with pytest.raises(EngineFailure):
                    await svc.submit(IMG, quality=50)
                assert eng.calls == 1 and svc.stats.retries == 0
                assert_conserved(svc)
        run(main())


class TestServiceTimeout:
    def test_slow_attempt_times_out(self):
        eng = EchoEngine(step_s=0.25)
        cfg = fast_config(resilience=ResilienceConfig(timeout_s=0.02))

        async def main():
            async with CodecService(cfg, engine=eng) as svc:
                with pytest.raises(EngineFailure) as ei:
                    await svc.submit(IMG, quality=50)
                assert isinstance(ei.value.__cause__, EngineTimeout)
                assert svc.stats.timeouts == 1
                assert_conserved(svc)
        run(main())

    def test_timeout_plus_retry_recovers(self):
        plan = FaultPlan(phases=(FaultPhase(start=0, stop=1,
                                            latency_rate=1.0,
                                            latency_s=0.25),), seed=0)
        eng = ChaosEngine(echo_blobs, plan)
        cfg = fast_config(
            engine_concurrency=2,   # the abandoned thread parks worker 1
            resilience=ResilienceConfig(
                timeout_s=0.05,
                retry=RetryPolicy(max_attempts=2, backoff_base_s=0.001,
                                  backoff_cap_s=0.005)))

        async def main():
            async with CodecService(cfg, engine=eng) as svc:
                resp = await svc.submit(IMG, quality=50)
                assert resp.attempts == 2
                assert svc.stats.timeouts == 1
                assert svc.stats.retries == 1
                assert_conserved(svc)
        run(main())


class TestServiceBreaker:
    def test_storm_trips_breaker_and_recovery_closes_it(self):
        plan = FaultPlan(phases=(FaultPhase(start=0, stop=2,
                                            fail_rate=1.0),), seed=0)
        eng = ChaosEngine(echo_blobs, plan)
        cfg = fast_config(resilience=ResilienceConfig(
            breaker=BreakerConfig(window=4, min_calls=2,
                                  failure_threshold=0.5,
                                  reset_timeout_s=0.05,
                                  half_open_successes=1)))

        async def main():
            async with CodecService(cfg, engine=eng) as svc:
                for _ in range(2):
                    with pytest.raises(EngineFailure):
                        await svc.submit(IMG, quality=50)
                # breaker is now open: typed fast-fail at submit
                with pytest.raises(CircuitOpen) as ei:
                    await svc.submit(IMG, quality=50)
                assert ei.value.reason == admission.CIRCUIT_OPEN
                assert svc.stats.rejected[admission.CIRCUIT_OPEN] == 1
                await asyncio.sleep(0.08)     # reset timeout elapses
                resp = await svc.submit(IMG, quality=50)  # probe: clean
                assert resp.payload
                states = [(f, t) for _, f, t in svc.breaker.transitions]
                assert states == [("closed", "open"),
                                  ("open", "half_open"),
                                  ("half_open", "closed")]
                assert_conserved(svc)
        run(main())

    def test_open_breaker_parks_queued_work_until_half_open(self):
        # a request admitted *before* the trip stays queued while the
        # breaker is open and dispatches once probes are allowed
        plan = FaultPlan(phases=(FaultPhase(start=0, stop=2,
                                            fail_rate=1.0,
                                            latency_rate=1.0,
                                            latency_s=0.05),), seed=0)
        eng = ChaosEngine(echo_blobs, plan)
        cfg = fast_config(
            max_batch=1, max_wait_s=0.0005, max_inflight_batches=1,
            resilience=ResilienceConfig(
                breaker=BreakerConfig(window=4, min_calls=2,
                                      failure_threshold=0.5,
                                      reset_timeout_s=0.05,
                                      half_open_successes=1)))

        async def main():
            async with CodecService(cfg, engine=eng) as svc:
                async def one(img):
                    try:
                        return await svc.submit(img, quality=50)
                    except EngineFailure:
                        return None

                # with one in-flight slot A and B fail serially (50 ms
                # each); C is admitted while closed but is still queued
                # when B's failure trips the breaker — it must park,
                # then ride the half-open probe to success
                imgs = [((IMG + i) % 251).astype(np.uint8)
                        for i in range(3)]
                t0 = asyncio.get_running_loop().time()
                results = await asyncio.gather(*[one(im) for im in imgs])
                waited = asyncio.get_running_loop().time() - t0
                assert results[0] is None and results[1] is None
                assert results[2] is not None and results[2].payload
                # A (50ms) + B (50ms) + open period (50ms): C was parked
                assert waited >= 0.13
                states = [(f, t) for _, f, t in svc.breaker.transitions]
                assert states[:2] == [("closed", "open"),
                                      ("open", "half_open")]
                assert_conserved(svc)
        run(main())


class TestServiceCorruption:
    def test_corrupt_payload_never_served_and_retried(self):
        plan = FaultPlan(phases=(FaultPhase(start=0, stop=1,
                                            corrupt_rate=1.0),), seed=0)
        eng = ChaosEngine(echo_blobs, plan)
        # chaos corrupts by flipping one byte; the validator detects any
        # difference from the known clean echo payload
        clean = {}

        async def main():
            clean[echo_blobs([IMG], 50)[0]] = True
            cfg = fast_config(resilience=ResilienceConfig(
                validate_payload=lambda b: b in clean,
                retry=RetryPolicy(max_attempts=2, backoff_base_s=0.001,
                                  backoff_cap_s=0.005)))
            async with CodecService(cfg, engine=eng) as svc:
                resp = await svc.submit(IMG, quality=50)
                assert resp.payload in clean
                assert resp.attempts == 2
                assert svc.stats.corrupt_payloads == 1
                assert svc.stats.retries == 1
                assert_conserved(svc)
        run(main())

    def test_corrupt_payload_without_retry_fails_typed(self):
        plan = FaultPlan(phases=(FaultPhase(start=0,
                                            corrupt_rate=1.0),), seed=0)
        eng = ChaosEngine(echo_blobs, plan)
        cfg = fast_config(resilience=ResilienceConfig(
            validate_payload=lambda b: b == echo_blobs([IMG], 50)[0]))

        async def main():
            async with CodecService(cfg, engine=eng) as svc:
                with pytest.raises(EngineFailure) as ei:
                    await svc.submit(IMG, quality=50)
                assert isinstance(ei.value.__cause__, PayloadCorrupt)
                assert svc.stats.corrupt_payloads == 1
                assert svc.stats.failed == 1
                assert_conserved(svc)
        run(main())


class TestServiceWorkerDeath:
    def test_worker_death_fails_batch_not_service(self):
        plan = FaultPlan(phases=(FaultPhase(start=0, stop=1,
                                            kill_rate=1.0),), seed=0)
        eng = ChaosEngine(echo_blobs, plan)

        async def main():
            async with CodecService(fast_config(), engine=eng) as svc:
                with pytest.raises(EngineFailure) as ei:
                    await svc.submit(IMG, quality=50)
                assert isinstance(ei.value.__cause__, WorkerKilled)
                # the service must keep serving afterwards
                resp = await svc.submit(np.rot90(IMG).copy(), quality=50)
                assert resp.payload
                assert svc.stats.unhandled == 0
                assert svc.dispatcher_error is None
                assert_conserved(svc)
        run(main())


class TestServiceDegradation:
    def test_sustained_pressure_downshifts_quality(self):
        eng = EchoEngine()
        cfg = fast_config(resilience=ResilienceConfig(
            degrade=DegradeConfig(quality_caps=(100, 40),
                                  urgent_batch_caps=(None, 1),
                                  enter_pressure=0.0, exit_pressure=0.0,
                                  sustain_s=0.0, cool_s=60.0)))

        async def main():
            async with CodecService(cfg, engine=eng) as svc:
                # warm one loop iteration so the controller escalates
                await svc.submit(IMG, quality=90)
                await asyncio.sleep(0.01)
                resp = await svc.submit(np.rot90(IMG).copy(), quality=90)
                assert resp.degraded and resp.quality == 40
                assert svc.stats.degraded >= 1
                assert svc.stats.degraded_served >= 1
                assert_conserved(svc)
        run(main())

    def test_no_degradation_without_config(self):
        eng = EchoEngine()

        async def main():
            async with CodecService(fast_config(), engine=eng) as svc:
                resp = await svc.submit(IMG, quality=90)
                assert not resp.degraded and resp.quality == 90
                assert svc.stats.degraded == 0
        run(main())


class TestServiceClose:
    def test_close_resolves_future_stranded_by_dispatcher_crash(self):
        eng = EchoEngine()

        async def main():
            svc = CodecService(fast_config(), engine=eng)
            await svc.start()
            svc._planner.poll = lambda *a, **k: (_ for _ in ()).throw(
                RuntimeError("planner exploded"))
            task = asyncio.ensure_future(svc.submit(IMG, quality=50))
            await asyncio.sleep(0.02)
            assert not task.done()     # stranded: dispatcher is dead
            await svc.close()
            with pytest.raises(ServiceClosed) as ei:
                await task
            assert ei.value.reason == admission.SHUTDOWN
            assert isinstance(svc.dispatcher_error, RuntimeError)
            assert svc.stats.closed_unserved == 1
            assert_conserved(svc)
        run(main())

    def test_close_cancels_parked_retry_and_resolves_future(self):
        plan = FaultPlan(phases=(FaultPhase(start=0,
                                            fail_rate=1.0),), seed=0)
        eng = ChaosEngine(echo_blobs, plan)
        cfg = fast_config(resilience=ResilienceConfig(
            retry=RetryPolicy(max_attempts=3, backoff_base_s=5.0,
                              backoff_cap_s=5.0)))

        async def main():
            svc = CodecService(cfg, engine=eng)
            await svc.start()
            task = asyncio.ensure_future(svc.submit(IMG, quality=50))
            for _ in range(200):
                await asyncio.sleep(0.005)
                if svc.stats.retries:
                    break
            assert svc.stats.retries == 1
            await svc.close()          # must not wait out the 5s backoff
            with pytest.raises(ServiceClosed):
                await task
            assert svc.stats.closed_unserved == 1
            assert_conserved(svc)
        run(main())

    def test_clean_close_reports_no_unserved(self):
        eng = EchoEngine()

        async def main():
            svc = CodecService(fast_config(), engine=eng)
            await svc.start()
            await svc.submit(IMG, quality=50)
            await svc.close()
            assert svc.stats.closed_unserved == 0
            assert svc.dispatcher_error is None
            with pytest.raises(RejectedError) as ei:
                await svc.submit(IMG, quality=50)
            assert ei.value.reason == admission.SHUTDOWN
            assert_conserved(svc)
        run(main())


class TestConservationUnderChaos:
    def test_mixed_fault_storm_conserves_every_outcome(self):
        plan = FaultPlan(phases=(
            FaultPhase(start=2, stop=8, fail_rate=0.7),
            FaultPhase(start=8, stop=12, corrupt_rate=0.5),
            FaultPhase(start=12, stop=14, kill_rate=1.0),
        ), seed=11)
        eng = ChaosEngine(echo_blobs, plan)
        cfg = fast_config(
            max_batch=2, max_queue_depth=8,
            resilience=ResilienceConfig(
                retry=RetryPolicy(max_attempts=2, backoff_base_s=0.001,
                                  backoff_cap_s=0.004),
                breaker=BreakerConfig(window=6, min_calls=3,
                                      failure_threshold=0.7,
                                      reset_timeout_s=0.01,
                                      half_open_successes=1),
                validate_payload=lambda b: isinstance(b, bytes)
                and len(b) == 20 and not b.startswith(b"\xff")))

        async def main():
            async with CodecService(cfg, engine=eng) as svc:
                imgs = [((IMG + i) % 251).astype(np.uint8)
                        for i in range(40)]

                async def one(img):
                    try:
                        await svc.submit(img, quality=50,
                                         deadline_s=2.0)
                        return "served"
                    except RejectedError:
                        return "rejected"
                    except EngineFailure:
                        return "failed"

                outcomes = await asyncio.gather(*[one(im) for im in imgs])
                assert len(outcomes) == 40
                assert_conserved(svc)
                assert svc.stats.submitted == 40
        run(main())
