"""Per-architecture smoke + decode-equivalence tests (reduced configs)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.configs.base import SHAPES, input_specs, shape_supported
from repro.models import registry as M

ARCHS = R.ARCH_NAMES


def _batch(cfg, b=2, s=8, seed=1):
    key = jax.random.key(seed)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.input_mode == "embeds":
        batch = {"embeds": jax.random.normal(key, (b, s, cfg.d_model)),
                 "labels": batch["labels"],
                 "mask": jnp.ones((b, s), bool)}
    elif cfg.input_mode == "mixed":
        batch.update(
            vision_embeds=jax.random.normal(key, (b, s, cfg.d_model)),
            vision_mask=jnp.zeros((b, s), bool).at[:, :2].set(True),
            positions3=jnp.broadcast_to(jnp.arange(s)[None, None],
                                        (3, b, s)).astype(jnp.int32))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = R.reduced(arch)
    params = M.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    logits, _, aux = M.apply(cfg, params, batch, mode="train")
    assert logits.shape == (2, 8, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_runs_and_is_finite(arch):
    from repro.optim.adamw import AdamWConfig
    from repro.train import step as step_lib
    cfg = R.reduced(arch)
    scfg = step_lib.TrainStepConfig()
    state = step_lib.init_state(cfg, AdamWConfig(), jax.random.key(0), scfg)
    fn = jax.jit(step_lib.make_train_step(cfg, AdamWConfig(), scfg))
    state2, metrics = fn(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2["step"]) == 1
    # params actually changed
    moved = any(
        float(jnp.abs(state2["params"][k] - state["params"][k]).max()) > 0
        for k in state["params"])
    assert moved


DECODE_ARCHS = [a for a in ARCHS if R.get(a).supports_decode]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_prefill(arch):
    # MoE archs: pin capacity high so routing is batch-size independent
    over = {"moe_capacity_factor": 16.0} if R.get(arch).n_experts else {}
    cfg = R.reduced(arch, **over)
    params = M.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    full, _, _ = M.apply(cfg, params, batch, mode="prefill")
    cache = M.init_cache(cfg, batch=2, max_len=8)
    outs = []
    for i in range(8):
        step = {"tokens": batch["tokens"][:, i:i + 1],
                "cache_index": jnp.asarray(i, jnp.int32)}
        if cfg.input_mode == "mixed":
            step["positions3"] = batch["positions3"][:, :, i:i + 1]
            step["vision_embeds"] = batch["vision_embeds"][:, i:i + 1]
            step["vision_mask"] = batch["vision_mask"][:, i:i + 1]
        lg, cache, _ = M.apply(cfg, params, step, mode="decode", cache=cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               atol=5e-4, rtol=1e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_all_supported_shapes(arch):
    cfg = R.get(arch)
    for shape_name in SHAPES:
        ok, reason = shape_supported(cfg, shape_name)
        if not ok:
            assert reason
            continue
        specs = input_specs(cfg, shape_name)
        assert specs, (arch, shape_name)
        for k, v in specs.items():
            if k == "cache":
                assert isinstance(v, dict) and v
            else:
                assert hasattr(v, "shape")


def test_cell_count_matches_brief():
    """40 nominal cells; hubert decode (2) + full-attention long (7) skip."""
    total = runnable = 0
    for arch in ARCHS:
        cfg = R.get(arch)
        for shape_name in SHAPES:
            total += 1
            if shape_supported(cfg, shape_name)[0]:
                runnable += 1
    assert total == 40
    assert runnable == 31


def test_grads_flow_to_all_params():
    for arch in ("smollm-360m", "qwen3-moe-30b-a3b", "xlstm-1.3b",
                 "zamba2-1.2b"):
        cfg = R.reduced(arch)
        params = M.init_params(cfg, jax.random.key(0))
        batch = _batch(cfg, s=16)

        def loss(p):
            lg, _, aux = M.apply(cfg, p, batch, mode="train")
            extra = aux.get("aux_loss", 0.0)
            return jnp.mean(lg.astype(jnp.float32) ** 2) + extra
        g = jax.grad(loss)(params)
        zero = [k for k, v in g.items()
                if float(jnp.abs(v).max()) == 0.0]
        # biases/norm tails may be zero-grad in tiny nets; weights must flow
        big_zero = [k for k in zero if g[k].size > 64]
        assert not big_zero, (arch, big_zero)
