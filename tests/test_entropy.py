"""Entropy-coded bitstream stage: zig-zag properties, RLE/Huffman
round-trips (random + adversarial blocks), vectorized-vs-reference
identity (the wire-format lock for the fast path), golden ``.dctz``
fixtures from the PR 3 encoder, container framing errors, bit-exactness
against the quantised array path, and the engine's (pipelined) batch
byte path."""

import pathlib

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import codec, images
from repro.core.entropy import (BitstreamError, decode_image, decode_qcoeffs,
                                decode_zigzag_host, encode_image,
                                encode_qcoeffs, encode_zigzag_host,
                                read_header, verify_crc)
from repro.core.entropy import bitio, huffman, rle, scan

DATA_DIR = pathlib.Path(__file__).parent / "data"


def _roundtrip_blocks(dc_diff, ac):
    """symbolize -> tables -> payload -> decode, for (n,)+(n,63) arrays.

    Also asserts, on every use, that the vectorized path matches the
    scalar reference at all three levels: symbol stream, payload bytes,
    and decoded blocks."""
    is_dc, syms, amp_vals, amp_lens = rle.symbolize(dc_diff, ac)
    ref = rle.symbolize_reference(dc_diff, ac)
    for got, want in zip((is_dc, syms, amp_vals, amp_lens), ref):
        np.testing.assert_array_equal(got, want)
    dc_freq, ac_freq = rle.symbol_frequencies(is_dc, syms)
    dc_t, ac_t = huffman.build_table(dc_freq), huffman.build_table(ac_freq)
    payload = rle.encode_payload(is_dc, syms, amp_vals, amp_lens, dc_t, ac_t)
    out = rle.decode_payload(payload, len(dc_diff), dc_t, ac_t)
    ref_out = rle.decode_payload_reference(payload, len(dc_diff), dc_t, ac_t)
    np.testing.assert_array_equal(out[0], ref_out[0])
    np.testing.assert_array_equal(out[1], ref_out[1])
    return out


class TestZigzag:
    def test_perm_is_permutation_and_involution_with_inverse(self):
        perm = scan.zigzag_perm()
        inv = scan.inverse_zigzag_perm()
        assert sorted(perm.tolist()) == list(range(64))
        np.testing.assert_array_equal(perm[inv], np.arange(64))
        np.testing.assert_array_equal(inv[perm], np.arange(64))

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_unscan_inverts_scan(self, seed):
        blocks = jnp.asarray(np.random.default_rng(seed).integers(
            -500, 500, (3, 8, 8), dtype=np.int32))
        z = scan.zigzag_scan(blocks)
        np.testing.assert_array_equal(np.asarray(scan.zigzag_unscan(z)),
                                      np.asarray(blocks))

    def test_dc_differential_integrates_back(self):
        z = jnp.asarray(np.random.default_rng(0).integers(
            -100, 100, (7, 64), dtype=np.int32))
        dc_diff, ac = scan.dc_differential(z)
        dc = scan.dc_integrate(dc_diff)
        np.testing.assert_array_equal(np.asarray(dc), np.asarray(z[:, 0]))
        back = scan.assemble_stream(dc, ac)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(z))


class TestRLEHuffman:
    @given(st.integers(0, 2**31 - 1), st.integers(1, 8))
    @settings(max_examples=15, deadline=None)
    def test_roundtrip_random_blocks(self, seed, n):
        rng = np.random.default_rng(seed)
        # mostly-zero AC (the realistic case) plus dense noise blocks
        ac = rng.integers(-1000, 1000, (n, 63))
        ac[rng.random((n, 63)) < 0.7] = 0
        dc_diff = rng.integers(-2000, 2000, (n,))
        dec_dc, dec_ac = _roundtrip_blocks(dc_diff, ac)
        np.testing.assert_array_equal(dec_dc, dc_diff)
        np.testing.assert_array_equal(dec_ac, ac)

    @pytest.mark.parametrize("name,dc,acrow", [
        ("all_zero", [0, 0, 0], np.zeros((3, 63), int)),
        ("single_giant_ac_last",
         [5], np.eye(1, 63, 62, dtype=int) * 32767),
        ("single_giant_negative_ac",
         [-32768 + 1], np.eye(1, 63, 40, dtype=int) * -32767),
        ("max_run_zrl",                    # 62 zeros then one coefficient
         [1], np.eye(1, 63, 62, dtype=int) * 3),
        ("alternating_runs",
         [7], np.tile([0, 0, 0, 0, 0, 0, 0, 0, 0, 1], 7)[:63]
         .reshape(1, 63)),
        ("dense_max",                      # no zero anywhere, all max cat
         [100], np.full((1, 63), 255)),
    ])
    def test_adversarial_blocks(self, name, dc, acrow):
        ac = np.asarray(acrow, dtype=np.int64)
        dc_diff = np.asarray(dc, dtype=np.int64)
        dec_dc, dec_ac = _roundtrip_blocks(dc_diff, ac)
        np.testing.assert_array_equal(dec_dc, dc_diff, err_msg=name)
        np.testing.assert_array_equal(dec_ac, ac, err_msg=name)

    def test_amplitude_range_rejected(self):
        with pytest.raises(rle.RangeError):
            rle.symbolize(np.array([2**16]), np.zeros((1, 63), int))
        with pytest.raises(rle.RangeError):
            rle.symbolize(np.array([0]),
                          np.full((1, 63), 40000, dtype=np.int64))

    def test_pack_bits_msb_first_and_one_padded(self):
        out = bitio.pack_bits(np.array([0b101, 0b1]),
                              np.array([3, 1]))
        assert out == bytes([0b10111111])
        reader = bitio.BitReader(out)
        assert reader.take(3) == 0b101 and reader.take(1) == 1

    def test_bitreader_truncation_raises(self):
        reader = bitio.BitReader(b"\xff")
        reader.take(8)
        with pytest.raises(bitio.TruncatedStream):
            reader.take(1)


class TestHuffman:
    def test_canonical_codes_are_prefix_free_and_ordered(self):
        t = huffman.build_table(np.array([0, 50, 30, 10, 5, 3, 2]))
        codes = t.code_lengths()
        strs = [format(c, f"0{l}b") for c, l in codes]
        for i, a in enumerate(strs):
            for b in strs[i + 1:]:
                assert not b.startswith(a) and not a.startswith(b)
        # more frequent symbols never get longer codes
        lens = dict(zip(t.symbols, (l for _, l in codes)))
        assert lens[1] <= lens[6]

    def test_single_symbol_table(self):
        t = huffman.build_table(np.eye(1, 256, 7).ravel())
        assert t.symbols == (7,) and t.code_lengths() == [(0, 1)]

    def test_length_limit_16(self):
        # fibonacci-ish frequencies force depth > 16 before limiting
        freqs = np.zeros(40)
        a, b = 1, 1
        for s in range(40):
            freqs[s] = a
            a, b = b, a + b
        t = huffman.build_table(freqs)
        assert max(l for _, l in t.code_lengths()) <= 16

    def test_segment_roundtrip_and_validation(self):
        t = huffman.build_table(np.array([5, 3, 2, 1]))
        seg = t.to_segment()
        t2, off = huffman.CanonicalTable.from_segment(seg)
        assert t2 == t and off == len(seg)
        with pytest.raises(huffman.InvalidTable):
            huffman.CanonicalTable.from_segment(seg[:10])
        with pytest.raises(huffman.InvalidTable):   # Kraft overfull
            huffman.CanonicalTable(counts=(4,) + (0,) * 15,
                                   symbols=(1, 2, 3, 4))


class TestContainer:
    def test_bit_exact_against_quantised_path(self):
        # the acceptance criterion: decode(encode(img, q)) reproduces the
        # quantised-roundtrip reconstruction bit-exactly, bench images
        # included (sizes cut down for test speed)
        for gen, (h, w) in ((images.lena_like, (96, 96)),
                            (images.lena_like, (96, 102)),   # non-8-divisible
                            (images.cablecar_like, (64, 48))):
            img = gen(h, w)
            for q in (10, 50, 90):
                c = codec.compress(img, q)
                blob = c.to_bytes()
                rec_bytes = np.asarray(decode_image(blob))
                rec_array = np.asarray(codec.decompress(c))
                np.testing.assert_array_equal(rec_bytes, rec_array)

    def test_qcoeffs_lossless_and_header_fields(self):
        img = images.cablecar_like(72, 80)
        c = codec.compress(img, 30, "cordic")
        blob = c.to_bytes()
        qc, hdr = decode_qcoeffs(blob)
        np.testing.assert_array_equal(np.asarray(qc), np.asarray(c.qcoeffs))
        assert hdr["quality"] == 30 and hdr["transform"] == "cordic"
        assert (hdr["height"], hdr["width"]) == (72, 80)
        assert read_header(blob) == hdr

    def test_measured_nbytes_and_ratio(self):
        img = images.lena_like(128, 128)
        c = codec.compress(img, 50)
        assert c.nbytes == len(c.to_bytes())
        assert c.compression_ratio() == 128 * 128 / c.nbytes
        assert c.nbytes < 128 * 128          # actually compresses

    def test_from_bytes_equals_original(self):
        img = images.lena_like(64, 64)
        c = codec.compress(img, 50)
        c2 = codec.CompressedImage.from_bytes(c.to_bytes())
        assert c2.quality == 50 and c2.orig_shape == (64, 64)
        assert c2.to_bytes() == c.to_bytes()   # re-encode is stable

    @pytest.mark.parametrize("mutate,match", [
        (lambda b: b[:10], "truncated header"),
        (lambda b: b"JUNK" + b[4:], "not a DCTZ"),
        (lambda b: b[:4] + bytes([99]) + b[5:], "version"),
        (lambda b: b[:7] + bytes([9]) + b[8:], "transform"),
        (lambda b: b[:16] + bytes([3]) + b[17:], "table id"),
        (lambda b: b[:len(b) - 8], "truncated payload"),
        (lambda b: b + b"x", "trailing"),
        (lambda b: b[:-4] + bytes([b[-4] ^ 0xFF]) + b[-3:], "CRC"),
        # header fields after the magic are CRC-protected too: a flipped
        # quality bit must not dequantise plausibly with the wrong table
        (lambda b: b[:6] + bytes([b[6] ^ 1]) + b[7:], "CRC"),
    ])
    def test_malformed_streams_rejected_with_clear_errors(self, mutate,
                                                          match):
        blob = encode_image(images.lena_like(40, 40), 50)
        with pytest.raises(BitstreamError, match=match):
            decode_qcoeffs(mutate(blob))

    def test_crafted_huge_shape_rejected_before_allocation(self):
        # a crafted header with a valid CRC but an absurd shape must be
        # rejected by the block-count bound, not die in np allocation
        import struct
        import zlib
        blob = bytearray(encode_image(images.lena_like(40, 40), 50))
        struct.pack_into("<II", blob, 8, 0xFFFFFF00, 0xFFFFFF00)
        crc = zlib.crc32(bytes(blob[4:24]) + bytes(blob[28:]))
        struct.pack_into("<I", blob, 24, crc & 0xFFFFFFFF)
        with pytest.raises(BitstreamError, match="cannot hold"):
            decode_qcoeffs(bytes(blob))

    def test_encode_validates_inputs(self):
        qc = np.zeros((2, 2, 8, 8), np.int32)
        with pytest.raises(ValueError, match="quality"):
            encode_qcoeffs(qc, 0, "exact", (16, 16))
        with pytest.raises(ValueError, match="transform"):
            encode_qcoeffs(qc, 50, "dst", (16, 16))
        with pytest.raises(ValueError, match="block grid"):
            encode_qcoeffs(qc, 50, "exact", (64, 64))

    def test_bpp_monotone_in_quality(self):
        img = images.lena_like(96, 96)
        sizes = [len(encode_image(img, q)) for q in (10, 50, 90)]
        assert sizes[0] < sizes[1] < sizes[2]


class TestVectorizedVsReference:
    """The fast path's contract: bit-for-bit identical to the scalar
    reference oracles on streams the reference can produce."""

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_symbolize_matches_reference(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 24))
        ac = rng.integers(-32767, 32768, (n, 63))
        ac[rng.random((n, 63)) < rng.uniform(0.2, 0.995)] = 0
        dc_diff = rng.integers(-32767, 32768, (n,))
        got = rle.symbolize(dc_diff, ac)
        want = rle.symbolize_reference(dc_diff, ac)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)

    def test_decode_truncation_matches_reference_semantics(self):
        dc = np.arange(-8, 8)
        ac = np.zeros((16, 63), np.int64)
        ac[:, ::7] = np.arange(1, 17)[:, None]
        is_dc, syms, av, al = rle.symbolize(dc, ac)
        dc_f, ac_f = rle.symbol_frequencies(is_dc, syms)
        dc_t, ac_t = huffman.build_table(dc_f), huffman.build_table(ac_f)
        payload = rle.encode_payload(is_dc, syms, av, al, dc_t, ac_t)
        for cut in (0, 1, len(payload) // 2, len(payload) - 1):
            with pytest.raises(ValueError):
                rle.decode_payload(payload[:cut], 16, dc_t, ac_t)
        # asking for more blocks than the stream holds must also raise
        with pytest.raises(ValueError):
            rle.decode_payload(payload, 17, dc_t, ac_t)

    def test_out_of_spec_dc_table_rejected(self):
        # a DC table coding symbol 16 passes CanonicalTable validation
        # (symbols are only bounded to bytes) but is out of spec for the
        # DC alphabet (categories are 0..15) — the decoder must reject
        # it rather than guess an amplitude width
        bad_dc = huffman.CanonicalTable(counts=(2,) + (0,) * 15,
                                        symbols=(0, 16))
        ac_t = huffman.build_table(np.eye(1, 256, rle.EOB).ravel())
        with pytest.raises(ValueError, match="DC table"):
            rle.decode_payload(b"\x00", 1, bad_dc, ac_t)

    def test_truncation_raises_truncated_stream_not_overrun(self):
        # padding bits after a truncation point can mimic a valid symbol
        # whose run would overrun the block; the decoder must report
        # truncation (any bit past the payload end), like the reference
        dc = np.zeros(4, np.int64)
        ac = np.zeros((4, 63), np.int64)
        ac[:, 60] = 3
        is_dc, syms, av, al = rle.symbolize(dc, ac)
        dc_f, ac_f = rle.symbol_frequencies(is_dc, syms)
        dc_t, ac_t = huffman.build_table(dc_f), huffman.build_table(ac_f)
        payload = rle.encode_payload(is_dc, syms, av, al, dc_t, ac_t)
        for cut in range(len(payload)):
            with pytest.raises(ValueError):
                rle.decode_payload(payload[:cut], 4, dc_t, ac_t)

    def test_bit_windows_matches_bitreader_peek16(self):
        payload = bytes([0b10110010, 0b01111000, 0xFF])
        win = bitio.bit_windows(payload)
        reader = bitio.BitReader(payload)
        for p in range(len(payload) * 8 + 1):
            reader.pos = p
            assert win[p] == reader.peek16(), f"bit {p}"

    def test_bench_identity_gate_is_clean(self):
        from repro.bench.cases import entropy_identity_violations
        assert entropy_identity_violations(trials=5) == []

    def test_packing_identity_gate_is_clean(self):
        # random + adversarial field streams AND whole framed streams:
        # staged NumPy reference and Pallas kernel == bitio.pack_bits
        from repro.bench.cases import packing_identity_violations
        assert packing_identity_violations(trials=5) == []


class TestGoldenFixtures:
    """Wire-format lock: v1 streams encoded at the PR 3 revision must be
    reproduced byte-for-byte (under ``tables="embedded"``, which pins
    the v1 layout) and still decode under the v2 reader; v2 fixtures
    lock the shared-table layout and the deterministic auto cost rule."""

    FIXTURES = [
        ("lena_40x40_q50_exact.dctz",
         lambda: images.lena_like(40, 40), 50, "exact"),
        ("lena_64x72_q90_exact.dctz",
         lambda: images.lena_like(64, 72, seed=2), 90, "exact"),
        ("cablecar_48x40_q30_cordic.dctz",
         lambda: images.cablecar_like(48, 40), 30, "cordic"),
        ("lena_33x41_q10_loeffler.dctz",
         lambda: images.lena_like(33, 41, seed=7), 10, "loeffler"),
    ]
    # (name, image_fn, quality, transform, (dc_id, ac_id)): encoded with
    # tables="auto" at the PR 5 revision; the second fixture locks the
    # per-alphabet choice (shared DC, embedded AC)
    FIXTURES_V2 = [
        ("lena_40x40_q50_exact_v2.dctz",
         lambda: images.lena_like(40, 40), 50, "exact", (1, 2)),
        ("lena_64x72_q90_exact_v2.dctz",
         lambda: images.lena_like(64, 72, seed=2), 90, "exact", (1, 0)),
    ]

    @pytest.mark.parametrize("name,image_fn,quality,transform", FIXTURES)
    def test_encoder_reproduces_golden_v1_stream(self, name, image_fn,
                                                 quality, transform):
        golden = (DATA_DIR / name).read_bytes()
        assert read_header(golden)["version"] == 1
        assert encode_image(image_fn(), quality, transform,
                            tables="embedded") == golden

    @pytest.mark.parametrize("name,image_fn,quality,transform", FIXTURES)
    def test_v2_reader_decodes_golden_v1_stream(self, name, image_fn,
                                                quality, transform):
        golden = (DATA_DIR / name).read_bytes()
        hdr = read_header(golden)
        assert hdr["quality"] == quality
        assert hdr["transform"] == transform
        img = image_fn()
        assert (hdr["height"], hdr["width"]) == img.shape
        rec = np.asarray(decode_image(golden))
        want = np.asarray(codec.decompress(codec.compress(
            img, quality, transform)))
        np.testing.assert_array_equal(rec, want)

    @pytest.mark.parametrize("name,image_fn,quality,transform,ids",
                             FIXTURES_V2)
    def test_encoder_reproduces_golden_v2_stream(self, name, image_fn,
                                                 quality, transform, ids):
        golden = (DATA_DIR / name).read_bytes()
        hdr = read_header(golden)
        assert hdr["version"] == 2
        assert (hdr["dc_table_id"], hdr["ac_table_id"]) == ids
        assert encode_image(image_fn(), quality, transform) == golden

    @pytest.mark.parametrize("name,image_fn,quality,transform,ids",
                             FIXTURES_V2)
    def test_decoder_reads_golden_v2_stream(self, name, image_fn,
                                            quality, transform, ids):
        golden = (DATA_DIR / name).read_bytes()
        rec = np.asarray(decode_image(golden))
        want = np.asarray(codec.decompress(codec.compress(
            image_fn(), quality, transform)))
        np.testing.assert_array_equal(rec, want)


class TestSharedTables:
    """Container v2: well-known shared Huffman tables by id, cost-based
    selection, and version negotiation against v1."""

    def test_registry_contents_are_canonical(self):
        assert huffman.DEFAULT_TABLES.ids() == (1, 2)
        dc = huffman.DEFAULT_TABLES.get(huffman.STANDARD_DC_LUMA_ID)
        assert dc.symbols == tuple(range(12))
        ac = huffman.DEFAULT_TABLES.get(huffman.STANDARD_AC_LUMA_ID)
        assert len(ac.symbols) == 162
        assert rle.EOB in ac.symbols and rle.ZRL in ac.symbols

    def test_registry_validates(self):
        reg = huffman.TableRegistry()
        t = huffman.build_table(np.array([5, 3]))
        with pytest.raises(ValueError, match="1..255"):
            reg.register(0, t)
        reg.register(7, t)
        with pytest.raises(ValueError, match="already registered"):
            reg.register(7, t)
        assert reg.known(7) and not reg.known(8)
        with pytest.raises(KeyError):
            reg.get(8)

    def test_coded_bits_cost_model(self):
        t = huffman.build_table(np.array([5, 3, 2]))
        lens = dict(zip(t.symbols, (l for _, l in t.code_lengths())))
        freqs = np.zeros(256, np.int64)
        freqs[[0, 1, 2]] = [5, 3, 2]
        assert huffman.coded_bits(t, freqs) == (5 * lens[0] + 3 * lens[1]
                                                + 2 * lens[2])
        freqs[9] = 1                       # symbol the table cannot code
        assert huffman.coded_bits(t, freqs) is None

    @pytest.mark.parametrize("tables", ["shared", "auto", "embedded"])
    def test_roundtrip_bit_exact_under_every_policy(self, tables):
        img = images.lena_like(56, 48)
        blob = encode_image(img, 50, tables=tables)
        rec = np.asarray(decode_image(blob))
        want = np.asarray(codec.decompress(codec.compress(img, 50)))
        np.testing.assert_array_equal(rec, want)

    def test_version_negotiation_and_size_win(self):
        img = images.lena_like(40, 40)
        v1 = encode_image(img, 50, tables="embedded")
        v2 = encode_image(img, 50, tables="shared")
        assert read_header(v1)["version"] == 1
        h2 = read_header(v2)
        assert h2["version"] == 2
        assert (h2["dc_table_id"], h2["ac_table_id"]) == (
            huffman.STANDARD_DC_LUMA_ID, huffman.STANDARD_AC_LUMA_ID)
        # shared streams skip the ~56 embedded table bytes
        assert len(v2) < len(v1)

    def test_auto_never_larger_than_embedded(self):
        for q in (10, 50, 90):
            img = images.lena_like(48, 56, seed=q)
            assert len(encode_image(img, q)) <= len(
                encode_image(img, q, tables="embedded"))

    def test_shared_raises_when_uncoverable(self):
        # a 15-bit amplitude needs an AC size the Annex K table lacks
        z = np.zeros((1, 64), np.int64)
        z[0, 1] = 32767
        with pytest.raises(ValueError, match="shared table"):
            encode_zigzag_host(z, 50, "exact", (8, 8), tables="shared")

    def test_auto_falls_back_per_alphabet_on_uncoverable(self):
        z = np.zeros((1, 64), np.int64)
        z[0, 1] = 32767
        blob = encode_zigzag_host(z, 50, "exact", (8, 8))
        hdr = read_header(blob)
        # AC must embed (category 15 uncoverable); DC still goes shared
        assert hdr["ac_table_id"] == 0
        assert hdr["dc_table_id"] == huffman.STANDARD_DC_LUMA_ID
        zz, _ = decode_zigzag_host(blob)
        np.testing.assert_array_equal(zz, z)

    def test_v2_unknown_table_id_rejected(self):
        blob = bytearray(encode_image(images.lena_like(40, 40), 50,
                                      tables="shared"))
        blob[16] = 9                       # unregistered shared id
        with pytest.raises(BitstreamError, match="table id"):
            read_header(bytes(blob))

    def test_invalid_tables_mode_rejected(self):
        with pytest.raises(ValueError, match="tables mode"):
            encode_image(images.lena_like(8, 8), 50, tables="bogus")

    def test_verify_crc(self):
        for tables in ("embedded", "shared"):
            blob = encode_image(images.lena_like(40, 40), 50,
                                tables=tables)
            assert verify_crc(blob)
            assert not verify_crc(blob[:-1] + bytes([blob[-1] ^ 1]))
            assert not verify_crc(blob + b"x")
        with pytest.raises(BitstreamError):
            verify_crc(b"JUNKJUNK" * 8)


class TestHostHalves:
    """encode_zigzag_host / decode_zigzag_host — the jax-free halves the
    pipelined engine fans across threads — agree with the full-path
    container functions."""

    def test_encode_zigzag_host_matches_encode_qcoeffs(self):
        img = images.lena_like(72, 56)
        c = codec.compress(img, 40)
        z = np.asarray(scan.block_stream(jnp.asarray(c.qcoeffs)))
        blob_host = encode_zigzag_host(z, 40, "exact", (72, 56))
        assert blob_host == encode_qcoeffs(c.qcoeffs, 40, "exact", (72, 56))

    def test_decode_zigzag_host_matches_decode_qcoeffs(self):
        blob = encode_image(images.cablecar_like(48, 64), 60)
        z, hdr = decode_zigzag_host(blob)
        q, hdr2 = decode_qcoeffs(blob)
        assert hdr == hdr2
        np.testing.assert_array_equal(
            z, np.asarray(scan.block_stream(q)))

    def test_encode_zigzag_host_validates_inputs(self):
        z = np.zeros((4, 64), np.int32)
        with pytest.raises(ValueError, match="quality"):
            encode_zigzag_host(z, 0, "exact", (16, 16))
        with pytest.raises(ValueError, match="transform"):
            encode_zigzag_host(z, 50, "dst", (16, 16))
        with pytest.raises(ValueError, match="block grid"):
            encode_zigzag_host(z, 50, "exact", (64, 64))


class TestMemoisation:
    def test_build_table_memo_equals_build_table(self):
        freqs = np.zeros(256, np.int64)
        freqs[[0, 3, 7, 240]] = [50, 30, 10, 5]
        assert huffman.build_table_memo(freqs) == huffman.build_table(freqs)
        # cache hit returns the identical object
        assert huffman.build_table_memo(freqs) is huffman.build_table_memo(
            np.array(freqs))

    def test_decoder_luts_cached_per_table(self):
        t = huffman.build_table(np.array([5, 3, 2, 1]))
        sym1, len1 = huffman.decoder_luts(t)
        sym2, len2 = huffman.decoder_luts(
            huffman.CanonicalTable(t.counts, t.symbols))
        assert sym1 is sym2 and len1 is len2
        ref_sym, ref_len = t.decoder_lut()
        np.testing.assert_array_equal(sym1, ref_sym)
        np.testing.assert_array_equal(len1, ref_len)


class TestEngineBytePath:
    def test_stacked_and_ragged_match_single_image_bytes(self):
        from repro.serve import codec_engine
        stacked = np.stack([images.lena_like(64, 64, seed=i)
                            for i in range(3)])
        blobs = codec_engine.encode_batch(stacked, 50)
        assert blobs == [codec.compress(stacked[i], 50).to_bytes()
                         for i in range(3)]
        rag = [images.lena_like(64, 72), images.cablecar_like(40, 40)]
        blobs = codec_engine.encode_batch(rag, 70)
        assert blobs == [codec.compress(im, 70).to_bytes() for im in rag]

    def test_pipelined_and_serial_encode_bytes_identical(self):
        from repro.serve import codec_engine
        rag = [images.lena_like(64, 72), images.cablecar_like(40, 40),
               images.lena_like(100, 90, seed=3)]
        pipelined = codec_engine.encode_batch(rag, 50, pipelined=True)
        serial = codec_engine.encode_batch(rag, 50, pipelined=False)
        assert pipelined == serial

    def test_decode_batch_bit_exact_mixed_streams(self):
        from repro.serve import codec_engine
        blobs = [encode_image(images.lena_like(64, 72), 50),
                 encode_image(images.cablecar_like(40, 40), 30),
                 encode_image(images.lena_like(64, 72, seed=2), 50)]
        for pipelined in (True, False):
            recs = codec_engine.decode_batch(blobs, pipelined=pipelined)
            for blob, rec in zip(blobs, recs):
                np.testing.assert_array_equal(
                    np.asarray(rec), np.asarray(decode_image(blob)))
        with pytest.raises(ValueError):
            codec_engine.decode_batch([])

    def test_pack_backend_routing_is_byte_identical(self):
        from repro.serve import codec_engine
        rag = [images.lena_like(64, 72), images.cablecar_like(40, 40)]
        default = codec_engine.encode_batch(rag, 50)
        # the routed Pallas backend (interpret mode off-TPU) must frame
        # identical streams through the whole engine path
        cb = codec_engine.compress_batch(rag, 50)
        assert cb.to_bytes_list(pack_backend="pallas") == default
        with pytest.raises(ValueError, match="backend"):
            codec_engine.encode_batch(rag, 50, pack_backend="cuda")

    def test_tables_policy_re_keys_the_stream_cache(self):
        from repro.serve import codec_engine
        rag = [images.lena_like(64, 72), images.cablecar_like(40, 40)]
        cb = codec_engine.compress_batch(rag, 50)
        auto = cb.to_bytes_list()
        emb = cb.to_bytes_list(tables="embedded")
        assert emb == [codec.compress(im, 50).to_bytes(tables="embedded")
                       for im in rag]
        assert emb != auto                  # policy changes the bytes
        assert cb.to_bytes_list() == auto   # and the cache re-keys

    def test_decode_batch_process_pool_matches_thread(self):
        from repro.serve import codec_engine
        blobs = [encode_image(images.lena_like(48, 56, seed=i), 50)
                 for i in range(3)]
        thread = codec_engine.decode_batch(blobs)
        proc = codec_engine.decode_batch(blobs, executor="process",
                                         workers=2)
        for a, b in zip(thread, proc):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        with pytest.raises(ValueError, match="executor"):
            codec_engine.decode_batch(blobs, executor="fibers")

    def test_unpack_backend_routing_is_bit_identical(self):
        from repro.serve import codec_engine
        blobs = [encode_image(images.lena_like(48, 56, seed=i), 50)
                 for i in range(3)]
        default = codec_engine.decode_batch(blobs)
        # the routed Pallas backend (interpret mode off-TPU) must
        # reconstruct identical images through the whole engine path
        routed = codec_engine.decode_batch(blobs, unpack_backend="pallas")
        serial = codec_engine.decode_batch(blobs, pipelined=False,
                                           unpack_backend="pallas")
        for a, b, c in zip(default, routed, serial):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
        with pytest.raises(ValueError, match="backend"):
            codec_engine.decode_batch(blobs, unpack_backend="cuda")

    def test_process_pool_decodes_runtime_registered_tables(self):
        # regression: spawned workers re-import the huffman registry,
        # so a v2 stream referencing a table id registered at runtime
        # used to fail in executor="process" — decode_batch now ships
        # the parent registry to each worker on init
        import struct
        import zlib

        from repro.core.entropy import container
        from repro.serve import codec_engine
        for tid, table in ((201, huffman.STANDARD_DC_LUMA),
                           (202, huffman.STANDARD_AC_LUMA)):
            if not huffman.DEFAULT_TABLES.known(tid):
                huffman.DEFAULT_TABLES.register(tid, table)
        img = np.asarray(images.lena_like(40, 40))
        z, _ = decode_zigzag_host(encode_image(img, quality=50))
        dc_diff = np.diff(z[:, 0].astype(np.int64), prepend=0)
        syms = rle.symbolize(dc_diff, z[:, 1:].astype(np.int64))
        payload = rle.encode_payload(*syms, huffman.STANDARD_DC_LUMA,
                                     huffman.STANDARD_AC_LUMA)
        h, w = img.shape
        header = container._HEADER.pack(container.MAGIC, 2, 0, 50, 0,
                                        h, w, 201, 202, 0, len(payload), 0)
        crc = zlib.crc32(header[4:24] + payload) & 0xFFFFFFFF
        blob = header[:24] + struct.pack("<I", crc) + payload
        want = np.asarray(decode_image(blob))
        out = codec_engine.decode_batch([blob, blob], executor="process",
                                        workers=2)
        for rec in out:
            np.testing.assert_array_equal(np.asarray(rec), want)

    def test_nbytes_estimate_measured_after_materialise(self):
        from repro.core import quant
        from repro.serve import codec_engine
        rag = [images.lena_like(64, 72), images.cablecar_like(40, 40)]
        cb = codec_engine.compress_batch(rag, 50)
        proxy = cb.nbytes_estimate()
        want_proxy = sum(float(quant.estimate_bits(g.qcoeffs)) / 8.0
                         for g in cb.groups)
        assert proxy == want_proxy
        streams = cb.to_bytes_list()
        measured = cb.nbytes_estimate()
        assert measured == float(sum(len(s) for s in streams))
        assert measured != proxy            # the proxy is only a model
        # repeated calls reuse the cached streams
        assert cb.to_bytes_list() == streams


class TestBigPayloadDecodeRouting:
    """PR 7 regression: `decode_payload` without an unpacker must not
    build linear-memory walk tables for huge payloads — above
    `_ROUTED_DECODE_MIN_BITS` it routes to the staged decoder
    (`repro.kernels.unpack_bits`), whose scratch is bounded per tile."""

    @staticmethod
    def _stream(n_blocks, seed=0, density=0.5, amplitude=512):
        rng = np.random.default_rng(seed)
        dc = rng.integers(-1024, 1025, (n_blocks,))
        ac = rng.integers(-amplitude, amplitude + 1, (n_blocks, 63))
        ac[rng.random((n_blocks, 63)) > density] = 0
        is_dc, syms, av, al = rle.symbolize(dc, ac)
        dc_f, ac_f = rle.symbol_frequencies(is_dc, syms)
        dc_t, ac_t = huffman.build_table(dc_f), huffman.build_table(ac_f)
        payload = rle.encode_payload(is_dc, syms, av, al, dc_t, ac_t)
        return payload, dc, ac, dc_t, ac_t

    def test_walk_tables_grow_linearly_but_staged_scratch_saturates(self):
        from repro.kernels import unpack_bits
        # the latent blowup: walk memory is ~16 B/bit with no ceiling,
        # while the staged decoder's scratch stops growing once one
        # tile's worth of positions is resident
        assert rle.walk_table_nbytes(1 << 24) > \
            7 * rle.walk_table_nbytes(1 << 21)
        assert unpack_bits.scratch_nbytes(1 << 21) == \
            unpack_bits.scratch_nbytes(1 << 24)
        # at the routing threshold the walk already costs more than the
        # staged decoder's (saturated) scratch ever will
        thr = rle._ROUTED_DECODE_MIN_BITS
        assert rle.walk_table_nbytes(thr + 8) > \
            unpack_bits.scratch_nbytes(thr + 8)
        # and the gap is what routing saves: linear vs constant
        assert rle.walk_table_nbytes(1 << 27) > \
            100 * unpack_bits.scratch_nbytes(1 << 27)

    def test_small_payloads_keep_the_walk(self, monkeypatch):
        payload, dc, ac, dc_t, ac_t = self._stream(8)
        monkeypatch.setattr(
            rle, "_staged_unpacker",
            lambda: (_ for _ in ()).throw(
                AssertionError("small payload must not route")))
        got_dc, got_ac = rle.decode_payload(payload, 8, dc_t, ac_t)
        np.testing.assert_array_equal(got_dc, dc)
        np.testing.assert_array_equal(got_ac, ac)

    def test_big_payloads_route_to_staged_decoder(self, monkeypatch):
        # shrink the threshold so routing triggers on a cheap stream,
        # and poison the walk-table builder: decode succeeding proves
        # the staged decoder served the request end to end
        payload, dc, ac, dc_t, ac_t = self._stream(32, seed=1)
        assert len(payload) * 8 > 256
        monkeypatch.setattr(rle, "_ROUTED_DECODE_MIN_BITS", 256)
        monkeypatch.setattr(
            rle, "_decode_table",
            lambda *a, **k: (_ for _ in ()).throw(
                AssertionError("big payload built walk tables")))
        got_dc, got_ac = rle.decode_payload(payload, 32, dc_t, ac_t)
        np.testing.assert_array_equal(got_dc, dc)
        np.testing.assert_array_equal(got_ac, ac)

    def test_missing_kernels_layer_falls_back_to_walk(self, monkeypatch):
        payload, dc, ac, dc_t, ac_t = self._stream(32, seed=2)
        monkeypatch.setattr(rle, "_ROUTED_DECODE_MIN_BITS", 256)
        monkeypatch.setattr(rle, "_staged_unpacker", lambda: None)
        got_dc, got_ac = rle.decode_payload(payload, 32, dc_t, ac_t)
        np.testing.assert_array_equal(got_dc, dc)
        np.testing.assert_array_equal(got_ac, ac)

    def test_above_threshold_payload_end_to_end(self):
        # a real > 2^20-bit payload: the default decode routes to the
        # staged decoder and still matches the scalar reference oracle
        n_blocks = 1400
        payload, dc, ac, dc_t, ac_t = self._stream(n_blocks, seed=3,
                                                   density=0.9,
                                                   amplitude=32767)
        assert len(payload) * 8 > rle._ROUTED_DECODE_MIN_BITS
        got_dc, got_ac = rle.decode_payload(payload, n_blocks, dc_t, ac_t)
        want_dc, want_ac = rle.decode_payload_reference(
            payload, n_blocks, dc_t, ac_t)
        np.testing.assert_array_equal(got_dc, want_dc)
        np.testing.assert_array_equal(got_ac, want_ac)

    def test_container_default_path_reaches_routing(self, monkeypatch):
        # decode_image with no unpacker (the latent-blowup entry point)
        # must inherit the routing fix
        from repro.core.entropy import container
        calls = []
        real = rle._staged_unpacker

        def spy():
            calls.append(True)
            return real()
        monkeypatch.setattr(rle, "_ROUTED_DECODE_MIN_BITS", 64)
        monkeypatch.setattr(rle, "_staged_unpacker", spy)
        img = images.lena_like(48, 48)
        blob = container.encode_image(np.asarray(img), quality=50)
        out = container.decode_image(blob)
        assert out.shape == (48, 48)
        assert calls, "decode_image default path bypassed the routing"
