"""Entropy-coded bitstream stage: zig-zag properties, RLE/Huffman
round-trips (random + adversarial blocks), container framing errors,
bit-exactness against the quantised array path, and the engine's batch
byte path."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import codec, images
from repro.core.entropy import (BitstreamError, decode_image, decode_qcoeffs,
                                encode_image, encode_qcoeffs, read_header)
from repro.core.entropy import bitio, huffman, rle, scan


def _roundtrip_blocks(dc_diff, ac):
    """symbolize -> tables -> payload -> decode, for (n,)+(n,63) arrays."""
    is_dc, syms, amp_vals, amp_lens = rle.symbolize(dc_diff, ac)
    dc_freq, ac_freq = rle.symbol_frequencies(is_dc, syms)
    dc_t, ac_t = huffman.build_table(dc_freq), huffman.build_table(ac_freq)
    payload = rle.encode_payload(is_dc, syms, amp_vals, amp_lens, dc_t, ac_t)
    return rle.decode_payload(payload, len(dc_diff), dc_t, ac_t)


class TestZigzag:
    def test_perm_is_permutation_and_involution_with_inverse(self):
        perm = scan.zigzag_perm()
        inv = scan.inverse_zigzag_perm()
        assert sorted(perm.tolist()) == list(range(64))
        np.testing.assert_array_equal(perm[inv], np.arange(64))
        np.testing.assert_array_equal(inv[perm], np.arange(64))

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_unscan_inverts_scan(self, seed):
        blocks = jnp.asarray(np.random.default_rng(seed).integers(
            -500, 500, (3, 8, 8), dtype=np.int32))
        z = scan.zigzag_scan(blocks)
        np.testing.assert_array_equal(np.asarray(scan.zigzag_unscan(z)),
                                      np.asarray(blocks))

    def test_dc_differential_integrates_back(self):
        z = jnp.asarray(np.random.default_rng(0).integers(
            -100, 100, (7, 64), dtype=np.int32))
        dc_diff, ac = scan.dc_differential(z)
        dc = scan.dc_integrate(dc_diff)
        np.testing.assert_array_equal(np.asarray(dc), np.asarray(z[:, 0]))
        back = scan.assemble_stream(dc, ac)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(z))


class TestRLEHuffman:
    @given(st.integers(0, 2**31 - 1), st.integers(1, 8))
    @settings(max_examples=15, deadline=None)
    def test_roundtrip_random_blocks(self, seed, n):
        rng = np.random.default_rng(seed)
        # mostly-zero AC (the realistic case) plus dense noise blocks
        ac = rng.integers(-1000, 1000, (n, 63))
        ac[rng.random((n, 63)) < 0.7] = 0
        dc_diff = rng.integers(-2000, 2000, (n,))
        dec_dc, dec_ac = _roundtrip_blocks(dc_diff, ac)
        np.testing.assert_array_equal(dec_dc, dc_diff)
        np.testing.assert_array_equal(dec_ac, ac)

    @pytest.mark.parametrize("name,dc,acrow", [
        ("all_zero", [0, 0, 0], np.zeros((3, 63), int)),
        ("single_giant_ac_last",
         [5], np.eye(1, 63, 62, dtype=int) * 32767),
        ("single_giant_negative_ac",
         [-32768 + 1], np.eye(1, 63, 40, dtype=int) * -32767),
        ("max_run_zrl",                    # 62 zeros then one coefficient
         [1], np.eye(1, 63, 62, dtype=int) * 3),
        ("alternating_runs",
         [7], np.tile([0, 0, 0, 0, 0, 0, 0, 0, 0, 1], 7)[:63]
         .reshape(1, 63)),
        ("dense_max",                      # no zero anywhere, all max cat
         [100], np.full((1, 63), 255)),
    ])
    def test_adversarial_blocks(self, name, dc, acrow):
        ac = np.asarray(acrow, dtype=np.int64)
        dc_diff = np.asarray(dc, dtype=np.int64)
        dec_dc, dec_ac = _roundtrip_blocks(dc_diff, ac)
        np.testing.assert_array_equal(dec_dc, dc_diff, err_msg=name)
        np.testing.assert_array_equal(dec_ac, ac, err_msg=name)

    def test_amplitude_range_rejected(self):
        with pytest.raises(rle.RangeError):
            rle.symbolize(np.array([2**16]), np.zeros((1, 63), int))
        with pytest.raises(rle.RangeError):
            rle.symbolize(np.array([0]),
                          np.full((1, 63), 40000, dtype=np.int64))

    def test_pack_bits_msb_first_and_one_padded(self):
        out = bitio.pack_bits(np.array([0b101, 0b1]),
                              np.array([3, 1]))
        assert out == bytes([0b10111111])
        reader = bitio.BitReader(out)
        assert reader.take(3) == 0b101 and reader.take(1) == 1

    def test_bitreader_truncation_raises(self):
        reader = bitio.BitReader(b"\xff")
        reader.take(8)
        with pytest.raises(bitio.TruncatedStream):
            reader.take(1)


class TestHuffman:
    def test_canonical_codes_are_prefix_free_and_ordered(self):
        t = huffman.build_table(np.array([0, 50, 30, 10, 5, 3, 2]))
        codes = t.code_lengths()
        strs = [format(c, f"0{l}b") for c, l in codes]
        for i, a in enumerate(strs):
            for b in strs[i + 1:]:
                assert not b.startswith(a) and not a.startswith(b)
        # more frequent symbols never get longer codes
        lens = dict(zip(t.symbols, (l for _, l in codes)))
        assert lens[1] <= lens[6]

    def test_single_symbol_table(self):
        t = huffman.build_table(np.eye(1, 256, 7).ravel())
        assert t.symbols == (7,) and t.code_lengths() == [(0, 1)]

    def test_length_limit_16(self):
        # fibonacci-ish frequencies force depth > 16 before limiting
        freqs = np.zeros(40)
        a, b = 1, 1
        for s in range(40):
            freqs[s] = a
            a, b = b, a + b
        t = huffman.build_table(freqs)
        assert max(l for _, l in t.code_lengths()) <= 16

    def test_segment_roundtrip_and_validation(self):
        t = huffman.build_table(np.array([5, 3, 2, 1]))
        seg = t.to_segment()
        t2, off = huffman.CanonicalTable.from_segment(seg)
        assert t2 == t and off == len(seg)
        with pytest.raises(huffman.InvalidTable):
            huffman.CanonicalTable.from_segment(seg[:10])
        with pytest.raises(huffman.InvalidTable):   # Kraft overfull
            huffman.CanonicalTable(counts=(4,) + (0,) * 15,
                                   symbols=(1, 2, 3, 4))


class TestContainer:
    def test_bit_exact_against_quantised_path(self):
        # the acceptance criterion: decode(encode(img, q)) reproduces the
        # quantised-roundtrip reconstruction bit-exactly, bench images
        # included (sizes cut down for test speed)
        for gen, (h, w) in ((images.lena_like, (96, 96)),
                            (images.lena_like, (96, 102)),   # non-8-divisible
                            (images.cablecar_like, (64, 48))):
            img = gen(h, w)
            for q in (10, 50, 90):
                c = codec.compress(img, q)
                blob = c.to_bytes()
                rec_bytes = np.asarray(decode_image(blob))
                rec_array = np.asarray(codec.decompress(c))
                np.testing.assert_array_equal(rec_bytes, rec_array)

    def test_qcoeffs_lossless_and_header_fields(self):
        img = images.cablecar_like(72, 80)
        c = codec.compress(img, 30, "cordic")
        blob = c.to_bytes()
        qc, hdr = decode_qcoeffs(blob)
        np.testing.assert_array_equal(np.asarray(qc), np.asarray(c.qcoeffs))
        assert hdr["quality"] == 30 and hdr["transform"] == "cordic"
        assert (hdr["height"], hdr["width"]) == (72, 80)
        assert read_header(blob) == hdr

    def test_measured_nbytes_and_ratio(self):
        img = images.lena_like(128, 128)
        c = codec.compress(img, 50)
        assert c.nbytes == len(c.to_bytes())
        assert c.compression_ratio() == 128 * 128 / c.nbytes
        assert c.nbytes < 128 * 128          # actually compresses

    def test_from_bytes_equals_original(self):
        img = images.lena_like(64, 64)
        c = codec.compress(img, 50)
        c2 = codec.CompressedImage.from_bytes(c.to_bytes())
        assert c2.quality == 50 and c2.orig_shape == (64, 64)
        assert c2.to_bytes() == c.to_bytes()   # re-encode is stable

    @pytest.mark.parametrize("mutate,match", [
        (lambda b: b[:10], "truncated header"),
        (lambda b: b"JUNK" + b[4:], "not a DCTZ"),
        (lambda b: b[:4] + bytes([99]) + b[5:], "version"),
        (lambda b: b[:7] + bytes([9]) + b[8:], "transform"),
        (lambda b: b[:16] + bytes([3]) + b[17:], "table id"),
        (lambda b: b[:len(b) - 8], "truncated payload"),
        (lambda b: b + b"x", "trailing"),
        (lambda b: b[:-4] + bytes([b[-4] ^ 0xFF]) + b[-3:], "CRC"),
        # header fields after the magic are CRC-protected too: a flipped
        # quality bit must not dequantise plausibly with the wrong table
        (lambda b: b[:6] + bytes([b[6] ^ 1]) + b[7:], "CRC"),
    ])
    def test_malformed_streams_rejected_with_clear_errors(self, mutate,
                                                          match):
        blob = encode_image(images.lena_like(40, 40), 50)
        with pytest.raises(BitstreamError, match=match):
            decode_qcoeffs(mutate(blob))

    def test_crafted_huge_shape_rejected_before_allocation(self):
        # a crafted header with a valid CRC but an absurd shape must be
        # rejected by the block-count bound, not die in np allocation
        import struct
        import zlib
        blob = bytearray(encode_image(images.lena_like(40, 40), 50))
        struct.pack_into("<II", blob, 8, 0xFFFFFF00, 0xFFFFFF00)
        crc = zlib.crc32(bytes(blob[4:24]) + bytes(blob[28:]))
        struct.pack_into("<I", blob, 24, crc & 0xFFFFFFFF)
        with pytest.raises(BitstreamError, match="cannot hold"):
            decode_qcoeffs(bytes(blob))

    def test_encode_validates_inputs(self):
        qc = np.zeros((2, 2, 8, 8), np.int32)
        with pytest.raises(ValueError, match="quality"):
            encode_qcoeffs(qc, 0, "exact", (16, 16))
        with pytest.raises(ValueError, match="transform"):
            encode_qcoeffs(qc, 50, "dst", (16, 16))
        with pytest.raises(ValueError, match="block grid"):
            encode_qcoeffs(qc, 50, "exact", (64, 64))

    def test_bpp_monotone_in_quality(self):
        img = images.lena_like(96, 96)
        sizes = [len(encode_image(img, q)) for q in (10, 50, 90)]
        assert sizes[0] < sizes[1] < sizes[2]


class TestEngineBytePath:
    def test_stacked_and_ragged_match_single_image_bytes(self):
        from repro.serve import codec_engine
        stacked = np.stack([images.lena_like(64, 64, seed=i)
                            for i in range(3)])
        blobs = codec_engine.encode_batch(stacked, 50)
        assert blobs == [codec.compress(stacked[i], 50).to_bytes()
                         for i in range(3)]
        rag = [images.lena_like(64, 72), images.cablecar_like(40, 40)]
        blobs = codec_engine.encode_batch(rag, 70)
        assert blobs == [codec.compress(im, 70).to_bytes() for im in rag]

    def test_decode_batch_bit_exact_mixed_streams(self):
        from repro.serve import codec_engine
        blobs = [encode_image(images.lena_like(64, 72), 50),
                 encode_image(images.cablecar_like(40, 40), 30),
                 encode_image(images.lena_like(64, 72, seed=2), 50)]
        recs = codec_engine.decode_batch(blobs)
        for blob, rec in zip(blobs, recs):
            np.testing.assert_array_equal(np.asarray(rec),
                                          np.asarray(decode_image(blob)))
        with pytest.raises(ValueError):
            codec_engine.decode_batch([])
