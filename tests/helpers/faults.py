"""Deterministic fault-plan injection for service tests.

The real implementation lives in :mod:`repro.serve.chaos` so the
``service_chaos`` bench (which runs with only ``src`` on the path) can
use the identical fault engine; this module re-exports it for tests and
keeps the test-facing import path stable next to ``flaky.py`` (whose
``EchoEngine``/``FlakyEngine`` remain the simple per-call stubs — use
:class:`ChaosEngine` when a test needs a scripted multi-phase plan).
"""

from repro.serve.chaos import (  # noqa: F401
    ChaosEngine,
    FaultPhase,
    FaultPlan,
    InjectedFault,
    WorkerKilled,
    dctz_crc_ok,
)

__all__ = ["ChaosEngine", "FaultPhase", "FaultPlan", "InjectedFault",
           "WorkerKilled", "dctz_crc_ok"]
