"""Shared test fixtures and fault-injection harnesses."""
