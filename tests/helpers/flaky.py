"""Fault-injection engine wrappers for the serving-layer tests.

:class:`FlakyEngine` wraps any service engine callable (``(images,
quality) -> list[bytes]``; e.g. the real
:func:`repro.serve.service.default_engine` or the cheap
:class:`EchoEngine`) and injects configurable faults *around* the call:

* **failures** — raise on chosen call indices (``fail_calls``) or with
  a seeded probability (``fail_rate``), with a configurable exception
  type,
* **latency** — sleep before delegating (``latency_s``), either on
  every call or only on chosen indices (``slow_calls``),
* **short returns** — drop streams from the result
  (``short_return_calls``) to exercise the service's
  wrong-batch-length check.

Every call is recorded in :attr:`FlakyEngine.calls` as ``(n_images,
quality)`` so tests can assert batching behaviour (occupancy, retries
absent, etc.).  The wrapper is deliberately synchronous — it runs in
the service's engine thread pool exactly like the real engine.
"""

from __future__ import annotations

import hashlib
import random
import threading
import time


class InjectedEngineError(RuntimeError):
    """Default fault raised by :class:`FlakyEngine`."""


class EchoEngine:
    """Deterministic stand-in engine: digest-derived bytes per image.

    Encodes nothing, but keeps the properties tests rely on: output is
    a pure function of (image bytes, shape, quality), so "same request
    twice -> same payload" and cache-identity assertions hold without
    paying for the real codec.
    """

    def __init__(self, step_s: float = 0.0):
        self.step_s = step_s
        self.calls: list = []
        self._lock = threading.Lock()

    def __call__(self, images, quality: int):
        with self._lock:
            self.calls.append((len(images), quality))
        if self.step_s:
            time.sleep(self.step_s)
        out = []
        for im in images:
            h = hashlib.sha1(im.tobytes())
            h.update(repr((im.shape, quality)).encode())
            out.append(h.digest())
        return out


class FlakyEngine:
    """Configurable failure/latency injection around an engine callable.

    Args:
        inner: the wrapped engine callable.
        fail_calls: 0-based call indices that raise instead of encoding.
        fail_rate: probability in [0, 1] that any call raises (seeded).
        latency_s: sleep this long before each delegated call.
        slow_calls: if given, ``latency_s`` applies only to these call
            indices (others run at full speed).
        short_return_calls: call indices whose result drops its last
            stream (simulates an engine returning too few payloads).
        exc_type: exception class for injected failures.
        seed: RNG seed for ``fail_rate`` draws.
    """

    def __init__(self, inner, *, fail_calls=(), fail_rate: float = 0.0,
                 latency_s: float = 0.0, slow_calls=None,
                 short_return_calls=(), exc_type=InjectedEngineError,
                 seed: int = 0):
        self.inner = inner
        self.fail_calls = frozenset(fail_calls)
        self.fail_rate = fail_rate
        self.latency_s = latency_s
        self.slow_calls = (None if slow_calls is None
                           else frozenset(slow_calls))
        self.short_return_calls = frozenset(short_return_calls)
        self.exc_type = exc_type
        self.calls: list = []
        self.failures = 0
        self._rng = random.Random(seed)
        self._lock = threading.Lock()

    def __call__(self, images, quality: int):
        with self._lock:
            idx = len(self.calls)
            self.calls.append((len(images), quality))
            fail = (idx in self.fail_calls
                    or (self.fail_rate > 0
                        and self._rng.random() < self.fail_rate))
            if fail:
                self.failures += 1
        slow = self.latency_s and (self.slow_calls is None
                                   or idx in self.slow_calls)
        if slow:
            time.sleep(self.latency_s)
        if fail:
            raise self.exc_type(f"injected failure on engine call {idx}")
        out = self.inner(images, quality)
        if idx in self.short_return_calls:
            out = out[:-1]
        return out
