"""examples/dctz_cli.py: exit codes and diagnostics on corrupt streams.

The CLI is the shell-facing edge of the failure model: ``info`` and
``decode`` must exit nonzero with a one-line ``error:`` diagnostic on
any malformed stream (so pipelines can gate on corruption), and
``decode --verify-crc`` must catch a CRC mismatch before parsing.
"""

import importlib.util
import pathlib
import sys

import numpy as np
import pytest

_CLI = pathlib.Path(__file__).resolve().parents[1] / "examples" \
    / "dctz_cli.py"
_spec = importlib.util.spec_from_file_location("dctz_cli", _CLI)
cli = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(cli)

from repro.core import entropy  # noqa: E402


@pytest.fixture
def stream(tmp_path):
    img = (np.arange(32 * 32).reshape(32, 32) % 251).astype(np.uint8)
    path = tmp_path / "img.dctz"
    path.write_bytes(entropy.encode_image(img, 50, "exact"))
    return path


def _run(argv, capsys):
    sys_argv, sys.argv = sys.argv, ["dctz_cli.py", *argv]
    try:
        rc = cli.main()
    finally:
        sys.argv = sys_argv
    out = capsys.readouterr()
    return rc, out.out, out.err


def _flip(path, offset=40):
    blob = bytearray(path.read_bytes())
    blob[offset] ^= 0xFF
    bad = path.with_suffix(".bad.dctz")
    bad.write_bytes(bytes(blob))
    return bad


class TestInfo:
    def test_clean_stream_exits_zero(self, stream, capsys):
        rc, out, err = _run(["info", str(stream)], capsys)
        assert rc == 0 and "crc=ok" in out and err == ""

    def test_crc_mismatch_exits_nonzero(self, stream, capsys):
        bad = _flip(stream)
        rc, out, err = _run(["info", str(bad)], capsys)
        assert rc == 1
        assert "crc=MISMATCH" in out          # header still printable
        assert err.startswith("error:") and "CRC mismatch" in err
        assert len(err.strip().splitlines()) == 1

    def test_truncated_header_exits_nonzero(self, stream, capsys):
        bad = stream.with_suffix(".trunc.dctz")
        bad.write_bytes(stream.read_bytes()[:10])
        rc, out, err = _run(["info", str(bad)], capsys)
        assert rc == 1 and err.startswith("error:")
        assert "truncated" in err


class TestDecode:
    def test_clean_round_trip(self, stream, tmp_path, capsys):
        out_path = tmp_path / "rec.npy"
        rc, out, err = _run(
            ["decode", str(stream), str(out_path), "--verify-crc"],
            capsys)
        assert rc == 0 and "crc ok" in out and err == ""
        assert np.load(out_path).shape == (32, 32)

    def test_corrupt_stream_exits_nonzero(self, stream, tmp_path,
                                          capsys):
        bad = _flip(stream)
        out_path = tmp_path / "rec.npy"
        rc, out, err = _run(["decode", str(bad), str(out_path)], capsys)
        assert rc == 1 and err.startswith("error:")
        assert "CRC mismatch" in err
        assert not out_path.exists()          # nothing written on error

    def test_verify_crc_catches_before_parse(self, stream, tmp_path,
                                             capsys):
        bad = _flip(stream)
        rc, out, err = _run(
            ["decode", str(bad), str(tmp_path / "r.npy"),
             "--verify-crc"], capsys)
        assert rc == 1 and "CRC mismatch" in err
        assert "header says" in err           # stored digest named

    def test_truncated_stream_exits_nonzero(self, stream, tmp_path,
                                            capsys):
        bad = stream.with_suffix(".trunc.dctz")
        bad.write_bytes(stream.read_bytes()[:40])
        rc, out, err = _run(
            ["decode", str(bad), str(tmp_path / "r.npy")], capsys)
        assert rc == 1 and err.startswith("error:")
