"""Tile-invariance property tests: the gate that makes autotuning safe.

For every pow2 candidate the autotuner may select
(:data:`repro.bench.autotune.CANDIDATES`), the image kernels must be
**bit-exact** across tile sizes and the bit-stream kernels must stay
byte/error-identical to their scalar references at non-default
``tile_bits`` — so a tuning artifact can only ever change speed, never
output.  Plus the :func:`repro.kernels.common.pick_tile` boundary
behaviour the routers rely on (dims 8/16, non-pow2 padded shapes,
dim <= 0 rejection)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.bench.autotune import CANDIDATES
from repro.kernels import common

# Non-pow2 sizes pad to tile multiples inside the routers (100 -> 104);
# kept small so the full candidate sweep stays tier-1 fast.
IMAGE_SIZES = (24, 64, 100)
REFERENCE_TILE = 256


def _image(size: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.uniform(0.0, 255.0, (size, size)).astype(np.float32)


# ---------------------------------------------------------------------------
# Image kernels: bit-exact across every tile candidate
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.sampled_from(CANDIDATES["dct8x8"]),
       st.sampled_from(IMAGE_SIZES), st.integers(0, 3))
def test_dct8x8_tile_invariant(tile, size, seed):
    from repro.kernels.dct8x8 import ops
    x = _image(size, seed)
    want = np.asarray(ops.dct8x8(x, tile=REFERENCE_TILE))
    got = np.asarray(ops.dct8x8(x, tile=tile))
    assert np.array_equal(got, want), f"dct8x8 tile={tile} size={size}"
    coeffs = want
    want_inv = np.asarray(ops.idct8x8(coeffs, tile=REFERENCE_TILE))
    got_inv = np.asarray(ops.idct8x8(coeffs, tile=tile))
    assert np.array_equal(got_inv, want_inv), \
        f"idct8x8 tile={tile} size={size}"


@settings(max_examples=12, deadline=None)
@given(st.sampled_from(CANDIDATES["cordic_loeffler"]),
       st.sampled_from(IMAGE_SIZES), st.integers(0, 3))
def test_cordic_loeffler_tile_invariant(tile, size, seed):
    from repro.kernels.cordic_loeffler import ops
    x = _image(size, seed)
    want = np.asarray(ops.cordic_loeffler_dct(x, tile=REFERENCE_TILE))
    got = np.asarray(ops.cordic_loeffler_dct(x, tile=tile))
    assert np.array_equal(got, want), f"cordic tile={tile} size={size}"


@settings(max_examples=12, deadline=None)
@given(st.sampled_from(CANDIDATES["fused_codec"]),
       st.sampled_from(IMAGE_SIZES), st.integers(0, 3))
def test_fused_codec_tile_invariant(tile, size, seed):
    from repro.kernels.fused_codec import ops
    x = _image(size, seed)
    want_rec, want_qc = ops.fused_codec(x, tile=REFERENCE_TILE)
    got_rec, got_qc = ops.fused_codec(x, tile=tile)
    assert np.array_equal(np.asarray(got_rec), np.asarray(want_rec)), \
        f"fused_codec rec tile={tile} size={size}"
    assert np.array_equal(np.asarray(got_qc), np.asarray(want_qc)), \
        f"fused_codec qc tile={tile} size={size}"


# ---------------------------------------------------------------------------
# pack_bits: byte-identical to the scalar reference at every tile_bits
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.sampled_from(CANDIDATES["pack_bits"]),
       st.integers(0, 400), st.integers(0, 3))
def test_pack_bits_tile_bits_invariant(tile_bits, n_fields, seed):
    from repro.core.entropy import bitio
    from repro.kernels.pack_bits import ops
    rng = np.random.default_rng(seed * 1000 + n_fields)
    lengths = rng.integers(0, 17, n_fields)         # zero-width included
    codes = rng.integers(0, 1 << 16, n_fields) & ((1 << lengths) - 1)
    want = bitio.pack_bits(codes, lengths)
    got = ops.pack_bits(codes, lengths, backend="pallas",
                        tile_bits=tile_bits, interpret=True)
    assert got == want, f"pack_bits tile_bits={tile_bits} n={n_fields}"


# ---------------------------------------------------------------------------
# unpack_bits: value- and error-identical to the scalar oracle
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def entropy_payload():
    """One real entropy stream (image -> zig-zag -> symbols -> payload)."""
    from repro.bench.cases import _entropy_stage_inputs
    (_, _, _, payload, (dc_t, ac_t),
     n_blocks) = _entropy_stage_inputs(32)
    return payload, n_blocks, dc_t, ac_t


def _outcome(fn, *args, **kw):
    from repro.core.entropy import bitio
    try:
        dc, ac = fn(*args, **kw)
        return ("ok", dc.tobytes(), ac.tobytes())
    except (bitio.TruncatedStream, ValueError) as e:
        return (type(e).__name__, str(e))


@pytest.mark.parametrize("tile_bits", CANDIDATES["unpack_bits"])
def test_unpack_bits_tile_bits_invariant(tile_bits, entropy_payload):
    from repro.core.entropy import rle
    from repro.kernels.unpack_bits import ops
    payload, n_blocks, dc_t, ac_t = entropy_payload
    want = _outcome(rle.decode_payload_reference, payload, n_blocks,
                    dc_t, ac_t)
    got = _outcome(ops.unpack_bits, payload, n_blocks, dc_t, ac_t,
                   backend="pallas", tile_bits=tile_bits, interpret=True)
    assert got == want, f"unpack_bits tile_bits={tile_bits}"


@pytest.mark.parametrize("tile_bits", (CANDIDATES["unpack_bits"][0],
                                       CANDIDATES["unpack_bits"][-1]))
def test_unpack_bits_truncation_errors_tile_invariant(tile_bits,
                                                      entropy_payload):
    from repro.core.entropy import rle
    from repro.kernels.unpack_bits import ops
    payload, n_blocks, dc_t, ac_t = entropy_payload
    for cut in (0, len(payload) // 2, len(payload) - 1):
        want = _outcome(rle.decode_payload, payload[:cut], n_blocks,
                        dc_t, ac_t)
        got = _outcome(ops.unpack_bits, payload[:cut], n_blocks, dc_t,
                       ac_t, backend="pallas", tile_bits=tile_bits,
                       interpret=True)
        assert got == want, \
            f"unpack_bits tile_bits={tile_bits} truncated at byte {cut}"


# ---------------------------------------------------------------------------
# symbolize: element-identical to the scalar oracle at every tile_blocks
# ---------------------------------------------------------------------------

def _blocks(n: int, seed: int):
    rng = np.random.default_rng(seed)
    dc_diff = rng.integers(-1024, 1025, n)
    ac = rng.integers(-255, 256, (n, 63))
    ac[rng.uniform(size=ac.shape) < 0.85] = 0     # realistic sparsity
    return dc_diff, ac


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(CANDIDATES["symbolize"]),
       st.integers(1, 40), st.integers(0, 3))
def test_symbolize_tile_blocks_invariant(tile_blocks, n, seed):
    from repro.core.entropy import rle
    from repro.kernels.symbolize import ops
    dc_diff, ac = _blocks(n, seed)
    want = rle.symbolize_reference(dc_diff, ac)
    got = ops.symbolize(dc_diff, ac, backend="pallas",
                        tile_blocks=tile_blocks, interpret=True)
    for w, g in zip(want, got):
        assert w.dtype == g.dtype and np.array_equal(w, g), \
            f"symbolize tile_blocks={tile_blocks} n={n}"
    dense = ops.symbolize_dense(dc_diff, ac, backend="pallas",
                                tile_blocks=tile_blocks, interpret=True)
    dc_freq, ac_freq = rle.symbol_frequencies(want[0], want[1])
    assert np.array_equal(dense.dc_freq, dc_freq)
    assert np.array_equal(dense.ac_freq, ac_freq)


# ---------------------------------------------------------------------------
# grad_dct: bit-exact across every block_rows candidate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_rows", CANDIDATES["grad_dct"])
def test_grad_dct_block_rows_invariant(block_rows):
    from repro.kernels import grad_dct as gd
    rng = np.random.default_rng(block_rows)
    g = rng.standard_normal(200 * gd.BLOCK + 9).astype(np.float32)
    ref_rows = CANDIDATES["grad_dct"][-1]
    want = gd.encode(g, block_rows=ref_rows, interpret=True)
    got = gd.encode(g, block_rows=block_rows, interpret=True)
    assert np.array_equal(np.asarray(got.q), np.asarray(want.q))
    assert np.array_equal(np.asarray(got.scale), np.asarray(want.scale))
    assert np.array_equal(np.asarray(got.tail), np.asarray(want.tail))
    want_g = np.asarray(gd.decode(want, block_rows=ref_rows,
                                  interpret=True))
    got_g = np.asarray(gd.decode(want, block_rows=block_rows,
                                 interpret=True))
    assert np.array_equal(got_g, want_g), \
        f"grad_dct decode block_rows={block_rows}"


def test_grad_dct_routes_tuned_block_rows(tmp_path, monkeypatch):
    # block_rows=None must consult the tuning artifact, like the other
    # kernel routers
    import json

    from repro.kernels import grad_dct as gd
    from repro.kernels import tuning
    doc = tuning.make_doc([{"kernel": "grad_dct", "bucket": 256,
                            "params": {"block_rows": 64},
                            "best_us": 1.0}], backend="cpu")
    p = tmp_path / "tuning.json"
    p.write_text(json.dumps(doc))
    monkeypatch.setenv("REPRO_TUNING_PATH", str(p))
    tuning.invalidate_cache()
    try:
        seen = {}
        real = gd.ops.kernel.grad_dct_encode_pallas

        def spy(body, c, *, keep, block_rows, interpret):
            seen["block_rows"] = block_rows
            return real(body, c, keep=keep, block_rows=block_rows,
                        interpret=interpret)

        monkeypatch.setattr(gd.ops.kernel, "grad_dct_encode_pallas", spy)
        g = np.ones(200 * gd.BLOCK, np.float32)
        gd.encode(g, interpret=True)
        assert seen["block_rows"] == 64
    finally:
        tuning.invalidate_cache()


# ---------------------------------------------------------------------------
# pick_tile boundary behaviour (the contract the routers rely on)
# ---------------------------------------------------------------------------

class TestPickTile:
    def test_dim_8(self):
        assert common.pick_tile(8) == 8
        assert common.pick_tile(8, target=8) == 8

    def test_dim_16(self):
        assert common.pick_tile(16) == 16
        assert common.pick_tile(16, target=8) == 8

    def test_non_pow2_padded_shapes(self):
        # 100 pads to 104 = 8 * 13: only 8, 104 divide it
        assert common.pick_tile(104, target=64) == 8
        assert common.pick_tile(104, target=104) == 104
        # 200 = 8 * 25: largest divisor <= 100 that is a multiple of 8
        assert common.pick_tile(200, target=100) == 40
        assert common.pick_tile(200) == 200

    def test_target_below_multiple_returns_multiple(self):
        # the tile must stay a multiple of 8 even when the target is
        # smaller: the worst case the docstring pins
        assert common.pick_tile(64, target=4) == 8
        assert common.pick_tile(64, target=0) == 8

    def test_nonpositive_dim_rejected(self):
        with pytest.raises(ValueError, match="positive"):
            common.pick_tile(0)
        with pytest.raises(ValueError, match="positive"):
            common.pick_tile(-8)

    def test_non_multiple_dim_rejected(self):
        with pytest.raises(ValueError, match="multiple"):
            common.pick_tile(12)

    def test_every_candidate_yields_valid_tile(self):
        # any pow2 target the autotuner may route resolves to a tile
        # that divides the padded dim — for every padded image size
        from repro.bench.autotune import CANDIDATES
        for size in (8, 16, 64, 104, 200, 256):
            for target in CANDIDATES["dct8x8"]:
                t = common.pick_tile(size, target)
                assert size % t == 0 and t % 8 == 0 and t <= max(target, 8)
