"""Serving engine + DCT KV-cache compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.models import registry as M
from repro.serve import engine, kv_compress
from repro.serve.engine import ServeConfig

CFG = R.reduced("smollm-360m", n_layers=2, d_model=64, vocab_size=128)


def test_generate_shapes_and_determinism():
    params = M.init_params(CFG, jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1), (3, 16), 0, 128)
    out1 = engine.generate(CFG, params, prompts, 8,
                           ServeConfig(max_len=64))
    out2 = engine.generate(CFG, params, prompts, 8,
                           ServeConfig(max_len=64))
    assert out1.shape == (3, 8)
    assert (np.asarray(out1) == np.asarray(out2)).all()  # greedy


def test_prefill_then_decode_matches_one_shot():
    params = M.init_params(CFG, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(2), (2, 12), 0, 128)
    # one-shot logits at the last position
    full, _, _ = M.apply(CFG, params, {"tokens": toks}, mode="prefill")
    cache = M.init_cache(CFG, batch=2, max_len=16)
    prefill = engine.make_prefill(CFG)
    logits, cache = prefill(params, toks, cache)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(full[:, -1], np.float32),
                               atol=5e-4, rtol=1e-3)


class TestKVCompress:
    """DCT KV compression exploits *temporal* redundancy; tests use
    slowly-varying prompts (runs of repeated tokens), the synthetic
    analogue of real text's correlation.  White-noise prompts do not
    compact — that is physics, not a bug (see serve/kv_compress.py)."""

    def _filled_cache(self, t=130, structured=True):
        params = M.init_params(CFG, jax.random.key(0))
        if structured:
            base = jax.random.randint(jax.random.key(3),
                                      (2, t // 16 + 1), 0, 128)
            toks = jnp.repeat(base, 16, axis=1)[:, :t]
        else:
            toks = jax.random.randint(jax.random.key(3), (2, t), 0, 128)
        cache = M.init_cache(CFG, batch=2, max_len=t + 8)
        prefill = engine.make_prefill(CFG)
        _, cache = prefill(params, toks, cache)
        return params, toks, cache

    def test_roundtrip_error_small(self):
        _, _, cache = self._filled_cache()
        ckv, tails = kv_compress.compress_cache(cache, keep=32,
                                                prefix_len=130)
        rec = kv_compress.reconstruct_cache(ckv, tails)
        for p in cache:
            a = np.asarray(cache[p][:, :, :128], np.float32)
            b = np.asarray(rec[p][:, :, :128], np.float32)
            rel = np.linalg.norm(a - b) / (np.linalg.norm(a) + 1e-9)
            assert rel < 0.2, (p, rel)
            # tail is exact
            np.testing.assert_array_equal(
                np.asarray(cache[p][:, :, 128:]),
                np.asarray(rec[p][:, :, 128:]))

    def test_wire_bytes_reduction(self):
        _, _, cache = self._filled_cache(256)
        raw = sum(v.size * v.dtype.itemsize for v in cache.values())
        ckv, tails = kv_compress.compress_cache(cache, keep=16,
                                                prefix_len=256)
        comp = kv_compress.wire_bytes(ckv, tails)
        assert raw / comp > 6.0

    def test_decode_logit_drift_bounded(self):
        params, toks, cache = self._filled_cache()
        step_fn = engine.make_decode_step(CFG)
        key = jax.random.key(0)
        tok = toks[:, -1:]
        idx = jnp.asarray(130, jnp.int32)
        # exact cache step
        nxt_a, _ = step_fn(params, tok, cache, idx, key)
        # compressed cache step
        ckv, tails = kv_compress.compress_cache(cache, keep=48,
                                                prefix_len=130)
        cache_c = kv_compress.reconstruct_cache(ckv, tails)
        nxt_b, _ = step_fn(params, tok, cache_c, idx, key)
        # greedy tokens agree at keep=48 on structured content
        agree = float((nxt_a == nxt_b).mean())
        assert agree >= 0.99

    def test_more_coeffs_less_error(self):
        _, _, cache = self._filled_cache()
        errs = []
        for keep in (8, 24, 56):
            ckv, tails = kv_compress.compress_cache(cache, keep=keep,
                                                    prefix_len=130)
            rec = kv_compress.reconstruct_cache(ckv, tails)
            p = "k"
            a = np.asarray(cache[p][:, :, :128], np.float32)
            b = np.asarray(rec[p][:, :, :128], np.float32)
            errs.append(np.linalg.norm(a - b))
        assert errs[0] > errs[1] > errs[2]
