"""Tuned-tile artifact robustness: schema round-trip, fallback-with-one-
warning on every failure mode (missing / corrupt / wrong version /
backend mismatch), bucket precedence, and the ops.py routing seam."""

import json
import threading
import warnings

import numpy as np
import pytest

from repro.kernels import tuning


@pytest.fixture
def tuning_path(tmp_path, monkeypatch):
    """Point the loader at a per-test artifact path, cache cleared on
    both sides so no test sees another's artifact or warning history."""
    path = tmp_path / "tuning.json"
    monkeypatch.setenv(tuning.ENV_VAR, str(path))
    tuning.invalidate_cache()
    yield path
    tuning.invalidate_cache()


def _entry(kernel="dct8x8", bucket=64, value=32):
    return {"kernel": kernel, "bucket": bucket,
            "params": {tuning.PARAM_OF[kernel]: value}, "best_us": 123.0}


def _write(path, entries, backend="cpu", **doc_overrides):
    doc = tuning.make_doc(entries, backend=backend,
                          environment={"git_sha": "abc1234"})
    doc.update(doc_overrides)
    path.write_text(json.dumps(doc))


def _no_warnings(fn):
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = fn()
    assert [str(x.message) for x in w] == []
    return out


# ---------------------------------------------------------------------------
# Schema round-trip + validation
# ---------------------------------------------------------------------------

def test_artifact_roundtrip(tuning_path):
    entries = [_entry("dct8x8", 64, 32), _entry("pack_bits", 4096, 512)]
    written = tuning.save(tuning.make_doc(entries, backend="cpu"),
                          tuning_path)
    assert written == tuning_path
    assert tuning.validate(json.loads(tuning_path.read_text())) == entries
    assert tuning.lookup("dct8x8", 64, backend="cpu") == {"tile": 32}
    assert tuning.lookup("pack_bits", 4000, backend="cpu") == {
        "tile_bits": 512}


@pytest.mark.parametrize("mutate, msg", [
    (lambda d: d.update(schema_version=999), "schema_version"),
    (lambda d: d.pop("backend"), "backend"),
    (lambda d: d.update(entries="nope"), "entries"),
    (lambda d: d["entries"].append({"kernel": "warp_drive", "bucket": 64,
                                    "params": {"tile": 32}}),
     "unknown kernel"),
    (lambda d: d["entries"].append(_entry(bucket=48)), "pow2"),
    (lambda d: d["entries"].append(_entry(value=48)), "pow2"),
    (lambda d: d["entries"].append({"kernel": "dct8x8", "bucket": 64,
                                    "params": {}}), "lacks param"),
])
def test_validate_rejects(mutate, msg):
    doc = tuning.make_doc([_entry()], backend="cpu")
    mutate(doc)
    with pytest.raises(ValueError, match=msg):
        tuning.validate(doc)


def test_bucket_of_pow2_ceiling():
    assert tuning.bucket_of(1) == 8
    assert tuning.bucket_of(8) == 8
    assert tuning.bucket_of(9) == 16
    assert tuning.bucket_of(256) == 256
    assert tuning.bucket_of(257) == 512


# ---------------------------------------------------------------------------
# Fallback-with-one-warning on every failure mode
# ---------------------------------------------------------------------------

def _assert_single_warning_then_silence(match):
    with pytest.warns(tuning.TuningWarning, match=match):
        assert tuning.lookup("dct8x8", 64, backend="cpu") is None
    # the second lookup must be silent (one warning per failure reason)
    assert _no_warnings(
        lambda: tuning.lookup("dct8x8", 64, backend="cpu")) is None
    # and tile_for falls back to the built-in default
    assert tuning.tile_for("dct8x8", 64, backend="cpu") == \
        tuning.DEFAULTS["dct8x8"]["tile"]


def test_missing_file_falls_back(tuning_path):
    _assert_single_warning_then_silence("no tuning artifact")


def test_corrupt_json_falls_back(tuning_path):
    tuning_path.write_text("{not json!")
    _assert_single_warning_then_silence("rejected")


def test_wrong_schema_version_falls_back(tuning_path):
    _write(tuning_path, [_entry()], schema_version=999)
    _assert_single_warning_then_silence("schema_version")


def test_invalid_entries_fall_back(tuning_path):
    _write(tuning_path, [_entry()])
    doc = json.loads(tuning_path.read_text())
    doc["entries"][0]["bucket"] = 48
    tuning_path.write_text(json.dumps(doc))
    _assert_single_warning_then_silence("rejected")


def test_backend_mismatch_falls_back(tuning_path):
    _write(tuning_path, [_entry()], backend="tpu")
    _assert_single_warning_then_silence("backend")


def test_valid_artifact_loads_silently(tuning_path):
    _write(tuning_path, [_entry("dct8x8", 64, 16)])
    assert _no_warnings(
        lambda: tuning.lookup("dct8x8", 64, backend="cpu")) == {"tile": 16}
    assert tuning.tile_for("dct8x8", 64, backend="cpu") == 16


def test_unknown_kernel_lookup_raises(tuning_path):
    with pytest.raises(KeyError, match="unknown kernel"):
        tuning.lookup("warp_drive", 64, backend="cpu")


# ---------------------------------------------------------------------------
# Bucket precedence
# ---------------------------------------------------------------------------

def test_bucket_precedence_smallest_covering_else_largest(tuning_path):
    _write(tuning_path, [_entry("dct8x8", 64, 16),
                         _entry("dct8x8", 256, 128)])
    # exact bucket
    assert tuning.tile_for("dct8x8", 64, backend="cpu") == 16
    # dim 100 -> bucket 128: smallest swept bucket >= 128 is 256
    assert tuning.tile_for("dct8x8", 100, backend="cpu") == 128
    # below the smallest bucket: the 64 sweep covers it
    assert tuning.tile_for("dct8x8", 10, backend="cpu") == 16
    # beyond the largest bucket: nearest (largest) swept entry applies
    assert tuning.tile_for("dct8x8", 4096, backend="cpu") == 128
    # a kernel with no entries keeps its built-in default, silently
    assert _no_warnings(
        lambda: tuning.tile_for("unpack_bits", 4096, backend="cpu")) == \
        tuning.DEFAULTS["unpack_bits"]["tile_bits"]


def test_concurrent_lookups_consistent(tuning_path):
    _write(tuning_path, [_entry("dct8x8", 64, 32)])
    got, errs = [], []

    def hit():
        try:
            got.append(tuning.tile_for("dct8x8", 64, backend="cpu"))
        except Exception as e:  # pragma: no cover - failure path
            errs.append(e)

    threads = [threading.Thread(target=hit) for _ in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs and got == [32] * 16


# ---------------------------------------------------------------------------
# The ops.py routing seam: tile=None consults the artifact
# ---------------------------------------------------------------------------

def test_dct8x8_routes_tuned_tile(tuning_path, monkeypatch):
    from repro.kernels.dct8x8 import kernel, ops
    _write(tuning_path, [_entry("dct8x8", 64, 16)])
    seen = {}
    real = kernel.dct8x8_pallas

    def spy(x, t, *, tile_h, tile_w, **kw):
        seen["tile"] = (tile_h, tile_w)
        return real(x, t, tile_h=tile_h, tile_w=tile_w, **kw)

    monkeypatch.setattr(kernel, "dct8x8_pallas", spy)
    x = np.zeros((64, 64), np.float32)
    ops.dct8x8(x)                       # tile=None -> tuned 16
    assert seen["tile"] == (16, 16)
    ops.dct8x8(x, tile=32)              # explicit tile pins the knob
    assert seen["tile"] == (32, 32)


def test_pack_bits_routes_tuned_tile_bits(tuning_path, monkeypatch):
    from repro.kernels.pack_bits import kernel, ops
    _write(tuning_path, [_entry("pack_bits", 8192, 256)])
    seen = {}
    real = kernel.pack_bits_pallas

    def spy(*args, tile_bits, window, **kw):
        seen["tb"] = (tile_bits, window)
        return real(*args, tile_bits=tile_bits, window=window, **kw)

    monkeypatch.setattr(kernel, "pack_bits_pallas", spy)
    rng = np.random.default_rng(0)
    lengths = rng.integers(1, 17, 300)
    codes = rng.integers(0, 1 << 16, 300) & ((1 << lengths) - 1)
    want = ops.pack_bits(codes, lengths, backend="numpy")
    got = ops.pack_bits(codes, lengths, backend="pallas", interpret=True)
    assert got == want
    assert seen["tb"] == (256, 256 + ops.WINDOW_MARGIN)
    # explicit tile_bits pins the knob
    ops.pack_bits(codes, lengths, backend="pallas", tile_bits=512,
                  interpret=True)
    assert seen["tb"] == (512, 512 + ops.WINDOW_MARGIN)


def test_committed_artifact_is_valid_for_routers():
    """The repo-root results/tuning.json (when present) must validate and
    carry an entry for every kernel, so the routers never warn in CI."""
    import pathlib
    path = pathlib.Path(tuning.__file__).resolve().parents[3] \
        / "results" / "tuning.json"
    if not path.exists():
        pytest.skip("no committed tuning artifact")
    doc = json.loads(path.read_text())
    entries = tuning.validate(doc)
    assert {e["kernel"] for e in entries} == set(tuning.KERNELS)
