"""Benchmark subsystem: registry resolution, artifact schema round-trip,
RESULTS.md golden snippets, and an end-to-end smoke run of the paper
pipeline at its smallest grid."""

import json

import numpy as np
import pytest

from repro.bench import registry, report, runner, schema
from repro.bench.cases import check_monotone, check_rd_monotone
from repro.bench.timer import TimerConfig, Timing, measure

PAPER_TABLE_CASES = ("table1_lena", "table2_cablecar", "table3_psnr_lena",
                     "table4_psnr_cablecar")


# ---------------------------------------------------------------------------
# Registry resolution
# ---------------------------------------------------------------------------

def test_registry_has_paper_tables_and_serve_cases():
    cases = registry.all_cases()
    for name in PAPER_TABLE_CASES + ("rate_distortion",
                                     "entropy_throughput",
                                     "serve_batch_throughput",
                                     "serve_ragged", "framework_micro",
                                     "roofline"):
        assert name in cases
    # each paper table declares which table it feeds
    assert cases["table1_lena"].table == "Table 1"
    assert cases["table4_psnr_cablecar"].table == "Table 4"


@pytest.mark.parametrize("suite", ("smoke", "paper", "full"))
def test_suites_contain_all_paper_tables(suite):
    names = {c.name for c in registry.resolve(suite)}
    assert set(PAPER_TABLE_CASES) <= names


def test_smoke_excludes_micro_and_micro_excludes_tables():
    assert "framework_micro" not in {
        c.name for c in registry.resolve("smoke")}
    assert {c.name for c in registry.resolve("micro")} == {"framework_micro"}


def test_resolve_unknown_suite_and_case():
    with pytest.raises(KeyError):
        registry.resolve("nope")
    with pytest.raises(KeyError):
        registry.get("not_a_benchmark")
    with pytest.raises(KeyError):
        registry.resolve("smoke", names=["framework_micro"])  # not a member


def test_name_filter_preserves_request_order():
    picked = registry.resolve("paper", names=["table2_cablecar",
                                              "table1_lena"])
    assert [c.name for c in picked] == ["table2_cablecar", "table1_lena"]


def test_duplicate_registration_rejected():
    registry.all_cases()        # ensure cases.py has self-registered
    with pytest.raises(ValueError):
        registry.benchmark("table1_lena")(lambda ctx: [])
    with pytest.raises(ValueError):
        registry.benchmark("x", suites=("paper", "bogus"))


# ---------------------------------------------------------------------------
# Timer
# ---------------------------------------------------------------------------

def test_measure_counts_calls_and_blocks():
    calls = []
    t = measure(lambda: calls.append(1), warmup=2, iters=3)
    assert len(calls) == 5
    assert isinstance(t, Timing) and t.iters == 3
    assert t.best_us <= t.median_us


def test_timer_config_scaled():
    base = TimerConfig(warmup=2, iters=5)
    assert base.scaled(iters=1) == TimerConfig(2, 1)
    assert base.scaled() == base


# ---------------------------------------------------------------------------
# Artifact schema round-trip
# ---------------------------------------------------------------------------

def _fake_result(name="table1_lena", suite="paper"):
    rec = schema.BenchRecord(
        label="lena_512x512",
        params={"height": 512, "width": 512, "image": "lena",
                "transform": "exact", "quality": 50},
        timings_us={"parallel": {"median_us": 3902.7, "best_us": 3800.1,
                                 "iters": 3},
                    "serial": {"median_us": 28865.0, "best_us": 28001.5,
                               "iters": 3}},
        metrics={"speedup": 7.4, "mpix_per_s": 67.2})
    return schema.BenchResult(
        name=name, suite=suite, records=[rec],
        environment={"backend": "cpu", "device_count": 1,
                     "jax_version": "0", "git_sha": "abc1234",
                     "timestamp_utc": "2026-07-30T00:00:00Z"})


def test_schema_write_load_roundtrip(tmp_path):
    result = _fake_result()
    path = schema.save(result, tmp_path)
    assert path == tmp_path / "table1_lena.json"
    loaded = schema.load(path)
    assert loaded.to_json() == result.to_json()
    # and the round-tripped artifact still renders
    assert "Table 1" in report.render([loaded])


def test_schema_version_mismatch_rejected(tmp_path):
    blob = _fake_result().to_json()
    blob["schema_version"] = 999
    p = tmp_path / "old.json"
    p.write_text(json.dumps(blob))
    with pytest.raises(ValueError, match="schema_version"):
        schema.load(p)


def test_load_many_sorts_by_name(tmp_path):
    for name in ("zzz_case", "aaa_case"):
        schema.save(_fake_result(name=name), tmp_path)
    names = [r.name for r in schema.load_many(
        sorted(tmp_path.glob("*.json")))]
    assert names == ["aaa_case", "zzz_case"]


# ---------------------------------------------------------------------------
# RESULTS.md rendering (golden snippets)
# ---------------------------------------------------------------------------

def test_render_golden_snippet_timing_table():
    md = report.render([_fake_result()])
    assert "## Table 1 — DCT codec time vs Lena image size" in md
    # 28865.0us -> 28.865ms, 3902.7us -> 3.903ms
    assert "| lena | 512x512 | 28.865 | 3.903 | 7.4x | 67.2 |" in md
    assert "backend=`cpu`" in md and "git=`abc1234`" in md


def test_render_golden_snippet_psnr_table():
    rec = schema.BenchRecord(
        label="cablecar_320x288",
        params={"height": 320, "width": 288, "image": "cablecar",
                "quality": 50},
        metrics={"psnr_db_exact": 33.682, "psnr_db_cordic": 31.2,
                 "gap_db": 2.482})
    result = schema.BenchResult(name="table4_psnr_cablecar", suite="paper",
                                records=[rec], environment={})
    md = report.render([result])
    assert "## Table 4 — PSNR, exact DCT vs Cordic-Loeffler (Cable-car)" \
        in md
    assert "| cablecar | 320x288 | 33.682 | 31.200 | 2.482 |" in md


def test_render_empty_rejected_and_unknown_listed():
    with pytest.raises(ValueError):
        report.render([])
    odd = schema.BenchResult(name="mystery", suite="paper",
                             records=[], environment={})
    assert "`mystery`" in report.render([odd])


def test_timing_legs_handle_non_block_aligned_sizes():
    # the paper's full Lena grid includes 1024x814 (not divisible by 8);
    # both legs must pad rather than crash
    from repro.bench.cases import _timing_records
    recs = _timing_records(
        [(40, 26)], lambda h, w: np.zeros((h, w), "uint8"), "lena",
        registry.RunContext(suite="full", timer=TimerConfig(0, 1)))
    assert recs[0].label == "lena_40x26"
    assert recs[0].metrics["speedup"] > 0


def test_check_monotone():
    assert check_monotone({1: 10.0, 2: 20.0, 4: 30.0, 128: 1.0}) == []
    assert check_monotone({1: 10.0, 2: 5.0, 4: 30.0}) == [(1, 2)]


def test_render_golden_snippet_rd_table():
    rec = schema.BenchRecord(
        label="lena_200x200_q50",
        params={"height": 200, "width": 200, "image": "lena",
                "quality": 50, "transform": "exact", "nbytes": 2041},
        timings_us={"encode": {"median_us": 12000.0, "best_us": 11000.0,
                               "iters": 3},
                    "decode": {"median_us": 9000.0, "best_us": 8000.0,
                               "iters": 3}},
        metrics={"bpp": 0.4082, "compression_ratio": 19.6,
                 "psnr_db": 37.598, "enc_mpix_per_s": 3.3,
                 "dec_mpix_per_s": 4.4})
    md = report.render([schema.BenchResult(
        name="rate_distortion", suite="paper", records=[rec],
        environment={})])
    assert "## Rate–distortion (measured bytes)" in md
    assert "| lena | 200x200 | 50 | 0.408 | 19.6x | 37.60 " \
           "| 12.000 | 9.000 |" in md


def test_render_golden_snippet_entropy_table():
    stage = schema.BenchRecord(
        label="entropy_stage_256",
        params={"height": 256, "width": 256, "image": "lena",
                "quality": 50, "n_blocks": 1024, "payload_nbytes": 2786},
        timings_us={"enc_vectorized": {"median_us": 2000.0,
                                       "best_us": 1900.0, "iters": 5},
                    "enc_reference": {"median_us": 18000.0,
                                      "best_us": 17000.0, "iters": 2},
                    "dec_vectorized": {"median_us": 8000.0,
                                       "best_us": 7000.0, "iters": 5},
                    "dec_reference": {"median_us": 40000.0,
                                      "best_us": 39000.0, "iters": 2}},
        metrics={"enc_speedup": 9.0, "dec_speedup": 5.0,
                 "enc_mb_per_s": 32.8, "dec_mb_per_s": 8.2})
    batch = schema.BenchRecord(
        label="batch_8",
        params={"batch": 8, "height": 256, "width": 256, "quality": 50,
                "nbytes": 22288},
        timings_us={"encode_pipelined": {"median_us": 20000.0,
                                         "best_us": 19000.0, "iters": 5},
                    "encode_serial": {"median_us": 30000.0,
                                      "best_us": 29000.0, "iters": 2},
                    "decode_pipelined": {"median_us": 50000.0,
                                         "best_us": 49000.0, "iters": 5},
                    "decode_serial": {"median_us": 45000.0,
                                      "best_us": 44000.0, "iters": 2}},
        metrics={"enc_img_per_s": 400.0, "enc_img_per_s_serial": 266.7,
                 "dec_img_per_s": 160.0, "dec_img_per_s_serial": 177.8,
                 "enc_mb_per_s": 26.2, "speedup_vs_reference": 7.5})
    md = report.render([schema.BenchResult(
        name="entropy_throughput", suite="paper", records=[stage, batch],
        environment={})])
    assert "## Entropy throughput (vectorized host coding)" in md
    assert "| encode | 2.000 | 18.000 | 9.0x | 32.8 |" in md
    assert "| 8 | 400.0 | 266.7 | 160.0 | 26.2 | 7.50x |" in md


def test_entropy_identity_gate_and_adversarial_blocks():
    from repro.bench.cases import (adversarial_blocks,
                                   entropy_identity_violations)
    # the gate must pass on the shipped implementation ...
    assert entropy_identity_violations(trials=3) == []
    # ... and its adversarial set must cover the documented corners:
    # a ZRL chain (zero run >= 16), an all-zero block, max amplitudes
    blocks = adversarial_blocks()
    assert any((ac == 0).all() for _, ac in blocks)
    assert any(np.abs(ac).max() == 32767 for _, ac in blocks)
    longest_run = 0
    for _, ac in blocks:
        for row in ac:
            nz = np.nonzero(row)[0]
            if nz.size:
                longest_run = max(longest_run, int(nz[0]))
    assert longest_run >= 16


def test_render_golden_snippet_tuning_table():
    rec = schema.BenchRecord(
        label="dct8x8_b256",
        params={"kernel": "dct8x8", "bucket": 256, "tile": 128,
                "candidates": [64, 128, 256]},
        timings_us={"tile_64": {"median_us": 900.0, "best_us": 880.0,
                                "iters": 3},
                    "tile_128": {"median_us": 500.0, "best_us": 480.0,
                                 "iters": 3},
                    "tile_256": {"median_us": 700.0, "best_us": 690.0,
                                 "iters": 3}},
        metrics={"best_us": 500.0, "speedup_vs_default": 1.4})
    md = report.render([schema.BenchResult(
        name="autotune", suite="paper", records=[rec],
        environment={"backend": "cpu"})])
    assert "## Kernel tile autotuning" in md
    assert "| dct8x8 | 256 | tile=128 | 0.500 | 1.40x | 3 |" in md


def test_render_golden_snippet_roofline_table():
    rec = schema.BenchRecord(
        label="dct8x8",
        params={"kernel": "dct8x8", "height": 256, "width": 256},
        timings_us={"routed": {"median_us": 2000.0, "best_us": 1900.0,
                               "iters": 3}},
        metrics={"flops": 2.1e6, "bytes_accessed": 2.6e6,
                 "achieved_gflop_s": 1.05, "achieved_gb_s": 1.31,
                 "frac_peak_flops": 5.33e-6, "frac_peak_bw": 1.6e-3,
                 "intensity_flop_per_byte": 0.81, "compute_bound": 0.0})
    bits = schema.BenchRecord(
        label="pack_bits",
        params={"kernel": "pack_bits", "payload_bits": 32768,
                "entropy_size": 128, "fields": 4000},
        timings_us={"routed": {"median_us": 800.0, "best_us": 790.0,
                               "iters": 3}},
        metrics={"flops": 0.0, "bytes_accessed": 52096.0,
                 "achieved_gflop_s": 0.0, "achieved_gb_s": 0.065,
                 "frac_peak_flops": 0.0, "frac_peak_bw": 7.9e-5,
                 "intensity_flop_per_byte": 0.0, "compute_bound": 0.0})
    md = report.render([schema.BenchResult(
        name="roofline", suite="paper", records=[rec, bits],
        environment={})])
    assert "## Kernel roofline (achieved vs peak)" in md
    assert "| dct8x8 | 256x256 | 2.000 | 1.05 | 1.31 " in md
    assert "| pack_bits | 32768 bits | 0.800 | 0.00 | 0.07 " in md
    assert "| memory |" in md


def test_default_artifacts_excludes_tuning_json(tmp_path):
    schema.save(_fake_result(), tmp_path)
    (tmp_path / "tuning.json").write_text("{}")
    paths = runner.default_artifacts(tmp_path)
    assert [p.name for p in paths] == ["table1_lena.json"]
    # ... so a report glob over a tuned results/ tree never crashes
    assert "Table 1" in report.render(schema.load_many(paths))


def test_autotune_sweep_machinery():
    """The sweep->entries->artifact pipeline on a fake candidate runner
    (no kernel timing): winner selection, record layout, tuning schema."""
    from repro.bench import autotune
    from repro.bench.timer import TimerConfig
    from repro.kernels import tuning

    fake_us = {8: 300.0, 16: 100.0, 32: 200.0}
    calls = []

    def run_candidate(tile):
        calls.append(tile)

    import repro.bench.timer as timer_mod
    real_measure = autotune.measure
    try:
        autotune.measure = lambda fn, cand, warmup, iters: (
            fn(cand) or timer_mod.Timing(median_us=fake_us[cand],
                                         best_us=fake_us[cand], iters=iters))
        rec = autotune._sweep_one(
            "dct8x8", 64, (8, 16, 32), run_candidate,
            TimerConfig(warmup=1, iters=2), lambda *_: None,
            extra_params={"image_hw": 64})
    finally:
        autotune.measure = real_measure

    assert calls == [8, 16, 32]
    assert rec.params["tile"] == 16 and rec.metrics["best_us"] == 100.0
    assert set(rec.timings_us) == {"tile_8", "tile_16", "tile_32"}
    entries = autotune.tuning_entries([rec])
    doc = tuning.make_doc(entries, backend="cpu")
    assert tuning.validate(doc)[0] == {
        "kernel": "dct8x8", "bucket": 64, "params": {"tile": 16},
        "best_us": 100.0}


def test_cli_has_autotune_subcommand():
    from repro.bench import cli
    args = cli.build_parser().parse_args(
        ["autotune", "--smoke", "--out", "r/"])
    assert args.fn is cli._cmd_autotune
    assert args.smoke and args.out == "r/"


def test_check_rd_monotone():
    good = [(10, 0.1, 30.0), (50, 0.4, 37.0), (90, 1.5, 40.0)]
    assert check_rd_monotone(good) == []
    # out-of-order input is sorted by quality before checking
    assert check_rd_monotone(list(reversed(good))) == []
    bad = [(10, 0.5, 30.0), (50, 0.4, 29.0)]
    assert check_rd_monotone(bad) == [("bpp", 10, 50), ("psnr", 10, 50)]


# ---------------------------------------------------------------------------
# End-to-end: smoke run of the paper pipeline at its smallest grid
# ---------------------------------------------------------------------------

def test_smoke_suite_end_to_end(tmp_path):
    out = tmp_path / "results"
    paths = runner.run_suite("smoke", out_dir=out, log=lambda *_: None)
    assert {p.name for p in paths} >= {f"{n}.json"
                                       for n in PAPER_TABLE_CASES}
    results = schema.load_many(paths)
    for r in results:
        assert r.suite == "smoke"
        assert r.records, f"{r.name} produced no records"
        assert r.environment["device_count"] >= 1

    md_path = report.write_results(results, tmp_path / "RESULTS.md")
    md = md_path.read_text()
    for title in ("## Table 1", "## Table 2", "## Table 3", "## Table 4",
                  "## Rate–distortion (measured bytes)",
                  "## Entropy throughput (vectorized host coding)",
                  "## Batch throughput", "## Ragged mixed-size batches",
                  "## Kernel roofline (achieved vs peak)"):
        assert title in md, f"missing section {title}"
    # sanity on reproduced physics: PSNR gap is positive (exact > cordic)
    t3 = next(r for r in results if r.name == "table3_psnr_lena")
    assert t3.records[0].metrics["gap_db"] > 0


def test_cli_report_from_artifacts(tmp_path, capsys):
    from repro.bench import cli
    schema.save(_fake_result(), tmp_path)
    md = tmp_path / "R.md"
    rc = cli.main(["report", str(tmp_path / "table1_lena.json"),
                   "--md", str(md)])
    assert rc == 0 and "Table 1" in md.read_text()
    rc = cli.main(["report", "--results-dir", str(tmp_path / "empty")])
    assert rc == 1
