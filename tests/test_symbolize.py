"""Symbolize kernel triplet: oracle identity, routing, and the
histogram -> table-negotiation -> bytes chain.

The load-bearing property (the last test class): for every routed
symbolize backend the device/staged histograms equal the host
histograms **bit-for-bit** as int64 arrays, therefore
:func:`repro.core.entropy.huffman.build_table_memo` — keyed on the raw
histogram bytes — returns the *identical* memoised table object,
therefore ``tables="auto"`` negotiates the same table ids and the
framed ``DCTZ`` streams come out byte-identical.  That chain is what
lets the engine swap symbolize backends per request without ever
changing the wire format.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.entropy import container, huffman, rle
from repro.kernels.symbolize import MAX_DEVICE_BLOCKS, ops
from repro.kernels.symbolize import ref as sref

BACKENDS = ("numpy", "pallas")


def _backend_kwargs(backend):
    # interpret=True keeps the Pallas leg runnable off-TPU
    return {"backend": backend, "interpret": True}


DENSITIES = (0.02, 0.15, 0.6)


def _rand_blocks(n, seed, density, max_mag=255):
    rng = np.random.default_rng(seed)
    dc_diff = rng.integers(-max_mag, max_mag + 1, n)
    ac = rng.integers(-max_mag, max_mag + 1, (n, 63))
    ac[rng.uniform(size=ac.shape) >= density] = 0
    return dc_diff, ac


def _adversarial():
    """Hand-built blocks hitting every structural edge at once."""
    rows = [
        np.zeros(63, np.int64),                      # all-zero: DC + EOB
        np.r_[np.zeros(62, np.int64), 7],            # 3 ZRLs, no EOB
        np.ones(63, np.int64),                       # dense, no runs
        np.r_[5, np.zeros(61, np.int64), -1],        # leading + trailing
        np.full(63, 32767, np.int64),                # max 15-bit amplitude
        np.full(63, -32767, np.int64),
    ]
    ac = np.stack(rows)
    dc = np.array([0, 32767, -32767, 1, -1, 16], np.int64)
    return dc, ac


# ---------------------------------------------------------------------------
# stream/element identity against the scalar oracle
# ---------------------------------------------------------------------------

class TestOracleIdentity:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 10), st.integers(0, 2**31 - 1),
           st.sampled_from(DENSITIES))
    def test_staged_ref_matches_oracle(self, n, seed, density):
        dc_diff, ac = _rand_blocks(n, seed, density)
        want = rle.symbolize_reference(dc_diff, ac)
        got = sref.symbolize_ref(dc_diff, ac)
        for w, g in zip(want, got):
            assert w.dtype == g.dtype
            assert np.array_equal(w, g)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 6), st.integers(0, 2**31 - 1),
           st.sampled_from(DENSITIES))
    def test_routed_backends_match_oracle(self, n, seed, density):
        dc_diff, ac = _rand_blocks(n, seed, density)
        want = rle.symbolize_reference(dc_diff, ac)
        for backend in BACKENDS:
            got = ops.symbolize(dc_diff, ac, **_backend_kwargs(backend))
            for w, g in zip(want, got):
                assert w.dtype == g.dtype
                assert np.array_equal(w, g)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_adversarial_blocks(self, backend):
        dc_diff, ac = _adversarial()
        want = rle.symbolize_reference(dc_diff, ac)
        got = ops.symbolize(dc_diff, ac, **_backend_kwargs(backend))
        for w, g in zip(want, got):
            assert np.array_equal(w, g)

    def test_empty_stream(self):
        dc_diff = np.zeros(0, np.int64)
        ac = np.zeros((0, 63), np.int64)
        want = rle.symbolize_reference(dc_diff, ac)
        got = ops.symbolize(dc_diff, ac, backend="numpy")
        for w, g in zip(want, got):
            assert w.dtype == g.dtype and w.shape == g.shape

    def test_shape_mismatch_rejected(self):
        with pytest.raises(ValueError, match="does not match"):
            sref.symbolize_dense(np.zeros(2, np.int64),
                                 np.zeros((3, 63), np.int64))


# ---------------------------------------------------------------------------
# range guards: oracle-exact RangeError from every backend
# ---------------------------------------------------------------------------

class TestRangeErrors:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_dc_overflow_message_identical(self, backend):
        dc = np.array([1 << 15], np.int64)
        ac = np.zeros((1, 63), np.int64)
        with pytest.raises(rle.RangeError) as oracle:
            rle.symbolize_reference(dc, ac)
        with pytest.raises(rle.RangeError) as routed:
            ops.symbolize(dc, ac, **_backend_kwargs(backend))
        assert str(routed.value) == str(oracle.value)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_ac_overflow_message_identical(self, backend):
        dc = np.zeros(1, np.int64)
        ac = np.zeros((1, 63), np.int64)
        ac[0, 5] = -(1 << 15)
        with pytest.raises(rle.RangeError) as oracle:
            rle.symbolize_reference(dc, ac)
        with pytest.raises(rle.RangeError) as routed:
            ops.symbolize(dc, ac, **_backend_kwargs(backend))
        assert str(routed.value) == str(oracle.value)


# ---------------------------------------------------------------------------
# routing and guard fallbacks
# ---------------------------------------------------------------------------

class TestRouting:
    def test_auto_is_numpy_off_tpu(self):
        import jax
        want = "pallas" if jax.default_backend() == "tpu" else "numpy"
        assert ops.select_backend("auto") == want

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="backend"):
            ops.select_backend("cuda")

    def test_oversized_batch_falls_back_to_ref(self):
        # past the device ceiling the pallas route must still answer —
        # via the staged host pass — with oracle-identical output
        n = MAX_DEVICE_BLOCKS + 1
        dc_diff = np.ones(n, np.int64)
        ac = np.zeros((n, 63), np.int64)
        ac[:, 0] = -3
        want = rle.symbolize_reference(dc_diff, ac)
        got = ops.symbolize(dc_diff, ac, backend="pallas", interpret=True)
        for w, g in zip(want, got):
            assert np.array_equal(w, g)


# ---------------------------------------------------------------------------
# the symbolizer protocol: histograms -> memoised tables -> bytes
# ---------------------------------------------------------------------------

class TestTableNegotiationChain:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 6), st.integers(0, 2**31 - 1),
           st.sampled_from(DENSITIES))
    def test_histograms_bit_for_bit_and_memo_key_identity(self, n, seed,
                                                          density):
        dc_diff, ac = _rand_blocks(n, seed, density)
        is_dc, syms, _, _ = rle.symbolize_reference(dc_diff, ac)
        host_dc, host_ac = rle.symbol_frequencies(is_dc, syms)
        for backend in BACKENDS:
            dense = ops.symbolize_dense(dc_diff, ac,
                                        **_backend_kwargs(backend))
            for got, want in ((dense.dc_freq, host_dc),
                              (dense.ac_freq, host_ac)):
                got = np.asarray(got)
                assert got.dtype == np.int64
                assert np.array_equal(got, want)
            # bit-identical int64 histograms -> identical memo key ->
            # build_table_memo returns the very same table object, so
            # "auto" negotiation cannot diverge between backends
            assert (huffman.build_table_memo(dense.dc_freq)
                    is huffman.build_table_memo(host_dc))
            assert (huffman.build_table_memo(dense.ac_freq)
                    is huffman.build_table_memo(host_ac))

    @settings(max_examples=8, deadline=None)
    @given(st.integers(1, 6), st.integers(0, 2**31 - 1),
           st.sampled_from(DENSITIES))
    def test_auto_negotiated_streams_byte_identical(self, n, seed,
                                                    density):
        dc_diff, ac = _rand_blocks(n, seed, density, max_mag=100)
        dc = np.cumsum(dc_diff)
        z = np.concatenate([dc[:, None], ac], axis=1)
        shape = (8, 8 * n)                       # 1 x n block grid
        want = container.encode_zigzag_host(z, 50, "exact", shape,
                                            tables="auto")
        hdr = container.read_header(want)
        for backend in BACKENDS:
            symbolizer = ops.make_symbolizer(backend, interpret=True)
            got = container.encode_zigzag_host(z, 50, "exact", shape,
                                               tables="auto",
                                               symbolizer=symbolizer)
            got_hdr = container.read_header(got)
            assert (got_hdr["dc_table_id"], got_hdr["ac_table_id"]) == \
                (hdr["dc_table_id"], hdr["ac_table_id"])
            assert got == want
        qc, _ = container.decode_qcoeffs(want)
        assert qc.shape == (1, n, 8, 8)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_payload_matches_encode_payload(self, backend):
        dc_diff, ac = _adversarial()
        # clamp to keep every amplitude codable by the standard tables
        ac = np.clip(ac, -1023, 1023)
        dc_diff = np.clip(dc_diff, -1023, 1023)
        stream = rle.symbolize_reference(dc_diff, ac)
        dc_t = huffman.DEFAULT_TABLES.get(huffman.STANDARD_DC_LUMA_ID)
        ac_t = huffman.DEFAULT_TABLES.get(huffman.STANDARD_AC_LUMA_ID)
        want = rle.encode_payload(*stream, dc_t, ac_t)
        prep = ops.make_symbolizer(backend, interpret=True)(dc_diff, ac)
        assert prep.payload(dc_t, ac_t) == want

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_uncodable_symbol_error_identical(self, backend):
        # a table that cannot code the stream must raise the same
        # ValueError as rle.codeword_fields
        dc_diff = np.array([3], np.int64)
        ac = np.zeros((1, 63), np.int64)
        tiny = huffman.build_table(
            np.bincount([rle.EOB], minlength=256))  # codes only EOB
        stream = rle.symbolize_reference(dc_diff, ac)
        with pytest.raises(ValueError) as oracle:
            rle.encode_payload(*stream, tiny, tiny)
        prep = ops.make_symbolizer(backend, interpret=True)(dc_diff, ac)
        with pytest.raises(ValueError) as routed:
            prep.payload(tiny, tiny)
        assert str(routed.value) == str(oracle.value)
