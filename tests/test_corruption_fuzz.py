"""Byte-flip / truncation fuzz over DCTZ containers, across backends.

Every mutation of a valid v1 (embedded tables) or v2 (shared tables)
stream must be rejected with :class:`BitstreamError` — never an
IndexError, struct.error or a wrong-shaped "success" — and the three
payload-decode backends (the scalar LUT walk, the staged NumPy
reference and the Pallas kernel in interpret mode) must agree on the
outcome.  The CRC-repair tests re-seal the container after the flip so
the corrupt bits actually reach the entropy decoders instead of being
stopped at the framing check; that path is exactly what the service's
``validate_payload`` hook and the chaos bench's corruption phase rely
on (docs/serving.md).

Runs against real hypothesis when installed, or the deterministic
seeded stub from conftest.py in the hermetic container.
"""

import struct
import zlib

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import entropy
from repro.core.entropy import container
from repro.kernels import unpack_bits
from repro.kernels.unpack_bits import ref as unpack_ref


def _stream(tables: str) -> bytes:
    rng = np.random.default_rng(7)
    z = np.zeros((9, 64), np.int64)
    z[:, 0] = rng.integers(-300, 300, 9)
    nz = rng.random((9, 63)) < 0.2
    z[:, 1:][nz] = rng.integers(-40, 40, int(nz.sum()))
    return entropy.encode_zigzag_host(z, 50, "exact", (24, 24),
                                      tables=tables)


STREAMS = {
    "v1-embedded": _stream("embedded"),
    "v2-shared": _stream("shared"),
}

BACKENDS = {
    "scalar": None,
    "staged": unpack_ref.unpack_bits_ref,
    "pallas-interpret": lambda *a: unpack_bits.unpack_bits(
        *a, backend="pallas", interpret=True),
}


def _decode(data: bytes, unpacker):
    """("ok", z bytes, header tuple) or ("error", exception)."""
    try:
        z, hdr = entropy.decode_zigzag_host(data, unpacker=unpacker)
        return ("ok", z.tobytes(), hdr["height"], hdr["width"])
    except entropy.BitstreamError as exc:
        return ("error", exc)


def _reseal(data: bytes) -> bytes:
    """Recompute the CRC so a mutated body passes the framing check."""
    crc = zlib.crc32(data[4:24] + data[container.HEADER_NBYTES:])
    return data[:24] + struct.pack("<I", crc & 0xFFFFFFFF) + data[28:]


def _agree(data: bytes):
    """Decode with every backend; assert they agree, return one result."""
    results = {name: _decode(data, up) for name, up in BACKENDS.items()}
    kinds = {name: r[0] for name, r in results.items()}
    assert len(set(kinds.values())) == 1, f"backends disagree: {kinds}"
    first = results["scalar"]
    if first[0] == "ok":
        for name, r in results.items():
            assert r == first, f"{name} decoded different values"
    return first


class TestVariantsAreValid:
    def test_both_streams_round_trip(self):
        for name, data in STREAMS.items():
            kind, *_ = _agree(data)
            assert kind == "ok", name
        assert entropy.read_header(STREAMS["v1-embedded"])["version"] == 1
        assert entropy.read_header(STREAMS["v2-shared"])["version"] == 2


class TestRawMutations:
    """Mutations of the sealed container: the CRC / framing layer must
    reject them all, identically, before any backend runs."""

    @settings(max_examples=40)
    @given(st.sampled_from(sorted(STREAMS)),
           st.floats(0.0, 0.999999))
    def test_byte_flip_rejected(self, variant, frac):
        data = bytearray(STREAMS[variant])
        data[int(frac * len(data))] ^= 0xFF
        kind, exc = _agree(bytes(data))
        assert kind == "error"
        assert isinstance(exc, entropy.BitstreamError)

    @settings(max_examples=40)
    @given(st.sampled_from(sorted(STREAMS)),
           st.floats(0.0, 0.999999))
    def test_truncation_rejected(self, variant, frac):
        data = STREAMS[variant]
        kind, exc = _agree(data[:int(frac * len(data))])
        assert kind == "error"
        assert isinstance(exc, entropy.BitstreamError)

    @settings(max_examples=20)
    @given(st.sampled_from(sorted(STREAMS)), st.integers(1, 16))
    def test_trailing_garbage_rejected(self, variant, n_extra):
        kind, exc = _agree(STREAMS[variant] + b"\xAA" * n_extra)
        assert kind == "error"
        assert isinstance(exc, entropy.BitstreamError)


class TestResealedMutations:
    """Flips hidden behind a recomputed CRC: the corrupt bits reach the
    entropy decoders, which must either all reject with BitstreamError
    or all decode the same alternative stream (padding-bit flips and
    value-preserving amplitude aliases are legitimately decodable)."""

    @settings(max_examples=60)
    @given(st.sampled_from(sorted(STREAMS)),
           st.floats(0.0, 0.999999), st.integers(1, 255))
    def test_body_flip_outcomes_agree(self, variant, frac, mask):
        data = bytearray(STREAMS[variant])
        body = range(container.HEADER_NBYTES, len(data))
        data[body[int(frac * len(body))]] ^= mask
        kind, *rest = _agree(_reseal(bytes(data)))
        if kind == "error":
            assert isinstance(rest[0], entropy.BitstreamError)

    @settings(max_examples=30)
    @given(st.sampled_from(sorted(STREAMS)),
           st.floats(0.0, 0.999999))
    def test_resealed_payload_truncation_agrees(self, variant, frac):
        data = STREAMS[variant]
        hdr = entropy.read_header(data)
        body_len = len(data) - container.HEADER_NBYTES
        keep = int(frac * hdr["payload_nbytes"])
        cut = data[:len(data) - (hdr["payload_nbytes"] - keep)]
        patched = cut[:20] + struct.pack("<I", keep) + cut[24:]
        kind, *rest = _agree(_reseal(patched))
        if keep and body_len:
            assert kind == "error"
            assert isinstance(rest[0], entropy.BitstreamError)


class TestServiceValidatorConsistency:
    """chaos.dctz_crc_ok — the bench/service payload validator — must
    track verify_crc on every mutation the fuzzers generate."""

    @settings(max_examples=30)
    @given(st.sampled_from(sorted(STREAMS)),
           st.floats(0.0, 0.999999), st.booleans())
    def test_crc_ok_matches_verify(self, variant, frac, truncate):
        from repro.serve.chaos import dctz_crc_ok
        data = bytearray(STREAMS[variant])
        if truncate:
            data = data[:int(frac * len(data))]
        else:
            data[int(frac * len(data))] ^= 0xFF
        blob = bytes(data)
        try:
            want = entropy.verify_crc(blob)
        except entropy.BitstreamError:
            want = False
        assert dctz_crc_ok(blob) is want
        assert dctz_crc_ok(bytes(STREAMS[variant])) is True


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
