"""Routed entropy unpack: the staged NumPy reference and the Pallas
speculative-decode kernel must be coefficient-identical to the scalar
``decode_payload_reference`` oracle on every stream — including the
errors malformed streams raise — mirroring ``pack_bits``' suite on the
encode side."""

import pathlib

import numpy as np
import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.entropy import bitio, huffman, rle
from repro.kernels import unpack_bits
from repro.kernels.unpack_bits import ref as unpack_ref

DATA_DIR = pathlib.Path(__file__).parent / "data"


def _encode(dc_diff, ac, std_tables=True):
    """Blocks -> (payload, dc_table, ac_table)."""
    syms = rle.symbolize(np.asarray(dc_diff, np.int64),
                         np.asarray(ac, np.int64))
    if std_tables:
        dc_t, ac_t = huffman.STANDARD_DC_LUMA, huffman.STANDARD_AC_LUMA
    else:
        dc_f, ac_f = rle.symbol_frequencies(syms[0], syms[1])
        dc_t, ac_t = huffman.build_table(dc_f), huffman.build_table(ac_f)
    return rle.encode_payload(*syms, dc_t, ac_t), dc_t, ac_t


def _random_blocks(rng, n, hi=1000):
    dc = rng.integers(-hi, hi + 1, n)
    ac = np.zeros((n, 63), np.int64)
    for b in range(n):
        k = int(rng.integers(0, 16))
        cols = rng.choice(63, size=k, replace=False)
        ac[b, cols] = rng.integers(-hi, hi + 1, k)
    return dc, ac


class TestUnpackBitsKernel:
    @staticmethod
    def _all(payload, n_blocks, dc_t, ac_t, tile_sizes=(64,)):
        """Every backend must match the scalar oracle exactly."""
        want = rle.decode_payload_reference(payload, n_blocks, dc_t, ac_t)
        outs = [unpack_ref.unpack_bits_ref(payload, n_blocks, dc_t, ac_t)]
        outs += [unpack_ref.unpack_bits_ref(payload, n_blocks, dc_t, ac_t,
                                            tile_bits=tb)
                 for tb in tile_sizes]
        outs.append(unpack_bits.unpack_bits(payload, n_blocks, dc_t, ac_t,
                                            backend="pallas",
                                            interpret=True))
        for dc, ac in outs:
            np.testing.assert_array_equal(dc, want[0])
            np.testing.assert_array_equal(ac, want[1])
        return want

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_random_streams(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 150))
        dc, ac = _random_blocks(rng, n)
        payload, dc_t, ac_t = _encode(dc, ac, std_tables=bool(n % 2))
        self._all(payload, n, dc_t, ac_t)

    def test_empty_and_trivial_blocks(self):
        # zero blocks: empty output, no stream validation (reference
        # semantics), on every backend
        dc_t, ac_t = huffman.STANDARD_DC_LUMA, huffman.STANDARD_AC_LUMA
        for fn in (unpack_ref.unpack_bits_ref,
                   lambda *a: unpack_bits.unpack_bits(
                       *a, backend="pallas", interpret=True)):
            dc, ac = fn(b"\xAB\xCD", 0, dc_t, ac_t)
            assert dc.shape == (0,) and ac.shape == (0, 63)
        # all-zero blocks: DC category 0 + EOB only
        payload, dc_t, ac_t = _encode(np.zeros(9), np.zeros((9, 63)))
        self._all(payload, 9, dc_t, ac_t, tile_sizes=(1, 7))

    def test_all_zrl_chains(self):
        # a lone coefficient at column 62 costs three ZRLs + a run-14
        # symbol; stacking such blocks makes ZRL the dominant unit and
        # exercises the doubling's 16-position hops
        n = 40
        ac = np.zeros((n, 63), np.int64)
        ac[:, 62] = 7
        payload, dc_t, ac_t = _encode(np.zeros(n), ac, std_tables=False)
        self._all(payload, n, dc_t, ac_t, tile_sizes=(33, 64))

    def test_max_category_amplitudes(self):
        # +/-32767 needs category 15 — the widest legal amplitude field
        # (code + 15 bits) and the largest unit advance
        n = 12
        rng = np.random.default_rng(3)
        dc = rng.choice([-32767, 32767], n)
        ac = np.zeros((n, 63), np.int64)
        ac[:, rng.choice(63, 8, replace=False)] = 32767
        ac[:, 0] = -32767
        payload, dc_t, ac_t = _encode(dc, ac, std_tables=False)
        self._all(payload, n, dc_t, ac_t)

    def test_dense_blocks(self):
        # every AC slot nonzero: 64 units per block, the doubling's
        # worst case (chains must terminate by crossing, never EOB)
        n = 6
        rng = np.random.default_rng(4)
        ac = rng.integers(1, 500, (n, 63))
        payload, dc_t, ac_t = _encode(rng.integers(-500, 500, n), ac)
        self._all(payload, n, dc_t, ac_t)

    def test_tile_boundary_straddles(self):
        # blocks whose codewords straddle resolver tile boundaries in
        # every phase: tiny tiles shift the boundary through the chain
        rng = np.random.default_rng(5)
        dc, ac = _random_blocks(rng, 50)
        payload, dc_t, ac_t = _encode(dc, ac)
        self._all(payload, 50, dc_t, ac_t,
                  tile_sizes=(1, 2, 3, 5, 8, 13, 31, 64, 257))

    def test_truncated_streams_rejected_identically(self):
        rng = np.random.default_rng(6)
        dc, ac = _random_blocks(rng, 20)
        payload, dc_t, ac_t = _encode(dc, ac)

        def result(fn):
            try:
                dc_o, ac_o = fn()
                return ("ok", dc_o.tobytes(), ac_o.tobytes())
            except (bitio.TruncatedStream, ValueError) as e:
                return (type(e).__name__, str(e))

        for cut in (0, 1, 2, len(payload) // 2, len(payload) - 1):
            want = result(lambda: rle.decode_payload(
                payload[:cut], 20, dc_t, ac_t))
            for fn in (
                    lambda: unpack_ref.unpack_bits_ref(
                        payload[:cut], 20, dc_t, ac_t),
                    lambda: unpack_ref.unpack_bits_ref(
                        payload[:cut], 20, dc_t, ac_t, tile_bits=17),
                    lambda: unpack_bits.unpack_bits(
                        payload[:cut], 20, dc_t, ac_t, backend="pallas",
                        interpret=True)):
                assert result(fn) == want
        # over-claimed block count walks into the 1-padding: same error
        want = result(lambda: rle.decode_payload(payload, 21, dc_t, ac_t))
        got = result(lambda: unpack_bits.unpack_bits(
            payload, 21, dc_t, ac_t, backend="pallas", interpret=True))
        assert got == want and want[0] != "ok"

    def test_out_of_spec_dc_table_rejected(self):
        # a "DC" table coding symbol 16 is not a magnitude-category
        # alphabet; every backend rejects it up front like the walk
        bad_dc = huffman.build_table(
            np.bincount([0, 1, 16, 16], minlength=17))
        ac_t = huffman.STANDARD_AC_LUMA
        for fn in (rle.decode_payload, unpack_ref.unpack_bits_ref,
                   lambda *a: unpack_bits.unpack_bits(
                       *a, backend="pallas", interpret=True)):
            with pytest.raises(ValueError, match="magnitude-category"):
                fn(b"\x00", 1, bad_dc, ac_t)

    def test_oversize_stream_falls_back_to_reference(self, monkeypatch):
        # payloads past the VMEM guard must quietly take the NumPy path
        from repro.kernels.unpack_bits import ops
        monkeypatch.setattr(ops, "MAX_DEVICE_BITS", 64)
        rng = np.random.default_rng(7)
        dc, ac = _random_blocks(rng, 30)
        payload, dc_t, ac_t = _encode(dc, ac)
        assert len(payload) * 8 > 64
        want = rle.decode_payload_reference(payload, 30, dc_t, ac_t)
        got = unpack_bits.unpack_bits(payload, 30, dc_t, ac_t,
                                      backend="pallas", interpret=True)
        np.testing.assert_array_equal(got[0], want[0])
        np.testing.assert_array_equal(got[1], want[1])

    def test_backend_selection(self):
        # off-TPU "auto" resolves to the NumPy reference
        assert unpack_bits.select_backend("auto") in unpack_bits.BACKENDS
        if jax.default_backend() != "tpu":
            assert unpack_bits.select_backend("auto") == "numpy"
            assert unpack_bits.make_unpacker("auto") is None
        assert unpack_bits.make_unpacker("pallas") is not None
        with pytest.raises(ValueError, match="backend"):
            unpack_bits.select_backend("cuda")

    def test_scratch_is_bounded_by_tile_not_payload(self):
        # the staged decoder's memory claim: scratch saturates at one
        # tile + margin while the LUT walk's tables keep growing
        one_tile = unpack_ref.scratch_nbytes(unpack_ref.TILE_BITS)
        assert unpack_ref.scratch_nbytes(64 * unpack_ref.TILE_BITS) \
            == unpack_ref.scratch_nbytes(8 * unpack_ref.TILE_BITS)
        assert unpack_ref.scratch_nbytes(1 << 22) < 2 * one_tile
        assert rle.walk_table_nbytes(1 << 24) > \
            3 * rle.walk_table_nbytes(1 << 22)


class TestUnpackThroughContainer:
    def test_golden_fixtures_identical_across_backends(self):
        from repro.core import entropy
        for f in sorted(DATA_DIR.glob("*.dctz")):
            data = f.read_bytes()
            z0, h0 = entropy.decode_zigzag_host(data)
            for up in (unpack_bits.make_unpacker("pallas", interpret=True),
                       lambda *a: unpack_bits.unpack_bits(
                           *a, backend="numpy")):
                z1, h1 = entropy.decode_zigzag_host(data, unpacker=up)
                np.testing.assert_array_equal(z0, z1, err_msg=f.name)
                assert h0 == h1

    def test_decode_image_with_unpacker(self):
        from repro.core import entropy, images
        img = np.asarray(images.lena_like(48, 56))
        blob = entropy.encode_image(img, quality=50)
        base = np.asarray(entropy.decode_image(blob))
        routed = np.asarray(entropy.decode_image(
            blob, unpacker=unpack_bits.make_unpacker("pallas",
                                                     interpret=True)))
        np.testing.assert_array_equal(base, routed)
