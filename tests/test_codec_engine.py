"""Batched multi-device codec engine vs the single-image reference."""

import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codec, images, metrics
from repro.serve import codec_engine as eng

TRANSFORMS = ["exact", "loeffler", "cordic"]


def _batch(n=5, h=96, w=102):
    # non-8-divisible width: the paper's 1024x814 case, batched
    return np.stack([images.lena_like(h, w, seed=i) if i % 2 == 0
                     else images.cablecar_like(h, w, seed=i)
                     for i in range(n)])


class TestBatchVsLoop:
    @pytest.mark.parametrize("transform", TRANSFORMS)
    def test_roundtrip_matches_per_image_bitexact(self, transform):
        batch = _batch()
        rec, psnr = eng.roundtrip_batch(batch, 50, transform)
        assert rec.shape == batch.shape and rec.dtype == jnp.uint8
        for i in range(batch.shape[0]):
            ref, p = codec.roundtrip(batch[i], 50, transform)
            np.testing.assert_array_equal(np.asarray(rec[i]),
                                          np.asarray(ref))
            assert abs(psnr[i] - p) < 1e-4

    @pytest.mark.parametrize("transform", TRANSFORMS)
    def test_compress_matches_per_image_qcoeffs(self, transform):
        batch = _batch(n=3, h=64, w=64)
        cb = eng.compress_batch(batch, 50, transform)
        (grp,) = cb.groups
        for i in range(3):
            c = codec.compress(batch[i], 50, transform)
            np.testing.assert_array_equal(np.asarray(grp.qcoeffs[i]),
                                          np.asarray(c.qcoeffs))

    def test_matched_mode_matches_per_image(self):
        batch = _batch(n=3, h=64, w=64)
        cb = eng.compress_batch(batch, 50, "cordic")
        rec = eng.decompress_batch(cb, mode="matched")
        for i in range(3):
            ref = codec.decompress(codec.compress(batch[i], 50, "cordic"),
                                   mode="matched")
            np.testing.assert_array_equal(np.asarray(rec[i]),
                                          np.asarray(ref))

    def test_empty_batch_rejected_cleanly(self):
        with pytest.raises(ValueError, match="empty batch"):
            eng.compress_batch(np.zeros((0, 64, 64), np.uint8))
        with pytest.raises(ValueError, match="empty batch"):
            eng.compress_batch([])

    def test_non_power_of_two_batch_is_padded_and_cropped(self):
        batch = _batch(n=7, h=64, w=64)     # pads to 8 internally
        rec, psnr = eng.roundtrip_batch(batch, 50)
        assert rec.shape == (7, 64, 64)
        assert psnr.shape == (7,)


class TestRagged:
    def test_padding_roundtrip_mixed_sizes(self):
        rag = [images.lena_like(64, 64, seed=0),
               images.cablecar_like(100, 52, seed=1),
               images.lena_like(64, 64, seed=2),
               images.lena_like(200, 178, seed=3)]
        cb = eng.compress_batch(rag, 50)
        # equal buckets grouped: both 64x64 images share one group
        sizes = sorted(len(g.indices) for g in cb.groups)
        assert sizes == [1, 1, 2]
        rec = eng.decompress_batch(cb)
        assert [tuple(r.shape) for r in rec] == [
            (64, 64), (100, 52), (64, 64), (200, 178)]
        for im, r in zip(rag, rec):
            ref, _ = codec.roundtrip(im, 50)
            np.testing.assert_array_equal(np.asarray(r), np.asarray(ref))

    def test_bucketing_bounds_compiled_shapes(self):
        # 63/65/70-wide images all land in the same 64/128 buckets
        rag = [images.lena_like(64, 63, seed=0),
               images.lena_like(60, 65, seed=1),
               images.lena_like(58, 70, seed=2)]
        cb = eng.compress_batch(rag, 50)
        buckets = {(g.qcoeffs.shape[1] * 8, g.qcoeffs.shape[2] * 8)
                   for g in cb.groups}
        assert buckets == {(64, 64), (64, 128)}

    def test_ragged_roundtrip_psnr(self):
        rag = [images.lena_like(96, 96, seed=0),
               images.cablecar_like(120, 88, seed=1)]
        rec, psnr = eng.roundtrip_batch(rag, 50)
        assert len(rec) == 2 and psnr.shape == (2,)
        assert (psnr > 25.0).all()


class TestPsnrParity:
    def test_psnr_range_matches_paper_tables(self):
        # same expectations as tests/test_quant_codec.py, through the engine
        batch = np.stack([images.lena_like(512, 512)])
        _, p = eng.roundtrip_batch(batch, 50)
        assert 28.0 < p[0] < 45.0
        batch2 = np.stack([images.cablecar_like(320, 288)])
        _, p2 = eng.roundtrip_batch(batch2, 50)
        assert 24.0 < p2[0] < 42.0
        assert p2[0] < p[0]

    def test_quality_ordering_batched(self):
        batch = np.stack([images.lena_like(128, 128, seed=i)
                          for i in range(3)])
        psnrs = [eng.roundtrip_batch(batch, q)[1].mean()
                 for q in (10, 50, 90)]
        assert psnrs[0] < psnrs[1] < psnrs[2]

    def test_cordic_gap_in_paper_band_batched(self):
        batch = np.stack([images.lena_like(256, 256, seed=i)
                          for i in range(2)])
        _, pe = eng.roundtrip_batch(batch, 50, "exact")
        _, pc = eng.roundtrip_batch(batch, 50, "cordic")
        gap = pe.mean() - pc.mean()
        assert 0.5 < gap < 4.0, (pe, pc)


@pytest.mark.multidevice
def test_sharded_engine_matches_per_image():
    """The shard_map path (8 emulated devices) stays bit-exact."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import numpy as np
from repro.core import codec, images
from repro.serve import codec_engine as eng
imgs = np.stack([images.lena_like(64, 64, seed=i) for i in range(6)])
rec, psnr = eng.roundtrip_batch(imgs, 50, 'cordic')
for i in range(6):
    ref, p = codec.roundtrip(imgs[i], 50, 'cordic')
    np.testing.assert_array_equal(np.asarray(rec[i]), np.asarray(ref))
    assert abs(psnr[i] - p) < 1e-4
print('TEST-OK')
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, cwd=repo, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "TEST-OK" in r.stdout
