"""Training loop, checkpoint/restart, fault-tolerance behaviour."""

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint
from repro.configs import registry as R
from repro.data.synth import DataConfig, make_batch_fn
from repro.ft.watchdog import StepWatchdog
from repro.optim.adamw import AdamWConfig
from repro.optim.grad_compress import GradCompressConfig
from repro.train import step as step_lib
from repro.train.trainer import Trainer, TrainerConfig

CFG = R.reduced("smollm-360m", n_layers=2, d_model=64, vocab_size=128)
DATA = DataConfig(vocab_size=128, seq_len=32, global_batch=8, seed=0)
OPT = AdamWConfig(lr_peak=3e-3, warmup_steps=5, decay_steps=100)


def test_loss_decreases():
    tr = Trainer(CFG, OPT, TrainerConfig(total_steps=25, log_every=100),
                 make_batch_fn(DATA))
    h = tr.run()
    assert h[-1]["loss"] < h[0]["loss"] - 0.3


def test_microbatch_equivalence():
    bf = make_batch_fn(DATA)
    s1 = step_lib.init_state(CFG, OPT, jax.random.key(1))
    f1 = jax.jit(step_lib.make_train_step(CFG, OPT,
                                          step_lib.TrainStepConfig(1)))
    f2 = jax.jit(step_lib.make_train_step(CFG, OPT,
                                          step_lib.TrainStepConfig(2)))
    o1, _ = f1(s1, bf(0))
    o2, _ = f2(s1, bf(0))
    for k in o1["params"]:
        # Adam's rsqrt amplifies f32 grad-accumulation reorder noise
        np.testing.assert_allclose(np.asarray(o1["params"][k]),
                                   np.asarray(o2["params"][k]), atol=5e-6)


def test_grad_compression_error_feedback_accumulates():
    bf = make_batch_fn(DATA)
    gc = GradCompressConfig(enabled=True, keep=16, min_size=128)
    scfg = step_lib.TrainStepConfig(grad_compress=gc)
    state = step_lib.init_state(CFG, OPT, jax.random.key(2), scfg)
    fn = jax.jit(step_lib.make_train_step(CFG, OPT, scfg))
    state2, _ = fn(state, bf(0))
    # ef became nonzero for large leaves (lossy projection residual)
    big = [k for k, v in state2["ef"].items() if v.size >= 128]
    assert any(float(jnp.abs(state2["ef"][k]).max()) > 0 for k in big)
    # and training still converges comparably
    tr = Trainer(CFG, OPT, TrainerConfig(total_steps=25, log_every=100),
                 bf, step_cfg=scfg)
    h = tr.run()
    assert h[-1]["loss"] < h[0]["loss"] - 0.3


def test_checkpoint_roundtrip_bitwise():
    state = step_lib.init_state(CFG, OPT, jax.random.key(3))
    with tempfile.TemporaryDirectory() as td:
        checkpoint.save(td, 7, state, {"step": 7})
        assert checkpoint.latest_step(td) == 7
        loaded, extra = checkpoint.load(td, 7)
        assert extra["step"] == 7
        for k in state["params"]:
            assert (np.asarray(loaded["params"][k]) ==
                    np.asarray(state["params"][k])).all()


def test_resume_is_bitwise_identical():
    """train 10 straight == train 5, crash, resume 5 — exactly."""
    bf = make_batch_fn(DATA)
    tr_a = Trainer(CFG, OPT, TrainerConfig(total_steps=10, log_every=100),
                   bf, seed=5)
    tr_a.run()
    ref = tr_a.state["params"]

    with tempfile.TemporaryDirectory() as td:
        tcfg = TrainerConfig(total_steps=5, ckpt_dir=td, ckpt_every=5,
                             ckpt_async=False, log_every=100)
        tr_b = Trainer(CFG, OPT, tcfg, bf, seed=5)
        tr_b.run(steps=5)
        # "crash": new trainer instance resumes from disk
        tcfg2 = TrainerConfig(total_steps=10, ckpt_dir=td, ckpt_every=5,
                              ckpt_async=False, log_every=100)
        tr_c = Trainer(CFG, OPT, tcfg2, bf, seed=999)  # seed ignored on resume
        assert tr_c.start_step == 5
        tr_c.run()
        for k in ref:
            np.testing.assert_array_equal(np.asarray(tr_c.state["params"][k]),
                                          np.asarray(ref[k]), err_msg=k)


def test_async_checkpointer_commits_atomically():
    state = {"a": jnp.arange(10), "b": {"c": jnp.ones((3, 3))}}
    with tempfile.TemporaryDirectory() as td:
        ck = checkpoint.AsyncCheckpointer(td, keep=2)
        for step in (1, 2, 3):
            ck.submit(step, state, {"step": step})
        ck.wait()
        ck.close()
        steps = checkpoint.all_steps(td)
        assert steps == [2, 3]  # keep=2 gc'd step 1
        loaded, _ = checkpoint.load(td, 3)
        assert (np.asarray(loaded["b"]["c"]) == 1).all()


def test_corrupt_uncommitted_checkpoint_ignored():
    with tempfile.TemporaryDirectory() as td:
        checkpoint.save(td, 1, {"x": jnp.ones(3)}, {})
        # simulate crash mid-write: directory without COMMITTED sentinel
        os.makedirs(os.path.join(td, "step_00000002"))
        assert checkpoint.latest_step(td) == 1


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(ratio=3.0)
    for i in range(8):
        wd.observe(i, 0.1)
    ev = wd.observe(8, 0.5)
    assert ev is not None and ev.ratio > 3
    assert wd.observe(9, 0.11) is None
    wd.close()


def test_watchdog_hang_detection():
    fired = []
    wd = StepWatchdog(hang_timeout=0.2, on_hang=lambda: fired.append(1))
    time.sleep(0.5)
    wd.close()
    assert fired


def test_data_pipeline_deterministic_and_learnable():
    bf = make_batch_fn(DATA)
    b1, b2 = bf(3), bf(3)
    assert (np.asarray(b1["tokens"]) == np.asarray(b2["tokens"])).all()
    assert not (np.asarray(bf(3)["tokens"]) ==
                np.asarray(bf(4)["tokens"])).all()
    # markov structure: successor entropy lower than marginal entropy
    toks = np.asarray(bf(0)["tokens"])
    pairs = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), []).append(int(b))
    # average successor diversity per token is far below vocab size
    div = np.mean([len(set(v)) / DATA.vocab_size
                   for v in pairs.values() if len(v) >= 3])
    assert div < 0.5
