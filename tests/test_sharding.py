"""Logical sharding rules — unit tests (single device, no mesh needed)."""

import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry as R
from repro.configs.base import SHAPES
from repro.dist import sharding as sh
from repro.launch import specs as specs_lib

pytestmark = pytest.mark.multidevice


def test_constrain_is_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = sh.constrain(x, "batch", "embed")
    assert (y == x).all()


def test_logical_spec_no_mesh_is_empty():
    assert sh.logical_spec(("batch", "embed")) == P()


def test_rules_for_default_and_long():
    cfg = R.get("qwen2.5-14b")
    r = specs_lib.rules_for(cfg, "train_4k")
    assert r["batch"] == ("pod", "data")
    assert r["embed"] == ("pod", "data")          # FSDP
    r2 = specs_lib.rules_for(cfg, "long_500k")
    assert r2["batch"] is None
    assert r2["cache_time"] == ("pod", "data", "model")  # sequence parallel
    r3 = specs_lib.rules_for(cfg, "decode_32k")
    assert r3["cache_time"] == "model"            # cache time-sharding
    assert r3["seq"] is None                      # intra-step stays local
    assert r3["embed"] is None                    # weights resident (no FSDP)


def test_cache_axes_cover_all_families():
    for arch in R.ARCH_NAMES:
        cfg = R.get(arch)
        if not cfg.supports_decode:
            continue
        from repro.models import registry as M
        axes = specs_lib.cache_axes(cfg)
        cache = M.abstract_cache(cfg, batch=2, max_len=64)
        assert set(axes) == set(cache), arch
        for p, a in axes.items():
            assert len(a) == len(cache[p].shape), (arch, p)


def test_batch_axes_cover_all_input_specs():
    from repro.configs.base import input_specs, shape_supported
    for arch in R.ARCH_NAMES:
        cfg = R.get(arch)
        for shape_name in SHAPES:
            if not shape_supported(cfg, shape_name)[0]:
                continue
            for k in input_specs(cfg, shape_name):
                assert k == "cache" or k in specs_lib.BATCH_AXES, (arch, k)
