"""Async codec service tests: batching, SLOs, cache, fault injection.

Most tests drive :class:`repro.serve.service.CodecService` with the
cheap deterministic :class:`helpers.flaky.EchoEngine` (digest bytes, no
codec) so they exercise the asyncio dispatch machinery, not the
encoder; a couple of end-to-end tests pin the real-engine contract
(service bytes == serial ``encode_batch`` bytes).  The fault-injection
half wraps engines in :class:`helpers.flaky.FlakyEngine` and asserts
the service degrades gracefully: engine failures fail only their own
batch, slow engines surface as ``deadline_missed`` (never as silent
drops), backpressure rejects carry machine-readable reasons, and the
dispatch loop survives all of it.
"""

import asyncio

import numpy as np
import pytest
from helpers.flaky import EchoEngine, FlakyEngine, InjectedEngineError

from repro.serve import admission
from repro.serve.admission import RejectedError, TenantTier
from repro.serve.service import (CodecService, EngineFailure, Response,
                                 ServiceConfig, StreamCache)


def run(coro):
    return asyncio.run(coro)


def make_images(n, shape=(48, 48), seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 256, shape, dtype=np.uint8) for _ in range(n)]


def fast_config(**kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_wait_s", 0.002)
    kw.setdefault("max_queue_depth", 16)
    kw.setdefault("initial_step_s", 0.001)
    return ServiceConfig(**kw)


# ---------------------------------------------------------------------------
# lifecycle
# ---------------------------------------------------------------------------

def test_submit_before_start_raises():
    async def go():
        svc = CodecService(fast_config(), engine=EchoEngine())
        with pytest.raises(RuntimeError, match="not started"):
            await svc.submit(make_images(1)[0])
    run(go())


def test_submit_after_close_rejects_shutdown():
    async def go():
        svc = CodecService(fast_config(), engine=EchoEngine())
        async with svc:
            pass
        with pytest.raises(RejectedError) as ei:
            await svc.submit(make_images(1)[0])
        assert ei.value.reason == admission.SHUTDOWN
    run(go())


def test_close_is_idempotent_and_start_after_close_fails():
    async def go():
        svc = CodecService(fast_config(), engine=EchoEngine())
        await svc.start()
        await svc.close()
        await svc.close()
        with pytest.raises(RuntimeError, match="closed"):
            await svc.start()
    run(go())


def test_close_drains_queued_requests():
    async def go():
        # timer never fires, bucket never fills: requests only leave the
        # queue because close() drains them
        cfg = fast_config(max_batch=8, max_wait_s=30.0)
        svc = CodecService(cfg, engine=EchoEngine())
        await svc.start()
        imgs = make_images(3)
        tasks = [asyncio.ensure_future(svc.submit(im)) for im in imgs]
        await asyncio.sleep(0)
        assert svc.queue_depth() == 3
        await svc.close()
        resps = await asyncio.gather(*tasks)
        assert all(isinstance(r, Response) for r in resps)
        assert svc.stats.served == 3
    run(go())


def test_invalid_image_shape_raises_valueerror():
    async def go():
        async with CodecService(fast_config(),
                                engine=EchoEngine()) as svc:
            with pytest.raises(ValueError, match="2-D"):
                await svc.submit(np.zeros((4, 4, 3), dtype=np.uint8))
            # validation errors are caller bugs, not requests: they must
            # not count as submitted, or the conservation invariant
            # submitted == served + rejected + failed would break
            assert svc.stats.submitted == 0
    run(go())


def test_close_with_inflight_batch_terminates():
    async def go():
        # regression: the dispatcher rebound its in-flight set each
        # iteration while done-callbacks discarded from the *old* set
        # object, so a batch still running when close() triggered the
        # drain iteration stayed "in flight" forever and close() hung
        eng = EchoEngine(step_s=0.1)
        svc = CodecService(fast_config(max_batch=1), engine=eng)
        await svc.start()
        task = asyncio.ensure_future(svc.submit(make_images(1)[0]))
        while not eng.calls:            # batch dispatched, engine busy
            await asyncio.sleep(0.001)
        await asyncio.wait_for(svc.close(), timeout=10.0)
        resp = await task
        assert isinstance(resp, Response)
        assert svc.stats.served == 1
    run(go())


# ---------------------------------------------------------------------------
# batching behaviour
# ---------------------------------------------------------------------------

def test_concurrent_submits_share_engine_batches():
    async def go():
        engine = EchoEngine()
        cfg = fast_config(max_batch=4, max_wait_s=0.05)
        async with CodecService(cfg, engine=engine) as svc:
            resps = await asyncio.gather(
                *[svc.submit(im) for im in make_images(8)])
        assert [n for n, _ in engine.calls] == [4, 4]
        assert {r.batch_size for r in resps} == {4}
        assert svc.stats.occupancy == {4: 2}
    run(go())


def test_mixed_shapes_and_qualities_bucket_separately():
    async def go():
        engine = EchoEngine()
        async with CodecService(fast_config(max_wait_s=0.05),
                                engine=engine) as svc:
            a = make_images(2, shape=(48, 48), seed=1)
            b = make_images(2, shape=(130, 40), seed=2)
            resps = await asyncio.gather(
                *[svc.submit(im, quality=50) for im in a],
                *[svc.submit(im, quality=50) for im in b],
                svc.submit(a[0] + 1, quality=75))
        # three buckets: (64,64)@50, (192,64)@50, (64,64)@75
        assert sorted(engine.calls) == [(1, 75), (2, 50), (2, 50)]
        assert all(r.payload for r in resps)
    run(go())


def test_lone_request_dispatches_on_timer():
    async def go():
        engine = EchoEngine()
        cfg = fast_config(max_batch=8, max_wait_s=0.005)
        async with CodecService(cfg, engine=engine) as svc:
            resp = await svc.submit(make_images(1)[0])
        assert resp.batch_size == 1
        assert engine.calls == [(1, 50)]
    run(go())


def test_response_metadata_fields():
    async def go():
        async with CodecService(fast_config(),
                                engine=EchoEngine()) as svc:
            resp = await svc.submit(make_images(1)[0], quality=30)
        assert resp.quality == 30
        assert resp.batch_size == 1
        assert resp.req_id >= 0
        assert resp.latency_s >= 0.0
        assert not resp.cache_hit and not resp.deadline_missed
    run(go())


def test_bytes_are_engine_output():
    async def go():
        engine = EchoEngine()
        imgs = make_images(3, seed=3)
        async with CodecService(fast_config(), engine=engine) as svc:
            resps = await asyncio.gather(*[svc.submit(im) for im in imgs])
        assert [r.payload for r in resps] == engine(imgs, 50)
    run(go())


# ---------------------------------------------------------------------------
# tenant tiers
# ---------------------------------------------------------------------------

def test_tenant_tier_clamps_quality_and_buckets_at_clamped_value():
    async def go():
        engine = EchoEngine()
        cfg = fast_config(tenants={"free": TenantTier(max_quality=40)})
        async with CodecService(cfg, engine=engine) as svc:
            r = await svc.submit(make_images(1)[0], quality=90,
                                 tenant="free")
        assert r.quality == 40
        assert engine.calls == [(1, 40)]
    run(go())


def test_unknown_tenant_uses_default_tier():
    async def go():
        cfg = fast_config(default_tier=TenantTier(max_quality=60))
        async with CodecService(cfg, engine=EchoEngine()) as svc:
            r = await svc.submit(make_images(1)[0], quality=90,
                                 tenant="nobody")
        assert r.quality == 60
    run(go())


def test_tenant_tier_relaxes_deadline():
    async def go():
        # the tier's deadline floor (1s) overrides the hopeless 1ns ask,
        # so the request is admitted and served instead of rejected
        cfg = fast_config(tenants={"lenient":
                                   TenantTier(min_deadline_s=1.0)})
        async with CodecService(cfg, engine=EchoEngine()) as svc:
            r = await svc.submit(make_images(1)[0], tenant="lenient",
                                 deadline_s=1e-9)
        assert not r.deadline_missed
    run(go())


# ---------------------------------------------------------------------------
# hot-stream cache
# ---------------------------------------------------------------------------

def test_cache_hit_serves_identical_payload_without_engine_call():
    async def go():
        engine = EchoEngine()
        img = make_images(1)[0]
        async with CodecService(fast_config(), engine=engine) as svc:
            r1 = await svc.submit(img)
            r2 = await svc.submit(img)
        assert not r1.cache_hit and r2.cache_hit
        assert r2.payload == r1.payload
        assert r2.batch_size == 0
        assert len(engine.calls) == 1
        assert svc.cache.hits == 1
    run(go())


def test_cache_misses_on_quality_change():
    async def go():
        engine = EchoEngine()
        img = make_images(1)[0]
        async with CodecService(fast_config(), engine=engine) as svc:
            await svc.submit(img, quality=50)
            r = await svc.submit(img, quality=75)
        assert not r.cache_hit
        assert len(engine.calls) == 2
    run(go())


def test_cache_disabled_with_zero_entries():
    async def go():
        engine = EchoEngine()
        img = make_images(1)[0]
        cfg = fast_config(cache_entries=0)
        async with CodecService(cfg, engine=engine) as svc:
            await svc.submit(img)
            r = await svc.submit(img)
        assert not r.cache_hit
        assert len(engine.calls) == 2
    run(go())


def test_stream_cache_lru_eviction():
    c = StreamCache(entries=2)
    c.put(("a", 50, "auto"), b"A")
    c.put(("b", 50, "auto"), b"B")
    assert c.get(("a", 50, "auto")) == b"A"     # refreshes "a"
    c.put(("c", 50, "auto"), b"C")              # evicts "b"
    assert c.get(("b", 50, "auto")) is None
    assert c.get(("a", 50, "auto")) == b"A"
    assert len(c) == 2


def test_stream_cache_key_separates_content_quality_tables():
    img = make_images(1)[0]
    k = StreamCache.key(img, 50, "auto")
    assert StreamCache.key(img.copy(), 50, "auto") == k
    assert StreamCache.key(img, 75, "auto") != k
    assert StreamCache.key(img, 50, "embedded") != k
    other = img.copy()
    other[0, 0] ^= 0xFF
    assert StreamCache.key(other, 50, "auto") != k


# ---------------------------------------------------------------------------
# backpressure and deadlines
# ---------------------------------------------------------------------------

def test_queue_full_rejects_with_reason():
    async def go():
        # all submits admit before the dispatcher's next poll, so the
        # third hits the depth bound deterministically
        cfg = fast_config(max_batch=2, max_queue_depth=2,
                          max_wait_s=30.0)
        async with CodecService(cfg, engine=EchoEngine()) as svc:
            out = await asyncio.gather(
                *[svc.submit(im) for im in make_images(3)],
                return_exceptions=True)
        rejects = [r for r in out if isinstance(r, RejectedError)]
        served = [r for r in out if isinstance(r, Response)]
        assert len(rejects) == 1 and len(served) == 2
        assert rejects[0].reason == admission.QUEUE_FULL
        assert svc.stats.rejected == {admission.QUEUE_FULL: 1}
    run(go())


def test_hopeless_deadline_rejected_at_admission():
    async def go():
        cfg = fast_config(initial_step_s=0.050)
        async with CodecService(cfg, engine=EchoEngine()) as svc:
            with pytest.raises(RejectedError) as ei:
                await svc.submit(make_images(1)[0], deadline_s=1e-6)
        assert ei.value.reason == admission.DEADLINE_UNMEETABLE
        assert svc.stats.total_rejected == 1
    run(go())


def test_slow_engine_marks_deadline_missed_not_dropped():
    async def go():
        engine = FlakyEngine(EchoEngine(), latency_s=0.05)
        cfg = fast_config(initial_step_s=1e-4)
        async with CodecService(cfg, engine=engine) as svc:
            r = await svc.submit(make_images(1)[0], deadline_s=0.01)
        assert isinstance(r, Response)
        assert r.deadline_missed
        assert svc.stats.deadline_missed == 1
        assert svc.stats.served == 1
    run(go())


def test_queued_request_behind_slow_batch_is_swept_not_dispatched():
    async def go():
        # a full batch holds the engine for 50ms and teaches the
        # bucket's EWMA that steps are slow; the request queued behind
        # it has a deadline the learned step rules out (completion +
        # step > deadline), so the batch-completion wake must sweep it
        # as a reject rather than dispatch it to miss its SLO
        engine = FlakyEngine(EchoEngine(), latency_s=0.05,
                             slow_calls={0})
        cfg = fast_config(max_batch=2, max_wait_s=30.0,
                          initial_step_s=1e-4)
        async with CodecService(cfg, engine=engine) as svc:
            imgs = make_images(3)
            batch1 = [asyncio.ensure_future(svc.submit(im))
                      for im in imgs[:2]]        # fills the bucket
            await asyncio.sleep(0.01)            # batch 1 now in flight
            straggler = asyncio.ensure_future(
                svc.submit(imgs[2], deadline_s=0.07))
            out = await asyncio.gather(*batch1, straggler,
                                       return_exceptions=True)
        assert all(isinstance(r, Response) for r in out[:2])
        assert isinstance(out[2], RejectedError)
        assert out[2].reason == admission.DEADLINE_UNMEETABLE
        assert len(engine.calls) == 1       # straggler never encoded
    run(go())


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------

def test_engine_failure_fails_only_its_batch():
    async def go():
        engine = FlakyEngine(EchoEngine(), fail_calls={0})
        cfg = fast_config(max_batch=2, max_wait_s=0.05)
        async with CodecService(cfg, engine=engine) as svc:
            first = await asyncio.gather(
                *[svc.submit(im) for im in make_images(2, seed=1)],
                return_exceptions=True)
            second = await asyncio.gather(
                *[svc.submit(im) for im in make_images(2, seed=2)])
        assert all(isinstance(r, EngineFailure) for r in first)
        assert all(isinstance(r.__cause__, InjectedEngineError)
                   for r in first)
        assert all(isinstance(r, Response) for r in second)
        assert svc.stats.engine_failures == 1
        assert svc.stats.failed == 2
        assert svc.stats.served == 2
    run(go())


def test_engine_short_return_is_a_batch_failure():
    async def go():
        engine = FlakyEngine(EchoEngine(), short_return_calls={0})
        cfg = fast_config(max_batch=2, max_wait_s=0.05)
        async with CodecService(cfg, engine=engine) as svc:
            out = await asyncio.gather(
                *[svc.submit(im) for im in make_images(2)],
                return_exceptions=True)
        assert all(isinstance(r, EngineFailure) for r in out)
    run(go())


def test_dispatch_loop_survives_repeated_engine_failures():
    async def go():
        engine = FlakyEngine(EchoEngine(), fail_calls={0, 1, 2})
        async with CodecService(fast_config(), engine=engine) as svc:
            for i in range(3):
                with pytest.raises(EngineFailure):
                    await svc.submit(make_images(1, seed=i)[0])
            r = await svc.submit(make_images(1, seed=99)[0])
        assert isinstance(r, Response)
        assert svc.stats.engine_failures == 3
    run(go())


def test_every_submit_reaches_exactly_one_terminal_outcome_under_faults():
    async def go():
        engine = FlakyEngine(EchoEngine(), fail_rate=0.3, seed=7)
        cfg = fast_config(max_batch=3, max_queue_depth=6,
                          max_wait_s=0.005)
        n = 24
        rng = np.random.default_rng(5)
        async with CodecService(cfg, engine=engine) as svc:
            async def one(i):
                img = make_images(1, seed=i)[0]
                dl = None if rng.random() < 0.5 else 0.5
                return await svc.submit(img, deadline_s=dl)
            out = await asyncio.gather(*[one(i) for i in range(n)],
                                       return_exceptions=True)
        served = sum(isinstance(r, Response) for r in out)
        failed = sum(isinstance(r, EngineFailure) for r in out)
        rejected = sum(isinstance(r, RejectedError) for r in out)
        assert served + failed + rejected == n
        assert svc.stats.submitted == n
        assert svc.stats.served == served
        assert svc.stats.failed == failed
        assert svc.stats.total_rejected == rejected
        assert svc.queue_depth() == 0
    run(go())


def test_flaky_latency_only_on_selected_calls():
    engine = FlakyEngine(EchoEngine(), latency_s=0.05, slow_calls={1})
    imgs = make_images(1)
    import time
    t0 = time.monotonic()
    engine(imgs, 50)
    fast = time.monotonic() - t0
    t0 = time.monotonic()
    engine(imgs, 50)
    slow = time.monotonic() - t0
    assert fast < 0.02 < slow
    assert engine.calls == [(1, 50), (1, 50)]


def test_latency_reservoir_is_bounded():
    from repro.serve.service import ServiceStats
    stats = ServiceStats()
    for i in range(ServiceStats.LATENCY_WINDOW + 100):
        stats.latencies_s.append(float(i))
    assert len(stats.latencies_s) == ServiceStats.LATENCY_WINDOW
    # the window keeps the most recent samples
    assert stats.latency_percentile(100) == float(
        ServiceStats.LATENCY_WINDOW + 99)


def test_stats_snapshot_shape():
    async def go():
        async with CodecService(fast_config(),
                                engine=EchoEngine()) as svc:
            await svc.submit(make_images(1)[0])
        snap = svc.stats.snapshot()
        assert snap["submitted"] == snap["served"] == 1
        assert snap["occupancy"] == {"1": 1}
        assert snap["p50_latency_s"] >= 0.0
        assert set(snap) >= {"rejected", "failed", "engine_failures",
                             "deadline_missed", "p99_latency_s"}
    run(go())


# ---------------------------------------------------------------------------
# real engine end-to-end
# ---------------------------------------------------------------------------

def test_service_bytes_match_serial_encode_batch():
    codec_engine = pytest.importorskip("repro.serve.codec_engine")

    async def go(imgs):
        cfg = ServiceConfig(max_batch=4, max_wait_s=0.02)
        async with CodecService(cfg) as svc:
            return await asyncio.gather(*[svc.submit(im) for im in imgs])

    imgs = make_images(4, shape=(40, 56), seed=11)
    resps = run(go(imgs))
    serial = codec_engine.encode_batch(imgs, 50)
    assert [r.payload for r in resps] == serial


def test_service_payload_decodes_roundtrip():
    pytest.importorskip("repro.serve.codec_engine")
    from repro.core.entropy import container

    async def go(img):
        async with CodecService(ServiceConfig(max_batch=2,
                                              max_wait_s=0.02)) as svc:
            return await svc.submit(img, quality=75)

    img = make_images(1, shape=(33, 47), seed=12)[0]
    resp = run(go(img))
    decoded = container.decode_image(resp.payload)
    assert decoded.shape == img.shape
