"""Planner/admission unit tests + hypothesis-driven batching invariants.

The serving layer's correctness case rests on four dispatch-loop
invariants, pinned here against randomized request schedules (arrival
times, deadlines, shapes, qualities):

1. **no knowingly-unmeetable dispatch** — a request whose deadline the
   current step estimate rules out is rejected, never dispatched,
2. **FIFO within a bucket** — dispatch order preserves admission order
   per (shape bucket, quality) queue,
3. **bounded depth** — per-bucket queue depth never exceeds
   ``max_queue_depth``; overflow raises ``RejectedError(queue_full)``,
4. **conservation** — every admitted request reaches exactly one
   terminal outcome (dispatched or rejected); a drain poll leaves
   nothing queued.

The planner is jax-free, so these run thousands of synthetic schedules
in milliseconds (under the hermetic hypothesis stub they replay seeded
examples; with real hypothesis they search).
"""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serve import admission, queueing
from repro.serve.admission import RejectedError, TenantTier
from repro.serve.queueing import BatchPlanner, Ewma, shape_bucket

QUALITIES = (30, 50, 75)
SHAPES = ((48, 48), (48, 64), (100, 80), (130, 130))


# ---------------------------------------------------------------------------
# admission primitives
# ---------------------------------------------------------------------------

def test_rejected_error_carries_reason_and_detail():
    exc = RejectedError(admission.QUEUE_FULL, "depth 64")
    assert exc.reason == "queue_full"
    assert "depth 64" in str(exc)
    assert isinstance(exc, RuntimeError)


def test_rejected_error_rejects_unknown_reason():
    with pytest.raises(ValueError, match="unknown reject reason"):
        RejectedError("cosmic_rays")


def test_tenant_tier_clamps_quality():
    tier = TenantTier(max_quality=40)
    assert tier.resolve_quality(80) == 40
    assert tier.resolve_quality(25) == 25


def test_tenant_tier_validates_quality_range():
    with pytest.raises(ValueError, match="quality"):
        TenantTier().resolve_quality(0)
    with pytest.raises(ValueError, match="quality"):
        TenantTier().resolve_quality(101)


def test_tenant_tier_relaxes_tight_deadlines():
    tier = TenantTier(min_deadline_s=0.5)
    assert tier.resolve_deadline_s(0.1) == 0.5
    assert tier.resolve_deadline_s(2.0) == 2.0
    assert tier.resolve_deadline_s(None) == math.inf


def test_tenant_tier_rejects_nonpositive_deadline():
    with pytest.raises(ValueError, match="deadline"):
        TenantTier().resolve_deadline_s(0.0)


def test_feasibility_predicates_ordering():
    # with safety > 1 there is a window where a request is urgent
    # (dispatch now) but still feasible (not yet swept)
    step, safety = 0.1, 1.5
    deadline = 1.0
    now = deadline - 0.12          # urgent, feasible
    assert admission.urgent(deadline, now, step, safety)
    assert admission.feasible(deadline, now, step)
    assert not admission.admission_deadline_ok(deadline, now, step, safety)
    assert admission.feasible(math.inf, 1e9, step)


# ---------------------------------------------------------------------------
# queueing building blocks
# ---------------------------------------------------------------------------

def test_shape_bucket_rounds_up_to_multiple():
    assert shape_bucket(48, 48) == (64, 64)
    assert shape_bucket(64, 65) == (64, 128)
    assert shape_bucket(1, 200) == (64, 256)


def test_shape_bucket_matches_codec_engine():
    codec_engine = pytest.importorskip("repro.serve.codec_engine")
    assert queueing.DEFAULT_SHAPE_BUCKET == codec_engine.SHAPE_BUCKET


def test_ewma_first_observation_initialises():
    e = Ewma(alpha=0.25)
    assert e.value is None
    e.observe(0.1)
    assert e.value == pytest.approx(0.1)
    e.observe(0.2)
    assert e.value == pytest.approx(0.25 * 0.2 + 0.75 * 0.1)


def test_ewma_validates_alpha():
    with pytest.raises(ValueError, match="alpha"):
        Ewma(alpha=0.0)


def test_planner_validates_config():
    with pytest.raises(ValueError, match="max_batch"):
        BatchPlanner(max_batch=0)
    with pytest.raises(ValueError, match="max_queue_depth"):
        BatchPlanner(max_batch=8, max_queue_depth=4)


def test_observe_step_moves_estimate():
    p = BatchPlanner(initial_step_s=0.05)
    key = p.bucket_key((48, 48), 50)
    assert p.step_estimate(key) == 0.05
    p.observe_step(key, 0.2)
    assert p.step_estimate(key) == pytest.approx(0.2)


# ---------------------------------------------------------------------------
# dispatch triggers
# ---------------------------------------------------------------------------

def _planner(**kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_wait_s", 0.010)
    kw.setdefault("max_queue_depth", 8)
    kw.setdefault("initial_step_s", 0.05)
    return BatchPlanner(**kw)


def test_dispatch_on_full_bucket():
    p = _planner()
    for _ in range(4):
        p.admit((48, 48), 50, "t", now=0.0)
    poll = p.poll(0.0)
    assert [len(b.requests) for b in poll.batches] == [4]
    assert p.empty()


def test_no_dispatch_before_any_trigger():
    p = _planner()
    p.admit((48, 48), 50, "t", now=0.0, deadline=10.0)
    poll = p.poll(0.001)
    assert poll.batches == [] and poll.rejects == []
    assert p.total_depth() == 1


def test_dispatch_on_max_wait_timer():
    p = _planner()
    p.admit((48, 48), 50, "t", now=0.0)
    assert p.poll(0.009).batches == []
    poll = p.poll(0.011)
    assert len(poll.batches) == 1
    assert len(poll.batches[0].requests) == 1


def test_dispatch_on_urgent_deadline_before_timer():
    # deadline margin expires before the batching timer would fire
    p = _planner(max_wait_s=10.0, initial_step_s=0.05, safety=1.5)
    p.admit((48, 48), 50, "t", now=0.0, deadline=0.080)
    assert p.poll(0.001).batches == []
    poll = p.poll(0.006)       # 0.006 >= 0.080 - 1.5*0.05 = 0.005
    assert len(poll.batches) == 1


def test_sweep_rejects_expired_requests_instead_of_dispatching():
    p = _planner(max_wait_s=10.0, initial_step_s=0.05)
    r = p.admit((48, 48), 50, "t", now=0.0, deadline=0.080)
    poll = p.poll(0.05)        # 0.05 + step 0.05 > 0.080: unmeetable
    assert poll.batches == []
    assert len(poll.rejects) == 1
    swept, exc = poll.rejects[0]
    assert swept.req_id == r.req_id
    assert exc.reason == admission.DEADLINE_UNMEETABLE
    assert p.empty()


def test_drain_dispatches_partial_batches():
    p = _planner()
    p.admit((48, 48), 50, "t", now=0.0)
    p.admit((100, 80), 50, "t", now=0.0)
    poll = p.poll(0.0, drain=True)
    assert sorted(len(b.requests) for b in poll.batches) == [1, 1]
    assert p.empty()


def test_oversize_queue_dispatches_in_max_batch_chunks():
    p = _planner(max_batch=3, max_queue_depth=8)
    for _ in range(7):
        p.admit((48, 48), 50, "t", now=0.0)
    poll = p.poll(0.0)
    # two full batches fire; the remainder waits for more batchmates
    # (or its timer) instead of dispatching a premature partial batch
    assert [len(b.requests) for b in poll.batches] == [3, 3]
    assert p.total_depth() == 1
    assert [len(b.requests)
            for b in p.poll(0.011).batches] == [1]   # timer fires


def test_buckets_isolated_by_shape_and_quality():
    p = _planner()
    a = p.admit((48, 48), 50, "t", now=0.0)
    b = p.admit((48, 48), 75, "t", now=0.0)
    c = p.admit((200, 48), 50, "t", now=0.0)
    assert len({p.bucket_key(r.shape, r.quality)
                for r in (a, b, c)}) == 3
    assert p.depth((48, 48), 50) == 1
    poll = p.poll(0.0, drain=True)
    assert len(poll.batches) == 3


def test_admit_rejects_at_depth_bound():
    p = _planner(max_batch=4, max_queue_depth=4)
    for _ in range(4):
        p.admit((48, 48), 50, "t", now=0.0)
    with pytest.raises(RejectedError) as ei:
        p.admit((48, 48), 50, "t", now=0.0)
    assert ei.value.reason == admission.QUEUE_FULL
    # other buckets unaffected
    p.admit((48, 48), 75, "t", now=0.0)


def test_admit_rejects_hopeless_deadline():
    p = _planner(initial_step_s=0.05, safety=1.5)
    with pytest.raises(RejectedError) as ei:
        p.admit((48, 48), 50, "t", now=0.0, deadline=0.01)
    assert ei.value.reason == admission.DEADLINE_UNMEETABLE
    assert p.empty()


def test_next_wake_none_when_empty_zero_when_full():
    p = _planner()
    assert p.next_wake(0.0) is None
    p.admit((48, 48), 50, "t", now=0.0, deadline=10.0)
    # timer at arrival + max_wait_s
    assert p.next_wake(0.002) == pytest.approx(0.008)
    for _ in range(3):
        p.admit((48, 48), 50, "t", now=0.0, deadline=10.0)
    assert p.next_wake(0.002) == 0.0


def test_next_wake_tracks_deadline_margin():
    p = _planner(max_wait_s=10.0, initial_step_s=0.05, safety=1.5)
    p.admit((48, 48), 50, "t", now=0.0, deadline=1.0)
    # wake at deadline - safety*step = 0.925
    assert p.next_wake(0.0) == pytest.approx(0.925)


def test_fifo_within_bucket_simple():
    p = _planner(max_batch=2, max_queue_depth=8)
    ids = [p.admit((48, 48), 50, "t", now=0.0).req_id for _ in range(5)]
    poll = p.poll(0.0, drain=True)
    got = [r.req_id for b in poll.batches for r in b.requests]
    assert got == ids


# ---------------------------------------------------------------------------
# randomized schedules (hypothesis)
# ---------------------------------------------------------------------------

def _run_schedule(seed: int, max_batch: int, max_queue_depth: int,
                  n_events: int = 120):
    """Simulate a random schedule; return per-event observations."""
    rng = np.random.default_rng(seed)
    planner = BatchPlanner(max_batch=max_batch, max_wait_s=0.010,
                           max_queue_depth=max_queue_depth,
                           initial_step_s=0.020)
    now = 0.0
    admitted, dispatched, rejected = [], [], []
    batches = []
    for _ in range(n_events):
        now += float(rng.exponential(0.004))
        ev = rng.random()
        if ev < 0.55:
            shape = SHAPES[int(rng.integers(len(SHAPES)))]
            quality = QUALITIES[int(rng.integers(len(QUALITIES)))]
            deadline = (math.inf if rng.random() < 0.3
                        else now + float(rng.uniform(0.001, 0.120)))
            try:
                req = planner.admit(shape, quality, "t", now,
                                    deadline=deadline)
                admitted.append(req)
            except RejectedError as exc:
                rejected.append((None, exc))
            key = planner.bucket_key(shape, quality)
            assert planner.depth(shape, quality) <= max_queue_depth, \
                f"depth bound violated for {key}"
        else:
            poll = planner.poll(now)
            for batch in poll.batches:
                step = planner.step_estimate(batch.key)
                for r in batch.requests:
                    assert admission.feasible(r.deadline, now, step), (
                        f"dispatched knowingly-unmeetable request "
                        f"{r.req_id} at t={now}")
                batches.append(batch)
                dispatched.extend(batch.requests)
                if rng.random() < 0.5:
                    planner.observe_step(
                        batch.key, float(rng.uniform(0.001, 0.030)))
            rejected.extend(poll.rejects)
    # final drain: nothing may stay queued
    now += 1.0
    poll = planner.poll(now, drain=True)
    batches.extend(poll.batches)
    for batch in poll.batches:
        dispatched.extend(batch.requests)
    rejected.extend(poll.rejects)
    assert planner.empty()
    return admitted, dispatched, rejected, batches


@settings(max_examples=25)
@given(st.integers(0, 10_000))
def test_property_every_admit_reaches_one_terminal_outcome(seed):
    admitted, dispatched, rejected, _ = _run_schedule(seed, 4, 8)
    admitted_ids = [r.req_id for r in admitted]
    out_ids = ([r.req_id for r in dispatched]
               + [r.req_id for r, _ in rejected if r is not None])
    assert sorted(out_ids) == sorted(admitted_ids)
    assert len(set(out_ids)) == len(out_ids), "request finished twice"


@settings(max_examples=25)
@given(st.integers(0, 10_000), st.integers(1, 6))
def test_property_fifo_order_within_bucket(seed, max_batch):
    planner_probe = BatchPlanner(max_batch=max_batch,
                                 max_queue_depth=4 * max_batch)
    _, _, _, batches = _run_schedule(seed, max_batch, 4 * max_batch)
    per_key = {}
    for b in batches:
        per_key.setdefault(b.key, []).extend(r.req_id for r in b.requests)
        assert len(b.requests) <= max_batch
        assert all(planner_probe.bucket_key(r.shape, r.quality) == b.key
                   for r in b.requests)
    for key, ids in per_key.items():
        assert ids == sorted(ids), f"FIFO violated in bucket {key}"


@settings(max_examples=25)
@given(st.integers(0, 10_000), st.booleans())
def test_property_depth_bounded_and_overflow_rejects(seed, tight):
    # tight=True stresses the bound with a queue barely above max_batch
    max_batch = 3
    depth = 3 if tight else 6
    admitted, _, rejected, _ = _run_schedule(seed, max_batch, depth)
    # schedule asserts depth <= bound after every admit; additionally,
    # overflow rejections must be tagged queue_full
    reasons = {exc.reason for r, exc in rejected if r is None}
    assert reasons <= {admission.QUEUE_FULL,
                       admission.DEADLINE_UNMEETABLE}


@settings(max_examples=15)
@given(st.integers(0, 10_000), st.floats(0.001, 0.05))
def test_property_next_wake_never_negative_and_none_iff_empty(seed, step):
    rng = np.random.default_rng(seed)
    p = BatchPlanner(max_batch=4, max_queue_depth=8, initial_step_s=step)
    now = 0.0
    for _ in range(40):
        now += float(rng.exponential(0.003))
        try:
            p.admit(SHAPES[int(rng.integers(len(SHAPES)))],
                    50, "t", now,
                    deadline=now + float(rng.uniform(0.05, 0.5)))
        except RejectedError:
            pass
        wake = p.next_wake(now)
        if p.empty():
            assert wake is None
        else:
            assert wake is not None and wake >= 0.0
        if rng.random() < 0.4:
            p.poll(now)
    p.poll(now + 10.0, drain=True)
    assert p.next_wake(now + 10.0) is None
