"""Test fixtures.  NOTE: no global XLA_FLAGS here — tests must see ONE CPU
device by default; multi-device tests spawn subprocesses with their own
flags (CI additionally exports XLA_FLAGS=--xla_force_host_platform_
device_count=8 so the ``multidevice`` tests run emulated).

If ``hypothesis`` is unavailable (the hermetic container has no network),
a deterministic mini-stub is installed: ``@given`` replays a fixed number
of seeded examples instead of searching.  ``pip install -e .[dev]`` gets
the real thing.
"""

import random
import sys
import types

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - exercised only without the dep
    def _build_hypothesis_stub():
        mod = types.ModuleType("hypothesis")
        st = types.ModuleType("hypothesis.strategies")

        class _Strategy:
            def __init__(self, draw):
                self.draw = draw

        st.integers = lambda lo, hi: _Strategy(
            lambda r: r.randint(lo, hi))
        st.sampled_from = lambda seq: _Strategy(
            lambda r: seq[r.randrange(len(seq))])
        st.floats = lambda lo, hi, **kw: _Strategy(
            lambda r: r.uniform(lo, hi))
        st.booleans = lambda: _Strategy(lambda r: r.random() < 0.5)

        class settings:  # noqa: N801 - mirrors hypothesis' API
            def __init__(self, max_examples=10, deadline=None, **kw):
                self.max_examples = max_examples

            def __call__(self, fn):
                fn._stub_settings = self
                return fn

        def given(*strategies):
            def deco(fn):
                cfg = getattr(fn, "_stub_settings", None)
                n = cfg.max_examples if cfg else 10

                def wrapper(*args, **kwargs):
                    rng = random.Random(0)
                    for _ in range(n):
                        drawn = [s.draw(rng) for s in strategies]
                        fn(*args, *drawn, **kwargs)
                wrapper.__name__ = fn.__name__
                wrapper.__doc__ = fn.__doc__
                wrapper.__module__ = fn.__module__
                return wrapper
            return deco

        mod.given = given
        mod.settings = settings
        mod.strategies = st
        sys.modules["hypothesis"] = mod
        sys.modules["hypothesis.strategies"] = st

    _build_hypothesis_stub()


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multidevice: needs jax.device_count() >= 2 (CI emulates 8 via "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    config.addinivalue_line(
        "markers",
        "slow: long-running stress tests; excluded from the tier-1 run "
        "(pytest -m 'not slow') and run in the bench-smoke CI job")


def pytest_collection_modifyitems(config, items):
    marked = [it for it in items if it.get_closest_marker("multidevice")]
    if not marked:
        return
    import jax
    if jax.device_count() >= 2:
        return
    skip = pytest.mark.skip(
        reason="needs >= 2 jax devices; set "
               "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    for it in marked:
        it.add_marker(skip)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
