"""Test fixtures.  NOTE: no global XLA_FLAGS here — tests must see ONE CPU
device; multi-device tests spawn subprocesses with their own flags."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
