"""Multi-device distribution tests.

Each test spawns a subprocess with XLA_FLAGS=--xla_force_host_platform_
device_count=8 so the main pytest process keeps its single CPU device.
"""

import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.multidevice

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_script(body: str, timeout: int = 600):
    script = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=8'\n" + body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "TEST-OK" in r.stdout, r.stdout


def test_data_parallel_matches_single_device():
    run_script("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import registry as R
from repro.dist import sharding as sh
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_mesh
from repro.optim.adamw import AdamWConfig
from repro.train import step as step_lib
from repro.data.synth import DataConfig, make_batch_fn

cfg = R.reduced("smollm-360m", n_layers=2, d_model=64, vocab_size=128)
bf = make_batch_fn(DataConfig(vocab_size=128, seq_len=16, global_batch=8))
batch = bf(0)
state = step_lib.init_state(cfg, AdamWConfig(), jax.random.key(0))
fn = step_lib.make_train_step(cfg, AdamWConfig(), step_lib.TrainStepConfig())

# single device reference
ref, _ = jax.jit(fn)(state, batch)

# 4x2 mesh, batch sharded over data
mesh = make_mesh((4, 2), ("data", "model"))
with sh.use_mesh_and_rules(mesh, specs_lib.rules_for(cfg, "train_4k")):
    ssh = specs_lib.state_shardings(cfg, mesh)
    from repro.configs.base import input_specs
    bsh = {k: sh.input_sharding(v.shape, specs_lib.BATCH_AXES[k], mesh)
           for k, v in batch.items()}
    out, _ = jax.jit(fn, in_shardings=(ssh, bsh))(state, batch)

for k in ref["params"]:
    a = np.asarray(ref["params"][k], np.float32)
    b = np.asarray(out["params"][k], np.float32)
    np.testing.assert_allclose(a, b, atol=2e-5, err_msg=k)
print("TEST-OK")
""")


def test_compressed_cross_pod_mean_and_bytes():
    run_script("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.dist import compat
from repro.dist.compressed import compressed_mean_flat, make_cross_axis_grad_sync
from repro.optim.grad_compress import GradCompressConfig

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))

# per-pod different gradients -> compressed mean over pod
n = 4096
g = jnp.stack([jnp.sin(jnp.arange(n) / 50.0),
               jnp.sin(jnp.arange(n) / 50.0) + 0.1])   # (2, N), smooth
ef = jnp.zeros((2, n))
def body(gl, el):
    m, e = compressed_mean_flat(gl[0], el[0], "pod", keep=16)
    return m[None], e[None]
sm = compat.shard_map(body, mesh, in_specs=(P("pod"), P("pod")),
                      out_specs=(P("pod"), P("pod")))
mean, new_ef = jax.jit(sm)(g, ef)
true = np.asarray(g).mean(0)
a = np.asarray(mean[0]); b = np.asarray(mean[1])
np.testing.assert_allclose(a, b, atol=1e-6)          # both pods agree
rel = np.linalg.norm(a - true) / np.linalg.norm(true)
assert rel < 0.05, rel                                # smooth signal compacts
assert float(jnp.abs(new_ef).max()) > 0               # EF holds the residual

# tree-level plumbing via make_cross_axis_grad_sync
grads = {"w": jnp.tile(jnp.sin(jnp.arange(1024)/20.)[None], (2, 1)).reshape(2,1024)}
specs = {"w": P()}
sync = make_cross_axis_grad_sync(mesh, specs, GradCompressConfig(
    enabled=True, keep=16, min_size=64, axis="pod"))
out, ef2 = jax.jit(sync)({"w": grads["w"][0]}, {"w": jnp.zeros(1024)})
assert out["w"].shape == (1024,)

# collective bytes: int8 codes crossing the pod axis, not f32 grads
lowered = jax.jit(sm).lower(g, ef)
txt = lowered.compile().as_text()
assert "all-gather" in txt
print("TEST-OK")
""")


def test_dryrun_lowering_small_mesh():
    run_script("""
import jax, jax.numpy as jnp
from repro.configs import registry as R
from repro.configs.base import input_specs
from repro.dist import sharding as sh
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_mesh
from repro.models import registry as M
from repro.optim import adamw
from repro.train import step as step_lib

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
for arch in ("smollm-360m", "qwen3-moe-30b-a3b", "zamba2-1.2b"):
    cfg = R.reduced(arch, vocab_size=256)
    rules = specs_lib.rules_for(cfg, "train_4k")
    with sh.use_mesh_and_rules(mesh, rules):
        fn = step_lib.make_train_step(cfg, adamw.AdamWConfig(),
                                      step_lib.TrainStepConfig())
        state = step_lib.abstract_state(cfg, adamw.AdamWConfig())
        ssh = specs_lib.state_shardings(cfg, mesh)
        batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
        bsh = {k: sh.input_sharding(v.shape, specs_lib.BATCH_AXES[k], mesh)
               for k, v in batch.items()}
        compiled = jax.jit(fn, in_shardings=(ssh, bsh)).lower(
            state, batch).compile()
        assert compiled.memory_analysis() is not None
        print(arch, "ok")
print("TEST-OK")
""")


def test_elastic_reshard_across_meshes():
    run_script("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.ckpt import checkpoint
from repro.launch.mesh import make_mesh

mesh_a = make_mesh((4, 2), ("data", "model"))
mesh_b = make_mesh((2, 4), ("data", "model"))
x = jnp.arange(64 * 8, dtype=jnp.float32).reshape(64, 8)
xa = jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))
with tempfile.TemporaryDirectory() as td:
    checkpoint.save(td, 1, {"w": xa}, {"step": 1})
    # load resharded for a different mesh topology (elastic rescale)
    tree, _ = checkpoint.load(td, 1, shardings={
        "w": NamedSharding(mesh_b, P("model", "data"))})
    np.testing.assert_array_equal(np.asarray(tree["w"]), np.asarray(x))
    assert tree["w"].sharding.mesh.shape["data"] == 2
print("TEST-OK")
""")


def test_gpipe_pipeline_matches_sequential():
    run_script("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.dist import pipeline

mesh = make_mesh((4, 2), ("stage", "data"))

# 8 layers of a toy residual block, 4 stages x 2 layers
L, D, M, B = 8, 16, 4, 3
key = jax.random.key(0)
w = jax.random.normal(key, (L, D, D)) * (0.5 / np.sqrt(D))

def block_fn(layer_w, x):
    return x + jnp.tanh(x @ layer_w)

x_micro = jax.random.normal(jax.random.key(1), (M, B, D))

# sequential reference
def seq(x):
    for i in range(L):
        x = block_fn(w[i], x)
    return x
ref = jax.vmap(seq)(x_micro)

stage_params = pipeline.split_stages({"w": w}, 4)
run = pipeline.gpipe(lambda p, x: block_fn(p["w"], x), n_stages=4,
                     n_micro=M, mesh=mesh)
out = jax.jit(lambda sp, xm: run(sp, xm))(stage_params, x_micro)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
print("TEST-OK")
""")
