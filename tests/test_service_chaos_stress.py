"""Fault-injection chaos stress for the resilient service (marked slow).

The slow-tier companion to ``tests/test_resilience.py``: real
concurrent clients, the real :mod:`repro.serve.codec_engine`, and a
seeded :class:`repro.serve.chaos.ChaosEngine` storm (scripted
exceptions, a payload-corruption burst, one worker death) through the
full resilience envelope.  The claims are the chaos bench's gate,
under closed-loop concurrency instead of open-loop arrivals: every
request reaches exactly one terminal outcome, nothing escapes the
dispatch loop unhandled, corruption is caught by the CRC validator —
never served — and every payload that *is* served is byte-identical
to a serial ``encode_batch`` of the same image at the same quality.
"""

import asyncio

import numpy as np
import pytest
from helpers.faults import ChaosEngine, FaultPhase, FaultPlan, dctz_crc_ok

from repro.serve.admission import RejectedError
from repro.serve.resilience import (BreakerConfig, ResilienceConfig,
                                    RetryPolicy)
from repro.serve.service import (CodecService, EngineFailure, Response,
                                 ServiceConfig)

pytestmark = pytest.mark.slow

QUALITIES = (30, 75)
SHAPES = ((40, 40), (48, 56))


def test_chaos_storm_conserves_and_serves_identical_bytes():
    codec_engine = pytest.importorskip("repro.serve.codec_engine")
    rng = np.random.default_rng(0)
    pool = [rng.integers(0, 256, s, dtype=np.uint8) for s in SHAPES]

    inner = lambda images, quality: codec_engine.encode_batch(
        list(images), quality)
    # warm every (shape, quality) so jit compiles never eat the attempt
    # timeout on a shared runner
    for img in pool:
        for q in QUALITIES:
            inner([img], q)

    plan = FaultPlan(phases=(
        FaultPhase(start=2, stop=5, fail_rate=1.0),
        FaultPhase(start=8, stop=9, kill_rate=1.0),
        FaultPhase(start=10, stop=13, corrupt_rate=1.0),
    ), seed=0)
    eng = ChaosEngine(inner, plan)

    cfg = ServiceConfig(
        max_batch=4, max_wait_s=0.005, max_queue_depth=64,
        cache_entries=0, default_deadline_s=30.0,
        resilience=ResilienceConfig(
            timeout_s=5.0,
            retry=RetryPolicy(max_attempts=3, backoff_base_s=0.01,
                              backoff_cap_s=0.1, budget_rate=50,
                              budget_burst=100),
            breaker=BreakerConfig(window=8, min_calls=4,
                                  failure_threshold=0.5,
                                  reset_timeout_s=0.05),
            validate_payload=dctz_crc_ok))

    n_clients, per_client = 6, 5
    outcomes = []

    async def client(svc, cid):
        crng = np.random.default_rng(100 + cid)
        for _ in range(per_client):
            idx = int(crng.integers(len(pool)))
            q = QUALITIES[int(crng.integers(len(QUALITIES)))]
            try:
                resp = await svc.submit(pool[idx], quality=q)
                outcomes.append(("served", idx, q, resp))
            except RejectedError as exc:
                outcomes.append(("rejected", idx, q, exc))
            except EngineFailure as exc:
                outcomes.append(("failed", idx, q, exc))
            await asyncio.sleep(float(crng.uniform(0, 0.01)))

    async def go():
        async with CodecService(cfg, engine=eng) as svc:
            await asyncio.gather(*[client(svc, c)
                                   for c in range(n_clients)])
            # the storm is bounded in call-index space: once it has
            # passed, a fresh submit must be served cleanly again
            resp = await svc.submit(pool[0], quality=75)
            assert isinstance(resp, Response)
            outcomes.append(("served", 0, 75, resp))
        return svc.stats

    stats = asyncio.run(go())

    # one terminal outcome per submit, fully accounted
    assert len(outcomes) == n_clients * per_client + 1
    assert stats.submitted == n_clients * per_client + 1
    assert stats.submitted == (stats.served + stats.total_rejected
                               + stats.failed)
    assert stats.unhandled == 0
    assert stats.closed_unserved == 0

    # the storm actually happened and the envelope engaged
    counts = eng.event_counts()
    assert counts.get("fail", 0) >= 1
    assert counts.get("corrupt", 0) >= 1
    assert stats.retries >= 1

    # corruption is caught, never served: every served payload is
    # byte-identical to a serial encode of the same image/quality
    serial = {}
    for kind, idx, q, resp in outcomes:
        if kind != "served":
            continue
        key = (idx, q)
        if key not in serial:
            serial[key] = inner([pool[idx]], q)[0]
        assert bytes(resp.payload) == bytes(serial[key]), key
        assert dctz_crc_ok(resp.payload)
    assert any(kind == "served" for kind, *_ in outcomes)
