"""Quantiser + codec pipeline (the paper's experiment)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import codec, cordic, images, metrics, quant


class TestQuant:
    def test_quality_scales_table(self):
        q10 = np.asarray(quant.qtable(10))
        q50 = np.asarray(quant.qtable(50))
        q90 = np.asarray(quant.qtable(90))
        assert (q10 >= q50).all() and (q50 >= q90).all()
        np.testing.assert_allclose(q50, quant.JPEG_LUMA_QTABLE)

    @given(st.integers(0, 2**31 - 1), st.integers(1, 100))
    @settings(max_examples=20, deadline=None)
    def test_roundtrip_error_bounded_by_half_step(self, seed, quality):
        c = jnp.asarray(np.random.default_rng(seed).normal(
            scale=100, size=(4, 8, 8)).astype(np.float32))
        q = quant.qtable(quality)
        deq = quant.dequantize(quant.quantize(c, q), q)
        assert float(jnp.abs(deq - c).max()) <= float(q.max()) / 2 + 1e-3

    def test_zigzag_permutation(self):
        blk = jnp.arange(64).reshape(8, 8)
        z = np.asarray(quant.zigzag(blk))
        assert z[0] == 0 and z[1] == 1 and z[2] == 8 and z[3] == 16
        assert sorted(z.tolist()) == list(range(64))

    def test_bits_estimate_positive_and_monotone(self):
        rng = np.random.default_rng(0)
        small = jnp.asarray(rng.integers(-2, 2, (16, 8, 8)))
        big = jnp.asarray(rng.integers(-200, 200, (16, 8, 8)))
        assert float(quant.estimate_bits(small)) < float(
            quant.estimate_bits(big))


class TestCodec:
    def test_psnr_definition(self):
        o = jnp.full((16, 16), 200, jnp.uint8)
        c = o.at[0, 0].set(190)
        mse = 100.0 / 256.0
        expect = 20 * np.log10(200.0 / np.sqrt(mse))
        assert abs(float(metrics.psnr(o, c)) - expect) < 1e-3

    def test_roundtrip_quality_ordering(self):
        img = images.lena_like(128, 128)
        psnrs = [codec.roundtrip(img, q, "exact")[1] for q in (10, 50, 90)]
        assert psnrs[0] < psnrs[1] < psnrs[2]

    def test_nondivisible_size_padding(self):
        # the paper's 1024x814 case: 814 % 8 != 0
        img = images.lena_like(96, 102)
        rec, p = codec.roundtrip(img, 50)
        assert rec.shape == (96, 102)
        assert p > 25

    def test_loeffler_transform_equals_exact(self):
        img = images.lena_like(64, 64)
        _, p_exact = codec.roundtrip(img, 50, "exact")
        _, p_loef = codec.roundtrip(img, 50, "loeffler")
        assert abs(p_exact - p_loef) < 0.05

    def test_cordic_gap_in_paper_band(self):
        """Tables 3-4: Cordic-Loeffler loses ~1.1-3 dB vs exact DCT."""
        for gen, size in ((images.lena_like, (512, 512)),
                          (images.cablecar_like, (320, 288))):
            img = gen(*size)
            _, pe = codec.roundtrip(img, 50, "exact")
            _, pc = codec.roundtrip(img, 50, "cordic")
            assert 0.5 < pe - pc < 4.0, (pe, pc)

    def test_matched_adjoint_cancels_angle_error(self):
        """With a float datapath, the CORDIC *angle* error cancels between
        analysis and its adjoint synthesis (the finding recorded in
        EXPERIMENTS.md §PSNR: the paper's 2 dB gap therefore implies a
        fixed-point datapath, not the angle approximation)."""
        import jax.numpy as jnp
        from repro.core import dct as dct_mod, loeffler
        cfg = cordic.CordicConfig(iterations=3, gain_terms=4,
                                  fixed_point_bits=None)
        rot = cordic.make_cordic_rotate(cfg)
        x = jnp.asarray(np.random.default_rng(0).normal(
            scale=50, size=(10, 8, 8)).astype(np.float32))
        coef = loeffler.loeffler_dct2d_8x8(x, rotate_fn=rot)
        # matched adjoint: near-perfect roundtrip despite ~0.1 rad angle err
        rec_matched = loeffler.loeffler_idct2d_8x8(coef, rotate_fn=rot)
        rel_m = float(jnp.linalg.norm(rec_matched - x) /
                      jnp.linalg.norm(x))
        # standards-compliant decoder: exact IDCT sees the angle error
        rec_std = dct_mod.idct2d(coef)
        rel_s = float(jnp.linalg.norm(rec_std - x) / jnp.linalg.norm(x))
        assert rel_m < 0.01
        assert rel_s > 2 * rel_m

    def test_compression_ratio_above_one(self):
        img = images.lena_like(128, 128)
        c = codec.compress(img, 50)
        assert c.compression_ratio() > 2.0

    def test_psnr_range_matches_paper_tables(self):
        # paper: Lena 31.6-37.1 dB; Cable-car 24.2-32.3 dB at their sizes
        img = images.lena_like(512, 512)
        _, p = codec.roundtrip(img, 50)
        assert 28.0 < p < 45.0
        img2 = images.cablecar_like(320, 288)
        _, p2 = codec.roundtrip(img2, 50)
        assert 24.0 < p2 < 42.0
        assert p2 < p  # cable-car is harder, like the paper
