"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cordic, images, metrics, quant
from repro.core.entropy import bitio
from repro.kernels import grad_dct, pack_bits
from repro.kernels.cordic_loeffler import (cordic_loeffler_dct,
                                           cordic_loeffler_idct,
                                           cordic_loeffler_ref)
from repro.kernels.dct8x8 import dct8x8, dct8x8_ref, idct8x8, idct8x8_ref
from repro.kernels.fused_codec import fused_codec, fused_codec_ref

SHAPES = [(8, 8), (16, 64), (64, 16), (128, 128), (96, 200), (120, 104)]


def _img(shape, seed=0, dtype=np.float32):
    return jnp.asarray(np.random.default_rng(seed).normal(
        scale=50, size=shape).astype(dtype))


class TestDct8x8Kernel:
    @pytest.mark.parametrize("shape", SHAPES)
    def test_forward_matches_ref(self, shape):
        x = _img(shape)
        np.testing.assert_allclose(np.asarray(dct8x8(x)),
                                   np.asarray(dct8x8_ref(x)),
                                   atol=2e-3)

    @pytest.mark.parametrize("shape", [(16, 16), (64, 128)])
    def test_inverse_roundtrip(self, shape):
        x = _img(shape, 1)
        rec = idct8x8(dct8x8(x))
        np.testing.assert_allclose(np.asarray(rec), np.asarray(x), atol=1e-2)

    def test_batched(self):
        x = _img((3, 32, 32), 2)
        out = dct8x8(x)
        for i in range(3):
            np.testing.assert_allclose(np.asarray(out[i]),
                                       np.asarray(dct8x8_ref(x[i])),
                                       atol=2e-3)

    def test_bfloat16(self):
        x = _img((64, 64), 3).astype(jnp.bfloat16)
        out = dct8x8(x)
        assert out.dtype == jnp.bfloat16
        ref = dct8x8_ref(x.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref), rtol=0.06, atol=2.0)

    @pytest.mark.parametrize("tile", [8, 64, 256])
    def test_tile_sizes_agree(self, tile):
        x = _img((128, 128), 4)
        np.testing.assert_allclose(np.asarray(dct8x8(x, tile=tile)),
                                   np.asarray(dct8x8(x, tile=128)),
                                   atol=1e-4)


class TestCordicLoefflerKernel:
    @pytest.mark.parametrize("shape", SHAPES[:4])
    def test_matches_ref_exactly(self, shape):
        x = _img(shape, 5)
        out = cordic_loeffler_dct(x)
        ref = cordic_loeffler_ref(x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=0)

    def test_inverse_matches_ref(self, rng=None):
        x = _img((32, 32), 6)
        coeffs = cordic_loeffler_dct(x)
        rec = cordic_loeffler_idct(coeffs)
        ref = cordic_loeffler_ref(np.asarray(coeffs), inverse=True)
        np.testing.assert_allclose(np.asarray(rec), np.asarray(ref), atol=0)

    def test_float_config_approximates_exact(self):
        cfg = cordic.CordicConfig(16, 16, None)
        x = _img((32, 32), 7)
        out = cordic_loeffler_dct(x, config=cfg)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(dct8x8_ref(x)), atol=0.05)


class TestFusedCodecKernel:
    @pytest.mark.parametrize("quality", [10, 50, 90])
    def test_matches_unfused_ref(self, quality):
        img = images.lena_like(64, 64)
        rec, qc = fused_codec(img, quality=quality)
        ref_rec, ref_qc = fused_codec_ref(
            jnp.asarray(img, jnp.float32), quality)
        # kron-matmul vs separable accumulation order: allow off-by-one
        # quant levels at round boundaries for a tiny fraction of coeffs
        diff = np.abs(np.asarray(qc) - np.asarray(ref_qc))
        assert diff.max() <= 1
        assert (diff > 0).mean() < 1e-3
        np.testing.assert_allclose(np.asarray(rec, np.float32),
                                   np.asarray(ref_rec), atol=3.0)

    def test_cordic_transform_mode(self):
        img = images.cablecar_like(64, 64)
        rec, qc = fused_codec(img, quality=50, transform="cordic")
        ref_rec, ref_qc = fused_codec_ref(jnp.asarray(img, jnp.float32), 50,
                                          transform="cordic")
        assert (np.asarray(qc) == np.asarray(ref_qc)).all()

    def test_psnr_sane(self):
        img = images.lena_like(128, 128)
        rec, _ = fused_codec(img, quality=50)
        assert float(metrics.psnr(jnp.asarray(img), rec)) > 28.0


class TestPackBitsKernel:
    """Routed entropy bit packing: the staged NumPy reference and the
    Pallas scatter-pack kernel must be byte-identical to the retained
    ``bitio.pack_bits`` host-edge reference on every input."""

    @staticmethod
    def _both(codes, lengths):
        codes = np.asarray(codes)
        lengths = np.asarray(lengths)
        want = bitio.pack_bits(codes, lengths)
        assert pack_bits.pack_bits_ref(codes, lengths) == want
        assert pack_bits.pack_bits(codes, lengths, backend="pallas",
                                   interpret=True) == want
        return want

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=12, deadline=None)
    def test_random_field_streams(self, seed):
        rng = np.random.default_rng(seed)
        m = int(rng.integers(1, 700))
        # widths 0..16 with zero-width (absent amplitude) slots included
        lengths = rng.integers(0, 17, m)
        codes = rng.integers(0, 1 << 16, m) & ((1 << np.maximum(
            lengths, 1)) - 1)
        self._both(codes, lengths)

    @pytest.mark.parametrize("codes,lengths", [
        ([], []),                            # empty stream
        ([0], [0]),                          # only zero-width fields
        ([1], [1]),                          # single bit
        ([0xFFFF], [16]),                    # one max-width field
        ([0b101, 0b1], [3, 1]),              # partial final byte
        ([0xFFFF] * 200, [16] * 200),        # all-ones across 4 tiles
        ([0] * 1500, [1] * 1500),            # worst-case window density
        ([5, 0, 3, 0, 7], [3, 0, 2, 0, 3]),  # interleaved zero-widths
    ])
    def test_edge_cases(self, codes, lengths):
        self._both(codes, lengths)

    def test_tile_boundary_straddles(self):
        # 16-bit fields at every alignment force codes to straddle the
        # 1024-bit tile boundary in all 8 phase positions
        for phase in range(8):
            lengths = [1] * phase + [16] * 200
            codes = [1] * phase + [0xABCD & 0xFFFF] * 200
            self._both(codes, lengths)

    def test_multi_tile_payload(self):
        rng = np.random.default_rng(0)
        m = 4000                             # ~32k bits, many tiles
        lengths = rng.integers(1, 17, m)
        codes = rng.integers(0, 1 << 16, m) & ((1 << lengths) - 1)
        self._both(codes, lengths)

    def test_high_bits_above_field_width_are_ignored(self):
        # the contract reads only the low `lengths[k]` bits; stray high
        # bits must not leak into neighbouring bytes on any backend
        self._both([1, 3], [1, 1])
        self._both([0xFFFF, 0xFFFF, 0x7FFF], [3, 16, 1])
        rng = np.random.default_rng(7)
        lengths = rng.integers(0, 17, 300)
        codes = rng.integers(0, 1 << 16, 300)      # deliberately unmasked
        self._both(codes, lengths)

    def test_width_over_16_rejected(self):
        with pytest.raises(ValueError, match="wider"):
            pack_bits.pack_bits_ref(np.array([1]), np.array([17]))
        with pytest.raises(ValueError, match="wider"):
            pack_bits.pack_bits(np.array([1]), np.array([17]),
                                backend="pallas", interpret=True)

    def test_oversize_stream_falls_back_to_reference(self, monkeypatch):
        # streams past the VMEM guard must quietly take the NumPy path
        from repro.kernels.pack_bits import ops
        monkeypatch.setattr(ops, "MAX_DEVICE_FIELDS", 64)
        rng = np.random.default_rng(11)
        lengths = rng.integers(0, 17, 300)
        codes = rng.integers(0, 1 << 16, 300)
        self._both(codes, lengths)

    def test_backend_selection(self):
        # off-TPU "auto" resolves to the NumPy reference
        assert pack_bits.select_backend("auto") in pack_bits.BACKENDS
        if jax.default_backend() != "tpu":
            assert pack_bits.select_backend("auto") == "numpy"
            assert pack_bits.make_packer("auto") is None
        assert pack_bits.make_packer("pallas") is not None
        with pytest.raises(ValueError, match="backend"):
            pack_bits.select_backend("cuda")


class TestGradDctKernel:
    def test_encode_decode_match_ref(self):
        g = _img((8192,), 8)
        cg = grad_dct.encode(g, keep=16)
        q_ref, s_ref = grad_dct.grad_dct_encode_ref(g.reshape(-1, 64), 16)
        assert (np.asarray(cg.q) == np.asarray(q_ref)).all()
        np.testing.assert_allclose(np.asarray(cg.scale), np.asarray(s_ref),
                                   rtol=1e-6)
        dec = grad_dct.decode(cg)
        ref = grad_dct.grad_dct_decode_ref(q_ref, s_ref).reshape(-1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                                   atol=1e-5)

    @given(st.integers(1, 500), st.sampled_from([8, 16, 32, 48]))
    @settings(max_examples=20, deadline=None)
    def test_arbitrary_lengths(self, n, keep):
        g = _img((n,), n)
        dec = grad_dct.decode(grad_dct.encode(g, keep=keep))
        assert dec.shape == g.shape
        tail = n % 64
        if tail:
            np.testing.assert_allclose(np.asarray(dec[-tail:]),
                                       np.asarray(g[-tail:]))

    def test_smooth_signal_compacts(self):
        # low-frequency signal: keep=16 of 64 should reconstruct well
        t = np.linspace(0, 4 * np.pi, 4096).astype(np.float32)
        g = jnp.asarray(np.sin(t) + 0.5 * np.cos(2 * t))
        dec = grad_dct.decode(grad_dct.encode(g, keep=16))
        rel = float(jnp.linalg.norm(dec - g) / jnp.linalg.norm(g))
        assert rel < 0.05

    def test_wire_bytes_ratio(self):
        g = _img((65536,), 9)
        cg = grad_dct.encode(g, keep=16)
        ratio = g.size * 4 / cg.wire_bytes()
        assert ratio > 10.0  # 256/(16+4) = 12.8x nominal

    def test_keep_64_is_near_lossless_modulo_quant(self):
        g = _img((4096,), 10)
        dec = grad_dct.decode(grad_dct.encode(g, keep=64))
        rel = float(jnp.linalg.norm(dec - g) / jnp.linalg.norm(g))
        assert rel < 0.01  # int8 quantisation only
