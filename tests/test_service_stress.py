"""Concurrency stress tests for the async codec service (marked slow).

Excluded from the tier-1 run (``pytest -m "not slow"``); CI runs them in
the non-blocking bench-smoke job.  The core claims under real
concurrency: (1) every submitted request reaches exactly one terminal
outcome — a response, a reject, or an engine failure; nothing deadlocks
and nothing is dropped silently — and (2) payload bytes are identical
to a serial :func:`repro.serve.codec_engine.encode_batch` of the same
image at the same quality, i.e. the ``DCTZ`` stream does not depend on
how requests happened to be batched under load.
"""

import asyncio
import collections

import numpy as np
import pytest
from helpers.flaky import EchoEngine, FlakyEngine

from repro.serve.admission import RejectedError, TenantTier
from repro.serve.service import (CodecService, EngineFailure, Response,
                                 ServiceConfig)

pytestmark = pytest.mark.slow

QUALITIES = (30, 75)
SHAPES = ((40, 40), (48, 56), (100, 64))


def _pool(rng, per_shape=2):
    pool = []
    for shape in SHAPES:
        for _ in range(per_shape):
            pool.append(rng.integers(0, 256, shape, dtype=np.uint8))
    return pool


def test_async_clients_match_serial_encode_batch_bytes():
    codec_engine = pytest.importorskip("repro.serve.codec_engine")
    rng = np.random.default_rng(0)
    pool = _pool(rng)
    n_clients, per_client = 8, 6

    async def client(svc, cid, results):
        crng = np.random.default_rng(1000 + cid)
        for _ in range(per_client):
            idx = int(crng.integers(len(pool)))
            q = QUALITIES[int(crng.integers(len(QUALITIES)))]
            resp = await svc.submit(pool[idx], quality=q)
            results.append((idx, q, resp))
            await asyncio.sleep(float(crng.uniform(0, 0.005)))

    async def go():
        cfg = ServiceConfig(max_batch=4, max_wait_s=0.01,
                            max_queue_depth=64)
        results = []
        async with CodecService(cfg) as svc:
            await asyncio.gather(*[client(svc, c, results)
                                   for c in range(n_clients)])
        return results, svc.stats

    results, stats = asyncio.run(go())
    assert len(results) == n_clients * per_client
    assert stats.served == len(results)
    assert stats.failed == 0 and stats.total_rejected == 0

    # serial oracle: one encode per distinct (image, quality)
    serial = {}
    for q in QUALITIES:
        blobs = codec_engine.encode_batch(pool, q)
        for idx, blob in enumerate(blobs):
            serial[(idx, q)] = blob
    for idx, q, resp in results:
        assert isinstance(resp, Response)
        assert resp.payload == serial[(idx, q)], (
            f"bytes diverge for image {idx} q{q} "
            f"(batch_size={resp.batch_size}, cache={resp.cache_hit})")
    # with 6 distinct images x 2 qualities and 48 requests, the
    # hot-stream cache must have absorbed most of the load
    assert stats.occupancy and sum(
        k * v for k, v in stats.occupancy.items()) <= len(results)


def test_heavy_fault_mix_conserves_every_request():
    # EchoEngine keeps this CPU-cheap at a volume (400 requests, 20
    # clients) where a dispatch-loop deadlock or silent drop would hang
    # or miscount; faults cover engine failures, rejects and deadlines
    n_clients, per_client = 20, 20

    async def client(svc, cid, counter):
        crng = np.random.default_rng(2000 + cid)
        tenant = "free" if cid % 3 == 0 else "default"
        for i in range(per_client):
            img = crng.integers(0, 256, SHAPES[cid % len(SHAPES)],
                                dtype=np.uint8)
            deadline = (None if crng.random() < 0.5
                        else float(crng.uniform(0.005, 0.2)))
            try:
                resp = await svc.submit(img, quality=50, tenant=tenant,
                                        deadline_s=deadline)
                counter["served"] += 1
                if resp.deadline_missed:
                    counter["late"] += 1
            except RejectedError as exc:
                counter[f"rejected:{exc.reason}"] += 1
            except EngineFailure:
                counter["failed"] += 1
            if crng.random() < 0.3:
                await asyncio.sleep(float(crng.uniform(0, 0.002)))

    async def go():
        engine = FlakyEngine(EchoEngine(step_s=0.002), fail_rate=0.1,
                             seed=3)
        cfg = ServiceConfig(
            max_batch=4, max_wait_s=0.004, max_queue_depth=8,
            initial_step_s=0.002, cache_entries=0,
            tenants={"free": TenantTier(max_quality=40,
                                        min_deadline_s=0.05)})
        counter = collections.Counter()
        async with CodecService(cfg, engine=engine) as svc:
            await asyncio.wait_for(
                asyncio.gather(*[client(svc, c, counter)
                                 for c in range(n_clients)]),
                timeout=120)
        return counter, svc.stats

    counter, stats = asyncio.run(go())
    total = n_clients * per_client
    outcomes = (counter["served"] + counter["failed"]
                + sum(v for k, v in counter.items()
                      if k.startswith("rejected:")))
    assert outcomes == total, f"lost/duplicated outcomes: {counter}"
    assert stats.submitted == total
    assert stats.served == counter["served"]
    assert stats.failed == counter["failed"]
    assert stats.total_rejected == total - counter["served"] \
        - counter["failed"]
    assert stats.engine_failures > 0     # faults actually fired
    assert counter["served"] > 0
