"""Core DCT math: exactness, orthonormality, Loeffler graph, CORDIC."""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cordic, dct, loeffler

F32 = np.float32


def _rand(shape, seed=0):
    return np.random.default_rng(seed).normal(size=shape).astype(F32)


class TestDctMatrix:
    def test_orthonormal(self):
        for n in (4, 8, 16, 64):
            c = dct._dct_matrix_np(n)   # float64 host-side matrix
            np.testing.assert_allclose(c @ c.T, np.eye(n), atol=1e-12)

    def test_matches_definition(self):
        # paper eq. (3): F(x) = sqrt(2/N) sum alpha(i) cos(...) f(i)
        n = 8
        c = dct._dct_matrix_np(n)
        x = _rand((n,)).astype(np.float64)
        for k in range(n):
            alpha = math.sqrt(0.5) if k == 0 else 1.0
            expect = math.sqrt(2.0 / n) * alpha * sum(
                x[i] * math.cos(math.pi * k * (2 * i + 1) / (2 * n))
                for i in range(n))
            assert abs((c @ x)[k] - expect) < 1e-12

    def test_kron_equals_separable(self):
        img = jnp.asarray(_rand((2, 32, 40)))
        a = dct.blockwise_dct2d(img)
        b = dct.blockwise_dct2d_kron(img)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_parseval(self, seed):
        x = jnp.asarray(_rand((8, 8), seed))
        y = dct.dct2d(x)
        assert abs(float((x**2).sum()) - float((y**2).sum())) < 1e-3

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_roundtrip(self, seed):
        x = jnp.asarray(_rand((16, 24), seed))
        rec = dct.blockwise_idct2d(dct.blockwise_dct2d(x))
        np.testing.assert_allclose(np.asarray(rec), np.asarray(x), atol=1e-4)

    def test_dc_coefficient(self):
        x = jnp.ones((8, 8))
        y = dct.dct2d(x)
        # orthonormal: DC = mean * N = 8 for all-ones
        assert abs(float(y[0, 0]) - 8.0) < 1e-5
        assert float(jnp.abs(y).sum()) - 8.0 < 1e-4  # all AC zero


class TestLoeffler:
    def test_matches_exact_dct(self):
        x = jnp.asarray(_rand((100, 8)))
        np.testing.assert_allclose(
            np.asarray(loeffler.loeffler_dct8(x)),
            np.asarray(dct.dct1d(x)), atol=2e-5)

    def test_inverse_is_transpose(self):
        x = jnp.asarray(_rand((50, 8), 1))
        y = loeffler.loeffler_dct8(x)
        rec = loeffler.loeffler_idct8(y)
        np.testing.assert_allclose(np.asarray(rec), np.asarray(x), atol=2e-5)

    def test_2d(self):
        blocks = jnp.asarray(_rand((5, 8, 8), 2))
        np.testing.assert_allclose(
            np.asarray(loeffler.loeffler_dct2d_8x8(blocks)),
            np.asarray(dct.dct2d(blocks)), atol=5e-5)

    def test_stage_count_is_serial(self):
        # the graph is 4 serial stages (paper §2.5.2) — structural property:
        # rotations are only in stages 2/3, never stage 1/4
        assert loeffler.THETA_ODD_A == 3 * math.pi / 16
        assert loeffler.THETA_ODD_B == math.pi / 16
        assert loeffler.THETA_EVEN == math.pi / 8


class TestCordic:
    def test_high_precision_matches_exact(self):
        cfg = cordic.EXACT_CONFIG
        u = jnp.asarray(_rand((100,)))
        v = jnp.asarray(_rand((100,), 1))
        for th in (loeffler.THETA_ODD_A, loeffler.THETA_ODD_B,
                   loeffler.THETA_EVEN):
            eu, ev = loeffler.exact_rotate(u, v, th)
            cu, cv = cordic.cordic_rotate(u, v, th, cfg)
            np.testing.assert_allclose(np.asarray(cu), np.asarray(eu),
                                       atol=1e-5)
            np.testing.assert_allclose(np.asarray(cv), np.asarray(ev),
                                       atol=1e-5)

    def test_paper_config_bounded_error(self):
        for th in (loeffler.THETA_ODD_A, loeffler.THETA_ODD_B,
                   loeffler.THETA_EVEN):
            ang_err, gain_err = cordic.rotation_error(th,
                                                      cordic.PAPER_CONFIG)
            assert ang_err < 0.15          # few-iteration approximation
            assert gain_err < 0.01

    def test_more_iterations_reduce_angle_error(self):
        errs = [cordic.rotation_error(loeffler.THETA_EVEN,
                                      cordic.CordicConfig(n, 24, None))[0]
                for n in (2, 4, 8, 16)]
        assert errs[-1] < errs[0]
        assert errs[-1] < 1e-3

    def test_cordic_loeffler_is_approximate_dct(self):
        x = jnp.asarray(_rand((64, 8), 3))
        exact = dct.dct1d(x)
        approx = loeffler.loeffler_dct8(
            x, rotate_fn=cordic.make_cordic_rotate(
                cordic.CordicConfig(4, 3, None)))
        err = float(jnp.abs(exact - approx).max())
        assert 1e-6 < err < 0.5 * float(jnp.abs(exact).max())
