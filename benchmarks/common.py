"""Legacy CSV helpers for the thin ``benchmarks/`` entrypoints.

All timing goes through :mod:`repro.bench.timer`; this module only keeps
the historical ``name,us_per_call,derived`` stdout format alive.  New
code should use ``python -m repro.bench run`` and consume JSON artifacts
instead (docs/benchmarks.md).
"""

from __future__ import annotations


def row(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")


def rows_from_records(prefix: str, records, legs=("parallel", "serial"),
                      metrics_fmt=None):
    """Print one legacy CSV row per (record, leg).

    Args:
        prefix: row-name prefix, e.g. ``"table1_lena"``.
        records: :class:`repro.bench.schema.BenchRecord` list.
        legs: timing legs to emit (ignored for records without timings —
            those print one ``us=0`` row carrying only derived metrics).
        metrics_fmt: optional ``record -> str`` for the derived column;
            defaults to ``k=v`` pairs from ``record.metrics``.
    """
    for r in records:
        derived = (metrics_fmt(r) if metrics_fmt else
                   ";".join(f"{k}={v:.3f}" for k, v in r.metrics.items()))
        if not r.timings_us:
            row(f"{prefix}_{r.label}", 0.0, derived)
            continue
        for leg in legs:
            if leg not in r.timings_us:
                continue
            us = r.timings_us[leg]["median_us"]
            row(f"{prefix}_{r.label}_{leg}", us,
                derived if leg == legs[0] else f"leg={leg}")
