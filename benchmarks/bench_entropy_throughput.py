"""Entropy-stage throughput: vectorized vs scalar-reference coding —
thin entrypoint over ``repro.bench``.

The measurements are :func:`repro.bench.cases.entropy_throughput_points`
(shared with the ``entropy_throughput`` registry case that feeds
RESULTS.md); this script keeps a CSV interface and the
``--check-identical`` CI gate, which covers the whole entropy stage:
the vectorized encoder/decoder must produce byte-identical output to
the scalar reference path; every routed pack-bits backend (the staged
NumPy reference and the Pallas scatter-pack kernel, interpret mode
off-TPU) must produce byte-identical payloads and whole ``DCTZ``
streams; every routed unpack-bits backend (the staged speculative
NumPy decode and the Pallas speculative kernel, interpret mode off-TPU)
must decode coefficients identical to ``decode_payload_reference`` and
reject truncated streams with the LUT walk's exact errors; and every
routed symbolize backend (the fused dense NumPy pass and the Pallas
symbolize kernel, interpret mode off-TPU) must match the scalar
``symbolize_reference`` oracle element-for-element — streams,
histograms, payload bytes, RangeError messages, and whole framed
``DCTZ`` v1/v2 containers under every table policy — all on random
*and* adversarial blocks (max-magnitude amplitudes, all-zero blocks,
ZRL chains).  Speed numbers are reported but never gated —
shared CI runners are too noisy for timing asserts
(docs/benchmarks.md).

    PYTHONPATH=src python benchmarks/bench_entropy_throughput.py
    PYTHONPATH=src python benchmarks/bench_entropy_throughput.py \
        --size 128 --batches 1 4 --check-identical
"""

from __future__ import annotations

import argparse
import sys

import jax

from repro.bench.cases import (entropy_identity_violations,
                               entropy_throughput_points,
                               packing_identity_violations,
                               symbolize_identity_violations,
                               unpack_identity_violations)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=256,
                    help="square image side for the throughput sweep")
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 4, 8])
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--trials", type=int, default=25,
                    help="random batches for --check-identical")
    ap.add_argument("--check-identical", action="store_true",
                    help="exit 1 unless the vectorized entropy path is "
                         "byte-identical to the scalar reference AND "
                         "every routed pack-bits backend (staged NumPy "
                         "+ Pallas kernel) is byte-identical to the "
                         "NumPy reference AND every routed unpack-bits "
                         "backend decodes (and rejects malformed "
                         "streams) identically to the scalar decode "
                         "oracle AND every routed symbolize backend "
                         "(fused dense NumPy + Pallas kernel) matches "
                         "the scalar symbolize oracle — streams, "
                         "histograms, payloads and framed DCTZ v1/v2 "
                         "containers — on random + adversarial blocks")
    args = ap.parse_args()

    print(f"# backend={jax.default_backend()} "
          f"devices={jax.local_device_count()} size={args.size}")

    if args.check_identical:
        bad = (entropy_identity_violations(trials=args.trials)
               + packing_identity_violations(trials=args.trials)
               + unpack_identity_violations(trials=args.trials)
               + symbolize_identity_violations(trials=args.trials))
        if bad:
            print("IDENTITY VIOLATIONS:", file=sys.stderr)
            for line in bad:
                print(f"  {line}", file=sys.stderr)
            return 1
        print(f"identity OK: vectorized == reference, routed packing "
              f"backends == NumPy reference, routed unpack backends "
              f"== scalar decode oracle, and routed symbolize "
              f"backends == scalar symbolize oracle on {args.trials} "
              f"random cases + adversarial blocks")

    records = entropy_throughput_points(args.size, sorted(args.batches),
                                        warmup=1, iters=args.iters)
    stage = records[0]
    print(f"entropy stage {args.size}x{args.size}: "
          f"encode {stage.metrics['enc_speedup']:.1f}x "
          f"({stage.metrics['enc_mb_per_s']:.1f} MB/s), "
          f"decode {stage.metrics['dec_speedup']:.1f}x "
          f"({stage.metrics['dec_mb_per_s']:.1f} MB/s) vs reference")
    for r in records:
        if not r.label.startswith("encode_stages"):
            continue
        us = {k: v["median_us"] for k, v in r.timings_us.items()}
        print(f"encode stages {args.size}x{args.size}: "
              f"symbolize {us['stage_symbolize']:.0f}us "
              f"(vectorized {us['stage_symbolize_vectorized']:.0f}us, "
              f"{r.metrics['symbolize_speedup_vs_vectorized']:.2f}x), "
              f"tables {us['stage_table_choice']:.0f}us, "
              f"codeword {us['stage_codeword']:.0f}us, "
              f"pack {us['stage_pack']:.0f}us; "
              f"transfer {r.metrics['device_transfer_bytes_per_image']:.0f}B"
              f" device vs {r.metrics['host_transfer_bytes_per_image']:.0f}B"
              f" host ({r.metrics['transfer_reduction']:.1f}x)")
    print("batch,enc_img_per_s,enc_img_per_s_serial,dec_img_per_s,"
          "enc_mb_per_s,speedup_vs_reference")
    for r in records:
        if "batch" not in r.params:
            continue
        print(f"{r.params['batch']},{r.metrics['enc_img_per_s']:.2f},"
              f"{r.metrics['enc_img_per_s_serial']:.2f},"
              f"{r.metrics['dec_img_per_s']:.2f},"
              f"{r.metrics['enc_mb_per_s']:.2f},"
              f"{r.metrics['speedup_vs_reference']:.2f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
