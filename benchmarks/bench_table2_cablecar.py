"""Paper Table 2 (Cable-car timings) — thin entrypoint over ``repro.bench``.

The case lives in :mod:`repro.bench.cases` (``table2_cablecar``).  Prefer::

    PYTHONPATH=src python -m repro.bench run --suite paper \
        --cases table2_cablecar
"""

from __future__ import annotations

from benchmarks.common import rows_from_records
from repro.bench import RunContext, get
from repro.bench.runner import SUITE_TIMERS


def run(full: bool = False):
    suite = "full" if full else "paper"
    ctx = RunContext(suite=suite, timer=SUITE_TIMERS[suite])
    records = get("table2_cablecar").run(ctx)
    rows_from_records(
        "table2", records,
        metrics_fmt=lambda r: f"speedup={r.metrics['speedup']:.1f}x")


if __name__ == "__main__":
    run(full=True)
