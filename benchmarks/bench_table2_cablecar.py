"""Paper Table 2: DCT codec time vs Cable-car image size (serial/parallel).

Same legs as bench_table1 on the paper's Cable-car sizes.
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.bench_table1_lena import _parallel_codec, _serial_codec
from benchmarks.common import row, time_fn
from repro.core import images, quant

SIZES = [(544, 512), (512, 480), (448, 416), (384, 352), (320, 288)]


def run(full: bool = False):
    q = quant.qtable(50)
    sizes = SIZES if full else SIZES[:3]
    for (h, w) in sizes:
        img = jnp.asarray(images.cablecar_like(h, w))
        us_par = time_fn(_parallel_codec, img, q, warmup=1, iters=3)
        us_ser = time_fn(_serial_codec, img, q, warmup=1, iters=3)
        row(f"table2_cablecar_{h}x{w}_parallel", us_par,
            f"speedup={us_ser/us_par:.1f}x")
        row(f"table2_cablecar_{h}x{w}_serial", us_ser, "leg=serial")


if __name__ == "__main__":
    run(full=True)
