"""Legacy CSV harness — thin entrypoint over ``repro.bench``.

Prints ``name,us_per_call,derived`` rows for the paper tables and the
framework micro-benches.  ``--full`` selects the paper's complete size
grids.  The JSON-artifact pipeline (preferred; feeds RESULTS.md)::

    PYTHONPATH=src python -m repro.bench run --suite paper --out results/
    PYTHONPATH=src python -m repro.bench report
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper's complete size grids (slow on 1 CPU core)")
    args = ap.parse_args()

    from benchmarks import (bench_framework, bench_table1_lena,
                            bench_table2_cablecar, bench_table3_psnr_lena,
                            bench_table4_psnr_cablecar)

    print("name,us_per_call,derived")
    bench_table1_lena.run(full=args.full)
    bench_table2_cablecar.run(full=args.full)
    bench_table3_psnr_lena.run(full=args.full)
    bench_table4_psnr_cablecar.run(full=args.full)
    bench_framework.run(full=args.full)


if __name__ == '__main__':
    main()
