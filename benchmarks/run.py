"""Benchmark harness — one function per paper table + framework benches.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` runs the paper's
complete size grids (several minutes on one CPU core); default is the
representative subset used by CI.
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper's complete size grids (slow on 1 CPU core)")
    args = ap.parse_args()

    from benchmarks import (bench_framework, bench_table1_lena,
                            bench_table2_cablecar, bench_table3_psnr_lena,
                            bench_table4_psnr_cablecar)

    print("name,us_per_call,derived")
    bench_table1_lena.run(full=args.full)
    bench_table2_cablecar.run(full=args.full)
    bench_table3_psnr_lena.run(full=args.full)
    bench_table4_psnr_cablecar.run(full=args.full)
    bench_framework.run(full=args.full)


if __name__ == '__main__':
    main()
