"""Batched codec throughput — thin entrypoint over ``repro.bench``.

The sweep itself is :func:`repro.bench.cases.batch_throughput_grid`
(shared with the ``serve_batch_throughput`` registry case that feeds
RESULTS.md); this script keeps the historical CSV interface and the
``--check-monotone`` CI gate.

    PYTHONPATH=src python benchmarks/bench_batch_throughput.py
    PYTHONPATH=src python benchmarks/bench_batch_throughput.py --size 128 \
        --max-batch 64 --check-monotone
"""

from __future__ import annotations

import argparse
import sys

import jax

from repro.bench.cases import (batch_sizes, batch_throughput_grid,
                               check_monotone)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=8,
                    help="square image side; the default is the paper's "
                         "atomic 8x8 block, where per-call dispatch "
                         "overhead — the thing batching amortises — "
                         "dominates.  Use larger sizes for realistic "
                         "service throughput numbers.")
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--iters", type=int, default=15)
    ap.add_argument("--check-monotone", action="store_true",
                    help="exit 1 unless throughput strictly increases "
                         "from batch 1 to 64")
    args = ap.parse_args()

    batches = batch_sizes(args.max_batch)
    print(f"# backend={jax.default_backend()} "
          f"devices={jax.local_device_count()} size={args.size}")
    print("batch," + ",".join(f"{t}_img_per_s" for t in ("exact", "cordic")))

    results = batch_throughput_grid(("exact", "cordic"), args.size, batches,
                                    args.iters)
    for b in batches:
        print(f"{b}," + ",".join(f"{results[t][b]:.1f}"
                                 for t in ("exact", "cordic")))

    if args.check_monotone:
        checked = [b for b in batches if b <= 64]
        lo, hi = checked[0], checked[-1]
        bad = [(t, a, b) for t in results
               for a, b in check_monotone(results[t], up_to=64)]
        if bad:
            print(f"NOT monotone {lo}->{hi}: {bad}", file=sys.stderr)
            raise SystemExit(1)
        print(f"# monotone {lo}->{hi}: OK")


if __name__ == "__main__":
    main()
