"""Batched codec throughput: images/sec vs batch size (1 -> 256).

The paper attributes the GPU's win to saturating the device with many
independent 8x8 blocks; this benchmark shows the same effect from
*batching* through the multi-device engine — per-call dispatch and
launch overheads amortise, so images/sec grows with batch size until
the backend saturates.

    PYTHONPATH=src python benchmarks/bench_batch_throughput.py
    PYTHONPATH=src python benchmarks/bench_batch_throughput.py --size 128 \
        --max-batch 64 --check-monotone
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.core import images
from repro.serve import codec_engine


def bench_transform(transform: str, size: int, batches, iters: int) -> dict:
    """Best-of-N throughput per batch size, with the N timing rounds
    *interleaved* across batch sizes so machine-load drift (shared CI
    runners) biases every batch size equally instead of whichever one it
    happened to land on."""
    base = np.stack([images.lena_like(size, size, seed=i)
                     for i in range(max(batches))])

    def run(x):
        rec, _ = codec_engine.roundtrip_batch(x, 50, transform,
                                              with_psnr=False)
        return rec

    best = {b: float("inf") for b in batches}
    for b in batches:                       # compile + warm every shape
        for _ in range(2):
            jax.block_until_ready(run(base[:b]))
    for _ in range(iters):
        for b in batches:
            t0 = time.perf_counter()
            jax.block_until_ready(run(base[:b]))
            best[b] = min(best[b], time.perf_counter() - t0)
    return {b: b / best[b] for b in batches}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=8,
                    help="square image side; the default is the paper's "
                         "atomic 8x8 block, where per-call dispatch "
                         "overhead — the thing batching amortises — "
                         "dominates.  Use larger sizes for realistic "
                         "service throughput numbers.")
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--iters", type=int, default=15)
    ap.add_argument("--check-monotone", action="store_true",
                    help="exit 1 unless throughput strictly increases "
                         "from batch 1 to 64")
    args = ap.parse_args()

    batches = [b for b in (1, 2, 4, 8, 16, 32, 64, 128, 256)
               if b <= args.max_batch]
    print(f"# backend={jax.default_backend()} "
          f"devices={jax.local_device_count()} size={args.size}")
    print("batch," + ",".join(f"{t}_img_per_s" for t in ("exact", "cordic")))

    results = {}
    for transform in ("exact", "cordic"):
        results[transform] = bench_transform(transform, args.size, batches,
                                             args.iters)
    for b in batches:
        print(f"{b}," + ",".join(f"{results[t][b]:.1f}"
                                 for t in ("exact", "cordic")))

    if args.check_monotone:
        lo, hi = [b for b in batches if b <= 64][0], [
            b for b in batches if b <= 64][-1]
        checked = [b for b in batches if b <= 64]
        bad = [(t, a, b) for t in results
               for a, b in zip(checked, checked[1:])
               if results[t][b] <= results[t][a]]
        if bad:
            print(f"NOT monotone {lo}->{hi}: {bad}", file=sys.stderr)
            raise SystemExit(1)
        print(f"# monotone {lo}->{hi}: OK")


if __name__ == "__main__":
    main()
