"""Framework micro-benches: fusion win, grad compression, KV compression,
decode step throughput (reduced configs, CPU wall-clock)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import dct, images, quant
from repro.kernels import grad_dct


def bench_fusion():
    """Unfused 3-pass (paper's kernel structure) vs fused 1-pass codec."""
    img = jnp.asarray(images.lena_like(1024, 1024), jnp.float32)
    q = quant.qtable(50)

    @jax.jit
    def unfused(img):
        x = img - 128.0
        coef = dct.blockwise_dct2d_kron(x)          # pass 1 (DCT kernel)
        qc = jnp.round(coef / q) * q                # pass 2 (quantiser)
        return dct.blockwise_idct2d_kron(qc) + 128  # pass 3 (IDCT kernel)

    @jax.jit
    def fused(img):
        x = img - 128.0
        t = dct.kron_dct_matrix(8)
        blocks = dct.to_blocks(x).reshape(-1, 64)
        coef = blocks @ t.T
        qv = q.reshape(64)
        qc = jnp.round(coef / qv) * qv
        rec = (qc @ t).reshape(128, 128, 8, 8)
        return dct.from_blocks(rec) + 128.0

    us_u = time_fn(unfused, img, warmup=1, iters=5)
    us_f = time_fn(fused, img, warmup=1, iters=5)
    row("fused_codec_1024", us_f, f"unfused_us={us_u:.0f};"
        f"fusion_speedup={us_u/us_f:.2f}x")


def bench_grad_compress():
    g = jax.random.normal(jax.random.key(0), (4 * 1024 * 1024,))
    fn = jax.jit(functools.partial(grad_dct.roundtrip, keep=16,
                                   interpret=True))
    us = time_fn(fn, g, warmup=1, iters=3)
    mb = g.size * 4 / 1e6
    cg = grad_dct.encode(g, keep=16)
    row("grad_dct_roundtrip_16MB", us,
        f"MB/s={mb/(us/1e6):.0f};wire_ratio={g.size*4/cg.wire_bytes():.1f}x")


def bench_kv_compress():
    from repro.serve import kv_compress
    cache = {"k": jax.random.normal(jax.random.key(1),
                                    (4, 2, 512, 4, 32), jnp.bfloat16),
             "v": jax.random.normal(jax.random.key(2),
                                    (4, 2, 512, 4, 32), jnp.bfloat16)}
    raw = sum(v.size * v.dtype.itemsize for v in cache.values())

    def roundtrip(c):
        ckv, tails = kv_compress.compress_cache(c, keep=16, prefix_len=512)
        return kv_compress.reconstruct_cache(ckv, tails)

    us = time_fn(roundtrip, cache, warmup=1, iters=3)
    ckv, tails = kv_compress.compress_cache(cache, keep=16, prefix_len=512)
    comp = kv_compress.wire_bytes(ckv, tails)
    row("kv_dct_roundtrip", us, f"hbm_ratio={raw/comp:.1f}x")


def bench_decode_step():
    from repro.configs import registry as R
    from repro.models import registry as M
    from repro.serve import engine
    cfg = R.reduced("smollm-360m", n_layers=4, d_model=128, vocab_size=1024)
    params = M.init_params(cfg, jax.random.key(0))
    cache = M.init_cache(cfg, batch=8, max_len=256)
    step = engine.make_decode_step(cfg)
    tok = jnp.zeros((8, 1), jnp.int32)
    key = jax.random.key(0)
    fn = lambda: step(params, tok, cache, jnp.asarray(128, jnp.int32), key)
    us = time_fn(fn, warmup=2, iters=5)
    row("decode_step_b8_reduced", us, f"tok/s={8/(us/1e6):.0f}")


def run(full: bool = False):
    bench_fusion()
    bench_grad_compress()
    bench_kv_compress()
    bench_decode_step()


if __name__ == "__main__":
    run()
