"""Framework micro-benches — thin entrypoint over ``repro.bench``.

Fusion win, grad compression, KV compression and decode-step throughput
now live in :mod:`repro.bench.cases` (``framework_micro``).  Prefer::

    PYTHONPATH=src python -m repro.bench run --suite micro
"""

from __future__ import annotations

from benchmarks.common import row
from repro.bench import RunContext, get
from repro.bench.runner import SUITE_TIMERS


def run(full: bool = False):
    ctx = RunContext(suite="micro", timer=SUITE_TIMERS["micro"])
    for r in get("framework_micro").run(ctx):
        leg, timing = next(iter(r.timings_us.items()))
        derived = ";".join(f"{k}={v:.2f}" for k, v in r.metrics.items())
        row(r.label, timing["median_us"], derived)


if __name__ == "__main__":
    run()
