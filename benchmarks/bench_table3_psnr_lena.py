"""Paper Table 3: PSNR of exact DCT vs Cordic-based Loeffler DCT on Lena.

Paper values (their images): DCT 31.6-37.1 dB, Cordic-Loeffler ~2 dB lower,
both increasing with image size.  Our synthetic Lena stand-in reproduces
the ordering, the size trend and the gap band (absolute dB differ — see
DESIGN.md §6 item 4).
"""

from __future__ import annotations

from benchmarks.common import row
from repro.core import codec, images

SIZES = [(200, 200), (512, 512), (2048, 2048), (3072, 3072)]


def run(full: bool = False):
    sizes = SIZES if full else SIZES[:2]
    for (h, w) in sizes:
        img = images.lena_like(h, w)
        _, p_dct = codec.roundtrip(img, 50, "exact")
        _, p_cor = codec.roundtrip(img, 50, "cordic")
        row(f"table3_psnr_lena_{h}x{w}", 0.0,
            f"dct_db={p_dct:.3f};cordic_db={p_cor:.3f};"
            f"gap_db={p_dct - p_cor:.3f}")


if __name__ == "__main__":
    run(full=True)
