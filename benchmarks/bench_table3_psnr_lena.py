"""Paper Table 3 (Lena PSNR) — thin entrypoint over ``repro.bench``.

The case lives in :mod:`repro.bench.cases` (``table3_psnr_lena``).  Prefer::

    PYTHONPATH=src python -m repro.bench run --suite paper \
        --cases table3_psnr_lena
"""

from __future__ import annotations

from benchmarks.common import rows_from_records
from repro.bench import RunContext, get


def _fmt(r):
    return (f"dct_db={r.metrics['psnr_db_exact']:.3f};"
            f"cordic_db={r.metrics['psnr_db_cordic']:.3f};"
            f"gap_db={r.metrics['gap_db']:.3f}")


def run(full: bool = False):
    ctx = RunContext(suite="full" if full else "paper")
    records = get("table3_psnr_lena").run(ctx)
    rows_from_records("table3_psnr", records, metrics_fmt=_fmt)


if __name__ == "__main__":
    run(full=True)
