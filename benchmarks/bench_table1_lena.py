"""Paper Table 1 (Lena timings) — thin entrypoint over ``repro.bench``.

The case itself lives in :mod:`repro.bench.cases` (``table1_lena``);
this script keeps the historical CSV-to-stdout interface.  Prefer::

    PYTHONPATH=src python -m repro.bench run --suite paper --cases table1_lena
"""

from __future__ import annotations

from benchmarks.common import rows_from_records
from repro.bench import RunContext, get
from repro.bench.runner import SUITE_TIMERS


def run(full: bool = False):
    suite = "full" if full else "paper"
    ctx = RunContext(suite=suite, timer=SUITE_TIMERS[suite])
    records = get("table1_lena").run(ctx)
    rows_from_records(
        "table1", records,
        metrics_fmt=lambda r: (f"speedup={r.metrics['speedup']:.1f}x;"
                               f"mpix/s={r.metrics['mpix_per_s']:.1f}"))


if __name__ == "__main__":
    run(full=True)
