"""Paper Table 1: DCT codec time vs Lena image size (serial vs parallel).

The paper measures CPU-serial vs GPU-parallel on a GTX 480.  This container
has no GPU, so the two legs are reproduced structurally on one CPU:

  serial   — the paper's CPU code shape: per-block loop (lax.map over
             8x8 blocks, one at a time, unfused three-pass DCT/quant/IDCT)
  parallel — the TPU-style data-parallel path: all blocks batched in one
             fused pipeline (what the Pallas kernel does per VMEM tile)

``derived`` reports the speedup (serialµs/parallelµs) and MPix/s of the
parallel leg; the *trend with image size* is the reproduction target
(paper Figs 5/6), not GTX-480 milliseconds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import dct, images, quant

# paper Table 1 sizes (largest first, like the paper)
SIZES = [(1024, 1024), (512, 512), (200, 200)]
SIZES_FULL = [(3072, 3072), (2048, 2048), (1600, 1400), (1024, 814),
              (576, 720), (512, 512), (200, 200)]


@functools.partial(jax.jit, static_argnames=())
def _parallel_codec(img, q):
    x = img.astype(jnp.float32) - 128.0
    coef = dct.blockwise_dct2d_kron(x)
    qc = jnp.round(coef / q)
    rec = dct.blockwise_idct2d_kron(qc * q)
    return jnp.clip(jnp.round(rec + 128.0), 0, 255).astype(jnp.uint8)


@jax.jit
def _serial_codec(img, q):
    """Per-block sequential processing (the paper's CPU loop shape)."""
    x = img.astype(jnp.float32) - 128.0
    blocks = dct.to_blocks(x)
    hb, wb = blocks.shape[0], blocks.shape[1]
    flat = blocks.reshape(hb * wb, 8, 8)

    def one(block):
        coef = dct.dct2d(block)
        qc = jnp.round(coef / q)
        return dct.idct2d(qc * q)

    out = jax.lax.map(one, flat)   # sequential over blocks
    rec = dct.from_blocks(out.reshape(hb, wb, 8, 8))
    return jnp.clip(jnp.round(rec + 128.0), 0, 255).astype(jnp.uint8)


def run(full: bool = False):
    q = quant.qtable(50)
    for (h, w) in (SIZES_FULL if full else SIZES):
        img = jnp.asarray(images.lena_like(h, w))
        us_par = time_fn(_parallel_codec, img, q, warmup=1, iters=3)
        us_ser = time_fn(_serial_codec, img, q, warmup=1, iters=3)
        mpixs = (h * w) / us_par
        row(f"table1_lena_{h}x{w}_parallel", us_par,
            f"speedup={us_ser/us_par:.1f}x;mpix/s={mpixs:.1f}")
        row(f"table1_lena_{h}x{w}_serial", us_ser, "leg=serial")


if __name__ == "__main__":
    run(full=True)
