"""Analytic MODEL_FLOPS per (arch x shape) cell.

Conventions (standard accounting):
  * dense matmul flops = 2 * m * n * k
  * train = fwd + bwd = 3x fwd on parameter matmuls => 6 * N_active * tokens
  * causal attention fwd = 4 * B * S^2 * H * hd * 0.5 (scores + AV, causal
    halves the work); bwd adds 2x => train attention = 6 * B * S^2 * H * hd
  * decode step: 2 * N_active * B on params + attention reads over the cache
MoE archs use activated params only (router-selected top-k + shared).
"""

from __future__ import annotations

from repro.configs.base import ArchConfig, SHAPES


def _attn_dims(cfg: ArchConfig):
    if cfg.use_mla:
        # MLA: qk dim = nope+rope per head, v dim = v_head_dim
        return cfg.n_heads, cfg.qk_nope_dim + cfg.qk_rope_dim, cfg.v_head_dim
    hd = cfg.resolved_head_dim
    return cfg.n_heads, hd, hd


def attention_flops(cfg: ArchConfig, b: int, s: int, train: bool) -> float:
    if cfg.family == "ssm":                      # xLSTM: chunk-local matmuls
        from repro.models import xlstm
        q = cfg.ssm_chunk or 64
        h = cfg.n_heads
        dqk = xlstm.m_qk(cfg) // h
        dv = xlstm.m_inner(cfg) // h
        per_layer = 2.0 * b * s * h * (q * (dqk + dv)      # intra-chunk
                                       + dqk * dv * 2)     # state update/read
        total = per_layer * xlstm.n_mlstm(cfg)
        return total * 3 if train else total
    h, dqk, dv = _attn_dims(cfg)
    n_attn_layers = cfg.n_layers
    if cfg.family == "hybrid":
        from repro.models import zamba
        n_attn_layers = len(zamba.attn_sites(cfg))
        # + mamba SSD state ops
        from repro.models import ssm as ssm_mod
        hm = ssm_mod.n_heads_ssm(cfg)
        ssd = 6.0 * b * s * hm * cfg.ssm_head_dim * cfg.ssm_state * \
            cfg.n_layers
        extra = ssd * (3 if train else 1)
    else:
        extra = 0.0
    fwd = 2.0 * b * s * s * h * (dqk + dv) * 0.5 * n_attn_layers
    return (fwd * 3 if train else fwd) + extra


def decode_attention_flops(cfg: ArchConfig, b: int, t: int) -> float:
    """One decode token attending over a t-deep cache."""
    if cfg.family == "ssm":
        from repro.models import xlstm
        h = cfg.n_heads
        dqk = xlstm.m_qk(cfg) // h
        dv = xlstm.m_inner(cfg) // h
        return 6.0 * b * h * dqk * dv * xlstm.n_mlstm(cfg)
    h, dqk, dv = _attn_dims(cfg)
    n_attn = cfg.n_layers
    extra = 0.0
    if cfg.family == "hybrid":
        from repro.models import zamba, ssm as ssm_mod
        n_attn = len(zamba.attn_sites(cfg))
        hm = ssm_mod.n_heads_ssm(cfg)
        extra = 6.0 * b * hm * cfg.ssm_head_dim * cfg.ssm_state * cfg.n_layers
    if cfg.use_mla:
        # absorbed decode: scores over (kvr + rope), AV over kvr, plus
        # per-head latent projections
        kvr = cfg.kv_lora_rank
        per = (2.0 * b * t * h * (kvr + cfg.qk_rope_dim)   # scores
               + 2.0 * b * t * h * kvr                      # AV
               + 2.0 * b * h * cfg.qk_nope_dim * kvr * 2)   # absorb projs
        return per * cfg.n_layers + extra
    return 2.0 * b * t * cfg.n_kv_heads * (dqk + dv) * n_attn * \
        (cfg.n_heads // cfg.n_kv_heads) + extra


def model_flops(cfg: ArchConfig, shape_name: str) -> float:
    info = SHAPES[shape_name]
    s, b, kind = info["seq_len"], info["global_batch"], info["kind"]
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = b * s
        return 6.0 * n_active * tokens + attention_flops(cfg, b, s, True)
    if kind == "prefill":
        tokens = b * s
        return 2.0 * n_active * tokens + attention_flops(cfg, b, s, False)
    # decode: 1 token/batch-row against a seq_len cache
    return 2.0 * n_active * b + decode_attention_flops(cfg, b, s)
