"""Codec-kernel roofline: achieved FLOP/s and bytes/s vs documented
peaks — thin entrypoint over ``repro.bench``.

The measurements are :func:`repro.bench.cases.roofline_points` (shared
with the ``roofline`` registry case that feeds RESULTS.md): every
routed codec kernel is timed through its public ``ops.py`` router
(tuned tiles apply when ``results/tuning.json`` is valid for this
backend) and placed on the roofline defined by the documented per-chip
peak terms (:data:`repro.launch.mesh.HW` — TPU v5e: 197 TFLOP/s bf16,
819 GB/s HBM).  FLOP and byte counts come from XLA's lowered cost
analysis of each kernel's jnp reference at the same shape; the two
bit-stream kernels (``pack_bits``/``unpack_bits``) use analytic byte
counts since their FLOP content is ~0.

Off-TPU the peak fractions are a pipeline proof, not an efficiency
claim — interpret-mode Pallas timings against TPU peak terms.  The
``--check-terms`` gate is therefore timing-free: it only asserts the
cost model is sane (positive byte traffic everywhere, positive FLOPs
for the arithmetic kernels, finite intensities).

    PYTHONPATH=src python benchmarks/roofline.py
    PYTHONPATH=src python benchmarks/roofline.py --size 64 \
        --entropy-size 48 --iters 2 --check-terms
"""

from __future__ import annotations

import argparse
import sys

import jax

from benchmarks.common import rows_from_records
from repro.bench.cases import roofline_points


def check_cost_terms(records) -> list:
    """Timing-free sanity gate on the roofline cost model."""
    bad = []
    for r in records:
        m = r.metrics
        kernel = r.params["kernel"]
        if m["bytes_accessed"] <= 0:
            bad.append(f"{kernel}: no byte traffic in cost model")
        if kernel in ("dct8x8", "cordic_loeffler", "fused_codec") \
                and m["flops"] <= 0:
            bad.append(f"{kernel}: no FLOPs in cost model")
        if not (m["intensity_flop_per_byte"] >= 0):
            bad.append(f"{kernel}: non-finite arithmetic intensity")
        if m["achieved_gb_s"] <= 0:
            bad.append(f"{kernel}: non-positive achieved bandwidth")
    return bad


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=256,
                    help="square image side for the image kernels")
    ap.add_argument("--entropy-size", type=int, default=128,
                    help="image side whose entropy payload drives the "
                         "bit-stream kernels")
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--check-terms", action="store_true",
                    help="exit 1 unless the cost model is sane "
                         "(positive bytes everywhere, positive FLOPs "
                         "for arithmetic kernels); never gates timings")
    args = ap.parse_args()

    print(f"# backend={jax.default_backend()} size={args.size} "
          f"entropy_size={args.entropy_size}")
    records = roofline_points(args.size, args.entropy_size,
                              warmup=args.warmup, iters=args.iters)
    rows_from_records(
        "roofline", records, legs=("routed",),
        metrics_fmt=lambda r: (
            f"gflop_s={r.metrics['achieved_gflop_s']:.3f};"
            f"gb_s={r.metrics['achieved_gb_s']:.3f};"
            f"frac_peak_flops={r.metrics['frac_peak_flops']:.2e};"
            f"frac_peak_bw={r.metrics['frac_peak_bw']:.2e};"
            f"intensity={r.metrics['intensity_flop_per_byte']:.3f}"))

    if args.check_terms:
        bad = check_cost_terms(records)
        if bad:
            print("COST-MODEL VIOLATIONS:", file=sys.stderr)
            for b in bad:
                print(f"  {b}", file=sys.stderr)
            return 1
        print("# cost-model check passed "
              f"({len(records)} kernels)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
