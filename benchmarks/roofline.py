import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# Roofline analysis (EXPERIMENTS.md §Roofline).
#
# cost_analysis counts a lax.scan body ONCE, so full-step numbers undercount
# scanned layers and microbatch loops.  This tool therefore lowers each
# cell COMPOSITIONALLY on the production mesh:
#     total = extras(embed+head+loss [+opt]) + n_layer_iters x block_terms
# where block_terms come from lowering one block (fwd, or fwd+bwd for train)
# standalone at the cell's per-microbatch shapes, under the same sharding
# rules as the dry-run.  Collective bytes are parsed from the partitioned
# HLO of each piece (per-device bytes).
#
# Terms (TPU v5e):  compute = flops / 197 TF/s; memory = bytes / 819 GB/s;
# collective = coll_bytes / 50 GB/s.  All per-chip.

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from benchmarks.flops import model_flops                     # noqa: E402
from repro.configs import registry as arch_registry          # noqa: E402
from repro.configs.base import SHAPES, input_specs, shape_supported  # noqa: E402
from repro.dist import sharding as sh                        # noqa: E402
from repro.launch import specs as specs_lib                  # noqa: E402
from repro.launch.dryrun import TRAIN_MICROBATCHES, parse_collectives  # noqa: E402
from repro.launch.mesh import HW, make_production_mesh       # noqa: E402
from repro.models import registry as model_registry          # noqa: E402
from repro.models.params import ParamSpec, abstract_params, subtree  # noqa: E402


def _terms_of(fn, args, in_shardings=None) -> dict:
    jitted = jax.jit(fn) if in_shardings is None else jax.jit(
        fn, in_shardings=in_shardings)
    lowered = jitted.lower(*args)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    colls = parse_collectives(compiled.as_text())
    return {"flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes accessed", 0.0),
            "coll": float(colls.get("total", 0))}


def _abstract_subtree(cfg, prefix: str) -> dict:
    specs = model_registry.param_specs(cfg)
    sub = {p[len(prefix) + 1:]: s for p, s in specs.items()
           if p.startswith(prefix + "/")}
    return sub


def _layer_param_structs(cfg, prefix: str, mesh) -> tuple:
    """(abstract one-layer params, shardings) from stacked specs."""
    sub = _abstract_subtree(cfg, prefix)
    structs, shards = {}, {}
    for p, s in sub.items():
        shape = s.shape[1:] if s.axes and s.axes[0] == "layers" else s.shape
        axes = s.axes[1:] if s.axes and s.axes[0] == "layers" else s.axes
        structs[p] = jax.ShapeDtypeStruct(shape, jnp.bfloat16)
        shards[p] = sh.input_sharding(shape, axes, mesh)
    return structs, shards


def _block_terms(cfg, shape_name: str, mesh) -> tuple:
    """(per-iteration block terms, n_iterations) for the dominant stack."""
    info = SHAPES[shape_name]
    kind = info["kind"]
    s, b = info["seq_len"], info["global_batch"]
    micro = TRAIN_MICROBATCHES.get(cfg.name, 1) if kind == "train" else 1
    b_eff = b // micro
    sl = s if kind != "decode" else 1

    from repro.models import layers as L

    if cfg.family == "ssm":
        from repro.models import xlstm
        structs, shards = _layer_param_structs(cfg, "mblocks", mesh)
        x = jax.ShapeDtypeStruct((b_eff, sl, cfg.d_model), jnp.bfloat16)
        xsh = sh.input_sharding(x.shape, ("batch", "seq", "embed"), mesh)

        if kind == "decode":
            def fn(p, x):
                out, _ = xlstm.mlstm_block(cfg, p, x, state=None,
                                           decode=False)
                return out
            # decode state update ~ chunked at S=1; lower parallel form
            t = _terms_of(fn, (structs, x), (shards, xsh))
            return t, xlstm.n_mlstm(cfg)

        if kind == "train":
            def fn(p, x):
                def loss(p, x):
                    out, _ = xlstm.mlstm_block(cfg, p, x)
                    return jnp.sum(out.astype(jnp.float32))
                return jax.grad(loss, argnums=(0, 1))(p, x)
        else:
            def fn(p, x):
                out, _ = xlstm.mlstm_block(cfg, p, x)
                return out
        t = _terms_of(fn, (structs, x), (shards, xsh))
        return t, xlstm.n_mlstm(cfg) * micro

    if cfg.family == "hybrid":
        from repro.models import ssm as ssm_mod
        structs, shards = _layer_param_structs(cfg, "mamba", mesh)
        x = jax.ShapeDtypeStruct((b_eff, sl, cfg.d_model), jnp.bfloat16)
        xsh = sh.input_sharding(x.shape, ("batch", "seq", "embed"), mesh)
        if kind == "decode":
            st_struct = ssm_mod.mamba_state_struct(cfg, b_eff)
            st = {k: jax.ShapeDtypeStruct(v[0], v[1])
                  for k, v in st_struct.items()}
            stsh = {"conv": sh.input_sharding(st["conv"].shape,
                                              ("batch", None, "mlp"), mesh),
                    "ssm": sh.input_sharding(st["ssm"].shape,
                                             ("batch", "heads", None, None),
                                             mesh)}

            def fn(p, x, st):
                out, _ = ssm_mod.mamba_block(cfg, p, x, st)
                return out
            t = _terms_of(fn, (structs, x, st), (shards, xsh, stsh))
            return t, cfg.n_layers
        if kind == "train":
            def fn(p, x):
                def loss(p, x):
                    out, _ = ssm_mod.mamba_block(cfg, p, x)
                    return jnp.sum(out.astype(jnp.float32))
                return jax.grad(loss, argnums=(0, 1))(p, x)
        else:
            def fn(p, x):
                out, _ = ssm_mod.mamba_block(cfg, p, x)
                return out
        t = _terms_of(fn, (structs, x), (shards, xsh))
        return t, cfg.n_layers * micro

    # transformer family (dense / moe / mla / encoder / vlm)
    if cfg.use_mla:
        from repro.models import deepseek
        structs, shards = _layer_param_structs(cfg, "blocks", mesh)
        block = deepseek._moe_block
        angles_dim = cfg.qk_rope_dim
    else:
        from repro.models import transformer
        structs, shards = _layer_param_structs(cfg, "blocks", mesh)
        block = transformer._run_block
        angles_dim = cfg.resolved_head_dim

    x = jax.ShapeDtypeStruct((b_eff, sl, cfg.d_model), jnp.bfloat16)
    xsh = sh.input_sharding(x.shape, ("batch", "seq", "embed"), mesh)
    cs = jax.ShapeDtypeStruct((b_eff, sl, angles_dim // 2), jnp.float32)
    cssh = sh.input_sharding(cs.shape, ("batch", "seq", None), mesh)

    if kind == "decode":
        cax = specs_lib.cache_axes(cfg)
        full_cache = model_registry.abstract_cache(cfg, b, s)
        lc, lcsh = {}, {}
        for p, v in full_cache.items():
            if p.startswith(("m/", "s/", "mamba/")):
                continue
            shp = v.shape[1:]
            lc[p] = jax.ShapeDtypeStruct(shp, v.dtype)
            lcsh[p] = sh.input_sharding(shp, cax[p][1:], mesh)

        def fn(p, x, cos, sin, cache):
            out = block(cfg, p, x, cos, sin, cache,
                        jnp.zeros((), jnp.int32) + s - 1)
            return out[0]
        t = _terms_of(fn, (structs, x, cs, cs, lc),
                      (shards, xsh, cssh, cssh, lcsh))
        return t, cfg.n_layers

    if kind == "train":
        def fn(p, x, cos, sin):
            def loss(p, x):
                out = block(cfg, p, x, cos, sin, None, None)
                return jnp.sum(out[0].astype(jnp.float32))
            return jax.grad(loss, argnums=(0, 1))(p, x)
    else:
        def fn(p, x, cos, sin):
            return block(cfg, p, x, cos, sin, None, None)[0]
    t = _terms_of(fn, (structs, x, cs, cs), (shards, xsh, cssh, cssh))
    return t, cfg.n_layers * micro


def _extras_terms(cfg, shape_name: str, mesh) -> dict:
    """Embedding + head + loss (+ backward for train), per step."""
    info = SHAPES[shape_name]
    kind = info["kind"]
    s, b = info["seq_len"], info["global_batch"]
    micro = TRAIN_MICROBATCHES.get(cfg.name, 1) if kind == "train" else 1
    b_eff = b // micro
    sl = s if kind != "decode" else 1
    v, d = cfg.vocab_size, cfg.d_model

    emb = jax.ShapeDtypeStruct((v, d), jnp.bfloat16)
    embsh = sh.input_sharding((v, d), ("vocab", "embed"), mesh)
    tok = jax.ShapeDtypeStruct((b_eff, sl), jnp.int32)
    toksh = sh.input_sharding(tok.shape, ("batch", "seq"), mesh)

    if kind == "train":
        def fn(emb, tokens, labels):
            def loss(emb):
                x = emb[tokens]
                logits = (x @ emb.T).astype(jnp.float32)
                logz = jax.nn.logsumexp(logits, axis=-1)
                gold = jnp.take_along_axis(logits, labels[..., None],
                                           axis=-1)[..., 0]
                return jnp.mean(logz - gold)
            return jax.grad(loss)(emb)
        t = _terms_of(fn, (emb, tok, tok), (embsh, toksh, toksh))
        return {k: val * micro for k, val in t.items()}

    def fn(emb, tokens):
        x = emb[tokens]
        return x @ emb.T
    return _terms_of(fn, (emb, tok), (embsh, toksh))


def roofline_cell(arch: str, shape_name: str) -> dict:
    cfg = arch_registry.get(arch)
    ok, reason = shape_supported(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": reason}
    mesh = make_production_mesh(multi_pod=False)
    chips = 256
    rules = specs_lib.rules_for(cfg, shape_name)
    with sh.use_mesh_and_rules(mesh, rules):
        block_t, iters = _block_terms(cfg, shape_name, mesh)
        extras_t = _extras_terms(cfg, shape_name, mesh)

    total = {k: extras_t[k] + block_t[k] * iters for k in block_t}
    mf = model_flops(cfg, shape_name)
    compute_s = total["flops"] / HW["peak_flops_bf16"]
    memory_s = total["bytes"] / HW["hbm_bw"]
    coll_s = total["coll"] / HW["ici_bw"]
    bound = max((compute_s, "compute"), (memory_s, "memory"),
                (coll_s, "collective"))[1]
    ideal_s = mf / (chips * HW["peak_flops_bf16"])
    frac = ideal_s / max(compute_s, memory_s, coll_s, 1e-30)
    return {
        "arch": arch, "shape": shape_name, "status": "ok",
        "block": block_t, "iters": iters, "extras": extras_t,
        "per_device": total,
        "model_flops": mf,
        "hlo_flops_global": total["flops"] * chips,
        "useful_ratio": mf / max(total["flops"] * chips, 1e-30),
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "bound": bound,
        "roofline_fraction": frac,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="roofline_results.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    archs = [args.arch] if args.arch else arch_registry.ARCH_NAMES
    shapes = [args.shape] if args.shape else list(SHAPES)
    for arch in archs:
        for shape_name in shapes:
            key = f"{arch}|{shape_name}"
            if key in results and not args.force and \
                    results[key].get("status") in ("ok", "skipped"):
                continue
            t0 = time.monotonic()
            try:
                rec = roofline_cell(arch, shape_name)
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": shape_name, "status": "error",
                       "error": str(e)[:1500]}
            rec["wall_s"] = round(time.monotonic() - t0, 1)
            if rec["status"] == "ok":
                print(f"{key}: bound={rec['bound']} "
                      f"c={rec['compute_s']*1e3:.2f}ms "
                      f"m={rec['memory_s']*1e3:.2f}ms "
                      f"x={rec['collective_s']*1e3:.2f}ms "
                      f"frac={rec['roofline_fraction']:.3f} "
                      f"useful={rec['useful_ratio']:.2f}")
            else:
                print(f"{key}: {rec['status']} {rec.get('error', rec.get('reason',''))[:200]}")
            results[key] = rec
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
