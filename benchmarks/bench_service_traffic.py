"""Closed-loop service traffic — thin entrypoint over ``repro.bench``.

The traffic generator itself is
:func:`repro.bench.cases.service_traffic_points` (shared with the
``service_traffic`` registry case that feeds RESULTS.md); this script
keeps the stdout summary interface and the ``--check`` CI gate, which
exits nonzero on any :func:`traffic_conservation_violations` finding
(a request without exactly one terminal outcome, or an occupancy
histogram that fails to account for the served count).

    PYTHONPATH=src python benchmarks/bench_service_traffic.py
    PYTHONPATH=src python benchmarks/bench_service_traffic.py \
        --size 48 --requests 60 --loads 0.5 1.0 2.0 --check
"""

from __future__ import annotations

import argparse
import sys

import jax

from repro.bench.cases import (service_traffic_points,
                               traffic_conservation_violations)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=48,
                    help="base square image side for the mixed-size pool")
    ap.add_argument("--requests", type=int, default=60,
                    help="requests per offered-load level")
    ap.add_argument("--loads", type=float, nargs="+",
                    default=(0.5, 1.0, 2.0),
                    help="offered loads as multiples of calibrated "
                         "engine capacity")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on any outcome-conservation or "
                         "occupancy-accounting violation")
    args = ap.parse_args()

    print(f"# backend={jax.default_backend()} "
          f"devices={jax.local_device_count()} size={args.size} "
          f"requests={args.requests}")
    records = service_traffic_points(args.size, args.requests,
                                     tuple(args.loads),
                                     max_batch=args.max_batch,
                                     seed=args.seed)
    print("load,p50_ms,p99_ms,goodput_rps,reject_rate,served,"
          "deadline_missed,cache_hit_rate,mean_batch_occupancy")
    for r in records:
        m = r.metrics
        print(f"{r.params['offered_load']:g},{m['p50_ms']:.2f},"
              f"{m['p99_ms']:.2f},{m['goodput_rps']:.1f},"
              f"{m['reject_rate']:.3f},{m['served']:.0f},"
              f"{m['deadline_missed']:.0f},{m['cache_hit_rate']:.3f},"
              f"{m['mean_batch_occupancy']:.2f}")

    if args.check:
        violations = traffic_conservation_violations(records)
        if violations:
            for v in violations:
                print(f"VIOLATION: {v}", file=sys.stderr)
            raise SystemExit(1)
        print("# conservation: OK")


if __name__ == "__main__":
    main()
