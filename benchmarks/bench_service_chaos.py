"""Chaos traffic through the resilient service — thin entrypoint.

The storm itself is :func:`repro.bench.cases.service_chaos_points`
(shared with the ``service_chaos`` registry case that feeds
RESULTS.md): open-loop Poisson traffic at a multiple of the calibrated
engine capacity while a seeded, call-indexed fault plan injects engine
exceptions, latency spikes past the attempt timeout, one worker death
and a payload-corruption burst.  ``--check`` is the CI gate: it exits
nonzero on any :func:`chaos_violations` finding — an outcome that is
not conserved, a served payload that differs from serial
``encode_batch``, an unhandled exception escaping the dispatch loop, a
breaker that never completed its closed→open→half-open→closed cycle,
or a scripted fault kind that never fired.

    PYTHONPATH=src python benchmarks/bench_service_chaos.py
    PYTHONPATH=src python benchmarks/bench_service_chaos.py \
        --size 48 --requests 80 --load 1.0 --check
"""

from __future__ import annotations

import argparse
import sys

import jax

from repro.bench.cases import chaos_violations, service_chaos_points


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=48,
                    help="base square image side for the mixed-size pool")
    ap.add_argument("--requests", type=int, default=80,
                    help="Poisson arrivals driven through the storm")
    ap.add_argument("--load", type=float, default=1.0,
                    help="offered load as a multiple of calibrated "
                         "engine capacity")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds the fault plan, backoff jitter and "
                         "arrival process")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on any conservation / byte-identity / "
                         "breaker-cycle / unhandled-exception violation")
    args = ap.parse_args()

    print(f"# backend={jax.default_backend()} "
          f"devices={jax.local_device_count()} size={args.size} "
          f"requests={args.requests} load={args.load:g} "
          f"seed={args.seed}")
    records = service_chaos_points(args.size, args.requests, args.load,
                                   max_batch=args.max_batch,
                                   seed=args.seed)
    print("load,p50_ms,p99_ms,goodput_rps,served,reject_rate,failed,"
          "retry_rate,timeouts,corrupt_caught,degraded_served,"
          "byte_mismatches")
    for r in records:
        m = r.metrics
        print(f"{r.params['offered_load']:g},{m['p50_ms']:.2f},"
              f"{m['p99_ms']:.2f},{m['goodput_rps']:.1f},"
              f"{m['served']:.0f},{m['reject_rate']:.3f},"
              f"{m['failed']:.0f},{m['retry_rate']:.3f},"
              f"{m['timeouts']:.0f},{m['corrupt_caught']:.0f},"
              f"{m['degraded_served']:.0f},{m['byte_mismatches']:.0f}")
        cyc = " -> ".join(f"{frm}->{to}@{t:.2f}s" for t, frm, to
                          in r.params["breaker_transitions"])
        print(f"# breaker: {cyc or 'no transitions'}")
        print(f"# faults injected: {r.params['fault_events']} over "
              f"{r.params['engine_calls']} engine calls")

    if args.check:
        violations = chaos_violations(records)
        if violations:
            for v in violations:
                print(f"VIOLATION: {v}", file=sys.stderr)
            raise SystemExit(1)
        print("# chaos gate: OK")


if __name__ == "__main__":
    main()
