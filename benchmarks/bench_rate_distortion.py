"""Rate–distortion sweep over the entropy-coded byte path — thin
entrypoint over ``repro.bench``.

The sweep itself is :func:`repro.bench.cases.rate_distortion_points`
(shared with the ``rate_distortion`` registry case that feeds
RESULTS.md); this script keeps the CSV interface and the
``--check-monotone`` CI gate: higher quality must cost strictly more
*measured* bits-per-pixel and buy strictly more PSNR.

    PYTHONPATH=src python benchmarks/bench_rate_distortion.py
    PYTHONPATH=src python benchmarks/bench_rate_distortion.py --size 200 \
        --qualities 10 50 90 --check-monotone
"""

from __future__ import annotations

import argparse
import sys

import jax

from repro.bench.cases import check_rd_monotone, rate_distortion_points
from repro.core import images


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=256,
                    help="square image side for the sweep")
    ap.add_argument("--image", default="lena",
                    choices=["lena", "cablecar"])
    ap.add_argument("--qualities", type=int, nargs="+",
                    default=[10, 30, 50, 70, 90])
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--check-monotone", action="store_true",
                    help="exit 1 unless bits-per-pixel and PSNR both "
                         "strictly increase with quality")
    args = ap.parse_args()

    image_fn = (images.lena_like if args.image == "lena"
                else images.cablecar_like)
    print(f"# backend={jax.default_backend()} "
          f"devices={jax.local_device_count()} "
          f"image={args.image} size={args.size}")
    print("quality,nbytes,bits_per_px,psnr_db,encode_ms,decode_ms")

    records = rate_distortion_points(
        image_fn, args.image, args.size, args.size,
        sorted(args.qualities), warmup=1, iters=args.iters)
    points = []
    for r in records:
        q = r.params["quality"]
        points.append((q, r.metrics["bpp"], r.metrics["psnr_db"]))
        print(f"{q},{r.params['nbytes']},{r.metrics['bpp']:.4f},"
              f"{r.metrics['psnr_db']:.3f},"
              f"{r.timings_us['encode']['median_us'] / 1e3:.3f},"
              f"{r.timings_us['decode']['median_us'] / 1e3:.3f}")

    if args.check_monotone:
        bad = check_rd_monotone(points)
        if bad:
            print(f"MONOTONICITY VIOLATIONS: {bad}", file=sys.stderr)
            return 1
        lo, hi = min(p[0] for p in points), max(p[0] for p in points)
        print(f"monotone OK: bpp and PSNR strictly increase from "
              f"quality {lo} to {hi}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
