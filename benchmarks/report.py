"""Render RESULTS.md from benchmark artifacts — alias for
``python -m repro.bench report``.

    PYTHONPATH=src python -m benchmarks.report results/*.json

Historical note: this script once rendered dry-run/roofline tables from
``dryrun_results.json`` / ``roofline_results.json`` that no current tool
emits; those dead paths are gone.  ``benchmarks/roofline.py`` still
prints its own per-cell summary and writes its own JSON.
"""

from __future__ import annotations

import sys

from repro.bench.cli import main

if __name__ == "__main__":
    raise SystemExit(main(["report", *sys.argv[1:]]))
