"""Render EXPERIMENTS.md tables from dryrun/roofline JSON artifacts.

    PYTHONPATH=src python -m benchmarks.report \
        --dryrun dryrun_results.json dryrun_results_multi.json \
        --roofline roofline_results.json
"""

from __future__ import annotations

import argparse
import json


def _gb(x):
    return f"{x/1e9:.2f}"


def dryrun_table(paths):
    rows = []
    for path in paths:
        try:
            with open(path) as f:
                results = json.load(f)
        except FileNotFoundError:
            continue
        for key in sorted(results):
            r = results[key]
            if r["status"] == "skipped":
                rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                            f"SKIP | {r['reason']} |||||")
                continue
            if r["status"] == "error":
                rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                            f"ERROR | {r.get('error','')[:60]} |||||")
                continue
            m = r["memory"]
            c = r["collectives"]
            coll_desc = " ".join(
                f"{k.split('-')[0]}-{k.split('-')[1][:1] if '-' in k else k}"
                f"={_gb(v)}" for k, v in sorted(c.items()) if k != "total")
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
                f"({r['compile_s']:.0f}s) | µB={r.get('microbatches',1)} "
                f"| {_gb(m['argument_bytes'])} | {_gb(m['temp_bytes'])} "
                f"| {_gb(c['total'])} | {coll_desc} |")
    hdr = ("| arch | shape | mesh | compile | µbatch | args GB/dev "
           "| temp GB/dev | coll GB/dev | collective mix (GB) |\n"
           "|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def roofline_table(path):
    try:
        with open(path) as f:
            results = json.load(f)
    except FileNotFoundError:
        return "(roofline_results.json missing)"
    hdr = ("| arch | shape | compute s | memory s | collective s | bound "
           "| MODEL_FLOPS | useful ratio | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|")
    rows = []
    for key in sorted(results):
        r = results[key]
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | "
                        f"{r['status']}: {r.get('reason', r.get('error',''))[:60]} |||||||")
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f}m "
            f"| {r['memory_s']*1e3:.2f}m | {r['collective_s']*1e3:.2f}m "
            f"| **{r['bound']}** | {r['model_flops']:.2e} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    return hdr + "\n" + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", nargs="*", default=["dryrun_results.json",
                    "dryrun_results_multi.json"])
    ap.add_argument("--roofline", default="roofline_results.json")
    args = ap.parse_args()
    print("## Dry-run table\n")
    print(dryrun_table(args.dryrun))
    print("\n## Roofline table (single-pod, per-chip; 'm' = milliseconds)\n")
    print(roofline_table(args.roofline))


if __name__ == "__main__":
    main()
