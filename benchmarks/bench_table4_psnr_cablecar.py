"""Paper Table 4: PSNR of exact DCT vs Cordic-Loeffler DCT on Cable-car."""

from __future__ import annotations

from benchmarks.common import row
from repro.core import codec, images

SIZES = [(320, 288), (384, 352), (448, 416), (512, 480), (544, 512)]


def run(full: bool = False):
    sizes = SIZES if full else SIZES[:2]
    for (h, w) in sizes:
        img = images.cablecar_like(h, w)
        _, p_dct = codec.roundtrip(img, 50, "exact")
        _, p_cor = codec.roundtrip(img, 50, "cordic")
        row(f"table4_psnr_cablecar_{h}x{w}", 0.0,
            f"dct_db={p_dct:.3f};cordic_db={p_cor:.3f};"
            f"gap_db={p_dct - p_cor:.3f}")


if __name__ == "__main__":
    run(full=True)
