"""Paper Table 4 (Cable-car PSNR) — thin entrypoint over ``repro.bench``.

The case lives in :mod:`repro.bench.cases` (``table4_psnr_cablecar``).
Prefer::

    PYTHONPATH=src python -m repro.bench run --suite paper \
        --cases table4_psnr_cablecar
"""

from __future__ import annotations

from benchmarks.bench_table3_psnr_lena import _fmt
from benchmarks.common import rows_from_records
from repro.bench import RunContext, get


def run(full: bool = False):
    ctx = RunContext(suite="full" if full else "paper")
    records = get("table4_psnr_cablecar").run(ctx)
    rows_from_records("table4_psnr", records, metrics_fmt=_fmt)


if __name__ == "__main__":
    run(full=True)
