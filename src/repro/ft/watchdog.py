"""Fault-tolerance: step watchdog (straggler detection) and retry policy.

On a real pod, straggler mitigation means: detect a slow/hung step,
attribute it to a host, and either (a) wait with a deadline then restart
from the last checkpoint excluding the bad host (elastic rescale) or
(b) pre-emptively re-dispatch work.  On this single-process container the
detection/bookkeeping layer is fully real (threads + wall-clock); the
"replace the node" action is delegated to the launcher (launch/train.py
--max-restarts), and elastic rescale is ft/elastic.py.
"""

from __future__ import annotations

import dataclasses
import statistics
import threading
import time


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float
    ratio: float


class StepWatchdog:
    """Tracks step durations; flags steps slower than ratio x running median.

    Also runs a background heartbeat that fires ``on_hang`` if no step
    completes within ``hang_timeout`` seconds — the "node went away" signal
    that triggers checkpoint-restart in the trainer loop.
    """

    def __init__(self, ratio: float = 3.0, window: int = 32,
                 hang_timeout: float | None = None, on_hang=None):
        self.ratio = ratio
        self.window = window
        self.durations: list = []
        self.events: list = []
        self.hang_timeout = hang_timeout
        self.on_hang = on_hang
        self._last_beat = time.monotonic()
        self._stop = threading.Event()
        self._thread = None
        if hang_timeout is not None:
            self._thread = threading.Thread(target=self._monitor, daemon=True)
            self._thread.start()

    def _monitor(self):
        while not self._stop.wait(min(self.hang_timeout / 4, 1.0)):
            if time.monotonic() - self._last_beat > self.hang_timeout:
                self._last_beat = time.monotonic()
                if self.on_hang:
                    self.on_hang()

    def observe(self, step: int, duration: float):
        self._last_beat = time.monotonic()
        med = (statistics.median(self.durations[-self.window:])
               if self.durations else duration)
        self.durations.append(duration)
        if len(self.durations) >= 4 and duration > self.ratio * med:
            ev = StragglerEvent(step, duration, med, duration / med)
            self.events.append(ev)
            return ev
        return None

    def close(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)
