"""Elastic rescaling: resume any checkpoint on any mesh.

The checkpoint format is topology-free (whole logical arrays + a manifest);
rescaling is therefore "load with the new mesh's shardings":

    new_shardings = sharding.param_shardings(specs, new_mesh)   (flat paths)
    state = elastic.load_for_mesh(ckpt_dir, step, tree_shardings)

Scale-up, scale-down and axis-reshape (e.g. 16x16 -> 2x16x16) all reduce to
the same device_put; tests assert bitwise equality of the resharded tree
and exact training continuation across a simulated rescale.
"""

from __future__ import annotations

import jax

from repro.ckpt import checkpoint


def tree_shardings_for_state(param_shardings: dict) -> dict:
    """Expand param {path: sharding} to the full TrainState tree layout
    (params/opt.m/opt.v share shardings; counters replicate)."""
    out = {}
    for path, sh in param_shardings.items():
        out[f"params|{path}"] = sh
        out[f"opt|m|{path}"] = sh
        out[f"opt|v|{path}"] = sh
        out[f"ef|{path}"] = sh
    return out


def load_for_mesh(ckpt_dir: str, step: int, tree_shardings: dict):
    """Load a checkpoint resharded for a (possibly different) mesh."""
    return checkpoint.load(ckpt_dir, step, shardings=tree_shardings)
