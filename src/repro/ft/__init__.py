"""ft substrate."""
