"""data substrate."""
