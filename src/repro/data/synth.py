"""Deterministic synthetic data pipeline.

Every batch is a pure function of (seed, step): a seeded Markov chain over
the vocabulary with Zipf-ish marginals, so models have real structure to
learn (loss decreases), and resume/skip is exact — restoring a checkpoint
at step k and asking for batch k yields bit-identical data with no state to
persist.  This is the property that makes checkpoint-restart and elastic
rescaling deterministic end-to-end (tests assert it).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 4         # plausible successors per token


@functools.lru_cache(maxsize=8)
def _transition_table(vocab: int, branching: int, seed: int) -> np.ndarray:
    """(vocab, branching) plausible-successor table, Zipf-flavoured."""
    rng = np.random.default_rng(seed)
    # Zipf-ish stationary preference: low token ids more common
    ranks = np.arange(vocab) + 2.0
    pref = 1.0 / ranks
    pref /= pref.sum()
    return rng.choice(vocab, size=(vocab, branching), p=pref).astype(np.int32)


def make_batch_fn(cfg: DataConfig):
    """Returns batch_at(step) -> {"tokens", "labels"} (jit-friendly)."""
    table = jnp.asarray(_transition_table(cfg.vocab_size, cfg.branching,
                                          cfg.seed))

    def batch_at(step):
        key = jax.random.fold_in(jax.random.key(cfg.seed), step)
        k0, k1, k2 = jax.random.split(key, 3)
        start = jax.random.randint(k0, (cfg.global_batch,), 0,
                                   cfg.vocab_size)
        branch_keys = jax.random.randint(
            k1, (cfg.global_batch, cfg.seq_len), 0, cfg.branching)
        noise = jax.random.bernoulli(k2, 0.05,
                                     (cfg.global_batch, cfg.seq_len))
        noise_tok = jax.random.randint(k2, (cfg.global_batch, cfg.seq_len),
                                       0, cfg.vocab_size)

        def step_fn(tok, xs):
            br, nz, nt = xs
            nxt = table[tok, br]
            nxt = jnp.where(nz, nt, nxt)
            return nxt, nxt

        _, seq = jax.lax.scan(
            step_fn, start,
            (branch_keys.T, noise.T, noise_tok.T))
        tokens = jnp.concatenate([start[:, None], seq.T[:, :-1]], axis=1)
        return {"tokens": tokens, "labels": tokens}

    return batch_at


def make_encoder_batch_fn(cfg: DataConfig, d_model: int):
    """HuBERT-style: frame embeddings + cluster labels + mask."""
    base = make_batch_fn(cfg)
    proj = None

    def batch_at(step):
        b = base(step)
        key = jax.random.fold_in(jax.random.key(cfg.seed + 1), step)
        k1, k2 = jax.random.split(key)
        # frame embeddings correlated with the labels (learnable mapping)
        emb_table = jax.random.normal(
            jax.random.key(cfg.seed + 2), (cfg.vocab_size, d_model)) * 0.5
        embeds = emb_table[b["tokens"]]
        embeds = embeds + 0.3 * jax.random.normal(k1, embeds.shape)
        mask = jax.random.bernoulli(k2, 0.3,
                                    (cfg.global_batch, cfg.seq_len))
        # masked positions get a zeroed embedding (the model must infer)
        embeds = jnp.where(mask[..., None], 0.0, embeds)
        return {"embeds": embeds, "labels": b["tokens"], "mask": mask}

    return batch_at
