"""Suite runner: resolve registry cases, execute, write JSON artifacts.

The runner is the only writer of benchmark artifacts; the renderer
(:mod:`repro.bench.report`) is the only reader.  Everything between them
travels through :mod:`repro.bench.schema`.
"""

from __future__ import annotations

import pathlib
import time

from repro.bench import registry, schema
from repro.bench.timer import TimerConfig

# Default warmup/iteration counts per suite: smoke exists to prove the
# pipeline end-to-end quickly; paper/full trade wall time for stability.
SUITE_TIMERS = {
    "smoke": TimerConfig(warmup=1, iters=2),
    "paper": TimerConfig(warmup=1, iters=3),
    "full": TimerConfig(warmup=1, iters=3),
    "micro": TimerConfig(warmup=2, iters=5),
}


def run_suite(suite: str, out_dir: str = "results", cases=None,
              timer: TimerConfig | None = None, log=print) -> list:
    """Run every case a suite selects and write one artifact per case.

    Args:
        suite: one of :data:`repro.bench.registry.SUITES`; picks both the
            case set and each case's size grid.
        out_dir: directory receiving ``<case>.json`` artifacts
            (created if missing).
        cases: optional case-name filter (must be members of the suite).
        timer: override the suite's default :class:`TimerConfig`.
        log: progress sink (``print`` by default, silence with
            ``lambda *_: None``).

    Returns:
        List of written artifact paths, in execution order.
    """
    selected = registry.resolve(suite, cases)
    if not selected:
        raise KeyError(f"suite {suite!r} selects no cases")
    ctx = registry.RunContext(
        suite=suite, timer=timer or SUITE_TIMERS.get(suite, TimerConfig()))
    env = schema.capture_environment()
    log(f"# suite={suite} backend={env['backend']} "
        f"devices={env['device_count']} git={env['git_sha']}")

    paths = []
    for case in selected:
        t0 = time.monotonic()
        records = case.run(ctx)
        result = schema.BenchResult(name=case.name, suite=suite,
                                    records=records, environment=env)
        path = schema.save(result, out_dir)
        paths.append(path)
        log(f"{case.name}: {len(records)} records "
            f"({time.monotonic() - t0:.1f}s) -> {path}")
    return paths


def default_artifacts(out_dir: str = "results") -> list:
    """All ``*.json`` bench artifacts under ``out_dir``, sorted by name.

    ``tuning.json`` is excluded: it is the kernel-routing document
    (:mod:`repro.kernels.tuning` schema), not a
    :class:`~repro.bench.schema.BenchResult` the renderer can read —
    the sweep grid behind it lands in ``autotune.json`` instead.
    """
    return sorted(p for p in pathlib.Path(out_dir).glob("*.json")
                  if p.name != "tuning.json")
