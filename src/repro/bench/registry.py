"""Declarative benchmark registry.

A benchmark *case* is a function decorated with :func:`benchmark` that
takes a :class:`RunContext` and returns a list of
:class:`~repro.bench.schema.BenchRecord`.  The decorator declares which
*suites* include the case (``smoke`` / ``paper`` / ``full`` / ``micro``)
and which paper table (if any) its records feed, so the runner and the
renderer never hard-code script names.

    @benchmark("table1_lena", suites=("smoke", "paper", "full"),
               table="Table 1", description="...")
    def table1_lena(ctx: RunContext) -> list[BenchRecord]:
        ...

Suites are size grids, not different code: every case reads
``ctx.suite`` to pick its grid (``smoke`` = smallest point only,
``paper`` = the representative subset, ``full`` = the paper's complete
grid).
"""

from __future__ import annotations

import dataclasses

from repro.bench.timer import TimerConfig

SUITES = ("smoke", "paper", "full", "micro")


@dataclasses.dataclass(frozen=True)
class RunContext:
    """Everything a case needs to size and time itself.

    Attributes:
        suite: grid selector — one of :data:`SUITES`.
        timer: default warmup/iteration counts; cases may scale it down
            for expensive legs via :meth:`TimerConfig.scaled`.
    """
    suite: str = "paper"
    timer: TimerConfig = TimerConfig()


@dataclasses.dataclass(frozen=True)
class BenchCase:
    """A registered benchmark: callable + the declarative envelope."""
    name: str
    fn: object                 # (RunContext) -> list[BenchRecord]
    suites: tuple
    table: str | None          # paper table this feeds, e.g. "Table 1"
    description: str

    def run(self, ctx: RunContext) -> list:
        """Execute the case; returns its records."""
        return self.fn(ctx)


_REGISTRY: dict = {}


def benchmark(name: str, suites=("paper", "full"), table: str | None = None,
              description: str = ""):
    """Class-method-free registration decorator for benchmark cases.

    Args:
        name: unique case name; becomes the artifact filename stem.
        suites: suite names that include this case (subset of
            :data:`SUITES`).
        table: paper table the case reproduces ("Table 1".."Table 4"),
            or None for framework/serving benches.
        description: one-liner shown by ``python -m repro.bench list``.

    Returns:
        The decorator; the wrapped function is returned unchanged.
    """
    unknown = set(suites) - set(SUITES)
    if unknown:
        raise ValueError(f"unknown suites {sorted(unknown)}; "
                         f"pick from {SUITES}")

    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"benchmark {name!r} registered twice")
        _REGISTRY[name] = BenchCase(name=name, fn=fn, suites=tuple(suites),
                                    table=table,
                                    description=description or
                                    (fn.__doc__ or "").strip().split("\n")[0])
        return fn
    return deco


def _ensure_cases_loaded() -> None:
    # cases.py self-registers on import; deferred so registry.py has no
    # jax-touching import cost for pure schema/report users.
    from repro.bench import cases  # noqa: F401


def all_cases() -> dict:
    """name -> BenchCase for every registered benchmark."""
    _ensure_cases_loaded()
    return dict(_REGISTRY)


def get(name: str) -> BenchCase:
    """Look up one case by name; raises KeyError listing valid names."""
    cases = all_cases()
    if name not in cases:
        raise KeyError(f"unknown benchmark {name!r}; "
                       f"registered: {sorted(cases)}")
    return cases[name]


def resolve(suite: str, names=None) -> list:
    """Cases to run: a suite's members, optionally filtered by name.

    Args:
        suite: one of :data:`SUITES`.
        names: optional iterable of case names restricting the selection;
            each must exist and belong to ``suite``.

    Returns:
        BenchCase list in registration order.
    """
    if suite not in SUITES:
        raise KeyError(f"unknown suite {suite!r}; pick from {SUITES}")
    cases = [c for c in all_cases().values() if suite in c.suites]
    if names is not None:
        wanted = list(names)
        by_name = {c.name: c for c in cases}
        missing = [n for n in wanted if n not in by_name]
        if missing:
            raise KeyError(f"cases {missing} not in suite {suite!r}; "
                           f"members: {sorted(by_name)}")
        cases = [by_name[n] for n in wanted]
    return cases
