"""Warmup/steady-state wall-clock timer for jax callables.

All benchmark timing in the repo goes through :func:`measure` so every
number in a :class:`~repro.bench.schema.BenchResult` artifact means the
same thing: *wall time of one blocking call, after the compile and cache
warmup iterations have been discarded*.

Conventions
-----------
* the timed callable is invoked as ``fn(*args)`` and its result is passed
  to ``jax.block_until_ready`` — async dispatch never leaks into a number,
* ``warmup`` calls run (and block) first, absorbing compilation and any
  first-touch allocation,
* ``iters`` timed calls follow; the artifact keeps the median (robust to
  scheduler noise) and the best (the steady-state floor).
"""

from __future__ import annotations

import dataclasses
import time

import jax


@dataclasses.dataclass(frozen=True)
class TimerConfig:
    """How many untimed/timed iterations a measurement runs.

    Attributes:
        warmup: blocking calls discarded before timing starts (absorbs jit
            compilation; >= 1 for anything jitted).
        iters: timed blocking calls kept for the statistics.
    """
    warmup: int = 2
    iters: int = 5

    def scaled(self, warmup: int | None = None,
               iters: int | None = None) -> "TimerConfig":
        """Copy with per-case overrides (None keeps the suite default)."""
        return TimerConfig(self.warmup if warmup is None else warmup,
                           self.iters if iters is None else iters)


@dataclasses.dataclass(frozen=True)
class Timing:
    """One measurement: microseconds per blocking call.

    Attributes:
        median_us: median of the timed iterations — the headline number.
        best_us: fastest timed iteration — the steady-state floor.
        iters: how many timed iterations produced the statistics.
    """
    median_us: float
    best_us: float
    iters: int

    def to_json(self) -> dict:
        return {"median_us": self.median_us, "best_us": self.best_us,
                "iters": self.iters}


def measure(fn, *args, warmup: int = 2, iters: int = 5) -> Timing:
    """Time ``fn(*args)`` with warmup discarded and results blocked on.

    Args:
        fn: callable; its return value (any pytree) is blocked on with
            ``jax.block_until_ready`` so device work is included.
        *args: positional arguments forwarded to ``fn`` every call.
        warmup: untimed leading calls (compile + cache warm).
        iters: timed calls.

    Returns:
        A :class:`Timing` with median/best wall microseconds per call.
    """
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return Timing(median_us=times[len(times) // 2] * 1e6,
                  best_us=times[0] * 1e6, iters=len(times))
