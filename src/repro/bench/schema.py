"""Versioned JSON artifact schema for benchmark results.

One benchmark case run produces one :class:`BenchResult`, saved as one
JSON file (``<out>/<case>.json``).  The renderer
(:mod:`repro.bench.report`) regenerates ``RESULTS.md`` — including the
paper's Tables 1-4 — from these artifacts alone, so a result file must
carry everything a table needs: the measured numbers, the case
parameters that label them, and the environment that produced them.

Schema (version 1)::

    {
      "schema_version": 1,
      "name": "table1_lena",          # registry case name
      "suite": "paper",               # suite the run was invoked with
      "records": [                    # one entry per measured point
        {"label": "lena_512x512",
         "params":     {"height": 512, "width": 512, ...},
         "timings_us": {"parallel": {"median_us":..,"best_us":..,"iters":..},
                        "serial": {...}},
         "metrics":    {"speedup": 12.3, "psnr_db": ...}},
      ],
      "environment": {"backend": "cpu", "device_count": 1,
                      "jax_version": "...", "git_sha": "...",
                      "timestamp_utc": "..."}
    }

Loading rejects artifacts whose ``schema_version`` differs so a renderer
never silently mis-reads an old layout.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import subprocess
import time

SCHEMA_VERSION = 1


@dataclasses.dataclass
class BenchRecord:
    """One measured point of a benchmark case (one table row).

    Attributes:
        label: unique-within-case row id, e.g. ``"lena_512x512"``.
        params: declarative case parameters for this point
            (sizes, transform, quality, batch, ...) — JSON scalars only.
        timings_us: leg name -> timing dict (``median_us``/``best_us``/
            ``iters`` as produced by :meth:`repro.bench.timer.Timing.to_json`).
            Empty for quality-only cases (Tables 3-4).
        metrics: derived numbers (``speedup``, ``psnr_db_exact``,
            ``img_per_s``, ...) keyed by metric name.
    """
    label: str
    params: dict = dataclasses.field(default_factory=dict)
    timings_us: dict = dataclasses.field(default_factory=dict)
    metrics: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "BenchRecord":
        return cls(label=d["label"], params=dict(d.get("params", {})),
                   timings_us=dict(d.get("timings_us", {})),
                   metrics=dict(d.get("metrics", {})))


@dataclasses.dataclass
class BenchResult:
    """Artifact for one case run: records + provenance.

    Attributes:
        name: registry case name (also the artifact filename stem).
        suite: suite name the runner was invoked with (sets the size grid).
        records: measured points, in presentation order.
        environment: backend/device/git provenance
            (see :func:`capture_environment`).
        schema_version: artifact layout version; loaders reject mismatches.
    """
    name: str
    suite: str
    records: list
    environment: dict = dataclasses.field(default_factory=dict)
    schema_version: int = SCHEMA_VERSION

    def to_json(self) -> dict:
        return {"schema_version": self.schema_version, "name": self.name,
                "suite": self.suite,
                "records": [r.to_json() for r in self.records],
                "environment": self.environment}

    @classmethod
    def from_json(cls, d: dict) -> "BenchResult":
        version = d.get("schema_version")
        if version != SCHEMA_VERSION:
            raise ValueError(
                f"artifact schema_version={version!r} but this reader "
                f"understands {SCHEMA_VERSION}; re-run "
                f"`python -m repro.bench run` to regenerate it")
        return cls(name=d["name"], suite=d.get("suite", ""),
                   records=[BenchRecord.from_json(r) for r in d["records"]],
                   environment=dict(d.get("environment", {})),
                   schema_version=version)


def git_sha(repo_root: str | None = None) -> str:
    """Short git sha of the working tree, or ``"unknown"`` outside git."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=repo_root,
            capture_output=True, text=True, timeout=5)
        return out.stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def capture_environment() -> dict:
    """Provenance stamped into every artifact: backend, devices, git sha."""
    import jax
    return {
        "backend": jax.default_backend(),
        "device_count": jax.local_device_count(),
        "jax_version": jax.__version__,
        "git_sha": git_sha(),
        "timestamp_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }


def save(result: BenchResult, out_dir: str | pathlib.Path) -> pathlib.Path:
    """Write ``<out_dir>/<result.name>.json``; returns the path."""
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"{result.name}.json"
    path.write_text(json.dumps(result.to_json(), indent=1) + "\n")
    return path


def load(path: str | pathlib.Path) -> BenchResult:
    """Read one artifact; raises ValueError on schema-version mismatch."""
    with open(path) as f:
        return BenchResult.from_json(json.load(f))


def load_many(paths) -> list:
    """Load artifacts in name order (stable table order in the report)."""
    results = [load(p) for p in paths]
    results.sort(key=lambda r: r.name)
    return results
