"""Benchmark subsystem: registry + runner + artifact pipeline + renderer.

The measurement backbone of the repo (docs/benchmarks.md):

* :mod:`repro.bench.registry` — ``@benchmark`` decorator, suites,
  :func:`~repro.bench.registry.resolve`;
* :mod:`repro.bench.cases` — the paper's Tables 1-4 plus serving-layer
  benches, registered declaratively;
* :mod:`repro.bench.timer` — warmup/steady-state wall-clock timing with
  ``jax.block_until_ready``;
* :mod:`repro.bench.schema` — versioned JSON artifact
  (:class:`~repro.bench.schema.BenchResult`);
* :mod:`repro.bench.runner` — :func:`~repro.bench.runner.run_suite`;
* :mod:`repro.bench.autotune` — pow2 tile sweeps per (kernel, backend,
  shape bucket); winners persist to ``results/tuning.json`` for the
  kernel routers (:mod:`repro.kernels.tuning`);
* :mod:`repro.bench.report` — regenerates ``RESULTS.md`` (Tables 1-4 +
  throughput curves, tile-tuning winners, kernel roofline) from
  artifacts alone;
* :mod:`repro.bench.cli` — ``python -m repro.bench
  run | autotune | report | list``.
"""

from repro.bench.autotune import run_autotune                        # noqa: F401
from repro.bench.registry import (RunContext, all_cases, benchmark,  # noqa: F401
                                  get, resolve)
from repro.bench.runner import run_suite                             # noqa: F401
from repro.bench.schema import (SCHEMA_VERSION, BenchRecord,         # noqa: F401
                                BenchResult, load, load_many, save)
from repro.bench.timer import TimerConfig, Timing, measure           # noqa: F401
