"""``python -m repro.bench`` — run suites, autotune tiles, render RESULTS.md.

    python -m repro.bench run --suite paper --out results/
    python -m repro.bench autotune --suite paper --out results/
    python -m repro.bench report results/*.json --md RESULTS.md
    python -m repro.bench list

``report`` with no artifact arguments picks up ``results/*.json``
(minus ``tuning.json``, the kernel-routing document ``autotune``
writes alongside its ``autotune.json`` sweep artifact).
"""

from __future__ import annotations

import argparse
import sys


def _cmd_run(args) -> int:
    from repro.bench import runner
    from repro.bench.timer import TimerConfig
    timer = None
    if args.warmup is not None or args.iters is not None:
        base = runner.SUITE_TIMERS.get(args.suite, TimerConfig())
        timer = base.scaled(warmup=args.warmup, iters=args.iters)
    runner.run_suite(args.suite, out_dir=args.out, cases=args.cases,
                     timer=timer)
    return 0


def _cmd_autotune(args) -> int:
    from repro.bench import autotune
    from repro.bench.timer import TimerConfig
    suite = "smoke" if args.smoke else args.suite
    timer = None
    if args.warmup is not None or args.iters is not None:
        base = autotune.SUITE_TIMERS.get(suite, TimerConfig(1, 3))
        timer = base.scaled(warmup=args.warmup, iters=args.iters)
    autotune.run_autotune(suite, out_dir=args.out, timer=timer)
    return 0


def _cmd_report(args) -> int:
    from repro.bench import report, runner, schema
    paths = args.artifacts or runner.default_artifacts(args.results_dir)
    if not paths:
        print(f"no artifacts found (looked for {args.results_dir}/*.json); "
              f"run `python -m repro.bench run --suite paper` first",
              file=sys.stderr)
        return 1
    results = schema.load_many(paths)
    if args.stdout:
        print(report.render(results), end="")
    else:
        path = report.write_results(results, args.md)
        print(f"wrote {path} from {len(results)} artifacts")
    return 0


def _cmd_list(args) -> int:
    from repro.bench import registry
    cases = registry.all_cases()
    width = max(len(n) for n in cases)
    for name, case in sorted(cases.items()):
        table = f" [{case.table}]" if case.table else ""
        print(f"{name:<{width}}  suites={','.join(case.suites)}{table}  "
              f"{case.description}")
    return 0


def build_parser() -> argparse.ArgumentParser:
    """The CLI parser (exposed for --help snapshotting in tests)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.bench",
        description="benchmark suites + RESULTS.md renderer")
    sub = ap.add_subparsers(dest="cmd", required=True)

    run = sub.add_parser("run", help="run a suite, write JSON artifacts")
    run.add_argument("--suite", default="paper",
                     choices=("smoke", "paper", "full", "micro"),
                     help="size grid + case set (default: paper)")
    run.add_argument("--out", default="results",
                     help="artifact directory (default: results/)")
    run.add_argument("--cases", nargs="*", default=None,
                     help="restrict to these case names")
    run.add_argument("--warmup", type=int, default=None,
                     help="override warmup iterations")
    run.add_argument("--iters", type=int, default=None,
                     help="override timed iterations")
    run.set_defaults(fn=_cmd_run)

    at = sub.add_parser("autotune",
                        help="sweep kernel tile candidates, write "
                             "results/tuning.json + autotune.json")
    at.add_argument("--suite", default="paper",
                    choices=("smoke", "paper", "full"),
                    help="sweep grid size (default: paper)")
    at.add_argument("--smoke", action="store_true",
                    help="shorthand for --suite smoke (tiny CI sweep)")
    at.add_argument("--out", default="results",
                    help="artifact directory (default: results/)")
    at.add_argument("--warmup", type=int, default=None,
                    help="override warmup iterations")
    at.add_argument("--iters", type=int, default=None,
                    help="override timed iterations")
    at.set_defaults(fn=_cmd_autotune)

    rep = sub.add_parser("report", help="render RESULTS.md from artifacts")
    rep.add_argument("artifacts", nargs="*",
                     help="artifact JSON files (default: results/*.json)")
    rep.add_argument("--results-dir", default="results",
                     help="where to glob artifacts when none are given")
    rep.add_argument("--md", default="RESULTS.md",
                     help="output path (default: RESULTS.md)")
    rep.add_argument("--stdout", action="store_true",
                     help="print the report instead of writing --md")
    rep.set_defaults(fn=_cmd_report)

    ls = sub.add_parser("list", help="list registered benchmark cases")
    ls.set_defaults(fn=_cmd_list)
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
