"""Render ``RESULTS.md`` — the paper's Tables 1-4 and the serving
curves — from benchmark JSON artifacts alone.

The renderer is a pure function of the artifacts: no benchmark re-runs,
no imports of jax.  ``render(results)`` returns the markdown;
:func:`write_results` places it at ``RESULTS.md``.  Section <-> artifact
mapping (see docs/benchmarks.md):

========================  =========================================
artifact (case name)      RESULTS.md section
========================  =========================================
table1_lena               Table 1 — codec time vs Lena size
table2_cablecar           Table 2 — codec time vs Cable-car size
table3_psnr_lena          Table 3 — PSNR exact vs Cordic (Lena)
table4_psnr_cablecar      Table 4 — PSNR exact vs Cordic (Cable-car)
rate_distortion           Rate–distortion (measured bytes)
entropy_throughput        Entropy throughput (vectorized host coding)
entropy_decode            Entropy decode (speculative unpack backends)
serve_batch_throughput    Batch throughput curve (serving engine)
serve_ragged              Ragged mixed-size batches (serving engine)
service_traffic           Open-loop service traffic (async service)
service_chaos             Fault-storm traffic (resilient service)
autotune                  Kernel tile autotuning (sweep winners)
roofline                  Kernel roofline (achieved vs peak)
framework_micro           Framework micro-benches
========================  =========================================
"""

from __future__ import annotations

import pathlib


def _ms(timing: dict) -> str:
    return f"{timing['median_us'] / 1e3:.3f}"


def _size(rec) -> str:
    return f"{rec.params.get('height', '?')}x{rec.params.get('width', '?')}"


def _timing_table(result, title: str, blurb: str) -> str:
    lines = [f"## {title}", "", blurb, "",
             "| image | size | serial (ms) | parallel (ms) | speedup "
             "| MPix/s |",
             "|---|---|---|---|---|---|"]
    for r in result.records:
        lines.append(
            f"| {r.params.get('image', result.name)} | {_size(r)} "
            f"| {_ms(r.timings_us['serial'])} "
            f"| {_ms(r.timings_us['parallel'])} "
            f"| {r.metrics['speedup']:.1f}x "
            f"| {r.metrics['mpix_per_s']:.1f} |")
    return "\n".join(lines)


def _psnr_table(result, title: str, blurb: str) -> str:
    lines = [f"## {title}", "", blurb, "",
             "| image | size | exact DCT (dB) | Cordic-Loeffler (dB) "
             "| gap (dB) |",
             "|---|---|---|---|---|"]
    for r in result.records:
        lines.append(
            f"| {r.params.get('image', result.name)} | {_size(r)} "
            f"| {r.metrics['psnr_db_exact']:.3f} "
            f"| {r.metrics['psnr_db_cordic']:.3f} "
            f"| {r.metrics['gap_db']:.3f} |")
    return "\n".join(lines)


def _rd_table(result) -> str:
    lines = ["## Rate–distortion (measured bytes)", "",
             "Quality sweep through the complete codec — DCT, quantise, "
             "zig-zag, run-length, canonical Huffman, `DCTZ` container "
             "(`repro.core.entropy`).  Bits-per-pixel are *measured* "
             "from the entropy-coded stream, never an estimator; "
             "encode is image→bytes, decode is "
             "bytes→image.", "",
             "| image | size | quality | bits/px | ratio | PSNR (dB) "
             "| encode (ms) | decode (ms) |",
             "|---|---|---|---|---|---|---|---|"]
    for r in result.records:
        lines.append(
            f"| {r.params.get('image', result.name)} | {_size(r)} "
            f"| {r.params['quality']} "
            f"| {r.metrics['bpp']:.3f} "
            f"| {r.metrics['compression_ratio']:.1f}x "
            f"| {r.metrics['psnr_db']:.2f} "
            f"| {_ms(r.timings_us['encode'])} "
            f"| {_ms(r.timings_us['decode'])} |")
    return "\n".join(lines)


def _entropy_table(result) -> str:
    stage = [r for r in result.records if r.label.startswith("entropy_")]
    stages = [r for r in result.records
              if r.label.startswith("encode_stages_")]
    batches = [r for r in result.records if r.label.startswith("batch_")]
    lines = ["## Entropy throughput (vectorized host coding)", "",
             "The host entropy stage (`repro.core.entropy.rle`) measured "
             "against the scalar per-block reference it replaced, plus "
             "the serving engine's overlapped byte path "
             "(`encode_batch`/`decode_batch`: device DCT/quant for "
             "bucket *k+1* in flight while a thread pool entropy-codes "
             "bucket *k*).  `speedup vs ref` scores the pipelined path "
             "against the single-image reference end-to-end encode "
             "rate — growth with batch size is the overlap win.", ""]
    for r in stage:
        lines += [
            f"Single image {_size(r)} (quality {r.params['quality']}, "
            f"{r.params['n_blocks']} blocks, "
            f"{r.params['payload_nbytes']} payload bytes):", "",
            "| direction | vectorized (ms) | reference (ms) | speedup "
            "| MB/s |",
            "|---|---|---|---|---|",
            f"| encode | {_ms(r.timings_us['enc_vectorized'])} "
            f"| {_ms(r.timings_us['enc_reference'])} "
            f"| {r.metrics['enc_speedup']:.1f}x "
            f"| {r.metrics['enc_mb_per_s']:.1f} |",
            f"| decode | {_ms(r.timings_us['dec_vectorized'])} "
            f"| {_ms(r.timings_us['dec_reference'])} "
            f"| {r.metrics['dec_speedup']:.1f}x "
            f"| {r.metrics['dec_mb_per_s']:.1f} |", ""]
    for r in stages:
        lines += [
            f"Per-stage encode breakdown {_size(r)} (staged pipeline; "
            f"`symbolize` is the fused `kernels/symbolize` pass, scored "
            f"against the PR 4 vectorized symbolise+histogram path; "
            f"transfer compares the coefficient bytes the host path "
            f"pulls per image against the histograms+payload the "
            f"device-resident TPU chain ships):", "",
            "| stage | median (ms) |",
            "|---|---|",
            f"| symbolize (fused) | "
            f"{_ms(r.timings_us['stage_symbolize'])} |",
            f"| symbolize (PR 4 vectorized) | "
            f"{_ms(r.timings_us['stage_symbolize_vectorized'])} "
            f"({r.metrics['symbolize_speedup_vs_vectorized']:.2f}x "
            f"fused win) |",
            f"| table choice | {_ms(r.timings_us['stage_table_choice'])} |",
            f"| codeword lookup | {_ms(r.timings_us['stage_codeword'])} |",
            f"| bit packing | {_ms(r.timings_us['stage_pack'])} |", "",
            f"Transfer per image: "
            f"{r.metrics['host_transfer_bytes_per_image']:.0f} B host "
            f"coefficients vs "
            f"{r.metrics['device_transfer_bytes_per_image']:.0f} B "
            f"device (histograms + payload) — "
            f"{r.metrics['transfer_reduction']:.1f}x less traffic.", ""]
    if batches:
        lines += [
            "| batch | enc img/s (pipelined) | enc img/s (serial) "
            "| dec img/s | enc MB/s | speedup vs ref |",
            "|---|---|---|---|---|---|"]
        for r in batches:
            lines.append(
                f"| {r.params['batch']} "
                f"| {r.metrics['enc_img_per_s']:.1f} "
                f"| {r.metrics['enc_img_per_s_serial']:.1f} "
                f"| {r.metrics['dec_img_per_s']:.1f} "
                f"| {r.metrics['enc_mb_per_s']:.1f} "
                f"| {r.metrics['speedup_vs_reference']:.2f}x |")
    return "\n".join(lines).rstrip()


def _entropy_decode_table(result) -> str:
    lines = ["## Entropy decode (speculative unpack backends)", "",
             "Payload-bits → coefficients through the routed unpack "
             "backends (`repro.kernels.unpack_bits`): the staged NumPy "
             "speculative decode (decode from every bit offset, pointer "
             "doubling, per-tile emission) and the Pallas kernel in "
             "interpret mode, against the scalar `decode_payload_"
             "reference` oracle and the vectorized LUT walk "
             "(`rle.decode_payload`).  Interpret-mode Pallas timings are "
             "a correctness vehicle off-TPU, reported but not scored.  "
             "`scratch` is the staged decoder's per-tile working set — "
             "bounded by the tile size — vs the LUT walk's tables, which "
             "grow with payload bits.", "",
             "| size | payload (bits) | reference (ms) | LUT walk (ms) "
             "| staged (ms) | staged vs ref | staged vs walk "
             "| scratch / walk tables |",
             "|---|---|---|---|---|---|---|---|"]
    for r in result.records:
        lines.append(
            f"| {_size(r)} | {r.params['payload_nbits']} "
            f"| {_ms(r.timings_us['dec_reference'])} "
            f"| {_ms(r.timings_us['dec_lut_walk'])} "
            f"| {_ms(r.timings_us['dec_staged'])} "
            f"| {r.metrics['staged_speedup_vs_reference']:.1f}x "
            f"| {r.metrics['staged_speedup_vs_walk']:.2f}x "
            f"| {r.metrics['staged_scratch_nbytes'] / 1024:.0f} KiB / "
            f"{r.metrics['walk_table_nbytes'] / 1024:.0f} KiB |")
    return "\n".join(lines)


def _throughput_table(result) -> str:
    transforms = sorted({k[len("img_per_s_"):]
                         for r in result.records for k in r.metrics
                         if k.startswith("img_per_s_")})
    head = " | ".join(f"{t} (img/s)" for t in transforms)
    lines = ["## Batch throughput (serving engine)", "",
             "Images/sec vs batch size through "
             "`codec_engine.roundtrip_batch` — the paper's GPU-saturation "
             "win, realised here as dispatch-overhead amortisation; one "
             f"image is {result.records[0].params.get('size', 8)}px square.",
             "",
             f"| batch | {head} |",
             "|---|" + "---|" * len(transforms)]
    for r in result.records:
        cells = " | ".join(f"{r.metrics[f'img_per_s_{t}']:.1f}"
                           for t in transforms)
        lines.append(f"| {r.params['batch']} | {cells} |")
    return "\n".join(lines)


def _ragged_table(result) -> str:
    lines = ["## Ragged mixed-size batches (serving engine)", "",
             "A list of mixed-size images in one `roundtrip_batch` call: "
             "shapes bucket up to multiples of "
             f"{result.records[0].params.get('bucket', 64)}px, equal "
             "buckets compile once and run together.", "",
             "| images | distinct buckets | roundtrip (ms) | img/s |",
             "|---|---|---|---|"]
    for r in result.records:
        lines.append(
            f"| {r.params['n_images']} | {r.metrics['n_buckets']:.0f} "
            f"| {_ms(r.timings_us['roundtrip'])} "
            f"| {r.metrics['img_per_s']:.1f} |")
    return "\n".join(lines)


def _service_traffic_table(result) -> str:
    p0 = result.records[0].params
    lines = ["## Open-loop service traffic (async batching service)", "",
             "Open-loop Poisson arrivals through the deadline-aware "
             f"batching service ({p0['n_requests']} requests per level, "
             f"{p0['size']}px image pool, per-request deadline "
             f"{p0['deadline_ms']:.0f} ms, max_batch {p0['max_batch']}). "
             "Offered load is a multiple of the engine's calibrated "
             f"capacity ({p0['capacity_rps']:.0f} req/s); below capacity "
             "the service batches for latency, above it the admission "
             "bound and deadline sweep shed load instead of queueing "
             "without bound (docs/serving.md).", "",
             "| offered load | p50 (ms) | p99 (ms) | goodput (req/s) "
             "| rejected | late | cache hits | mean batch |",
             "|---|---|---|---|---|---|---|---|"]
    for r in result.records:
        m = r.metrics
        lines.append(
            f"| {r.params['offered_load']:g}x "
            f"| {m['p50_ms']:.1f} | {m['p99_ms']:.1f} "
            f"| {m['goodput_rps']:.0f} "
            f"| {m['reject_rate'] * 100:.0f}% "
            f"| {m['deadline_missed']:.0f} "
            f"| {m['cache_hit_rate'] * 100:.0f}% "
            f"| {m['mean_batch_occupancy']:.1f} |")
    return "\n".join(lines)


def _service_chaos_table(result) -> str:
    lines = ["## Fault-storm traffic (resilient service)", ""]
    for r in result.records:
        p, m = r.params, r.metrics
        faults = ", ".join(f"{k} x{v}" for k, v in
                           sorted(p["fault_events"].items()))
        cycle = " → ".join([p["breaker_transitions"][0][1]] +
                           [t[2] for t in p["breaker_transitions"]]) \
            if p["breaker_transitions"] else "none"
        lines += [
            "Open-loop Poisson traffic at "
            f"{p['offered_load']:g}x calibrated capacity "
            f"({p['n_requests']} requests, {p['size']}px pool, "
            f"deadline {p['deadline_ms']:.0f} ms, attempt timeout "
            f"{p['timeout_ms']:.0f} ms) while a seeded call-indexed "
            f"fault plan injects {faults} across {p['engine_calls']} "
            "engine calls.  The resilience envelope (bounded retries, "
            "circuit breaker, CRC payload validation, graceful "
            "degradation) keeps every outcome conserved and every "
            "served payload byte-identical to serial encode "
            "(docs/serving.md); the chaos gate in CI enforces it.", "",
            "| offered load | p50 (ms) | p99 (ms) | goodput (req/s) "
            "| served | rejected | failed | retries | timeouts "
            "| corrupt caught | byte mismatches |",
            "|---|---|---|---|---|---|---|---|---|---|---|",
            f"| {p['offered_load']:g}x | {m['p50_ms']:.1f} "
            f"| {m['p99_ms']:.1f} | {m['goodput_rps']:.0f} "
            f"| {m['served']:.0f} | {m['reject_rate'] * 100:.0f}% "
            f"| {m['failed']:.0f} | {m['retries']:.0f} "
            f"| {m['timeouts']:.0f} | {m['corrupt_caught']:.0f} "
            f"| {m['byte_mismatches']:.0f} |", "",
            f"Breaker cycle: {cycle}.",
        ]
    return "\n".join(lines)


def _tuning_table(result) -> str:
    lines = ["## Kernel tile autotuning", "",
             "Pow2 tile sweep per (kernel, shape bucket) on backend "
             f"`{result.environment.get('backend', '?')}` "
             "(`python -m repro.bench autotune`).  Winners persist to "
             "`results/tuning.json`; each kernel's `ops.py` router loads "
             "them when its tile knob is left at `None` — on a different "
             "backend the artifact is rejected and built-in defaults "
             "apply.  Identity across every candidate is pinned by the "
             "tile-invariance property tests, so tuning can only change "
             "speed, never bits.", "",
             "| kernel | bucket | winner | best (ms) | vs default "
             "| candidates swept |",
             "|---|---|---|---|---|---|"]
    from repro.kernels import tuning
    for r in result.records:
        kernel = r.params["kernel"]
        param = tuning.PARAM_OF.get(kernel, "tile")
        vs = r.metrics.get("speedup_vs_default")
        lines.append(
            f"| {kernel} | {r.params['bucket']} "
            f"| {param}={r.params[param]} "
            f"| {r.metrics['best_us'] / 1e3:.3f} "
            f"| {f'{vs:.2f}x' if vs is not None else '—'} "
            f"| {len(r.timings_us)} |")
    return "\n".join(lines)


def _roofline_table(result) -> str:
    lines = ["## Kernel roofline (achieved vs peak)", "",
             "Achieved FLOP/s and bytes/s of every routed codec kernel: "
             "wall time of the routed call (tuned tiles when a valid "
             "artifact applies) against FLOP/byte counts from XLA's "
             "lowered cost analysis of the jnp reference at the same "
             "shape (analytic byte counts for the two bit-stream "
             "kernels).  Peaks are the documented TPU v5e per-chip "
             "terms (`repro.launch.mesh.HW`), so off-TPU the fractions "
             "prove the pipeline, not efficiency.", "",
             "| kernel | shape | time (ms) | GFLOP/s | GB/s "
             "| % peak FLOPs | % peak BW | FLOP/byte | bound |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in result.records:
        m = r.metrics
        if "height" in r.params:
            shape = f"{r.params['height']}x{r.params['width']}"
        else:
            shape = f"{r.params['payload_bits']} bits"
        bound = "compute" if m["compute_bound"] else "memory"
        lines.append(
            f"| {r.params['kernel']} | {shape} "
            f"| {_ms(r.timings_us['routed'])} "
            f"| {m['achieved_gflop_s']:.2f} "
            f"| {m['achieved_gb_s']:.2f} "
            f"| {m['frac_peak_flops'] * 100:.4f}% "
            f"| {m['frac_peak_bw'] * 100:.4f}% "
            f"| {m['intensity_flop_per_byte']:.2f} "
            f"| {bound} |")
    return "\n".join(lines)


def _micro_table(result) -> str:
    lines = ["## Framework micro-benches", "",
             "| bench | time (ms) | derived |",
             "|---|---|---|"]
    for r in result.records:
        leg, timing = next(iter(r.timings_us.items()))
        derived = "; ".join(f"{k}={v:.2f}" for k, v in r.metrics.items())
        lines.append(f"| {r.label} ({leg}) | {_ms(timing)} | {derived} |")
    return "\n".join(lines)


_TIMING_BLURBS = {
    "table1_lena": ("Paper Table 1 (Lena): per-block sequential codec (the "
                    "paper's CPU code shape) vs the batched serving path "
                    "(fused kernel on TPU, staged batch path elsewhere)."),
    "table2_cablecar": ("Paper Table 2 (Cable-car): same legs as Table 1 on "
                        "the paper's Cable-car sizes."),
}
_PSNR_BLURBS = {
    "table3_psnr_lena": ("Paper Table 3 (Lena): reconstruction quality of "
                         "the exact DCT vs the Cordic-based Loeffler DCT at "
                         "quality 50; the ~2 dB ordering and the size trend "
                         "are the reproduction targets."),
    "table4_psnr_cablecar": ("Paper Table 4 (Cable-car): as Table 3 on the "
                             "edge-rich Cable-car image (lower PSNR at equal "
                             "quality, matching the paper's ordering)."),
}

_SECTIONS = (
    ("table1_lena", "Table 1 — DCT codec time vs Lena image size"),
    ("table2_cablecar", "Table 2 — DCT codec time vs Cable-car image size"),
    ("table3_psnr_lena", "Table 3 — PSNR, exact DCT vs Cordic-Loeffler "
                         "(Lena)"),
    ("table4_psnr_cablecar", "Table 4 — PSNR, exact DCT vs Cordic-Loeffler "
                             "(Cable-car)"),
    ("rate_distortion", None),
    ("entropy_throughput", None),
    ("entropy_decode", None),
    ("serve_batch_throughput", None),
    ("serve_ragged", None),
    ("service_traffic", None),
    ("service_chaos", None),
    ("autotune", None),
    ("roofline", None),
    ("framework_micro", None),
)


def render(results) -> str:
    """Markdown report from loaded artifacts.

    Args:
        results: iterable of :class:`repro.bench.schema.BenchResult`
            (any subset; sections render only for present artifacts,
            always in paper-table order).

    Returns:
        The full RESULTS.md text, environment header included.
    """
    by_name = {r.name: r for r in results}
    if not by_name:
        raise ValueError("no artifacts to render; run "
                         "`python -m repro.bench run --suite paper` first")
    env = next(iter(by_name.values())).environment
    suites = sorted({r.suite for r in by_name.values() if r.suite})
    parts = [
        "# RESULTS",
        "Regenerated from benchmark JSON artifacts by "
        "`python -m repro.bench report` — do not edit by hand; see "
        "docs/benchmarks.md for the artifact schema and the "
        "section-to-artifact mapping.",
        f"*Environment:* backend=`{env.get('backend', '?')}` "
        f"devices={env.get('device_count', '?')} "
        f"jax={env.get('jax_version', '?')} "
        f"git=`{env.get('git_sha', '?')}` "
        f"at {env.get('timestamp_utc', '?')} "
        f"(suite{'s' if len(suites) != 1 else ''}: "
        f"{', '.join(suites) or '?'})",
        "Absolute times are whatever this backend delivers (the paper "
        "measured a Core i7 vs a GTX 480); the reproduction targets are "
        "the *trends* — time growth with image size, serial/parallel "
        "ratio, PSNR ordering and the exact-vs-Cordic gap.",
    ]
    for name, title in _SECTIONS:
        if name not in by_name:
            continue
        result = by_name[name]
        if name in _TIMING_BLURBS:
            parts.append(_timing_table(result, title, _TIMING_BLURBS[name]))
        elif name in _PSNR_BLURBS:
            parts.append(_psnr_table(result, title, _PSNR_BLURBS[name]))
        elif name == "rate_distortion":
            parts.append(_rd_table(result))
        elif name == "entropy_throughput":
            parts.append(_entropy_table(result))
        elif name == "entropy_decode":
            parts.append(_entropy_decode_table(result))
        elif name == "serve_batch_throughput":
            parts.append(_throughput_table(result))
        elif name == "serve_ragged":
            parts.append(_ragged_table(result))
        elif name == "service_traffic":
            parts.append(_service_traffic_table(result))
        elif name == "service_chaos":
            parts.append(_service_chaos_table(result))
        elif name == "autotune":
            parts.append(_tuning_table(result))
        elif name == "roofline":
            parts.append(_roofline_table(result))
        elif name == "framework_micro":
            parts.append(_micro_table(result))
    extra = sorted(set(by_name) - {n for n, _ in _SECTIONS})
    if extra:
        parts.append("## Other artifacts\n\n" + "\n".join(
            f"- `{n}`: {len(by_name[n].records)} records "
            f"(no renderer section)" for n in extra))
    return "\n\n".join(parts) + "\n"


def write_results(results, out_path: str = "RESULTS.md") -> pathlib.Path:
    """Render and write the report; returns the written path."""
    path = pathlib.Path(out_path)
    path.write_text(render(results))
    return path
