"""Kernel tile autotuner: sweep pow2 candidates, persist the winners.

``python -m repro.bench autotune`` times every pow2 tile candidate per
(kernel, backend, shape bucket) through the standard warmup/steady-state
timer (:func:`repro.bench.timer.measure`) and writes two artifacts:

* ``results/tuning.json`` — the versioned, git-sha-stamped winners
  document (:mod:`repro.kernels.tuning` schema) that each kernel's
  ``ops.py`` router loads when its tile knob is left at ``None``;
* ``results/autotune.json`` — a standard :class:`~repro.bench.schema.
  BenchResult` carrying the full candidate-vs-time grid, so the
  RESULTS.md renderer can show *why* each winner won.

The swept knobs are exactly the ones the routers expose: ``tile`` (the
``common.pick_tile`` target) for ``dct8x8`` / ``cordic_loeffler`` /
``fused_codec``, ``tile_bits`` (window follows as
``tile_bits + margin``) for ``pack_bits`` / ``unpack_bits``,
``block_rows`` for ``grad_dct``, and ``tile_blocks`` for
``symbolize``.  Off-TPU
the Pallas legs run in interpret mode — the sweep then measures the
interpreter, which is still a full pipeline proof (CI runs it with
``--smoke``); winners are only *routed* on the backend they were swept
on (:func:`repro.kernels.tuning.lookup` rejects backend mismatches).

Correctness never depends on the sweep: the tile-invariance property
tests (``tests/test_tile_invariance.py``) pin byte/coefficient identity
across every candidate listed here, so the autotuner can only change
speed, not bits.
"""

from __future__ import annotations

import pathlib

import numpy as np

from repro.bench import schema
from repro.bench.schema import BenchRecord
from repro.bench.timer import TimerConfig, measure
from repro.kernels import tuning

# Every tile candidate the autotuner may select, per kernel.  The
# tile-invariance tests import this dict: adding a candidate here
# automatically widens the identity gate.
CANDIDATES = {
    "dct8x8": (8, 16, 32, 64, 128, 256),
    "cordic_loeffler": (8, 16, 32, 64, 128, 256),
    "fused_codec": (8, 16, 32, 64, 128, 256),
    "pack_bits": (256, 512, 1024, 2048, 4096),
    "unpack_bits": (512, 1024, 2048, 4096, 8192),
    "grad_dct": (64, 128, 256, 512, 1024),
    "symbolize": (8, 16, 32, 64, 128),
}

# Suite -> sweep grid.  ``image_buckets`` are square image sizes (the
# pow2 shape buckets tuned entries are keyed by); ``entropy_size`` is
# the image size whose real entropy payload drives the bit-kernel
# sweeps; ``max_candidates`` trims each candidate list from the top
# (smoke keeps the sweep tiny for CI).
SUITE_GRIDS = {
    "smoke": {"image_buckets": (64,), "entropy_size": 48,
              "grad_rows": 256, "max_candidates": 2},
    "paper": {"image_buckets": (256,), "entropy_size": 128,
              "grad_rows": 4096, "max_candidates": None},
    "full": {"image_buckets": (256, 512), "entropy_size": 256,
             "grad_rows": 16384, "max_candidates": None},
}

SUITE_TIMERS = {
    "smoke": TimerConfig(warmup=1, iters=2),
    "paper": TimerConfig(warmup=1, iters=3),
    "full": TimerConfig(warmup=1, iters=3),
}

IMAGE_KERNELS = ("dct8x8", "cordic_loeffler", "fused_codec")


def _image_candidates(kernel: str, bucket: int, cap: int | None) -> list:
    cands = [c for c in CANDIDATES[kernel] if c <= bucket]
    return cands[-cap:] if cap else cands


def _bit_candidates(kernel: str, cap: int | None) -> list:
    cands = list(CANDIDATES[kernel])
    return cands[:cap] if cap else cands


def _image_fn(kernel: str):
    if kernel == "dct8x8":
        from repro.kernels.dct8x8 import ops
        return lambda img, t: ops.dct8x8(img, tile=t)
    if kernel == "cordic_loeffler":
        from repro.kernels.cordic_loeffler import ops
        return lambda img, t: ops.cordic_loeffler_dct(img, tile=t)
    from repro.kernels.fused_codec import ops
    return lambda img, t: ops.fused_codec(img, tile=t)


def _entropy_workload(size: int):
    """One real image's entropy stage: (codes, lengths, payload, tables,
    n_blocks, dc_diff, ac).  The pack sweep times the captured codeword
    fields; the unpack sweep times the payload they packed into; the
    symbolize sweep re-symbolises the raw block arrays."""
    from repro.bench import cases
    from repro.core.entropy import bitio, rle
    (_, dc_diff, ac, payload, (dc_t, ac_t),
     n_blocks) = cases._entropy_stage_inputs(size)
    syms = rle.symbolize(dc_diff, ac)
    captured = {}

    def cap(fields, widths):
        captured["cl"] = (np.asarray(fields), np.asarray(widths))
        return bitio.pack_bits(fields, widths)

    rle.encode_payload(*syms, dc_t, ac_t, packer=cap)
    codes, lengths = captured["cl"]
    return codes, lengths, payload, (dc_t, ac_t), n_blocks, dc_diff, ac


def sweep(suite: str = "paper", timer: TimerConfig | None = None,
          log=print) -> list:
    """Time every candidate; one :class:`BenchRecord` per (kernel, bucket).

    Record layout: ``params`` carries kernel/bucket/winner, ``timings_us``
    one leg per candidate (``tile_<n>``), ``metrics`` the winning median
    and its speedup over the built-in default tile.
    """
    from repro.core import images

    grid = SUITE_GRIDS.get(suite, SUITE_GRIDS["paper"])
    timer = timer or SUITE_TIMERS.get(suite, TimerConfig(warmup=1, iters=3))
    cap = grid["max_candidates"]
    records = []

    for kernel in IMAGE_KERNELS:
        fn = _image_fn(kernel)
        for bucket in grid["image_buckets"]:
            img = np.asarray(images.lena_like(bucket, bucket),
                             dtype=np.float32)
            records.append(_sweep_one(
                kernel, tuning.bucket_of(bucket),
                _image_candidates(kernel, bucket, cap),
                lambda t, f=fn, x=img: f(x, t), timer, log,
                extra_params={"image_hw": bucket}))

    size = grid["entropy_size"]
    codes, lengths, payload, (dc_t, ac_t), n_blocks, dc_diff, ac = (
        _entropy_workload(size))
    nbits = len(payload) * 8

    from repro.kernels import pack_bits as pb
    from repro.kernels import unpack_bits as ub
    total_bits = int(np.sum(lengths))
    records.append(_sweep_one(
        "pack_bits", tuning.bucket_of(total_bits),
        _bit_candidates("pack_bits", cap),
        lambda t: pb.pack_bits(codes, lengths, backend="pallas",
                               tile_bits=t),
        timer, log, extra_params={"entropy_size": size,
                                  "payload_bits": total_bits}))
    records.append(_sweep_one(
        "unpack_bits", tuning.bucket_of(nbits),
        _bit_candidates("unpack_bits", cap),
        lambda t: ub.unpack_bits(payload, n_blocks, dc_t, ac_t,
                                 backend="pallas", tile_bits=t),
        timer, log, extra_params={"entropy_size": size,
                                  "payload_bits": nbits,
                                  "n_blocks": n_blocks}))

    # symbolize: same image's zig-zag blocks through the Pallas kernel
    # (interpret mode off-TPU), keyed by block count like the routers
    from repro.kernels import symbolize as sy
    records.append(_sweep_one(
        "symbolize", tuning.bucket_of(n_blocks),
        [c for c in _bit_candidates("symbolize", cap) if c <= n_blocks]
        or [CANDIDATES["symbolize"][0]],
        lambda t: sy.symbolize_dense(dc_diff, ac, backend="pallas",
                                     tile_blocks=t),
        timer, log, extra_params={"entropy_size": size,
                                  "n_blocks": n_blocks}))

    # grad_dct: a flat gradient vector (the distributed-training
    # compressor), keyed by 64-sample row count
    from repro.kernels import grad_dct as gd
    rows = grid["grad_rows"]
    g = np.asarray(np.random.default_rng(0).standard_normal(
        rows * gd.BLOCK + 7), dtype=np.float32)
    # measure() blocks on the returned pytree; CompressedGrad is a plain
    # dataclass, so hand its arrays back as a tuple
    records.append(_sweep_one(
        "grad_dct", tuning.bucket_of(rows),
        [c for c in _bit_candidates("grad_dct", cap) if c <= rows]
        or [CANDIDATES["grad_dct"][0]],
        lambda t: (lambda cg: (cg.q, cg.scale, cg.tail))(
            gd.encode(g, block_rows=t)),
        timer, log, extra_params={"grad_rows": rows}))
    return records


def _sweep_one(kernel: str, bucket: int, candidates, run_candidate,
               timer: TimerConfig, log, extra_params: dict) -> BenchRecord:
    param = tuning.PARAM_OF[kernel]
    default = tuning.DEFAULTS[kernel][param]
    timings = {}
    for cand in candidates:
        t = measure(run_candidate, cand,
                    warmup=timer.warmup, iters=timer.iters)
        timings[f"tile_{cand}"] = t.to_json()
    best = min(timings, key=lambda k: timings[k]["median_us"])
    winner = int(best.split("_", 1)[1])
    best_us = timings[best]["median_us"]
    default_key = f"tile_{default}"
    metrics = {"best_us": best_us}
    if default_key in timings:
        metrics["speedup_vs_default"] = (
            timings[default_key]["median_us"] / best_us)
    log(f"autotune {kernel} bucket={bucket}: {param}={winner} "
        f"({best_us:.0f} us over {len(timings)} candidates)")
    return BenchRecord(
        label=f"{kernel}_b{bucket}",
        params={"kernel": kernel, "bucket": bucket, param: winner,
                "candidates": list(candidates), **extra_params},
        timings_us=timings,
        metrics=metrics)


def tuning_entries(records) -> list:
    """Winner entries (the :mod:`repro.kernels.tuning` schema) from
    sweep records."""
    entries = []
    for r in records:
        kernel = r.params["kernel"]
        param = tuning.PARAM_OF[kernel]
        entries.append({
            "kernel": kernel,
            "bucket": int(r.params["bucket"]),
            "params": {param: int(r.params[param])},
            "best_us": r.metrics["best_us"],
        })
    return entries


def run_autotune(suite: str = "paper", out_dir: str = "results",
                 timer: TimerConfig | None = None, log=print) -> dict:
    """Full autotune run: sweep, write both artifacts, reload the cache.

    Returns ``{"tuning_path": ..., "bench_path": ..., "records": ...}``.
    """
    env = schema.capture_environment()
    log(f"# autotune suite={suite} backend={env['backend']} "
        f"git={env['git_sha']}")
    records = sweep(suite, timer=timer, log=log)

    doc = tuning.make_doc(tuning_entries(records), backend=env["backend"],
                          environment=env)
    tuning_path = tuning.save(doc, pathlib.Path(out_dir) / "tuning.json")
    tuning.invalidate_cache()

    result = schema.BenchResult(name="autotune", suite=suite,
                                records=records, environment=env)
    bench_path = schema.save(result, out_dir)
    log(f"autotune: {len(records)} sweeps -> {tuning_path} + {bench_path}")
    return {"tuning_path": tuning_path, "bench_path": bench_path,
            "records": records}
