"""Registered benchmark cases: the paper's four tables + serving benches.

Every case is declarative about *what* it measures (image family, size
grid, transform, quality) and delegates *how* to the shared machinery:
:func:`repro.bench.timer.measure` for timing and
:mod:`repro.serve.codec_engine` for the accelerated leg, so CPU-vs-
accelerated comparisons run one code path (the engine routes to the
fused Pallas kernel on TPU and to the bit-exact staged path elsewhere).

Legs for the timing tables (paper Tables 1-2):

* ``serial``   — the paper's CPU code shape: ``lax.map`` over 8x8 blocks,
  one at a time, unfused three-pass DCT/quant/IDCT,
* ``parallel`` — the serving path: :func:`codec_engine.roundtrip_batch`
  on a batch of one (all blocks batched; fused kernel on TPU).

This container has no GPU, so the paper's CPU-vs-GTX480 contrast is
reproduced structurally on whatever backend jax reports; the *trend with
image size* and the serial/parallel ratio are the reproduction targets,
not GTX-480 milliseconds (see PAPER.md and docs/benchmarks.md).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench.registry import RunContext, benchmark
from repro.bench.schema import BenchRecord
from repro.bench.timer import measure
from repro.core import codec, dct, images, metrics, quant

QUALITY = 50               # the paper's fixed JPEG quality factor

# Size grids per suite.  "smoke" = smallest point (CI / tests), "paper" =
# the representative subset, "full" = the paper's complete grid.
TABLE1_GRID = {
    "smoke": [(200, 200)],
    "paper": [(1024, 1024), (512, 512), (200, 200)],
    "full": list(images.LENA_SIZES),
}
TABLE2_GRID = {
    "smoke": [(320, 288)],
    "paper": list(images.CABLECAR_SIZES[:3]),
    "full": list(images.CABLECAR_SIZES),
}
TABLE3_GRID = {
    "smoke": [(200, 200)],
    "paper": [(200, 200), (512, 512)],
    "full": [(200, 200), (512, 512), (2048, 2048), (3072, 3072)],
}
TABLE4_GRID = {
    "smoke": [(320, 288)],
    "paper": [(320, 288), (384, 352)],
    "full": list(reversed(images.CABLECAR_SIZES)),
}
BATCH_GRID = {"smoke": 8, "paper": 64, "full": 256}


def batch_sizes(max_batch: int) -> list:
    """The power-of-two batch grid shared by the registry case and the
    CI monotone gate (``benchmarks/bench_batch_throughput.py``)."""
    return [b for b in (1, 2, 4, 8, 16, 32, 64, 128, 256) if b <= max_batch]


def _grid(table: dict, suite: str) -> list:
    return table.get(suite, table["paper"])


# ---------------------------------------------------------------------------
# Legs
# ---------------------------------------------------------------------------

@jax.jit
def _serial_codec(img, q):
    """The paper's CPU loop shape: per-block sequential three-pass codec."""
    x = img.astype(jnp.float32) - 128.0
    blocks = dct.to_blocks(x)
    hb, wb = blocks.shape[0], blocks.shape[1]
    flat = blocks.reshape(hb * wb, 8, 8)

    def one(block):
        coef = dct.dct2d(block)
        qc = jnp.round(coef / q)
        return dct.idct2d(qc * q)

    out = jax.lax.map(one, flat)   # sequential over blocks
    rec = dct.from_blocks(out.reshape(hb, wb, 8, 8))
    return jnp.clip(jnp.round(rec + 128.0), 0, 255).astype(jnp.uint8)


def _parallel_roundtrip(img: jnp.ndarray):
    """The serving path on a batch of one (fused on TPU, staged on CPU)."""
    from repro.serve import codec_engine
    rec, _ = codec_engine.roundtrip_batch(img[None], QUALITY, "exact",
                                          with_psnr=False)
    return rec


def _timing_records(sizes, image_fn, family: str, ctx: RunContext) -> list:
    q = quant.qtable(QUALITY)
    timer = ctx.timer.scaled(warmup=max(ctx.timer.warmup, 1))
    records = []
    for (h, w) in sizes:
        img = jnp.asarray(image_fn(h, w))
        t_par = measure(_parallel_roundtrip, img,
                        warmup=timer.warmup, iters=timer.iters)
        # the engine pads internally; the serial leg needs the same
        # 8-multiple padding (the paper's 1024x814 is not block-aligned)
        t_ser = measure(_serial_codec, codec.pad_to_block(img), q,
                        warmup=timer.warmup, iters=timer.iters)
        records.append(BenchRecord(
            label=f"{family}_{h}x{w}",
            params={"height": h, "width": w, "image": family,
                    "transform": "exact", "quality": QUALITY},
            timings_us={"parallel": t_par.to_json(),
                        "serial": t_ser.to_json()},
            metrics={"speedup": t_ser.median_us / t_par.median_us,
                     "mpix_per_s": (h * w) / t_par.median_us}))
    return records


def _psnr_records(sizes, image_fn, family: str) -> list:
    records = []
    for (h, w) in sizes:
        img = image_fn(h, w)
        _, p_dct = codec.roundtrip(img, QUALITY, "exact")
        _, p_cor = codec.roundtrip(img, QUALITY, "cordic")
        records.append(BenchRecord(
            label=f"{family}_{h}x{w}",
            params={"height": h, "width": w, "image": family,
                    "quality": QUALITY},
            metrics={"psnr_db_exact": p_dct, "psnr_db_cordic": p_cor,
                     "gap_db": p_dct - p_cor}))
    return records


# ---------------------------------------------------------------------------
# Paper tables
# ---------------------------------------------------------------------------

@benchmark("table1_lena", suites=("smoke", "paper", "full"), table="Table 1",
           description="DCT codec time vs Lena size, serial vs parallel leg")
def table1_lena(ctx: RunContext) -> list:
    return _timing_records(_grid(TABLE1_GRID, ctx.suite),
                           images.lena_like, "lena", ctx)


@benchmark("table2_cablecar", suites=("smoke", "paper", "full"),
           table="Table 2",
           description="DCT codec time vs Cable-car size, serial vs parallel")
def table2_cablecar(ctx: RunContext) -> list:
    return _timing_records(_grid(TABLE2_GRID, ctx.suite),
                           images.cablecar_like, "cablecar", ctx)


@benchmark("table3_psnr_lena", suites=("smoke", "paper", "full"),
           table="Table 3",
           description="PSNR of exact DCT vs Cordic-Loeffler DCT on Lena")
def table3_psnr_lena(ctx: RunContext) -> list:
    return _psnr_records(_grid(TABLE3_GRID, ctx.suite),
                         images.lena_like, "lena")


@benchmark("table4_psnr_cablecar", suites=("smoke", "paper", "full"),
           table="Table 4",
           description="PSNR of exact DCT vs Cordic-Loeffler on Cable-car")
def table4_psnr_cablecar(ctx: RunContext) -> list:
    return _psnr_records(_grid(TABLE4_GRID, ctx.suite),
                         images.cablecar_like, "cablecar")


# ---------------------------------------------------------------------------
# Rate–distortion (measured bytes through the entropy stage)
# ---------------------------------------------------------------------------

RD_QUALITIES = {
    "smoke": [10, 50, 90],
    "paper": [10, 30, 50, 70, 90],
    "full": [10, 20, 30, 40, 50, 60, 70, 80, 90],
}
RD_IMAGES = {
    "smoke": [("lena", images.lena_like, (200, 200))],
    "paper": [("lena", images.lena_like, (512, 512)),
              ("cablecar", images.cablecar_like, (320, 288))],
}
RD_IMAGES["full"] = RD_IMAGES["paper"]


def rate_distortion_points(image_fn, family: str, h: int, w: int,
                           qualities, warmup: int, iters: int) -> list:
    """Measured rate–distortion sweep for one image: one record per
    quality with real container bytes, PSNR, and encode/decode timings.

    Shared by the ``rate_distortion`` registry case and the
    ``benchmarks/bench_rate_distortion.py`` CI gate.

    Args:
        image_fn: (h, w) -> uint8 image generator.
        family: label prefix ("lena"/"cablecar").
        h, w: image size.
        qualities: JPEG quality factors to sweep.
        warmup: untimed leading calls per leg (compile + cache warm).
        iters: timed calls per leg.

    Returns:
        BenchRecord list; ``metrics["bpp"]`` is *measured*
        bits-per-pixel (``8 * len(stream) / (h * w)``), not the
        ``estimate_bits`` proxy.
    """
    from repro.core import entropy
    img = image_fn(h, w)
    records = []
    for q in qualities:
        blob = entropy.encode_image(img, q)
        rec = entropy.decode_image(blob)
        psnr = float(metrics.psnr(jnp.asarray(img), rec))
        t_enc = measure(entropy.encode_image, img, q,
                        warmup=warmup, iters=iters)
        t_dec = measure(entropy.decode_image, blob,
                        warmup=warmup, iters=iters)
        bpp = len(blob) * 8 / (h * w)
        records.append(BenchRecord(
            label=f"{family}_{h}x{w}_q{q}",
            params={"height": h, "width": w, "image": family,
                    "quality": q, "transform": "exact",
                    "nbytes": len(blob)},
            timings_us={"encode": t_enc.to_json(),
                        "decode": t_dec.to_json()},
            metrics={"bpp": bpp, "compression_ratio": 8.0 / bpp,
                     "psnr_db": psnr,
                     "enc_mpix_per_s": (h * w) / t_enc.median_us,
                     "dec_mpix_per_s": (h * w) / t_dec.median_us}))
    return records


def check_rd_monotone(points) -> list:
    """Rate–distortion monotonicity violations over (quality, bpp, psnr).

    Higher quality must cost more measured bits-per-pixel and buy more
    PSNR; that joint ordering is the CI gate for the entropy stage.

    Args:
        points: iterable of (quality, bpp, psnr_db) tuples (any order;
            duplicate qualities collapse to one point — re-measuring
            the same quality is not a violation).

    Returns:
        ``(metric_name, lower_quality, higher_quality)`` tuples where
        the metric failed to strictly increase with quality.
    """
    pts = sorted({q: (q, b, p) for q, b, p in sorted(points)}.values())
    bad = []
    for (q1, b1, p1), (q2, b2, p2) in zip(pts, pts[1:]):
        if b2 <= b1:
            bad.append(("bpp", q1, q2))
        if p2 <= p1:
            bad.append(("psnr", q1, q2))
    return bad


@benchmark("rate_distortion", suites=("smoke", "paper", "full"),
           description="measured bits-per-pixel, PSNR and encode/decode "
                       "throughput vs quality (entropy-coded bytes)")
def rate_distortion(ctx: RunContext) -> list:
    """Quality sweep through the full codec: DCT -> quantise -> zig-zag
    -> RLE -> canonical Huffman -> ``DCTZ`` container, sizes measured
    from the real stream."""
    qualities = RD_QUALITIES.get(ctx.suite, RD_QUALITIES["paper"])
    grid = RD_IMAGES.get(ctx.suite, RD_IMAGES["paper"])
    timer = ctx.timer.scaled(warmup=max(ctx.timer.warmup, 1))
    records = []
    for family, image_fn, (h, w) in grid:
        records.extend(rate_distortion_points(
            image_fn, family, h, w, qualities,
            warmup=timer.warmup, iters=timer.iters))
    return records


# ---------------------------------------------------------------------------
# Entropy throughput (vectorized host coding vs the scalar reference)
# ---------------------------------------------------------------------------

ENTROPY_GRID = {
    "smoke": {"size": 128, "batches": [1, 4]},
    "paper": {"size": 256, "batches": [1, 2, 4, 8]},
    "full": {"size": 512, "batches": [1, 2, 4, 8, 16]},
}


def _entropy_stage_inputs(size: int, quality: int = QUALITY):
    """(z, dc_diff, ac, payload, tables, n_blocks) for one image's
    entropy-stage legs, derived once outside the timed region."""
    from repro.core.entropy import huffman, rle, scan
    img = images.lena_like(size, size)
    c = codec.compress(img, quality)
    z = np.asarray(scan.block_stream(jnp.asarray(c.qcoeffs)))
    dc_diff = np.diff(z[:, 0].astype(np.int64), prepend=np.int64(0))
    ac = z[:, 1:].astype(np.int64)
    syms = rle.symbolize(dc_diff, ac)
    dc_freq, ac_freq = rle.symbol_frequencies(syms[0], syms[1])
    dc_t, ac_t = huffman.build_table(dc_freq), huffman.build_table(ac_freq)
    payload = rle.encode_payload(*syms, dc_t, ac_t)
    return z, dc_diff, ac, payload, (dc_t, ac_t), z.shape[0]


def reference_encode_stream(dc_diff, ac) -> bytes:
    """The PR 3 scalar host path: per-block symbolisation + uncached
    tables + packing.  The golden baseline the vectorized legs are
    measured (and identity-checked) against."""
    from repro.core.entropy import huffman, rle
    syms = rle.symbolize_reference(dc_diff, ac)
    dc_freq, ac_freq = rle.symbol_frequencies(syms[0], syms[1])
    return rle.encode_payload(*syms, huffman.build_table(dc_freq),
                              huffman.build_table(ac_freq))


def vectorized_encode_stream(dc_diff, ac) -> bytes:
    """The production vectorized host path over the same inputs (whole-
    array symbolisation, uncached tables for a fair comparison)."""
    from repro.core.entropy import huffman, rle
    syms = rle.symbolize(dc_diff, ac)
    dc_freq, ac_freq = rle.symbol_frequencies(syms[0], syms[1])
    return rle.encode_payload(*syms, huffman.build_table(dc_freq),
                              huffman.build_table(ac_freq))


def entropy_throughput_points(size: int, batches, warmup: int,
                              iters: int) -> list:
    """Measured records for the ``entropy_throughput`` case.

    One ``entropy_stage`` record times the host entropy stage in
    isolation on a single image — vectorized vs scalar-reference, both
    directions — and one ``encode_batch_{b}`` / ``decode_batch_{b}``
    record per batch size drives the engine's overlapped byte path
    (pipelined vs serial), scoring ``speedup_vs_reference`` against the
    single-image reference end-to-end rate (device compress + scalar
    host coding), the PR 3 code shape.

    Shared by the registry case and
    ``benchmarks/bench_entropy_throughput.py``.
    """
    from repro.core.entropy import rle
    from repro.serve import codec_engine

    (z, dc_diff, ac, payload, (dc_t, ac_t),
     n_blocks) = _entropy_stage_inputs(size)
    mb = size * size / 1e6          # decoded image payload in MB
    shape = (size, size)

    t_enc_vec = measure(vectorized_encode_stream, dc_diff, ac,
                        warmup=warmup, iters=iters)
    t_enc_ref = measure(reference_encode_stream, dc_diff, ac,
                        warmup=min(warmup, 1), iters=max(iters // 2, 2))
    t_dec_vec = measure(rle.decode_payload, payload, n_blocks, dc_t, ac_t,
                        warmup=warmup, iters=iters)
    t_dec_ref = measure(rle.decode_payload_reference, payload, n_blocks,
                        dc_t, ac_t,
                        warmup=min(warmup, 1), iters=max(iters // 2, 2))
    records = [BenchRecord(
        label=f"entropy_stage_{size}",
        params={"height": size, "width": size, "image": "lena",
                "quality": QUALITY, "n_blocks": n_blocks,
                "payload_nbytes": len(payload)},
        timings_us={"enc_vectorized": t_enc_vec.to_json(),
                    "enc_reference": t_enc_ref.to_json(),
                    "dec_vectorized": t_dec_vec.to_json(),
                    "dec_reference": t_dec_ref.to_json()},
        metrics={"enc_speedup": t_enc_ref.median_us / t_enc_vec.median_us,
                 "dec_speedup": t_dec_ref.median_us / t_dec_vec.median_us,
                 "enc_mb_per_s": mb / (t_enc_vec.median_us / 1e6),
                 "dec_mb_per_s": mb / (t_dec_vec.median_us / 1e6)})]

    # per-stage encode breakdown: the fused dense pass split into its
    # stages (symbolize incl. histograms / table choice / codeword
    # lookup / bit pack), scored against the PR 4 vectorized host
    # symbolisation on the same blocks, plus the host<->device traffic
    # each symbolize routing implies (docs/benchmarks.md)
    from repro.core.entropy import bitio, huffman
    from repro.kernels.symbolize import ref as sref

    def vectorized_symbolize():
        syms = rle.symbolize(dc_diff, ac)
        return rle.symbol_frequencies(syms[0], syms[1])

    dense = sref.symbolize_dense(dc_diff, ac)
    fields, widths = sref.encode_fields_dense(dense, dc_t, ac_t)
    t_sym = measure(sref.symbolize_dense, dc_diff, ac,
                    warmup=warmup, iters=iters)
    t_sym_vec = measure(vectorized_symbolize, warmup=warmup, iters=iters)
    t_tab = measure(lambda: (huffman.build_table(dense.dc_freq),
                             huffman.build_table(dense.ac_freq)),
                    warmup=warmup, iters=iters)
    t_cw = measure(sref.encode_fields_dense, dense, dc_t, ac_t,
                   warmup=warmup, iters=iters)
    t_pack = measure(bitio.pack_bits, fields, widths,
                     warmup=warmup, iters=iters)
    # host-routed encode pulls the full int32 coefficient tensor; the
    # device-resident chain pulls two (1, 256) int32 histograms, one
    # scalar bit count + flag, and the finished payload bytes
    host_xfer = n_blocks * 64 * 4
    device_xfer = 2 * 256 * 4 + 8 + len(payload)
    records.append(BenchRecord(
        label=f"encode_stages_{size}",
        params={"height": size, "width": size, "image": "lena",
                "quality": QUALITY, "n_blocks": n_blocks,
                "payload_nbytes": len(payload)},
        timings_us={"stage_symbolize": t_sym.to_json(),
                    "stage_symbolize_vectorized": t_sym_vec.to_json(),
                    "stage_table_choice": t_tab.to_json(),
                    "stage_codeword": t_cw.to_json(),
                    "stage_pack": t_pack.to_json()},
        metrics={
            "symbolize_speedup_vs_vectorized":
                t_sym_vec.median_us / t_sym.median_us,
            "host_transfer_bytes_per_image": float(host_xfer),
            "device_transfer_bytes_per_image": float(device_xfer),
            "transfer_reduction": host_xfer / device_xfer,
        }))

    # single-image reference end-to-end rate: sharded device compress
    # (shared by both code shapes) + the scalar host coding PR 3 paid
    img1 = images.lena_like(size, size, seed=0)[None]

    def ref_encode_e2e():
        cb = codec_engine.compress_batch(img1, QUALITY)
        cb._image_qcoeffs()                 # forces the device->host copy
        return reference_encode_stream(dc_diff, ac)

    t_ref_e2e = measure(ref_encode_e2e, warmup=min(warmup, 1),
                        iters=max(iters // 2, 2))
    ref_img_per_s = 1e6 / t_ref_e2e.median_us

    for b in batches:
        imgs = np.stack([images.lena_like(size, size, seed=i)
                         for i in range(b)])

        def enc(pipelined):
            return codec_engine.encode_batch(imgs, QUALITY,
                                             pipelined=pipelined)

        t_pipe = measure(enc, True, warmup=warmup, iters=iters)
        t_ser = measure(enc, False, warmup=min(warmup, 1),
                        iters=max(iters // 2, 2))
        blobs = enc(True)
        nbytes = sum(len(x) for x in blobs)

        def dec(pipelined):
            return codec_engine.decode_batch(blobs, pipelined=pipelined)

        t_dpipe = measure(dec, True, warmup=warmup, iters=iters)
        t_dser = measure(dec, False, warmup=min(warmup, 1),
                         iters=max(iters // 2, 2))
        pipe_img_per_s = b / (t_pipe.median_us / 1e6)
        records.append(BenchRecord(
            label=f"batch_{b}",
            params={"batch": b, "height": size, "width": size,
                    "quality": QUALITY, "nbytes": nbytes},
            timings_us={"encode_pipelined": t_pipe.to_json(),
                        "encode_serial": t_ser.to_json(),
                        "decode_pipelined": t_dpipe.to_json(),
                        "decode_serial": t_dser.to_json()},
            metrics={
                "enc_img_per_s": pipe_img_per_s,
                "enc_img_per_s_serial": b / (t_ser.median_us / 1e6),
                "dec_img_per_s": b / (t_dpipe.median_us / 1e6),
                "dec_img_per_s_serial": b / (t_dser.median_us / 1e6),
                "enc_mb_per_s": b * mb / (t_pipe.median_us / 1e6),
                "speedup_vs_reference": pipe_img_per_s / ref_img_per_s,
            }))
    return records


def adversarial_blocks() -> list:
    """(dc_diff, ac) pairs exercising the symboliser's corner cases:
    max-magnitude amplitudes, all-zero blocks, and ZRL chains (shared
    by the ``--check-identical`` CI gate and the property tests)."""
    return [
        (np.array([0, 0, 0]), np.zeros((3, 63), np.int64)),
        (np.array([5]), np.eye(1, 63, 62, dtype=np.int64) * 32767),
        (np.array([-32767]), np.eye(1, 63, 40, dtype=np.int64) * -32767),
        (np.array([1]), np.eye(1, 63, 62, dtype=np.int64) * 3),
        (np.array([7]),
         np.tile([0] * 9 + [1], 7)[:63].reshape(1, 63).astype(np.int64)),
        (np.array([100]), np.full((1, 63), 255, np.int64)),
        (np.array([0]),
         np.concatenate([np.zeros(47, np.int64), [7],
                         np.zeros(15, np.int64)]).reshape(1, 63)),
    ]


def entropy_identity_violations(seed: int = 0, trials: int = 25) -> list:
    """Cases where the vectorized entropy path diverges from the scalar
    reference — the ``--check-identical`` CI gate (must return []).

    Checks, per case: symbol-stream equality, payload byte equality,
    and both decoders inverting the stream exactly, over ``trials``
    random batches (mixed density, full amplitude range) plus the
    :func:`adversarial_blocks`.
    """
    from repro.core.entropy import huffman, rle
    rng = np.random.default_rng(seed)
    cases = []
    for t in range(trials):
        n = int(rng.integers(1, 24))
        ac = rng.integers(-32767, 32768, (n, 63))
        ac[rng.random((n, 63)) < rng.uniform(0.2, 0.995)] = 0
        dc = rng.integers(-32767, 32768, (n,))
        cases.append((f"random_{t}", dc, ac))
    cases += [(f"adversarial_{i}", dc, ac)
              for i, (dc, ac) in enumerate(adversarial_blocks())]

    bad = []
    for name, dc, ac in cases:
        vec = rle.symbolize(dc, ac)
        ref = rle.symbolize_reference(dc, ac)
        if not all(np.array_equal(a, b) for a, b in zip(vec, ref)):
            bad.append(f"{name}: symbol stream mismatch")
            continue
        if vectorized_encode_stream(dc, ac) != reference_encode_stream(
                dc, ac):
            bad.append(f"{name}: payload bytes mismatch")
            continue
        dc_f, ac_f = rle.symbol_frequencies(vec[0], vec[1])
        dc_t = huffman.build_table(dc_f)
        ac_t = huffman.build_table(ac_f)
        payload = rle.encode_payload(*vec, dc_t, ac_t)
        got = rle.decode_payload(payload, len(dc), dc_t, ac_t)
        want = rle.decode_payload_reference(payload, len(dc), dc_t, ac_t)
        if not (np.array_equal(got[0], want[0])
                and np.array_equal(got[1], want[1])):
            bad.append(f"{name}: decoder mismatch vs reference")
        elif not (np.array_equal(got[0], dc) and np.array_equal(got[1],
                                                                ac)):
            bad.append(f"{name}: decode does not invert encode")
    return bad


def packing_identity_violations(seed: int = 0, trials: int = 25) -> list:
    """Cases where a routed pack-bits backend diverges from the NumPy
    reference — the packing half of the ``--check-identical`` CI gate
    (must return []).

    Checks, per case, that the staged NumPy reference
    (:func:`repro.kernels.pack_bits.pack_bits_ref`) and the Pallas
    kernel (interpret mode off-TPU) both produce bytes identical to
    :func:`repro.core.entropy.bitio.pack_bits`, over ``trials`` random
    field streams (mixed widths 0..16, including zero-width amplitude
    slots) plus the codeword fields of the :func:`adversarial_blocks`;
    then that whole ``DCTZ`` streams framed through the routed Pallas
    packer are identical to the default path, under both embedded and
    shared table policies.
    """
    from repro.core import entropy
    from repro.core.entropy import bitio, huffman, rle
    from repro.kernels import pack_bits as pb
    rng = np.random.default_rng(seed)
    cases = []
    for t in range(trials):
        m = int(rng.integers(1, 600))
        widths = rng.integers(0, 17, m)
        # deliberately unmasked: only the low `widths` bits are payload,
        # and backends must agree on ignoring the stray high bits
        fields = rng.integers(0, 1 << 16, m)
        cases.append((f"random_{t}", fields, widths))
    for i, (dc, ac) in enumerate(adversarial_blocks()):
        syms = rle.symbolize(dc, ac)
        dc_f, ac_f = rle.symbol_frequencies(syms[0], syms[1])
        fields, widths = rle.codeword_fields(
            *syms, huffman.build_table(dc_f), huffman.build_table(ac_f))
        cases.append((f"adversarial_{i}", fields, widths))

    bad = []
    for name, fields, widths in cases:
        want = bitio.pack_bits(fields, widths)
        if pb.pack_bits_ref(fields, widths) != want:
            bad.append(f"{name}: staged reference bytes mismatch")
            continue
        if pb.pack_bits(fields, widths, backend="pallas",
                        interpret=True) != want:
            bad.append(f"{name}: Pallas kernel bytes mismatch")

    # whole-stream check: the routed packer must frame identical DCTZ
    # containers under every table policy
    c = codec.compress(images.lena_like(32, 32), QUALITY)
    packer = pb.make_packer(backend="pallas", interpret=True)
    for tables in ("auto", "embedded", "shared"):
        want = entropy.encode_qcoeffs(c.qcoeffs, QUALITY, "exact",
                                      (32, 32), tables=tables)
        got = entropy.encode_qcoeffs(c.qcoeffs, QUALITY, "exact",
                                     (32, 32), tables=tables,
                                     packer=packer)
        if got != want:
            bad.append(f"stream_{tables}: routed Pallas stream mismatch")
    return bad


def unpack_identity_violations(seed: int = 0, trials: int = 25) -> list:
    """Cases where a routed unpack-bits backend diverges from the scalar
    decode oracle — the decode half of the ``--check-identical`` CI
    gate (must return []).

    Checks, per case, that the staged NumPy reference
    (:func:`repro.kernels.unpack_bits.unpack_bits_ref`, at the default
    and at a boundary-straddling tile size) and the Pallas speculative
    kernel (interpret mode off-TPU) decode coefficients identical to
    :func:`repro.core.entropy.rle.decode_payload_reference`, over
    ``trials`` random batches plus the :func:`adversarial_blocks`; that
    truncated prefixes of those payloads are rejected with the same
    error type and message as the production LUT walk; and that whole
    ``DCTZ`` streams decoded through the routed unpacker match the
    default path under every table policy.
    """
    from repro.core import entropy
    from repro.core.entropy import bitio, huffman, rle
    from repro.kernels import unpack_bits as ub
    from repro.kernels.unpack_bits import ref as uref
    rng = np.random.default_rng(seed)
    cases = []
    for t in range(trials):
        n = int(rng.integers(1, 24))
        ac = rng.integers(-32767, 32768, (n, 63))
        ac[rng.random((n, 63)) < rng.uniform(0.2, 0.995)] = 0
        dc = rng.integers(-32767, 32768, (n,))
        cases.append((f"random_{t}", dc, ac))
    cases += [(f"adversarial_{i}", dc, ac)
              for i, (dc, ac) in enumerate(adversarial_blocks())]

    def outcome(fn, *args, **kw):
        try:
            dc_o, ac_o = fn(*args, **kw)
            return ("ok", dc_o.tobytes(), ac_o.tobytes())
        except (bitio.TruncatedStream, ValueError) as e:
            return (type(e).__name__, str(e))

    backends = [
        ("staged", lambda p, n, d, a: uref.unpack_bits_ref(p, n, d, a)),
        ("staged_tiled", lambda p, n, d, a: uref.unpack_bits_ref(
            p, n, d, a, tile_bits=64)),
        ("pallas", lambda p, n, d, a: ub.unpack_bits(
            p, n, d, a, backend="pallas", interpret=True)),
    ]
    bad = []
    for name, dc, ac in cases:
        syms = rle.symbolize(dc, ac)
        dc_f, ac_f = rle.symbol_frequencies(syms[0], syms[1])
        dc_t = huffman.build_table(dc_f)
        ac_t = huffman.build_table(ac_f)
        payload = rle.encode_payload(*syms, dc_t, ac_t)
        want = outcome(rle.decode_payload_reference, payload, len(dc),
                       dc_t, ac_t)
        for bname, fn in backends:
            if outcome(fn, payload, len(dc), dc_t, ac_t) != want:
                bad.append(f"{name}: {bname} decode mismatch vs reference")
        # truncated prefixes must fail identically to the LUT walk
        # (the shipped backend): same error class, same bit offset
        for cut in (0, len(payload) // 2, len(payload) - 1):
            want = outcome(rle.decode_payload, payload[:cut], len(dc),
                           dc_t, ac_t)
            for bname, fn in backends:
                if outcome(fn, payload[:cut], len(dc), dc_t, ac_t) != want:
                    bad.append(f"{name}: {bname} truncation at byte "
                               f"{cut} not rejected identically")

    # whole-stream check: the routed unpacker must reproduce the
    # default decode of DCTZ containers under every table policy
    c = codec.compress(images.lena_like(32, 32), QUALITY)
    unpacker = ub.make_unpacker(backend="pallas", interpret=True)
    for tables in ("auto", "embedded", "shared"):
        stream = entropy.encode_qcoeffs(c.qcoeffs, QUALITY, "exact",
                                        (32, 32), tables=tables)
        want_z, want_hdr = entropy.decode_zigzag_host(stream)
        got_z, got_hdr = entropy.decode_zigzag_host(stream,
                                                    unpacker=unpacker)
        if not (np.array_equal(want_z, got_z) and want_hdr == got_hdr):
            bad.append(f"stream_{tables}: routed unpack stream mismatch")
    return bad


def symbolize_identity_violations(seed: int = 0, trials: int = 25) -> list:
    """Cases where a routed symbolize backend diverges from the scalar
    oracle — the symbolisation third of the ``--check-identical`` CI
    gate (must return []).

    Checks, per case, that the staged dense NumPy pass
    (:func:`repro.kernels.symbolize.ref.symbolize_ref`) and the Pallas
    kernel (interpret mode off-TPU) produce symbol streams element- and
    dtype-identical to
    :func:`repro.core.entropy.rle.symbolize_reference`, histograms
    bit-identical to :func:`repro.core.entropy.rle.symbol_frequencies`,
    and payload bytes identical to the scalar path, over ``trials``
    random batches plus the :func:`adversarial_blocks`; that levels too
    wide for a 15-bit amplitude are rejected with the oracle's exact
    :class:`repro.core.entropy.rle.RangeError` message on every
    backend; and that whole ``DCTZ`` streams framed through each routed
    symbolizer (v1 embedded-table and v2 shared/auto-negotiated framing
    alike) are byte-identical to the default path.
    """
    from repro.core import entropy
    from repro.core.entropy import huffman, rle
    from repro.kernels import symbolize as sy
    from repro.kernels.symbolize import ref as sref
    rng = np.random.default_rng(seed)
    cases = []
    for t in range(trials):
        n = int(rng.integers(1, 24))
        ac = rng.integers(-32767, 32768, (n, 63))
        ac[rng.random((n, 63)) < rng.uniform(0.2, 0.995)] = 0
        dc = rng.integers(-32767, 32768, (n,))
        cases.append((f"random_{t}", dc, ac))
    cases += [(f"adversarial_{i}", dc, ac)
              for i, (dc, ac) in enumerate(adversarial_blocks())]

    backends = [
        ("staged", lambda d, a: sref.symbolize_ref(d, a)),
        ("pallas", lambda d, a: sy.symbolize(d, a, backend="pallas",
                                             interpret=True)),
    ]
    preps = [(bname, sy.make_symbolizer(bname, interpret=True))
             for bname in ("numpy", "pallas")]
    bad = []
    for name, dc, ac in cases:
        want = rle.symbolize_reference(dc, ac)
        for bname, fn in backends:
            got = fn(dc, ac)
            if not all(np.array_equal(a, b) and a.dtype == b.dtype
                       for a, b in zip(got, want)):
                bad.append(f"{name}: {bname} symbol stream mismatch")
        dc_f, ac_f = rle.symbol_frequencies(want[0], want[1])
        dc_t, ac_t = (huffman.build_table(dc_f), huffman.build_table(ac_f))
        want_payload = rle.encode_payload(*want, dc_t, ac_t)
        for bname, prepare in preps:
            prep = prepare(dc, ac)
            if not (np.array_equal(prep.dc_freq, dc_f)
                    and np.array_equal(prep.ac_freq, ac_f)):
                bad.append(f"{name}: {bname} histogram mismatch")
                continue
            if prep.payload(dc_t, ac_t) != want_payload:
                bad.append(f"{name}: {bname} payload bytes mismatch")

    # out-of-range levels must raise the oracle's exact RangeError on
    # every backend (the device guard routes them to the reference)
    def outcome(fn):
        try:
            fn()
            return None
        except rle.RangeError as e:
            return str(e)

    for rname, dc, ac in [
            ("dc_overflow", np.array([1 << 15]), np.zeros((1, 63))),
            ("ac_overflow", np.array([0]),
             np.eye(1, 63, 5, dtype=np.int64) * (1 << 15))]:
        want_err = outcome(lambda: rle.symbolize_reference(dc, ac))
        for bname, fn in backends:
            if outcome(lambda: fn(dc, ac)) != want_err:
                bad.append(f"{rname}: {bname} RangeError mismatch")
        for bname, prepare in preps:
            if outcome(lambda: prepare(dc, ac)) != want_err:
                bad.append(f"{rname}: {bname} prepared RangeError mismatch")

    # whole-stream check: each routed symbolizer must frame identical
    # DCTZ containers under every table policy (v1 embedded framing and
    # v2 shared/auto-negotiated framing, from the device histograms)
    c = codec.compress(images.lena_like(32, 32), QUALITY)
    for tables in ("auto", "embedded", "shared"):
        want_s = entropy.encode_qcoeffs(c.qcoeffs, QUALITY, "exact",
                                        (32, 32), tables=tables)
        for bname, prepare in preps:
            got_s = entropy.encode_qcoeffs(c.qcoeffs, QUALITY, "exact",
                                           (32, 32), tables=tables,
                                           symbolizer=prepare)
            if got_s != want_s:
                bad.append(f"stream_{tables}: routed {bname} "
                           f"symbolizer stream mismatch")
    return bad


ENTROPY_DECODE_GRID = {
    "smoke": {"sizes": [64, 128]},
    "paper": {"sizes": [128, 256]},
    "full": {"sizes": [256, 512]},
}


def entropy_decode_points(sizes, warmup: int, iters: int) -> list:
    """Measured records for the ``entropy_decode`` case.

    One record per image size, timing the same payload through every
    decode backend: the PR 3 scalar ``decode_payload_reference``, the
    PR 4 LUT walk (``decode_payload``), the staged speculative NumPy
    decode and the Pallas kernel in interpret mode (a correctness
    vehicle off-TPU, reported but not scored).  Two sizes per suite
    make the memory metrics comparable across payload lengths: the
    walk's decode tables (``walk_table_nbytes``) grow with every
    payload bit while the staged decoder's per-tile scratch
    (``staged_scratch_nbytes``) saturates at one tile + margin.

    Shared by the registry case and
    ``benchmarks/bench_entropy_throughput.py``.
    """
    from repro.core.entropy import rle
    from repro.kernels import unpack_bits as ub
    from repro.kernels.unpack_bits import ref as uref

    records = []
    for size in sizes:
        (z, dc_diff, ac, payload, (dc_t, ac_t),
         n_blocks) = _entropy_stage_inputs(size)
        nbits = len(payload) * 8
        t_ref = measure(rle.decode_payload_reference, payload, n_blocks,
                        dc_t, ac_t, warmup=min(warmup, 1),
                        iters=max(iters // 2, 2))
        t_walk = measure(rle.decode_payload, payload, n_blocks, dc_t,
                         ac_t, warmup=warmup, iters=iters)
        t_staged = measure(uref.unpack_bits_ref, payload, n_blocks, dc_t,
                           ac_t, warmup=warmup, iters=iters)
        t_pallas = measure(
            lambda: ub.unpack_bits(payload, n_blocks, dc_t, ac_t,
                                   backend="pallas", interpret=True),
            warmup=min(warmup, 1), iters=max(iters // 2, 2))
        records.append(BenchRecord(
            label=f"entropy_decode_{size}",
            params={"height": size, "width": size, "image": "lena",
                    "quality": QUALITY, "n_blocks": n_blocks,
                    "payload_nbits": nbits},
            timings_us={"dec_reference": t_ref.to_json(),
                        "dec_lut_walk": t_walk.to_json(),
                        "dec_staged": t_staged.to_json(),
                        "dec_pallas_interpret": t_pallas.to_json()},
            metrics={
                "staged_speedup_vs_reference":
                    t_ref.median_us / t_staged.median_us,
                "staged_speedup_vs_walk":
                    t_walk.median_us / t_staged.median_us,
                "dec_mb_per_s": (size * size / 1e6)
                    / (t_staged.median_us / 1e6),
                "walk_table_nbytes": rle.walk_table_nbytes(nbits),
                "staged_scratch_nbytes": uref.scratch_nbytes(nbits),
                "scratch_vs_walk":
                    uref.scratch_nbytes(nbits)
                    / rle.walk_table_nbytes(nbits),
            }))
    return records


@benchmark("entropy_decode", suites=("smoke", "paper", "full"),
           description="staged speculative decode vs scalar reference + "
                       "bounded decoder scratch vs per-bit LUT walk")
def entropy_decode(ctx: RunContext) -> list:
    """Decode-side counterpart of ``entropy_throughput``: the staged
    speculative decoder vs the scalar reference and the LUT walk on one
    payload per size, plus the decoder-memory metrics the unpack_bits
    design bounds (per-tile scratch, not per-payload-bit tables)."""
    grid = ENTROPY_DECODE_GRID.get(ctx.suite, ENTROPY_DECODE_GRID["paper"])
    timer = ctx.timer.scaled(warmup=max(ctx.timer.warmup, 1))
    return entropy_decode_points(grid["sizes"], warmup=timer.warmup,
                                 iters=timer.iters)


@benchmark("entropy_throughput", suites=("smoke", "paper", "full"),
           description="vectorized vs reference entropy coding MB/s + "
                       "overlapped encode_batch/decode_batch scaling")
def entropy_throughput(ctx: RunContext) -> list:
    """Host entropy stage in isolation (vectorized vs the PR 3 scalar
    reference) plus the engine's overlapped byte path across batch
    sizes; ``speedup_vs_reference`` scores the whole pipeline against
    the single-image reference encode rate."""
    grid = ENTROPY_GRID.get(ctx.suite, ENTROPY_GRID["paper"])
    timer = ctx.timer.scaled(warmup=max(ctx.timer.warmup, 1))
    return entropy_throughput_points(grid["size"], grid["batches"],
                                     warmup=timer.warmup,
                                     iters=timer.iters)


# ---------------------------------------------------------------------------
# Serving-layer coverage
# ---------------------------------------------------------------------------

def batch_throughput_grid(transforms, size: int, batches, iters: int) -> dict:
    """Best-of-N images/sec per (transform, batch) via the serving engine.

    The N timing rounds are *interleaved* across batch sizes so machine-
    load drift (shared CI runners) biases every batch size equally
    instead of whichever one it happened to land on.

    Args:
        transforms: iterable of codec transforms ("exact", "cordic", ...).
        size: square image side per batch element.
        batches: increasing batch sizes to sweep.
        iters: timing rounds per (transform, batch) point.

    Returns:
        transform -> {batch: img_per_s} with the best round kept.
    """
    from repro.serve import codec_engine
    batches = list(batches)
    base = np.stack([images.lena_like(size, size, seed=i)
                     for i in range(max(batches))])
    out = {}
    for transform in transforms:
        def run(x, transform=transform):
            rec, _ = codec_engine.roundtrip_batch(x, QUALITY, transform,
                                                  with_psnr=False)
            return rec

        best = {b: float("inf") for b in batches}
        for b in batches:                       # compile + warm every shape
            for _ in range(2):
                jax.block_until_ready(run(base[:b]))
        for _ in range(iters):
            for b in batches:
                t0 = time.perf_counter()
                jax.block_until_ready(run(base[:b]))
                best[b] = min(best[b], time.perf_counter() - t0)
        out[transform] = {b: b / best[b] for b in batches}
    return out


def check_monotone(per_batch: dict, up_to: int = 64) -> list:
    """Violations of strictly-increasing throughput for batches <= up_to.

    Args:
        per_batch: {batch: img_per_s} as one value of
            :func:`batch_throughput_grid`'s result.
        up_to: largest batch size the monotonicity claim covers (beyond
            it the backend may saturate).

    Returns:
        (smaller_batch, larger_batch) pairs where throughput did not grow.
    """
    checked = sorted(b for b in per_batch if b <= up_to)
    return [(a, b) for a, b in zip(checked, checked[1:])
            if per_batch[b] <= per_batch[a]]


@benchmark("serve_batch_throughput", suites=("smoke", "paper", "full"),
           description="images/sec vs batch size through codec_engine")
def serve_batch_throughput(ctx: RunContext) -> list:
    batches = batch_sizes(BATCH_GRID.get(ctx.suite, BATCH_GRID["paper"]))
    iters = {"smoke": 3, "paper": 8}.get(ctx.suite, 15)
    size = 8    # the paper's atomic block: dispatch overhead dominates,
    #             which is exactly what batching amortises
    grid = batch_throughput_grid(("exact", "cordic"), size, batches, iters)
    return [BenchRecord(
        label=f"batch_{b}",
        params={"batch": b, "size": size, "quality": QUALITY},
        metrics={f"img_per_s_{t}": grid[t][b] for t in grid})
        for b in batches]


RAGGED_SHAPES = {
    "smoke": [(200, 200), (96, 80), (200, 200)],
    "paper": [(200, 200), (320, 288), (512, 480), (96, 80), (64, 48),
              (200, 200), (1024, 814)],
}
RAGGED_SHAPES["full"] = RAGGED_SHAPES["paper"]


@benchmark("serve_ragged", suites=("smoke", "paper", "full"),
           description="ragged mixed-size batch through codec_engine "
                       "bucketing")
def serve_ragged(ctx: RunContext) -> list:
    """Mixed-size list in one call: bucketed shapes, grouped compilation."""
    from repro.serve import codec_engine
    shapes = RAGGED_SHAPES.get(ctx.suite, RAGGED_SHAPES["paper"])
    imgs = [images.lena_like(h, w, seed=i)
            for i, (h, w) in enumerate(shapes)]
    cb = codec_engine.compress_batch(imgs, QUALITY, "exact")
    n_buckets = len(cb.groups)

    def run():
        rec, _ = codec_engine.roundtrip_batch(imgs, QUALITY, "exact",
                                              with_psnr=False)
        return rec

    t = measure(run, warmup=max(ctx.timer.warmup, 1), iters=ctx.timer.iters)
    return [BenchRecord(
        label=f"ragged_{len(imgs)}imgs",
        params={"n_images": len(imgs), "quality": QUALITY,
                "shapes": [list(s) for s in shapes],
                "bucket": codec_engine.SHAPE_BUCKET},
        timings_us={"roundtrip": t.to_json()},
        metrics={"n_buckets": n_buckets,
                 "img_per_s": len(imgs) / (t.median_us / 1e6)})]


SERVICE_TRAFFIC_GRID = {
    "smoke": {"size": 48, "n_requests": 60, "loads": (0.5, 1.0, 2.0)},
    "paper": {"size": 64, "n_requests": 150, "loads": (0.5, 1.0, 2.0)},
    "full": {"size": 64, "n_requests": 300,
             "loads": (0.25, 0.5, 1.0, 2.0, 4.0)},
}

TRAFFIC_QUALITIES = (30, 75)


def _traffic_pool(size: int, variants: int = 6) -> list:
    """Mixed-size image pool; reuse across requests exercises the cache."""
    pool = []
    for i in range(variants):
        gen = images.lena_like if i % 2 == 0 else images.cablecar_like
        h = size - 8 * (i % 2)
        w = size - 6 * (i % 3)
        pool.append(np.asarray(gen(h, w, seed=i)))
    return pool


def calibrate_service_step(pool, max_batch: int) -> float:
    """Measured seconds for one full engine batch (per-level capacity).

    Warms every (shape bucket, quality) combination the traffic will
    hit (compile time must not pollute latency percentiles), then times
    a full ``max_batch`` encode — the model step the offered-load
    multiples are expressed against.
    """
    from repro.serve import codec_engine
    # adaptive batching can dispatch ANY batch size 1..max_batch, and
    # first calls at a new size still compile (beyond the engine's pow2
    # batch padding, the entropy edge specialises further) — a cold
    # compile landing in the bucket EWMA would poison admission for the
    # whole run, so warm every (size, quality) combination
    for b in range(1, max_batch + 1):
        batch = [pool[i % len(pool)] for i in range(b)]
        for q in TRAFFIC_QUALITIES:
            codec_engine.encode_batch(batch, q)
    batch = [pool[i % len(pool)] for i in range(max_batch)]
    t0 = time.perf_counter()
    codec_engine.encode_batch(batch, TRAFFIC_QUALITIES[0])
    return time.perf_counter() - t0


def service_traffic_points(size: int, n_requests: int, loads,
                           max_batch: int = 8, seed: int = 0) -> list:
    """Open-loop Poisson traffic through :class:`CodecService`.

    Arrivals are scheduled at precomputed absolute times, independent
    of completions — the standard open-loop methodology for offered
    load/goodput curves, where clients must keep offering load even
    when the service falls behind (a closed-loop client would slow
    down with the server and never drive it past saturation).

    For each offered-load level (a multiple of the measured engine
    capacity ``max_batch / step_s``), a fresh service is driven with
    ``n_requests`` Poisson arrivals of mixed sizes and qualities under
    a deadline of ``8 x step_s``, and the record reports the SLO view:
    p50/p99 client latency, goodput (served within deadline per
    second), reject rate by admission reason, cache hit rate, and the
    batch-occupancy histogram (how full dispatched engine batches ran).

    Shared by the ``service_traffic`` registry case and
    ``benchmarks/bench_service_traffic.py`` (whose ``--check`` gates
    outcome conservation in CI).
    """
    import asyncio

    from repro.serve.admission import RejectedError
    from repro.serve.service import (CodecService, EngineFailure,
                                     ServiceConfig)

    pool = _traffic_pool(size)
    step_s = calibrate_service_step(pool, max_batch)
    capacity_rps = max_batch / step_s
    deadline_s = 8 * step_s
    cfg_kw = dict(max_batch=max_batch,
                  max_wait_s=min(max(step_s / 2, 0.001), 0.05),
                  max_queue_depth=4 * max_batch,
                  initial_step_s=step_s,
                  default_deadline_s=deadline_s)

    async def run_level(offered_rps: float, rng) -> tuple:
        arrivals = np.cumsum(rng.exponential(1.0 / offered_rps,
                                             n_requests))
        outcomes: list = []

        async def one(at: float, img, quality: int):
            await asyncio.sleep(at)
            t0 = time.perf_counter()
            try:
                resp = await svc.submit(img, quality=quality)
                outcomes.append(("served", time.perf_counter() - t0,
                                 resp.deadline_missed, resp.cache_hit))
            except RejectedError as exc:
                outcomes.append((f"rejected:{exc.reason}",
                                 time.perf_counter() - t0, False, False))
            except EngineFailure:
                outcomes.append(("failed", time.perf_counter() - t0,
                                 False, False))

        async with CodecService(ServiceConfig(**cfg_kw)) as svc:
            t_start = time.perf_counter()
            await asyncio.gather(*[
                one(float(arrivals[i]),
                    pool[int(rng.integers(len(pool)))],
                    TRAFFIC_QUALITIES[int(rng.integers(
                        len(TRAFFIC_QUALITIES)))])
                for i in range(n_requests)])
            makespan = time.perf_counter() - t_start
        return outcomes, makespan, svc.stats

    records = []
    for load in loads:
        rng = np.random.default_rng(seed)
        offered = load * capacity_rps
        outcomes, makespan, stats = asyncio.run(run_level(offered, rng))
        served = [o for o in outcomes if o[0] == "served"]
        lat_ms = sorted(o[1] * 1e3 for o in served)
        in_deadline = sum(1 for o in served if not o[2])
        rejects = [o for o in outcomes if o[0].startswith("rejected:")]

        def pct(p):
            if not lat_ms:
                return float("nan")
            return lat_ms[min(len(lat_ms) - 1,
                              round(p / 100 * (len(lat_ms) - 1)))]

        records.append(BenchRecord(
            label=f"load_{load:g}x",
            params={"offered_load": load, "offered_rps": offered,
                    "capacity_rps": capacity_rps,
                    "step_ms": step_s * 1e3,
                    "deadline_ms": deadline_s * 1e3,
                    "n_requests": n_requests, "size": size,
                    "max_batch": max_batch,
                    "qualities": list(TRAFFIC_QUALITIES),
                    "occupancy": {str(k): v for k, v in
                                  sorted(stats.occupancy.items())},
                    "rejected_by_reason": dict(stats.rejected)},
            metrics={
                "p50_ms": pct(50),
                "p99_ms": pct(99),
                "goodput_rps": in_deadline / makespan,
                "reject_rate": len(rejects) / n_requests,
                "served": float(len(served)),
                "deadline_missed": float(stats.deadline_missed),
                "failed": float(stats.failed),
                "cache_hit_rate": (sum(1 for o in served if o[3])
                                   / max(len(served), 1)),
                "mean_batch_occupancy": (
                    sum(k * v for k, v in stats.occupancy.items())
                    / max(sum(stats.occupancy.values()), 1)),
            }))
    return records


def traffic_conservation_violations(records) -> list:
    """CI-gate checks for ``service_traffic`` records.

    Every offered request must reach exactly one terminal outcome
    (served + rejected + failed == n_requests — the bench completing at
    all already rules out a dispatch deadlock), and the occupancy
    histogram must account for every non-cache-hit served request.

    Returns:
        Human-readable violation strings (empty == gate passes).
    """
    out = []
    for rec in records:
        n = rec.params["n_requests"]
        served = rec.metrics["served"]
        rejected = rec.metrics["reject_rate"] * n
        failed = rec.metrics["failed"]
        total = served + rejected + failed
        if abs(total - n) > 1e-6:
            out.append(f"{rec.label}: {total:g} outcomes for {n} "
                       f"requests (served {served:g} + rejected "
                       f"{rejected:g} + failed {failed:g})")
        occ = sum(int(k) * v for k, v in
                  rec.params["occupancy"].items())
        hits = round(rec.metrics["cache_hit_rate"] * max(served, 1))
        if occ + hits + failed < served:
            out.append(f"{rec.label}: occupancy accounts for {occ} "
                       f"requests + {hits} cache hits < {served:g} "
                       f"served")
    return out


@benchmark("service_traffic", suites=("smoke", "paper", "full"),
           description="open-loop Poisson traffic through the async "
                       "service: p50/p99 latency, goodput, reject rate")
def service_traffic(ctx: RunContext) -> list:
    """The serving SLO view the straight-line benches cannot give:
    latency percentiles, goodput and shed load at offered loads below,
    at, and above the engine's measured capacity, through the
    deadline-aware batching service (docs/serving.md)."""
    grid = SERVICE_TRAFFIC_GRID.get(ctx.suite,
                                    SERVICE_TRAFFIC_GRID["paper"])
    return service_traffic_points(grid["size"], grid["n_requests"],
                                  grid["loads"])


SERVICE_CHAOS_GRID = {
    "smoke": {"size": 48, "n_requests": 80, "load": 1.0},
    "paper": {"size": 64, "n_requests": 160, "load": 1.0},
    "full": {"size": 64, "n_requests": 240, "load": 1.5},
}

#: Fault-kind coverage the chaos gate requires (every kind must fire).
CHAOS_FAULT_KINDS = ("fail", "latency", "corrupt", "kill")


def chaos_fault_plan(step_s: float, timeout_s: float, seed: int = 0):
    """The seeded fault storm the chaos bench replays, by call index.

    Phases are indexed by **engine-call number** (not wall time), so the
    same (plan, seed) injects the same faults regardless of scheduler
    jitter: a clean warm-up, an exception storm long enough to trip the
    breaker *and* feed its half-open probes (probes consume call
    indices, so the closed→open→half-open→closed cycle completes
    deterministically in call space), a clean recovery window, latency
    spikes past the attempt timeout, one worker death, a corruption
    burst (every payload byte-flipped — the CRC validator must catch
    all of them), then a clean tail that drains the retry backlog.
    """
    from repro.serve.chaos import FaultPhase, FaultPlan
    return FaultPlan(phases=(
        # exception storm: trips the breaker by call 3 (min_calls=4,
        # threshold 0.5); the first half-open probe lands on call 4
        # (fails, re-opens), later probes land in the clean window
        # [5, 8) and close the breaker — cycle provable in call space
        FaultPhase(start=2, stop=5, fail_rate=1.0),
        FaultPhase(start=8, stop=9, latency_rate=1.0,
                   latency_s=2.0 * timeout_s),
        FaultPhase(start=9, stop=10, kill_rate=1.0),
        FaultPhase(start=10, stop=12, corrupt_rate=1.0),
    ), seed=seed)


def service_chaos_points(size: int, n_requests: int, load: float,
                         max_batch: int = 4, seed: int = 0) -> list:
    """Open-loop Poisson traffic through a *resilient* service under a
    scripted fault storm (engine exceptions, latency spikes past the
    attempt timeout, worker death, payload byte flips).

    Same methodology as :func:`service_traffic_points` — arrivals at
    precomputed absolute times against the calibrated engine capacity —
    but the engine is wrapped in the deterministic
    :class:`repro.serve.chaos.ChaosEngine` and the service runs with
    the full resilience envelope: bounded retries, per-attempt
    timeouts, a circuit breaker, CRC payload validation
    (:func:`repro.serve.chaos.dctz_crc_ok`) and graceful degradation.

    The record carries everything :func:`chaos_violations` CI-gates:
    outcome conservation, the breaker's transition log, injected-fault
    coverage, the unhandled-exception guard counter, and byte identity
    of every served payload against serial ``encode_batch``.

    Shared by the ``service_chaos`` registry case and
    ``benchmarks/bench_service_chaos.py --check``.
    """
    import asyncio

    from repro.serve import codec_engine
    from repro.serve.admission import RejectedError
    from repro.serve.chaos import ChaosEngine, dctz_crc_ok
    from repro.serve.resilience import (BreakerConfig, DegradeConfig,
                                        ResilienceConfig, RetryPolicy)
    from repro.serve.service import (CodecService, EngineFailure,
                                     ServiceConfig)

    pool = _traffic_pool(size)
    step_s = calibrate_service_step(pool, max_batch)
    capacity_rps = max_batch / step_s
    offered_rps = load * capacity_rps
    timeout_s = max(6 * step_s, 0.05)
    deadline_s = max(24 * step_s, 5 * timeout_s)
    plan = chaos_fault_plan(step_s, timeout_s, seed=seed)

    def inner(imgs, quality):
        return codec_engine.encode_batch(list(imgs), quality)

    eng = ChaosEngine(inner, plan)
    cfg = ServiceConfig(
        max_batch=max_batch,
        max_wait_s=min(max(step_s / 2, 0.001), 0.05),
        max_queue_depth=4 * max_batch,
        initial_step_s=step_s,
        default_deadline_s=deadline_s,
        # the traffic reuses ~a dozen (image, quality) pairs — a warm
        # cache would absorb nearly every request and starve the fault
        # phases of engine calls, so the chaos run disables it
        cache_entries=0,
        # a timed-out attempt abandons its worker thread until the
        # engine returns; a second worker keeps the service moving
        # through the latency-spike phase
        engine_concurrency=2,
        resilience=ResilienceConfig(
            timeout_s=timeout_s,
            retry=RetryPolicy(max_attempts=3,
                              backoff_base_s=step_s / 4,
                              backoff_cap_s=2 * step_s,
                              budget_rate=2 * offered_rps,
                              budget_burst=2 * max_batch * 4),
            breaker=BreakerConfig(window=8, min_calls=4,
                                  failure_threshold=0.5,
                                  reset_timeout_s=2 * step_s,
                                  half_open_max_calls=1,
                                  half_open_successes=2),
            # level-1 cap = 30, already in TRAFFIC_QUALITIES: degraded
            # encodes hit warm compilations only
            degrade=DegradeConfig(quality_caps=(100, 30),
                                  urgent_batch_caps=(None, 2),
                                  enter_pressure=0.85,
                                  exit_pressure=0.3,
                                  sustain_s=step_s,
                                  cool_s=4 * step_s),
            validate_payload=dctz_crc_ok,
            seed=seed))

    async def run_storm(rng) -> tuple:
        arrivals = np.cumsum(rng.exponential(1.0 / offered_rps,
                                             n_requests))
        outcomes: list = []
        served_payloads: list = []      # (pool_idx, quality, payload)

        async def one(at: float, pool_idx: int, quality: int):
            await asyncio.sleep(at)
            t0 = time.perf_counter()
            try:
                resp = await svc.submit(pool[pool_idx], quality=quality)
                outcomes.append(("served", time.perf_counter() - t0,
                                 resp.deadline_missed))
                served_payloads.append((pool_idx, resp.quality,
                                        resp.payload))
            except RejectedError as exc:
                outcomes.append((f"rejected:{exc.reason}",
                                 time.perf_counter() - t0, False))
            except EngineFailure:
                outcomes.append(("failed", time.perf_counter() - t0,
                                 False))

        async with CodecService(cfg, engine=eng) as svc:
            t_start = time.perf_counter()
            await asyncio.gather(*[
                one(float(arrivals[i]),
                    int(rng.integers(len(pool))),
                    TRAFFIC_QUALITIES[int(rng.integers(
                        len(TRAFFIC_QUALITIES)))])
                for i in range(n_requests)])
            makespan = time.perf_counter() - t_start
        return outcomes, served_payloads, makespan, svc

    rng = np.random.default_rng(seed)
    outcomes, served_payloads, makespan, svc = asyncio.run(
        run_storm(rng))
    stats = svc.stats

    # byte identity: every successfully served payload must match the
    # serial single-image encode exactly — resilience may delay or shed
    # work, never alter it
    byte_mismatches = 0
    reference: dict = {}
    for pool_idx, quality, payload in served_payloads:
        k = (pool_idx, quality)
        if k not in reference:
            reference[k] = inner([pool[pool_idx]], quality)[0]
        if payload != reference[k]:
            byte_mismatches += 1

    served = [o for o in outcomes if o[0] == "served"]
    lat_ms = sorted(o[1] * 1e3 for o in served)
    in_deadline = sum(1 for o in served if not o[2])
    rejects = [o for o in outcomes if o[0].startswith("rejected:")]

    def pct(p):
        if not lat_ms:
            return float("nan")
        return lat_ms[min(len(lat_ms) - 1,
                          round(p / 100 * (len(lat_ms) - 1)))]

    transitions = [[t, frm, to] for t, frm, to in
                   svc.breaker.transitions]
    return [BenchRecord(
        label=f"storm_{load:g}x",
        params={"offered_load": load, "offered_rps": offered_rps,
                "capacity_rps": capacity_rps,
                "step_ms": step_s * 1e3,
                "timeout_ms": timeout_s * 1e3,
                "deadline_ms": deadline_s * 1e3,
                "n_requests": n_requests, "size": size,
                "max_batch": max_batch, "seed": seed,
                "qualities": list(TRAFFIC_QUALITIES),
                "engine_calls": eng.calls,
                "fault_events": eng.event_counts(),
                "breaker_transitions": transitions,
                "rejected_by_reason": dict(stats.rejected),
                "dispatcher_ok": svc.dispatcher_error is None},
        metrics={
            "p50_ms": pct(50),
            "p99_ms": pct(99),
            "goodput_rps": in_deadline / makespan,
            "served": float(len(served)),
            "reject_rate": len(rejects) / n_requests,
            "failed": float(stats.failed),
            "retries": float(stats.retries),
            "retry_rate": stats.retries / n_requests,
            "timeouts": float(stats.timeouts),
            "corrupt_caught": float(stats.corrupt_payloads),
            "degraded_served": float(stats.degraded_served),
            "closed_unserved": float(stats.closed_unserved),
            "unhandled": float(stats.unhandled),
            "byte_mismatches": float(byte_mismatches),
        })]


def chaos_violations(records) -> list:
    """CI-gate checks for ``service_chaos`` records.

    The resilience acceptance criteria, checked per record: outcome
    conservation (served + rejected + failed == n_requests, degraded ⊆
    served), zero byte mismatches against serial encode, zero unhandled
    exceptions escaping the dispatch loop, a live dispatcher at close,
    a provable closed→open→half-open→closed breaker cycle, and every
    scripted fault kind having actually fired.

    Returns:
        Human-readable violation strings (empty == gate passes).
    """
    out = []
    for rec in records:
        n = rec.params["n_requests"]
        served = rec.metrics["served"]
        rejected = rec.metrics["reject_rate"] * n
        failed = rec.metrics["failed"]
        total = served + rejected + failed
        if abs(total - n) > 1e-6:
            out.append(f"{rec.label}: {total:g} outcomes for {n} "
                       f"requests (served {served:g} + rejected "
                       f"{rejected:g} + failed {failed:g})")
        if rec.metrics["degraded_served"] > served:
            out.append(f"{rec.label}: degraded_served "
                       f"{rec.metrics['degraded_served']:g} exceeds "
                       f"served {served:g}")
        if rec.metrics["byte_mismatches"]:
            out.append(f"{rec.label}: "
                       f"{rec.metrics['byte_mismatches']:g} served "
                       f"payloads differ from serial encode_batch")
        if rec.metrics["unhandled"]:
            out.append(f"{rec.label}: {rec.metrics['unhandled']:g} "
                       f"unhandled exceptions escaped batch handling")
        if not rec.params["dispatcher_ok"]:
            out.append(f"{rec.label}: dispatcher crashed during the run")
        if rec.metrics["closed_unserved"]:
            out.append(f"{rec.label}: "
                       f"{rec.metrics['closed_unserved']:g} futures "
                       f"dangling at close")
        cycle = ["closed", "open", "half_open", "closed"]
        trans = rec.params["breaker_transitions"]
        # the visited-state sequence: every from-state plus the final
        # to-state; the required cycle must appear as a subsequence
        states = [frm for _, frm, _ in trans]
        if trans:
            states.append(trans[-1][2])
        i = 0
        for s in states:
            if i < len(cycle) and s == cycle[i]:
                i += 1
        if i < len(cycle):
            out.append(f"{rec.label}: breaker never completed the "
                       f"closed→open→half-open→closed cycle "
                       f"(transitions: "
                       f"{rec.params['breaker_transitions']})")
        fired = rec.params["fault_events"]
        for kind in CHAOS_FAULT_KINDS:
            if not fired.get(kind):
                out.append(f"{rec.label}: scripted fault kind "
                           f"{kind!r} never fired "
                           f"({rec.params['engine_calls']} engine "
                           f"calls)")
    return out


@benchmark("service_chaos", suites=("smoke", "paper", "full"),
           description="seeded fault storm through the resilient "
                       "service: goodput, retry rate, breaker cycle, "
                       "byte-identical payloads")
def service_chaos(ctx: RunContext) -> list:
    """The failure-mode view the clean traffic bench cannot give: how
    goodput, latency and shed load behave through an engine exception
    storm, timeout-tripping latency spikes, a worker death and a
    payload-corruption burst — with retries, circuit breaking, CRC
    validation and graceful degradation turned on (docs/serving.md)."""
    grid = SERVICE_CHAOS_GRID.get(ctx.suite, SERVICE_CHAOS_GRID["paper"])
    return service_chaos_points(grid["size"], grid["n_requests"],
                                grid["load"])


# ---------------------------------------------------------------------------
# Framework micro-benches (suite "micro"; also in --full runs)
# ---------------------------------------------------------------------------

@benchmark("framework_micro", suites=("micro", "full"),
           description="fusion win, grad/KV DCT compression, decode step")
def framework_micro(ctx: RunContext) -> list:
    """Micro-benches of the framework pieces built around the codec."""
    import functools

    from repro.kernels import grad_dct

    records = []

    # --- fusion: unfused 3-pass (paper's kernel structure) vs fused 1-pass
    img = jnp.asarray(images.lena_like(1024, 1024), jnp.float32)
    q = quant.qtable(QUALITY)

    @jax.jit
    def unfused(img):
        x = img - 128.0
        coef = dct.blockwise_dct2d_kron(x)          # pass 1 (DCT kernel)
        qc = jnp.round(coef / q) * q                # pass 2 (quantiser)
        return dct.blockwise_idct2d_kron(qc) + 128  # pass 3 (IDCT kernel)

    @jax.jit
    def fused(img):
        x = img - 128.0
        t = dct.kron_dct_matrix(8)
        blocks = dct.to_blocks(x).reshape(-1, 64)
        coef = blocks @ t.T
        qv = q.reshape(64)
        qc = jnp.round(coef / qv) * qv
        rec = (qc @ t).reshape(128, 128, 8, 8)
        return dct.from_blocks(rec) + 128.0

    t_u = measure(unfused, img, warmup=1, iters=5)
    t_f = measure(fused, img, warmup=1, iters=5)
    records.append(BenchRecord(
        label="fused_codec_1024",
        params={"height": 1024, "width": 1024, "quality": QUALITY},
        timings_us={"fused": t_f.to_json(), "unfused": t_u.to_json()},
        metrics={"fusion_speedup": t_u.median_us / t_f.median_us}))

    # --- gradient DCT compression roundtrip
    g = jax.random.normal(jax.random.key(0), (4 * 1024 * 1024,))
    fn = jax.jit(functools.partial(grad_dct.roundtrip, keep=16,
                                   interpret=True))
    t_g = measure(fn, g, warmup=1, iters=3)
    cg = grad_dct.encode(g, keep=16)
    mb = g.size * 4 / 1e6
    records.append(BenchRecord(
        label="grad_dct_roundtrip_16MB",
        params={"elements": g.size, "keep": 16},
        timings_us={"roundtrip": t_g.to_json()},
        metrics={"mb_per_s": mb / (t_g.median_us / 1e6),
                 "wire_ratio": g.size * 4 / cg.wire_bytes()}))

    # --- KV-cache DCT compression roundtrip
    from repro.serve import kv_compress
    cache = {"k": jax.random.normal(jax.random.key(1),
                                    (4, 2, 512, 4, 32), jnp.bfloat16),
             "v": jax.random.normal(jax.random.key(2),
                                    (4, 2, 512, 4, 32), jnp.bfloat16)}
    raw = sum(v.size * v.dtype.itemsize for v in cache.values())

    def kv_roundtrip(c):
        ckv, tails = kv_compress.compress_cache(c, keep=16, prefix_len=512)
        return kv_compress.reconstruct_cache(ckv, tails)

    t_kv = measure(kv_roundtrip, cache, warmup=1, iters=3)
    ckv, tails = kv_compress.compress_cache(cache, keep=16, prefix_len=512)
    comp = kv_compress.wire_bytes(ckv, tails)
    records.append(BenchRecord(
        label="kv_dct_roundtrip",
        params={"keep": 16, "prefix_len": 512},
        timings_us={"roundtrip": t_kv.to_json()},
        metrics={"hbm_ratio": raw / comp}))

    # --- LM decode-step throughput (reduced config)
    from repro.configs import registry as R
    from repro.models import registry as M
    from repro.serve import engine
    cfg = R.reduced("smollm-360m", n_layers=4, d_model=128, vocab_size=1024)
    params = M.init_params(cfg, jax.random.key(0))
    cache = M.init_cache(cfg, batch=8, max_len=256)
    step = engine.make_decode_step(cfg)
    tok = jnp.zeros((8, 1), jnp.int32)
    key = jax.random.key(0)
    fn = lambda: step(params, tok, cache, jnp.asarray(128, jnp.int32), key)
    t_d = measure(fn, warmup=2, iters=5)
    records.append(BenchRecord(
        label="decode_step_b8_reduced",
        params={"batch": 8, "n_layers": 4, "d_model": 128},
        timings_us={"step": t_d.to_json()},
        metrics={"tok_per_s": 8 / (t_d.median_us / 1e6)}))
    return records


# ---------------------------------------------------------------------------
# Codec-kernel roofline: achieved FLOP/s and bytes/s vs documented peaks
# ---------------------------------------------------------------------------

ROOFLINE_GRID = {
    "smoke": {"size": 64, "entropy_size": 48},
    "paper": {"size": 256, "entropy_size": 128},
    "full": {"size": 512, "entropy_size": 256},
}


def kernel_cost_terms(fn, *args) -> tuple:
    """(flops, bytes_accessed) from XLA's lowered cost analysis of ``fn``.

    ``cost_analysis()`` returns a dict on newer jax and a one-element
    list of dicts on 0.4.x CPU; both forms are handled.  Missing terms
    count as zero (interpret-mode Pallas bodies, for instance, report
    nothing — that is why the roofline lowers the *jnp reference*
    implementations, which XLA can fully analyse).
    """
    cost = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    cost = cost or {}
    return (float(cost.get("flops", 0.0) or 0.0),
            float(cost.get("bytes accessed", 0.0) or 0.0))


def roofline_points(size: int, entropy_size: int, warmup: int,
                    iters: int) -> list:
    """Measured records for the ``roofline`` case.

    One record per routed kernel: wall time of the *routed* call (tile
    knobs at ``None``, so the tuned-tile artifact applies when valid),
    FLOP and byte counts from XLA cost analysis of the kernel's jnp
    reference at the same shape (analytic byte counts for the two
    bit-stream kernels, whose FLOP content is ~0), and achieved
    GFLOP/s / GB/s against the documented per-chip peaks
    (:data:`repro.launch.mesh.HW` — TPU v5e terms, so off-TPU fractions
    read as a pipeline proof, not an efficiency claim).

    Shared by the registry case and ``benchmarks/roofline.py``.
    """
    from repro.core.entropy import rle
    from repro.kernels import pack_bits as pb
    from repro.kernels import unpack_bits as ub
    from repro.kernels.cordic_loeffler import ops as cl_ops
    from repro.kernels.cordic_loeffler import ref as cl_ref
    from repro.kernels.dct8x8 import ops as d_ops
    from repro.kernels.dct8x8 import ref as d_ref
    from repro.kernels.fused_codec import ops as f_ops
    from repro.kernels.fused_codec import ref as f_ref
    from repro.launch.mesh import HW

    img = jnp.asarray(images.lena_like(size, size), jnp.float32)
    f32 = img.size * 4

    points = []

    def add(kernel, run, flops, nbytes, params):
        t = measure(run, warmup=warmup, iters=iters)
        sec = t.median_us / 1e6
        achieved_flops = flops / sec
        achieved_bw = nbytes / sec
        # Ridge point: intensity above flops_peak/bw_peak is compute-bound.
        intensity = flops / nbytes if nbytes else float("inf")
        ridge = HW["peak_flops_bf16"] / HW["hbm_bw"]
        points.append(BenchRecord(
            label=kernel,
            params={"kernel": kernel, **params},
            timings_us={"routed": t.to_json()},
            metrics={
                "flops": flops,
                "bytes_accessed": nbytes,
                "achieved_gflop_s": achieved_flops / 1e9,
                "achieved_gb_s": achieved_bw / 1e9,
                "frac_peak_flops": achieved_flops / HW["peak_flops_bf16"],
                "frac_peak_bw": achieved_bw / HW["hbm_bw"],
                "intensity_flop_per_byte": intensity,
                "compute_bound": float(intensity > ridge),
            }))

    fl, by = kernel_cost_terms(d_ref.dct8x8_ref, img)
    add("dct8x8", lambda: d_ops.dct8x8(img), fl, by,
        {"height": size, "width": size})

    fl, by = kernel_cost_terms(cl_ref.cordic_loeffler_ref, img)
    add("cordic_loeffler", lambda: cl_ops.cordic_loeffler_dct(img), fl, by,
        {"height": size, "width": size})

    fl, by = kernel_cost_terms(f_ref.fused_codec_ref, img)
    add("fused_codec", lambda: f_ops.fused_codec(img), fl, by,
        {"height": size, "width": size, "quality": QUALITY})

    (_, dc_diff, ac, payload, (dc_t, ac_t),
     n_blocks) = _entropy_stage_inputs(entropy_size)
    syms = rle.symbolize(dc_diff, ac)
    from repro.core.entropy import bitio
    captured = {}

    def cap(fields, widths):
        captured["cl"] = (np.asarray(fields), np.asarray(widths))
        return bitio.pack_bits(fields, widths)

    rle.encode_payload(*syms, dc_t, ac_t, packer=cap)
    codes, lengths = captured["cl"]
    nbits = len(payload) * 8

    # The bit kernels are pure data movement: FLOP content ~0, byte
    # traffic is analytic — three int32 field columns in, payload out
    # (pack); bit windows in, three per-offset word planes out (unpack).
    pack_bytes = 3 * codes.size * 4 + len(payload)
    add("pack_bits",
        lambda: pb.pack_bits(codes, lengths, backend="pallas"),
        0.0, float(pack_bytes),
        {"entropy_size": entropy_size, "fields": int(codes.size),
         "payload_bits": nbits})

    unpack_bytes = (nbits + 1) * 4 + 3 * (nbits + 1) * 4
    add("unpack_bits",
        lambda: ub.unpack_bits(payload, n_blocks, dc_t, ac_t,
                               backend="pallas"),
        0.0, float(unpack_bytes),
        {"entropy_size": entropy_size, "payload_bits": nbits,
         "n_blocks": n_blocks})
    return points


@benchmark("roofline", suites=("smoke", "paper", "full"),
           description="per-kernel achieved FLOP/s and bytes/s from XLA "
                       "cost analysis vs documented per-chip peaks")
def roofline(ctx: RunContext) -> list:
    """Achieved-vs-peak view of every routed codec kernel: the paper's
    computational-efficiency claim expressed as roofline coordinates
    instead of speedup-vs-reference."""
    grid = ROOFLINE_GRID.get(ctx.suite, ROOFLINE_GRID["paper"])
    timer = ctx.timer.scaled(warmup=max(ctx.timer.warmup, 1))
    return roofline_points(grid["size"], grid["entropy_size"],
                           warmup=timer.warmup, iters=timer.iters)
