"""Production mesh definitions (MULTI-POD DRY-RUN spec).

``make_production_mesh`` is a function (not a module-level constant) so
importing this module never touches jax device state — device count is
locked on first jax init, and only launch/dryrun.py sets the 512-device
XLA flag.
"""

from __future__ import annotations

import jax


def _mk(shape: tuple, axes: tuple):
    # jax < 0.5 has neither jax.sharding.AxisType nor the axis_types kwarg
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod stacks 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary mesh for tests / reduced runs."""
    return _mk(shape, axes)


def make_data_mesh(n_devices: int | None = None):
    """1-D "data" mesh over the local devices (batch-sharded serving)."""
    n = n_devices if n_devices is not None else jax.local_device_count()
    return _mk((n,), ("data",))


# TPU v5e hardware model used by the roofline analysis (benchmarks/roofline).
HW = dict(
    peak_flops_bf16=197e12,     # per chip
    hbm_bw=819e9,               # bytes/s per chip
    ici_bw=50e9,                # bytes/s per link (conservative single-link)
    hbm_bytes=16e9,             # v5e HBM capacity
)
