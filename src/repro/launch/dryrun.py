import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell on
# the production mesh with ShapeDtypeStruct stand-ins (no allocation), then
# record memory_analysis / cost_analysis / the collective schedule.
# THE TWO LINES ABOVE MUST STAY FIRST: jax locks device count on first init.

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import registry as arch_registry          # noqa: E402
from repro.configs.base import SHAPES, input_specs, shape_supported  # noqa: E402
from repro.dist import sharding as sh                        # noqa: E402
from repro.launch import specs as specs_lib                  # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.models import registry as model_registry          # noqa: E402
from repro.optim import adamw                                # noqa: E402
from repro.train import step as step_lib                     # noqa: E402

# Microbatch counts for the train_4k cell, sized so activations + MoE
# dispatch buffers fit v5e HBM (EXPERIMENTS.md §Dry-run discusses).
TRAIN_MICROBATCHES = {
    "deepseek-v3-671b": 16,
    "qwen1.5-110b": 8,
    "qwen3-32b": 4,
    "qwen2.5-14b": 4,
    "qwen3-moe-30b-a3b": 4,
    "qwen2-vl-7b": 2,
    "hubert-xlarge": 2,
}

_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s16": 2, "u16": 2, "f64": 8, "s64": 8,
                "u64": 8, "c64": 8}


def parse_collectives(hlo_text: str) -> dict:
    """Per-device collective bytes by op kind from partitioned HLO text.

    Note: ops inside a scanned layer body appear once; §Roofline uses the
    compositional per-layer lowering for corrected totals (see
    benchmarks/roofline.py); this function reports the compiled artifact
    as-is for the §Dry-run record.
    """
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.groups()
        numel = 1
        for d in dims.split(","):
            if d:
                numel *= int(d)
        size = numel * _DTYPE_BYTES.get(dtype, 4)
        out[kind] = out.get(kind, 0) + size
    out["total"] = sum(v for k, v in out.items())
    return out


def build_lowerable(cfg, shape_name: str, mesh):
    """Returns (fn, args, in_shardings) for the cell's step kind."""
    info = SHAPES[shape_name]
    kind = info["kind"]
    ispecs = input_specs(cfg, shape_name)
    bsh = specs_lib.batch_shardings(cfg, ispecs, mesh)

    if kind == "train":
        micro = TRAIN_MICROBATCHES.get(cfg.name, 1)
        scfg = step_lib.TrainStepConfig(microbatches=micro)
        ocfg = adamw.AdamWConfig()
        fn = step_lib.make_train_step(cfg, ocfg, scfg)
        state = step_lib.abstract_state(cfg, ocfg, scfg)
        ssh = specs_lib.state_shardings(cfg, mesh)
        return fn, (state, ispecs), (ssh, bsh)

    psh = sh.param_shardings(model_registry.param_specs(cfg), mesh)
    pstructs = model_registry.abstract_params(cfg)

    if kind == "prefill":
        def prefill_fn(params, batch):
            logits, _, _ = model_registry.apply(cfg, params, batch,
                                                mode="prefill")
            return logits
        return prefill_fn, (pstructs, ispecs), (psh, bsh)

    # decode: one token against a filled cache
    cache = ispecs.pop("cache")
    csh = bsh.pop("cache")

    def serve_step(params, batch, cache):
        logits, new_cache, _ = model_registry.apply(cfg, params, batch,
                                                    mode="decode",
                                                    cache=cache)
        return logits, new_cache

    return serve_step, (pstructs, ispecs, cache), (psh, bsh, csh)


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    cfg = arch_registry.get(arch)
    ok, reason = shape_supported(cfg, shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = specs_lib.rules_for(cfg, shape_name)
    t0 = time.monotonic()
    with sh.use_mesh_and_rules(mesh, rules):
        fn, args, in_sh = build_lowerable(cfg, shape_name, mesh)
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
        t_lower = time.monotonic() - t0
        t0 = time.monotonic()
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    print(f"[{arch} x {shape_name} x {mesh_name}]")
    print("  memory_analysis:", mem)
    print("  cost_analysis: flops={:.3e} bytes={:.3e}".format(
        cost.get("flops", 0.0), cost.get("bytes accessed", 0.0)))
    print("  collectives:", colls)

    rec.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        microbatches=TRAIN_MICROBATCHES.get(arch, 1)
        if SHAPES[shape_name]["kind"] == "train" else 1,
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
        ),
        hlo_flops=cost.get("flops", 0.0),
        hlo_bytes=cost.get("bytes accessed", 0.0),
        collectives=colls,
    )
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="single arch id (default all)")
    ap.add_argument("--shape", default=None, help="single shape (default all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    archs = [args.arch] if args.arch else arch_registry.ARCH_NAMES
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                key = f"{arch}|{shape_name}|{'multi' if multi else 'single'}"
                if key in results and results[key].get("status") in (
                        "ok", "skipped") and not args.force:
                    continue
                try:
                    rec = run_cell(arch, shape_name, multi)
                except Exception as e:   # noqa: BLE001 — recorded, not hidden
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if multi else "16x16",
                           "status": "error", "error": str(e)[:2000]}
                    failures.append(key)
                    print(f"[{key}] ERROR: {str(e)[:300]}")
                results[key] = rec
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in results.values() if r.get("status") == "skipped")
    n_err = sum(1 for r in results.values() if r.get("status") == "error")
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
