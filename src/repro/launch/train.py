"""Training launcher.

Examples:
  # CPU-runnable reduced config, synthetic data, checkpoints + auto-resume:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
      --steps 200 --ckpt-dir /tmp/run1 --grad-compress

  # production lowering check for a full config (no execution):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --lower-only

On a real TPU pod this same entry point runs under one process per host
(jax.distributed.initialize is called when JAX_COORDINATOR is set); the
mesh/rules plumbing is identical to the dry-run's.
"""

from __future__ import annotations

import argparse
import os

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--gc-keep", type=int, default=16)
    ap.add_argument("--lower-only", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-restarts", type=int, default=0,
                    help="auto-restart from latest ckpt on crash")
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):
        jax.distributed.initialize()   # multi-host pod entry

    from repro.configs import registry as R
    from repro.data.synth import DataConfig, make_batch_fn, \
        make_encoder_batch_fn
    from repro.optim.adamw import AdamWConfig
    from repro.optim.grad_compress import GradCompressConfig
    from repro.train.step import TrainStepConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = R.reduced(args.arch) if args.reduced else R.get(args.arch)

    if args.lower_only:
        # single-device abstract lowering of the full config
        from repro.models import registry as M
        from repro.optim import adamw
        from repro.train import step as step_lib
        from repro.configs.base import input_specs
        fn = step_lib.make_train_step(cfg, adamw.AdamWConfig(),
                                      step_lib.TrainStepConfig())
        state = step_lib.abstract_state(cfg, adamw.AdamWConfig())
        specs = input_specs(cfg, "train_4k")
        lowered = jax.jit(fn).lower(state, specs)
        print(lowered.as_text()[:2000])
        print(f"[lower-only] OK: {args.arch}")
        return

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.batch, seed=args.seed)
    if cfg.input_mode == "embeds":
        batch_fn = make_encoder_batch_fn(dcfg, cfg.d_model)
    else:
        base = make_batch_fn(dcfg)
        if cfg.input_mode == "mixed":
            import jax.numpy as jnp

            def batch_fn(step):
                b = base(step)
                bsz, s = b["tokens"].shape
                b["vision_embeds"] = jnp.zeros((bsz, s, cfg.d_model),
                                               cfg.compute_dtype)
                b["vision_mask"] = jnp.zeros((bsz, s), bool)
                b["positions3"] = jnp.broadcast_to(
                    jnp.arange(s)[None, None], (3, bsz, s)).astype(jnp.int32)
                return b
        else:
            batch_fn = base

    gc = GradCompressConfig(enabled=args.grad_compress, keep=args.gc_keep)
    scfg = TrainStepConfig(microbatches=args.microbatches, grad_compress=gc)
    ocfg = AdamWConfig(lr_peak=args.lr, warmup_steps=max(args.steps // 20, 5),
                       decay_steps=args.steps)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every)

    restarts = 0
    while True:
        try:
            trainer = Trainer(cfg, ocfg, tcfg, batch_fn, step_cfg=scfg,
                              seed=args.seed)
            history = trainer.run()
            print(f"final loss: {history[-1]['loss']:.4f}")
            return
        except Exception:
            restarts += 1
            if restarts > args.max_restarts:
                raise
            print(f"[ft] crash detected; restart {restarts}/"
                  f"{args.max_restarts} from latest checkpoint")


if __name__ == "__main__":
    main()
