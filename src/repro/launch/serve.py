"""Serving launcher: batched prefill + decode with optional DCT KV compression.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --batch 4 --prompt-len 32 --max-new 32 --kv-dct-keep 16
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-dct-keep", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import registry as R
    from repro.models import registry as M
    from repro.serve import engine, kv_compress
    from repro.serve.engine import ServeConfig

    cfg = R.reduced(args.arch) if args.reduced else R.get(args.arch)
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode")
    params = M.init_params(cfg, jax.random.key(args.seed))
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    scfg = ServeConfig(max_len=args.max_len, temperature=args.temperature,
                       kv_dct_keep=args.kv_dct_keep)
    t0 = time.monotonic()
    if args.kv_dct_keep and cfg.family in ("dense", "moe", "vlm"):
        # compress the prompt's cache blocks, decode over reconstruction
        cache = M.init_cache(cfg, batch=args.batch, max_len=args.max_len)
        prefill = engine.make_prefill(cfg)
        logits, cache = prefill(params, prompts, cache)
        ckv, tails = kv_compress.compress_cache(cache, args.kv_dct_keep,
                                                args.prompt_len)
        raw = sum(v.size * v.dtype.itemsize for v in cache.values())
        comp = kv_compress.wire_bytes(ckv, tails)
        print(f"kv cache bytes: raw={raw} dct={comp} "
              f"ratio={raw/comp:.2f}x")
        cache = kv_compress.reconstruct_cache(ckv, tails)
        step_fn = engine.make_decode_step(cfg, args.temperature)
        nxt = jnp.argmax(logits.astype(jnp.float32), -1).astype(jnp.int32)
        out = [nxt]
        key = jax.random.key(args.seed)
        for i in range(args.max_new - 1):
            key, sub = jax.random.split(key)
            nxt, cache = step_fn(params, nxt[:, None], cache,
                                 jnp.asarray(args.prompt_len + i, jnp.int32),
                                 sub)
            out.append(nxt)
        tokens = jnp.stack(out, axis=1)
    else:
        tokens = engine.generate(cfg, params, prompts, args.max_new, scfg,
                                 args.seed)
    dt = time.monotonic() - t0
    total = args.batch * args.max_new
    print(f"generated {tokens.shape} in {dt:.2f}s "
          f"({total/dt:.1f} tok/s incl. compile)")
    print("sample:", tokens[0, :16].tolist())


if __name__ == "__main__":
    main()
