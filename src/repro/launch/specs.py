"""Input/state sharding spec builders for the dry-run and launchers."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, SHAPES
from repro.dist import sharding as sh
from repro.models import registry

BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "mask": ("batch", "seq"),
    "embeds": ("batch", "seq", "embed"),
    "vision_embeds": ("batch", "seq", "embed"),
    "vision_mask": ("batch", "seq"),
    "positions3": (None, "batch", "seq"),
    "cache_index": (),
}


def cache_axes(cfg: ArchConfig) -> dict:
    if cfg.use_mla:
        return {"ckv": ("layers", "batch", "cache_time", None),
                "krope": ("layers", "batch", "cache_time", None)}
    if cfg.family == "hybrid":
        return {"attn/k": (None, "batch", "cache_time", "kv_heads", "head_dim"),
                "attn/v": (None, "batch", "cache_time", "kv_heads", "head_dim"),
                "mamba/conv": ("layers", "batch", None, "mlp"),
                "mamba/ssm": ("layers", "batch", "heads", None, "state")}
    if cfg.family == "ssm":
        axes = {"m/C": ("layers", "batch", "heads", None, "mlp"),
                "m/n": ("layers", "batch", "heads", None),
                "m/m": ("layers", "batch", "heads")}
        from repro.models import xlstm
        if xlstm.n_slstm(cfg):
            axes.update({"s/h": ("layers", "batch", "mlp"),
                         "s/c": ("layers", "batch", "mlp"),
                         "s/n": ("layers", "batch", "mlp"),
                         "s/m": ("layers", "batch", "mlp")})
        return axes
    return {"k": ("layers", "batch", "cache_time", "kv_heads", "head_dim"),
            "v": ("layers", "batch", "cache_time", "kv_heads", "head_dim")}


def rules_for(cfg: ArchConfig, shape_name: str) -> dict:
    """Rule table for a cell.

    decode_32k: the KV-cache time axis shards over "model" (partial-softmax
    attention over T shards) — kv_heads (e.g. 8) rarely divides the 16-wide
    model axis, and batch alone leaves the cache 16x too big per device.
    long_500k (batch=1) flips fully to sequence parallelism: the 512k-token
    axis shards over every mesh axis (pod, data, model).
    """
    rules = dict(sh.DEFAULT_RULES)
    rules["embed"] = ("pod", "data")          # FSDP params by default
    if cfg.family == "ssm":
        # Perf iteration B (EXPERIMENTS.md §Perf): xLSTM has 4 heads and
        # odd inner dims — TP over a 16-wide axis forces GSPMD into
        # involuntary remat/reshard storms.  Pure DP over every axis +
        # FSDP over (pod, data) eliminates them: params are small (2.9B),
        # activations never cross chips.
        rules["batch"] = ("pod", "data", "model")
        rules["heads"] = None
        rules["mlp"] = None
        rules["kv_heads"] = None
    if SHAPES[shape_name]["kind"] == "decode":
        # cache TIME shards over model; intra-step "seq" (length 1) must
        # stay unsharded or GSPMD replicates downstream compute (§Perf C3)
        rules["cache_time"] = "model"
        # Perf iteration C2: serving keeps weights RESIDENT (TP over model,
        # replicated over data) — FSDP would re-all-gather every weight on
        # every decode step (measured: ~1 GB/layer/step on qwen2.5-14b).
        rules["embed"] = None
    if shape_name == "long_500k":
        rules["batch"] = None
        rules["cache_time"] = ("pod", "data", "model")
    return rules


def batch_shardings(cfg: ArchConfig, specs: dict, mesh: Mesh) -> dict:
    out = {}
    for name, struct in specs.items():
        if name == "cache":
            caxes = cache_axes(cfg)
            out[name] = {p: sh.input_sharding(struct[p].shape, caxes[p], mesh)
                         for p in struct}
        else:
            out[name] = sh.input_sharding(struct.shape, BATCH_AXES[name],
                                          mesh)
    return out


def state_shardings(cfg: ArchConfig, mesh: Mesh, with_ef: bool = False):
    pspecs = registry.param_specs(cfg)
    psh = sh.param_shardings(pspecs, mesh)
    repl = NamedSharding(mesh, P())
    out = {"params": psh,
           "opt": {"m": psh, "v": psh, "count": repl},
           "step": repl}
    if with_ef:
        out["ef"] = psh
    return out
