"""DCT gradient compression with error feedback (the paper's technique as a
distributed-optimisation feature — DESIGN.md §3.2).

Mechanics per parameter leaf:
  1. residual-corrected gradient  g' = g + ef          (error feedback)
  2. DCT-domain projection        p  = IDCT(trunc_k(DCT(g')))  [+ int8 quant]
  3. new error feedback           ef' = g' - p
The projection is exactly the grad_dct Pallas kernel's encode/decode pair,
so what the optimiser applies is bit-identical to what would cross the
interconnect.

Two integration points:
  * ``project_tree``       — in-jit projection (single-device tests, and the
                             math the cross-pod exchange implements),
  * ``dist.compressed``    — shard_map all-gather of the int8 codes over a
                             chosen mesh axis (the actual bytes saving;
                             dry-run measures it in the collective table).

Seide et al. (2014)-style error feedback keeps the method unbiased in the
long run; tests check convergence parity within tolerance on a real
training run (examples/train_lm.py --grad-compress).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels.grad_dct import ops as gd


@dataclasses.dataclass(frozen=True)
class GradCompressConfig:
    enabled: bool = False
    keep: int = 16                 # of 64 DCT coefficients
    axis: str = "pod"              # mesh axis whose traffic is compressed
    min_size: int = 4096           # leaves smaller than this stay exact

    @property
    def ratio(self) -> float:
        """wire-bytes ratio vs f32 (per 64-float block: keep int8 + 1 f32)."""
        return (self.keep * 1 + 4) / (64 * 4)


def project_leaf(g: jnp.ndarray, keep: int) -> jnp.ndarray:
    """Lossy DCT projection of one gradient leaf (any shape)."""
    flat = g.reshape(-1).astype(jnp.float32)
    proj = gd.roundtrip(flat, keep=keep)
    return proj.reshape(g.shape).astype(g.dtype)


def init_error_feedback(params: dict) -> dict:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def abstract_error_feedback(param_structs: dict) -> dict:
    return jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                        param_structs)


def project_tree(grads: dict, ef: dict, cfg: GradCompressConfig):
    """Apply EF-corrected DCT projection to every (large) leaf.

    Returns (projected_grads, new_ef).
    """
    new_g, new_ef = {}, {}
    for path, g in grads.items():
        if g.size < cfg.min_size:
            new_g[path] = g
            new_ef[path] = ef[path]
            continue
        corrected = g.astype(jnp.float32) + ef[path]
        proj = project_leaf(corrected, cfg.keep)
        new_g[path] = proj.astype(g.dtype)
        new_ef[path] = corrected - proj.astype(jnp.float32)
    return new_g, new_ef
