"""AdamW with ZeRO-sharded states and configurable state dtype.

Optimizer states inherit the parameter PartitionSpecs, so under the FSDP
rule set ("embed" -> data axis) m/v are automatically ZeRO-sharded; with
``state_dtype=bfloat16`` the optimizer-state HBM footprint halves again —
the combination is what lets the 100B+ configs fit the production mesh
(EXPERIMENTS.md §Dry-run quantifies it per arch).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32


def lr_at(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup then cosine decay to lr_min."""
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (
        1.0 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(cfg: AdamWConfig, params: dict) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def abstract_state(cfg: AdamWConfig, param_structs: dict) -> dict:
    st = lambda p: jax.ShapeDtypeStruct(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree.map(st, param_structs),
        "v": jax.tree.map(st, param_structs),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


def update(cfg: AdamWConfig, params: dict, grads: dict, state: dict,
           decay_mask: dict | None = None):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state["count"] + 1
    lr = lr_at(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v, path_decay):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * cfg.b1 + g32 * (1.0 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + g32 * g32 * (1.0 - cfg.b2)
        mh = m32 / b1c
        vh = v32 / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        if path_decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return (newp.astype(p.dtype), m32.astype(cfg.state_dtype),
                v32.astype(cfg.state_dtype))

    new_params, new_m, new_v = {}, {}, {}
    for path in params:
        # no decay on norms / biases / scalars
        dec = (decay_mask[path] if decay_mask is not None
               else params[path].ndim >= 2)
        new_params[path], new_m[path], new_v[path] = upd(
            params[path], grads[path], state["m"][path], state["v"][path],
            dec)
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
