"""optim substrate."""
