"""CORDIC plane rotations — the paper's "Cordic based" ingredient.

Sun/Heyne/Ruan/Götze (2006) replace the three plane rotations in the Loeffler
DCT graph with CORDIC micro-rotations: each rotation becomes a short sequence
of shift-add operations (multiplications by 2^-k) plus a shift-add
approximation of the 1/K gain.  The win on ASIC/FPGA (and, in the paper's
argument, on many-core GPUs) is *multiplierless* arithmetic; the cost is an
angle-approximation error that shows up as the ~2 dB PSNR deficit in the
paper's Tables 3 and 4.

On TPU this trade inverts (VPU multipliers are full-throughput, the MXU makes
small matmuls nearly free), but the variant is implemented faithfully so the
paper's quality/efficiency comparison is reproducible — see DESIGN.md §2.

All micro-rotation schedules are resolved at *trace time* (the three graph
angles are static), so the jitted computation is a fixed sequence of
multiply-adds by power-of-two constants — the float analogue of the paper's
shift-adds.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CordicConfig:
    """Approximation budget for the CORDIC rotations.

    iterations: number of micro-rotations (paper-faithful low-power mode: 4).
    gain_terms: number of signed power-of-two terms approximating 1/K.
    fixed_point_bits: if set, emulate a fixed-point datapath of this word
      length (sign + integer + fraction) sized for the 8-bit-image DCT
      dynamic range: each micro-rotation result is rounded to a grid of
      step 2^(12 - bits).  This models the short-word-length shift-add
      hardware the Cordic-Loeffler design targets and is what produces the
      paper's ~2 dB PSNR deficit (the angle error alone is hidden under
      JPEG quantisation — see EXPERIMENTS.md §PSNR for the ablation).
    A large budget (iterations=24, gain_terms=24, fixed_point_bits=None)
    recovers the exact rotation to float precision.
    """
    iterations: int = 4
    gain_terms: int = 3
    fixed_point_bits: int | None = None


# The paper-faithful low-power default (word length calibrated so the
# standard-decoder PSNR deficit lands in the paper's ~1.1–3 dB band;
# measured: +1.2..+2.5 dB across the paper's image sizes)...
PAPER_CONFIG = CordicConfig(iterations=4, gain_terms=3, fixed_point_bits=8)
# ...and a high-precision configuration for sanity checks.
EXACT_CONFIG = CordicConfig(iterations=24, gain_terms=24,
                            fixed_point_bits=None)


@functools.lru_cache(maxsize=None)
def _schedule(theta: float, iterations: int, gain_terms: int):
    """Greedy CORDIC schedule for a static angle.

    Returns (sigmas, shifts, gain_approx): the micro-rotation signs, their
    2^-k shift amounts, and the shift-add approximation of 1/K.
    """
    z = theta
    sigmas, shifts = [], []
    for k in range(iterations):
        sigma = 1.0 if z >= 0 else -1.0
        z -= sigma * math.atan(2.0 ** -k)
        sigmas.append(sigma)
        shifts.append(2.0 ** -k)
    gain = 1.0
    for k in range(iterations):
        gain *= math.sqrt(1.0 + 4.0 ** -k)
    # Greedy signed power-of-two expansion of 1/K.
    target = 1.0 / gain
    approx = 0.0
    for _ in range(gain_terms):
        resid = target - approx
        if resid == 0.0:
            break
        mag = abs(resid)
        p = round(math.log2(mag))
        # choose the power of two closest to the residual
        best = min((2.0 ** (p - 1), 2.0 ** p, 2.0 ** (p + 1)),
                   key=lambda c: abs(mag - c))
        approx += math.copysign(best, resid)
    return tuple(sigmas), tuple(shifts), approx


def cordic_rotate(u: jnp.ndarray, v: jnp.ndarray, theta: float,
                  config: CordicConfig = PAPER_CONFIG):
    """Approximate plane rotation, same convention as loeffler.exact_rotate:

        (u, v) -> (u cosθ + v sinθ, -u sinθ + v cosθ)

    CORDIC's canonical iteration rotates by +θ in the (x+iy) sense; our
    convention is the negated angle, handled by negating the schedule signs.
    """
    sigmas, shifts, gain = _schedule(float(theta), config.iterations,
                                     config.gain_terms)
    if config.fixed_point_bits is not None:
        step = 2.0 ** (12 - config.fixed_point_bits)
        quantize = lambda t: jnp.round(t * (1.0 / step)) * step
    else:
        quantize = lambda t: t
    for sigma, shift in zip(sigmas, shifts):
        # rotation by -theta: invert sigma relative to canonical CORDIC
        s = -sigma * shift
        u, v = quantize(u - s * v), quantize(v + s * u)
    return quantize(u * gain), quantize(v * gain)


def make_cordic_rotate(config: CordicConfig = PAPER_CONFIG):
    """rotate_fn factory compatible with loeffler.RotateFn."""
    def rotate(u, v, theta):
        return cordic_rotate(u, v, theta, config)
    return rotate


def fixed_quantizer(config: CordicConfig):
    """Stage-output rounding fn emulating the fixed-point register grid.

    Returns None when the config is a float datapath, so callers can skip
    the op entirely.
    """
    if config.fixed_point_bits is None:
        return None
    step = 2.0 ** (12 - config.fixed_point_bits)
    inv = 1.0 / step

    def quantize(x):
        return jnp.round(x * inv) * step
    return quantize


def rotation_error(theta: float, config: CordicConfig = PAPER_CONFIG):
    """(angle_error_rad, gain_error_rel) of the schedule — used by tests."""
    sigmas, _, gain_approx = _schedule(float(theta), config.iterations,
                                       config.gain_terms)
    z = float(theta)
    for k, sigma in enumerate(sigmas):
        z -= sigma * math.atan(2.0 ** -k)
    gain = 1.0
    for k in range(config.iterations):
        gain *= math.sqrt(1.0 + 4.0 ** -k)
    return abs(z), abs(gain_approx * gain - 1.0)
