"""JPEG-style quantisation for 8x8 DCT coefficient blocks.

The paper's pipeline is DCT -> quantiser -> IDCT (each a separate CUDA
kernel).  We use the ITU-T T.81 Annex K luminance table with the standard
IJG quality scaling.  Note: the orthonormal 2-D DCT used throughout this
repo coincides exactly with the JPEG FDCT convention (the (1/4)·C(u)C(v)
scaling equals the orthonormal alpha_u·alpha_v), so the table applies
without rescaling.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

# ITU-T T.81 Annex K, Table K.1 (luminance).
JPEG_LUMA_QTABLE = np.array([
    [16, 11, 10, 16, 24, 40, 51, 61],
    [12, 12, 14, 19, 26, 58, 60, 55],
    [14, 13, 16, 24, 40, 57, 69, 56],
    [14, 17, 22, 29, 51, 87, 80, 62],
    [18, 22, 37, 56, 68, 109, 103, 77],
    [24, 35, 55, 64, 81, 104, 113, 92],
    [49, 64, 78, 87, 103, 121, 120, 101],
    [72, 92, 95, 98, 112, 100, 103, 99],
], dtype=np.float64)


@functools.lru_cache(maxsize=None)
def _scaled_qtable_np(quality: int) -> np.ndarray:
    """IJG quality scaling: quality in [1, 100]."""
    quality = int(np.clip(quality, 1, 100))
    if quality < 50:
        scale = 5000.0 / quality
    else:
        scale = 200.0 - 2.0 * quality
    q = np.floor((JPEG_LUMA_QTABLE * scale + 50.0) / 100.0)
    return np.clip(q, 1.0, 255.0)


def qtable(quality: int = 50, dtype=jnp.float32) -> jnp.ndarray:
    """Quantisation step table for an IJG quality factor.

    This is the only table-derivation rule in the codec: the ``DCTZ``
    bitstream stores just the quality byte and decoders rebuild the
    steps with exactly this function (docs/bitstream.md §5).

    Args:
        quality: IJG quality factor, clipped to [1, 100]; 50 is the
            unscaled Annex K table, lower is coarser.
        dtype: element dtype of the returned table.

    Returns:
        (8, 8) array of quantisation steps in [1, 255].
    """
    return jnp.asarray(_scaled_qtable_np(quality), dtype=dtype)


def quantize(coeffs: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Round DCT coefficients to quantised levels.

    Args:
        coeffs: (..., 8, 8) float DCT coefficients (any leading batch/
            block-grid axes).
        q: (8, 8) step table from :func:`qtable` (broadcast over the
            leading axes).

    Returns:
        (..., 8, 8) int32 quantised levels ``round(coeffs / q)``.
    """
    return jnp.round(coeffs / q).astype(jnp.int32)


def dequantize(qcoeffs: jnp.ndarray, q: jnp.ndarray,
               dtype=jnp.float32) -> jnp.ndarray:
    """Reconstruct coefficient values from quantised levels.

    Args:
        qcoeffs: (..., 8, 8) int quantised levels from :func:`quantize`.
        q: (8, 8) step table; must match the quantiser's.
        dtype: output dtype.

    Returns:
        (..., 8, 8) dequantised coefficients ``qcoeffs * q``.
    """
    return qcoeffs.astype(dtype) * q.astype(dtype)


@functools.lru_cache(maxsize=None)
def _zigzag_perm(n: int = 8) -> np.ndarray:
    """Raster->zigzag permutation of block indices (length n*n)."""
    idx = sorted(((i + j, i if (i + j) % 2 else j, i, j)
                  for i in range(n) for j in range(n)))
    return np.array([i * n + j for (_, _, i, j) in idx], dtype=np.int32)


def zigzag(blocks: jnp.ndarray) -> jnp.ndarray:
    """Reorder blocks into the JPEG zig-zag sequence.

    Args:
        blocks: (..., n, n) square blocks (n = 8 in the codec).

    Returns:
        (..., n*n) array in zig-zag order (DC first); the inverse lives
        in :mod:`repro.core.entropy.scan` (``zigzag_unscan``).
    """
    *lead, b, b2 = blocks.shape
    perm = jnp.asarray(_zigzag_perm(b))
    return blocks.reshape(*lead, b * b2)[..., perm]


def estimate_bits(qcoeffs: jnp.ndarray) -> jnp.ndarray:
    """JPEG-flavoured size *proxy* (bits) for quantised blocks.

    The **one** surviving device-side size estimator (the PR 5 audit
    deleted every other proxy — ``CompressedImage.nbytes_estimate``,
    ``quant.compression_ratio`` — in favour of measured stream bytes).
    It stays because it is jit-able inside compiled pipelines, where
    bit packing is not: ``CompressedBatch.nbytes_estimate`` uses it for
    pre-materialisation telemetry, and that is its only load-bearing
    call site.  Every *reported* size in RESULTS.md is a measured
    entropy-coded stream length (``CompressedImage.nbytes`` /
    :mod:`repro.core.entropy`), never this.

    Per nonzero coefficient: magnitude-category bits + ~4 bits of
    Huffman overhead; + 4 bits EOB per block.

    Args:
        qcoeffs: (..., 8, 8) int quantised levels.

    Returns:
        Scalar estimated bit count over all blocks.
    """
    mag = jnp.abs(qcoeffs).astype(jnp.float32)
    nz = mag > 0
    cat_bits = jnp.where(nz, jnp.ceil(jnp.log2(mag + 1.0)), 0.0)
    huff_bits = jnp.where(nz, 4.0, 0.0)
    per_block = (cat_bits + huff_bits).sum(axis=(-1, -2)) + 4.0
    return per_block.sum()
