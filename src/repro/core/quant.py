"""JPEG-style quantisation for 8x8 DCT coefficient blocks.

The paper's pipeline is DCT -> quantiser -> IDCT (each a separate CUDA
kernel).  We use the ITU-T T.81 Annex K luminance table with the standard
IJG quality scaling.  Note: the orthonormal 2-D DCT used throughout this
repo coincides exactly with the JPEG FDCT convention (the (1/4)·C(u)C(v)
scaling equals the orthonormal alpha_u·alpha_v), so the table applies
without rescaling.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

# ITU-T T.81 Annex K, Table K.1 (luminance).
JPEG_LUMA_QTABLE = np.array([
    [16, 11, 10, 16, 24, 40, 51, 61],
    [12, 12, 14, 19, 26, 58, 60, 55],
    [14, 13, 16, 24, 40, 57, 69, 56],
    [14, 17, 22, 29, 51, 87, 80, 62],
    [18, 22, 37, 56, 68, 109, 103, 77],
    [24, 35, 55, 64, 81, 104, 113, 92],
    [49, 64, 78, 87, 103, 121, 120, 101],
    [72, 92, 95, 98, 112, 100, 103, 99],
], dtype=np.float64)


@functools.lru_cache(maxsize=None)
def _scaled_qtable_np(quality: int) -> np.ndarray:
    """IJG quality scaling: quality in [1, 100]."""
    quality = int(np.clip(quality, 1, 100))
    if quality < 50:
        scale = 5000.0 / quality
    else:
        scale = 200.0 - 2.0 * quality
    q = np.floor((JPEG_LUMA_QTABLE * scale + 50.0) / 100.0)
    return np.clip(q, 1.0, 255.0)


def qtable(quality: int = 50, dtype=jnp.float32) -> jnp.ndarray:
    """(8, 8) quantisation step table for an IJG quality factor."""
    return jnp.asarray(_scaled_qtable_np(quality), dtype=dtype)


def quantize(coeffs: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Round coefficients to quantisation steps.  (..., 8, 8) -> int32."""
    return jnp.round(coeffs / q).astype(jnp.int32)


def dequantize(qcoeffs: jnp.ndarray, q: jnp.ndarray,
               dtype=jnp.float32) -> jnp.ndarray:
    """Reconstruct coefficient values from quantised levels."""
    return qcoeffs.astype(dtype) * q.astype(dtype)


@functools.lru_cache(maxsize=None)
def _zigzag_perm(n: int = 8) -> np.ndarray:
    """Raster->zigzag permutation of block indices (length n*n)."""
    idx = sorted(((i + j, i if (i + j) % 2 else j, i, j)
                  for i in range(n) for j in range(n)))
    return np.array([i * n + j for (_, _, i, j) in idx], dtype=np.int32)


def zigzag(blocks: jnp.ndarray) -> jnp.ndarray:
    """(..., 8, 8) -> (..., 64) in zigzag order."""
    *lead, b, b2 = blocks.shape
    perm = jnp.asarray(_zigzag_perm(b))
    return blocks.reshape(*lead, b * b2)[..., perm]


def estimate_bits(qcoeffs: jnp.ndarray) -> jnp.ndarray:
    """JPEG-flavoured size proxy (bits) for quantised blocks (..., 8, 8).

    Per nonzero coefficient: magnitude-category bits + ~4 bits of Huffman
    overhead; + 4 bits EOB per block.  This is a *proxy* used only to report
    compression ratios (the paper reports none — it reports time + PSNR — so
    this is auxiliary telemetry, not a reproduction target).
    """
    mag = jnp.abs(qcoeffs).astype(jnp.float32)
    nz = mag > 0
    cat_bits = jnp.where(nz, jnp.ceil(jnp.log2(mag + 1.0)), 0.0)
    huff_bits = jnp.where(nz, 4.0, 0.0)
    per_block = (cat_bits + huff_bits).sum(axis=(-1, -2)) + 4.0
    return per_block.sum()


def compression_ratio(qcoeffs: jnp.ndarray, h: int, w: int,
                      bits_per_pixel: int = 8) -> jnp.ndarray:
    """original bits / estimated compressed bits."""
    return (h * w * bits_per_pixel) / estimate_bits(qcoeffs)
