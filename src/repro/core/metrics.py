"""Image-quality metrics — PSNR / MSE exactly as the paper defines them.

Paper eq. (24): MSE(O, C) = (1/NM) ΣΣ ||O(i,j) - C(i,j)||²
Paper eq. (23): PSNR(O, C) = 20 log10( MAX / sqrt(MSE) ), with MAX the
maximum pixel value of the *original* image O (not a fixed 255) — we follow
that definition by default and expose ``max_val`` for the conventional one.
"""

from __future__ import annotations

import jax.numpy as jnp


def mse(original: jnp.ndarray, reconstructed: jnp.ndarray) -> jnp.ndarray:
    o = original.astype(jnp.float32)
    c = reconstructed.astype(jnp.float32)
    return jnp.mean((o - c) ** 2)


def psnr(original: jnp.ndarray, reconstructed: jnp.ndarray,
         max_val: float | None = None) -> jnp.ndarray:
    """PSNR in dB per paper eq. (23)."""
    m = mse(original, reconstructed)
    if max_val is None:
        max_val = original.astype(jnp.float32).max()
    return 20.0 * jnp.log10(max_val / jnp.sqrt(jnp.maximum(m, 1e-12)))
