"""Synthetic structured test images.

The paper uses Lena and Cable-car from "Marco Schmidt's standard database".
Those images are not redistributable/available offline, so we synthesise
structured grayscale images with controlled spectral content:

* ``lena_like``     — smooth portrait-like low-frequency field + soft texture
                      (high energy compaction => higher PSNR, like Lena),
* ``cablecar_like`` — edge-rich scene with strong mid/high-frequency content
                      (lower PSNR at the same quality, matching the paper's
                      Cable-car < Lena ordering).

PSNR *trends* across sizes and the exact-DCT vs Cordic-Loeffler *gap* are the
reproduction targets (DESIGN.md §6), not absolute dB values.
"""

from __future__ import annotations

import numpy as np


def _grid(h: int, w: int):
    y = np.linspace(0.0, 1.0, h, endpoint=False)[:, None]
    x = np.linspace(0.0, 1.0, w, endpoint=False)[None, :]
    return y, x


def lena_like(h: int, w: int, seed: int = 0) -> np.ndarray:
    """Smooth, low-frequency-dominated grayscale image (uint8)."""
    rng = np.random.default_rng(seed)
    y, x = _grid(h, w)
    img = np.zeros((h, w), dtype=np.float64)
    # large-scale luminance field: a few gaussian blobs
    for _ in range(6):
        cy, cx = rng.uniform(0.1, 0.9, size=2)
        sy, sx = rng.uniform(0.08, 0.35, size=2)
        amp = rng.uniform(-90.0, 110.0)
        img += amp * np.exp(-((y - cy) ** 2 / (2 * sy ** 2)
                              + (x - cx) ** 2 / (2 * sx ** 2)))
    # gentle sweeping gradient
    img += 60.0 * (0.5 * y + 0.5 * x)
    # soft sinusoidal texture (hair/feathers analogue)
    img += 9.0 * np.sin(2 * np.pi * (7 * x + 2 * y))
    img += 6.0 * np.sin(2 * np.pi * (3 * x - 9 * y))
    # mild sensor noise
    img += rng.normal(0.0, 2.0, size=(h, w))
    img = img - img.min()
    img = 235.0 * img / max(img.max(), 1e-9) + 12.0
    return np.clip(img, 0, 255).astype(np.uint8)


def cablecar_like(h: int, w: int, seed: int = 1) -> np.ndarray:
    """Edge-rich grayscale image with strong high-frequency energy (uint8)."""
    rng = np.random.default_rng(seed)
    y, x = _grid(h, w)
    img = 110.0 + 70.0 * y  # sky-to-ground gradient
    # hard-edged "buildings": rectangles of random intensity
    for _ in range(24):
        y0, x0 = rng.uniform(0.0, 0.85, size=2)
        hh, ww = rng.uniform(0.04, 0.3, size=2)
        amp = rng.uniform(-80.0, 80.0)
        mask = ((y >= y0) & (y < y0 + hh)) * ((x >= x0) & (x < x0 + ww))
        img = img + amp * mask
    # cable lines: thin high-contrast diagonals
    for k in range(5):
        d = np.abs((y - 0.15 - 0.12 * k) - 0.35 * x)
        img = img - 70.0 * (d < 0.004)
    # high-frequency texture + noise
    img = img + 14.0 * np.sign(np.sin(2 * np.pi * (23 * x + 17 * y)))
    img = img + rng.normal(0.0, 4.0, size=(h, w))
    img = img - img.min()
    img = 243.0 * img / max(img.max(), 1e-9) + 6.0
    return np.clip(img, 0, 255).astype(np.uint8)


# Image sizes from the paper's tables.
LENA_SIZES = [(3072, 3072), (2048, 2048), (1600, 1400), (1024, 814),
              (576, 720), (512, 512), (200, 200)]
CABLECAR_SIZES = [(544, 512), (512, 480), (448, 416), (384, 352), (320, 288)]
