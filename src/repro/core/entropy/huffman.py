"""Canonical, length-limited Huffman codes (JPEG-table shaped).

A table is fully described by ``counts`` (how many codes have each
length 1..16) and ``symbols`` (all coded symbols in canonical order) —
the same (BITS, HUFFVAL) shape JPEG uses, which is what the ``DCTZ``
container embeds.  Codes are *canonical*: within a length, codes are
assigned in ``symbols`` order, numerically increasing, and the first
code of length L+1 is twice the next code of length L.  A third-party
decoder can therefore rebuild the exact codes from the two arrays alone
(docs/bitstream.md gives the reconstruction algorithm).

Tables are built per stream from the actual symbol frequencies
(:func:`build_table`): plain Huffman over the frequencies, then the
histogram rebalancing of ITU-T T.81 K.3 to cap code length at 16 while
preserving the Kraft sum.
"""

from __future__ import annotations

import dataclasses
import functools
import heapq

import numpy as np

MAX_CODE_LEN = 16


class InvalidTable(ValueError):
    """An embedded table segment violates the canonical-code invariants."""


@dataclasses.dataclass(frozen=True)
class CanonicalTable:
    """A canonical Huffman code: (counts per length, symbols in order).

    Attributes:
        counts: length-16 tuple; ``counts[i]`` codes have length i+1.
        symbols: all coded symbols (ints in [0, 255]) in canonical order
            — shortest codes first, ties in assignment order.
    """
    counts: tuple
    symbols: tuple

    def __post_init__(self):
        if len(self.counts) != MAX_CODE_LEN:
            raise InvalidTable(f"counts must have {MAX_CODE_LEN} entries")
        if sum(self.counts) != len(self.symbols):
            raise InvalidTable("counts sum != number of symbols")
        if len(set(self.symbols)) != len(self.symbols):
            raise InvalidTable("duplicate symbol in table")
        if any(s < 0 or s > 255 for s in self.symbols):
            raise InvalidTable("symbols must be bytes (0..255)")
        kraft = sum(c * 2 ** (MAX_CODE_LEN - l)
                    for l, c in enumerate(self.counts, start=1))
        if kraft > 2 ** MAX_CODE_LEN:
            raise InvalidTable("code lengths overfill the code space "
                               "(Kraft sum > 1)")

    def code_lengths(self) -> list:
        """Per-symbol (code, length) pairs in canonical ``symbols`` order."""
        out = []
        code = 0
        i = 0
        for length, c in enumerate(self.counts, start=1):
            for _ in range(c):
                out.append((code, length))
                code += 1
                i += 1
            code <<= 1
        return out

    def encoder_luts(self) -> tuple:
        """(code_of, len_of): 256-entry arrays indexed by symbol.

        ``len_of[s] == 0`` marks a symbol the table cannot encode.
        """
        code_of = np.zeros(256, dtype=np.int64)
        len_of = np.zeros(256, dtype=np.int64)
        for sym, (code, length) in zip(self.symbols, self.code_lengths()):
            code_of[sym] = code
            len_of[sym] = length
        return code_of, len_of

    def decoder_lut(self) -> tuple:
        """(sym_lut, len_lut): 2**16-entry prefix tables.

        Indexing with the next 16 bits of the stream yields the decoded
        symbol and its code length; ``len_lut == 0`` marks an invalid
        prefix (no code starts with those bits).
        """
        sym_lut = np.zeros(1 << MAX_CODE_LEN, dtype=np.int16)
        len_lut = np.zeros(1 << MAX_CODE_LEN, dtype=np.uint8)
        for sym, (code, length) in zip(self.symbols, self.code_lengths()):
            base = code << (MAX_CODE_LEN - length)
            span = 1 << (MAX_CODE_LEN - length)
            sym_lut[base:base + span] = sym
            len_lut[base:base + span] = length
        return sym_lut, len_lut

    def to_segment(self) -> bytes:
        """Serialise as 16 count bytes + the symbol bytes (JPEG DHT shape)."""
        return bytes(self.counts) + bytes(self.symbols)

    @classmethod
    def from_segment(cls, data: bytes, offset: int = 0) -> tuple:
        """Parse a table segment; returns ``(table, next_offset)``.

        Raises:
            InvalidTable: malformed counts/symbols (also covers
                truncation, reported with the missing byte count).
        """
        if len(data) < offset + MAX_CODE_LEN:
            raise InvalidTable("table segment truncated (counts)")
        counts = tuple(data[offset:offset + MAX_CODE_LEN])
        nsym = sum(counts)
        end = offset + MAX_CODE_LEN + nsym
        if len(data) < end:
            raise InvalidTable(
                f"table segment truncated: {end - len(data)} symbol "
                f"bytes missing")
        symbols = tuple(data[offset + MAX_CODE_LEN:end])
        return cls(counts=counts, symbols=symbols), end


def _huffman_depths(freqs: dict) -> dict:
    """Unlimited-depth Huffman code lengths for symbol -> frequency."""
    if len(freqs) == 1:
        return {next(iter(freqs)): 1}
    heap = [(f, sym, None, None) for sym, f in freqs.items()]
    heapq.heapify(heap)
    n = 0
    while len(heap) > 1:
        a = heapq.heappop(heap)
        b = heapq.heappop(heap)
        n -= 1                       # unique, non-symbol tie-break key
        heapq.heappush(heap, (a[0] + b[0], n, a, b))
    depths: dict = {}
    stack = [(heap[0], 0)]
    while stack:
        (f, key, left, right), d = stack.pop()
        if left is None:
            depths[key] = d
        else:
            stack.append((left, d + 1))
            stack.append((right, d + 1))
    return depths


def _limit_lengths(hist: list) -> list:
    """Cap a code-length histogram at MAX_CODE_LEN (ITU-T T.81 K.3).

    ``hist[l]`` is the number of codes of length ``l`` (index 0 unused).
    Each move retires two codes of the longest length into one code one
    bit shorter plus two codes one bit longer than some shorter code —
    the Kraft sum and the symbol count are both preserved.
    """
    max_len = len(hist) - 1
    for i in range(max_len, MAX_CODE_LEN, -1):
        while hist[i] > 0:
            j = i - 2
            while hist[j] == 0:
                j -= 1
            hist[i] -= 2
            hist[i - 1] += 1
            hist[j + 1] += 2
            hist[j] -= 1
    return hist[:MAX_CODE_LEN + 1] + [0] * (MAX_CODE_LEN + 1 - len(hist))


def build_table(freqs: np.ndarray) -> CanonicalTable:
    """Canonical length-limited table from symbol frequencies.

    Args:
        freqs: (<=256,) occurrence counts indexed by symbol; zero-count
            symbols get no code.

    Returns:
        A :class:`CanonicalTable` assigning shorter codes to more
        frequent symbols; ties break toward the smaller symbol value, so
        the construction is deterministic.

    Raises:
        ValueError: all frequencies are zero (nothing to code).
    """
    freqs = np.asarray(freqs)
    present = {int(s): int(freqs[s]) for s in np.nonzero(freqs)[0]}
    if not present:
        raise ValueError("cannot build a Huffman table from an empty "
                         "symbol set")
    depths = _huffman_depths(present)
    max_d = max(depths.values())
    hist = [0] * (max_d + 1)
    for d in depths.values():
        hist[d] += 1
    hist = _limit_lengths(hist)
    # assign limited lengths shortest-first to symbols ordered by
    # (frequency desc, symbol asc)
    order = sorted(present, key=lambda s: (-present[s], s))
    counts = [0] * MAX_CODE_LEN
    symbols = []
    it = iter(order)
    for length in range(1, MAX_CODE_LEN + 1):
        for _ in range(hist[length]):
            counts[length - 1] += 1
            symbols.append(next(it))
    return CanonicalTable(counts=tuple(counts), symbols=tuple(symbols))


@functools.lru_cache(maxsize=512)
def _table_from_histogram(freq_bytes: bytes) -> CanonicalTable:
    return build_table(np.frombuffer(freq_bytes, dtype=np.int64))


def build_table_memo(freqs: np.ndarray) -> CanonicalTable:
    """Memoised :func:`build_table` keyed on the frequency histogram.

    Streaming workloads repeat histogram shapes constantly (same source
    imagery at the same quality produces the same symbol statistics), so
    the heap construction + T.81 K.3 length limiting is cached on the
    raw histogram bytes.  Equal histograms return the identical
    :class:`CanonicalTable` object; distinct histograms never collide.
    """
    arr = np.ascontiguousarray(np.asarray(freqs, dtype=np.int64))
    return _table_from_histogram(arr.tobytes())


@functools.lru_cache(maxsize=64)
def decoder_luts(table: CanonicalTable) -> tuple:
    """Memoised :meth:`CanonicalTable.decoder_lut`.

    The 2**16-entry prefix tables cost more to build than a small image
    costs to decode; caching on the (hashable, frozen) table makes
    repeated decodes of same-table streams — the streaming case — pay
    for the tables once.  Callers must treat the arrays as read-only.
    """
    return table.decoder_lut()
