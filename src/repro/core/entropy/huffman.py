"""Canonical, length-limited Huffman codes (JPEG-table shaped).

A table is fully described by ``counts`` (how many codes have each
length 1..16) and ``symbols`` (all coded symbols in canonical order) —
the same (BITS, HUFFVAL) shape JPEG uses, which is what the ``DCTZ``
container embeds.  Codes are *canonical*: within a length, codes are
assigned in ``symbols`` order, numerically increasing, and the first
code of length L+1 is twice the next code of length L.  A third-party
decoder can therefore rebuild the exact codes from the two arrays alone
(docs/bitstream.md gives the reconstruction algorithm).

Tables are built per stream from the actual symbol frequencies
(:func:`build_table`): plain Huffman over the frequencies, then the
histogram rebalancing of ITU-T T.81 K.3 to cap code length at 16 while
preserving the Kraft sum.

Since container version 2, streams may instead reference **well-known
shared tables** by id (:class:`TableRegistry`): the encoder skips both
the per-stream table build and the ~56 embedded table bytes whenever a
registered table codes the stream more cheaply (:func:`coded_bits` is
the cost model).  Ids 1 and 2 ship the ITU-T T.81 Annex K luminance
tables — the canonical "well-known" JPEG tables — and encoder and
decoder share one registry (:data:`DEFAULT_TABLES`) so the choice needs
no negotiation beyond the id byte in the header.
"""

from __future__ import annotations

import dataclasses
import functools
import heapq

import numpy as np

MAX_CODE_LEN = 16


class InvalidTable(ValueError):
    """An embedded table segment violates the canonical-code invariants."""


@dataclasses.dataclass(frozen=True)
class CanonicalTable:
    """A canonical Huffman code: (counts per length, symbols in order).

    Attributes:
        counts: length-16 tuple; ``counts[i]`` codes have length i+1.
        symbols: all coded symbols (ints in [0, 255]) in canonical order
            — shortest codes first, ties in assignment order.
    """
    counts: tuple
    symbols: tuple

    def __post_init__(self):
        if len(self.counts) != MAX_CODE_LEN:
            raise InvalidTable(f"counts must have {MAX_CODE_LEN} entries")
        if sum(self.counts) != len(self.symbols):
            raise InvalidTable("counts sum != number of symbols")
        if len(set(self.symbols)) != len(self.symbols):
            raise InvalidTable("duplicate symbol in table")
        if any(s < 0 or s > 255 for s in self.symbols):
            raise InvalidTable("symbols must be bytes (0..255)")
        kraft = sum(c * 2 ** (MAX_CODE_LEN - l)
                    for l, c in enumerate(self.counts, start=1))
        if kraft > 2 ** MAX_CODE_LEN:
            raise InvalidTable("code lengths overfill the code space "
                               "(Kraft sum > 1)")

    def code_lengths(self) -> list:
        """Per-symbol (code, length) pairs in canonical ``symbols`` order."""
        out = []
        code = 0
        i = 0
        for length, c in enumerate(self.counts, start=1):
            for _ in range(c):
                out.append((code, length))
                code += 1
                i += 1
            code <<= 1
        return out

    def encoder_luts(self) -> tuple:
        """(code_of, len_of): 256-entry arrays indexed by symbol.

        ``len_of[s] == 0`` marks a symbol the table cannot encode.
        """
        code_of = np.zeros(256, dtype=np.int64)
        len_of = np.zeros(256, dtype=np.int64)
        for sym, (code, length) in zip(self.symbols, self.code_lengths()):
            code_of[sym] = code
            len_of[sym] = length
        return code_of, len_of

    def decoder_lut(self) -> tuple:
        """(sym_lut, len_lut): 2**16-entry prefix tables.

        Indexing with the next 16 bits of the stream yields the decoded
        symbol and its code length; ``len_lut == 0`` marks an invalid
        prefix (no code starts with those bits).
        """
        sym_lut = np.zeros(1 << MAX_CODE_LEN, dtype=np.int16)
        len_lut = np.zeros(1 << MAX_CODE_LEN, dtype=np.uint8)
        for sym, (code, length) in zip(self.symbols, self.code_lengths()):
            base = code << (MAX_CODE_LEN - length)
            span = 1 << (MAX_CODE_LEN - length)
            sym_lut[base:base + span] = sym
            len_lut[base:base + span] = length
        return sym_lut, len_lut

    def to_segment(self) -> bytes:
        """Serialise as 16 count bytes + the symbol bytes (JPEG DHT shape)."""
        return bytes(self.counts) + bytes(self.symbols)

    @classmethod
    def from_segment(cls, data: bytes, offset: int = 0) -> tuple:
        """Parse a table segment; returns ``(table, next_offset)``.

        Raises:
            InvalidTable: malformed counts/symbols (also covers
                truncation, reported with the missing byte count).
        """
        if len(data) < offset + MAX_CODE_LEN:
            raise InvalidTable("table segment truncated (counts)")
        counts = tuple(data[offset:offset + MAX_CODE_LEN])
        nsym = sum(counts)
        end = offset + MAX_CODE_LEN + nsym
        if len(data) < end:
            raise InvalidTable(
                f"table segment truncated: {end - len(data)} symbol "
                f"bytes missing")
        symbols = tuple(data[offset + MAX_CODE_LEN:end])
        return cls(counts=counts, symbols=symbols), end


def _huffman_depths(freqs: dict) -> dict:
    """Unlimited-depth Huffman code lengths for symbol -> frequency."""
    if len(freqs) == 1:
        return {next(iter(freqs)): 1}
    heap = [(f, sym, None, None) for sym, f in freqs.items()]
    heapq.heapify(heap)
    n = 0
    while len(heap) > 1:
        a = heapq.heappop(heap)
        b = heapq.heappop(heap)
        n -= 1                       # unique, non-symbol tie-break key
        heapq.heappush(heap, (a[0] + b[0], n, a, b))
    depths: dict = {}
    stack = [(heap[0], 0)]
    while stack:
        (f, key, left, right), d = stack.pop()
        if left is None:
            depths[key] = d
        else:
            stack.append((left, d + 1))
            stack.append((right, d + 1))
    return depths


def _limit_lengths(hist: list) -> list:
    """Cap a code-length histogram at MAX_CODE_LEN (ITU-T T.81 K.3).

    ``hist[l]`` is the number of codes of length ``l`` (index 0 unused).
    Each move retires two codes of the longest length into one code one
    bit shorter plus two codes one bit longer than some shorter code —
    the Kraft sum and the symbol count are both preserved.
    """
    max_len = len(hist) - 1
    for i in range(max_len, MAX_CODE_LEN, -1):
        while hist[i] > 0:
            j = i - 2
            while hist[j] == 0:
                j -= 1
            hist[i] -= 2
            hist[i - 1] += 1
            hist[j + 1] += 2
            hist[j] -= 1
    return hist[:MAX_CODE_LEN + 1] + [0] * (MAX_CODE_LEN + 1 - len(hist))


def build_table(freqs: np.ndarray) -> CanonicalTable:
    """Canonical length-limited table from symbol frequencies.

    Args:
        freqs: (<=256,) occurrence counts indexed by symbol; zero-count
            symbols get no code.

    Returns:
        A :class:`CanonicalTable` assigning shorter codes to more
        frequent symbols; ties break toward the smaller symbol value, so
        the construction is deterministic.

    Raises:
        ValueError: all frequencies are zero (nothing to code).
    """
    freqs = np.asarray(freqs)
    present = {int(s): int(freqs[s]) for s in np.nonzero(freqs)[0]}
    if not present:
        raise ValueError("cannot build a Huffman table from an empty "
                         "symbol set")
    depths = _huffman_depths(present)
    max_d = max(depths.values())
    hist = [0] * (max_d + 1)
    for d in depths.values():
        hist[d] += 1
    hist = _limit_lengths(hist)
    # assign limited lengths shortest-first to symbols ordered by
    # (frequency desc, symbol asc)
    order = sorted(present, key=lambda s: (-present[s], s))
    counts = [0] * MAX_CODE_LEN
    symbols = []
    it = iter(order)
    for length in range(1, MAX_CODE_LEN + 1):
        for _ in range(hist[length]):
            counts[length - 1] += 1
            symbols.append(next(it))
    return CanonicalTable(counts=tuple(counts), symbols=tuple(symbols))


@functools.lru_cache(maxsize=512)
def _table_from_histogram(freq_bytes: bytes) -> CanonicalTable:
    return build_table(np.frombuffer(freq_bytes, dtype=np.int64))


def build_table_memo(freqs: np.ndarray) -> CanonicalTable:
    """Memoised :func:`build_table` keyed on the frequency histogram.

    Streaming workloads repeat histogram shapes constantly (same source
    imagery at the same quality produces the same symbol statistics), so
    the heap construction + T.81 K.3 length limiting is cached on the
    raw histogram bytes.  Equal histograms return the identical
    :class:`CanonicalTable` object; distinct histograms never collide.
    """
    arr = np.ascontiguousarray(np.asarray(freqs, dtype=np.int64))
    return _table_from_histogram(arr.tobytes())


@functools.lru_cache(maxsize=64)
def encoder_luts(table: CanonicalTable) -> tuple:
    """Memoised :meth:`CanonicalTable.encoder_luts`.

    Streaming encoders hit the same (shared or memoised per-stream)
    tables constantly; caching on the frozen table makes the 256-entry
    code/length arrays a one-time cost per table.  Callers must treat
    the arrays as read-only.
    """
    return table.encoder_luts()


def coded_bits(table: CanonicalTable, freqs: np.ndarray):
    """Huffman bits this table spends coding a frequency histogram.

    The cost model the v2 encoder uses to pick embedded vs shared
    tables: amplitude bits are identical under any table, so only the
    per-symbol code lengths matter.

    Args:
        table: candidate canonical table.
        freqs: (<=256,) occurrence counts indexed by symbol.

    Returns:
        ``sum(freqs[s] * code_len(s))`` as an int, or ``None`` when the
        histogram needs a symbol the table cannot code (the table is
        unusable for this stream, not merely expensive).
    """
    freqs = np.asarray(freqs, dtype=np.int64)
    _, len_of = encoder_luts(table)
    len_of = len_of[:freqs.size]
    if bool(((freqs > 0) & (len_of == 0)).any()):
        return None
    return int((freqs * len_of).sum())


class TableRegistry:
    """Well-known Huffman tables addressable by container table id.

    Ids are one byte; id 0 always means "table embedded in this stream"
    and is not registrable.  Encoder and decoder must share the same
    registry contents (the container stores only the id), which is why
    the default tables live in this module next to the code
    construction rather than in the container.
    """

    def __init__(self):
        self._tables: dict = {}

    def register(self, table_id: int, table: CanonicalTable) -> None:
        """Register ``table`` under ``table_id`` (1..255, no rebinding:
        reassigning an id would silently corrupt every stream already
        written against it)."""
        if not 1 <= int(table_id) <= 255:
            raise ValueError(f"shared table ids are 1..255, got "
                             f"{table_id} (0 means embedded)")
        if table_id in self._tables:
            raise ValueError(f"table id {table_id} already registered")
        if not isinstance(table, CanonicalTable):
            raise TypeError("registry entries must be CanonicalTable")
        self._tables[int(table_id)] = table

    def known(self, table_id: int) -> bool:
        """True when ``table_id`` resolves (id 0 is never 'known' —
        embedded tables travel in the stream, not the registry)."""
        return int(table_id) in self._tables

    def get(self, table_id: int) -> CanonicalTable:
        """The table registered under ``table_id``.

        Raises:
            KeyError: unknown id (callers translate this into a
                bitstream error for decode paths).
        """
        return self._tables[int(table_id)]

    def ids(self) -> tuple:
        """All registered ids, ascending."""
        return tuple(sorted(self._tables))


# Well-known default tables (ITU-T T.81 Annex K, luminance).  The DC
# table codes categories 0..11 and the AC table (run, size) symbols
# with size <= 10 — streams whose levels need wider amplitudes fall
# back to embedded tables automatically (coded_bits returns None).
STANDARD_DC_LUMA_ID = 1
STANDARD_AC_LUMA_ID = 2

STANDARD_DC_LUMA = CanonicalTable(
    counts=(0, 1, 5, 1, 1, 1, 1, 1, 1, 0, 0, 0, 0, 0, 0, 0),
    symbols=tuple(range(12)))

STANDARD_AC_LUMA = CanonicalTable(
    counts=(0, 2, 1, 3, 3, 2, 4, 3, 5, 5, 4, 4, 0, 0, 1, 125),
    symbols=(
        0x01, 0x02, 0x03, 0x00, 0x04, 0x11, 0x05, 0x12,
        0x21, 0x31, 0x41, 0x06, 0x13, 0x51, 0x61, 0x07,
        0x22, 0x71, 0x14, 0x32, 0x81, 0x91, 0xA1, 0x08,
        0x23, 0x42, 0xB1, 0xC1, 0x15, 0x52, 0xD1, 0xF0,
        0x24, 0x33, 0x62, 0x72, 0x82, 0x09, 0x0A, 0x16,
        0x17, 0x18, 0x19, 0x1A, 0x25, 0x26, 0x27, 0x28,
        0x29, 0x2A, 0x34, 0x35, 0x36, 0x37, 0x38, 0x39,
        0x3A, 0x43, 0x44, 0x45, 0x46, 0x47, 0x48, 0x49,
        0x4A, 0x53, 0x54, 0x55, 0x56, 0x57, 0x58, 0x59,
        0x5A, 0x63, 0x64, 0x65, 0x66, 0x67, 0x68, 0x69,
        0x6A, 0x73, 0x74, 0x75, 0x76, 0x77, 0x78, 0x79,
        0x7A, 0x83, 0x84, 0x85, 0x86, 0x87, 0x88, 0x89,
        0x8A, 0x92, 0x93, 0x94, 0x95, 0x96, 0x97, 0x98,
        0x99, 0x9A, 0xA2, 0xA3, 0xA4, 0xA5, 0xA6, 0xA7,
        0xA8, 0xA9, 0xAA, 0xB2, 0xB3, 0xB4, 0xB5, 0xB6,
        0xB7, 0xB8, 0xB9, 0xBA, 0xC2, 0xC3, 0xC4, 0xC5,
        0xC6, 0xC7, 0xC8, 0xC9, 0xCA, 0xD2, 0xD3, 0xD4,
        0xD5, 0xD6, 0xD7, 0xD8, 0xD9, 0xDA, 0xE1, 0xE2,
        0xE3, 0xE4, 0xE5, 0xE6, 0xE7, 0xE8, 0xE9, 0xEA,
        0xF1, 0xF2, 0xF3, 0xF4, 0xF5, 0xF6, 0xF7, 0xF8,
        0xF9, 0xFA))

DEFAULT_TABLES = TableRegistry()
DEFAULT_TABLES.register(STANDARD_DC_LUMA_ID, STANDARD_DC_LUMA)
DEFAULT_TABLES.register(STANDARD_AC_LUMA_ID, STANDARD_AC_LUMA)


@functools.lru_cache(maxsize=64)
def decoder_luts(table: CanonicalTable) -> tuple:
    """Memoised :meth:`CanonicalTable.decoder_lut`.

    The 2**16-entry prefix tables cost more to build than a small image
    costs to decode; caching on the (hashable, frozen) table makes
    repeated decodes of same-table streams — the streaming case — pay
    for the tables once.  Callers must treat the arrays as read-only.
    """
    return table.decoder_lut()
