"""MSB-first bit packing and unpacking at the host edge (pure NumPy).

The writer side is fully vectorised: the entropy encoder accumulates
``(code, length)`` pairs in stream order and :func:`pack_bits` turns them
into bytes in one shot (repeat/shift/packbits — no Python per-bit loop).
The reader exposes the next 16-bit window of the payload on demand
(O(1) time and memory per symbol) so a canonical-Huffman decoder can
consume one symbol per prefix-LUT lookup.

Conventions (see docs/bitstream.md):

* bits are written MSB-first within each code and within each byte,
* the final partial byte is padded with 1-bits (JPEG's convention),
* no code or amplitude field is longer than 16 bits.
"""

from __future__ import annotations

import numpy as np

MAX_FIELD_BITS = 16


class TruncatedStream(ValueError):
    """Raised by :class:`BitReader` when a read runs past the payload."""


def pack_bits(codes: np.ndarray, lengths: np.ndarray) -> bytes:
    """Concatenate MSB-first bit fields into padded bytes.

    Args:
        codes: (M,) non-negative ints; field k contributes its low
            ``lengths[k]`` bits, most significant first.
        lengths: (M,) field widths in [0, 16]; zero-width fields are
            skipped (convenient for absent amplitude fields).

    Returns:
        The packed payload, final byte padded with 1-bits.
    """
    codes = np.asarray(codes, dtype=np.int64)
    lengths = np.asarray(lengths, dtype=np.int64)
    if lengths.size and int(lengths.max()) > MAX_FIELD_BITS:
        raise ValueError(f"bit field wider than {MAX_FIELD_BITS} bits")
    keep = lengths > 0
    codes, lengths = codes[keep], lengths[keep]
    total = int(lengths.sum())
    if total == 0:
        return b""
    # per-bit shift amounts: for a field of length L the bits come out at
    # shifts L-1, L-2, ..., 0 (MSB first); at global bit position p inside
    # field k that shift is (ends[k] - 1) - p
    ends = np.cumsum(lengths)
    shifts = (np.repeat(ends - 1, lengths)
              - np.arange(total, dtype=np.int64))
    bits = ((np.repeat(codes, lengths) >> shifts) & 1).astype(np.uint8)
    pad = (-total) % 8
    if pad:
        bits = np.concatenate([bits, np.ones(pad, np.uint8)])
    return np.packbits(bits).tobytes()


def bit_windows(payload: bytes) -> np.ndarray:
    """All 16-bit MSB-first windows of a payload, 1-padded past the end.

    ``bit_windows(p)[k]`` equals what a :class:`BitReader` positioned at
    bit ``k`` would ``peek16()`` — but computed for *every* bit position
    in one vectorised pass.  Two decoders are built on it: the LUT walk
    in :func:`repro.core.entropy.rle.decode_payload` indexes its
    per-position symbol tables with it, and the speculative unpack
    backends (``repro.kernels.unpack_bits``, docs/decoding.md) decode a
    candidate unit from every window at once.  The 1-padding past the
    payload end mirrors the writer, so "decodes but runs past the end"
    is detected by position arithmetic, never by bit pattern.

    Args:
        payload: packed bytes (as produced by :func:`pack_bits`).

    Returns:
        (8*len(payload) + 17,) uint16 array (2 bytes per bit position —
        the footprint matters: the LUT decoder precomputes over every
        position); entries at and past the payload end see the writer's
        1-padding convention.
    """
    nbits = len(payload) * 8
    b = np.frombuffer(payload, dtype=np.uint8).astype(np.int32)
    b = np.concatenate([b, np.full(5, 0xFF, np.int32)])     # 1-padding
    # 24-bit rolling words; window at bit p is bits r..r+15 of the word
    # starting at byte p >> 3, where r = p & 7
    w24 = (b[:-2] << 16) | (b[1:-1] << 8) | b[2:]
    shifts = np.arange(8, 0, -1, dtype=np.int32)
    return (((w24[:, None] >> shifts) & 0xFFFF)
            .astype(np.uint16).ravel()[:nbits + 17])


class BitReader:
    """Sequential MSB-first reader over a packed payload.

    ``peek16()`` returns the next 16 bits (1-padded past the end, like
    the writer's padding) without consuming them — the shape a canonical
    Huffman prefix-LUT wants — and ``skip``/``take`` advance the cursor.
    """

    _POW16 = (1 << np.arange(15, -1, -1)).astype(np.int32)

    def __init__(self, payload: bytes):
        bits = np.unpackbits(np.frombuffer(payload, dtype=np.uint8))
        self.nbits = bits.size
        # 1-padding tail so peek16 near the end needs no branching; the
        # window is computed on demand (O(1) memory beyond the bits)
        self._bits = np.concatenate(
            [bits, np.ones(MAX_FIELD_BITS, np.uint8)])
        self.pos = 0

    def peek16(self) -> int:
        """Next 16 bits as an int (1-padded past the payload end)."""
        if self.pos > self.nbits:
            raise TruncatedStream("bit reader ran past end of payload")
        return int(self._bits[self.pos:self.pos + MAX_FIELD_BITS]
                   @ self._POW16)

    def skip(self, n: int) -> None:
        """Consume ``n`` bits; raises :class:`TruncatedStream` if the
        cursor would pass the payload end."""
        self.pos += n
        if self.pos > self.nbits:
            raise TruncatedStream(
                f"entropy payload truncated: needed bit {self.pos} "
                f"of {self.nbits}")

    def take(self, n: int) -> int:
        """Consume and return ``n`` bits (MSB-first), n in [0, 16]."""
        if n == 0:
            return 0
        v = self.peek16() >> (MAX_FIELD_BITS - n)
        self.skip(n)
        return v
