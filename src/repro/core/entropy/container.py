"""The ``DCTZ`` container: a versioned bitstream around the entropy stage.

Layout (all integers little-endian; full spec in docs/bitstream.md)::

    offset size field
    0      4    magic  b"DCTZ"
    4      1    version (currently 1)
    5      1    flags (reserved, must be 0)
    6      1    quality (1..100, IJG scaling)
    7      1    transform code (0 exact / 1 cordic / 2 loeffler)
    8      4    height  u32 (original, pre-padding)
    12     4    width   u32
    16     1    dc_table_id (0 = table embedded in this stream)
    17     1    ac_table_id (0 = table embedded in this stream)
    18     2    reserved (must be 0)
    20     4    payload_nbytes u32
    24     4    crc32 over (header bytes 4..23 ‖ tables ‖ payload)
    28     ...  DC table segment, AC table segment (id 0 only)
    ...    ...  entropy-coded payload (payload_nbytes bytes)

The encoder always derives per-stream canonical Huffman tables from the
actual symbol frequencies and embeds them (table id 0); nonzero table
ids are reserved for future shared tables and must be rejected.
Decoders must reject unknown magic/version/transform/table ids and
trailing bytes — the format versions by replacement, not extension.
"""

from __future__ import annotations

import struct
import zlib

import jax.numpy as jnp
import numpy as np

from repro.core import codec, cordic
from repro.core.entropy import bitio, huffman, rle, scan

MAGIC = b"DCTZ"
VERSION = 1
TABLE_EMBEDDED = 0

_HEADER = struct.Struct("<4sBBBBIIBBHII")
HEADER_NBYTES = _HEADER.size            # 28

TRANSFORM_CODES = {"exact": 0, "cordic": 1, "loeffler": 2}
_TRANSFORM_NAMES = {v: k for k, v in TRANSFORM_CODES.items()}


class BitstreamError(ValueError):
    """A ``DCTZ`` stream is malformed: bad magic/version/field values,
    truncated data, CRC mismatch, or an invalid entropy payload."""


def _grid_shape(height: int, width: int) -> tuple:
    return (height + 7) // 8, (width + 7) // 8


def encode_qcoeffs(qcoeffs, quality: int, transform: str,
                   orig_shape: tuple) -> bytes:
    """Entropy-code one image's quantised levels into a ``DCTZ`` stream.

    Args:
        qcoeffs: (gh, gw, 8, 8) int quantised levels, raster block
            order; ``(gh, gw)`` must equal the block grid of
            ``orig_shape`` padded to 8.
        quality: JPEG quality factor in [1, 100] (stored so the decoder
            rebuilds the same quantisation table).
        transform: encoder transform name (see
            :data:`TRANSFORM_CODES`); stored for provenance and for
            ``mode="matched"`` decodes.
        orig_shape: (H, W) of the image before block padding.

    Returns:
        The complete container as bytes.

    Raises:
        ValueError: shape/quality/transform out of range, or a level too
            large for a 15-bit amplitude (:class:`repro.core.entropy.
            rle.RangeError`).
    """
    h, w = int(orig_shape[0]), int(orig_shape[1])
    if transform not in TRANSFORM_CODES:
        raise ValueError(f"unknown transform {transform!r}; "
                         f"expected one of {sorted(TRANSFORM_CODES)}")
    if not 1 <= int(quality) <= 100:
        raise ValueError(f"quality {quality} outside [1, 100]")
    gh, gw = _grid_shape(h, w)
    qcoeffs = jnp.asarray(qcoeffs)
    if qcoeffs.shape != (gh, gw, 8, 8):
        raise ValueError(f"qcoeffs shape {qcoeffs.shape} does not match "
                         f"the {gh}x{gw} block grid of a {h}x{w} image")

    # accelerated half: zig-zag + DC differential (jnp, vmappable)
    z = scan.block_stream(qcoeffs)
    dc_diff, ac = scan.dc_differential(z)
    return _frame_stream(np.asarray(dc_diff), np.asarray(ac),
                         quality, transform, h, w)


def encode_zigzag_host(z: np.ndarray, quality: int, transform: str,
                       orig_shape: tuple) -> bytes:
    """Entropy-code a (n_blocks, 64) zig-zag stream — pure host path.

    The jax-free sibling of :func:`encode_qcoeffs` for callers that
    already ran the zig-zag scan on the device for a whole batch (the
    engine's overlapped ``to_bytes_list``): everything here — DC
    differential, symbolisation, tables, packing, framing — is NumPy,
    so worker threads never contend on jax dispatch and release the GIL
    inside the array ops.  Bytes are identical to
    :func:`encode_qcoeffs` on the same blocks.

    Args:
        z: (gh*gw, 64) int zig-zag stream in raster block order (as
            produced by :func:`repro.core.entropy.scan.block_stream`).
        quality: JPEG quality factor in [1, 100].
        transform: encoder transform name (see
            :data:`TRANSFORM_CODES`).
        orig_shape: (H, W) of the image before block padding.

    Returns:
        The complete container as bytes.

    Raises:
        ValueError: shape/quality/transform out of range, or a level too
            large for a 15-bit amplitude.
    """
    h, w = int(orig_shape[0]), int(orig_shape[1])
    if transform not in TRANSFORM_CODES:
        raise ValueError(f"unknown transform {transform!r}; "
                         f"expected one of {sorted(TRANSFORM_CODES)}")
    if not 1 <= int(quality) <= 100:
        raise ValueError(f"quality {quality} outside [1, 100]")
    gh, gw = _grid_shape(h, w)
    z = np.asarray(z)
    if z.shape != (gh * gw, 64):
        raise ValueError(f"zig-zag stream shape {z.shape} does not match "
                         f"the {gh}x{gw} block grid of a {h}x{w} image")
    dc = z[:, 0].astype(np.int64)
    dc_diff = np.diff(dc, prepend=np.int64(0))
    return _frame_stream(dc_diff, z[:, 1:], quality, transform, h, w)


def _frame_stream(dc_diff: np.ndarray, ac: np.ndarray, quality: int,
                  transform: str, h: int, w: int) -> bytes:
    """Host edge shared by both encoders: symbolise (whole-array),
    memoised canonical tables, vectorised bit packing, framing."""
    is_dc, syms, amp_vals, amp_lens = rle.symbolize(dc_diff, ac)
    dc_freq, ac_freq = rle.symbol_frequencies(is_dc, syms)
    dc_table = huffman.build_table_memo(dc_freq)
    ac_table = huffman.build_table_memo(ac_freq)
    payload = rle.encode_payload(is_dc, syms, amp_vals, amp_lens,
                                 dc_table, ac_table)

    tables = dc_table.to_segment() + ac_table.to_segment()
    header = _HEADER.pack(MAGIC, VERSION, 0, int(quality),
                          TRANSFORM_CODES[transform], h, w,
                          TABLE_EMBEDDED, TABLE_EMBEDDED, 0,
                          len(payload), 0)
    # CRC protects every header field after the magic (a flipped quality
    # or shape byte must not decode plausibly) plus tables and payload
    crc = zlib.crc32(header[4:24] + tables + payload) & 0xFFFFFFFF
    return header[:24] + struct.pack("<I", crc) + tables + payload


def read_header(data: bytes) -> dict:
    """Parse and validate the fixed 28-byte header.

    Args:
        data: at least the first 28 bytes of a stream.

    Returns:
        Dict with ``version``, ``quality``, ``transform``, ``height``,
        ``width``, ``dc_table_id``, ``ac_table_id``, ``payload_nbytes``,
        ``crc32``.

    Raises:
        BitstreamError: short data, bad magic, unsupported version,
            or any field outside its valid range.
    """
    if len(data) < HEADER_NBYTES:
        raise BitstreamError(
            f"truncated header: got {len(data)} bytes, need "
            f"{HEADER_NBYTES}")
    (magic, version, flags, quality, tcode, height, width,
     dc_id, ac_id, reserved, payload_nbytes, crc) = _HEADER.unpack_from(
        data)
    if magic != MAGIC:
        raise BitstreamError(f"not a DCTZ stream (magic {magic!r})")
    if version != VERSION:
        raise BitstreamError(f"unsupported DCTZ version {version}; this "
                             f"decoder reads version {VERSION}")
    if flags != 0 or reserved != 0:
        raise BitstreamError("reserved header fields must be zero")
    if tcode not in _TRANSFORM_NAMES:
        raise BitstreamError(f"unknown transform code {tcode}")
    if not 1 <= quality <= 100:
        raise BitstreamError(f"quality {quality} outside [1, 100]")
    if height == 0 or width == 0:
        raise BitstreamError("zero image dimension")
    if dc_id != TABLE_EMBEDDED or ac_id != TABLE_EMBEDDED:
        raise BitstreamError(
            f"unknown table ids ({dc_id}, {ac_id}); only embedded "
            f"tables (id {TABLE_EMBEDDED}) are defined in version "
            f"{VERSION}")
    return {"version": version, "quality": quality,
            "transform": _TRANSFORM_NAMES[tcode],
            "height": height, "width": width,
            "dc_table_id": dc_id, "ac_table_id": ac_id,
            "payload_nbytes": payload_nbytes, "crc32": crc}


def decode_zigzag_host(data: bytes) -> tuple:
    """Parse + entropy-decode a stream to its zig-zag form — pure host.

    The jax-free half of :func:`decode_qcoeffs`: framing validation,
    CRC, embedded tables, the LUT entropy decode and the (integer,
    bit-exact) DC integration all run in NumPy, so the engine's
    pipelined ``decode_batch`` can fan streams across threads without
    contending on jax dispatch; only the inverse zig-zag permutation is
    left for the device.

    Args:
        data: one complete ``DCTZ`` stream.

    Returns:
        ``(z, header)``: the (gh*gw, 64) int32 zig-zag stream in raster
        block order and the parsed header dict.

    Raises:
        BitstreamError: any malformation — truncation (header, tables or
            payload), trailing bytes, CRC mismatch, invalid table
            segments, or an undecodable entropy payload.
    """
    hdr = read_header(data)
    try:
        dc_table, off = huffman.CanonicalTable.from_segment(
            data, HEADER_NBYTES)
        ac_table, off = huffman.CanonicalTable.from_segment(data, off)
    except huffman.InvalidTable as e:
        raise BitstreamError(f"bad embedded Huffman table: {e}") from e
    end = off + hdr["payload_nbytes"]
    if len(data) < end:
        raise BitstreamError(
            f"truncated payload: stream has {len(data) - off} of "
            f"{hdr['payload_nbytes']} declared bytes")
    if len(data) > end:
        raise BitstreamError(f"{len(data) - end} trailing bytes after "
                             f"the declared payload")
    crc = zlib.crc32(data[4:24] + data[HEADER_NBYTES:end]) & 0xFFFFFFFF
    if crc != hdr["crc32"]:
        raise BitstreamError(
            f"CRC mismatch: header says {hdr['crc32']:#010x}, stream "
            f"hashes to {crc:#010x} (corrupted stream)")

    gh, gw = _grid_shape(hdr["height"], hdr["width"])
    # every block costs at least 2 payload bits (DC code + EOB), so a
    # shape whose block count exceeds 4 bytes^-1 * payload is invalid —
    # this bounds allocation before trusting the header's dimensions
    if gh * gw > 4 * hdr["payload_nbytes"]:
        raise BitstreamError(
            f"declared {hdr['height']}x{hdr['width']} image needs "
            f"{gh * gw} blocks but the {hdr['payload_nbytes']}-byte "
            f"payload cannot hold them (corrupted shape)")
    try:
        dc_diff, ac = rle.decode_payload(data[off:end], gh * gw,
                                         dc_table, ac_table)
    except (bitio.TruncatedStream, ValueError) as e:
        raise BitstreamError(f"bad entropy payload: {e}") from e

    # DC integration is integer-exact, so the host cumsum matches the
    # device's scan.dc_integrate bit for bit
    z = np.empty((gh * gw, 64), dtype=np.int32)
    z[:, 0] = np.cumsum(dc_diff, dtype=np.int64)
    z[:, 1:] = ac
    return z, hdr


def decode_qcoeffs(data: bytes) -> tuple:
    """Full inverse of :func:`encode_qcoeffs`.

    Args:
        data: one complete ``DCTZ`` stream.

    Returns:
        ``(qcoeffs, header)``: the (gh, gw, 8, 8) int32 quantised levels
        and the parsed header dict.

    Raises:
        BitstreamError: any malformation — truncation (header, tables or
            payload), trailing bytes, CRC mismatch, invalid table
            segments, or an undecodable entropy payload.
    """
    z, hdr = decode_zigzag_host(data)
    gh, gw = _grid_shape(hdr["height"], hdr["width"])
    # accelerated half of the inverse: the inverse zig-zag permutation
    return scan.unblock_stream(jnp.asarray(z), gh, gw), hdr


def encode_image(img, quality: int = 50,
                 transform: codec.Transform = "exact",
                 cordic_config: cordic.CordicConfig = cordic.PAPER_CONFIG
                 ) -> bytes:
    """Compress a (H, W) grayscale image to a complete ``DCTZ`` stream.

    The array half (DCT + quantise + zig-zag) runs the same jitted path
    as :func:`repro.core.codec.compress`; only bit packing happens on
    the host.

    Args:
        img: (H, W) uint8/float grayscale image.
        quality: JPEG quality factor in [1, 100].
        transform: encoder transform ("exact"/"cordic"/"loeffler").
        cordic_config: CORDIC config for ``transform == "cordic"``.

    Returns:
        The container bytes; ``len()`` of it is the *measured* size the
        rate–distortion benches report.
    """
    c = codec.compress(img, quality, transform, cordic_config)
    return c.to_bytes()


def decode_image(data: bytes, mode: str = "standard") -> jnp.ndarray:
    """Reconstruct the (H, W) uint8 image from a ``DCTZ`` stream.

    The entropy stage is lossless over the quantised levels, so the
    result is bit-exact with decoding the in-memory
    :class:`repro.core.codec.CompressedImage` the encoder started from.

    Args:
        data: one complete ``DCTZ`` stream.
        mode: "standard" (exact IDCT — a decoder that ignores the
            encoder's approximate transform) or "matched" (the adjoint
            of the stored transform, with the paper's CORDIC config).

    Returns:
        (H, W) uint8 reconstruction, cropped to the stored shape.

    Raises:
        BitstreamError: see :func:`decode_qcoeffs`.
    """
    c = codec.CompressedImage.from_bytes(data)
    return codec.decompress(c, mode=mode)
