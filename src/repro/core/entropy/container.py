"""The ``DCTZ`` container: a versioned bitstream around the entropy stage.

Layout (all integers little-endian; full spec in docs/bitstream.md)::

    offset size field
    0      4    magic  b"DCTZ"
    4      1    version (1 or 2)
    5      1    flags (reserved, must be 0)
    6      1    quality (1..100, IJG scaling)
    7      1    transform code (0 exact / 1 cordic / 2 loeffler)
    8      4    height  u32 (original, pre-padding)
    12     4    width   u32
    16     1    dc_table_id (0 = table embedded in this stream)
    17     1    ac_table_id (0 = table embedded in this stream)
    18     2    reserved (must be 0)
    20     4    payload_nbytes u32
    24     4    crc32 over (header bytes 4..23 ‖ tables ‖ payload)
    28     ...  DC table segment, then AC table segment (embedded only)
    ...    ...  entropy-coded payload (payload_nbytes bytes)

Version 1 embeds both canonical Huffman tables (table id 0).  Version 2
adds **shared table ids** (>= 1, resolved through
:data:`repro.core.entropy.huffman.DEFAULT_TABLES`): the encoder picks,
per alphabet, whichever is cheaper — per-stream table coding bits plus
the embedded segment bytes, or the well-known shared table — and only
writes version 2 when at least one shared id is used, so fully-embedded
streams stay byte-identical to version 1.  Decoders reject unknown
magic/version/transform/table ids and trailing bytes; within a version
the format evolves by replacement, not extension.

This module is importable without jax: the host halves
(:func:`encode_zigzag_host` / :func:`decode_zigzag_host`) are pure
NumPy so process-pool workers (``codec_engine.decode_batch``) don't pay
a jax import per child; only the qcoeff/image entry points pull in the
array stack, lazily.
"""

from __future__ import annotations

import struct
import zlib

import numpy as np

from repro.core.entropy import bitio, huffman, rle

MAGIC = b"DCTZ"
VERSION_EMBEDDED = 1        # both tables embedded (the v1 layout)
VERSION_SHARED = 2          # at least one shared table id
SUPPORTED_VERSIONS = (VERSION_EMBEDDED, VERSION_SHARED)
VERSION = VERSION_SHARED    # newest version this module writes/reads
TABLE_EMBEDDED = 0

TABLE_MODES = ("auto", "embedded", "shared")

_HEADER = struct.Struct("<4sBBBBIIBBHII")
HEADER_NBYTES = _HEADER.size            # 28

TRANSFORM_CODES = {"exact": 0, "cordic": 1, "loeffler": 2}
_TRANSFORM_NAMES = {v: k for k, v in TRANSFORM_CODES.items()}


class BitstreamError(ValueError):
    """A ``DCTZ`` stream is malformed: bad magic/version/field values,
    truncated data, CRC mismatch, or an invalid entropy payload."""


def _grid_shape(height: int, width: int) -> tuple:
    return (height + 7) // 8, (width + 7) // 8


def _check_encode_args(quality: int, transform: str, tables: str) -> None:
    if transform not in TRANSFORM_CODES:
        raise ValueError(f"unknown transform {transform!r}; "
                         f"expected one of {sorted(TRANSFORM_CODES)}")
    if not 1 <= int(quality) <= 100:
        raise ValueError(f"quality {quality} outside [1, 100]")
    if tables not in TABLE_MODES:
        raise ValueError(f"unknown tables mode {tables!r}; "
                         f"expected one of {TABLE_MODES}")


def encode_qcoeffs(qcoeffs, quality: int, transform: str,
                   orig_shape: tuple, *, tables: str = "auto",
                   packer=None, symbolizer=None) -> bytes:
    """Entropy-code one image's quantised levels into a ``DCTZ`` stream.

    Args:
        qcoeffs: (gh, gw, 8, 8) int quantised levels, raster block
            order; ``(gh, gw)`` must equal the block grid of
            ``orig_shape`` padded to 8.
        quality: JPEG quality factor in [1, 100] (stored so the decoder
            rebuilds the same quantisation table).
        transform: encoder transform name (see
            :data:`TRANSFORM_CODES`); stored for provenance and for
            ``mode="matched"`` decodes.
        orig_shape: (H, W) of the image before block padding.
        tables: Huffman table policy — "auto" (per alphabet, shared
            table when it beats embedded cost), "embedded" (always
            per-stream tables: the version-1 layout, byte-identical to
            pre-v2 encoders), or "shared" (force the shared ids; raises
            if the stream needs a symbol they cannot code).
        packer: bit-packing backend override, a ``(fields, widths) ->
            bytes`` callable (e.g. the routed
            :func:`repro.kernels.pack_bits.pack_bits`); None = the
            NumPy reference.
        symbolizer: symbolisation backend override (see
            :func:`_frame_stream`), e.g. the routed
            :func:`repro.kernels.symbolize.make_symbolizer`; None = the
            vectorised host pipeline.  Bytes identical either way.

    Returns:
        The complete container as bytes.

    Raises:
        ValueError: shape/quality/transform/tables out of range, a
            level too large for a 15-bit amplitude
            (:class:`repro.core.entropy.rle.RangeError`), or
            ``tables="shared"`` with an uncoverable symbol stream.
    """
    import jax.numpy as jnp

    from repro.core.entropy import scan
    h, w = int(orig_shape[0]), int(orig_shape[1])
    _check_encode_args(quality, transform, tables)
    gh, gw = _grid_shape(h, w)
    qcoeffs = jnp.asarray(qcoeffs)
    if qcoeffs.shape != (gh, gw, 8, 8):
        raise ValueError(f"qcoeffs shape {qcoeffs.shape} does not match "
                         f"the {gh}x{gw} block grid of a {h}x{w} image")

    # accelerated half: zig-zag + DC differential (jnp, vmappable)
    z = scan.block_stream(qcoeffs)
    dc_diff, ac = scan.dc_differential(z)
    return _frame_stream(np.asarray(dc_diff), np.asarray(ac),
                         quality, transform, h, w, tables=tables,
                         packer=packer, symbolizer=symbolizer)


def encode_zigzag_host(z: np.ndarray, quality: int, transform: str,
                       orig_shape: tuple, *, tables: str = "auto",
                       packer=None, symbolizer=None) -> bytes:
    """Entropy-code a (n_blocks, 64) zig-zag stream — pure host path.

    The jax-free sibling of :func:`encode_qcoeffs` for callers that
    already ran the zig-zag scan on the device for a whole batch (the
    engine's overlapped ``to_bytes_list``): everything here — DC
    differential, symbolisation, tables, packing, framing — is NumPy,
    so worker threads never contend on jax dispatch and release the GIL
    inside the array ops.  Bytes are identical to
    :func:`encode_qcoeffs` on the same blocks.

    Args:
        z: (gh*gw, 64) int zig-zag stream in raster block order (as
            produced by :func:`repro.core.entropy.scan.block_stream`).
        quality: JPEG quality factor in [1, 100].
        transform: encoder transform name (see
            :data:`TRANSFORM_CODES`).
        orig_shape: (H, W) of the image before block padding.
        tables: Huffman table policy, as in :func:`encode_qcoeffs`.
        packer: bit-packing backend override, as in
            :func:`encode_qcoeffs`.
        symbolizer: symbolisation backend override, as in
            :func:`encode_qcoeffs`.  The default keeps this function's
            no-jax-import property; a routed symbolizer built in the
            parent process is fine for worker *threads*.

    Returns:
        The complete container as bytes.

    Raises:
        ValueError: shape/quality/transform/tables out of range, or a
            level too large for a 15-bit amplitude.
    """
    h, w = int(orig_shape[0]), int(orig_shape[1])
    _check_encode_args(quality, transform, tables)
    gh, gw = _grid_shape(h, w)
    z = np.asarray(z)
    if z.shape != (gh * gw, 64):
        raise ValueError(f"zig-zag stream shape {z.shape} does not match "
                         f"the {gh}x{gw} block grid of a {h}x{w} image")
    dc = z[:, 0].astype(np.int64)
    dc_diff = np.diff(dc, prepend=np.int64(0))
    return _frame_stream(dc_diff, z[:, 1:], quality, transform, h, w,
                         tables=tables, packer=packer,
                         symbolizer=symbolizer)


def _choose_table(freqs: np.ndarray, shared_id: int, tables: str,
                  what: str) -> tuple:
    """Pick (table_id, table) for one alphabet under the table policy.

    "auto" compares total Huffman bits: the per-stream table costs its
    coded bits plus 8x its embedded segment bytes; the shared table
    costs its coded bits alone (or is unusable when the stream needs a
    symbol it lacks).  Amplitude bits cancel.  The rule is
    deterministic, so re-encoding a decoded stream reproduces it.
    Forcing "shared" skips the per-stream table build entirely — the
    streaming fast path; "auto" still builds it (memoised on the
    histogram) because the comparison needs its coded bits.
    """
    if tables == "shared":
        shared = huffman.DEFAULT_TABLES.get(shared_id)
        if huffman.coded_bits(shared, freqs) is None:
            raise ValueError(
                f"{what} stream needs a symbol the shared table id "
                f"{shared_id} cannot code; use tables='auto' or "
                f"'embedded'")
        return shared_id, shared
    embedded = huffman.build_table_memo(freqs)
    if tables == "embedded":
        return TABLE_EMBEDDED, embedded
    shared = huffman.DEFAULT_TABLES.get(shared_id)
    shared_bits = huffman.coded_bits(shared, freqs)
    embedded_cost = (huffman.coded_bits(embedded, freqs)
                     + 8 * len(embedded.to_segment()))
    if shared_bits is not None and shared_bits < embedded_cost:
        return shared_id, shared
    return TABLE_EMBEDDED, embedded


def _frame_stream(dc_diff: np.ndarray, ac: np.ndarray, quality: int,
                  transform: str, h: int, w: int, *,
                  tables: str = "auto", packer=None,
                  symbolizer=None) -> bytes:
    """Host edge shared by both encoders: the staged entropy pipeline
    (symbolise -> table choice -> codeword lookup -> routed packing)
    plus framing.

    ``symbolizer`` routes the symbolisation/payload stages: a
    ``(dc_diff, ac, packer=None) -> prepared`` callable whose result
    exposes ``dc_freq``/``ac_freq`` histograms (consumed by table
    choice below) and ``payload(dc_table, ac_table) -> bytes`` — e.g.
    :func:`repro.kernels.symbolize.make_symbolizer`.  ``None`` keeps
    the vectorised host pipeline; bytes are identical either way
    (CI-gated), so the table negotiation and framing here never change.
    """
    prep = (symbolizer or rle.prepare_stream)(dc_diff, ac, packer=packer)
    dc_id, dc_table = _choose_table(prep.dc_freq,
                                    huffman.STANDARD_DC_LUMA_ID,
                                    tables, "DC")
    ac_id, ac_table = _choose_table(prep.ac_freq,
                                    huffman.STANDARD_AC_LUMA_ID,
                                    tables, "AC")
    payload = prep.payload(dc_table, ac_table)

    table_segs = b""
    if dc_id == TABLE_EMBEDDED:
        table_segs += dc_table.to_segment()
    if ac_id == TABLE_EMBEDDED:
        table_segs += ac_table.to_segment()
    # fully-embedded streams keep the version-1 byte layout so pre-v2
    # decoders (and the golden fixtures) are untouched
    version = (VERSION_EMBEDDED
               if dc_id == ac_id == TABLE_EMBEDDED else VERSION_SHARED)
    header = _HEADER.pack(MAGIC, version, 0, int(quality),
                          TRANSFORM_CODES[transform], h, w,
                          dc_id, ac_id, 0, len(payload), 0)
    # CRC protects every header field after the magic (a flipped quality
    # or shape byte must not decode plausibly) plus tables and payload
    crc = zlib.crc32(header[4:24] + table_segs + payload) & 0xFFFFFFFF
    return header[:24] + struct.pack("<I", crc) + table_segs + payload


def read_header(data: bytes) -> dict:
    """Parse and validate the fixed 28-byte header.

    Args:
        data: at least the first 28 bytes of a stream.

    Returns:
        Dict with ``version``, ``quality``, ``transform``, ``height``,
        ``width``, ``dc_table_id``, ``ac_table_id``, ``payload_nbytes``,
        ``crc32``.

    Raises:
        BitstreamError: short data, bad magic, unsupported version,
            or any field outside its valid range — including a table id
            the version does not define (version 1 allows only
            embedded; version 2 also allows registered shared ids).
    """
    if len(data) < HEADER_NBYTES:
        raise BitstreamError(
            f"truncated header: got {len(data)} bytes, need "
            f"{HEADER_NBYTES}")
    (magic, version, flags, quality, tcode, height, width,
     dc_id, ac_id, reserved, payload_nbytes, crc) = _HEADER.unpack_from(
        data)
    if magic != MAGIC:
        raise BitstreamError(f"not a DCTZ stream (magic {magic!r})")
    if version not in SUPPORTED_VERSIONS:
        raise BitstreamError(
            f"unsupported DCTZ version {version}; this decoder reads "
            f"versions {SUPPORTED_VERSIONS}")
    if flags != 0 or reserved != 0:
        raise BitstreamError("reserved header fields must be zero")
    if tcode not in _TRANSFORM_NAMES:
        raise BitstreamError(f"unknown transform code {tcode}")
    if not 1 <= quality <= 100:
        raise BitstreamError(f"quality {quality} outside [1, 100]")
    if height == 0 or width == 0:
        raise BitstreamError("zero image dimension")
    for tid in (dc_id, ac_id):
        if tid == TABLE_EMBEDDED:
            continue
        if version == VERSION_EMBEDDED:
            raise BitstreamError(
                f"unknown table ids ({dc_id}, {ac_id}); only embedded "
                f"tables (id {TABLE_EMBEDDED}) are defined in version "
                f"{VERSION_EMBEDDED}")
        if not huffman.DEFAULT_TABLES.known(tid):
            raise BitstreamError(
                f"unknown table ids ({dc_id}, {ac_id}); version "
                f"{VERSION_SHARED} defines embedded (id 0) and "
                f"registered shared ids {huffman.DEFAULT_TABLES.ids()}")
    return {"version": version, "quality": quality,
            "transform": _TRANSFORM_NAMES[tcode],
            "height": height, "width": width,
            "dc_table_id": dc_id, "ac_table_id": ac_id,
            "payload_nbytes": payload_nbytes, "crc32": crc}


def _resolve_tables(data: bytes, hdr: dict) -> tuple:
    """(dc_table, ac_table, payload_offset): embedded segments are
    parsed from the stream (DC first), shared ids resolve through the
    default registry (``read_header`` already vetted the ids)."""
    off = HEADER_NBYTES
    try:
        if hdr["dc_table_id"] == TABLE_EMBEDDED:
            dc_table, off = huffman.CanonicalTable.from_segment(data, off)
        else:
            dc_table = huffman.DEFAULT_TABLES.get(hdr["dc_table_id"])
        if hdr["ac_table_id"] == TABLE_EMBEDDED:
            ac_table, off = huffman.CanonicalTable.from_segment(data, off)
        else:
            ac_table = huffman.DEFAULT_TABLES.get(hdr["ac_table_id"])
    except huffman.InvalidTable as e:
        raise BitstreamError(f"bad embedded Huffman table: {e}") from e
    return dc_table, ac_table, off


def verify_crc(data: bytes) -> bool:
    """Check a stream's CRC without entropy-decoding the payload.

    Parses the header and table segments only (to locate the payload
    extent), then recomputes the CRC the way the writer does.  Used by
    ``dctz_cli info`` to report integrity cheaply.

    Returns:
        True iff the framing lengths agree and the CRC matches.

    Raises:
        BitstreamError: the header itself is invalid (there is no CRC
            to check against).
    """
    hdr = read_header(data)
    try:
        _, _, off = _resolve_tables(data, hdr)
    except BitstreamError:
        return False
    end = off + hdr["payload_nbytes"]
    if len(data) != end:
        return False
    crc = zlib.crc32(data[4:24] + data[HEADER_NBYTES:end]) & 0xFFFFFFFF
    return crc == hdr["crc32"]


def decode_zigzag_host(data: bytes, *, unpacker=None) -> tuple:
    """Parse + entropy-decode a stream to its zig-zag form — pure host.

    The jax-free half of :func:`decode_qcoeffs`: framing validation,
    CRC, table resolution (embedded segments or shared registry ids),
    the LUT entropy decode and the (integer, bit-exact) DC integration
    all run in NumPy, so the engine's pipelined ``decode_batch`` can
    fan streams across threads — or processes, this module imports
    without jax — without contending on jax dispatch; only the inverse
    zig-zag permutation is left for the device.

    Args:
        data: one complete ``DCTZ`` stream (version 1 or 2).
        unpacker: optional payload-decode backend handed through to
            :func:`repro.core.entropy.rle.decode_payload` — e.g. the
            routed :func:`repro.kernels.unpack_bits.unpack_bits` for a
            device-resident decode.  ``None`` keeps the jax-free LUT
            walk (and with it this function's no-jax-import property).

    Returns:
        ``(z, header)``: the (gh*gw, 64) int32 zig-zag stream in raster
        block order and the parsed header dict.

    Raises:
        BitstreamError: any malformation — truncation (header, tables or
            payload), trailing bytes, CRC mismatch, invalid table
            segments or ids, or an undecodable entropy payload.
    """
    hdr = read_header(data)
    dc_table, ac_table, off = _resolve_tables(data, hdr)
    end = off + hdr["payload_nbytes"]
    if len(data) < end:
        raise BitstreamError(
            f"truncated payload: stream has {len(data) - off} of "
            f"{hdr['payload_nbytes']} declared bytes")
    if len(data) > end:
        raise BitstreamError(f"{len(data) - end} trailing bytes after "
                             f"the declared payload")
    crc = zlib.crc32(data[4:24] + data[HEADER_NBYTES:end]) & 0xFFFFFFFF
    if crc != hdr["crc32"]:
        raise BitstreamError(
            f"CRC mismatch: header says {hdr['crc32']:#010x}, stream "
            f"hashes to {crc:#010x} (corrupted stream)")

    gh, gw = _grid_shape(hdr["height"], hdr["width"])
    # every block costs at least 2 payload bits (DC code + EOB), so a
    # shape whose block count exceeds 4 bytes^-1 * payload is invalid —
    # this bounds allocation before trusting the header's dimensions
    if gh * gw > 4 * hdr["payload_nbytes"]:
        raise BitstreamError(
            f"declared {hdr['height']}x{hdr['width']} image needs "
            f"{gh * gw} blocks but the {hdr['payload_nbytes']}-byte "
            f"payload cannot hold them (corrupted shape)")
    try:
        dc_diff, ac = rle.decode_payload(data[off:end], gh * gw,
                                         dc_table, ac_table,
                                         unpacker=unpacker)
    except (bitio.TruncatedStream, ValueError) as e:
        raise BitstreamError(f"bad entropy payload: {e}") from e

    # DC integration is integer-exact, so the host cumsum matches the
    # device's scan.dc_integrate bit for bit
    z = np.empty((gh * gw, 64), dtype=np.int32)
    z[:, 0] = np.cumsum(dc_diff, dtype=np.int64)
    z[:, 1:] = ac
    return z, hdr


def decode_qcoeffs(data: bytes, *, unpacker=None) -> tuple:
    """Full inverse of :func:`encode_qcoeffs`.

    Args:
        data: one complete ``DCTZ`` stream.
        unpacker: optional payload-decode backend (see
            :func:`decode_zigzag_host`).

    Returns:
        ``(qcoeffs, header)``: the (gh, gw, 8, 8) int32 quantised levels
        and the parsed header dict.

    Raises:
        BitstreamError: any malformation — truncation (header, tables or
            payload), trailing bytes, CRC mismatch, invalid table
            segments or ids, or an undecodable entropy payload.
    """
    import jax.numpy as jnp

    from repro.core.entropy import scan
    z, hdr = decode_zigzag_host(data, unpacker=unpacker)
    gh, gw = _grid_shape(hdr["height"], hdr["width"])
    # accelerated half of the inverse: the inverse zig-zag permutation
    return scan.unblock_stream(jnp.asarray(z), gh, gw), hdr


def encode_image(img, quality: int = 50, transform: str = "exact",
                 cordic_config=None, *, tables: str = "auto") -> bytes:
    """Compress a (H, W) grayscale image to a complete ``DCTZ`` stream.

    The array half (DCT + quantise + zig-zag) runs the same jitted path
    as :func:`repro.core.codec.compress`; only bit packing happens on
    the host.

    Args:
        img: (H, W) uint8/float grayscale image.
        quality: JPEG quality factor in [1, 100].
        transform: encoder transform ("exact"/"cordic"/"loeffler").
        cordic_config: CORDIC config for ``transform == "cordic"``
            (None = the paper's config).
        tables: Huffman table policy (see :func:`encode_qcoeffs`).

    Returns:
        The container bytes; ``len()`` of it is the *measured* size the
        rate–distortion benches report.
    """
    from repro.core import codec, cordic
    c = codec.compress(img, quality, transform,
                       cordic_config or cordic.PAPER_CONFIG)
    return c.to_bytes(tables=tables)


def decode_image(data: bytes, mode: str = "standard", *, unpacker=None):
    """Reconstruct the (H, W) uint8 image from a ``DCTZ`` stream.

    The entropy stage is lossless over the quantised levels, so the
    result is bit-exact with decoding the in-memory
    :class:`repro.core.codec.CompressedImage` the encoder started from.

    Args:
        data: one complete ``DCTZ`` stream.
        mode: "standard" (exact IDCT — a decoder that ignores the
            encoder's approximate transform) or "matched" (the adjoint
            of the stored transform, with the paper's CORDIC config).
        unpacker: optional payload-decode backend (see
            :func:`decode_zigzag_host`), e.g.
            ``repro.kernels.unpack_bits.make_unpacker()``.

    Returns:
        (H, W) uint8 reconstruction, cropped to the stored shape.

    Raises:
        BitstreamError: see :func:`decode_qcoeffs`.
    """
    from repro.core import codec
    c = codec.CompressedImage.from_bytes(data, unpacker=unpacker)
    return codec.decompress(c, mode=mode)
