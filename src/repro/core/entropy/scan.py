"""Accelerated half of the entropy stage: zig-zag scan + DC differential.

Everything here is pure ``jnp`` on fixed shapes — vmappable per block and
shardable with the rest of the codec — so the array-heavy reordering runs
wherever the DCT ran.  The variable-length half (run-length symbols, bit
packing) lives in :mod:`repro.core.entropy.rle` / ``bitio`` at the host
edge.

Block order is raster order over the block grid: block ``(i, j)`` of a
``(gh, gw, 8, 8)`` coefficient array is stream element ``i * gw + j``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import quant


def zigzag_perm(n: int = 8) -> np.ndarray:
    """Raster -> zig-zag permutation of flat block indices (length n*n)."""
    return quant._zigzag_perm(n)


def inverse_zigzag_perm(n: int = 8) -> np.ndarray:
    """Zig-zag -> raster permutation (the inverse of :func:`zigzag_perm`)."""
    return np.argsort(quant._zigzag_perm(n)).astype(np.int32)


def zigzag_scan(blocks: jnp.ndarray) -> jnp.ndarray:
    """(..., 8, 8) coefficient blocks -> (..., 64) in zig-zag order."""
    return quant.zigzag(blocks)


def zigzag_unscan(z: jnp.ndarray) -> jnp.ndarray:
    """(..., 64) zig-zag vectors -> (..., 8, 8) raster blocks."""
    *lead, n2 = z.shape
    n = int(round(n2 ** 0.5))
    inv = jnp.asarray(inverse_zigzag_perm(n))
    return z[..., inv].reshape(*lead, n, n)


def block_stream(qcoeffs: jnp.ndarray) -> jnp.ndarray:
    """(gh, gw, 8, 8) quantised levels -> (gh*gw, 64) zig-zag stream.

    Args:
        qcoeffs: one image's quantised coefficient grid, raster block
            order (as produced by :func:`repro.core.codec.compress`).

    Returns:
        (gh*gw, 64) int32 array; row k is block ``(k // gw, k % gw)`` in
        zig-zag coefficient order.
    """
    gh, gw = qcoeffs.shape[:2]
    return zigzag_scan(qcoeffs).reshape(gh * gw, 64)


def unblock_stream(z: jnp.ndarray, gh: int, gw: int) -> jnp.ndarray:
    """(gh*gw, 64) zig-zag stream -> (gh, gw, 8, 8) quantised levels."""
    return zigzag_unscan(z).reshape(gh, gw, 8, 8)


def dc_differential(z: jnp.ndarray) -> tuple:
    """Split a (n, 64) zig-zag stream into DC differences and the AC tail.

    The DC coefficient of each block is coded as its difference from the
    previous block's DC (predictor 0 for the first block), exactly as in
    JPEG baseline.

    Args:
        z: (n, 64) int32 zig-zag stream in block order.

    Returns:
        ``(dc_diff, ac)``: (n,) int32 DC differences and the (n, 63)
        int32 AC tail (zig-zag positions 1..63).
    """
    dc = z[:, 0]
    prev = jnp.concatenate([jnp.zeros((1,), dc.dtype), dc[:-1]])
    return dc - prev, z[:, 1:]


def dc_integrate(dc_diff: jnp.ndarray) -> jnp.ndarray:
    """Invert :func:`dc_differential`'s DC leg: (n,) diffs -> (n,) DCs."""
    return jnp.cumsum(dc_diff)


def assemble_stream(dc: jnp.ndarray, ac: jnp.ndarray) -> jnp.ndarray:
    """Recombine (n,) DCs and (n, 63) AC tails into a (n, 64) stream."""
    return jnp.concatenate([dc[:, None].astype(ac.dtype), ac], axis=1)
