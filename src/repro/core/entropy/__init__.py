"""Entropy-coded bitstream stage: quantised blocks -> real bytes.

Completes the paper's pipeline (DCT -> quantise -> IDCT) with a
JPEG-style lossless entropy stage so compression ratios are *measured*
bytes, not the :func:`repro.core.quant.estimate_bits` proxy:

* :mod:`scan`      — zig-zag scan + DC differential, vectorised in JAX
  (vmappable per block; this half rides the accelerator),
* :mod:`rle`       — run-length symbolisation of the zig-zag AC tail and
  magnitude-category coding, NumPy at the host edge,
* :mod:`huffman`   — canonical, length-limited Huffman codes built from
  per-stream symbol frequencies, plus the shared-table registry
  (well-known ITU-T T.81 Annex K tables under ids >= 1),
* :mod:`bitio`     — MSB-first bit packing/unpacking (NumPy; the
  retained reference the routed :mod:`repro.kernels.pack_bits` backend
  is gated against),
* :mod:`container` — the versioned ``DCTZ`` container (magic, version,
  shape, quality, transform, table ids, CRC) with
  :func:`encode_image` / :func:`decode_image`.

The encode path is a staged pipeline — symbolize -> table choice ->
codeword lookup -> prefix-sum offsets -> scatter-pack — whose packing
stage routes between the NumPy reference and the Pallas kernel
(``packer`` argument on the encoders).  The stage is exactly lossless
over the quantised levels, so ``decode_image(encode_image(img, q))``
reproduces the quantised round-trip reconstruction bit-exactly.  The
byte layout a third-party decoder needs is specified in
``docs/bitstream.md``.  This package (and the host halves
``encode_zigzag_host`` / ``decode_zigzag_host``) imports without jax,
which is what makes the engine's process-pool decode fallback cheap.
"""

from repro.core.entropy.bitio import TruncatedStream
from repro.core.entropy.container import (BitstreamError, decode_image,
                                          decode_qcoeffs,
                                          decode_zigzag_host, encode_image,
                                          encode_qcoeffs,
                                          encode_zigzag_host, read_header,
                                          verify_crc)

__all__ = ["BitstreamError", "TruncatedStream", "decode_image",
           "decode_qcoeffs", "decode_zigzag_host", "encode_image",
           "encode_qcoeffs", "encode_zigzag_host", "read_header",
           "verify_crc"]
