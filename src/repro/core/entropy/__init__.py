"""Entropy-coded bitstream stage: quantised blocks -> real bytes.

Completes the paper's pipeline (DCT -> quantise -> IDCT) with a
JPEG-style lossless entropy stage so compression ratios are *measured*
bytes, not the :func:`repro.core.quant.estimate_bits` proxy:

* :mod:`scan`      — zig-zag scan + DC differential, vectorised in JAX
  (vmappable per block; this half rides the accelerator),
* :mod:`rle`       — run-length symbolisation of the zig-zag AC tail and
  magnitude-category coding, NumPy at the host edge,
* :mod:`huffman`   — canonical, length-limited Huffman codes built from
  per-stream symbol frequencies,
* :mod:`bitio`     — MSB-first bit packing/unpacking (NumPy),
* :mod:`container` — the versioned ``DCTZ`` container (magic, version,
  shape, quality, transform, table ids, CRC) with
  :func:`encode_image` / :func:`decode_image`.

The stage is exactly lossless over the quantised levels, so
``decode_image(encode_image(img, q))`` reproduces the quantised
round-trip reconstruction bit-exactly.  The byte layout a third-party
decoder needs is specified in ``docs/bitstream.md``.
"""

from repro.core.entropy.container import (BitstreamError, decode_image,
                                          decode_qcoeffs,
                                          decode_zigzag_host, encode_image,
                                          encode_qcoeffs,
                                          encode_zigzag_host, read_header)

__all__ = ["BitstreamError", "decode_image", "decode_qcoeffs",
           "decode_zigzag_host", "encode_image", "encode_qcoeffs",
           "encode_zigzag_host", "read_header"]
