"""DC-differential + run-length symbolisation of zig-zag blocks.

Host-edge half of the entropy stage (NumPy): turns the fixed-shape
arrays produced by :mod:`repro.core.entropy.scan` into the JPEG-baseline
symbol stream that :mod:`huffman`/:mod:`bitio` serialise, and back.

Symbol alphabet (docs/bitstream.md):

* DC: the magnitude category ``S`` of the DC difference (0..15), then
  ``S`` raw amplitude bits.
* AC: one byte ``(run << 4) | size`` per nonzero coefficient, where
  ``run`` is the number of zeros skipped (0..15) and ``size`` its
  magnitude category (1..15), then ``size`` amplitude bits.  Two
  specials: ``0x00`` (EOB) ends a block early, ``0xF0`` (ZRL) skips 16
  zeros without coding a coefficient.
* amplitudes use JPEG's one's-complement convention: ``v > 0`` codes as
  ``v``; ``v < 0`` codes as ``v + 2**size - 1``.
"""

from __future__ import annotations

import numpy as np

from repro.core.entropy import bitio, huffman

EOB = 0x00
ZRL = 0xF0
MAX_CATEGORY = 15          # amplitudes are at most 15 bits
AC_LEN = 63                # zig-zag positions 1..63


class RangeError(ValueError):
    """A quantised level is too large for a 15-bit amplitude field."""


def magnitude_category(v: np.ndarray) -> np.ndarray:
    """Bit length of |v| per element (category 0 for v == 0)."""
    mag = np.abs(np.asarray(v, dtype=np.int64))
    # frexp exponent == bit length for exact integer floats; int64
    # magnitudes here are bounded well below 2**53 by the range check
    return np.where(mag == 0, 0,
                    np.frexp(mag.astype(np.float64))[1]).astype(np.int64)


def amplitude_value(v: np.ndarray, size: np.ndarray) -> np.ndarray:
    """One's-complement amplitude field for nonzero v of category size."""
    v = np.asarray(v, dtype=np.int64)
    return np.where(v >= 0, v, v + (1 << size) - 1)


def amplitude_decode(bits: int, size: int) -> int:
    """Invert :func:`amplitude_value` for one field."""
    if size == 0:
        return 0
    if bits < (1 << (size - 1)):
        return bits - (1 << size) + 1
    return bits


def _check_range(cat: np.ndarray, what: str) -> None:
    if cat.size and int(cat.max()) > MAX_CATEGORY:
        raise RangeError(
            f"{what} magnitude needs category {int(cat.max())} > "
            f"{MAX_CATEGORY}; levels must fit 15-bit amplitudes")


def symbolize(dc_diff: np.ndarray, ac: np.ndarray) -> tuple:
    """Blocks -> the interleaved (symbol, amplitude) stream.

    Args:
        dc_diff: (n,) int DC differences in block order.
        ac: (n, 63) int AC tails in zig-zag order.

    Returns:
        ``(is_dc, syms, amp_vals, amp_lens)`` — parallel arrays over the
        symbol stream in coding order (each block: one DC symbol, then
        its AC symbols).  ``amp_lens[k] == 0`` means symbol k carries no
        amplitude field (EOB/ZRL/zero DC diff).

    Raises:
        RangeError: some level needs an amplitude wider than 15 bits.
    """
    dc_diff = np.asarray(dc_diff, dtype=np.int64)
    ac = np.asarray(ac, dtype=np.int64)
    n = dc_diff.shape[0]
    dc_cat = magnitude_category(dc_diff)
    _check_range(dc_cat, "DC difference")
    ac_cat = magnitude_category(ac)
    _check_range(ac_cat, "AC coefficient")
    dc_amp = amplitude_value(dc_diff, dc_cat)
    ac_amp = amplitude_value(ac, ac_cat)

    is_dc, syms, amp_vals, amp_lens = [], [], [], []
    for b in range(n):
        is_dc.append(True)
        syms.append(int(dc_cat[b]))
        amp_vals.append(int(dc_amp[b]))
        amp_lens.append(int(dc_cat[b]))
        nz = np.nonzero(ac[b])[0]
        prev = -1
        for pos in nz:
            run = int(pos) - prev - 1
            while run >= 16:
                is_dc.append(False)
                syms.append(ZRL)
                amp_vals.append(0)
                amp_lens.append(0)
                run -= 16
            is_dc.append(False)
            syms.append((run << 4) | int(ac_cat[b, pos]))
            amp_vals.append(int(ac_amp[b, pos]))
            amp_lens.append(int(ac_cat[b, pos]))
            prev = int(pos)
        if prev != AC_LEN - 1:
            is_dc.append(False)
            syms.append(EOB)
            amp_vals.append(0)
            amp_lens.append(0)
    return (np.asarray(is_dc, dtype=bool),
            np.asarray(syms, dtype=np.int64),
            np.asarray(amp_vals, dtype=np.int64),
            np.asarray(amp_lens, dtype=np.int64))


def symbol_frequencies(is_dc, syms) -> tuple:
    """(dc_freqs, ac_freqs): 256-bin histograms of the two alphabets."""
    dc = np.bincount(syms[is_dc], minlength=256)
    ac = np.bincount(syms[~is_dc], minlength=256)
    return dc, ac


def encode_payload(is_dc, syms, amp_vals, amp_lens,
                   dc_table: huffman.CanonicalTable,
                   ac_table: huffman.CanonicalTable) -> bytes:
    """Huffman-code the symbol stream and pack it into bytes.

    Every symbol contributes its code, immediately followed by its
    amplitude field (when present); the interleave is realised by laying
    codes at even and amplitudes at odd slots of a (2M,) field array and
    letting :func:`repro.core.entropy.bitio.pack_bits` drop the
    zero-length slots.
    """
    dc_code, dc_len = dc_table.encoder_luts()
    ac_code, ac_len = ac_table.encoder_luts()
    codes = np.where(is_dc, dc_code[syms], ac_code[syms])
    lens = np.where(is_dc, dc_len[syms], ac_len[syms])
    if bool((lens == 0).any()):
        raise ValueError("symbol stream contains a symbol absent from "
                         "the Huffman table")
    m = syms.shape[0]
    fields = np.empty(2 * m, dtype=np.int64)
    widths = np.empty(2 * m, dtype=np.int64)
    fields[0::2], widths[0::2] = codes, lens
    fields[1::2], widths[1::2] = amp_vals, amp_lens
    return bitio.pack_bits(fields, widths)


def decode_payload(payload: bytes, n_blocks: int,
                   dc_table: huffman.CanonicalTable,
                   ac_table: huffman.CanonicalTable) -> tuple:
    """Decode ``n_blocks`` blocks from an entropy payload.

    Args:
        payload: packed bits from :func:`encode_payload`.
        n_blocks: how many 8x8 blocks the stream must contain (known
            from the container's image shape).
        dc_table: canonical table for DC categories.
        ac_table: canonical table for AC (run, size) symbols.

    Returns:
        ``(dc_diff, ac)`` — (n,) int32 DC differences and (n, 63) int32
        AC tails, exactly inverting :func:`symbolize`.

    Raises:
        bitio.TruncatedStream: the payload ends mid-block.
        ValueError: an invalid Huffman prefix or a coefficient overrun
            (corrupted stream).
    """
    dc_sym, dc_len = dc_table.decoder_lut()
    ac_sym, ac_len = ac_table.decoder_lut()
    reader = bitio.BitReader(payload)
    dc_diff = np.zeros(n_blocks, dtype=np.int32)
    ac = np.zeros((n_blocks, AC_LEN), dtype=np.int32)
    for b in range(n_blocks):
        w = reader.peek16()
        length = int(dc_len[w])
        if length == 0:
            raise ValueError(f"invalid DC Huffman prefix at bit "
                             f"{reader.pos}")
        reader.skip(length)
        size = int(dc_sym[w])
        dc_diff[b] = amplitude_decode(reader.take(size), size)
        pos = 0                     # next AC slot to fill (0-based in ac)
        while pos < AC_LEN:
            w = reader.peek16()
            length = int(ac_len[w])
            if length == 0:
                raise ValueError(f"invalid AC Huffman prefix at bit "
                                 f"{reader.pos}")
            reader.skip(length)
            sym = int(ac_sym[w])
            if sym == EOB:
                break
            if sym == ZRL:
                pos += 16
                continue
            run, size = sym >> 4, sym & 0xF
            pos += run
            if pos >= AC_LEN:
                raise ValueError(
                    f"corrupted stream: AC run overruns block {b}")
            ac[b, pos] = amplitude_decode(reader.take(size), size)
            pos += 1
    return dc_diff, ac
