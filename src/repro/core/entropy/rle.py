"""DC-differential + run-length symbolisation of zig-zag blocks.

Host-edge half of the entropy stage (NumPy): turns the fixed-shape
arrays produced by :mod:`repro.core.entropy.scan` into the JPEG-baseline
symbol stream that :mod:`huffman`/:mod:`bitio` serialise, and back.

Both directions are **batch-vectorized**: :func:`symbolize` builds the
(run, size) symbols, ZRL expansions, EOB markers and amplitude fields
for every block of the stream with whole-array NumPy (no per-block
Python loop), and :func:`decode_payload` drives a precomputed
peek-16-bit prefix-LUT decoder whose per-bit-position symbol/advance/
amplitude tables are built in one vectorised pass, leaving only the
(data-dependent) walk along the symbol chain in Python.  The original
scalar implementations survive as :func:`symbolize_reference` /
:func:`decode_payload_reference` — the golden oracles the property
tests and the ``entropy_throughput`` bench compare against.  A third
decode family lives in ``repro.kernels.unpack_bits`` (speculative
per-offset decode + pointer doubling, docs/decoding.md) and plugs in
through :func:`decode_payload`'s ``unpacker`` hook; all three agree on
values *and* errors by CI gate.

Symbol alphabet (docs/bitstream.md):

* DC: the magnitude category ``S`` of the DC difference (0..15), then
  ``S`` raw amplitude bits.
* AC: one byte ``(run << 4) | size`` per nonzero coefficient, where
  ``run`` is the number of zeros skipped (0..15) and ``size`` its
  magnitude category (1..15), then ``size`` amplitude bits.  Two
  specials: ``0x00`` (EOB) ends a block early, ``0xF0`` (ZRL) skips 16
  zeros without coding a coefficient.
* amplitudes use JPEG's one's-complement convention: ``v > 0`` codes as
  ``v``; ``v < 0`` codes as ``v + 2**size - 1``.
"""

from __future__ import annotations

import numpy as np

from repro.core.entropy import bitio, huffman

EOB = 0x00
ZRL = 0xF0
MAX_CATEGORY = 15          # amplitudes are at most 15 bits
AC_LEN = 63                # zig-zag positions 1..63


class RangeError(ValueError):
    """A quantised level is too large for a 15-bit amplitude field."""


def magnitude_category(v: np.ndarray) -> np.ndarray:
    """Bit length of |v| per element (category 0 for v == 0)."""
    mag = np.abs(np.asarray(v, dtype=np.int64))
    # frexp exponent == bit length for exact integer floats; int64
    # magnitudes here are bounded well below 2**53 by the range check
    return np.where(mag == 0, 0,
                    np.frexp(mag.astype(np.float64))[1]).astype(np.int64)


def amplitude_value(v: np.ndarray, size: np.ndarray) -> np.ndarray:
    """One's-complement amplitude field for nonzero v of category size."""
    v = np.asarray(v, dtype=np.int64)
    return np.where(v >= 0, v, v + (1 << size) - 1)


def amplitude_decode(bits: int, size: int) -> int:
    """Invert :func:`amplitude_value` for one field."""
    if size == 0:
        return 0
    if bits < (1 << (size - 1)):
        return bits - (1 << size) + 1
    return bits


def _check_range(cat: np.ndarray, what: str) -> None:
    if cat.size and int(cat.max()) > MAX_CATEGORY:
        raise RangeError(
            f"{what} magnitude needs category {int(cat.max())} > "
            f"{MAX_CATEGORY}; levels must fit 15-bit amplitudes")


def symbolize(dc_diff: np.ndarray, ac: np.ndarray) -> tuple:
    """Blocks -> the interleaved (symbol, amplitude) stream, vectorised.

    Every quantity — zero runs, ZRL expansions, (run, size) symbols,
    magnitude categories, amplitude fields and the output offsets that
    interleave them into coding order — is computed with whole-array
    NumPy over all blocks at once; no Python loop touches a block.
    Bit-for-bit identical to :func:`symbolize_reference`.

    Args:
        dc_diff: (n,) int DC differences in block order.
        ac: (n, 63) int AC tails in zig-zag order.

    Returns:
        ``(is_dc, syms, amp_vals, amp_lens)`` — parallel arrays over the
        symbol stream in coding order (each block: one DC symbol, then
        its AC symbols).  ``amp_lens[k] == 0`` means symbol k carries no
        amplitude field (EOB/ZRL/zero DC diff).

    Raises:
        RangeError: some level needs an amplitude wider than 15 bits.
    """
    dc_diff = np.asarray(dc_diff, dtype=np.int64)
    ac = np.asarray(ac, dtype=np.int64)
    n = dc_diff.shape[0]
    dc_cat = magnitude_category(dc_diff)
    _check_range(dc_cat, "DC difference")
    dc_amp = amplitude_value(dc_diff, dc_cat)

    # one row per nonzero AC coefficient, already in coding order
    # (np.nonzero is row-major: block ascending, then position ascending);
    # categories/amplitudes only touch the nonzero entries — zeros have
    # category 0 by definition, so the range check is unaffected
    nz_b, nz_c = np.nonzero(ac)
    k = nz_b.size
    ac_nz = ac[nz_b, nz_c]
    ac_cat_nz = magnitude_category(ac_nz)
    _check_range(ac_cat_nz, "AC coefficient")
    ac_amp_nz = amplitude_value(ac_nz, ac_cat_nz)
    first = np.empty(k, dtype=bool)         # first nonzero of its block?
    prev = np.empty(k, dtype=np.int64)      # previous nonzero position
    if k:
        first[0] = True
        first[1:] = nz_b[1:] != nz_b[:-1]
        prev[0] = -1
        prev[1:] = nz_c[:-1]
        prev[first] = -1
    run = nz_c - prev - 1
    zrl = run >> 4                          # ZRL expansions before the symbol
    coef_sym = ((run & 15) << 4) | ac_cat_nz
    unit = zrl + 1                          # symbols one coefficient emits

    # per-block symbol budget: 1 DC + coefficient units + optional EOB
    unit_b = np.bincount(nz_b, weights=unit, minlength=n).astype(np.int64)
    last_c = np.full(n, -1, dtype=np.int64)
    last_c[nz_b] = nz_c                     # row-major: last write is max pos
    eob_b = last_c != AC_LEN - 1
    block_total = 1 + unit_b + eob_b
    block_off = np.concatenate(
        [np.zeros(1, np.int64), np.cumsum(block_total)[:-1]])
    m = int(block_total.sum())

    is_dc = np.zeros(m, dtype=bool)
    syms = np.empty(m, dtype=np.int64)
    amp_vals = np.zeros(m, dtype=np.int64)
    amp_lens = np.zeros(m, dtype=np.int64)

    is_dc[block_off] = True
    syms[block_off] = dc_cat
    amp_vals[block_off] = dc_amp
    amp_lens[block_off] = dc_cat
    syms[(block_off + block_total - 1)[eob_b]] = EOB

    if k:
        # global start of each coefficient's unit: block start + 1 (DC)
        # + the within-block exclusive cumsum of earlier units
        cu = np.cumsum(unit) - unit
        base = cu[first][np.cumsum(first) - 1]     # cu at block's first coef
        start = block_off[nz_b] + 1 + (cu - base)
        coded = start + zrl
        syms[coded] = coef_sym
        amp_vals[coded] = ac_amp_nz
        amp_lens[coded] = ac_cat_nz
        total_zrl = int(zrl.sum())
        if total_zrl:
            # expand each run's ZRL slots: start .. start+zrl-1
            zc = np.cumsum(zrl) - zrl
            pos = (np.repeat(start, zrl)
                   + np.arange(total_zrl, dtype=np.int64)
                   - np.repeat(zc, zrl))
            syms[pos] = ZRL
    return is_dc, syms, amp_vals, amp_lens


def symbolize_reference(dc_diff: np.ndarray, ac: np.ndarray) -> tuple:
    """Scalar per-block oracle for :func:`symbolize` (same contract).

    The original loop implementation, kept as the golden reference the
    property tests and ``--check-identical`` bench gate compare the
    vectorised path against.  Not used on the production encode path.
    """
    dc_diff = np.asarray(dc_diff, dtype=np.int64)
    ac = np.asarray(ac, dtype=np.int64)
    n = dc_diff.shape[0]
    dc_cat = magnitude_category(dc_diff)
    _check_range(dc_cat, "DC difference")
    ac_cat = magnitude_category(ac)
    _check_range(ac_cat, "AC coefficient")
    dc_amp = amplitude_value(dc_diff, dc_cat)
    ac_amp = amplitude_value(ac, ac_cat)

    is_dc, syms, amp_vals, amp_lens = [], [], [], []
    for b in range(n):
        is_dc.append(True)
        syms.append(int(dc_cat[b]))
        amp_vals.append(int(dc_amp[b]))
        amp_lens.append(int(dc_cat[b]))
        nz = np.nonzero(ac[b])[0]
        prev = -1
        for pos in nz:
            run = int(pos) - prev - 1
            while run >= 16:
                is_dc.append(False)
                syms.append(ZRL)
                amp_vals.append(0)
                amp_lens.append(0)
                run -= 16
            is_dc.append(False)
            syms.append((run << 4) | int(ac_cat[b, pos]))
            amp_vals.append(int(ac_amp[b, pos]))
            amp_lens.append(int(ac_cat[b, pos]))
            prev = int(pos)
        if prev != AC_LEN - 1:
            is_dc.append(False)
            syms.append(EOB)
            amp_vals.append(0)
            amp_lens.append(0)
    return (np.asarray(is_dc, dtype=bool),
            np.asarray(syms, dtype=np.int64),
            np.asarray(amp_vals, dtype=np.int64),
            np.asarray(amp_lens, dtype=np.int64))


def symbol_frequencies(is_dc, syms) -> tuple:
    """(dc_freqs, ac_freqs): 256-bin histograms of the two alphabets."""
    dc = np.bincount(syms[is_dc], minlength=256)
    ac = np.bincount(syms[~is_dc], minlength=256)
    return dc, ac


def codeword_fields(is_dc, syms, amp_vals, amp_lens,
                    dc_table: huffman.CanonicalTable,
                    ac_table: huffman.CanonicalTable) -> tuple:
    """Codeword-lookup stage: symbol stream -> interleaved bit fields.

    Every symbol contributes its Huffman code, immediately followed by
    its amplitude field (when present); the interleave is realised by
    laying codes at even and amplitudes at odd slots of a (2M,) field
    array — packers drop the zero-width slots.

    Returns:
        ``(fields, widths)`` int64 arrays ready for any bit packer
        (:func:`repro.core.entropy.bitio.pack_bits` or the routed
        :mod:`repro.kernels.pack_bits` backend).

    Raises:
        ValueError: the stream contains a symbol the table cannot code
            (possible with shared tables; the container's cost-based
            selection never picks an uncovering table).
    """
    dc_code, dc_len = huffman.encoder_luts(dc_table)
    ac_code, ac_len = huffman.encoder_luts(ac_table)
    codes = np.where(is_dc, dc_code[syms], ac_code[syms])
    lens = np.where(is_dc, dc_len[syms], ac_len[syms])
    if bool((lens == 0).any()):
        raise ValueError("symbol stream contains a symbol absent from "
                         "the Huffman table")
    m = syms.shape[0]
    fields = np.empty(2 * m, dtype=np.int64)
    widths = np.empty(2 * m, dtype=np.int64)
    fields[0::2], widths[0::2] = codes, lens
    fields[1::2], widths[1::2] = amp_vals, amp_lens
    return fields, widths


def encode_payload(is_dc, syms, amp_vals, amp_lens,
                   dc_table: huffman.CanonicalTable,
                   ac_table: huffman.CanonicalTable,
                   packer=None) -> bytes:
    """Huffman-code the symbol stream and pack it into bytes.

    Two explicit stages of the staged encode pipeline: codeword lookup
    (:func:`codeword_fields`) then bit packing.  ``packer`` selects the
    packing backend — a ``(fields, widths) -> bytes`` callable, e.g.
    the routed :func:`repro.kernels.pack_bits.pack_bits`; ``None`` uses
    the NumPy reference :func:`repro.core.entropy.bitio.pack_bits`.
    Every backend is byte-identical by contract (CI-gated).
    """
    fields, widths = codeword_fields(is_dc, syms, amp_vals, amp_lens,
                                     dc_table, ac_table)
    return (packer or bitio.pack_bits)(fields, widths)


class PreparedStream:
    """Two-phase symbolisation: histograms first, payload on demand.

    The shape the container's table negotiation needs — it must see the
    per-alphabet histograms *before* it can pick tables, and only then
    can codeword lookup and packing run.  This default implementation
    wraps the vectorised host pipeline (:func:`symbolize` →
    :func:`symbol_frequencies` → :func:`encode_payload`); the routed
    alternatives (:func:`repro.kernels.symbolize.make_symbolizer`)
    expose the same two attributes and method over the fused dense pass
    or the device-resident chain, byte-identically (CI-gated).
    """

    def __init__(self, dc_diff: np.ndarray, ac: np.ndarray, packer=None):
        self._stream = symbolize(dc_diff, ac)
        self._packer = packer
        self.dc_freq, self.ac_freq = symbol_frequencies(
            self._stream[0], self._stream[1])

    def payload(self, dc_table: huffman.CanonicalTable,
                ac_table: huffman.CanonicalTable) -> bytes:
        """Huffman-code + pack the prepared stream for chosen tables."""
        return encode_payload(*self._stream, dc_table, ac_table,
                              packer=self._packer)


def prepare_stream(dc_diff: np.ndarray, ac: np.ndarray,
                   packer=None) -> PreparedStream:
    """The default ``symbolizer=`` backend: vectorised host pipeline."""
    return PreparedStream(dc_diff, ac, packer=packer)


_PAST_END = 32     # sentinel slots appended past the last window position

# packed per-position decode word: (ctrl + 2) << 23 | adv << 17 |
# (val + 32768); ctrl is the symbol byte, -1 = invalid prefix, -2 =
# a unit that needs bits past the payload end (truncation)
_CTRL_SHIFT = 23
_ADV_SHIFT = 17
_ADV_MASK = 0x3F
_VAL_MASK = 0x1FFFF
_VAL_BIAS = 32768
_SENTINEL = _VAL_BIAS      # ctrl -2, adv 0, val 0

# payloads up to this many bits get their packed tables converted to
# Python lists (~36 bytes per boxed entry, but the walk indexes them
# ~2.5x faster than ndarray scalars); larger payloads keep the int64
# ndarray so decode memory stays at 8 bytes per bit position per table
_WALK_LIST_MAX_BITS = 1 << 20

# payloads above this many bits are routed to the staged decoder
# (:func:`repro.kernels.unpack_bits.unpack_bits`, which selects its own
# backend) when :func:`decode_payload` is called without an ``unpacker``:
# the LUT walk's tables grow linearly with the payload
# (:func:`walk_table_nbytes` — ~16 B/bit across both alphabets on the
# ndarray branch) while the staged decoder's scratch is bounded per tile
# (:func:`repro.kernels.unpack_bits.ref.scratch_nbytes`), so a 100 MB
# payload costs ~13 GB of walk tables but < 3 MB of staged scratch
_ROUTED_DECODE_MIN_BITS = _WALK_LIST_MAX_BITS


def _decode_table(win: np.ndarray, nbits: int,
                  table: huffman.CanonicalTable):
    """Per-bit-position packed decode table for one Huffman alphabet.

    For every bit offset ``p`` of the payload (``win`` is its
    :func:`repro.core.entropy.bitio.bit_windows`, 1-padded past the end
    like the writer), assume a symbol of ``table`` starts at ``p`` and
    precompute — fully vectorised — one packed int per position holding:

    * ``ctrl`` — the decoded symbol byte, -1 for an invalid prefix, or
      -2 when the unit starting at ``p`` would need bits past the
      payload end (truncation, exactly when the reference reader's
      skip/take would run out),
    * ``adv``  — total bits the unit spans (code + amplitude field),
    * ``val``  — the amplitude field decoded to its signed value (for
      DC the field width is the symbol itself; for AC its low nibble —
      callers pick the table accordingly).

    Only the walk along the actual symbol chain (data-dependent) stays
    in Python; each step is one O(1) lookup plus shifts.  Returns a
    Python list for small payloads and the int64 ndarray above
    :data:`_WALK_LIST_MAX_BITS` (same indexing, bounded memory).
    """
    sym_lut, len_lut = huffman.decoder_luts(table)
    n = win.shape[0]
    # intermediates stay int32 (all values fit 17 bits) so the per-bit
    # precompute peaks at a few int32 arrays, not int64 ones; only the
    # final packed word widens to int64 (ctrl << 23 needs 32+ bits and
    # the walk's ndarray branch relies on signed arithmetic)
    sym = sym_lut[win].astype(np.int32)
    length = len_lut[win].astype(np.int32)
    # amplitude width: DC symbols *are* the width; AC keep the low nibble
    # (EOB=0x00 and ZRL=0xF0 both have a zero nibble => no field)
    size = np.where(sym > MAX_CATEGORY, sym & 0xF, sym)
    pidx = np.arange(n, dtype=np.int64)
    amp_at = np.minimum(pidx + length, n - 1)
    safe = np.maximum(size, 1)
    bits = win[amp_at].astype(np.int32) >> (bitio.MAX_FIELD_BITS - safe)
    val = np.where(bits < (1 << (safe - 1)), bits - (1 << safe) + 1, bits)
    val = np.where(size == 0, 0, val)
    ctrl = np.where(length == 0, 1, sym + 2)        # ctrl field, biased +2
    packed = ((ctrl.astype(np.int64) << _CTRL_SHIFT)
              | ((length + size).astype(np.int64) << _ADV_SHIFT)
              | (val + _VAL_BIAS))
    # a unit that would consume any bit past the payload end is
    # truncation, not decoding (mirrors the reference reader, which
    # raises before interpreting such bits); folding it into the packed
    # word keeps the walk at one branch per symbol, and the sentinel
    # tail covers any p a step can reach (a step advances < _PAST_END
    # bits) before the walk raises
    packed[pidx + length + size > nbits] = _SENTINEL
    packed = np.concatenate(
        [packed, np.full(_PAST_END, _SENTINEL, np.int64)])
    if nbits <= _WALK_LIST_MAX_BITS:
        return packed.tolist()
    return packed


def _staged_unpacker():
    """The routed staged decoder, or ``None`` without the kernels layer.

    Lazy so :mod:`repro.core.entropy` itself stays importable (and
    cheap) without jax — the import only runs for payloads above
    :data:`_ROUTED_DECODE_MIN_BITS`, and a missing/broken kernels layer
    falls back to the linear-memory ndarray walk rather than failing.
    """
    try:
        from repro.kernels import unpack_bits
    except Exception:       # pragma: no cover - kernels layer optional
        return None
    return unpack_bits.unpack_bits


def walk_table_nbytes(nbits: int) -> int:
    """Approximate resident bytes of both LUT-walk decode tables.

    :func:`_decode_table` materialises one packed word per payload bit
    position *per alphabet* — ~36 bytes per boxed entry on the
    list branch, 8 on the ndarray branch past
    :data:`_WALK_LIST_MAX_BITS` — so the walk's decode memory scales
    linearly with the payload.  The ``entropy_decode`` bench case
    reports this against the staged decoder's bounded per-tile scratch
    (:func:`repro.kernels.unpack_bits.ref.scratch_nbytes`).
    """
    entries = 2 * (nbits + 17 + _PAST_END)
    return entries * (36 if nbits <= _WALK_LIST_MAX_BITS else 8)


def decode_payload(payload: bytes, n_blocks: int,
                   dc_table: huffman.CanonicalTable,
                   ac_table: huffman.CanonicalTable, *,
                   unpacker=None) -> tuple:
    """Decode ``n_blocks`` blocks from an entropy payload (LUT decoder).

    Replaces bit-at-a-time Huffman walking: the peek-16 prefix LUTs of
    both tables are applied to *every* bit position of the payload in
    one vectorised pass (:func:`_decode_table`), including amplitude
    extraction, so the remaining Python walk just follows the symbol
    chain with O(1) lookups per symbol.  Output is identical to
    :func:`decode_payload_reference` on every well-formed stream;
    malformed streams are always rejected by both, though the error
    *subtype* (truncation vs corruption) can differ in corner cases
    where padding bits mimic a valid symbol.

    Args:
        payload: packed bits from :func:`encode_payload`.
        n_blocks: how many 8x8 blocks the stream must contain (known
            from the container's image shape).
        dc_table: canonical table for DC categories; a table coding a
            symbol above :data:`MAX_CATEGORY` is rejected (the spec
            bounds DC categories to 0..15).
        ac_table: canonical table for AC (run, size) symbols.
        unpacker: optional ``(payload, n_blocks, dc_table, ac_table) ->
            (dc_diff, ac)`` callable replacing the whole decode, e.g.
            the routed :func:`repro.kernels.unpack_bits.unpack_bits`;
            ``None`` keeps the zero-indirection LUT walk below for
            payloads up to :data:`_ROUTED_DECODE_MIN_BITS` bits and
            routes larger ones to the staged decoder itself (the walk
            tables grow linearly with the payload; the staged scratch
            is bounded per tile).  Any
            unpacker must honour this function's full contract —
            values *and* errors (CI-gated by ``bench_entropy_throughput
            --check-identical``).

    Returns:
        ``(dc_diff, ac)`` — (n,) int32 DC differences and (n, 63) int32
        AC tails, exactly inverting :func:`symbolize`.

    Raises:
        bitio.TruncatedStream: the payload ends mid-block.
        ValueError: an invalid Huffman prefix, a coefficient overrun, or
            an out-of-spec DC table (corrupted stream).
    """
    if unpacker is not None:
        return unpacker(payload, n_blocks, dc_table, ac_table)
    if dc_table.symbols and max(dc_table.symbols) > MAX_CATEGORY:
        raise ValueError(
            f"DC table codes symbol {max(dc_table.symbols)} > "
            f"{MAX_CATEGORY}: not a magnitude-category alphabet")
    nbits = len(payload) * 8
    if nbits > _ROUTED_DECODE_MIN_BITS:
        # the walk tables below would cost ~16 B per payload bit; route
        # big payloads to the staged decoder's bounded per-tile scratch
        # (it picks its own backend via unpack_bits.select_backend)
        unpack = _staged_unpacker()
        if unpack is not None:
            return unpack(payload, n_blocks, dc_table, ac_table)
    win = bitio.bit_windows(payload)
    dc_tab = _decode_table(win, nbits, dc_table)
    ac_tab = _decode_table(win, nbits, ac_table)

    def bad(s: int, p: int, what: str):
        if s == -2:
            return bitio.TruncatedStream(
                f"entropy payload truncated: needed bit {p} of {nbits}")
        return ValueError(f"invalid {what} Huffman prefix at bit {p}")

    dc_out = [0] * n_blocks
    rows: list = []
    cols: list = []
    vals: list = []
    p = 0
    for b in range(n_blocks):
        x = dc_tab[p]
        s = (x >> _CTRL_SHIFT) - 2
        if s < 0:
            raise bad(s, p, "DC")
        dc_out[b] = (x & _VAL_MASK) - _VAL_BIAS
        p += (x >> _ADV_SHIFT) & _ADV_MASK
        pos = 0                     # next AC slot to fill (0-based in ac)
        while pos < AC_LEN:
            x = ac_tab[p]
            s = (x >> _CTRL_SHIFT) - 2
            if s <= 0:
                if s < 0:
                    raise bad(s, p, "AC")
                p += (x >> _ADV_SHIFT) & _ADV_MASK   # EOB: rest is zero
                break
            if s == ZRL:
                pos += 16
                p += (x >> _ADV_SHIFT) & _ADV_MASK
                continue
            pos += s >> 4
            if pos >= AC_LEN:
                raise ValueError(
                    f"corrupted stream: AC run overruns block {b}")
            rows.append(b)
            cols.append(pos)
            vals.append((x & _VAL_MASK) - _VAL_BIAS)
            p += (x >> _ADV_SHIFT) & _ADV_MASK
            pos += 1
    if p > nbits:
        raise bitio.TruncatedStream(
            f"entropy payload truncated: needed bit {p} of {nbits}")
    ac = np.zeros((n_blocks, AC_LEN), dtype=np.int32)
    if rows:
        ac[rows, cols] = vals
    return np.asarray(dc_out, dtype=np.int32), ac


def decode_payload_reference(payload: bytes, n_blocks: int,
                             dc_table: huffman.CanonicalTable,
                             ac_table: huffman.CanonicalTable) -> tuple:
    """Bit-at-a-time oracle for :func:`decode_payload` (same contract).

    The original :class:`repro.core.entropy.bitio.BitReader` walk, kept
    as the golden reference for the property tests and the
    ``--check-identical`` bench gate.  Not on the production path.
    """
    dc_sym, dc_len = dc_table.decoder_lut()
    ac_sym, ac_len = ac_table.decoder_lut()
    reader = bitio.BitReader(payload)
    dc_diff = np.zeros(n_blocks, dtype=np.int32)
    ac = np.zeros((n_blocks, AC_LEN), dtype=np.int32)
    for b in range(n_blocks):
        w = reader.peek16()
        length = int(dc_len[w])
        if length == 0:
            raise ValueError(f"invalid DC Huffman prefix at bit "
                             f"{reader.pos}")
        reader.skip(length)
        size = int(dc_sym[w])
        dc_diff[b] = amplitude_decode(reader.take(size), size)
        pos = 0                     # next AC slot to fill (0-based in ac)
        while pos < AC_LEN:
            w = reader.peek16()
            length = int(ac_len[w])
            if length == 0:
                raise ValueError(f"invalid AC Huffman prefix at bit "
                                 f"{reader.pos}")
            reader.skip(length)
            sym = int(ac_sym[w])
            if sym == EOB:
                break
            if sym == ZRL:
                pos += 16
                continue
            run, size = sym >> 4, sym & 0xF
            pos += run
            if pos >= AC_LEN:
                raise ValueError(
                    f"corrupted stream: AC run overruns block {b}")
            ac[b, pos] = amplitude_decode(reader.take(size), size)
            pos += 1
    return dc_diff, ac
