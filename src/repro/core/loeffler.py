"""Loeffler 8-point DCT flow graph (exact, 11-multiplication form).

This is the factorisation the paper's "Cordic based Loeffler DCT" is derived
from (Loeffler/Ligtenberg/Moshytz 1989; Sun/Heyne/Ruan/Götze 2006).  The graph
has 4 serial stages (the paper notes the stages are data-dependent and must
execute serially, while everything *inside* a stage is parallel):

  stage 1: 4 input butterflies  (x_i ± x_{7-i})
  stage 2: even: 2 butterflies · odd: two plane rotations (3π/16 and π/16)
  stage 3: even: butterfly + one rotation (π/8) · odd: 4 butterflies
  stage 4: odd: two √2 output scalings

Outputs here are **orthonormal** (same convention as core.dct), so this graph
is bit-comparable with ``dct.dct1d`` up to float round-off — the unit tests
assert that.  The CORDIC variant replaces the three plane rotations with
shift-add micro-rotations (see core.cordic); the rotation call is injectable
via ``rotate_fn`` precisely so both variants share one graph definition.
"""

from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp

# Rotation angles used by the graph.
THETA_ODD_A = 3.0 * math.pi / 16.0   # rotates (d3, d0)
THETA_ODD_B = 1.0 * math.pi / 16.0   # rotates (d2, d1)
THETA_EVEN = math.pi / 8.0           # rotates (b2, b3) -> (X2, X6)

_SQRT2 = math.sqrt(2.0)
_INV_2SQRT2 = 1.0 / (2.0 * _SQRT2)


def exact_rotate(u: jnp.ndarray, v: jnp.ndarray, theta: float):
    """Plane rotation: (u, v) -> (u cosθ + v sinθ, -u sinθ + v cosθ)."""
    c, s = math.cos(theta), math.sin(theta)
    return u * c + v * s, -u * s + v * c


RotateFn = Callable[[jnp.ndarray, jnp.ndarray, float], tuple]


def loeffler_dct8(x: jnp.ndarray, axis: int = -1,
                  rotate_fn: RotateFn = exact_rotate,
                  quantize_fn=None) -> jnp.ndarray:
    """Orthonormal 8-point DCT-II along ``axis`` via the Loeffler graph.

    ``rotate_fn(u, v, theta)`` implements the plane rotation; pass
    ``cordic.cordic_rotate`` to obtain the paper's Cordic-based variant.
    ``quantize_fn`` (optional) is applied to every stage output, emulating
    the fixed-point register grid of the short-word-length hardware the
    Cordic-Loeffler design targets (see core.cordic.fixed_quantizer).
    """
    q = quantize_fn if quantize_fn is not None else (lambda t: t)
    x = jnp.moveaxis(x, axis, 0)
    if x.shape[0] != 8:
        raise ValueError(f"loeffler_dct8 needs length-8 axis, got {x.shape}")
    x0, x1, x2, x3, x4, x5, x6, x7 = [x[i] for i in range(8)]

    # ---- stage 1: butterflies ------------------------------------------
    a0 = q(x0 + x7)
    a1 = q(x1 + x6)
    a2 = q(x2 + x5)
    a3 = q(x3 + x4)
    d3 = q(x3 - x4)   # a4 in the paper's figure
    d2 = q(x2 - x5)   # a5
    d1 = q(x1 - x6)   # a6
    d0 = q(x0 - x7)   # a7

    # ---- stage 2: even butterflies · odd rotations ---------------------
    b0 = q(a0 + a3)
    b1 = q(a1 + a2)
    b2 = q(a1 - a2)
    b3 = q(a0 - a3)
    r4, r7 = rotate_fn(d3, d0, THETA_ODD_A)   # c3-rotator
    r5, r6 = rotate_fn(d2, d1, THETA_ODD_B)   # c1-rotator

    # ---- stage 3: even output butterfly + rotation · odd butterflies ---
    y0 = q(b0 + b1)
    y4 = q(b0 - b1)
    c4 = q(r4 + r6)
    c5 = q(r7 - r5)
    c6 = q(r4 - r6)
    c7 = q(r7 + r5)

    # Even rotation outputs: X2 = (b3 cos(π/8) + b2 sin(π/8)) / 2 and
    # X6 = (b3 sin(π/8) - b2 cos(π/8)) / 2, i.e. the plane rotation applied
    # to the swapped pair (b3, b2):
    z2, z6 = rotate_fn(b3, b2, THETA_EVEN)
    # z2 = b3 c + b2 s = 2·X2;  z6 = -b3 s + b2 c = -2·X6

    # ---- stage 4: output scalings --------------------------------------
    out = [None] * 8
    out[0] = q(y0 * _INV_2SQRT2)
    out[4] = q(y4 * _INV_2SQRT2)
    out[2] = q(z2 * 0.5)
    out[6] = q(-z6 * 0.5)
    out[1] = q((c4 + c7) * _INV_2SQRT2)
    out[7] = q((c7 - c4) * _INV_2SQRT2)
    out[3] = q(c5 * 0.5)
    out[5] = q(c6 * 0.5)

    y = jnp.stack(out, axis=0)
    return jnp.moveaxis(y, 0, axis)


def loeffler_idct8(y: jnp.ndarray, axis: int = -1,
                   rotate_fn: RotateFn = exact_rotate,
                   quantize_fn=None) -> jnp.ndarray:
    """Inverse (DCT-III) via the transposed flow graph.

    For the exact rotation the graph is orthonormal so the inverse is the
    exact transpose; we implement the transpose explicitly (stages reversed,
    butterflies transposed, rotations by -θ) so that the CORDIC variant's
    inverse uses CORDIC rotations too — matching the paper's pipeline where
    the IDCT kernel is also CORDIC-based.
    """
    q = quantize_fn if quantize_fn is not None else (lambda t: t)
    y = jnp.moveaxis(y, axis, 0)
    if y.shape[0] != 8:
        raise ValueError(f"loeffler_idct8 needs length-8 axis, got {y.shape}")
    Y0, Y1, Y2, Y3, Y4, Y5, Y6, Y7 = [y[i] for i in range(8)]

    # transpose of stage 4
    y0 = q(Y0 * _INV_2SQRT2)
    y4 = q(Y4 * _INV_2SQRT2)
    c4 = q((Y1 - Y7) * _INV_2SQRT2)
    c7 = q((Y1 + Y7) * _INV_2SQRT2)
    c5 = q(Y3 * 0.5)
    c6 = q(Y5 * 0.5)
    z2 = q(Y2 * 0.5)
    z6 = q(-Y6 * 0.5)

    # transpose of stage 3
    b0 = q(y0 + y4)
    b1 = q(y0 - y4)
    # (z2, z6) = R(θ) @ (b3, b2)  =>  (b3, b2) = R(-θ) @ (z2, z6)
    b3, b2 = rotate_fn(z2, z6, -THETA_EVEN)
    r4 = q(c4 + c6)
    r6 = q(c4 - c6)
    r7 = q(c7 + c5)
    r5 = q(c7 - c5)

    # transpose of stage 2
    a0 = q(b0 + b3)
    a3 = q(b0 - b3)
    a1 = q(b1 + b2)
    a2 = q(b1 - b2)
    d3, d0 = rotate_fn(r4, r7, -THETA_ODD_A)
    d2, d1 = rotate_fn(r5, r6, -THETA_ODD_B)

    # transpose of stage 1 (plain butterfly transpose — the orthonormal
    # scaling was already applied by the diagonal above)
    x0 = q(a0 + d0)
    x7 = q(a0 - d0)
    x1 = q(a1 + d1)
    x6 = q(a1 - d1)
    x2 = q(a2 + d2)
    x5 = q(a2 - d2)
    x3 = q(a3 + d3)
    x4 = q(a3 - d3)

    x = jnp.stack([x0, x1, x2, x3, x4, x5, x6, x7], axis=0)
    return jnp.moveaxis(x, 0, axis)


def loeffler_dct2d_8x8(blocks: jnp.ndarray,
                       rotate_fn: RotateFn = exact_rotate,
                       quantize_fn=None) -> jnp.ndarray:
    """2-D 8x8 DCT on (..., 8, 8) blocks via two separable graph passes."""
    once = loeffler_dct8(blocks, axis=-1, rotate_fn=rotate_fn,
                         quantize_fn=quantize_fn)
    return loeffler_dct8(once, axis=-2, rotate_fn=rotate_fn,
                         quantize_fn=quantize_fn)


def loeffler_idct2d_8x8(coeffs: jnp.ndarray,
                        rotate_fn: RotateFn = exact_rotate,
                        quantize_fn=None) -> jnp.ndarray:
    """Inverse of :func:`loeffler_dct2d_8x8`."""
    once = loeffler_idct8(coeffs, axis=-2, rotate_fn=rotate_fn,
                          quantize_fn=quantize_fn)
    return loeffler_idct8(once, axis=-1, rotate_fn=rotate_fn,
                          quantize_fn=quantize_fn)
