"""repro.core — the paper's contribution: blockwise DCT image compression.

Modules:
  dct       exact orthonormal DCT (matrix + Kronecker MXU forms)
  loeffler  Loeffler 8-point flow graph (exact rotations)
  cordic    CORDIC micro-rotation approximation (the paper's variant)
  quant     JPEG-style quantiser
  codec     compress / decompress / roundtrip pipeline
  metrics   PSNR / MSE per the paper's definitions
  images    synthetic stand-ins for the paper's test images
"""

from repro.core import cordic, dct, images, loeffler, metrics, quant, codec  # noqa: F401
