"""repro.core — the paper's contribution: blockwise DCT image compression.

Modules:
  dct       exact orthonormal DCT (matrix + Kronecker MXU forms)
  loeffler  Loeffler 8-point flow graph (exact rotations)
  cordic    CORDIC micro-rotation approximation (the paper's variant)
  quant     JPEG-style quantiser
  codec     compress / decompress / roundtrip pipeline
  metrics   PSNR / MSE per the paper's definitions
  images    synthetic stand-ins for the paper's test images
  entropy   lossless bitstream tail (jax-free at import)

Submodules load lazily (PEP 562): ``from repro.core import dct`` works
exactly as before, but ``import repro.core.entropy`` no longer drags in
the jax array stack — which is what lets the codec engine's
process-pool decode workers spawn with a NumPy-only import footprint.
"""

_SUBMODULES = ("codec", "cordic", "dct", "entropy", "images", "loeffler",
               "metrics", "quant")


def __getattr__(name):
    if name in _SUBMODULES:
        import importlib
        module = importlib.import_module(f"repro.core.{name}")
        globals()[name] = module
        return module
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_SUBMODULES))
