"""Exact DCT-II / DCT-III (inverse) transforms.

Conventions
-----------
All transforms here are *orthonormal* (DCT-II with alpha(0)=sqrt(1/N),
alpha(k)=sqrt(2/N)), so ``idct == dct.T`` and Parseval holds exactly:
``||dct(x)||_2 == ||x||_2``.  This is the reference ("exact DCT") path the
paper compares the Cordic-based Loeffler DCT against (paper eq. (3)/(6)).

Two blockwise formulations are provided — they are mathematically identical
but map differently onto TPU hardware (see DESIGN.md §2):

* separable:  ``Y = C @ X @ C.T`` per 8x8 block (two small matmuls),
* kron:       ``vec(Y) = (C ⊗ C) @ vec(X)`` — one (nblocks, 64) @ (64, 64)
              matmul, which is the MXU-friendly form used by the Pallas
              kernels.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np


@functools.lru_cache(maxsize=None)
def _dct_matrix_np(n: int) -> np.ndarray:
    """Orthonormal DCT-II matrix C, shape (n, n):  X = C @ x."""
    k = np.arange(n)[:, None].astype(np.float64)
    i = np.arange(n)[None, :].astype(np.float64)
    mat = np.cos(np.pi * k * (2.0 * i + 1.0) / (2.0 * n))
    mat *= np.sqrt(2.0 / n)
    mat[0] *= 1.0 / np.sqrt(2.0)
    return mat


def dct_matrix(n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Orthonormal DCT-II matrix of size (n, n)."""
    return jnp.asarray(_dct_matrix_np(n), dtype=dtype)


def kron_dct_matrix(n: int = 8, dtype=jnp.float32) -> jnp.ndarray:
    """(n*n, n*n) operator T with vec(Y) = T @ vec(X) for Y = C X C^T.

    vec() is row-major.  T = kron(C, C).
    """
    c = _dct_matrix_np(n)
    return jnp.asarray(np.kron(c, c), dtype=dtype)


def dct1d(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Orthonormal DCT-II along ``axis``."""
    n = x.shape[axis]
    c = dct_matrix(n, x.dtype)
    x = jnp.moveaxis(x, axis, -1)
    y = x @ c.T
    return jnp.moveaxis(y, -1, axis)


def idct1d(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Orthonormal inverse DCT (DCT-III) along ``axis``."""
    n = x.shape[axis]
    c = dct_matrix(n, x.dtype)
    x = jnp.moveaxis(x, axis, -1)
    y = x @ c
    return jnp.moveaxis(y, -1, axis)


def dct2d(x: jnp.ndarray) -> jnp.ndarray:
    """Orthonormal 2-D DCT-II over the last two axes (paper eq. (6))."""
    return dct1d(dct1d(x, axis=-1), axis=-2)


def idct2d(x: jnp.ndarray) -> jnp.ndarray:
    """Orthonormal 2-D inverse DCT over the last two axes."""
    return idct1d(idct1d(x, axis=-1), axis=-2)


# ---------------------------------------------------------------------------
# Blockwise forms
# ---------------------------------------------------------------------------

def to_blocks(img: jnp.ndarray, block: int = 8) -> jnp.ndarray:
    """(..., H, W) -> (..., H//b, W//b, b, b).  H, W must divide by b."""
    *lead, h, w = img.shape
    if h % block or w % block:
        raise ValueError(f"image {h}x{w} not divisible by block {block}")
    x = img.reshape(*lead, h // block, block, w // block, block)
    # (..., hb, b, wb, b) -> (..., hb, wb, b, b)
    return jnp.swapaxes(x, -3, -2)


def from_blocks(blocks: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`to_blocks`."""
    *lead, hb, wb, b, b2 = blocks.shape
    assert b == b2, blocks.shape
    x = jnp.swapaxes(blocks, -3, -2)
    return x.reshape(*lead, hb * b, wb * b)


def blockwise_dct2d(img: jnp.ndarray, block: int = 8) -> jnp.ndarray:
    """Blockwise 2-D DCT.  (..., H, W) -> (..., H//b, W//b, b, b) coeffs."""
    blocks = to_blocks(img, block)
    return dct2d(blocks)


def blockwise_idct2d(coeffs: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`blockwise_dct2d` back to (..., H, W)."""
    return from_blocks(idct2d(coeffs))


def blockwise_dct2d_kron(img: jnp.ndarray, block: int = 8) -> jnp.ndarray:
    """Same as :func:`blockwise_dct2d` via the single-matmul Kronecker form."""
    t = kron_dct_matrix(block, img.dtype)
    blocks = to_blocks(img, block)
    *lead, hb, wb, b, _ = blocks.shape
    flat = blocks.reshape(*lead, hb, wb, b * b)
    out = flat @ t.T
    return out.reshape(*lead, hb, wb, b, b)


def blockwise_idct2d_kron(coeffs: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`blockwise_dct2d_kron` (T is orthonormal: inv = T.T)."""
    *lead, hb, wb, b, _ = coeffs.shape
    t = kron_dct_matrix(b, coeffs.dtype)
    flat = coeffs.reshape(*lead, hb, wb, b * b)
    out = flat @ t
    return from_blocks(out.reshape(*lead, hb, wb, b, b))
