"""The paper's pipeline: blockwise DCT -> quantise -> (dequantise) -> IDCT.

``compress`` / ``decompress`` are the public codec API; ``roundtrip`` is the
exact experiment the paper runs (compress then reconstruct, then PSNR against
the original).  ``transform`` selects:

* ``"exact"``   — orthonormal matrix DCT (paper's reference "DCT"),
* ``"cordic"``  — Cordic-based Loeffler DCT (the paper's subject),
* ``"loeffler"``— Loeffler graph with exact rotations (sanity bridge: must
                  match "exact" to float round-off).

Images of sizes not divisible by 8 (e.g. the paper's 1024x814) are padded
with edge replication and cropped back on reconstruction.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import cordic, dct, loeffler, metrics, quant

Transform = Literal["exact", "cordic", "loeffler"]


@dataclasses.dataclass
class CompressedImage:
    """Quantised DCT representation of a single grayscale image.

    ``to_bytes``/``from_bytes`` round-trip through the entropy-coded
    ``DCTZ`` container (:mod:`repro.core.entropy`) losslessly, so the
    ``nbytes`` property is the *measured* compressed size.  (The old
    ``nbytes_estimate`` heuristic is gone; the one surviving
    device-side estimator is :func:`repro.core.quant.estimate_bits`,
    for telemetry that cannot afford bit packing.)
    """
    qcoeffs: jnp.ndarray          # (H/8, W/8, 8, 8) int32 quantised levels
    quality: int
    transform: str
    orig_shape: tuple             # (H, W) before padding
    cordic_config: cordic.CordicConfig | None = None
    _nbytes_cache: int | None = dataclasses.field(
        default=None, repr=False, compare=False)

    def to_bytes(self, *, tables: str = "auto") -> bytes:
        """Serialise as one entropy-coded ``DCTZ`` stream (lossless over
        the quantised levels; layout in docs/bitstream.md).

        Args:
            tables: Huffman table policy — "auto" picks, per alphabet,
                whichever of the per-stream (embedded) or well-known
                shared table codes the stream more cheaply; "embedded"
                forces the version-1 layout; "shared" forces the shared
                ids (see :func:`repro.core.entropy.encode_qcoeffs`).
        """
        from repro.core import entropy
        return entropy.encode_qcoeffs(self.qcoeffs, self.quality,
                                      self.transform, self.orig_shape,
                                      tables=tables)

    @classmethod
    def from_bytes(cls, data: bytes, *,
                   unpacker=None) -> "CompressedImage":
        """Parse a ``DCTZ`` stream back into a :class:`CompressedImage`.

        The stream does not carry a CORDIC config (it only matters for
        ``mode="matched"`` decodes); the paper's config is assumed.

        Args:
            data: one complete ``DCTZ`` stream.
            unpacker: optional payload-decode backend (see
                :func:`repro.core.entropy.decode_zigzag_host`).

        Raises:
            repro.core.entropy.BitstreamError: malformed stream.
        """
        from repro.core import entropy
        qcoeffs, hdr = entropy.decode_qcoeffs(data, unpacker=unpacker)
        return cls(qcoeffs=qcoeffs, quality=hdr["quality"],
                   transform=hdr["transform"],
                   orig_shape=(hdr["height"], hdr["width"]),
                   cordic_config=None, _nbytes_cache=len(data))

    @property
    def nbytes(self) -> int:
        """Measured size in bytes of the entropy-coded stream (cached)."""
        if self._nbytes_cache is None:
            self._nbytes_cache = len(self.to_bytes())
        return self._nbytes_cache

    def compression_ratio(self) -> float:
        """original bytes / *measured* entropy-coded bytes."""
        h, w = self.orig_shape
        return (h * w) / float(self.nbytes)


def pad_to_block(img: jnp.ndarray, block: int = 8) -> jnp.ndarray:
    """Edge-replicate the trailing (H, W) axes up to block multiples.

    Args:
        img: (..., H, W) array; leading axes (e.g. batch) pass through.
        block: tile size; output H and W are the next multiples of it.

    Returns:
        (..., H', W') array with H' = ceil(H/block)*block (same for W);
        the input object itself when no padding is needed.
    """
    h, w = img.shape[-2:]
    ph = (-h) % block
    pw = (-w) % block
    if ph == 0 and pw == 0:
        return img
    pad = [(0, 0)] * (img.ndim - 2) + [(0, ph), (0, pw)]
    return jnp.pad(img, pad, mode="edge")


def _forward(img_f32: jnp.ndarray, transform: Transform,
             cordic_config: cordic.CordicConfig) -> jnp.ndarray:
    if transform == "exact":
        return dct.blockwise_dct2d_kron(img_f32)
    blocks = dct.to_blocks(img_f32)
    if transform == "loeffler":
        return loeffler.loeffler_dct2d_8x8(blocks)
    if transform == "cordic":
        rot = cordic.make_cordic_rotate(cordic_config)
        qfn = cordic.fixed_quantizer(cordic_config)
        return loeffler.loeffler_dct2d_8x8(blocks, rotate_fn=rot,
                                           quantize_fn=qfn)
    raise ValueError(f"unknown transform {transform!r}")


def _inverse(coeffs: jnp.ndarray, transform: Transform,
             cordic_config: cordic.CordicConfig) -> jnp.ndarray:
    if transform == "exact":
        return dct.blockwise_idct2d_kron(coeffs)
    if transform == "loeffler":
        return dct.from_blocks(loeffler.loeffler_idct2d_8x8(coeffs))
    if transform == "cordic":
        rot = cordic.make_cordic_rotate(cordic_config)
        qfn = cordic.fixed_quantizer(cordic_config)
        return dct.from_blocks(
            loeffler.loeffler_idct2d_8x8(coeffs, rotate_fn=rot,
                                         quantize_fn=qfn))
    raise ValueError(f"unknown transform {transform!r}")


def compress_batch_blocks(imgs: jnp.ndarray, transform: Transform,
                          quality: int,
                          cordic_config: cordic.CordicConfig) -> jnp.ndarray:
    """Batch-first body: (B, H, W) -> (B, H/8, W/8, 8, 8) quantised levels.

    Plain (unjitted) so serve.codec_engine can trace it inside shard_map;
    ``_compress_jit`` is its jitted single-host form.

    Args:
        imgs: (B, H, W) uint8/float batch, H and W already multiples of 8
            (see :func:`pad_to_block`).
        transform: forward transform — "exact", "cordic" or "loeffler".
        quality: JPEG quality factor in [1, 100] selecting the qtable.
        cordic_config: CORDIC iteration/width config (used only when
            ``transform == "cordic"``).

    Returns:
        (B, H/8, W/8, 8, 8) int32 quantised coefficient levels.
    """
    def one(img):
        # level-shift to signed range as in JPEG
        x = img.astype(jnp.float32) - 128.0
        return _forward(x, transform, cordic_config)
    coeffs = jax.vmap(one)(imgs)
    return quant.quantize(coeffs, quant.qtable(quality))


def decompress_batch_blocks(qcoeffs: jnp.ndarray, transform: Transform,
                            quality: int,
                            cordic_config: cordic.CordicConfig
                            ) -> jnp.ndarray:
    """Batch-first body: (B, H/8, W/8, 8, 8) levels -> (B, H, W) uint8.

    Args:
        qcoeffs: (B, H/8, W/8, 8, 8) int32 quantised levels as produced
            by :func:`compress_batch_blocks`.
        transform: inverse transform to apply ("exact"/"cordic"/
            "loeffler") — the *decoder's* transform, which a standards-
            compliant decode keeps "exact" regardless of the encoder.
        quality: JPEG quality factor; must match the encoder's.
        cordic_config: CORDIC config for ``transform == "cordic"``.

    Returns:
        (B, H, W) uint8 reconstruction, level-shifted back to [0, 255].
    """
    coeffs = quant.dequantize(qcoeffs, quant.qtable(quality))
    x = jax.vmap(lambda c: _inverse(c, transform, cordic_config))(coeffs)
    return jnp.clip(jnp.round(x + 128.0), 0.0, 255.0).astype(jnp.uint8)


_compress_jit = functools.partial(
    jax.jit, static_argnames=("transform", "quality", "cordic_config"))(
        compress_batch_blocks)

_decompress_jit = functools.partial(
    jax.jit, static_argnames=("transform", "quality", "cordic_config"))(
        decompress_batch_blocks)


def compress(img, quality: int = 50, transform: Transform = "exact",
             cordic_config: cordic.CordicConfig = cordic.PAPER_CONFIG
             ) -> CompressedImage:
    """Compress a (H, W) grayscale image (uint8 or float).

    Thin wrapper over the batch-first jit: a single image is a batch of
    one.  ``repro.serve.codec_engine`` drives the same jits with real
    batches (and shards them across devices).

    Args:
        img: (H, W) grayscale image; sizes not divisible by 8 (e.g. the
            paper's 1024x814) are edge-padded and cropped back on
            reconstruction.
        quality: JPEG quality factor in [1, 100].
        transform: "exact" (paper's reference DCT), "cordic" (the
            paper's subject) or "loeffler" (exact-rotation sanity
            bridge).
        cordic_config: CORDIC iteration/width config.

    Returns:
        A :class:`CompressedImage` carrying the (H/8, W/8, 8, 8) int32
        quantised levels plus everything needed to decode.
    """
    img = jnp.asarray(img)
    orig_shape = tuple(img.shape[-2:])
    padded = pad_to_block(img)
    q = _compress_jit(padded[None], transform, quality, cordic_config)[0]
    return CompressedImage(qcoeffs=q, quality=quality, transform=transform,
                           orig_shape=orig_shape, cordic_config=cordic_config)


def decompress(c: CompressedImage, mode: str = "standard") -> jnp.ndarray:
    """Reconstruct the (H, W) uint8 image.

    mode="standard": the decoder applies the *exact* IDCT — a standards-
      compliant decoder that does not know which approximate forward
      transform the encoder used.  With a CORDIC encoder, its angle-
      approximation error passes through to reconstruction; this reproduces
      the paper's exact-DCT vs Cordic-Loeffler PSNR gap (Tables 3-4).
    mode="matched": the decoder applies the adjoint of the encoder's own
      (approximate) transform.  CORDIC angle errors then largely cancel —
      a finding we report alongside the reproduction (EXPERIMENTS.md).

    Args:
        c: a :class:`CompressedImage` from :func:`compress`.
        mode: "standard" or "matched" as above.

    Returns:
        (H, W) uint8 reconstruction cropped to ``c.orig_shape``.
    """
    cfg = c.cordic_config or cordic.PAPER_CONFIG
    dec_transform = "exact" if mode == "standard" else c.transform
    out = _decompress_jit(c.qcoeffs[None], dec_transform, c.quality, cfg)[0]
    h, w = c.orig_shape
    return out[..., :h, :w]


def roundtrip(img, quality: int = 50, transform: Transform = "exact",
              cordic_config: cordic.CordicConfig = cordic.PAPER_CONFIG,
              mode: str = "standard"):
    """The paper's experiment: compress, reconstruct, score.

    Args:
        img: (H, W) grayscale image (uint8 or float).
        quality: JPEG quality factor in [1, 100].
        transform: encoder transform ("exact"/"cordic"/"loeffler").
        cordic_config: CORDIC config for ``transform == "cordic"``.
        mode: decode mode, see :func:`decompress`.

    Returns:
        ``(reconstructed, psnr_db)`` — the (H, W) uint8 reconstruction
        and its PSNR in dB against ``img`` (paper eq. 23).
    """
    c = compress(img, quality, transform, cordic_config)
    rec = decompress(c, mode=mode)
    return rec, float(metrics.psnr(jnp.asarray(img), rec))
