"""Distribution primitives: logical sharding rules, compressed cross-axis
gradient exchange, and pipeline parallelism."""
