"""Version tolerance for the shard_map entry point.

The container pins jax 0.4.37 (``jax.experimental.shard_map``, ``check_rep``)
while CI installs current jax (``jax.shard_map``, ``check_vma``).  Everything
in repro that shard_maps goes through :func:`shard_map` so call sites never
see the difference.
"""

from __future__ import annotations

import jax


def _resolve():
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map as fn
    return fn


def shard_map(f, mesh, in_specs, out_specs):
    """shard_map with replication checking off (portable across jax APIs)."""
    sm = _resolve()
    for kw in ({"check_vma": False}, {"check_rep": False}, {}):
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
        except TypeError:
            continue
    raise RuntimeError("no usable shard_map signature found")
