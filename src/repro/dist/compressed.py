"""Compressed cross-axis gradient exchange (shard_map collective).

What crosses the chosen mesh axis is the grad_dct wire format — int8 codes
of the first ``keep`` DCT coefficients per 64-sample block plus one f32
scale per block — not the raw f32 gradient.  Each participant projects its
error-feedback-corrected local gradient, all-gathers the codes, decodes
every participant's projection and averages, so all participants compute
the identical mean (no second collective needed).

The projection math mirrors ``kernels/grad_dct/ref.py`` in pure jnp: the
Pallas encode kernel is the single-device fast path, while inside shard_map
we want something every backend traces cheaply.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dct
from repro.dist import compat
from repro.optim.grad_compress import GradCompressConfig

BLOCK = 64


def _encode(flat: jnp.ndarray, keep: int):
    """(N,) f32 -> ((R, keep) int8 codes, (R, 1) f32 scales, (T,) f32 tail)."""
    n = flat.shape[0]
    r = n // BLOCK
    body = flat[:r * BLOCK].reshape(r, BLOCK)
    tail = flat[r * BLOCK:]
    c = dct.dct_matrix(BLOCK, jnp.float32)
    kept = (body @ c.T)[:, :keep]
    scale = jnp.maximum(jnp.max(jnp.abs(kept), axis=-1, keepdims=True)
                        / 127.0, 1e-30)
    q = jnp.clip(jnp.round(kept / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale.astype(jnp.float32), tail


def _decode(q: jnp.ndarray, scale: jnp.ndarray, tail: jnp.ndarray,
            n: int) -> jnp.ndarray:
    c = dct.dct_matrix(BLOCK, jnp.float32)
    kept = q.astype(jnp.float32) * scale
    coef = jnp.pad(kept, ((0, 0), (0, BLOCK - q.shape[-1])))
    body = (coef @ c).reshape(-1)
    return jnp.concatenate([body, tail])[:n]


def compressed_mean_flat(g: jnp.ndarray, ef: jnp.ndarray, axis: str,
                         keep: int = 16):
    """EF-corrected compressed mean of a flat gradient over a mesh axis.

    Call inside shard_map.  Returns (mean, new_ef): ``mean`` is identical on
    every participant (decoded from the gathered codes); ``new_ef`` is the
    local residual the projection dropped.
    """
    n = g.shape[0]
    corrected = g.astype(jnp.float32) + ef
    q, scale, tail = _encode(corrected, keep)
    proj = _decode(q, scale, tail, n)
    new_ef = corrected - proj

    # int8 codes + f32 scales cross the axis; tails are exact (small).
    qg = jax.lax.all_gather(q, axis)
    sg = jax.lax.all_gather(scale, axis)
    tg = jax.lax.all_gather(tail, axis)
    mean = jax.vmap(lambda qq, ss, tt: _decode(qq, ss, tt, n))(
        qg, sg, tg).mean(axis=0)
    return mean, new_ef


def make_cross_axis_grad_sync(mesh, specs: dict, cfg: GradCompressConfig):
    """Tree-level grad sync: f(grads, ef) -> (mean_grads, new_ef).

    ``specs`` gives each leaf's PartitionSpec on ``mesh``; leaves below
    ``cfg.min_size`` (or with compression disabled) take an exact pmean
    over ``cfg.axis`` instead of the compressed exchange.
    """
    axis = cfg.axis

    def body(grads: dict, ef: dict):
        out_g, out_e = {}, {}
        for path, g in grads.items():
            e = ef[path]
            if not cfg.enabled or g.size < cfg.min_size:
                out_g[path] = jax.lax.pmean(g, axis)
                out_e[path] = e
            else:
                m, ne = compressed_mean_flat(
                    g.reshape(-1), e.reshape(-1).astype(jnp.float32),
                    axis, keep=cfg.keep)
                out_g[path] = m.reshape(g.shape).astype(g.dtype)
                out_e[path] = ne.reshape(e.shape)
        return out_g, out_e

    spec_tree = {path: specs[path] for path in specs}
    sm = compat.shard_map(body, mesh,
                          in_specs=(spec_tree, spec_tree),
                          out_specs=(spec_tree, spec_tree))

    def sync(grads: dict, ef: dict):
        return sm(grads, ef)

    return sync
