"""GPipe-style pipeline parallelism over a "stage" mesh axis.

``split_stages`` reshapes stacked per-layer parameters into a leading
stage axis; ``gpipe`` returns a runner that shard_maps the classic GPipe
schedule: each stage applies its layer slice to the microbatch it holds,
then collective-permutes activations one stage down the ring.  After
``n_micro + n_stages - 1`` ticks the last stage has every microbatch's
output; a psum over the stage axis replicates the result.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import compat


def split_stages(params: dict, n_stages: int) -> dict:
    """Reshape stacked (L, ...) leaves to (n_stages, L // n_stages, ...)."""
    def r(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])
    return jax.tree.map(r, params)


def gpipe(block_fn, *, n_stages: int, n_micro: int, mesh,
          stage_axis: str = "stage"):
    """Build a runner f(stage_params, x_micro) -> y_micro.

    ``block_fn(layer_params, x) -> x`` applies one layer; ``stage_params``
    leaves carry a leading (n_stages, layers_per_stage) axis pair
    (from :func:`split_stages`); ``x_micro`` is (n_micro, ...) and is
    replicated to every stage.
    """
    def body(local_params, x_micro):
        # local leaves: (1, layers_per_stage, ...) after stage sharding
        layers = jax.tree.map(lambda a: a[0], local_params)
        sidx = jax.lax.axis_index(stage_axis)
        ring = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def stage_apply(x):
            def step(carry, layer):
                return block_fn(layer, carry), None
            out, _ = jax.lax.scan(step, x, layers)
            return out

        buf = jnp.zeros_like(x_micro)
        recv = jnp.zeros_like(x_micro[0])
        for t in range(n_micro + n_stages - 1):
            feed = x_micro[min(t, n_micro - 1)]
            inp = jnp.where(sidx == 0, feed, recv)
            out = stage_apply(inp)
            done = t - (n_stages - 1)       # microbatch finishing this tick
            if 0 <= done < n_micro:
                buf = buf.at[done].set(
                    jnp.where(sidx == n_stages - 1, out, buf[done]))
            recv = jax.lax.ppermute(out, stage_axis, ring)
        # only the last stage holds results; psum replicates them
        return jax.lax.psum(buf, stage_axis)

    def run(stage_params, x_micro):
        in_param_specs = jax.tree.map(lambda _: P(stage_axis), stage_params)
        sm = compat.shard_map(body, mesh,
                              in_specs=(in_param_specs, P()),
                              out_specs=P())
        return sm(stage_params, x_micro)

    return run
