"""Logical-axis sharding.

Model code names tensor dimensions with *logical* axes ("batch", "embed",
"heads", ...); a rule table maps each logical axis to zero or more *mesh*
axes.  ``use_mesh_and_rules`` activates a (mesh, rules) pair; inside the
context ``constrain`` lowers to ``with_sharding_constraint`` and the spec
builders resolve logical names against the active rules.  Outside any
context everything is a no-op / fully replicated, so single-device tests
run the same model code unchanged.

Resolution prunes rule entries that cannot apply: mesh axes absent from the
mesh (e.g. "pod" on a 2-axis mesh), axes already consumed by an earlier
dimension (a mesh axis may appear at most once per PartitionSpec), and —
when the concrete shape is known — axes whose device count does not divide
the dimension (e.g. 8 kv_heads over a 16-wide "model" axis).
"""

from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# Default rule table: training-style DP over (pod, data), TP over model.
# "embed" is None by default (replicated params); launch.specs.rules_for
# flips it to ("pod", "data") for FSDP in training shapes.
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_cap": None,
    "cache_time": None,
    "layers": None,
    "state": None,
}

# Active (mesh, rules) stack; the top entry governs constrain/spec building.
_ACTIVE: list[tuple] = []


def current_mesh():
    return _ACTIVE[-1][0] if _ACTIVE else None


def current_rules() -> dict:
    return _ACTIVE[-1][1] if _ACTIVE else DEFAULT_RULES


@contextlib.contextmanager
def use_mesh_and_rules(mesh, rules: dict):
    """Activate a mesh + logical rule table for constrain/spec builders."""
    _ACTIVE.append((mesh, dict(rules)))
    try:
        yield
    finally:
        _ACTIVE.pop()


def _resolve_dim(name, rules: dict, mesh, dim_size, used: set):
    """Mesh axes for one logical dim, pruned to what can actually apply."""
    rule = rules.get(name) if name is not None else None
    if rule is None:
        return ()
    if isinstance(rule, str):
        rule = (rule,)
    out = []
    shards = 1
    for ax in rule:
        if ax not in mesh.shape or ax in used:
            continue
        k = mesh.shape[ax]
        if dim_size is not None and dim_size % (shards * k):
            continue
        out.append(ax)
        used.add(ax)
        shards *= k
    return tuple(out)


def _spec(axes, rules: dict, mesh, shape=None) -> P:
    used: set = set()
    entries = []
    for i, name in enumerate(axes):
        dim = None if shape is None else shape[i]
        mesh_axes = _resolve_dim(name, rules, mesh, dim, used)
        if not mesh_axes:
            entries.append(None)
        elif len(mesh_axes) == 1:
            entries.append(mesh_axes[0])
        else:
            entries.append(mesh_axes)
    return P(*entries)


def logical_spec(axes: tuple) -> P:
    """PartitionSpec for logical axes under the active rules (P() if none)."""
    mesh = current_mesh()
    if mesh is None:
        return P()
    return _spec(axes, current_rules(), mesh)


def constrain(x, *axes):
    """Sharding-constrain ``x`` by logical axis names; no-op without mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = _spec(axes, current_rules(), mesh, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def input_sharding(shape: tuple, axes: tuple, mesh) -> NamedSharding:
    """NamedSharding for a batch input with per-dim logical names."""
    return NamedSharding(mesh, _spec(axes, current_rules(), mesh,
                                     shape=tuple(shape)))


def param_shardings(pspecs: dict, mesh) -> dict:
    """{path: NamedSharding} from a flat {path: ParamSpec} dict."""
    rules = current_rules()
    return {path: NamedSharding(mesh, _spec(s.axes, rules, mesh,
                                            shape=s.shape))
            for path, s in pspecs.items()}
