"""Jittable train / eval steps with microbatch accumulation and optional
DCT gradient compression.

``make_train_step`` returns a pure function
    (state, batch) -> (state, metrics)
suitable for jax.jit with in/out shardings from dist.sharding.  Microbatch
accumulation is a ``lax.scan`` over the leading microbatch split — required
for the biggest configs, where a full 1M-token global batch cannot coexist
with MoE dispatch buffers (EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import registry
from repro.optim import adamw
from repro.optim.grad_compress import GradCompressConfig, project_tree
from repro.train.loss import lm_loss


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1
    grad_compress: GradCompressConfig = GradCompressConfig()


def init_state(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig, key,
               step_cfg: TrainStepConfig | None = None) -> dict:
    params = registry.init_params(cfg, key)
    state = {"params": params,
             "opt": adamw.init_state(opt_cfg, params),
             "step": jnp.zeros((), jnp.int32)}
    if step_cfg and step_cfg.grad_compress.enabled:
        from repro.optim.grad_compress import init_error_feedback
        state["ef"] = init_error_feedback(params)
    return state


def abstract_state(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                   step_cfg: TrainStepConfig | None = None) -> dict:
    pstructs = registry.abstract_params(cfg)
    state = {"params": pstructs,
             "opt": adamw.abstract_state(opt_cfg, pstructs),
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    if step_cfg and step_cfg.grad_compress.enabled:
        from repro.optim.grad_compress import abstract_error_feedback
        state["ef"] = abstract_error_feedback(pstructs)
    return state


def _split_micro(batch: dict, n: int) -> dict:
    def r(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    # positions3 has leading 3-axis; microbatch on axis 1
    out = {}
    for k, v in batch.items():
        if k == "positions3":
            out[k] = jnp.moveaxis(
                v.reshape(3, n, v.shape[1] // n, *v.shape[2:]), 1, 0)
        else:
            out[k] = r(v)
    return out


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                    step_cfg: TrainStepConfig = TrainStepConfig(),
                    grad_sync=None):
    """grad_sync: optional f(grads, ef) -> (grads, ef) (dist.compressed)."""
    gc = step_cfg.grad_compress

    def loss_fn(params, batch):
        logits, _, aux = registry.apply(cfg, params, batch, mode="train")
        return lm_loss(cfg, logits, batch, aux)

    def compute_grads(params, batch):
        if step_cfg.microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return grads, metrics
        micro = _split_micro(batch, step_cfg.microbatches)
        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def acc_fn(carry, mb):
            g_acc = carry
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
            return g_acc, metrics
        g_sum, metrics_all = jax.lax.scan(acc_fn, zero, micro)
        grads = jax.tree.map(lambda g: g / step_cfg.microbatches, g_sum)
        metrics = jax.tree.map(lambda m: m.mean(), metrics_all)
        return grads, metrics

    def train_step(state, batch):
        grads, metrics = compute_grads(state["params"], batch)
        ef = state.get("ef")
        if gc.enabled:
            if grad_sync is not None:
                grads, ef = grad_sync(grads, ef)
            else:
                grads, ef = project_tree(grads, ef, gc)
        new_params, new_opt, opt_metrics = adamw.update(
            opt_cfg, state["params"], grads, state["opt"])
        metrics.update(opt_metrics)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if ef is not None:
            new_state["ef"] = ef
        return new_state, metrics

    return train_step


def make_eval_step(cfg: ArchConfig):
    def eval_step(params, batch):
        logits, _, aux = registry.apply(cfg, params, batch, mode="train")
        _, metrics = lm_loss(cfg, logits, batch, aux)
        return metrics
    return eval_step
