"""Trainer loop: jit'd step + checkpoint/auto-resume + watchdog + logging.

The loop is deliberately stateless between steps beyond TrainState: data is
a pure function of the step index (data/synth.py), so crash-restart resumes
bit-identically from the latest committed checkpoint — the fault-tolerance
tests kill a run mid-flight and assert exact continuation.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint
from repro.configs.base import ArchConfig
from repro.ft.watchdog import StepWatchdog
from repro.optim import adamw
from repro.train import step as step_lib


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    ckpt_async: bool = True
    log_every: int = 10
    straggler_ratio: float = 3.0


class Trainer:
    def __init__(self, cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                 tcfg: TrainerConfig, batch_fn,
                 step_cfg: step_lib.TrainStepConfig | None = None,
                 seed: int = 0):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.batch_fn = batch_fn
        self.step_cfg = step_cfg or step_lib.TrainStepConfig()
        self.watchdog = StepWatchdog(ratio=tcfg.straggler_ratio)
        self.history: list = []

        self._train_step = jax.jit(
            step_lib.make_train_step(cfg, opt_cfg, self.step_cfg))
        self._ckptr = None
        if tcfg.ckpt_dir and tcfg.ckpt_async:
            self._ckptr = checkpoint.AsyncCheckpointer(tcfg.ckpt_dir)

        # ---- init or auto-resume ------------------------------------------
        start = None
        if tcfg.ckpt_dir:
            start = checkpoint.latest_step(tcfg.ckpt_dir)
        if start is not None:
            tree, extra = checkpoint.load(tcfg.ckpt_dir, start)
            self.state = tree
            self.start_step = int(extra.get("step", start))
        else:
            self.state = step_lib.init_state(cfg, opt_cfg,
                                             jax.random.key(seed),
                                             self.step_cfg)
            self.start_step = 0

    def _save(self, step: int):
        if not self.tcfg.ckpt_dir:
            return
        if self._ckptr:
            self._ckptr.submit(step, self.state, {"step": step})
        else:
            checkpoint.save(self.tcfg.ckpt_dir, step, self.state,
                            {"step": step})

    def run(self, steps: int | None = None):
        total = steps or self.tcfg.total_steps
        for step in range(self.start_step, total):
            batch = self.batch_fn(step)
            t0 = time.monotonic()
            self.state, metrics = self._train_step(self.state, batch)
            loss = float(metrics["loss"])   # blocks: real step time
            dt = time.monotonic() - t0
            ev = self.watchdog.observe(step, dt)
            if ev is not None:
                print(f"[watchdog] straggler step {step}: "
                      f"{ev.duration:.3f}s vs median {ev.median:.3f}s")
            rec = {"step": step, "loss": loss, "time_s": dt,
                   "grad_norm": float(metrics["grad_norm"])}
            self.history.append(rec)
            if step % self.tcfg.log_every == 0 or step == total - 1:
                print(f"step {step:5d}  loss {loss:.4f}  "
                      f"gnorm {rec['grad_norm']:.3f}  {dt*1e3:.0f} ms")
            if (step + 1) % self.tcfg.ckpt_every == 0 or step == total - 1:
                self._save(step + 1)
        if self._ckptr:
            self._ckptr.wait()
        self.watchdog.close()
        return self.history
