"""train substrate."""
