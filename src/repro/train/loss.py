"""Losses: next-token CE, masked-prediction CE (encoder), MTP aux."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean token CE with f32 *row statistics* only (Perf iteration A):
    the (B, S, V)-sized tensors stay in the compute dtype; the rowwise
    max/logsumexp and the final mean are f32."""
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)                                  # V-sized, bf16
    z = jnp.sum(e.astype(jnp.float32), axis=-1)              # f32 rows
    logz = jnp.log(z) + m[..., 0].astype(jnp.float32)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0].astype(jnp.float32)
    nll = logz - gold
    if mask is not None:
        mk = mask.astype(jnp.float32)
        return (nll * mk).sum() / jnp.maximum(mk.sum(), 1.0)
    return nll.mean()


def lm_loss(cfg, logits: jnp.ndarray, batch: dict, aux: dict) -> tuple:
    """Family-aware training loss.  Returns (loss, metrics)."""
    metrics = {}
    if cfg.is_encoder:
        # masked-prediction: only masked frames contribute
        loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
    else:
        # next-token: shift left
        labels = batch["labels"]
        loss = cross_entropy(logits[:, :-1], labels[:, 1:])
    metrics["ce_loss"] = loss
    if "aux_loss" in aux:
        loss = loss + aux["aux_loss"]
        metrics["moe_aux"] = aux["aux_loss"]
    if "mtp_logits" in aux:
        # MTP predicts token t+2 from position t
        labels = batch["labels"]
        mtp = cross_entropy(aux["mtp_logits"][:, :-2], labels[:, 2:])
        loss = loss + 0.1 * mtp
        metrics["mtp_loss"] = mtp
    metrics["loss"] = loss
    return loss, metrics
