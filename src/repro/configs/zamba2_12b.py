"""zamba2-1.2b [arXiv:2411.15242; assignment spec].

Hybrid: Mamba2 backbone (state=64) + one weight-shared attention block
invoked every 6 layers: 38L d_model=2048 32H (MHA kv=32) d_ff=8192
vocab=32000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000, rope_base=10000.0,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=64,
    shared_attn_every=6,
)
