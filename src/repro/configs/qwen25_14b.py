"""qwen2.5-14b [hf:Qwen/Qwen2.5 family; assignment spec].

Dense GQA with QKV bias: 48L d_model=5120 40H (kv=8) d_ff=13824 vocab=152064.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=13824, vocab_size=152064, qkv_bias=True, rope_base=1e6,
)
