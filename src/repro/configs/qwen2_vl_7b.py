"""qwen2-vl-7b [arXiv:2409.12191; assignment spec].

VLM backbone with M-RoPE (sections 16/24/24 over head_dim 128) and dynamic-
resolution vision frontend STUB (input_specs provide patch embeddings +
3-D positions): 28L d_model=3584 28H (kv=4) d_ff=18944 vocab=152064.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064, qkv_bias=True, rope_base=1e6,
    input_mode="mixed", mrope_sections=(16, 24, 24),
)
