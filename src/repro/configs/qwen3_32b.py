"""qwen3-32b [hf:Qwen/Qwen3 family; assignment spec].

Dense GQA with qk-norm and explicit head_dim=128 (q width 8192 != d_model):
64L d_model=5120 64H (kv=8) d_ff=25600 vocab=151936.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=25600, vocab_size=151936, qk_norm=True, rope_base=1e6,
)
