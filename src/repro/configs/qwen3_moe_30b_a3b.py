"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B; assignment spec].

MoE: 48L d_model=2048 32H (kv=4) 128 experts top-8, expert d_ff=768,
vocab=151936, qk-norm, head_dim=128.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936, qk_norm=True, rope_base=1e6,
    n_experts=128, moe_top_k=8, moe_d_ff=768, moe_capacity_factor=1.25,
)
