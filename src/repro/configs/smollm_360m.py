"""smollm-360m [hf:HuggingFaceTB/SmolLM-135M scaled; assignment spec].

llama-arch small dense: 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
"""
import jax.numpy as jnp
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, head_dim=64,
    d_ff=2560, vocab_size=49152, rope_base=10000.0, tie_embeddings=True,
)
