"""ArchConfig — one dataclass covering all 10 assigned architectures.

Family-specific fields are optional; each model module reads what it needs.
``input_specs`` builds the ShapeDtypeStruct stand-ins for every (shape ×
step-kind) cell of the dry-run, per the assignment:

  train_4k      seq 4096   global_batch 256   (train_step)
  prefill_32k   seq 32768  global_batch 32    (prefill)
  decode_32k    seq 32768  global_batch 128   (serve_step, 1 new token)
  long_500k     seq 524288 global_batch 1     (serve_step; sub-quadratic only)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None      # explicit (qwen3) or d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_base: float = 1e6
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    n_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    first_dense_layers: int = 0      # deepseek: first k layers dense
    router_type: str = "softmax"     # softmax | sigmoid
    router_aux_weight: float = 0.001

    # --- MLA (deepseek) ------------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mtp_depth: int = 0               # multi-token-prediction aux depth

    # --- SSM / hybrid --------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    shared_attn_every: int = 0       # zamba2: shared attn block cadence

    # --- xLSTM ---------------------------------------------------------------
    slstm_every: int = 0             # 1-in-N blocks is sLSTM (0 = none)
    mlstm_proj_factor: float = 2.0
    mlstm_qk_factor: float = 0.5     # qk dim = qk_factor * d_inner
    slstm_proj_factor: float = 1.3334

    # --- modality stubs ------------------------------------------------------
    is_encoder: bool = False         # hubert: bidirectional, no decode
    input_mode: str = "tokens"       # tokens | embeds (audio) | mixed (vlm)
    mrope_sections: tuple | None = None

    # --- compute -------------------------------------------------------------
    compute_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat: str = "full"              # none | full | dots
    scan_layers: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def supports_decode(self) -> bool:
        return not self.is_encoder

    @property
    def sub_quadratic(self) -> bool:
        """Can run long_500k (SSM/hybrid/linear-attention families)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        from repro.models import registry
        from repro.models.params import param_count
        return param_count(registry.param_specs(self))

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k + shared only)."""
        if self.n_experts == 0:
            return self.param_count()
        from repro.models import registry
        from repro.models.params import param_count
        import numpy as np
        specs = registry.param_specs(self)
        total = 0
        for path, s in specs.items():
            n = int(np.prod(s.shape))
            if "experts/" in path:
                n = n // self.n_experts * self.moe_top_k
            total += n
        return total


# ---------------------------------------------------------------------------
# Shapes from the assignment
# ---------------------------------------------------------------------------

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def shape_supported(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """(supported, reason-if-not) for an (arch, shape) cell."""
    info = SHAPES[shape_name]
    if info["kind"] == "decode" and cfg.is_encoder:
        return False, "encoder-only arch has no decode step"
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: O(S^2) at 500k — skipped per brief"
    return True, ""


def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    info = SHAPES[shape_name]
    s, b, kind = info["seq_len"], info["global_batch"], info["kind"]
    i32 = jnp.int32

    def tok(shape):
        return jax.ShapeDtypeStruct(shape, i32)

    if kind == "train":
        specs = {"tokens": tok((b, s)), "labels": tok((b, s))}
        if cfg.input_mode == "embeds":
            specs = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                    cfg.compute_dtype),
                     "labels": tok((b, s)),
                     "mask": jax.ShapeDtypeStruct((b, s), jnp.bool_)}
        elif cfg.input_mode == "mixed":
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, s, cfg.d_model), cfg.compute_dtype)
            specs["vision_mask"] = jax.ShapeDtypeStruct((b, s), jnp.bool_)
            specs["positions3"] = tok((3, b, s))
        return specs

    if kind == "prefill":
        specs = {"tokens": tok((b, s))}
        if cfg.input_mode == "embeds":
            specs = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                    cfg.compute_dtype)}
        elif cfg.input_mode == "mixed":
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, s, cfg.d_model), cfg.compute_dtype)
            specs["vision_mask"] = jax.ShapeDtypeStruct((b, s), jnp.bool_)
            specs["positions3"] = tok((3, b, s))
        return specs

    # decode: one new token against a seq_len-deep cache
    specs = {"tokens": tok((b, 1)),
             "cache_index": jax.ShapeDtypeStruct((), i32)}
    if cfg.input_mode == "mixed":
        specs["positions3"] = tok((3, b, 1))
    from repro.models import registry
    specs["cache"] = registry.abstract_cache(cfg, batch=b, max_len=s)
    return specs
