"""xlstm-1.3b [arXiv:2405.04517; assignment spec].

sLSTM + mLSTM blocks (7:1 ratio -> slstm_every=8): 48L d_model=2048 4H,
d_ff=0 (in-block projections: mLSTM pf=2 with qk_factor=0.5, sLSTM FFN
pf=4/3), vocab=50304.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, slstm_every=8, ssm_chunk=64,
    mlstm_proj_factor=2.0, mlstm_qk_factor=0.5, slstm_proj_factor=1.3334,
)
