"""hubert-xlarge [arXiv:2106.07447; assignment spec].

Encoder-only audio transformer: 48L d_model=1280 16H d_ff=5120, masked
cluster prediction over vocab=504.  The conv waveform frontend is a STUB
per the brief: input_specs provide precomputed frame embeddings (B, T, d).
Positional encoding adapted to RoPE (orig: conv-pos) — noted in DESIGN.md.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504, rope_base=10000.0,
    is_encoder=True, input_mode="embeds",
)
