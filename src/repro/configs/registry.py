"""Architecture registry: ``--arch <id>`` -> ArchConfig (full or reduced).

``reduced()`` builds a same-family miniature (few layers, narrow width,
few experts, tiny vocab) for CPU smoke tests; the full configs are only
ever lowered abstractly (dry-run), never materialised on CPU.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.configs.base import ArchConfig

_MODULES = {
    "xlstm-1.3b": "xlstm_13b",
    "hubert-xlarge": "hubert_xlarge",
    "zamba2-1.2b": "zamba2_12b",
    "qwen2.5-14b": "qwen25_14b",
    "qwen3-32b": "qwen3_32b",
    "qwen1.5-110b": "qwen15_110b",
    "smollm-360m": "smollm_360m",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "qwen2-vl-7b": "qwen2_vl_7b",
}

ARCH_NAMES = list(_MODULES)


def get(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_NAMES}")
    import importlib
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def reduced(name: str, **overrides) -> ArchConfig:
    """Miniature same-family config for CPU smoke tests."""
    cfg = get(name)
    r = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=64,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        compute_dtype=jnp.float32,
        remat="none",
    )
    if cfg.n_experts:
        r.update(n_experts=8, moe_top_k=2, moe_d_ff=32,
                 moe_capacity_factor=2.0)
    if cfg.use_mla:
        r.update(q_lora_rank=24, kv_lora_rank=16, qk_nope_dim=8,
                 qk_rope_dim=4, v_head_dim=8, first_dense_layers=1,
                 n_layers=3)
    if cfg.first_dense_layers and not cfg.use_mla:
        r.update(first_dense_layers=1)
    if cfg.family in ("ssm", "hybrid"):
        r.update(ssm_state=8, ssm_head_dim=8, ssm_chunk=4)
    if cfg.shared_attn_every:
        r.update(shared_attn_every=2, n_layers=4, n_kv_heads=4)
    if cfg.slstm_every:
        r.update(slstm_every=4, n_layers=4)
    if cfg.mrope_sections:
        r.update(mrope_sections=(4, 2, 2))
    r.update(overrides)
    return dataclasses.replace(cfg, **r)
