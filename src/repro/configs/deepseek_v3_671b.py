"""deepseek-v3-671b [arXiv:2412.19437; assignment spec].

MLA + fine-grained MoE: 61L d_model=7168 128 heads, q_lora=1536 kv_lora=512
(nope 128 / rope 64 / v 128), 1 shared + 256 routed experts top-8 with
expert d_ff=2048 (dense first-3 layers use 9*2048=18432), vocab=129280,
sigmoid router, MTP depth 1.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=2048, vocab_size=129280, rope_base=10000.0,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    n_experts=256, moe_top_k=8, moe_d_ff=2048, n_shared_experts=1,
    moe_capacity_factor=1.25, first_dense_layers=3,
    router_type="sigmoid", mtp_depth=1,
)
