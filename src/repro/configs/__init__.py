from repro.configs.base import ArchConfig, SHAPES, input_specs, shape_supported  # noqa: F401
from repro.configs.registry import ARCH_NAMES, get, reduced  # noqa: F401
