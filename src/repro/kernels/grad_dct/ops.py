"""Jitted public wrappers: compress/decompress arbitrary-shaped gradients.

``encode``/``decode`` operate on flat vectors of any length: the tail that
does not fill a 64-sample block is carried *uncompressed* (exact), which
keeps the projection deterministic and shape-stable for jit.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import dct
from repro.kernels import common, tuning
from repro.kernels.grad_dct import kernel

BLOCK = kernel.BLOCK


@dataclasses.dataclass
class CompressedGrad:
    """DCT-compressed flat gradient."""
    q: jnp.ndarray        # (R, keep) int8
    scale: jnp.ndarray    # (R, 1) f32
    tail: jnp.ndarray     # (T,) f32 uncompressed remainder (T < 64)
    n: int                # original length

    def wire_bytes(self) -> int:
        """Bytes that would cross the interconnect."""
        return (self.q.size * 1 + self.scale.size * 4 + self.tail.size * 4)


def _split(g: jnp.ndarray):
    n = g.shape[0]
    r = n // BLOCK
    return g[:r * BLOCK].reshape(r, BLOCK), g[r * BLOCK:]


def encode(g: jnp.ndarray, keep: int = 16, *, block_rows: int | None = None,
           interpret: bool | None = None) -> CompressedGrad:
    """Compress a flat f32 gradient vector.

    ``block_rows=None`` routes through the tuned-tile artifact
    (:func:`repro.kernels.tuning.tile_for`), the same default the
    image and bit kernels got in PR 8; ``r`` is a static shape, so the
    lookup happens at trace time and jit caching is unaffected.
    """
    if interpret is None:
        interpret = common.interpret_default()
    n = g.shape[0]
    body, tail = _split(g.astype(jnp.float32))
    r = body.shape[0]
    if r == 0:
        return CompressedGrad(q=jnp.zeros((0, keep), jnp.int8),
                              scale=jnp.zeros((0, 1), jnp.float32),
                              tail=tail, n=n)
    if block_rows is None:
        block_rows = tuning.tile_for("grad_dct", r)
    # pad rows to a grid multiple
    br = min(block_rows, r)
    pad_rows = (-r) % br
    if pad_rows:
        body = jnp.pad(body, ((0, pad_rows), (0, 0)))
    c = dct.dct_matrix(BLOCK, jnp.float32)
    q, s = kernel.grad_dct_encode_pallas(body, c, keep=keep, block_rows=br,
                                         interpret=interpret)
    return CompressedGrad(q=q[:r], scale=s[:r], tail=tail, n=n)


def decode(cg: CompressedGrad, *, block_rows: int | None = None,
           interpret: bool | None = None) -> jnp.ndarray:
    """Reconstruct the flat gradient (lossy in the compressed span).

    ``block_rows=None`` routes through the tuned-tile artifact, as in
    :func:`encode`; the tile never changes values, only grid shape
    (pinned by the tile-invariance tests).
    """
    if interpret is None:
        interpret = common.interpret_default()
    r = cg.q.shape[0]
    if r == 0:
        return cg.tail[:cg.n]
    if block_rows is None:
        block_rows = tuning.tile_for("grad_dct", r)
    br = min(block_rows, r)
    pad_rows = (-r) % br
    q, s = cg.q, cg.scale
    if pad_rows:
        q = jnp.pad(q, ((0, pad_rows), (0, 0)))
        s = jnp.pad(s, ((0, pad_rows), (0, 0)))
    c = dct.dct_matrix(BLOCK, jnp.float32)
    body = kernel.grad_dct_decode_pallas(q, s, c, block_rows=br,
                                         interpret=interpret)[:r]
    return jnp.concatenate([body.reshape(-1), cg.tail])[:cg.n]


@functools.partial(jax.jit, static_argnames=("keep", "interpret"))
def roundtrip(g: jnp.ndarray, keep: int = 16,
              interpret: bool | None = None) -> jnp.ndarray:
    """encode+decode in one jit — the projection used inside train steps."""
    return decode(encode(g, keep, interpret=interpret), interpret=interpret)
