"""Pallas TPU kernel: DCT-domain gradient compression (encode / decode).

The paper's energy-compaction argument applied to distributed training
(DESIGN.md §3): gradients are viewed as 1-D signals, cut into 64-sample
blocks, DCT'd, truncated to the lowest ``keep`` frequencies and int8-
quantised with a per-block scale.  The compressed representation is what
crosses the pod-interconnect; error feedback (optim/grad_compress.py) keeps
optimisation unbiased.

Wire format per 64-float block: ``keep`` int8 codes + 1 f32 scale
=> compression ratio 256 / (keep + 4) bytes (e.g. keep=16 -> 12.8x).

Kernel shape: rows of blocks — input (R, 64) f32, grid over row tiles of
``block_rows``; the DCT is an MXU matmul against C64^T, the truncation is a
static slice, the quantiser a VPU max/round.  Encode emits (R, keep) int8 +
(R, 1) f32; decode reverses.  VMEM at the default 512-row tile:
512*64*4 B = 128 KiB per operand — small; the op is HBM-bound by design
(that is the point: it trades FLOPs for interconnect bytes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 64  # DCT block length (frequency axis)


def _encode_kernel(g_ref, c_ref, q_ref, s_ref):
    g = g_ref[...]                    # (rows, 64)
    c = c_ref[...]                    # (64, 64) DCT-II matrix
    keep = q_ref.shape[-1]
    coef = g @ c.T                    # (rows, 64) frequency coefficients
    kept = coef[:, :keep]             # low frequencies carry the energy
    scale = jnp.max(jnp.abs(kept), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(kept / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _decode_kernel(q_ref, s_ref, c_ref, g_ref):
    q = q_ref[...].astype(jnp.float32)   # (rows, keep)
    s = s_ref[...]                        # (rows, 1)
    c = c_ref[...]                        # (64, 64)
    rows, keep = q.shape
    kept = q * s
    coef = jnp.pad(kept, ((0, 0), (0, BLOCK - keep)))
    g_ref[...] = coef @ c                 # inverse (C orthonormal)


@functools.partial(jax.jit, static_argnames=("keep", "block_rows",
                                             "interpret"))
def grad_dct_encode_pallas(g: jnp.ndarray, c: jnp.ndarray, *, keep: int,
                           block_rows: int, interpret: bool = True):
    """(R, 64) f32 -> ((R, keep) int8, (R, 1) f32).  R % block_rows == 0."""
    r = g.shape[0]
    return pl.pallas_call(
        _encode_kernel,
        out_shape=(jax.ShapeDtypeStruct((r, keep), jnp.int8),
                   jax.ShapeDtypeStruct((r, 1), jnp.float32)),
        grid=(r // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK, BLOCK), lambda i: (0, 0)),
        ],
        out_specs=(pl.BlockSpec((block_rows, keep), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows, 1), lambda i: (i, 0))),
        interpret=interpret,
    )(g, c)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def grad_dct_decode_pallas(q: jnp.ndarray, s: jnp.ndarray, c: jnp.ndarray, *,
                           block_rows: int, interpret: bool = True):
    """((R, keep) int8, (R, 1) f32) -> (R, 64) f32."""
    r, keep = q.shape
    return pl.pallas_call(
        _decode_kernel,
        out_shape=jax.ShapeDtypeStruct((r, BLOCK), jnp.float32),
        grid=(r // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, keep), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK, BLOCK), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, BLOCK), lambda i: (i, 0)),
        interpret=interpret,
    )(q, s, c)
