"""Pure-jnp oracle for the grad_dct kernels."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import dct

BLOCK = 64


def grad_dct_encode_ref(g: jnp.ndarray, keep: int):
    """(R, 64) f32 -> ((R, keep) int8, (R, 1) f32)."""
    c = dct.dct_matrix(BLOCK, jnp.float32)
    coef = g @ c.T
    kept = coef[:, :keep]
    scale = jnp.max(jnp.abs(kept), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(kept / scale), -127.0, 127.0)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def grad_dct_decode_ref(q: jnp.ndarray, s: jnp.ndarray):
    """((R, keep) int8, (R, 1) f32) -> (R, 64) f32."""
    c = dct.dct_matrix(BLOCK, jnp.float32)
    keep = q.shape[-1]
    kept = q.astype(jnp.float32) * s
    coef = jnp.pad(kept, ((0, 0), (0, BLOCK - keep)))
    return coef @ c


def grad_dct_roundtrip_ref(g: jnp.ndarray, keep: int):
    """Encode+decode — the lossy projection the optimiser sees."""
    q, s = grad_dct_encode_ref(g, keep)
    return grad_dct_decode_ref(q, s)
