from repro.kernels.grad_dct.ops import (  # noqa: F401
    BLOCK, CompressedGrad, decode, encode, roundtrip)
from repro.kernels.grad_dct.ref import (  # noqa: F401
    grad_dct_decode_ref, grad_dct_encode_ref, grad_dct_roundtrip_ref)
