"""Pallas TPU kernel: speculative Huffman decode of entropy payloads.

Device-resident realisation of the decode half of the entropy stage,
mirroring :mod:`repro.kernels.pack_bits.kernel` on the encode side.
Huffman decode is serial in the *bit offset* chain, not in the work:
following Cloud et al. (arXiv:1107.1525), the grid tiles the payload's
bit space and every program decodes **from every candidate bit offset**
of its tile at once, leaving only an O(1)-per-block chain resolution to
the host (:func:`repro.kernels.unpack_bits.ref.resolve`).

Three structural tricks keep the speculation TPU-shaped:

* **canonical bounds instead of the 64K prefix LUT** — the host hands
  in the table's per-length ``(mincode, maxcode, valptr)`` triplet via
  scalar prefetch; a codeword is matched by 16 unrolled compares of the
  window's top ``L`` bits against the length-``L`` bounds (prefix-free
  codes make at most one length match, so matches combine with
  ``where`` and no priority logic).  The symbol itself comes from a
  ``(window, 256)`` one-hot sum against the table's symbol list.
* **pointer doubling over the unit graph** — each offset's decoded unit
  is summarised as ``next`` (first bit after the unit) and ``dpos``
  (coefficient positions covered); six squarings via
  ``jnp.take_along_axis`` collapse every speculative AC chain to its
  terminal or its position-63 crossing, exactly as the NumPy stage.
* **values stay in the bitstream** — unit words carry control and
  advance only; amplitudes are re-read on the host at resolved offsets,
  so per-program state is ``O(window)`` regardless of payload size.

Each program covers ``tile_bits`` offsets plus a ``window -
tile_bits`` overhang so any block *starting* in the tile finishes
inside the window (see ``ref.MARGIN_BITS``).  Unit and outcome words
are bit-identical to :mod:`repro.kernels.unpack_bits.ref` at every
offset the resolver can consume; margin-start chains clamped at the
window edge are never read back.

Like ``pack_bits``, this kernel has only ever run in interpret mode
(CPU CI); compiled-TPU validation rides the ROADMAP hardware item.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.unpack_bits import ref as _ref

_ZRL = _ref.ZRL


def _gather(arr, idx):
    """``arr[idx]`` for (W, 1) int32 columns, TPU-gather shaped."""
    return jnp.take_along_axis(arr, idx, axis=0)


def _make_kernel(tile_bits: int, window: int):
    def unit_words(w16, pidx, nbits, params_ref, base, sym_ref):
        length = jnp.zeros(w16.shape, jnp.int32)
        sidx = jnp.zeros(w16.shape, jnp.int32)
        for L in range(1, 17):
            c = w16 >> (16 - L)
            mn = params_ref[base + L - 1]
            mx = params_ref[base + 16 + L - 1]
            vp = params_ref[base + 32 + L - 1]
            ok = (mx >= 0) & (c >= mn) & (c <= mx)
            length = jnp.where(ok, L, length)
            sidx = jnp.where(ok, vp + (c - mn), sidx)
        j = jax.lax.broadcasted_iota(jnp.int32, (window, 256), 1)
        hot = (sidx == j) & (length > 0)
        sym = jnp.sum(jnp.where(hot, sym_ref[...], 0), axis=1,
                      keepdims=True)
        size = jnp.where(sym > _ref.MAX_CATEGORY, sym & 0xF, sym)
        adv = length + size
        ctrl = jnp.where(length == 0, -1, sym)
        ctrl = jnp.where(pidx + adv > nbits, -2, ctrl)
        adv = jnp.where(ctrl < 0, 0, adv)
        return ((ctrl + 2) << 6) | adv

    def kernel(meta_ref, params_ref, win_ref, dcsym_ref, acsym_ref,
               dcw_ref, acw_ref, out_ref):
        i = pl.program_id(0)
        t0 = i * tile_bits
        nbits = meta_ref[0]
        w16 = win_ref[pl.ds(t0, window), :]                # (W, 1)
        pidx = t0 + jax.lax.broadcasted_iota(jnp.int32, (window, 1), 0)
        dcw = unit_words(w16, pidx, nbits, params_ref, 0, dcsym_ref)
        acw = unit_words(w16, pidx, nbits, params_ref, 48, acsym_ref)

        ctrl = (acw >> 6) - 2
        adv = acw & 0x3F
        idx = jax.lax.broadcasted_iota(jnp.int32, (window, 1), 0)
        term = ctrl <= 0
        d0 = jnp.where(term, 0, (ctrl >> 4) + 1)
        j0 = jnp.where(term, idx, jnp.minimum(idx + adv, window - 1))
        levels = []
        J, S = j0, d0
        for _ in range(6):
            levels.append((J, S))
            S = S + _gather(S, J)
            J = _gather(J, J)
        t_ctrl = _gather(ctrl, J)
        t_end = t0 + J + _gather(adv, J)
        t_out = jnp.where(
            t_ctrl == 0, t_end << 2,
            jnp.where(t_ctrl == -1, ((t0 + J) << 2) | 1,
                      ((t0 + J) << 2) | 2))
        cur, s = idx, jnp.zeros((window, 1), jnp.int32)
        for Jk, Sk in reversed(levels):
            ns = s + _gather(Sk, cur)
            take = ns < 63
            s = jnp.where(take, ns, s)
            cur = jnp.where(take, _gather(Jk, cur), cur)
        c_ctrl = _gather(ctrl, cur)
        c_run = jnp.where(c_ctrl > 0, c_ctrl >> 4, 0)
        overrun = (c_ctrl != _ZRL) & (s + c_run + 1 >= 64)
        c_out = jnp.where(overrun, 3,
                          (t0 + cur + _gather(adv, cur)) << 2)
        outc = jnp.where(S < 63, t_out, c_out)

        dcw_ref[...] = dcw.reshape(1, window)
        acw_ref[...] = acw.reshape(1, window)
        out_ref[...] = outc.reshape(1, window)

    return kernel


@functools.partial(jax.jit, static_argnames=("n_tiles", "tile_bits",
                                             "window", "interpret"))
def unpack_bits_pallas(meta: jnp.ndarray, params: jnp.ndarray,
                       win: jnp.ndarray, dc_syms: jnp.ndarray,
                       ac_syms: jnp.ndarray, *, n_tiles: int,
                       tile_bits: int = 2048, window: int = 4096,
                       interpret: bool = True) -> tuple:
    """Stage unit and outcome words for every payload bit offset.

    Args:
        meta: (1,) int32 scalar-prefetch — the payload bit count.
        params: (96,) int32 scalar-prefetch — per-length canonical
            bounds ``mincode[16] | maxcode[16] | valptr[16]`` for the
            DC then the AC table (``maxcode == -1`` marks an unused
            code length).
        win: (n_pad, 1) int32 MSB-first 16-bit windows from
            ``bitio.bit_windows``, padded with 0xFFFF to at least
            ``n_tiles * tile_bits + window`` rows.
        dc_syms: (1, 256) int32 DC symbol list in canonical order.
        ac_syms: (1, 256) int32 AC symbol list in canonical order.
        n_tiles: grid size (static via the jit cache key).
        tile_bits: bit offsets resolved per program.
        window: offsets staged per program; must cover ``tile_bits +
            MARGIN_BITS`` so chains starting in the tile finish inside.
        interpret: run in Pallas interpret mode (non-TPU backends).

    Returns:
        ``(dc_words, ac_words, outcomes)`` — (n_tiles, window) int32
        arrays in the layouts documented in
        :mod:`repro.kernels.unpack_bits.ref`.
    """
    if window < tile_bits + _ref.MARGIN_BITS:
        raise ValueError(f"window {window} cannot cover a {tile_bits}-bit "
                         f"tile (needs >= tile_bits + {_ref.MARGIN_BITS})")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, window), lambda i, meta, params: (i, 0)),
            pl.BlockSpec((1, window), lambda i, meta, params: (i, 0)),
            pl.BlockSpec((1, window), lambda i, meta, params: (i, 0)),
        ],
    )
    shape = jax.ShapeDtypeStruct((n_tiles, window), jnp.int32)
    return pl.pallas_call(
        _make_kernel(tile_bits, window),
        out_shape=[shape, shape, shape],
        grid_spec=grid_spec,
        interpret=interpret,
    )(meta, params, win, dc_syms, ac_syms)
