from repro.kernels.unpack_bits.kernel import unpack_bits_pallas  # noqa: F401
from repro.kernels.unpack_bits.ops import (BACKENDS,  # noqa: F401
                                           make_unpacker, scratch_nbytes,
                                           select_backend, unpack_bits)
from repro.kernels.unpack_bits.ref import unpack_bits_ref  # noqa: F401
