"""Routed public wrappers for the unpack_bits kernel.

``unpack_bits`` is the decode backend the entropy layer routes through
via ``rle.decode_payload(unpacker=)``: the Pallas speculative-decode
kernel on TPU, the staged NumPy reference everywhere else — the same
backend-selection shape as :mod:`repro.kernels.pack_bits` on the
encode side, and coefficient-identical output either way (CI-gated by
``bench_entropy_throughput --check-identical``).
"""

from __future__ import annotations

import functools

import jax
import numpy as np

from repro.core.entropy import bitio, huffman
from repro.kernels import tuning
from repro.kernels.unpack_bits import kernel, ref

TILE_BITS = 2048                    # default bit offsets resolved per program
WINDOW = TILE_BITS + ref.MARGIN_BITS

# Above this many payload bits the stream falls back to the NumPy
# reference: the kernel holds the (n_pad, 1) int32 window array
# unblocked in VMEM and stages three (n_tiles, WINDOW) outputs, and
# pow2 padding doubles the worst case, so 2**20 bits (~128 KB payload,
# beyond typical per-image streams) keeps the resident arrays a few MB.
# Blocking the window array would lift the cap if ever needed.
MAX_DEVICE_BITS = 1 << 20

BACKENDS = ("pallas", "numpy")

scratch_nbytes = ref.scratch_nbytes


def select_backend(backend: str = "auto") -> str:
    """Resolve the unpacking backend name ("pallas" on TPU, else "numpy")."""
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "numpy"
    if backend not in BACKENDS:
        raise ValueError(f"unknown unpack_bits backend {backend!r}; "
                         f"expected one of {('auto',) + BACKENDS}")
    return backend


def unpack_bits(payload: bytes, n_blocks: int,
                dc_table: huffman.CanonicalTable,
                ac_table: huffman.CanonicalTable, *,
                backend: str = "auto",
                tile_bits: int | None = None,
                interpret: bool | None = None) -> tuple:
    """Decode one entropy payload into ``(dc_diff, ac)`` coefficients.

    Same contract as :func:`repro.core.entropy.rle.decode_payload`
    (same values, same errors at the same bit offsets), with the
    speculative stage routed per backend.

    Args:
        payload: MSB-first packed entropy bytes (1-padded tail).
        n_blocks: number of 8x8 blocks encoded in the payload.
        dc_table: magnitude-category Huffman table (symbols <= 15).
        ac_table: (run, size) Huffman table.
        backend: "auto" (Pallas on TPU, NumPy elsewhere), "pallas", or
            "numpy".
        tile_bits: bit offsets resolved per kernel program (pow2);
            ``None`` routes through the tuned-tile artifact
            (:func:`repro.kernels.tuning.tile_for`, falling back to
            :data:`TILE_BITS`).  Ignored by "numpy".  The speculative
            window is always ``tile_bits + ref.MARGIN_BITS``.
        interpret: Pallas interpret-mode override (None = interpret
            exactly when no TPU is present); ignored by "numpy".

    Returns:
        ``(dc_diff (n_blocks,) int32, ac (n_blocks, 63) int32)``,
        identical across backends and across every ``tile_bits``.
    """
    if select_backend(backend) == "numpy":
        return ref.unpack_bits_ref(payload, n_blocks, dc_table, ac_table)
    return _unpack_device(payload, n_blocks, dc_table, ac_table, interpret,
                          tile_bits)


def make_unpacker(backend: str = "auto", interpret: bool | None = None,
                  tile_bits: int | None = None):
    """Unpacking callable for the entropy decoders' ``unpacker`` argument.

    Returns ``None`` when the resolved backend is "numpy" — callers
    then keep their zero-indirection default (the LUT walk inside
    :func:`repro.core.entropy.rle.decode_payload`) — and a routed
    device-unpacking callable for "pallas".  The returned partial is
    picklable, so ``decode_batch(executor="process")`` can ship it to
    spawned workers (which then import jax on first use).
    """
    if select_backend(backend) == "numpy":
        return None
    return functools.partial(unpack_bits, backend="pallas",
                             tile_bits=tile_bits, interpret=interpret)


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def table_params(table: huffman.CanonicalTable) -> tuple:
    """Canonical decode parameters for the kernel's bounds matcher.

    Returns ``(params (48,) int32, symbols (256,) int32)`` where
    ``params`` is ``mincode[16] | maxcode[16] | valptr[16]``:
    at code length ``L`` (1-based), valid codes are exactly
    ``mincode[L-1] .. maxcode[L-1]`` (``maxcode == -1`` when the table
    has no codes of that length) and the matching symbol is
    ``symbols[valptr[L-1] + code - mincode[L-1]]`` — the classic
    T.81 F.2.2.3 decoder state, here evaluated for all 16 lengths at
    once since prefix-free codes make at most one length match.
    """
    params = np.full(48, -1, np.int32)
    syms = np.zeros(256, np.int32)
    syms[:len(table.symbols)] = table.symbols
    code = 0
    k = 0
    for i, c in enumerate(table.counts):
        if c:
            params[i] = code                # mincode
            params[16 + i] = code + c - 1   # maxcode
            params[32 + i] = k              # valptr
        else:
            params[i] = 0
            params[32 + i] = 0
        code = (code + c) << 1
        k += c
    return params, syms


def _unpack_device(payload: bytes, n_blocks: int,
                   dc_table: huffman.CanonicalTable,
                   ac_table: huffman.CanonicalTable,
                   interpret: bool | None,
                   tile_bits: int | None = None) -> tuple:
    """Host orchestration of the device speculative decode.

    The kernel stages unit/outcome words for every bit offset; chain
    resolution and value emission are the shared O(1)-per-block host
    stage (:func:`repro.kernels.unpack_bits.ref.resolve`).  Tile count
    is bucketed to powers of two so a streaming workload sees a
    bounded set of compiled shapes.
    """
    from repro.kernels import common
    if interpret is None:
        interpret = common.interpret_default()
    if dc_table.symbols and max(dc_table.symbols) > ref.MAX_CATEGORY:
        raise ValueError(f"DC table codes symbol {max(dc_table.symbols)} "
                         f"> {ref.MAX_CATEGORY}: not a magnitude-category "
                         f"alphabet")
    if n_blocks == 0:
        return (np.zeros(0, np.int32), np.zeros((0, ref.AC_LEN), np.int32))
    nbits = len(payload) * 8
    if nbits == 0 or nbits > MAX_DEVICE_BITS:
        return ref.unpack_bits_ref(payload, n_blocks, dc_table, ac_table)
    if tile_bits is None:
        tile_bits = tuning.tile_for("unpack_bits", nbits)
    window = tile_bits + ref.MARGIN_BITS
    win = bitio.bit_windows(payload)
    n_tiles = _pow2(-(-(nbits + 1) // tile_bits))
    n_pad = n_tiles * tile_bits + window
    win_col = np.full((n_pad, 1), 0xFFFF, np.int32)
    win_col[:win.size, 0] = win
    dc_params, dc_syms = table_params(dc_table)
    ac_params, ac_syms = table_params(ac_table)
    dcw, acw, outc = kernel.unpack_bits_pallas(
        np.array([nbits], np.int32),
        np.concatenate([dc_params, ac_params]),
        win_col, dc_syms.reshape(1, -1), ac_syms.reshape(1, -1),
        n_tiles=n_tiles, tile_bits=tile_bits, window=window,
        interpret=interpret)
    dcw, acw, outc = (np.asarray(a) for a in (dcw, acw, outc))

    def get_tile(t):
        return dcw[t], acw[t], outc[t]

    return ref.resolve(win, nbits, n_blocks, tile_bits, get_tile)
