"""Staged NumPy reference for speculative parallel Huffman decode.

The scalar oracle (:func:`repro.core.entropy.rle.decode_payload_reference`)
walks the payload one codeword at a time; its LUT-walk successor
(``decode_payload``) removes the per-*codeword* Python loop but still
serialises on the chain of bit offsets.  This module removes that
dependency too, following Cloud et al. (arXiv:1107.1525): decode
speculatively from *every* candidate bit offset, then resolve the one
true chain per block.

The work is split into two stages sharing a compact per-position "unit
word" encoding (also produced by the Pallas kernel in
:mod:`repro.kernels.unpack_bits.kernel`):

1. **stage** (data-parallel, per tile) — for every bit offset ``p`` in
   a tile, decode the single codeword starting at ``p`` against both
   Huffman tables and summarise it as a unit word; then collapse each
   speculative *AC chain* starting at ``p`` into one outcome word via
   pointer doubling over the per-position ``next`` array (6 squarings
   cover the at-most-64 units of a block).
2. **resolve** (host, per block) — hop block starts through the
   precomputed outcomes: each block costs O(1) lookups (one DC unit
   word + one AC chain outcome), after which coefficient values are
   emitted tile-by-tile with a vectorized wavefront over all blocks
   that start in the tile (every block advances one unit per step, at
   most 64 steps, regardless of block count).

Unit word layout (int64 here, int32 in the kernel)::

    word = (ctrl + 2) << 6 | advance
    ctrl    = -2 truncated | -1 invalid prefix | symbol byte
    advance = code length + amplitude width (0 for terminal units)

Outcome word layout::

    word = value << 2 | kind
    kind  = 0 ok (value = first bit after the block's AC run)
            1 invalid prefix   (value = offending bit offset)
            2 truncated        (value = offending bit offset)
            3 AC run overruns the block (value unused)

Amplitude bits are *not* staged: they are re-read from the shared
``bitio.bit_windows`` array only at resolved offsets, so decoder
scratch is bounded by ``TILE_BITS + MARGIN_BITS`` positions however
long the payload is — unlike the LUT walk, whose tables grow with
every payload bit (see :func:`scratch_nbytes`).

Bit-exact against ``decode_payload_reference`` on every stream, with
the same error classes and messages on malformed input.
"""

from __future__ import annotations

import numpy as np

from repro.core.entropy import bitio, huffman

AC_LEN = 63                   # AC coefficients per 8x8 block
MAX_CATEGORY = 15             # largest magnitude category (amplitude width)
ZRL = 0xF0                    # sixteen-zeros AC run marker

#: Default bit offsets per resolver tile.  Any positive value is
#: correct; this one keeps per-tile scratch around a few MB while
#: amortising the staging cost over many blocks.
TILE_BITS = 1 << 15

#: Stage window overhang past the tile: a block whose *DC* codeword
#: starts inside the tile must finish inside ``tile + margin``.  Worst
#: case is 31 bits of DC unit (16-bit code + 15-bit amplitude), then 63
#: non-terminal AC units of 31 bits plus one terminal EOB code of up to
#: 16 bits: 31 + 63 * 31 + 16 = 2000 < 2048.
MARGIN_BITS = 1 << 11

_CTRL_SHIFT = 6
_ADV_MASK = 0x3F

# outcome kinds
_OK, _INVALID, _TRUNCATED, _OVERRUN = 0, 1, 2, 3


def scratch_nbytes(nbits: int, tile_bits: int = TILE_BITS) -> int:
    """Upper bound on the staged decoder's per-tile scratch, in bytes.

    Counts the dominant int64 per-position arrays held at once while
    staging one tile: two unit-word arrays, the outcome array, the six
    doubling levels (position + step-sum each), and roughly four
    temporaries of the same shape.  The bound is *constant* in the
    payload size once ``nbits`` exceeds one tile — the claim the
    ``entropy_decode`` bench case measures against the LUT walk's
    per-payload-bit tables.
    """
    w = min(tile_bits + MARGIN_BITS, max(nbits, 0) + 1)
    return (3 + 12 + 4) * 8 * w


def _unit_words(win: np.ndarray, nbits: int, t0: int, w: int,
                sym_lut: np.ndarray, len_lut: np.ndarray) -> np.ndarray:
    """Speculative unit words for bit offsets ``[t0, t0 + w)``.

    One vectorized pass over the 16-bit windows: prefix-LUT decode,
    then classification.  Truncation (the codeword or its amplitude
    would read past ``nbits``) takes precedence over an invalid prefix,
    matching ``rle._decode_table``'s sentinel override.
    """
    hi = min(t0 + w, win.shape[0])
    ww = np.empty(w, np.int64)
    k = max(hi - t0, 0)
    ww[:k] = win[t0:hi]
    ww[k:] = 0xFFFF                        # past-end: arbitrary, truncated
    sym = sym_lut[ww].astype(np.int64)
    length = len_lut[ww].astype(np.int64)
    size = np.where(sym > MAX_CATEGORY, sym & 0xF, sym)
    adv = length + size
    ctrl = np.where(length == 0, -1, sym)
    ctrl = np.where(t0 + np.arange(w) + adv > nbits, -2, ctrl)
    adv = np.where(ctrl < 0, 0, adv)       # terminal units advance nowhere
    return ((ctrl + 2) << _CTRL_SHIFT) | adv


def _ac_outcomes(ac_words: np.ndarray, t0: int) -> np.ndarray:
    """Collapse every speculative AC chain into one outcome word.

    ``next`` hops land on the first bit after each unit; terminal units
    (EOB / invalid / truncated) absorb.  Each non-terminal unit covers
    ``run + 1`` coefficient positions (ZRL is run 15 with no
    coefficient, i.e. exactly 16 positions), so six squarings of the
    (position-after, positions-covered) maps summarise 64 units — more
    than any legal chain.  A chain either parks on a terminal with
    fewer than 63 positions covered, or crosses position 63; the
    crossing unit is recovered by a top-down binary descent through the
    saved doubling levels.
    """
    w = ac_words.shape[0]
    ctrl = (ac_words >> _CTRL_SHIFT) - 2
    adv = ac_words & _ADV_MASK
    idx = np.arange(w, dtype=np.int64)
    term = ctrl <= 0                       # EOB or error: absorbing
    d0 = np.where(term, 0, (ctrl >> 4) + 1)
    j0 = np.where(term, idx, np.minimum(idx + adv, w - 1))
    levels = []
    J, S = j0, d0
    for _ in range(6):
        levels.append((J, S))
        S = S + S[J]
        J = J[J]
    # parked-on-terminal branch (S < 63 after 64 steps)
    t_ctrl = ctrl[J]
    t_end = t0 + J + adv[J]
    t_out = np.where(
        t_ctrl == 0, (t_end << 2) | _OK,
        np.where(t_ctrl == -1, ((t0 + J) << 2) | _INVALID,
                 ((t0 + J) << 2) | _TRUNCATED))
    # crossing branch: descend to the unit that reaches position >= 63
    cur, s = idx.copy(), np.zeros(w, np.int64)
    for Jk, Sk in reversed(levels):
        ns = s + Sk[cur]
        take = ns < 63
        s = np.where(take, ns, s)
        cur = np.where(take, Jk[cur], cur)
    c_ctrl = ctrl[cur]
    c_run = np.where(c_ctrl > 0, c_ctrl >> 4, 0)
    # a ZRL may overshoot 63 freely; a coefficient landing past the
    # last column (position 62) is the reference's "overruns block"
    overrun = (c_ctrl != ZRL) & (s + c_run + 1 >= 64)
    c_out = np.where(overrun, _OVERRUN,
                     ((t0 + cur + adv[cur]) << 2) | _OK)
    return np.where(S < 63, t_out, c_out)


def _emit_tile(win: np.ndarray, t0: int, dc_words: np.ndarray,
               ac_words: np.ndarray, dc_starts: list, ac_starts: list,
               block_ids: list, dc_out: np.ndarray,
               ac_out: np.ndarray) -> None:
    """Emit coefficient values for all blocks starting in one tile.

    DC amplitudes are gathered in one shot; AC units are emitted with a
    wavefront — every live block consumes one unit per step, so the
    loop runs at most 64 times however many blocks the tile holds.
    Amplitude bits are re-read from ``win`` at the resolved offsets
    only (the unit words carry no values).
    """
    def amplitude(p, words):
        x = words[p - t0]
        adv = x & _ADV_MASK
        c = (x >> _CTRL_SHIFT) - 2
        size = c & 0xF                     # c >= 0 for resolved units
        safe = np.maximum(size, 1)
        bits = win[p + (adv - size)].astype(np.int64) >> (16 - safe)
        val = np.where(bits < (1 << (safe - 1)), bits - (1 << safe) + 1,
                       bits)
        return c, adv, np.where(size == 0, 0, val)

    bids = np.asarray(block_ids, np.int64)
    _, _, dc_val = amplitude(np.asarray(dc_starts, np.int64), dc_words)
    dc_out[bids] = dc_val.astype(np.int32)

    pos = np.zeros(len(bids), np.int64)
    p = np.asarray(ac_starts, np.int64)
    alive = np.ones(len(bids), bool)
    while alive.any():
        c, adv, val = amplitude(p[alive], ac_words)
        eob = c == 0
        run = c >> 4
        coef = ~eob & (c != ZRL)
        col = pos[alive] + run
        if coef.any():
            ac_out[bids[alive][coef], col[coef]] = val[coef].astype(np.int32)
        new_pos = pos[alive] + np.where(eob, 0, run + 1)
        pos[alive] = new_pos
        p[alive] += adv
        live_idx = np.flatnonzero(alive)
        alive[live_idx[eob | (new_pos >= AC_LEN)]] = False


def resolve(win: np.ndarray, nbits: int, n_blocks: int, tile_bits: int,
            get_tile) -> tuple:
    """Resolve the true chain and emit values from staged tiles.

    ``get_tile(t)`` must return ``(dc_words, ac_words, outcomes)`` for
    bit offsets ``[t * tile_bits, t * tile_bits + w)`` with
    ``w >= min(tile_bits + MARGIN_BITS, nbits + 1 - t * tile_bits)`` —
    the stage is the parallel part; this resolver is the serial O(1)
    -per-block remainder, shared by the NumPy and Pallas backends.

    Raises exactly what ``rle.decode_payload`` raises, at the same bit
    offsets: :class:`repro.core.entropy.bitio.TruncatedStream` when a
    block needs bits past the payload, ``ValueError`` on invalid
    prefixes and AC runs overrunning a block.
    """
    dc_out = np.zeros(n_blocks, np.int32)
    ac_out = np.zeros((n_blocks, AC_LEN), np.int32)
    t = -1
    dcw = acw = outc = None
    dc_starts: list = []
    ac_starts: list = []
    block_ids: list = []
    p = 0
    for b in range(n_blocks):
        nt = p // tile_bits
        if nt != t:
            if block_ids:
                _emit_tile(win, t * tile_bits, dcw, acw, dc_starts,
                           ac_starts, block_ids, dc_out, ac_out)
                dc_starts, ac_starts, block_ids = [], [], []
            dcw, acw, outc = get_tile(nt)
            t = nt
        t0 = t * tile_bits
        x = int(dcw[p - t0])
        c = (x >> _CTRL_SHIFT) - 2
        if c == -2:
            raise bitio.TruncatedStream(
                f"entropy payload truncated: needed bit {p} of {nbits}")
        if c == -1:
            raise ValueError(f"invalid DC Huffman prefix at bit {p}")
        q = p + (x & _ADV_MASK)
        o = int(outc[q - t0])
        kind = o & 3
        v = o >> 2
        if kind == _INVALID:
            raise ValueError(f"invalid AC Huffman prefix at bit {v}")
        if kind == _TRUNCATED:
            raise bitio.TruncatedStream(
                f"entropy payload truncated: needed bit {v} of {nbits}")
        if kind == _OVERRUN:
            raise ValueError(f"corrupted stream: AC run overruns block {b}")
        dc_starts.append(p)
        ac_starts.append(q)
        block_ids.append(b)
        p = v
    if block_ids:
        _emit_tile(win, t * tile_bits, dcw, acw, dc_starts, ac_starts,
                   block_ids, dc_out, ac_out)
    return dc_out, ac_out


def unpack_bits_ref(payload: bytes, n_blocks: int,
                    dc_table: huffman.CanonicalTable,
                    ac_table: huffman.CanonicalTable, *,
                    tile_bits: int = TILE_BITS) -> tuple:
    """Staged NumPy decode of one entropy payload.

    Same contract as :func:`repro.core.entropy.rle.decode_payload`:
    returns ``(dc_diff (n_blocks,), ac (n_blocks, 63)) int32`` and
    raises the reference's errors on malformed streams.

    Args:
        payload: MSB-first packed entropy bytes (1-padded tail).
        n_blocks: number of 8x8 blocks encoded in the payload.
        dc_table: magnitude-category Huffman table (symbols <= 15).
        ac_table: (run, size) Huffman table.
        tile_bits: bit offsets staged per tile; any positive value
            decodes identically (tests shrink it to force blocks to
            straddle tile boundaries).
    """
    if dc_table.symbols and max(dc_table.symbols) > MAX_CATEGORY:
        raise ValueError(f"DC table codes symbol {max(dc_table.symbols)} "
                         f"> {MAX_CATEGORY}: not a magnitude-category "
                         f"alphabet")
    if n_blocks == 0:
        return np.zeros(0, np.int32), np.zeros((0, AC_LEN), np.int32)
    if tile_bits <= 0:
        raise ValueError(f"tile_bits must be positive, got {tile_bits}")
    nbits = len(payload) * 8
    win = bitio.bit_windows(payload)
    dc_sym, dc_len = huffman.decoder_luts(dc_table)
    ac_sym, ac_len = huffman.decoder_luts(ac_table)

    def get_tile(t):
        t0 = t * tile_bits
        w = min(tile_bits + MARGIN_BITS, nbits + 1 - t0)
        dcw = _unit_words(win, nbits, t0, w, dc_sym, dc_len)
        acw = _unit_words(win, nbits, t0, w, ac_sym, ac_len)
        return dcw, acw, _ac_outcomes(acw, t0)

    return resolve(win, nbits, n_blocks, tile_bits, get_tile)
