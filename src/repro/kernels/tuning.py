"""Tuned-tile artifact: the routing side of the kernel autotuner.

The autotuner (:mod:`repro.bench.autotune`) sweeps pow2 tile candidates
per (kernel, backend, shape bucket) and persists the winners as a
versioned, git-sha-stamped JSON artifact (``results/tuning.json`` by
default).  This module is the *consumer*: each kernel's ``ops.py``
router calls :func:`tile_for` when the caller leaves the tile knob at
``None``, and gets either a tuned winner or the built-in default.

The loader is deliberately paranoid and quiet:

* the artifact is read lazily, once, under a lock (engine worker
  threads route ``pack_bits`` concurrently);
* a missing file, unparseable JSON, wrong ``schema_version``, invalid
  entries, or a backend mismatch each fall back to :data:`DEFAULTS`
  with a **single** :class:`TuningWarning` per failure reason — never
  an exception, never a repeat warning, never a silent misroute;
* ``REPRO_TUNING_PATH`` overrides the artifact location (tests and
  multi-machine result trees).

This module imports without jax so the jax-free entropy decode workers
can keep importing the kernel packages' neighbours cheaply; only
:func:`tile_for` touches the backend name, and callers pass it in.
"""

from __future__ import annotations

import json
import os
import pathlib
import threading

TUNING_SCHEMA_VERSION = 1

ENV_VAR = "REPRO_TUNING_PATH"

# Built-in defaults: the pre-autotuner hard-coded knobs, kept as the
# fallback whenever no valid tuned entry applies.  ``tile`` is the
# pick_tile target for the image kernels; ``tile_bits`` is the per-tile
# bit budget of the entropy pack/unpack kernels (window margins are
# derived by the ops modules, not stored here); ``block_rows`` is the
# gradient rows per grad_dct program; ``tile_blocks`` the 8x8 blocks
# per symbolize program.
DEFAULTS = {
    "dct8x8": {"tile": 256},
    "cordic_loeffler": {"tile": 256},
    "fused_codec": {"tile": 256},
    "pack_bits": {"tile_bits": 1024},
    "unpack_bits": {"tile_bits": 2048},
    "grad_dct": {"block_rows": 512},
    "symbolize": {"tile_blocks": 64},
}

KERNELS = tuple(DEFAULTS)

# The single knob each kernel exposes to the autotuner.
PARAM_OF = {k: next(iter(v)) for k, v in DEFAULTS.items()}


class TuningWarning(UserWarning):
    """A tuning artifact could not be used; built-in defaults apply."""


_lock = threading.Lock()
_cache: dict = {"path": None, "doc": None}
_warned: set = set()


def default_path() -> pathlib.Path:
    """Artifact path: ``$REPRO_TUNING_PATH`` or ``<repo>/results/tuning.json``."""
    env = os.environ.get(ENV_VAR)
    if env:
        return pathlib.Path(env)
    return pathlib.Path(__file__).resolve().parents[3] / "results" / "tuning.json"


def bucket_of(dim: int) -> int:
    """Pow2 shape bucket a dimension (or bit count) falls into (min 8).

    Tuned entries are keyed by pow2 buckets, the same bounded-shape-set
    idiom the serving engine and the pack/unpack routers already use,
    so one sweep covers a family of nearby sizes.
    """
    b = 8
    while b < dim:
        b *= 2
    return b


def validate(doc) -> list:
    """Check an artifact document; returns its entries or raises ValueError."""
    if not isinstance(doc, dict):
        raise ValueError("tuning artifact is not a JSON object")
    version = doc.get("schema_version")
    if version != TUNING_SCHEMA_VERSION:
        raise ValueError(
            f"tuning schema_version={version!r} but this reader understands "
            f"{TUNING_SCHEMA_VERSION}; re-run `python -m repro.bench autotune`")
    if not isinstance(doc.get("backend"), str):
        raise ValueError("tuning artifact has no backend string")
    entries = doc.get("entries")
    if not isinstance(entries, list):
        raise ValueError("tuning artifact has no entries list")
    for e in entries:
        if not isinstance(e, dict):
            raise ValueError("tuning entry is not an object")
        kern = e.get("kernel")
        if kern not in KERNELS:
            raise ValueError(f"tuning entry for unknown kernel {kern!r}")
        bucket = e.get("bucket")
        if not (isinstance(bucket, int) and bucket >= 8
                and bucket & (bucket - 1) == 0):
            raise ValueError(f"tuning entry bucket {bucket!r} is not a pow2 >= 8")
        params = e.get("params")
        if not isinstance(params, dict) or PARAM_OF[kern] not in params:
            raise ValueError(
                f"tuning entry for {kern!r} lacks param {PARAM_OF[kern]!r}")
        value = params[PARAM_OF[kern]]
        if not (isinstance(value, int) and value >= 8
                and value & (value - 1) == 0):
            raise ValueError(
                f"tuning value {value!r} for {kern!r} is not a pow2 >= 8")
        if PARAM_OF[kern] == "tile_bits" and value % 8:
            raise ValueError(f"tile_bits {value} is not a byte multiple")
    return entries


def make_doc(entries: list, *, backend: str, environment: dict | None = None
             ) -> dict:
    """Assemble an artifact document (the autotuner's writer half)."""
    doc = {
        "schema_version": TUNING_SCHEMA_VERSION,
        "backend": backend,
        "environment": dict(environment or {}),
        "entries": list(entries),
    }
    validate(doc)
    return doc


def save(doc: dict, path: str | os.PathLike | None = None) -> pathlib.Path:
    """Write a validated artifact document; returns the path written."""
    validate(doc)
    p = pathlib.Path(path) if path is not None else default_path()
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(doc, indent=1) + "\n")
    return p


def invalidate_cache() -> None:
    """Forget the cached artifact (and warning history): next lookup reloads."""
    with _lock:
        _cache["path"] = None
        _cache["doc"] = None
        _warned.clear()


def _warn_once(reason_key: str, message: str) -> None:
    # Caller holds _lock.
    if reason_key in _warned:
        return
    _warned.add(reason_key)
    import warnings
    warnings.warn(message, TuningWarning, stacklevel=4)


def _load_doc() -> dict | None:
    """The cached artifact document, or None when defaults apply."""
    path = default_path()
    with _lock:
        if _cache["path"] == path:
            return _cache["doc"]
        doc = None
        try:
            raw = path.read_text()
        except FileNotFoundError:
            _warn_once("missing", f"no tuning artifact at {path}; using "
                       f"built-in tile defaults (run `python -m repro.bench "
                       f"autotune` to generate one)")
        except OSError as e:
            _warn_once("unreadable", f"tuning artifact {path} unreadable "
                       f"({e}); using built-in tile defaults")
        else:
            try:
                parsed = json.loads(raw)
                validate(parsed)
                doc = parsed
            except (ValueError, TypeError) as e:
                _warn_once("invalid", f"tuning artifact {path} rejected "
                           f"({e}); using built-in tile defaults")
        _cache["path"] = path
        _cache["doc"] = doc
        return doc


def lookup(kernel: str, dim: int, *, backend: str) -> dict | None:
    """Tuned params for ``kernel`` at ``dim`` on ``backend``, or None.

    Bucket precedence: the smallest swept bucket >= the requested
    bucket (a sweep at 256 covers a 200-wide image padded into the
    256 bucket), else the largest swept bucket (better a measured
    winner from a nearby smaller shape than an unmeasured default).
    Returns None — defaults apply — when no valid artifact entry for
    this kernel/backend exists.
    """
    if kernel not in KERNELS:
        raise KeyError(f"unknown kernel {kernel!r}; expected one of {KERNELS}")
    doc = _load_doc()
    if doc is None:
        return None
    if doc["backend"] != backend:
        with _lock:
            _warn_once("backend", f"tuning artifact was swept on backend "
                       f"{doc['backend']!r} but this process runs "
                       f"{backend!r}; using built-in tile defaults "
                       f"(re-run `python -m repro.bench autotune` here)")
        return None
    mine = [e for e in doc["entries"] if e["kernel"] == kernel]
    if not mine:
        return None
    want = bucket_of(dim)
    at_least = [e for e in mine if e["bucket"] >= want]
    if at_least:
        entry = min(at_least, key=lambda e: e["bucket"])
    else:
        entry = max(mine, key=lambda e: e["bucket"])
    return dict(entry["params"])


def tile_for(kernel: str, dim: int, backend: str | None = None) -> int:
    """The routed tile knob: tuned winner when one applies, else default.

    ``dim`` is the padded image dimension for the image kernels and the
    payload bit count for ``pack_bits``/``unpack_bits``.  ``backend``
    defaults to the current jax backend (imported lazily so this module
    stays importable without jax).
    """
    if backend is None:
        import jax
        backend = jax.default_backend()
    params = lookup(kernel, dim, backend=backend)
    name = PARAM_OF[kernel]
    if params is not None:
        return int(params[name])
    return int(DEFAULTS[kernel][name])
