"""Pure-jnp oracle for the dct8x8 kernel (block-planar layout)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import dct


def dct8x8_ref(img: jnp.ndarray) -> jnp.ndarray:
    """(H, W) -> (H, W) blockwise DCT coefficients, block-planar layout."""
    return dct.from_blocks(dct.blockwise_dct2d(img))


def idct8x8_ref(coeffs: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`dct8x8_ref`."""
    return dct.blockwise_idct2d(dct.to_blocks(coeffs))
