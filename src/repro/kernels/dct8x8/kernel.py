"""Pallas TPU kernel: blockwise 8x8 2-D DCT / IDCT via the Kronecker matmul.

TPU adaptation of the paper's CUDA DCT kernel (DESIGN.md §2).  The CUDA
version assigns one thread block per 8x8 pixel block with shared-memory
staging; here each *grid cell* owns a (TH, TW) VMEM tile holding
(TH/8)·(TW/8) pixel blocks, and the whole tile's transform is a single
(nblocks, 64) @ (64, 64) matmul against the Kronecker operator
T = kron(C8, C8) — an MXU-shaped contraction instead of 8-wide butterflies.

VMEM budget at the default 256x256 f32 tile: 256 KiB in + 256 KiB out +
16 KiB operator ≈ 0.5 MiB, comfortably inside the ~16 MiB/core VMEM of
TPU v5e, leaving room for double buffering.

Layout: both input and output use the *in-place block-planar* convention —
the coefficient block of image block (i, j) lives at pixels
[8i:8i+8, 8j:8j+8] (JPEG-style), so forward and inverse kernels compose
without reshuffles and the HBM access pattern is fully coalesced.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile_to_rows(x: jnp.ndarray) -> jnp.ndarray:
    """(TH, TW) tile -> (nblocks, 64) rows of vec(8x8 block)."""
    th, tw = x.shape
    b = x.reshape(th // 8, 8, tw // 8, 8)
    return b.transpose(0, 2, 1, 3).reshape(-1, 64)


def _rows_to_tile(rows: jnp.ndarray, th: int, tw: int) -> jnp.ndarray:
    """(nblocks, 64) -> (TH, TW) tile (inverse of _tile_to_rows)."""
    b = rows.reshape(th // 8, tw // 8, 8, 8)
    return b.transpose(0, 2, 1, 3).reshape(th, tw)


def _dct_kernel(x_ref, t_ref, o_ref):
    x = x_ref[...]
    t = t_ref[...]
    th, tw = x.shape
    rows = _tile_to_rows(x)
    o_ref[...] = _rows_to_tile(rows @ t.T, th, tw)


def _idct_kernel(y_ref, t_ref, o_ref):
    y = y_ref[...]
    t = t_ref[...]
    th, tw = y.shape
    rows = _tile_to_rows(y)
    # T is orthonormal: inverse = T^T, i.e. rows @ T
    o_ref[...] = _rows_to_tile(rows @ t, th, tw)


@functools.partial(jax.jit, static_argnames=("tile_h", "tile_w", "inverse",
                                             "interpret"))
def dct8x8_pallas(img: jnp.ndarray, t: jnp.ndarray, *, tile_h: int,
                  tile_w: int, inverse: bool = False,
                  interpret: bool = True) -> jnp.ndarray:
    """Blockwise 2-D (I)DCT of a (H, W) image, block-planar layout.

    H % tile_h == 0, W % tile_w == 0, tiles multiples of 8 (ops.py enforces).
    """
    h, w = img.shape
    kernel = _idct_kernel if inverse else _dct_kernel
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((h, w), img.dtype),
        grid=(h // tile_h, w // tile_w),
        in_specs=[
            pl.BlockSpec((tile_h, tile_w), lambda i, j: (i, j)),
            pl.BlockSpec((64, 64), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_h, tile_w), lambda i, j: (i, j)),
        interpret=interpret,
    )(img, t)
