from repro.kernels.dct8x8.ops import dct8x8, idct8x8  # noqa: F401
from repro.kernels.dct8x8.ref import dct8x8_ref, idct8x8_ref  # noqa: F401
