"""Jitted public wrappers for the dct8x8 Pallas kernel.

Handles padding to tile multiples, leading batch dims (vmap), and
interpret-mode selection (CPU container: interpret=True; real TPU:
compiled).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dct
from repro.kernels import common, tuning
from repro.kernels.dct8x8 import kernel


def _run(img: jnp.ndarray, inverse: bool, tile: int | None,
         interpret: bool | None) -> jnp.ndarray:
    if interpret is None:
        interpret = common.interpret_default()
    h, w = img.shape[-2:]
    padded = common.pad2d_to_multiple(img, 8, 8)
    ph, pw = padded.shape[-2:]
    if tile is None:
        tile = tuning.tile_for("dct8x8", max(ph, pw))
    th = common.pick_tile(ph, tile)
    tw = common.pick_tile(pw, tile)
    t = dct.kron_dct_matrix(8, padded.dtype)

    fn = lambda x: kernel.dct8x8_pallas(x, t, tile_h=th, tile_w=tw,
                                        inverse=inverse, interpret=interpret)
    for _ in range(img.ndim - 2):
        fn = jax.vmap(fn)
    out = fn(padded)
    return out[..., :h, :w] if (ph, pw) != (h, w) else out


def dct8x8(img: jnp.ndarray, *, tile: int | None = None,
           interpret: bool | None = None) -> jnp.ndarray:
    """Blockwise 8x8 2-D DCT, block-planar layout.  (..., H, W).

    ``tile=None`` routes through the tuned-tile artifact
    (:func:`repro.kernels.tuning.tile_for`); an explicit tile pins it.
    """
    return _run(img, inverse=False, tile=tile, interpret=interpret)


def idct8x8(coeffs: jnp.ndarray, *, tile: int | None = None,
            interpret: bool | None = None) -> jnp.ndarray:
    """Blockwise 8x8 2-D inverse DCT, block-planar layout.  (..., H, W)."""
    return _run(coeffs, inverse=True, tile=tile, interpret=interpret)
