"""Shared helpers for the Pallas TPU kernels.

All kernels in this package target TPU (pl.pallas_call with explicit
BlockSpec VMEM tiling) and are *validated* on CPU via interpret mode, which
executes the kernel body in Python.  ``interpret_default()`` picks the mode
from the runtime backend so the same call sites work in both environments.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def interpret_default() -> bool:
    """True when we must interpret (no real TPU present)."""
    return jax.default_backend() != "tpu"


def pad2d_to_multiple(x: jnp.ndarray, mh: int, mw: int) -> jnp.ndarray:
    """Edge-pad the last two dims up to multiples of (mh, mw)."""
    h, w = x.shape[-2:]
    ph, pw = (-h) % mh, (-w) % mw
    if ph == 0 and pw == 0:
        return x
    pad = [(0, 0)] * (x.ndim - 2) + [(0, ph), (0, pw)]
    return jnp.pad(x, pad, mode="edge")


def pick_tile(dim: int, target: int = 256, multiple: int = 8) -> int:
    """Largest tile <= target that divides ``dim`` and is a multiple of 8.

    Image dims here are always positive multiples of 8 (ops pad first),
    so a valid tile always exists — worst case ``multiple`` itself,
    which is also the answer whenever ``target < multiple``: the tile
    must stay a multiple of ``multiple`` to keep whole 8x8 blocks per
    grid cell, so the target is a ceiling on the *search*, not on the
    returned tile.
    """
    if dim <= 0:
        raise ValueError(f"dim must be positive, got {dim}")
    if dim % multiple:
        raise ValueError(f"dim {dim} not a multiple of {multiple}")
    best = multiple
    t = multiple
    while t <= min(dim, target):
        if dim % t == 0:
            best = t
        t += multiple
    return best
