"""Pallas TPU kernels for the compute hot-spots the paper optimises.

Each subpackage follows the kernel.py (pl.pallas_call + BlockSpec) /
ops.py (jit wrapper) / ref.py (pure-jnp oracle) layout:

  dct8x8          blockwise 2-D DCT/IDCT via the MXU Kronecker matmul
  cordic_loeffler paper-faithful Cordic-based Loeffler DCT (VPU shift-add)
  fused_codec     DCT->quant->dequant->IDCT in one HBM round-trip
  grad_dct        DCT-domain gradient compression (encode/decode)
  pack_bits       entropy-stage bit packing (prefix-sum + scatter); its
                  ref.py is staged NumPy, not jnp — the oracle must be
                  byte-exact, and bytes are a host-edge artifact
  unpack_bits     entropy-stage speculative Huffman decode (per-offset
                  unit words + pointer doubling, resolved per block on
                  the host); staged NumPy ref.py for the same reason

`tuning` is the shared tuned-tile lookup: when an ops.py router's tile
knob is left at None it consults the autotuned winners persisted in
``results/tuning.json`` (written by ``python -m repro.bench autotune``),
falling back to built-in defaults — with a single warning — when the
artifact is missing, invalid, or tuned for a different backend.
"""
