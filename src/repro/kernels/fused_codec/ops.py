"""Jitted public wrappers for the fused_codec Pallas kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import cordic, dct, quant
from repro.kernels import common, tuning
from repro.kernels.fused_codec import kernel


def fused_codec(img: jnp.ndarray, *, quality: int = 50,
                transform: str = "exact",
                config: cordic.CordicConfig = cordic.PAPER_CONFIG,
                tile: int | None = None, interpret: bool | None = None):
    """One-pass codec roundtrip.  (..., H, W) uint8/float.

    Returns (reconstructed uint8, quantised coeffs int32 block-planar).
    ``tile=None`` routes through the tuned-tile artifact
    (:func:`repro.kernels.tuning.tile_for`); an explicit tile pins it.
    """
    if interpret is None:
        interpret = common.interpret_default()
    img = jnp.asarray(img)
    h, w = img.shape[-2:]
    padded = common.pad2d_to_multiple(img, 8, 8).astype(jnp.float32)
    ph, pw = padded.shape[-2:]
    if tile is None:
        tile = tuning.tile_for("fused_codec", max(ph, pw))
    th = common.pick_tile(ph, tile)
    tw = common.pick_tile(pw, tile)
    t = dct.kron_dct_matrix(8)
    qvec = quant.qtable(quality).reshape(1, 64)

    fn = lambda x: kernel.fused_codec_pallas(
        x, t, qvec, tile_h=th, tile_w=tw, transform=transform, config=config,
        interpret=interpret)
    for _ in range(img.ndim - 2):
        fn = jax.vmap(fn)
    rec, qc = fn(padded)
    rec = rec[..., :h, :w].astype(jnp.uint8)
    qc = qc[..., :h, :w]
    return rec, qc
