"""Pallas TPU kernel: fused DCT -> quantise -> dequantise -> IDCT.

The paper runs DCT, quantiser and IDCT as *three separate CUDA kernels* —
three HBM round-trips.  At 8-bit-image arithmetic intensity the op is
bandwidth-bound on TPU v5e (819 GB/s HBM vs 197 TFLOP/s), so fusing the
whole codec into one kernel cuts HBM traffic ~3x: the tile is read once,
transformed, quantised, reconstructed in VMEM, and written once (plus the
quantised coefficients as a second output for entropy coding / telemetry).

This is the main beyond-paper kernel-level optimisation (DESIGN.md §2);
benchmarks/bench_table1 reports unfused vs fused.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import cordic, loeffler
from repro.kernels.dct8x8.kernel import _rows_to_tile, _tile_to_rows


def _make_kernel(transform: str, config: cordic.CordicConfig):
    """transform: 'exact' (MXU Kronecker matmul) or 'cordic' (flow graph)."""

    def kernel(x_ref, t_ref, q_ref, rec_ref, qc_ref):
        x = x_ref[...].astype(jnp.float32) - 128.0  # JPEG level shift
        t = t_ref[...]
        qvec = q_ref[...]          # (1, 64) quant steps, row-major block order
        th, tw = x.shape

        if transform == "exact":
            rows = _tile_to_rows(x)              # (nb, 64)
            coef = rows @ t.T                    # MXU contraction
            qc = jnp.round(coef / qvec)          # quantise
            deq = qc * qvec                      # dequantise
            rec = _rows_to_tile(deq @ t, th, tw)  # inverse (T orthonormal)
        elif transform == "cordic":
            rot = cordic.make_cordic_rotate(config)
            qfn = cordic.fixed_quantizer(config)
            blocks = x.reshape(th // 8, 8, tw // 8, 8).transpose(0, 2, 1, 3)
            coef = loeffler.loeffler_dct2d_8x8(blocks, rotate_fn=rot,
                                               quantize_fn=qfn)
            qtab = qvec.reshape(8, 8)
            qc4 = jnp.round(coef / qtab)
            deq = qc4 * qtab
            rec4 = loeffler.loeffler_idct2d_8x8(deq, rotate_fn=rot,
                                                quantize_fn=qfn)
            rec = rec4.transpose(0, 2, 1, 3).reshape(th, tw)
            qc = qc4.transpose(0, 2, 1, 3).reshape(th, tw)
        else:
            raise ValueError(f"unknown transform {transform!r}")

        rec_ref[...] = jnp.clip(jnp.round(rec + 128.0), 0.0, 255.0)
        if transform == "exact":
            qc_ref[...] = _rows_to_tile(qc, th, tw).astype(jnp.int32)
        else:
            qc_ref[...] = qc.astype(jnp.int32)

    return kernel


@functools.partial(jax.jit, static_argnames=("tile_h", "tile_w", "transform",
                                             "config", "interpret"))
def fused_codec_pallas(img: jnp.ndarray, t: jnp.ndarray, qvec: jnp.ndarray, *,
                       tile_h: int, tile_w: int, transform: str = "exact",
                       config: cordic.CordicConfig = cordic.PAPER_CONFIG,
                       interpret: bool = True):
    """One-pass codec roundtrip of a (H, W) image.

    Returns (reconstructed f32 in [0,255], quantised coeffs int32
    block-planar).
    """
    h, w = img.shape
    rec, qc = pl.pallas_call(
        _make_kernel(transform, config),
        out_shape=(jax.ShapeDtypeStruct((h, w), jnp.float32),
                   jax.ShapeDtypeStruct((h, w), jnp.int32)),
        grid=(h // tile_h, w // tile_w),
        in_specs=[
            pl.BlockSpec((tile_h, tile_w), lambda i, j: (i, j)),
            pl.BlockSpec((64, 64), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 64), lambda i, j: (0, 0)),
        ],
        out_specs=(pl.BlockSpec((tile_h, tile_w), lambda i, j: (i, j)),
                   pl.BlockSpec((tile_h, tile_w), lambda i, j: (i, j))),
        interpret=interpret,
    )(img, t, qvec)
    return rec, qc
