from repro.kernels.fused_codec.ops import fused_codec  # noqa: F401
from repro.kernels.fused_codec.ref import fused_codec_ref  # noqa: F401
