"""Pure-jnp oracle for the fused_codec kernel — the *unfused* pipeline.

This is deliberately the paper's three-pass structure (DCT kernel, quantiser
kernel, IDCT kernel) built from core/: it doubles as the reference the
kernel must match bit-for-bit in float32, and as the "unfused baseline" leg
of the fusion benchmark.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import cordic, dct, loeffler, quant


def fused_codec_ref(img: jnp.ndarray, quality: int = 50,
                    transform: str = "exact",
                    config: cordic.CordicConfig = cordic.PAPER_CONFIG):
    """Returns (reconstructed f32 [0,255], quantised coeffs int32 planar)."""
    x = img.astype(jnp.float32) - 128.0
    q = quant.qtable(quality)
    if transform == "exact":
        coef = dct.blockwise_dct2d(x)
    else:
        rot = cordic.make_cordic_rotate(config)
        qfn = cordic.fixed_quantizer(config)
        coef = loeffler.loeffler_dct2d_8x8(dct.to_blocks(x), rotate_fn=rot,
                                           quantize_fn=qfn)
    qc = jnp.round(coef / q)
    deq = qc * q
    if transform == "exact":
        rec = dct.blockwise_idct2d(deq)
    else:
        rot = cordic.make_cordic_rotate(config)
        qfn = cordic.fixed_quantizer(config)
        rec = dct.from_blocks(loeffler.loeffler_idct2d_8x8(
            deq, rotate_fn=rot, quantize_fn=qfn))
    rec = jnp.clip(jnp.round(rec + 128.0), 0.0, 255.0)
    return rec, dct.from_blocks(qc).astype(jnp.int32)
