"""Pallas TPU kernel: scatter-pack Huffman bit fields into bytes.

Device-resident realisation of the entropy encoder's last stage.  The
serial dependency of bit packing is the field offsets; those are a
prefix sum computed *outside* the kernel (Cloud et al.,
arXiv:1107.1525), so the kernel itself is a pure scatter: the grid
tiles the **output** bit space, and each program gathers the window of
fields that can touch its tile and accumulates their byte
contributions.

Two structural tricks keep the scatter TPU-shaped:

* **windowed gather instead of scatter** — fields are sorted by start
  offset and every kept field is at least one bit wide, so the fields
  overlapping a ``tile_bits``-bit tile form a contiguous index window
  of at most ``tile_bits + 15`` fields.  The per-tile first index is a
  host-side ``searchsorted`` handed in via scalar prefetch; the kernel
  reads the window with one dynamic slice.
* **one-hot byte accumulation** — a field of width <= 16 starting at
  bit offset ``s`` spans at most 3 bytes; its 24-bit aligned window
  splits into 3 byte contributions.  Distinct fields never share a bit,
  so byte values are a plain *sum* of contributions (each < 256, exact
  in f32), accumulated with a ``(window, tile_bytes)`` one-hot compare
  against the tile's byte indices — no data-dependent writes anywhere.

Bytes past the payload end are written as zero; the caller applies the
writer's 1-padding to the final partial byte (a framing concern, kept
at the edge).  Bit-exact against :mod:`repro.kernels.pack_bits.ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _make_kernel(tile_bits: int, window: int):
    nb = tile_bits // 8

    def kernel(first_ref, codes_ref, lens_ref, starts_ref, out_ref):
        i = pl.program_id(0)
        f0 = first_ref[i]
        c = codes_ref[pl.ds(f0, window), :]               # (W, 1) int32
        ln = lens_ref[pl.ds(f0, window), :]
        s = starts_ref[pl.ds(f0, window), :] - i * tile_bits
        # byte-aligned 24-bit window of each field: bits occupy
        # [s, s+len) == bits [8b + r, 8b + r + len) with r in 0..7, so
        # v = code << (24 - r - len) places them inside bytes b..b+2
        b = jnp.floor_divide(s, 8)
        r = s - 8 * b
        v = jnp.where(ln > 0, c << (24 - r - ln), 0)
        j = jax.lax.broadcasted_iota(jnp.int32, (window, nb), 1)
        acc = jnp.zeros((window, nb), jnp.float32)
        for t in range(3):
            byte_t = ((v >> (16 - 8 * t)) & 0xFF).astype(jnp.float32)
            acc += jnp.where(b + t == j, byte_t, 0.0)
        # fields never overlap in bit space, so summing the (at most
        # 8) sub-byte contributions per output byte is exact (< 256)
        out_ref[...] = acc.sum(axis=0, keepdims=True).astype(jnp.int32)

    return kernel


@functools.partial(jax.jit, static_argnames=("tile_bits", "window",
                                             "interpret"))
def pack_bits_pallas(codes: jnp.ndarray, lengths: jnp.ndarray,
                     starts: jnp.ndarray, first: jnp.ndarray, *,
                     tile_bits: int = 1024, window: int = 1040,
                     interpret: bool = True) -> jnp.ndarray:
    """Scatter-pack prepared bit fields into payload bytes.

    Args:
        codes: (M, 1) int32 field values (low ``lengths`` bits used);
            padding rows must have ``lengths == 0``.
        lengths: (M, 1) int32 widths in [0, 16]; kept fields (width
            > 0) must be sorted by ``starts`` and non-overlapping.
        starts: (M, 1) int32 start bit offsets (prefix sum of widths).
        first: (n_tiles,) int32 scalar-prefetch — index of the first
            field whose end exceeds each tile's start bit, clipped so
            ``first + window <= M`` (see :mod:`.ops`).
        tile_bits: output bits per grid program (multiple of 8).
        window: fields gathered per tile; must be >= ``tile_bits + 15``
            so every overlapping field is inside the window.
        interpret: run in Pallas interpret mode (non-TPU backends).

    Returns:
        (n_tiles, tile_bits // 8) int32 byte values in [0, 255]; bytes
        past the payload end are zero.
    """
    if tile_bits % 8:
        raise ValueError(f"tile_bits {tile_bits} not a multiple of 8")
    if window < tile_bits + 15:
        raise ValueError(f"window {window} cannot cover a "
                         f"{tile_bits}-bit tile (needs >= tile_bits+15)")
    n_tiles = first.shape[0]
    nb = tile_bits // 8
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, nb), lambda i, first_ref: (i, 0)),
    )
    return pl.pallas_call(
        _make_kernel(tile_bits, window),
        out_shape=jax.ShapeDtypeStruct((n_tiles, nb), jnp.int32),
        grid_spec=grid_spec,
        interpret=interpret,
    )(first, codes, lengths, starts)
