from repro.kernels.pack_bits.kernel import pack_bits_pallas  # noqa: F401
from repro.kernels.pack_bits.ops import (BACKENDS, make_packer,  # noqa: F401
                                         pack_bits, select_backend)
from repro.kernels.pack_bits.ref import pack_bits_ref  # noqa: F401
