"""Routed public wrappers for the pack_bits kernel.

``pack_bits`` is the packing backend the staged entropy encode pipeline
(:func:`repro.core.entropy.rle.encode_payload`) routes through: the
Pallas kernel on TPU, the staged NumPy reference everywhere else — the
same backend-selection shape as ``fused_codec`` (compiled kernel on
TPU, bit-exact fallback elsewhere), and byte-identical output either
way (CI-gated by ``bench_entropy_throughput --check-identical``).
"""

from __future__ import annotations

import functools

import jax
import numpy as np

from repro.kernels import tuning
from repro.kernels.pack_bits import kernel, ref

TILE_BITS = 1024                    # default output bits per kernel program
WINDOW = TILE_BITS + 16             # fields gathered per tile (>= T+15)
WINDOW_MARGIN = 16                  # window = tile_bits + this margin

# Above this many kept fields the stream falls back to the NumPy
# reference: the kernel holds the three (m_pad, 1) int32 field arrays
# unblocked in VMEM, and pow2 padding doubles the worst case, so the
# cap must keep 3 * 4 B * 2 * MAX_DEVICE_FIELDS comfortably under the
# ~16 MiB of a TPU core (2**18 fields -> at most 6 MiB of inputs).
# 2**18 16-bit fields is a ~512 KB payload, beyond typical per-image
# streams; blocking the field arrays would lift the cap if ever needed.
MAX_DEVICE_FIELDS = 1 << 18

BACKENDS = ("pallas", "numpy")


def select_backend(backend: str = "auto") -> str:
    """Resolve the packing backend name ("pallas" on TPU, else "numpy")."""
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "numpy"
    if backend not in BACKENDS:
        raise ValueError(f"unknown pack_bits backend {backend!r}; "
                         f"expected one of {('auto',) + BACKENDS}")
    return backend


def pack_bits(codes, lengths, *, backend: str = "auto",
              tile_bits: int | None = None,
              interpret: bool | None = None) -> bytes:
    """Concatenate MSB-first bit fields into padded payload bytes.

    Same contract as :func:`repro.core.entropy.bitio.pack_bits`
    (zero-width fields skipped, final partial byte 1-padded), with the
    packing stage routed per backend.

    Args:
        codes: (M,) non-negative ints; field k contributes its low
            ``lengths[k]`` bits, most significant first.
        lengths: (M,) field widths in [0, 16].
        backend: "auto" (Pallas on TPU, NumPy elsewhere), "pallas", or
            "numpy".
        tile_bits: output bits per kernel program (pow2, byte multiple);
            ``None`` routes through the tuned-tile artifact
            (:func:`repro.kernels.tuning.tile_for`, falling back to
            :data:`TILE_BITS`).  Ignored by "numpy".  The gather window
            is always ``tile_bits + WINDOW_MARGIN``.
        interpret: Pallas interpret-mode override (None = interpret
            exactly when no TPU is present); ignored by "numpy".

    Returns:
        The packed payload bytes, identical across backends and across
        every ``tile_bits``.
    """
    if select_backend(backend) == "numpy":
        return ref.pack_bits_ref(codes, lengths)
    return _pack_bits_device(codes, lengths, interpret, tile_bits)


def make_packer(backend: str = "auto", interpret: bool | None = None,
                tile_bits: int | None = None):
    """Packing callable for the entropy encoders' ``packer`` argument.

    Returns ``None`` when the resolved backend is "numpy" — callers
    then keep their zero-indirection default
    (:func:`repro.core.entropy.bitio.pack_bits`) — and a routed
    device-packing callable for "pallas".
    """
    if select_backend(backend) == "numpy":
        return None
    return functools.partial(pack_bits, backend="pallas",
                             tile_bits=tile_bits, interpret=interpret)


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _pack_bits_device(codes, lengths, interpret: bool | None,
                      tile_bits: int | None = None) -> bytes:
    """Host orchestration of the device scatter-pack.

    Stages 1–2 (filter + prefix-sum offsets, plus the per-tile
    ``searchsorted`` window starts) are O(M) NumPy; stage 3 runs on the
    device.  Field count and tile count are bucketed to powers of two
    so a streaming workload sees a bounded set of compiled shapes.
    """
    from repro.kernels import common
    if interpret is None:
        interpret = common.interpret_default()
    c, ln, s, total = ref.field_layout(codes, lengths)
    if total == 0:
        return b""
    m = int(c.size)
    if m > MAX_DEVICE_FIELDS:
        return ref.scatter_pack_ref(c, ln, s, total).tobytes()
    if tile_bits is None:
        tile_bits = tuning.tile_for("pack_bits", total)
    window = tile_bits + WINDOW_MARGIN
    n_tiles = _pow2(-(-total // tile_bits))
    m_pad = _pow2(m + window)
    first = np.searchsorted(s + ln, np.arange(n_tiles, dtype=np.int64)
                            * tile_bits, side="right")
    first = np.minimum(first, m_pad - window).astype(np.int32)

    def col(arr):
        out = np.zeros((m_pad, 1), np.int32)
        out[:m, 0] = arr
        return out

    out = kernel.pack_bits_pallas(col(c), col(ln), col(s), first,
                                  tile_bits=tile_bits, window=window,
                                  interpret=interpret)
    nbytes = (total + 7) // 8
    by = np.asarray(out).astype(np.uint8).reshape(-1)[:nbytes].copy()
    pad = (-total) % 8
    if pad:                         # writer convention: 1-padded tail
        by[-1] |= (1 << pad) - 1
    return by.tobytes()
