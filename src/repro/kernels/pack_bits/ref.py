"""Staged NumPy reference for the pack_bits kernel (bit-exact oracle).

The same three stages the Pallas kernel runs, as whole-array NumPy:

1. **filter** — drop zero-width fields (absent amplitude slots),
2. **prefix-sum** — exclusive cumulative sum of the field widths gives
   every field's start bit offset (Cloud et al., arXiv:1107.1525: the
   offsets are the only serial dependency in Huffman packing, and a
   scan removes it),
3. **scatter** — each field's bits land at ``start + 0..len-1``,
   MSB-first, then 8 bits fold into each output byte.

:func:`pack_bits_ref` is byte-identical to
:func:`repro.core.entropy.bitio.pack_bits` (the retained host-edge
reference) on every input — the property tests and the
``--check-identical`` CI gate hold all three packers (bitio, this
staged reference, the Pallas kernel) to one output.
"""

from __future__ import annotations

import numpy as np

MAX_FIELD_BITS = 16


def field_layout(codes: np.ndarray, lengths: np.ndarray) -> tuple:
    """Stages 1–2: filter zero-width fields, prefix-sum the offsets.

    Args:
        codes: (M,) non-negative ints; field k contributes its low
            ``lengths[k]`` bits, most significant first.
        lengths: (M,) field widths in [0, 16]; zero-width fields are
            dropped.

    Returns:
        ``(codes, lengths, starts, total_bits)`` — the kept fields plus
        each field's start bit offset (exclusive prefix sum of the kept
        widths) and the total payload bit count.

    Raises:
        ValueError: a field wider than 16 bits.
    """
    codes = np.asarray(codes, dtype=np.int64)
    lengths = np.asarray(lengths, dtype=np.int64)
    if lengths.size and int(lengths.max()) > MAX_FIELD_BITS:
        raise ValueError(f"bit field wider than {MAX_FIELD_BITS} bits")
    keep = lengths > 0
    codes, lengths = codes[keep], lengths[keep]
    # only the low `lengths` bits of a field are payload; stray high
    # bits must not reach the kernel, whose byte-aligned shift would
    # smear them into the preceding field's bytes
    codes = codes & ((np.int64(1) << lengths) - 1)
    ends = np.cumsum(lengths)
    total = int(ends[-1]) if lengths.size else 0
    return codes, lengths, ends - lengths, total


def scatter_pack_ref(codes: np.ndarray, lengths: np.ndarray,
                     starts: np.ndarray, total: int) -> np.ndarray:
    """Stage 3: scatter every field's bits to its offset, fold to bytes.

    Args:
        codes, lengths, starts: kept fields from :func:`field_layout`
            (``starts`` need not be contiguous — the kernel relies only
            on fields never overlapping in bit space).
        total: payload length in bits; bits past it (the final partial
            byte) are written as 1s, matching the writer's padding.

    Returns:
        (ceil(total/8),) uint8 byte array.
    """
    nbits = total + (-total) % 8
    bits = np.zeros(nbits, dtype=np.uint8)
    bits[total:] = 1
    csum = np.cumsum(lengths) - lengths
    j = np.arange(int(lengths.sum()), dtype=np.int64) - np.repeat(csum,
                                                                  lengths)
    vals = (np.repeat(codes, lengths)
            >> (np.repeat(lengths, lengths) - 1 - j)) & 1
    bits[np.repeat(starts, lengths) + j] = vals
    return np.packbits(bits)


def pack_bits_ref(codes: np.ndarray, lengths: np.ndarray) -> bytes:
    """The full staged pipeline; byte-identical to ``bitio.pack_bits``."""
    codes, lengths, starts, total = field_layout(codes, lengths)
    if total == 0:
        return b""
    return scatter_pack_ref(codes, lengths, starts, total).tobytes()
