"""Pallas TPU kernel: RLE-symbolise zig-zagged blocks on device.

Device-resident realisation of the entropy encoder's first host stage
(:func:`repro.core.entropy.rle.symbolize`): the grid tiles the block
axis, and each program turns its ``tile_blocks`` zig-zag rows into the
dense per-block symbol layout of :mod:`repro.kernels.symbolize.ref` —
(run, size) symbols, amplitude fields and per-block symbol counts —
plus the two 256-bin alphabet histograms the host needs for Huffman
table negotiation.  Everything per-row is fixed-shape arithmetic:

* **categories** — magnitude category (bit length) as a sum of 15
  threshold compares (the ops layer guarantees ``|level| < 2**15``, so
  no ``frexp`` is needed on device);
* **runs** — the previous-nonzero position is an exclusive running
  maximum; both it and the unit-count prefix sum are computed with
  log-step shift doubling over the 63 AC lanes (6 static steps);
* **slot scatter** — each ZRL/coded symbol lands in its dense slot via
  a one-hot compare-sum against the 64 slot indices (the same
  no-data-dependent-writes idiom as ``pack_bits``); untouched slots
  keep the zero init, which *is* the EOB encoding;
* **histograms** — per-alphabet one-hot compare-sums, accumulated
  across grid steps by revisiting a single (1, 256) output block
  (sequential TPU grid; ``@pl.when(i == 0)`` zeroes it first).

Row validity (the block count is rarely a tile multiple) comes in via
scalar prefetch; padded rows contribute nothing to histograms and get
``total == 0``.  Element-exact against ``ref.symbolize_dense`` by the
tile-invariance and ``--check-identical`` gates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

AC_LEN = 63
SLOTS = 64
MAX_ZRL = (AC_LEN - 1) // 16       # a run can skip at most 62 zeros
EOB = 0x00
ZRL = 0xF0
MAX_CATEGORY = 15


def _shift_right(x: jnp.ndarray, s: int, fill: int) -> jnp.ndarray:
    """Shift columns right by ``s``, filling vacated lanes with ``fill``."""
    t, _ = x.shape
    pad = jnp.full((t, s), fill, x.dtype)
    return jnp.concatenate([pad, x[:, :-s]], axis=1)


def _category(mag: jnp.ndarray) -> jnp.ndarray:
    """Bit length of a magnitude < 2**15 as 15 threshold compares."""
    cat = jnp.zeros_like(mag)
    for b in range(MAX_CATEGORY):
        cat += (mag >= (1 << b)).astype(mag.dtype)
    return cat


def _make_kernel(tile_blocks: int):
    t = tile_blocks

    def kernel(nrows_ref, dc_ref, ac_ref, syms_ref, amps_ref, lens_ref,
               total_ref, dc_hist_ref, ac_hist_ref):
        i = pl.program_id(0)
        row = (i * t + jax.lax.broadcasted_iota(jnp.int32, (t, 1), 0))
        valid_row = row < nrows_ref[0]
        dcd = dc_ref[...]                                  # (t, 1) int32
        acb = ac_ref[...]                                  # (t, 63) int32

        dc_cat = _category(jnp.abs(dcd))
        dc_amp = jnp.where(dcd >= 0, dcd, dcd + (1 << dc_cat) - 1)

        nz = (acb != 0) & valid_row
        cat = _category(jnp.abs(acb))
        amp = jnp.where(acb >= 0, acb, acb + (1 << cat) - 1)

        # exclusive running max of nonzero positions = previous nonzero
        col = jax.lax.broadcasted_iota(jnp.int32, (t, AC_LEN), 1)
        run_max = jnp.where(nz, col, -1)
        for s in (1, 2, 4, 8, 16, 32):
            run_max = jnp.maximum(run_max, _shift_right(run_max, s, -1))
        prev = _shift_right(run_max, 1, -1)
        run = col - prev - 1
        zrl = run >> 4
        unit = jnp.where(nz, zrl + 1, 0)
        cu = unit
        for s in (1, 2, 4, 8, 16, 32):
            cu = cu + _shift_right(cu, s, 0)
        start = 1 + cu - unit
        coded_slot = start + zrl

        eob = ((run_max[:, -1:] != AC_LEN - 1) & valid_row)
        total = jnp.where(valid_row,
                          1 + cu[:, -1:] + eob.astype(jnp.int32), 0)

        # dense slot scatter: one-hot compare against the 64 slot
        # indices; inactive lanes target slot 64, which matches nothing.
        # Slots are unique per block, so each (row, slot) cell receives
        # at most one contribution and int32 sums are exact.
        slots3 = jax.lax.broadcasted_iota(jnp.int32, (t, AC_LEN, SLOTS), 2)
        coef_sym = ((run & 15) << 4) | cat

        def scatter(tgt, val):
            hit = (jnp.where(nz, tgt, SLOTS)[:, :, None] == slots3)
            return jnp.where(hit, val[:, :, None], 0).sum(axis=1)

        syms = scatter(coded_slot, coef_sym)
        amps = scatter(coded_slot, amp)
        lens = scatter(coded_slot, cat)
        for k in range(MAX_ZRL):
            live = nz & (zrl > k)
            hit = (jnp.where(live, start + k, SLOTS)[:, :, None] == slots3)
            syms += jnp.where(hit, ZRL, 0).sum(axis=1)

        slot2 = jax.lax.broadcasted_iota(jnp.int32, (t, SLOTS), 1)
        syms_ref[...] = syms + jnp.where(slot2 == 0, dc_cat, 0)
        amps_ref[...] = amps + jnp.where(slot2 == 0, dc_amp, 0)
        lens_ref[...] = lens + jnp.where(slot2 == 0, dc_cat, 0)
        total_ref[...] = total

        # per-alphabet histograms, accumulated across sequential grid
        # steps into one revisited (1, 256) block
        bins = jax.lax.broadcasted_iota(jnp.int32, (1, 256), 1)
        dc_sym_h = jnp.where(valid_row, dc_cat, -1)        # (t, 1)
        dc_step = (dc_sym_h == bins).astype(jnp.int32).sum(
            axis=0, keepdims=True)                         # (1, 256)
        ac_sym_h = jnp.where(nz, coef_sym, -1).reshape(-1, 1)
        ac_step = (ac_sym_h == bins).astype(jnp.int32).sum(
            axis=0, keepdims=True)
        zrl_sum = jnp.where(nz, zrl, 0).sum()
        eob_sum = eob.astype(jnp.int32).sum()
        ac_step = (ac_step
                   + jnp.where(bins == ZRL, zrl_sum, 0)
                   + jnp.where(bins == EOB, eob_sum, 0))

        @pl.when(i == 0)
        def _init():
            dc_hist_ref[...] = jnp.zeros_like(dc_hist_ref)
            ac_hist_ref[...] = jnp.zeros_like(ac_hist_ref)

        dc_hist_ref[...] += dc_step
        ac_hist_ref[...] += ac_step

    return kernel


@functools.partial(jax.jit, static_argnames=("tile_blocks", "interpret"))
def symbolize_pallas(dc_diff: jnp.ndarray, ac: jnp.ndarray,
                     nrows: jnp.ndarray, *, tile_blocks: int = 64,
                     interpret: bool = True) -> tuple:
    """Symbolise padded zig-zag blocks into dense slots + histograms.

    Args:
        dc_diff: (n_pad, 1) int32 DC differences; ``n_pad`` a multiple
            of ``tile_blocks``; ``|values| < 2**15`` (ops-layer guard).
        ac: (n_pad, 63) int32 AC tails in zig-zag order, same bound.
        nrows: (1,) int32 scalar-prefetch — the real block count; rows
            at and past it are padding (zero histogram weight,
            ``total == 0``).
        tile_blocks: blocks per grid program.
        interpret: run in Pallas interpret mode (non-TPU backends).

    Returns:
        ``(syms, amp_vals, amp_lens, total, dc_hist, ac_hist)`` —
        (n_pad, 64) int32 dense slot arrays, (n_pad, 1) int32 per-block
        symbol counts, and two (1, 256) int32 alphabet histograms.
    """
    n_pad = dc_diff.shape[0]
    if n_pad % tile_blocks:
        raise ValueError(f"{n_pad} rows not a multiple of tile_blocks="
                         f"{tile_blocks}")
    n_tiles = n_pad // tile_blocks
    t = tile_blocks
    tile = lambda i, nrows_ref: (i, 0)
    fixed = lambda i, nrows_ref: (0, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((t, 1), tile),
            pl.BlockSpec((t, AC_LEN), tile),
        ],
        out_specs=[
            pl.BlockSpec((t, SLOTS), tile),
            pl.BlockSpec((t, SLOTS), tile),
            pl.BlockSpec((t, SLOTS), tile),
            pl.BlockSpec((t, 1), tile),
            pl.BlockSpec((1, 256), fixed),
            pl.BlockSpec((1, 256), fixed),
        ],
    )
    out_shape = [
        jax.ShapeDtypeStruct((n_pad, SLOTS), jnp.int32),
        jax.ShapeDtypeStruct((n_pad, SLOTS), jnp.int32),
        jax.ShapeDtypeStruct((n_pad, SLOTS), jnp.int32),
        jax.ShapeDtypeStruct((n_pad, 1), jnp.int32),
        jax.ShapeDtypeStruct((1, 256), jnp.int32),
        jax.ShapeDtypeStruct((1, 256), jnp.int32),
    ]
    return pl.pallas_call(
        _make_kernel(tile_blocks),
        out_shape=out_shape,
        grid_spec=grid_spec,
        interpret=interpret,
    )(nrows, dc_diff, ac)
