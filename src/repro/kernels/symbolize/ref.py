"""Staged NumPy reference for the symbolize kernel (element-exact oracle).

The same stages the Pallas kernel runs, as whole-array NumPy over a
**dense per-block layout**: every 8x8 block owns 64 symbol slots (slot 0
is its DC symbol; a block never emits more than 64 symbols — 1 DC + at
most 63 coefficient units + EOB, and the three possible ZRL expansions
only occur when coefficient units are scarce), so symbolisation becomes
pure fixed-shape array arithmetic with no data-dependent output size:

1. **runs** — each nonzero AC coefficient's zero run is its zig-zag
   position minus the previous nonzero position (an exclusive running
   maximum), giving its ZRL expansion count and (run, size) symbol;
2. **slots** — an exclusive prefix sum of per-coefficient unit counts
   places every ZRL and coded symbol at a dense slot; EOB slots stay at
   the zero-initialised ``(EOB, no amplitude)``;
3. **histograms** — the per-alphabet 256-bin histograms fall out of the
   same pass (DC categories + coded symbols + ZRL/EOB counts), without
   materialising the compacted stream;
4. **compaction** — a validity mask (slot index < per-block total)
   flattens the dense arrays into the coding-order stream, element-
   identical to :func:`repro.core.entropy.rle.symbolize_reference`.

The layout is the load-bearing part: because every block owns a fixed
64-slot budget, the Pallas kernel can run the identical stages as pure
fixed-shape lane arithmetic on device, and the host reference shares
one algorithm (and one oracle) with it.  On the host the per-element
work runs over the gathered nonzeros — quantised AC tails are sparse,
so one O(nnz) pass replaces the PR 4 vectorized path's separate
symbolize + histogram + gather stages and is what the stage-breakdown
bench scores (docs/benchmarks.md).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.entropy import bitio, huffman, rle

AC_LEN = rle.AC_LEN            # 63 zig-zag AC positions
SLOTS = 64                     # dense symbol slots per block (see above)
# a coefficient at zig-zag position p <= 62 can skip at most 62 zeros,
# so it emits at most floor(62/16) = 3 ZRL expansions
MAX_ZRL = (AC_LEN - 1) // 16


@dataclasses.dataclass(frozen=True)
class DenseSymbols:
    """One fused symbolisation pass over a batch of blocks.

    ``syms``/``amp_vals``/``amp_lens`` are (n, 64) dense per-block
    slot arrays (slot 0 = DC; slots past ``total[b]`` are meaningless);
    ``total`` is the per-block symbol count; ``dc_freq``/``ac_freq``
    are the 256-bin alphabet histograms
    (:func:`repro.core.entropy.rle.symbol_frequencies` of the stream).

    The slot arrays are **int16**: symbols and amplitude widths are
    bytes, amplitude values fit 15 bits (the oracle's RangeError
    guard), and the dense layout's cost is dominated by touching
    3 x (n, 64) fresh pages per call — narrow lanes keep the fused
    pass ahead of the vectorized path it replaces.
    :func:`dense_to_stream` widens gathers back to the int64 stream
    contract.
    """
    syms: np.ndarray           # (n, 64) int16
    amp_vals: np.ndarray       # (n, 64) int16
    amp_lens: np.ndarray       # (n, 64) int16
    total: np.ndarray          # (n,) int64, in [1, 64]
    dc_freq: np.ndarray        # (256,) int64
    ac_freq: np.ndarray        # (256,) int64


def symbolize_dense(dc_diff: np.ndarray, ac: np.ndarray) -> DenseSymbols:
    """Blocks -> dense per-block symbol slots + histograms, one pass.

    Args:
        dc_diff: (n,) int DC differences in block order.
        ac: (n, 63) int AC tails in zig-zag order.

    Raises:
        rle.RangeError: some level needs an amplitude wider than 15
            bits (same message as the scalar oracle, DC checked first).
    """
    dc_diff = np.asarray(dc_diff, dtype=np.int64)
    ac = np.asarray(ac, dtype=np.int64)
    n = dc_diff.shape[0]
    if ac.shape != (n, AC_LEN):
        raise ValueError(f"ac shape {ac.shape} does not match "
                         f"({n}, {AC_LEN})")
    dc_cat = rle.magnitude_category(dc_diff)
    rle._check_range(dc_cat, "DC difference")
    dc_amp = rle.amplitude_value(dc_diff, dc_cat)
    # per-element work happens on the gathered nonzeros (O(nnz), the
    # host-side analogue of the kernel's all-lanes arithmetic; quantised
    # AC tails are sparse, so this is what makes the fused pass beat
    # the vectorized path); np.nonzero is row-major, which IS coding
    # order within each block
    flat = np.flatnonzero(ac.reshape(-1) != 0)
    rows, cols = divmod(flat, AC_LEN)
    vals = ac.reshape(-1)[flat]
    cat = rle.magnitude_category(vals)
    rle._check_range(cat, "AC coefficient")
    amp = rle.amplitude_value(vals, cat)

    # previous nonzero position within the row: the predecessor element,
    # or -1 at each row's first nonzero
    first = np.empty(rows.shape, bool)
    first[:1] = True
    first[1:] = rows[1:] != rows[:-1]
    prev = np.empty_like(cols)
    prev[1:] = cols[:-1]
    prev[first] = -1
    run = cols - prev - 1
    zrl = run >> 4                       # ZRL expansions before the symbol
    unit = zrl + 1                       # symbols this coefficient emits
    # within-row exclusive prefix sum of units = global running sum
    # minus the base at the row's first nonzero
    excl = np.cumsum(unit) - unit
    seg = np.cumsum(first) - 1           # nonzero -> its row-segment id
    start = 1 + excl - excl[first][seg]  # slot of the unit's first symbol
    base = rows * SLOTS                  # flat scatter addresses, once
    idx = base + start + zrl             # each coefficient's coded slot

    unit_b = np.zeros(n, np.int64)
    np.add.at(unit_b, rows, unit)
    last = np.full(n, -1, np.int64)
    last[rows] = cols                    # row-major: the max col wins
    eob = last != AC_LEN - 1
    total = 1 + unit_b + eob

    # dense scatter; EOB slots keep the zero init.  One int16 buffer:
    # the pass's cost is dominated by faulting the fresh dense pages,
    # so three narrow planes behind one allocation beat three int64
    # arrays ~4x on memory touched
    buf = np.zeros((3, n, SLOTS), np.int16)
    syms_d, amps_d, lens_d = buf
    flat_syms = syms_d.reshape(-1)
    syms_d[:, 0] = dc_cat
    amps_d[:, 0] = dc_amp
    lens_d[:, 0] = dc_cat
    coef_sym = ((run & 15) << 4) | cat
    flat_syms[idx] = coef_sym
    amps_d.reshape(-1)[idx] = amp
    lens_d.reshape(-1)[idx] = cat
    zidx = base + start
    for t in range(MAX_ZRL):
        live = zrl > t
        flat_syms[zidx[live] + t] = rle.ZRL

    dc_freq = np.bincount(dc_cat, minlength=256)
    # coded symbols never collide with ZRL (their size nibble is >= 1)
    # or EOB (nonzero), so the three contributions just add
    ac_freq = np.bincount(coef_sym, minlength=256)
    ac_freq[rle.ZRL] += int(zrl.sum())
    ac_freq[rle.EOB] += int(eob.sum())
    return DenseSymbols(syms=syms_d, amp_vals=amps_d, amp_lens=lens_d,
                        total=total, dc_freq=dc_freq, ac_freq=ac_freq)


def dense_to_stream(dense: DenseSymbols) -> tuple:
    """Compact dense slots into the coding-order symbol stream.

    Returns ``(is_dc, syms, amp_vals, amp_lens)`` with the exact
    contract (dtypes included) of
    :func:`repro.core.entropy.rle.symbolize`.
    """
    slot = np.arange(SLOTS)
    valid = slot < dense.total[:, None]
    is_dc = np.broadcast_to(slot == 0, valid.shape)[valid]
    return (is_dc,
            dense.syms[valid].astype(np.int64),
            dense.amp_vals[valid].astype(np.int64),
            dense.amp_lens[valid].astype(np.int64))


def symbolize_ref(dc_diff: np.ndarray, ac: np.ndarray) -> tuple:
    """The staged pipeline end-to-end; element-identical to
    :func:`repro.core.entropy.rle.symbolize_reference`."""
    return dense_to_stream(symbolize_dense(dc_diff, ac))


def encode_fields_dense(dense: DenseSymbols,
                        dc_table: huffman.CanonicalTable,
                        ac_table: huffman.CanonicalTable) -> tuple:
    """Codeword lookup on the dense layout: -> (fields, widths).

    Valid slots are addressed by flat index (per-block prefix sums of
    ``total``), so the lookup touches O(stream) elements; each
    contributes its Huffman code then its amplitude field, and the
    row-major interleave *is* the stream order.  Byte output equals
    :func:`repro.core.entropy.rle.codeword_fields` + the same packer
    (zero-width amplitude fields are dropped by every packer).

    Raises:
        ValueError: a valid slot holds a symbol its table cannot code
            (same message as ``codeword_fields``).
    """
    dc_code, dc_len = huffman.encoder_luts(dc_table)
    ac_code, ac_len = huffman.encoder_luts(ac_table)
    n = dense.syms.shape[0]
    # flat indices of the valid slots, in coding order: slot arithmetic
    # on O(stream) elements, not O(n * 64) lanes
    k = int(dense.total.sum())
    row = np.repeat(np.arange(n, dtype=np.int64), dense.total)
    cum = np.cumsum(dense.total)
    slot = np.arange(k, dtype=np.int64) - np.repeat(cum - dense.total,
                                                    dense.total)
    syms = dense.syms.reshape(-1)[row * SLOTS + slot]
    is_dc = slot == 0
    codes = np.where(is_dc, dc_code[syms], ac_code[syms])
    lens = np.where(is_dc, dc_len[syms], ac_len[syms])
    if bool((lens == 0).any()):
        raise ValueError("symbol stream contains a symbol absent from "
                         "the Huffman table")
    fields = np.empty((k, 2), np.int64)
    widths = np.empty((k, 2), np.int64)
    fields[:, 0] = codes
    fields[:, 1] = dense.amp_vals.reshape(-1)[row * SLOTS + slot]
    widths[:, 0] = lens
    widths[:, 1] = dense.amp_lens.reshape(-1)[row * SLOTS + slot]
    return fields.reshape(-1), widths.reshape(-1)


def encode_payload_dense(dense: DenseSymbols,
                         dc_table: huffman.CanonicalTable,
                         ac_table: huffman.CanonicalTable,
                         packer=None) -> bytes:
    """Dense codeword lookup + bit packing; byte-identical to
    :func:`repro.core.entropy.rle.encode_payload` on the same stream."""
    fields, widths = encode_fields_dense(dense, dc_table, ac_table)
    return (packer or bitio.pack_bits)(fields, widths)
