"""Routed public wrappers for the symbolize kernel.

``symbolize`` is a drop-in for :func:`repro.core.entropy.rle.symbolize`
with the stage routed per backend — the Pallas kernel on TPU, the
staged dense NumPy reference everywhere else — element-identical either
way (CI-gated by ``bench_entropy_throughput --check-identical``).

:func:`make_symbolizer` builds the object the container encoders thread
through (``symbolizer=``): a two-phase *prepared stream* exposing the
device-computed alphabet histograms first (all the host needs for
Huffman table negotiation) and producing the payload bytes once tables
are chosen.  On the Pallas backend that second phase chains entirely on
device — dense codeword gather, stable zero-width compaction,
prefix-sum offsets, then the ``pack_bits`` scatter-pack kernel — so the
host transfers two 1 KiB histograms, one scalar bit count and the
finished payload instead of the full coefficient tensor.  On the NumPy
backend it is the fused dense pass of :mod:`.ref` (one symbolize +
histogram sweep, codeword lookup on the dense slots, one packer call).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.entropy import huffman, rle
from repro.kernels import tuning
from repro.kernels.pack_bits import kernel as pack_kernel
from repro.kernels.pack_bits import ops as pack_ops
from repro.kernels.symbolize import kernel, ref

TILE_BLOCKS = 64                    # default blocks per kernel program

# Above this many blocks the stream falls back to the staged NumPy
# reference: the chained payload stage holds the three flattened
# (2 * 64 * n_pad,) field arrays unblocked in VMEM like pack_bits does,
# so the same MAX_DEVICE_FIELDS budget divided by the 128 fields a
# block can emit caps the device-resident block count.
MAX_DEVICE_BLOCKS = pack_ops.MAX_DEVICE_FIELDS // (2 * ref.SLOTS)

# The kernel computes magnitude categories as 15 threshold compares in
# int32, so levels must already fit 15-bit amplitudes; anything larger
# is routed to the reference, which raises the oracle's RangeError.
_MAX_DEVICE_LEVEL = 1 << rle.MAX_CATEGORY

BACKENDS = ("pallas", "numpy")


def select_backend(backend: str = "auto") -> str:
    """Resolve the symbolize backend ("pallas" on TPU, else "numpy")."""
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "numpy"
    if backend not in BACKENDS:
        raise ValueError(f"unknown symbolize backend {backend!r}; "
                         f"expected one of {('auto',) + BACKENDS}")
    return backend


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _device_ok(dc_diff: np.ndarray, ac: np.ndarray) -> bool:
    """True when the kernel's int32/15-bit preconditions hold."""
    n = dc_diff.shape[0]
    if n == 0 or n > MAX_DEVICE_BLOCKS:
        return False
    if n and int(np.abs(dc_diff).max()) >= _MAX_DEVICE_LEVEL:
        return False
    if ac.size and int(np.abs(ac).max()) >= _MAX_DEVICE_LEVEL:
        return False
    return True


def _run_kernel(dc_diff: np.ndarray, ac: np.ndarray, tile_blocks: int,
                interpret: bool) -> tuple:
    """Pad, launch, and return the kernel's device outputs + n_pad."""
    n = dc_diff.shape[0]
    n_pad = max(_pow2(n), tile_blocks)
    dc = np.zeros((n_pad, 1), np.int32)
    dc[:n, 0] = dc_diff
    acp = np.zeros((n_pad, ref.AC_LEN), np.int32)
    acp[:n] = ac
    nrows = np.array([n], np.int32)
    return kernel.symbolize_pallas(jnp.asarray(dc), jnp.asarray(acp),
                                   jnp.asarray(nrows),
                                   tile_blocks=tile_blocks,
                                   interpret=interpret)


def symbolize_dense(dc_diff, ac, *, backend: str = "auto",
                    tile_blocks: int | None = None,
                    interpret: bool | None = None) -> ref.DenseSymbols:
    """Routed fused pass: dense slots + histograms on the host.

    Args:
        dc_diff: (n,) int DC differences in block order.
        ac: (n, 63) int AC tails in zig-zag order.
        backend: "auto" (Pallas on TPU, NumPy elsewhere), "pallas", or
            "numpy".
        tile_blocks: blocks per kernel program (pow2); ``None`` routes
            through the tuned-tile artifact
            (:func:`repro.kernels.tuning.tile_for`).  Ignored by
            "numpy".
        interpret: Pallas interpret-mode override; ignored by "numpy".

    Returns:
        A :class:`repro.kernels.symbolize.ref.DenseSymbols`, identical
        across backends and every ``tile_blocks``.

    Raises:
        rle.RangeError: some level needs an amplitude wider than 15
            bits (the oracle's exact message, whichever backend runs).
    """
    dc_diff = np.asarray(dc_diff, dtype=np.int64)
    ac = np.asarray(ac, dtype=np.int64)
    if select_backend(backend) == "numpy" or not _device_ok(dc_diff, ac):
        return ref.symbolize_dense(dc_diff, ac)
    from repro.kernels import common
    if interpret is None:
        interpret = common.interpret_default()
    if tile_blocks is None:
        tile_blocks = tuning.tile_for("symbolize", dc_diff.shape[0])
    n = dc_diff.shape[0]
    syms, amps, lens, total, dc_h, ac_h = jax.device_get(
        _run_kernel(dc_diff, ac, tile_blocks, interpret))
    return ref.DenseSymbols(
        syms=np.asarray(syms[:n], np.int16),
        amp_vals=np.asarray(amps[:n], np.int16),
        amp_lens=np.asarray(lens[:n], np.int16),
        total=np.asarray(total[:n, 0], np.int64),
        dc_freq=np.asarray(dc_h[0], np.int64),
        ac_freq=np.asarray(ac_h[0], np.int64))


def symbolize(dc_diff, ac, *, backend: str = "auto",
              tile_blocks: int | None = None,
              interpret: bool | None = None) -> tuple:
    """Routed drop-in for :func:`repro.core.entropy.rle.symbolize`.

    Same contract and return dtypes (``(is_dc, syms, amp_vals,
    amp_lens)``), element-identical to the scalar oracle across every
    backend and tile (CI-gated).
    """
    return ref.dense_to_stream(symbolize_dense(
        dc_diff, ac, backend=backend, tile_blocks=tile_blocks,
        interpret=interpret))


# ---------------------------------------------------------------------------
# Prepared streams: the container's symbolizer= protocol
# ---------------------------------------------------------------------------

class _NumpyPrepared:
    """Fused host preparation: dense pass now, one packer call later."""

    def __init__(self, dense: ref.DenseSymbols, packer):
        self._dense = dense
        self._packer = packer
        self.dc_freq = dense.dc_freq
        self.ac_freq = dense.ac_freq

    def payload(self, dc_table: huffman.CanonicalTable,
                ac_table: huffman.CanonicalTable) -> bytes:
        return ref.encode_payload_dense(self._dense, dc_table, ac_table,
                                        packer=self._packer)


@jax.jit
def _fields_device(syms, amps, lens, total, dc_code, dc_len,
                   ac_code, ac_len):
    """Dense codeword gather + stable zero-width compaction, on device.

    Returns the flattened field/width/start arrays ready for the
    scatter-pack kernel (kept fields first, in stream order; zero-width
    tail at offset ``total_bits``), plus the payload bit count and an
    uncodeable-symbol flag.
    """
    slot = jnp.arange(ref.SLOTS, dtype=jnp.int32)[None, :]
    valid = slot < total                                    # (n_pad, 64)
    isdc = slot == 0
    codes = jnp.where(isdc, dc_code[syms], ac_code[syms])
    clens = jnp.where(isdc, dc_len[syms], ac_len[syms])
    bad = jnp.any((clens == 0) & valid)
    f = jnp.stack([codes, amps], axis=-1).reshape(-1)
    w = jnp.stack([jnp.where(valid, clens, 0),
                   jnp.where(valid, lens, 0)], axis=-1).reshape(-1)
    f = f & ((1 << w) - 1)          # only the low `w` bits are payload
    # stable partition without sorting: kept fields keep stream order,
    # zero-width fields move to the tail
    kept = w > 0
    m = f.shape[0]
    n_kept = jnp.cumsum(kept.astype(jnp.int32))
    dest = jnp.where(kept, n_kept - 1,
                     n_kept[-1] + jnp.cumsum((~kept).astype(jnp.int32)) - 1)
    f2 = jnp.zeros((m,), f.dtype).at[dest].set(f)
    w2 = jnp.zeros((m,), w.dtype).at[dest].set(w)
    ends = jnp.cumsum(w2)
    return f2, w2, ends - w2, ends[-1], bad


@jax.jit
def _first_device(ends, n_tiles_arr, tile_bits, window):
    first = jnp.searchsorted(ends, n_tiles_arr * tile_bits, side="right")
    return jnp.minimum(first, ends.shape[0] - window).astype(jnp.int32)


class _PallasPrepared:
    """Device-resident preparation: histograms now, device pack later.

    Construction runs the symbolize kernel and pulls only the two
    (1, 256) histograms; :meth:`payload` chains codeword gather →
    prefix-sum offsets → scatter-pack on device and pulls the finished
    bytes (plus one scalar bit count to size the tile grid).
    """

    def __init__(self, dc_diff, ac, tile_blocks, interpret):
        self._interpret = interpret
        n = dc_diff.shape[0]
        self._n = n
        (self._syms, self._amps, self._lens, self._total,
         dc_h, ac_h) = _run_kernel(dc_diff, ac, tile_blocks, interpret)
        dc_h, ac_h = jax.device_get((dc_h, ac_h))
        self.dc_freq = np.asarray(dc_h[0], np.int64)
        self.ac_freq = np.asarray(ac_h[0], np.int64)

    def payload(self, dc_table: huffman.CanonicalTable,
                ac_table: huffman.CanonicalTable) -> bytes:
        lut = lambda a: jnp.asarray(np.asarray(a, np.int32))
        dc_code, dc_len = huffman.encoder_luts(dc_table)
        ac_code, ac_len = huffman.encoder_luts(ac_table)
        f, w, s, total_bits, bad = _fields_device(
            self._syms, self._amps, self._lens, self._total,
            lut(dc_code), lut(dc_len), lut(ac_code), lut(ac_len))
        bad, total = jax.device_get((bad, total_bits))
        if bool(bad):
            raise ValueError("symbol stream contains a symbol absent "
                             "from the Huffman table")
        total = int(total)
        if total == 0:
            return b""
        tile_bits = tuning.tile_for("pack_bits", total)
        window = tile_bits + pack_ops.WINDOW_MARGIN
        n_tiles = _pow2(-(-total // tile_bits))
        m = int(f.shape[0])
        m_pad = _pow2(m + window)
        if m_pad > m:
            pad = m_pad - m
            f = jnp.concatenate([f, jnp.zeros((pad,), f.dtype)])
            w = jnp.concatenate([w, jnp.zeros((pad,), w.dtype)])
            # padding starts sit at the payload end (zero width), so
            # the `ends` array stays sorted for searchsorted
            s = jnp.concatenate([s, jnp.broadcast_to(total_bits, (pad,))])
        first = _first_device(s + w, jnp.arange(n_tiles, dtype=jnp.int32),
                              tile_bits, window)
        col = lambda a: a.reshape(-1, 1).astype(jnp.int32)
        out = pack_kernel.pack_bits_pallas(
            col(f), col(w), col(s), first, tile_bits=tile_bits,
            window=window, interpret=self._interpret)
        nbytes = (total + 7) // 8
        by = np.asarray(jax.device_get(out)).astype(np.uint8)
        by = by.reshape(-1)[:nbytes].copy()
        pad_bits = (-total) % 8
        if pad_bits:                # writer convention: 1-padded tail
            by[-1] |= (1 << pad_bits) - 1
        return by.tobytes()


def make_symbolizer(backend: str = "auto", *,
                    tile_blocks: int | None = None,
                    interpret: bool | None = None):
    """Symbolizer callable for the container encoders' ``symbolizer=``.

    The returned callable maps ``(dc_diff, ac, packer=None)`` to a
    prepared stream with ``dc_freq`` / ``ac_freq`` histogram attributes
    and a ``payload(dc_table, ac_table) -> bytes`` method — the
    two-phase shape :func:`repro.core.entropy.container._frame_stream`
    needs for table negotiation.  Bytes are identical across backends
    and to the default (``symbolizer=None``) path (CI-gated).

    On "pallas", ``packer`` only applies to streams the device guards
    reject (size/range fallbacks run the staged NumPy pass): accepted
    streams pack through the chained device scatter-pack.
    """
    resolved = select_backend(backend)

    def prepare(dc_diff, ac, packer=None):
        dc_diff = np.asarray(dc_diff, dtype=np.int64)
        ac = np.asarray(ac, dtype=np.int64)
        if resolved == "pallas" and _device_ok(dc_diff, ac):
            from repro.kernels import common
            interp = (common.interpret_default()
                      if interpret is None else interpret)
            tiles = (tuning.tile_for("symbolize", dc_diff.shape[0])
                     if tile_blocks is None else tile_blocks)
            return _PallasPrepared(dc_diff, ac, tiles, interp)
        return _NumpyPrepared(ref.symbolize_dense(dc_diff, ac), packer)

    return prepare
