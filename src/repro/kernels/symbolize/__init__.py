"""Device-resident RLE symbolisation (kernel / staged ref / routed ops).

The third kernel triplet of the entropy stack (after ``pack_bits`` and
``unpack_bits``): turns zig-zagged quantised blocks into the JPEG
(run, size) symbol stream, amplitude fields, per-block counts and the
per-alphabet histograms Huffman table choice needs — on device via the
Pallas kernel (TPU), or as one fused dense NumPy pass elsewhere.
"""

from repro.kernels.symbolize.ops import (BACKENDS, MAX_DEVICE_BLOCKS,
                                         TILE_BLOCKS, make_symbolizer,
                                         select_backend, symbolize,
                                         symbolize_dense)

__all__ = ["BACKENDS", "MAX_DEVICE_BLOCKS", "TILE_BLOCKS",
           "make_symbolizer", "select_backend", "symbolize",
           "symbolize_dense"]
