"""Pallas TPU kernel: paper-faithful Cordic-based Loeffler blockwise 2-D DCT.

The kernel body runs the Loeffler flow graph (4 serial stages, parallel
inside each stage — exactly the structure the paper describes) with CORDIC
micro-rotations, vectorised across all blocks of the VMEM tile: the
"parallel inside a stage" dimension maps to VPU lanes, and every shift-add
micro-rotation is a fused multiply-add by a power-of-two constant.

This is the TPU-native rendering of the paper's CUDA kernel.  It is kept as
the paper-faithful *baseline*; the MXU Kronecker-matmul kernel (dct8x8 /
fused_codec) is the beyond-paper optimised path — see DESIGN.md §2 for why
the CORDIC trade inverts on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import cordic, loeffler


def _make_kernel(config: cordic.CordicConfig, inverse: bool):
    rot = cordic.make_cordic_rotate(config)
    qfn = cordic.fixed_quantizer(config)

    def kernel(x_ref, o_ref):
        x = x_ref[...]
        th, tw = x.shape
        blocks = x.reshape(th // 8, 8, tw // 8, 8)
        blocks = blocks.transpose(0, 2, 1, 3)  # (nbh, nbw, 8, 8)
        if inverse:
            out = loeffler.loeffler_idct2d_8x8(blocks, rotate_fn=rot,
                                               quantize_fn=qfn)
        else:
            out = loeffler.loeffler_dct2d_8x8(blocks, rotate_fn=rot,
                                              quantize_fn=qfn)
        o_ref[...] = out.transpose(0, 2, 1, 3).reshape(th, tw)

    return kernel


@functools.partial(jax.jit, static_argnames=("tile_h", "tile_w", "config",
                                             "inverse", "interpret"))
def cordic_loeffler_pallas(img: jnp.ndarray, *, tile_h: int, tile_w: int,
                           config: cordic.CordicConfig = cordic.PAPER_CONFIG,
                           inverse: bool = False,
                           interpret: bool = True) -> jnp.ndarray:
    """Blockwise Cordic-Loeffler 2-D (I)DCT, block-planar layout."""
    h, w = img.shape
    return pl.pallas_call(
        _make_kernel(config, inverse),
        out_shape=jax.ShapeDtypeStruct((h, w), img.dtype),
        grid=(h // tile_h, w // tile_w),
        in_specs=[pl.BlockSpec((tile_h, tile_w), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tile_h, tile_w), lambda i, j: (i, j)),
        interpret=interpret,
    )(img)
