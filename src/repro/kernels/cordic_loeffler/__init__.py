from repro.kernels.cordic_loeffler.ops import (  # noqa: F401
    cordic_loeffler_dct, cordic_loeffler_idct)
from repro.kernels.cordic_loeffler.ref import cordic_loeffler_ref  # noqa: F401
