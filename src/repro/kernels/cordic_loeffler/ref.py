"""Pure-jnp oracle for the cordic_loeffler kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import cordic, dct, loeffler


def cordic_loeffler_ref(img: jnp.ndarray,
                        config: cordic.CordicConfig = cordic.PAPER_CONFIG,
                        inverse: bool = False) -> jnp.ndarray:
    """(H, W) -> (H, W) Cordic-Loeffler blockwise (I)DCT, block-planar."""
    rot = cordic.make_cordic_rotate(config)
    qfn = cordic.fixed_quantizer(config)
    blocks = dct.to_blocks(img)
    if inverse:
        out = loeffler.loeffler_idct2d_8x8(blocks, rotate_fn=rot,
                                           quantize_fn=qfn)
    else:
        out = loeffler.loeffler_dct2d_8x8(blocks, rotate_fn=rot,
                                          quantize_fn=qfn)
    return dct.from_blocks(out)
