"""Jitted public wrappers for the cordic_loeffler Pallas kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import cordic
from repro.kernels import common, tuning
from repro.kernels.cordic_loeffler import kernel


def _run(img: jnp.ndarray, config: cordic.CordicConfig, inverse: bool,
         tile: int | None, interpret: bool | None) -> jnp.ndarray:
    if interpret is None:
        interpret = common.interpret_default()
    h, w = img.shape[-2:]
    padded = common.pad2d_to_multiple(img, 8, 8)
    ph, pw = padded.shape[-2:]
    if tile is None:
        tile = tuning.tile_for("cordic_loeffler", max(ph, pw))
    th = common.pick_tile(ph, tile)
    tw = common.pick_tile(pw, tile)

    fn = lambda x: kernel.cordic_loeffler_pallas(
        x, tile_h=th, tile_w=tw, config=config, inverse=inverse,
        interpret=interpret)
    for _ in range(img.ndim - 2):
        fn = jax.vmap(fn)
    out = fn(padded)
    return out[..., :h, :w] if (ph, pw) != (h, w) else out


def cordic_loeffler_dct(img: jnp.ndarray, *,
                        config: cordic.CordicConfig = cordic.PAPER_CONFIG,
                        tile: int | None = None,
                        interpret: bool | None = None) -> jnp.ndarray:
    """Paper-faithful Cordic-Loeffler blockwise DCT.  (..., H, W).

    ``tile=None`` routes through the tuned-tile artifact
    (:func:`repro.kernels.tuning.tile_for`); an explicit tile pins it.
    """
    return _run(img, config, inverse=False, tile=tile, interpret=interpret)


def cordic_loeffler_idct(coeffs: jnp.ndarray, *,
                         config: cordic.CordicConfig = cordic.PAPER_CONFIG,
                         tile: int | None = None,
                         interpret: bool | None = None) -> jnp.ndarray:
    """Paper-faithful Cordic-Loeffler blockwise inverse DCT."""
    return _run(coeffs, config, inverse=True, tile=tile, interpret=interpret)
