"""Per-bucket request queues + deadline-aware adaptive batching (jax-free).

The synchronous core of the async codec service
(:mod:`repro.serve.service`): requests land in one FIFO queue per
*(shape bucket, quality)* — the unit the codec engine compiles and
batches over — and :class:`BatchPlanner` decides, from wall-clock
observations only, when each queue dispatches:

* **full** — the queue holds ``max_batch`` requests (one engine batch),
* **urgent** — the oldest request's deadline minus a safety multiple of
  the bucket's measured model-step EWMA is about to pass
  (:func:`repro.serve.admission.urgent`), so waiting for more batchmates
  would knowingly miss its SLO,
* **timer** — the oldest request has waited ``max_wait_s`` (bounds the
  latency a lone request pays for batching).

Backpressure is a bounded queue: :meth:`BatchPlanner.admit` raises
:class:`repro.serve.admission.RejectedError` (``queue_full``) at the
configured depth, and sheds requests whose deadline the current step
estimate already rules out (``deadline_unmeetable``).  :meth:`poll`
additionally *sweeps* queued requests whose deadline has become
unmeetable — they are returned as rejects, never dispatched, and never
dropped silently (the conservation invariant the property tests pin).

The planner is deliberately synchronous and single-threaded (the
asyncio service calls it only from the event loop) and imports neither
jax nor the engine, so hypothesis can drive thousands of synthetic
schedules against the real production logic.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from collections import deque

from repro.serve import admission
from repro.serve.admission import RejectedError

#: Shape-bucket granularity (must match
#: :data:`repro.serve.codec_engine.SHAPE_BUCKET`; asserted by tests so
#: this module stays importable without jax).
DEFAULT_SHAPE_BUCKET = 64


def shape_bucket(h: int, w: int, bucket: int = DEFAULT_SHAPE_BUCKET
                 ) -> tuple:
    """Bucketed (H, W): each dim rounds up to a multiple of ``bucket``."""
    return (h + (-h) % bucket, w + (-w) % bucket)


class Ewma:
    """Exponentially-weighted moving average of model-step seconds."""

    def __init__(self, alpha: float = 0.25, initial: float | None = None):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self._value = initial

    def observe(self, v: float) -> None:
        """Fold one measurement into the average."""
        self._value = (v if self._value is None
                       else self.alpha * v + (1 - self.alpha) * self._value)

    @property
    def value(self) -> float | None:
        return self._value


@dataclasses.dataclass
class Request:
    """One queued encode request (planner's view; payload untouched).

    Attributes:
        req_id: monotone id (assigned by :meth:`BatchPlanner.admit`).
        shape: the image's (H, W) — determines the shape bucket.
        quality: resolved (post-tier) JPEG quality.
        tenant: tenant name, for accounting only.
        arrival: clock time the request was admitted.
        deadline: absolute clock time the response is due (``inf`` =
            no deadline).
        payload: opaque caller data (the service stores the image and
            the asyncio future here; the planner never touches it).
    """
    req_id: int
    shape: tuple
    quality: int
    tenant: str
    arrival: float
    deadline: float = math.inf
    payload: object = None


@dataclasses.dataclass
class Batch:
    """One dispatchable engine batch: same bucket, FIFO order."""
    key: tuple                  # ((bh, bw), quality)
    requests: list


@dataclasses.dataclass
class PlannerPoll:
    """Result of one :meth:`BatchPlanner.poll`.

    Attributes:
        batches: batches to dispatch now (FIFO within each bucket).
        rejects: ``(request, RejectedError)`` pairs swept from queues
            because their deadline became unmeetable while queued.
    """
    batches: list
    rejects: list


class BatchPlanner:
    """Deadline-aware adaptive batcher over per-bucket FIFO queues.

    Args:
        max_batch: dispatch a bucket as soon as it holds this many.
        max_wait_s: batching timer — the oldest request never waits
            longer than this for batchmates.
        max_queue_depth: per-bucket admission bound (backpressure).
        safety: EWMA multiple used for urgency/admission margins.
        initial_step_s: model-step estimate before any observation.
        bucket: shape-bucket granularity (see :func:`shape_bucket`).
    """

    def __init__(self, max_batch: int = 8, max_wait_s: float = 0.010,
                 max_queue_depth: int = 64, safety: float = 1.5,
                 initial_step_s: float = 0.050,
                 bucket: int = DEFAULT_SHAPE_BUCKET):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_queue_depth < max_batch:
            raise ValueError(f"max_queue_depth ({max_queue_depth}) must "
                             f"be >= max_batch ({max_batch})")
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.max_queue_depth = max_queue_depth
        self.safety = safety
        self.initial_step_s = initial_step_s
        self.bucket = bucket
        self._queues: dict = {}          # key -> deque[Request]
        self._ewma: dict = {}            # key -> Ewma
        self._ids = itertools.count()

    # -- observation ------------------------------------------------------

    def bucket_key(self, shape: tuple, quality: int) -> tuple:
        """Queue key: requests batch only within equal buckets."""
        return (shape_bucket(shape[0], shape[1], self.bucket), quality)

    def step_estimate(self, key: tuple) -> float:
        """Current model-step EWMA for a bucket (seconds)."""
        e = self._ewma.get(key)
        v = e.value if e is not None else None
        return self.initial_step_s if v is None else v

    def observe_step(self, key: tuple, seconds: float) -> None:
        """Fold one measured engine-batch duration into the bucket EWMA."""
        self._ewma.setdefault(key, Ewma()).observe(seconds)

    # -- admission --------------------------------------------------------

    def admit(self, shape: tuple, quality: int, tenant: str, now: float,
              deadline: float = math.inf, payload: object = None
              ) -> Request:
        """Admit a request into its bucket queue or raise RejectedError.

        Raises:
            RejectedError: ``queue_full`` at the depth bound, or
                ``deadline_unmeetable`` when the bucket's current step
                estimate already rules the deadline out.
        """
        key = self.bucket_key(shape, quality)
        q = self._queues.get(key)
        depth = len(q) if q is not None else 0
        if depth >= self.max_queue_depth:
            raise RejectedError(
                admission.QUEUE_FULL,
                f"bucket {key} at depth bound {self.max_queue_depth}")
        step = self.step_estimate(key)
        if not admission.admission_deadline_ok(deadline, now, step,
                                               self.safety):
            raise RejectedError(
                admission.DEADLINE_UNMEETABLE,
                f"deadline {deadline - now:.4f}s away < {self.safety} x "
                f"step estimate {step:.4f}s")
        req = Request(req_id=next(self._ids), shape=tuple(shape),
                      quality=quality, tenant=tenant, arrival=now,
                      deadline=deadline, payload=payload)
        if q is None:
            q = self._queues[key] = deque()
        q.append(req)
        return req

    def readmit(self, req: Request) -> None:
        """Re-queue an already-admitted request (the retry path).

        The request object is reused verbatim — same ``req_id``,
        ``arrival`` and ``deadline`` — so SLO and latency accounting
        span every attempt.  Applies the depth bound (a retry does not
        get to overflow a bucket new work is being shed from) but not
        the admission deadline margin: the poll-time sweep decides
        feasibility with the request's original deadline.

        Raises:
            RejectedError: ``queue_full`` at the depth bound.
        """
        key = self.bucket_key(req.shape, req.quality)
        q = self._queues.get(key)
        if q is not None and len(q) >= self.max_queue_depth:
            raise RejectedError(
                admission.QUEUE_FULL,
                f"bucket {key} at depth bound {self.max_queue_depth} "
                f"(retry re-admission)")
        if q is None:
            q = self._queues[key] = deque()
        q.append(req)

    # -- dispatch ---------------------------------------------------------

    def poll(self, now: float, drain: bool = False,
             max_batches: int | None = None,
             urgent_cap: int | None = None) -> PlannerPoll:
        """Sweep unmeetable requests, then collect dispatchable batches.

        Args:
            now: current clock time.
            drain: dispatch every non-empty bucket regardless of
                triggers (shutdown path — nothing may stay queued).
            max_batches: dispatch at most this many batches (the
                service's in-flight cap: when the engine is saturated,
                requests stay *queued* — where the depth bound and the
                deadline sweep still apply — instead of piling up in an
                unbounded executor backlog).  ``None`` = unlimited;
                sweeping is never limited.
            urgent_cap: graceful-degradation hook — when a batch
                dispatches because its oldest request turned deadline-
                *urgent* (not full, not timer), cap its size at this
                many requests: a smaller batch completes sooner, so the
                urgent request's SLO survives overload at the cost of
                occupancy.  ``None`` = no cap.

        Returns:
            :class:`PlannerPoll` — batches preserve FIFO order within
            their bucket; swept requests come back as rejects so the
            caller can fail their futures (never silently dropped).
        """
        batches: list = []
        rejects: list = []
        for key in list(self._queues):
            q = self._queues[key]
            step = self.step_estimate(key)
            # sweep: a queued request whose deadline the step estimate
            # already rules out must be rejected, never dispatched
            kept = deque()
            for r in q:
                if admission.feasible(r.deadline, now, step):
                    kept.append(r)
                else:
                    rejects.append((r, RejectedError(
                        admission.DEADLINE_UNMEETABLE,
                        f"deadline passed in queue (step estimate "
                        f"{step:.4f}s)")))
            self._queues[key] = q = kept
            while q and (max_batches is None
                         or len(batches) < max_batches):
                trigger = ("drain" if drain
                           else self._dispatch_trigger(q, now, step))
                if trigger is None:
                    break
                take = min(len(q), self.max_batch)
                if trigger == "urgent" and urgent_cap is not None:
                    take = min(take, max(1, urgent_cap))
                batches.append(Batch(
                    key=key,
                    requests=[q.popleft() for _ in range(take)]))
            if not q:
                del self._queues[key]
        return PlannerPoll(batches=batches, rejects=rejects)

    def _dispatch_trigger(self, q: deque, now: float, step: float
                          ) -> str | None:
        """Why this queue dispatches now: "full" | "timer" | "urgent".

        Checked in that order — a full bucket is a full engine batch
        regardless of deadlines, and an expired batching timer already
        waited long enough; only a pure deadline-urgency dispatch is
        eligible for the degradation-time ``urgent_cap``.
        """
        if len(q) >= self.max_batch:
            return "full"
        oldest = q[0]
        if now - oldest.arrival >= self.max_wait_s:
            return "timer"
        if admission.urgent(oldest.deadline, now, step, self.safety):
            return "urgent"
        return None

    def next_wake(self, now: float) -> float | None:
        """Seconds until the earliest timer/urgency trigger, or None.

        ``None`` means every queue is empty — the dispatcher can sleep
        until the next admission wakes it.  A full bucket returns 0.0
        (dispatch immediately).
        """
        wake = math.inf
        for key, q in self._queues.items():
            if not q:
                continue
            if len(q) >= self.max_batch:
                return 0.0
            step = self.step_estimate(key)
            oldest = q[0]
            t = oldest.arrival + self.max_wait_s
            if oldest.deadline != math.inf:
                t = min(t, oldest.deadline - self.safety * step)
            wake = min(wake, t)
        if wake == math.inf:
            return None
        return max(0.0, wake - now)

    def next_sweep(self, now: float) -> float | None:
        """Seconds until the earliest queued deadline turns unmeetable.

        The dispatcher's timeout while the in-flight cap blocks
        dispatch: timers and urgency are moot (nothing may dispatch),
        but a queued request crossing ``deadline - step`` must still be
        swept promptly.  ``None`` = no queued request has a finite
        deadline.
        """
        t = math.inf
        for key, q in self._queues.items():
            step = self.step_estimate(key)
            for r in q:
                if r.deadline != math.inf:
                    t = min(t, r.deadline - step)
        if t == math.inf:
            return None
        return max(0.0, t - now)

    # -- introspection ----------------------------------------------------

    def depth(self, shape: tuple, quality: int) -> int:
        """Current queue depth for a request's bucket."""
        q = self._queues.get(self.bucket_key(shape, quality))
        return len(q) if q is not None else 0

    def total_depth(self) -> int:
        """Requests queued across all buckets."""
        return sum(len(q) for q in self._queues.values())

    def pressure(self) -> float:
        """Queue pressure in [0, 1]: the fullest bucket's depth fraction.

        The overload signal the degradation controller consumes — max
        (not mean) across buckets, because backpressure (``queue_full``)
        engages per bucket and one saturated bucket is already shedding.
        """
        if not self._queues:
            return 0.0
        return min(1.0, max(len(q) for q in self._queues.values())
                   / self.max_queue_depth)

    def empty(self) -> bool:
        return self.total_depth() == 0
