"""Admission control for the async codec service (jax-free).

A request is *admitted* when it enters a bucket queue, and every
admitted request gets exactly one terminal outcome later (a response or
a reject).  This module holds the pieces that decide the other branch —
requests that never enter a queue, or are swept out of one:

* :class:`RejectedError` — the one exception type clients see for every
  load-shedding decision, tagged with a machine-readable ``reason``
  (:data:`QUEUE_FULL`, :data:`DEADLINE_UNMEETABLE`, :data:`SHUTDOWN`,
  :data:`CIRCUIT_OPEN`); :class:`ServiceClosed` and
  :class:`repro.serve.resilience.CircuitOpen` are typed subclasses for
  the two reasons callers most often branch on,
* :class:`TenantTier` — per-tenant quality/deadline policy (a "free"
  tier encodes at a capped quality; a "gold" tier keeps what it asked
  for),
* the feasibility predicates (:func:`feasible`, :func:`urgent`) the
  batch planner uses to decide when a queued request's deadline is
  about to expire (dispatch now) versus knowingly unmeetable (reject,
  never dispatch).

Everything here is pure stdlib so the property tests can drive
thousands of synthetic schedules without importing jax.
"""

from __future__ import annotations

import dataclasses
import math

#: Reject reasons (``RejectedError.reason``).
QUEUE_FULL = "queue_full"               # bounded-queue backpressure
DEADLINE_UNMEETABLE = "deadline_unmeetable"   # could not/cannot make SLO
SHUTDOWN = "shutdown"                   # service draining or closed
CIRCUIT_OPEN = "circuit_open"           # engine-path breaker tripped

REASONS = (QUEUE_FULL, DEADLINE_UNMEETABLE, SHUTDOWN, CIRCUIT_OPEN)


class RejectedError(RuntimeError):
    """A request the service refused to serve (admission control).

    Attributes:
        reason: one of :data:`REASONS` — why the request was shed.
        detail: human-readable context (queue depth, deadline math).
    """

    def __init__(self, reason: str, detail: str = ""):
        if reason not in REASONS:
            raise ValueError(f"unknown reject reason {reason!r}; "
                             f"expected one of {REASONS}")
        self.reason = reason
        self.detail = detail
        super().__init__(f"rejected ({reason})" + (f": {detail}"
                                                   if detail else ""))


class ServiceClosed(RejectedError):
    """Typed reject: the service shut down before serving this request.

    Raised (via the request's future) for every submit still pending
    when :meth:`repro.serve.service.CodecService.close` finishes — a
    queued request the drain could not serve, a request parked in a
    retry backoff, or anything stranded by a dispatcher crash.  A
    :class:`RejectedError` with reason :data:`SHUTDOWN`, so the
    conservation invariant (submitted == served + rejected + failed)
    covers shutdown too: no awaiting client is ever left dangling.
    """

    def __init__(self, detail: str = ""):
        super().__init__(SHUTDOWN, detail or "service closed")


@dataclasses.dataclass(frozen=True)
class TenantTier:
    """Quality-of-service envelope for one tenant class.

    Attributes:
        max_quality: requested JPEG quality is clamped to this (paying
            tiers keep high quality; free tiers encode cheaper/smaller).
        min_deadline_s: tightest relative deadline the tier may demand;
            tighter requests are relaxed up to this floor (None = any).
    """
    max_quality: int = 100
    min_deadline_s: float | None = None

    def resolve_quality(self, quality: int) -> int:
        """Clamp a requested quality into the tier's envelope."""
        if not 1 <= quality <= 100:
            raise ValueError(f"quality must be in [1, 100], got {quality}")
        return min(quality, self.max_quality)

    def resolve_deadline_s(self, deadline_s: float | None) -> float:
        """Relative deadline after tier policy (``inf`` = no deadline)."""
        if deadline_s is None:
            return math.inf
        if deadline_s <= 0:
            raise ValueError(f"deadline_s must be positive, "
                             f"got {deadline_s}")
        if self.min_deadline_s is not None:
            return max(deadline_s, self.min_deadline_s)
        return deadline_s


def feasible(deadline: float, now: float, step_s: float) -> bool:
    """Could a request dispatched *right now* still meet its deadline?

    ``step_s`` is the planner's current estimate of one model step
    (batch encode) for the request's bucket.  A request that fails this
    is *knowingly unmeetable*: dispatching it would burn a batch slot on
    work whose SLO is already lost, so the planner rejects it instead —
    the dispatch-loop invariant the property tests pin.
    """
    return now + step_s <= deadline


def urgent(deadline: float, now: float, step_s: float,
           safety: float) -> bool:
    """Is a queued request's deadline about to expire?

    True once ``now`` reaches ``deadline - safety * step_s`` — the
    last moment (with ``safety`` margin over the EWMA step estimate) at
    which dispatching still meets the deadline.  The planner dispatches
    a partial batch rather than waiting out its batching timer when its
    oldest request turns urgent.
    """
    return now >= deadline - safety * step_s


def admission_deadline_ok(deadline: float, now: float, step_s: float,
                          safety: float) -> bool:
    """Admission-time feasibility: worth queueing at all?

    Slightly stricter than :func:`feasible` (the ``safety`` margin
    accounts for queueing ahead of the step itself) so hopeless
    requests are shed at the door instead of occupying queue slots.
    """
    return now + safety * step_s <= deadline
