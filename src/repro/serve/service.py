"""Async codec service: deadline-aware batching over the codec engine.

The serving front end the ROADMAP's "millions of users" story needs:
callers ``await service.submit(image, ...)`` and the service turns many
concurrent single-image requests into the batched engine calls
(:func:`repro.serve.codec_engine.encode_batch`) the hardware actually
wants, while holding per-request SLOs:

* requests queue per *(shape bucket, quality)* and dispatch when the
  bucket fills, when the oldest request's deadline (minus a safety
  multiple of the bucket's measured model-step EWMA) is about to
  expire, or on a ``max_wait_s`` batching timer
  (:class:`repro.serve.queueing.BatchPlanner`),
* bounded queues give explicit backpressure — an overloaded service
  raises :class:`repro.serve.admission.RejectedError` instead of
  accepting work it cannot finish, and queued requests whose deadline
  becomes unmeetable are rejected, never dispatched and never silently
  dropped,
* per-tenant :class:`repro.serve.admission.TenantTier` policies clamp
  quality (and relax too-tight deadlines) before admission,
* an LRU **hot-stream cache** keyed on ``(payload digest, quality,
  tables)`` serves repeated images without touching the engine —
  shared-table ``DCTZ`` streams are cheap to keep (no per-stream table
  segment),
* engine failures fail *only* the affected batch's requests (with
  :class:`EngineFailure`) and the dispatch loop keeps serving — the
  fault-injection suite drives this with a flaky engine wrapper,
* an optional **resilience envelope** (:mod:`repro.serve.resilience`,
  off by default) adds per-attempt engine timeouts, bounded
  budget-guarded retries with decorrelated-jitter backoff, a
  failure-rate circuit breaker over the engine path (typed
  :class:`~repro.serve.resilience.CircuitOpen` rejects while open),
  payload integrity validation, and graceful quality degradation under
  sustained queue pressure — all without breaking the one-terminal-
  outcome invariant (a retried request is still one submit).

The planner half is synchronous and jax-free
(:mod:`repro.serve.queueing`); this module adds the asyncio shell: one
dispatcher task multiplexing queue timers, engine batches running in a
(default single-worker) thread pool so the event loop never blocks on
device work, and per-request futures carrying exactly one terminal
outcome each.  See docs/serving.md for semantics and SLO knobs, and
``bench/cases.py::service_traffic`` / ``service_chaos`` for the
open-loop load tests that measure p50/p99 latency, goodput, reject
rate and fault-storm behaviour through this layer.
"""

from __future__ import annotations

import asyncio
import collections
import concurrent.futures
import dataclasses
import hashlib
import math
import random
import time

import numpy as np

from repro.serve import admission, queueing, resilience
from repro.serve.admission import RejectedError, ServiceClosed, TenantTier


class EngineFailure(RuntimeError):
    """The engine batch carrying this request raised; see ``__cause__``."""


class EngineTimeout(RuntimeError):
    """An engine attempt exceeded ``ResilienceConfig.timeout_s``.

    Used as the ``__cause__`` of the :class:`EngineFailure` a request
    sees when its timed-out attempt was its last; the abandoned worker
    thread keeps running until the engine returns (its result is
    discarded).
    """


class PayloadCorrupt(RuntimeError):
    """An engine-produced payload failed ``validate_payload``.

    Never served; used as the ``__cause__`` of the terminal
    :class:`EngineFailure` when retries are off or exhausted.
    """


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """SLO and batching knobs for :class:`CodecService`.

    Attributes:
        max_batch: engine batch size a bucket dispatches at.
        max_wait_s: batching timer — max time the oldest queued request
            waits for batchmates.
        max_queue_depth: per-bucket queue bound (backpressure).
        safety: EWMA multiple for deadline urgency/admission margins.
        initial_step_s: model-step estimate before any measurement.
        default_quality: quality when a request does not specify one.
        default_deadline_s: relative deadline applied when a request
            has none (None = requests without deadlines never expire).
        cache_entries: LRU hot-stream cache capacity (0 disables).
        transform: encoder transform for the default engine.
        tables: Huffman table policy for the default engine (also part
            of the cache key).
        tenants: tenant name -> :class:`TenantTier` policy map.
        default_tier: tier applied to unknown tenants.
        engine_concurrency: worker threads running engine batches (1 =
            strictly one model step at a time, the EWMA's assumption).
        max_inflight_batches: dispatched-but-unfinished batch cap.
            When the engine saturates, further requests stay queued —
            where the depth bound rejects and the deadline sweep sheds
            — instead of accumulating in an unbounded executor backlog
            that would serve everything late and reject nothing.
            Default 2: one batch encoding, one forming/waiting.
        shape_bucket: shape-bucket granularity (keep at the engine's
            :data:`repro.serve.codec_engine.SHAPE_BUCKET`).
        resilience: timeout/retry/breaker/degradation envelope
            (:class:`repro.serve.resilience.ResilienceConfig`); the
            default disables every mechanism, preserving the baseline
            service semantics exactly.
    """
    max_batch: int = 8
    max_wait_s: float = 0.010
    max_queue_depth: int = 64
    safety: float = 1.5
    initial_step_s: float = 0.050
    default_quality: int = 50
    default_deadline_s: float | None = None
    cache_entries: int = 256
    transform: str = "exact"
    tables: str = "auto"
    tenants: dict = dataclasses.field(default_factory=dict)
    default_tier: TenantTier = TenantTier()
    engine_concurrency: int = 1
    max_inflight_batches: int = 2
    shape_bucket: int = queueing.DEFAULT_SHAPE_BUCKET
    resilience: resilience.ResilienceConfig = dataclasses.field(
        default_factory=resilience.ResilienceConfig)

    def tier(self, tenant: str) -> TenantTier:
        """The tier serving ``tenant`` (unknown tenants get the default)."""
        return self.tenants.get(tenant, self.default_tier)


def default_engine(config: ServiceConfig):
    """The production engine callable: batched entropy-coded encode.

    Returns ``(images, quality) -> list[bytes]`` running
    :func:`repro.serve.codec_engine.encode_batch` under the service's
    transform/table policy.  Import is deferred so constructing a
    service with a stub engine (tests, property suites) never pays for
    jax.
    """
    from repro.serve import codec_engine

    def encode(images, quality: int):
        return codec_engine.encode_batch(
            list(images), quality, transform=config.transform,
            tables=config.tables)
    return encode


# ---------------------------------------------------------------------------
# Responses, cache, stats
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Response:
    """Terminal success outcome of one :meth:`CodecService.submit`.

    Attributes:
        payload: the entropy-coded ``DCTZ`` stream.
        quality: quality actually encoded at (post tenant tier).
        latency_s: admission-to-completion wall time.
        batch_size: engine batch the request rode in (0 = cache hit).
        cache_hit: served from the hot-stream cache.
        deadline_missed: completed, but after the request's deadline
            (counts against goodput, not against delivery).
        req_id: service-assigned id (-1 for cache hits, which never
            enter a queue).
        degraded: quality was downshifted by the graceful-degradation
            controller (``quality`` reflects what was actually served).
        attempts: engine attempts this request rode in (> 1 = retried;
            0 for cache hits).
    """
    payload: bytes
    quality: int
    latency_s: float
    batch_size: int
    cache_hit: bool = False
    deadline_missed: bool = False
    req_id: int = -1
    degraded: bool = False
    attempts: int = 1


class StreamCache:
    """LRU cache of encoded streams keyed ``(digest, quality, tables)``."""

    def __init__(self, entries: int):
        self.entries = entries
        self._data: collections.OrderedDict = collections.OrderedDict()
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(image: np.ndarray, quality: int, tables: str) -> tuple:
        """Cache key: content digest + the knobs that change the bytes."""
        h = hashlib.sha1(image.tobytes())
        h.update(repr((image.shape, str(image.dtype))).encode())
        return (h.hexdigest(), quality, tables)

    def get(self, key: tuple):
        if self.entries <= 0:
            return None
        blob = self._data.get(key)
        if blob is None:
            self.misses += 1
            return None
        self._data.move_to_end(key)
        self.hits += 1
        return blob

    def put(self, key: tuple, blob: bytes) -> None:
        if self.entries <= 0:
            return
        self._data[key] = blob
        self._data.move_to_end(key)
        while len(self._data) > self.entries:
            self._data.popitem(last=False)

    def __len__(self) -> int:
        return len(self._data)


class ServiceStats:
    """Counters the service maintains; snapshot with :meth:`snapshot`.

    Attributes:
        submitted: requests entering :meth:`CodecService.submit`.
        served: requests that got a payload (cache hits included).
        rejected: reject reason -> count.
        failed: requests failed by an engine error.
        engine_failures: engine batches that raised.
        deadline_missed: served, but past the deadline.
        occupancy: engine batch size -> dispatch count.
        latencies_s: admission-to-completion times of the most recent
            :data:`LATENCY_WINDOW` served requests (a bounded sliding
            window — a long-running service must not grow memory, or
            re-sort an ever-longer list per snapshot, without limit).
        retries: retry attempts scheduled (a retried request still
            counts once in ``submitted`` and reaches one terminal
            outcome).
        retry_budget_exhausted: retries denied by the token-bucket
            retry budget (the request fails instead).
        timeouts: engine attempts abandoned at ``timeout_s``.
        corrupt_payloads: engine payloads that failed
            ``validate_payload`` (never served).
        degraded: requests whose quality the degradation controller
            downshifted at admission.
        degraded_served: degraded requests that were served (always
            ⊆ ``served``).
        closed_unserved: futures resolved with
            :class:`~repro.serve.admission.ServiceClosed` at close
            (also counted under ``rejected["shutdown"]``).
        unhandled: batch tasks whose failure handling itself raised —
            the dispatch loop's last-resort containment guard; must
            stay 0 (CI-gated by the chaos bench).
    """

    LATENCY_WINDOW = 8192

    def __init__(self):
        self.submitted = 0
        self.served = 0
        self.rejected: collections.Counter = collections.Counter()
        self.failed = 0
        self.engine_failures = 0
        self.deadline_missed = 0
        self.occupancy: collections.Counter = collections.Counter()
        self.latencies_s: collections.deque = collections.deque(
            maxlen=self.LATENCY_WINDOW)
        self.retries = 0
        self.retry_budget_exhausted = 0
        self.timeouts = 0
        self.corrupt_payloads = 0
        self.degraded = 0
        self.degraded_served = 0
        self.closed_unserved = 0
        self.unhandled = 0

    @property
    def total_rejected(self) -> int:
        return sum(self.rejected.values())

    def latency_percentile(self, pct: float) -> float:
        """Empirical latency percentile in seconds (nan when empty)."""
        if not self.latencies_s:
            return math.nan
        xs = sorted(self.latencies_s)
        i = min(len(xs) - 1, max(0, round(pct / 100 * (len(xs) - 1))))
        return xs[i]

    def snapshot(self) -> dict:
        """JSON-friendly summary of every counter."""
        return {
            "submitted": self.submitted,
            "served": self.served,
            "rejected": dict(self.rejected),
            "failed": self.failed,
            "engine_failures": self.engine_failures,
            "deadline_missed": self.deadline_missed,
            "occupancy": {str(k): v for k, v
                          in sorted(self.occupancy.items())},
            "p50_latency_s": self.latency_percentile(50),
            "p99_latency_s": self.latency_percentile(99),
            "retries": self.retries,
            "retry_budget_exhausted": self.retry_budget_exhausted,
            "timeouts": self.timeouts,
            "corrupt_payloads": self.corrupt_payloads,
            "degraded": self.degraded,
            "degraded_served": self.degraded_served,
            "closed_unserved": self.closed_unserved,
            "unhandled": self.unhandled,
        }


@dataclasses.dataclass
class _Entry:
    """Service-side payload attached to each planner request.

    ``attempts``/``backoff_s`` track the retry state across engine
    attempts (the planner ``Request`` object — id, arrival, deadline —
    is reused verbatim on re-admission so latency and SLO accounting
    span the whole request, not just the last attempt).
    """
    image: np.ndarray
    cache_key: tuple
    future: asyncio.Future
    degraded: bool = False
    attempts: int = 0
    backoff_s: float = 0.0


# ---------------------------------------------------------------------------
# The service
# ---------------------------------------------------------------------------

class CodecService:
    """Asyncio front end turning concurrent submits into engine batches.

    Use as an async context manager (or call :meth:`start` /
    :meth:`close` explicitly)::

        async with CodecService(ServiceConfig(max_batch=8)) as svc:
            resp = await svc.submit(img, quality=75, tenant="gold",
                                    deadline_s=0.25)
            resp.payload    # DCTZ bytes

    Every submit reaches exactly one terminal outcome: a
    :class:`Response`, a :class:`RejectedError` (admission or queue
    sweep), or an :class:`EngineFailure` (its batch's engine call
    raised).  All planner state is touched only from the event loop;
    engine batches run in a thread pool sized by
    ``config.engine_concurrency``.

    Args:
        config: SLO/batching knobs (default :class:`ServiceConfig`).
        engine: ``(images, quality) -> list[bytes]`` override; defaults
            to :func:`default_engine` (the real codec engine).  Called
            from worker threads — must be thread-compatible.
        clock: monotonic time source (injectable for tests).
    """

    def __init__(self, config: ServiceConfig | None = None, *,
                 engine=None, clock=time.monotonic):
        self.config = config or ServiceConfig()
        self._engine = engine if engine is not None else \
            default_engine(self.config)
        self._clock = clock
        self._planner = queueing.BatchPlanner(
            max_batch=self.config.max_batch,
            max_wait_s=self.config.max_wait_s,
            max_queue_depth=self.config.max_queue_depth,
            safety=self.config.safety,
            initial_step_s=self.config.initial_step_s,
            bucket=self.config.shape_bucket)
        self.stats = ServiceStats()
        self.cache = StreamCache(self.config.cache_entries)
        self._wake: asyncio.Event | None = None
        self._dispatcher: asyncio.Task | None = None
        self._inflight: set = set()
        self._pool: concurrent.futures.ThreadPoolExecutor | None = None
        self._draining = False
        self._closed = False
        res = self.config.resilience
        self.breaker = (resilience.CircuitBreaker(res.breaker)
                        if res.breaker is not None else None)
        self.degrade = (resilience.DegradationController(res.degrade)
                        if res.degrade is not None else None)
        self._retry_budget = res.retry.make_budget()
        self._retry_rng = random.Random(res.seed)
        self._retry_tasks: set = set()
        # every admitted request's future, until it resolves: close()
        # uses this to guarantee no awaiting client dangles even after
        # a dispatcher crash or a cancelled retry backoff
        self._pending: set = set()
        self.dispatcher_error: BaseException | None = None

    # -- lifecycle --------------------------------------------------------

    async def start(self) -> "CodecService":
        """Start the dispatcher task; idempotent until :meth:`close`."""
        if self._closed:
            raise RuntimeError("service already closed")
        if self._dispatcher is None:
            self._wake = asyncio.Event()
            self._pool = concurrent.futures.ThreadPoolExecutor(
                max_workers=max(1, self.config.engine_concurrency),
                thread_name_prefix="codec-engine")
            self._dispatcher = asyncio.get_running_loop().create_task(
                self._dispatch_loop())
        return self

    async def close(self) -> None:
        """Drain queues, finish in-flight batches, stop the dispatcher.

        Every already-admitted request still gets its terminal outcome
        (queues are drained as forced partial batches); new submits
        raise ``RejectedError(reason="shutdown")``.  Requests the drain
        could not serve — parked in a retry backoff, or stranded by a
        dispatcher crash (recorded in :attr:`dispatcher_error`) — are
        resolved with a typed
        :class:`~repro.serve.admission.ServiceClosed` rejection and
        counted in ``stats.closed_unserved``: no awaiting client is
        ever left dangling.
        """
        if self._closed:
            return
        self._draining = True
        self._closed = True
        if self._dispatcher is not None:
            self._wake.set()
            try:
                await self._dispatcher
            except Exception as exc:    # noqa: BLE001 - record, keep closing
                self.dispatcher_error = exc
            while self._inflight:
                await asyncio.gather(*list(self._inflight),
                                     return_exceptions=True)
            # retries parked in a backoff sleep never re-admit now:
            # cancel them; the sweep below resolves their futures
            for t in list(self._retry_tasks):
                t.cancel()
            if self._retry_tasks:
                await asyncio.gather(*list(self._retry_tasks),
                                     return_exceptions=True)
            self._pool.shutdown(wait=True)
            self._dispatcher = None
        for fut in [f for f in self._pending if not f.done()]:
            self.stats.closed_unserved += 1
            self.stats.rejected[admission.SHUTDOWN] += 1
            fut.set_exception(ServiceClosed(
                "service closed before serving this request"))
        self._pending.clear()

    async def __aenter__(self) -> "CodecService":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.close()

    # -- client API -------------------------------------------------------

    async def submit(self, image, *, quality: int | None = None,
                     tenant: str = "default",
                     deadline_s: float | None = None) -> Response:
        """Encode one image to a ``DCTZ`` stream under the service SLOs.

        Args:
            image: 2-D (H, W) uint8 array (anything ``np.asarray``
                accepts).
            quality: requested JPEG quality (default
                ``config.default_quality``); clamped by the tenant tier.
            tenant: tenant name — selects the
                :class:`~repro.serve.admission.TenantTier` policy.
            deadline_s: relative SLO; None uses
                ``config.default_deadline_s`` (which may mean "none").

        Returns:
            A :class:`Response` (payload bytes + serving metadata).

        Raises:
            ValueError: invalid image/quality/deadline arguments —
                raised before the request counts as submitted, so the
                stats conservation invariant is unaffected.
            RejectedError: backpressure (``queue_full``), hopeless or
                expired deadline (``deadline_unmeetable``), a closing
                service (``shutdown``; :class:`ServiceClosed` when the
                request was admitted but shutdown beat its outcome), or
                an open engine-path breaker (``circuit_open``, typed
                :class:`~repro.serve.resilience.CircuitOpen`).
            EngineFailure: every engine attempt carrying this request
                raised, timed out or produced a corrupt payload; the
                last underlying exception is ``__cause__``.
        """
        if self._dispatcher is None and not self._closed:
            raise RuntimeError("service not started: use `async with "
                               "CodecService(...)` or await start()")
        image = np.asarray(image)
        if image.ndim != 2:
            raise ValueError(f"image must be 2-D (H, W), "
                             f"got shape {image.shape}")
        tier = self.config.tier(tenant)
        q = tier.resolve_quality(quality if quality is not None
                                 else self.config.default_quality)
        rel_deadline = tier.resolve_deadline_s(
            deadline_s if deadline_s is not None
            else self.config.default_deadline_s)
        # invalid arguments raised above, before the request counts as
        # submitted: every counted submit reaches exactly one terminal
        # outcome, so submitted == served + rejected + failed holds
        self.stats.submitted += 1
        if self._draining:
            exc = ServiceClosed("service closing")
            self.stats.rejected[exc.reason] += 1
            raise exc
        now = self._clock()
        degraded = False
        if self.degrade is not None:
            cap = self.degrade.quality_cap()
            if q > cap:
                q = cap
                degraded = True
                self.stats.degraded += 1
        key = StreamCache.key(image, q, self.config.tables)
        blob = self.cache.get(key)
        if blob is not None:
            self.stats.served += 1
            if degraded:
                self.stats.degraded_served += 1
            self.stats.latencies_s.append(self._clock() - now)
            return Response(payload=blob, quality=q,
                            latency_s=self._clock() - now, batch_size=0,
                            cache_hit=True, degraded=degraded, attempts=0)
        if self.breaker is not None and not self.breaker.admission_open(now):
            exc = resilience.CircuitOpen(
                f"engine path open; retry in "
                f"{self.breaker.retry_after_s(now):.3f}s")
            self.stats.rejected[exc.reason] += 1
            raise exc
        deadline = now + rel_deadline      # inf stays inf
        future = asyncio.get_running_loop().create_future()
        try:
            req = self._planner.admit(
                image.shape, q, tenant, now, deadline=deadline,
                payload=_Entry(image=image, cache_key=key, future=future,
                               degraded=degraded))
        except RejectedError as exc:
            self.stats.rejected[exc.reason] += 1
            raise
        self._pending.add(future)
        future.add_done_callback(self._pending.discard)
        self._wake.set()
        return await future

    # -- dispatcher -------------------------------------------------------

    async def _dispatch_loop(self) -> None:
        cap = max(1, self.config.max_inflight_batches)
        while True:
            # drop finished tasks here rather than trusting the
            # done-callback: it runs a loop iteration *after* the task
            # completes, and counting a done task against the cap when
            # its completion wake-up was already consumed would leave
            # the dispatcher sleeping with zero budget forever.  Prune
            # IN PLACE — the done-callbacks and close()'s drain loop
            # hold references to this set object, so rebinding it would
            # strand still-running tasks in a set nobody discards from
            self._inflight.difference_update(
                [t for t in self._inflight if t.done()])
            now = self._clock()
            budget = max(0, cap - len(self._inflight))
            if self.breaker is not None and not self._draining:
                # the breaker gates *dispatch*: 0 while open (queued
                # work waits for half-open or the deadline sweep),
                # bounded probes while half-open.  Draining ignores it
                # — shutdown must resolve everything, and a failed
                # drain batch is still a terminal outcome.
                b = self.breaker.dispatch_budget(now)
                if b is not None:
                    budget = min(budget, b)
            urgent_cap = (self.degrade.urgent_cap()
                          if self.degrade is not None else None)
            poll = self._planner.poll(
                now, drain=self._draining,
                max_batches=None if self._draining else budget,
                urgent_cap=urgent_cap)
            for req, exc in poll.rejects:
                self._finish_reject(req, exc)
            for batch in poll.batches:
                if self.breaker is not None:
                    self.breaker.on_dispatch(now)
                task = asyncio.get_running_loop().create_task(
                    self._run_batch(batch))
                self._inflight.add(task)
                task.add_done_callback(self._inflight.discard)
            if self._draining and self._planner.empty():
                return
            now = self._clock()
            if self.degrade is not None:
                self.degrade.observe(now, self._planner.pressure())
            breaker_blocked = (
                self.breaker is not None
                and not self._planner.empty()
                and self.breaker.dispatch_budget(now) == 0)
            if len(self._inflight) >= cap or breaker_blocked:
                # dispatch is blocked (in-flight cap, or the breaker):
                # a batch completion sets the wake event; until then
                # only the deadline sweep — and, while open, the
                # breaker's reset timer — need the clock
                timeout = self._planner.next_sweep(now)
                if breaker_blocked:
                    retry_after = self.breaker.retry_after_s(now)
                    if retry_after > 0:
                        timeout = (retry_after if timeout is None
                                   else min(timeout, retry_after))
            else:
                timeout = self._planner.next_wake(now)
            try:
                await asyncio.wait_for(self._wake.wait(), timeout)
            except asyncio.TimeoutError:
                pass
            self._wake.clear()

    def _timed_engine_call(self, images, quality):
        # runs in the worker thread: time the engine call itself, not
        # the executor queue wait, so the EWMA tracks the model step
        t0 = self._clock()
        blobs = self._engine(images, quality)
        return blobs, self._clock() - t0

    async def _run_batch(self, batch: queueing.Batch) -> None:
        try:
            await self._run_batch_inner(batch)
        except asyncio.CancelledError:
            raise
        except BaseException as exc:   # noqa: BLE001 - last-resort guard
            # nothing may escape into the dispatch loop — not even a
            # bug in the failure handling itself.  Fail the batch's
            # requests terminally and count the guard trip (the chaos
            # bench CI-gates this counter to zero).
            self.stats.unhandled += 1
            for r in batch.requests:
                fut = r.payload.future
                if not fut.done():
                    self.stats.failed += 1
                    err = EngineFailure("batch handling failed")
                    err.__cause__ = exc
                    fut.set_exception(err)
        finally:
            # a completed batch frees an in-flight slot: wake the
            # dispatcher so blocked queues dispatch immediately
            self._wake.set()

    async def _run_batch_inner(self, batch: queueing.Batch) -> None:
        res = self.config.resilience
        requests = batch.requests
        images = [r.payload.image for r in requests]
        quality = batch.key[1]
        call = asyncio.get_running_loop().run_in_executor(
            self._pool, self._timed_engine_call, images, quality)
        # if the attempt times out the call is abandoned, not awaited:
        # retrieve its eventual exception so it never surfaces as an
        # "exception was never retrieved" warning
        call.add_done_callback(
            lambda f: None if f.cancelled() else f.exception())
        try:
            if res.timeout_s is not None:
                done, _ = await asyncio.wait({call},
                                             timeout=res.timeout_s)
                if not done:
                    # the worker thread keeps running (a thread cannot
                    # be interrupted); its result is discarded and the
                    # attempt is treated as a retryable failure
                    raise EngineTimeout(
                        f"engine attempt exceeded {res.timeout_s}s")
                blobs, step_s = call.result()
            else:
                blobs, step_s = await call
            self._planner.observe_step(batch.key, step_s)
            if len(blobs) != len(requests):
                raise RuntimeError(
                    f"engine returned {len(blobs)} streams for "
                    f"{len(requests)} images")
        except asyncio.CancelledError:
            raise
        except BaseException as exc:  # noqa: BLE001 - isolate the batch;
            # BaseException because a dying worker delivers SystemExit
            # through the executor future, and that too must only fail
            # this batch, never the service
            now = self._clock()
            self.stats.engine_failures += 1
            if isinstance(exc, EngineTimeout):
                self.stats.timeouts += 1
            if self.breaker is not None:
                self.breaker.record_failure(now)
            self._fail_or_retry(requests, batch.key, exc, now)
            return
        end = self._clock()
        self.stats.occupancy[len(requests)] += 1
        validate = res.validate_payload
        corrupt: list = []
        serve: list = []
        for r, blob in zip(requests, blobs):
            if validate is not None and not validate(blob):
                corrupt.append(r)
            else:
                serve.append((r, blob))
        if self.breaker is not None:
            # one outcome per engine call keeps the breaker window in
            # call units; any corrupt payload marks the call failed
            if corrupt:
                self.breaker.record_failure(end)
            else:
                self.breaker.record_success(end)
        if corrupt:
            self.stats.corrupt_payloads += len(corrupt)
            self._fail_or_retry(
                corrupt, batch.key,
                PayloadCorrupt(f"{len(corrupt)}/{len(requests)} payloads "
                               f"failed integrity validation"), end)
        for r, blob in serve:
            entry = r.payload
            self.cache.put(entry.cache_key, blob)
            latency = end - r.arrival
            missed = end > r.deadline
            self.stats.served += 1
            if entry.degraded:
                self.stats.degraded_served += 1
            self.stats.latencies_s.append(latency)
            if missed:
                self.stats.deadline_missed += 1
            if not entry.future.done():
                entry.future.set_result(Response(
                    payload=blob, quality=r.quality, latency_s=latency,
                    batch_size=len(requests), deadline_missed=missed,
                    req_id=r.req_id, degraded=entry.degraded,
                    attempts=entry.attempts + 1))

    def _fail_or_retry(self, requests: list, key: tuple,
                       exc: BaseException, now: float) -> None:
        """Route each failed request to a backoff retry or a terminal
        :class:`EngineFailure`, preserving one-outcome-per-submit."""
        retry = self.config.resilience.retry
        step = self._planner.step_estimate(key)
        for r in requests:
            entry = r.payload
            entry.attempts += 1
            if entry.future.done():
                continue
            if retry.enabled and entry.attempts < retry.max_attempts \
                    and not self._draining:
                if self._retry_budget.take(now):
                    delay = retry.backoff_s(entry.backoff_s,
                                            self._retry_rng)
                    entry.backoff_s = delay
                    if now + delay + step <= r.deadline:
                        self.stats.retries += 1
                        task = asyncio.get_running_loop().create_task(
                            self._retry_later(r, delay))
                        self._retry_tasks.add(task)
                        task.add_done_callback(self._retry_tasks.discard)
                        continue
                    # deadline rules the retry out: fall through to the
                    # terminal failure below
                else:
                    self.stats.retry_budget_exhausted += 1
            self.stats.failed += 1
            err = EngineFailure(
                f"engine attempt {entry.attempts} of "
                f"{retry.max_attempts} failed")
            err.__cause__ = exc
            entry.future.set_exception(err)

    async def _retry_later(self, req: queueing.Request,
                           delay: float) -> None:
        """Sleep out a backoff, then re-queue the original request.

        The planner ``Request`` is re-admitted verbatim (same req_id,
        arrival, deadline), so the eventual response's latency spans
        every attempt.  If the service closes first the task is
        cancelled and :meth:`close` resolves the future with
        :class:`~repro.serve.admission.ServiceClosed`; if the queue is
        full at re-admission the request is rejected like any other.
        """
        try:
            await asyncio.sleep(delay)
        except asyncio.CancelledError:
            return
        if self._draining or req.payload.future.done():
            return      # close() resolves the future via _pending
        try:
            self._planner.readmit(req)
        except RejectedError as exc:
            self._finish_reject(req, exc)
            return
        self._wake.set()

    def _finish_reject(self, req: queueing.Request,
                       exc: RejectedError) -> None:
        self.stats.rejected[exc.reason] += 1
        fut = req.payload.future
        if not fut.done():
            fut.set_exception(exc)

    # -- introspection ----------------------------------------------------

    def queue_depth(self) -> int:
        """Requests currently queued (excludes in-flight batches)."""
        return self._planner.total_depth()
