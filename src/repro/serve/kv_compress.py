"""DCT KV-cache compression (the paper's energy compaction on the time axis).

Frozen cache blocks of 64 consecutive positions are DCT'd along time,
truncated to ``keep`` low-frequency coefficients and int8-quantised —
exactly the grad_dct wire format, reused across the framework.  Attention
keys/values vary smoothly along the sequence for adjacent positions (RoPE
phases aside), so energy compaction holds well enough that decode-quality
loss is small at keep=16..32 (tests bound the logit drift).

HBM read traffic per decode step drops by ~256/(keep+4) per compressed
block — directly attacking the memory roofline term that dominates
decode_32k / long_500k (EXPERIMENTS.md §Roofline).

Layout: dense-cache tensors (L, B, T, H, D) are compressed per (L, B, H, D)
column along T in blocks of 64: codes (L, B, T/64, keep, H, D) int8 +
scales (L, B, T/64, 1, H, D) f32.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import dct

BLOCK = 64


@dataclasses.dataclass
class CompressedKV:
    codes: dict       # path -> int8 (..., nb, keep, ...) codes
    scales: dict      # path -> f32 scales
    keep: int
    t_compressed: int  # positions covered by compressed blocks


def _move_t_last(x: jnp.ndarray):
    """(L, B, T, H, D) -> (L, B, H, D, T)."""
    return jnp.moveaxis(x, 2, -1)


def _move_t_back(x: jnp.ndarray):
    return jnp.moveaxis(x, -1, 2)


def compress_tensor(x: jnp.ndarray, keep: int):
    """x (L, B, T, ...) -> (codes int8, scales f32) blocks along T."""
    xt = _move_t_last(x).astype(jnp.float32)           # (..., T)
    t = xt.shape[-1]
    nb = t // BLOCK
    body = xt[..., :nb * BLOCK].reshape(*xt.shape[:-1], nb, BLOCK)
    c = dct.dct_matrix(BLOCK, jnp.float32)
    coef = body @ c.T
    kept = coef[..., :keep]
    scale = jnp.maximum(jnp.max(jnp.abs(kept), -1, keepdims=True) / 127.0,
                        1e-30)
    codes = jnp.clip(jnp.round(kept / scale), -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def decompress_tensor(codes: jnp.ndarray, scales: jnp.ndarray,
                      out_dtype=jnp.bfloat16):
    """Inverse of compress_tensor -> (L, B, T_c, ...)."""
    c = dct.dct_matrix(BLOCK, jnp.float32)
    keep = codes.shape[-1]
    kept = codes.astype(jnp.float32) * scales
    coef = jnp.pad(kept, [(0, 0)] * (kept.ndim - 1) + [(0, BLOCK - keep)])
    body = coef @ c                                     # (..., nb, BLOCK)
    xt = body.reshape(*body.shape[:-2], body.shape[-2] * BLOCK)
    return _move_t_back(xt).astype(out_dtype)


def compress_cache(cache: dict, keep: int, prefix_len: int) -> tuple:
    """Compress the first ``prefix_len - (prefix_len % 64)`` positions of
    every time-major cache tensor; return (CompressedKV, raw_tail_cache).

    The tail (ragged remainder + all future decode writes) stays raw.
    """
    t_c = (prefix_len // BLOCK) * BLOCK
    codes, scales, tails = {}, {}, {}
    for path, x in cache.items():
        if x.ndim >= 3 and x.shape[2] >= BLOCK:
            cc, ss = compress_tensor(x[:, :, :t_c], keep)
            codes[path] = cc
            scales[path] = ss
            tails[path] = x[:, :, t_c:]
        else:
            tails[path] = x
    return CompressedKV(codes, scales, keep, t_c), tails


def reconstruct_cache(ckv: CompressedKV, tails: dict,
                      dtype=jnp.bfloat16) -> dict:
    """Materialise a full cache from compressed blocks + raw tail."""
    out = {}
    for path, tail in tails.items():
        if path in ckv.codes:
            head = decompress_tensor(ckv.codes[path], ckv.scales[path],
                                     tail.dtype)
            out[path] = jnp.concatenate([head, tail], axis=2)
        else:
            out[path] = tail
    return out


def wire_bytes(ckv: CompressedKV, tails: dict) -> int:
    """HBM bytes of the compressed representation."""
    total = 0
    for p in ckv.codes:
        total += ckv.codes[p].size + ckv.scales[p].size * 4
    for p, t in tails.items():
        total += t.size * t.dtype.itemsize
    return total
