"""Batched multi-device codec pipeline.

The paper's throughput win comes from saturating the device with many
independent 8x8 blocks; this engine is the serving-side realisation:

* ``compress_batch`` / ``decompress_batch`` / ``roundtrip_batch`` accept a
  stacked ``(B, H, W)`` batch *or* a ragged list of mixed-size images,
* ragged images are edge-padded to **bucketed** shapes (next multiple of
  :data:`SHAPE_BUCKET`) so a service sees a bounded set of compiled shapes,
* the batch axis is padded to a power of two (same recompilation argument)
  and sharded over all local devices with shard_map on a 1-D "data" mesh
  (:func:`repro.launch.mesh.make_data_mesh`),
* on TPU the one-pass fused Pallas kernel (:mod:`repro.kernels.fused_codec`)
  handles roundtrips; everywhere else (and for compress/decompress halves)
  the batch-first :mod:`repro.core.codec` path runs, so CPU results are
  bit-identical to the single-image API,
* ``encode_batch`` / ``decode_batch`` extend the same pipeline to real
  entropy-coded bytes: the array half stays sharded, the entropy stage
  (:mod:`repro.core.entropy`) runs per image at the host edge —
  by default *overlapped* with the device: jax async dispatch keeps
  bucket ``k+1``'s DCT/quant in flight while a thread pool (the
  vectorised NumPy entropy stage releases the GIL) codes bucket ``k``'s
  streams, and per-stream Huffman tables are memoised across repeated
  histogram shapes (``huffman.build_table_memo``).  The packing stage
  of each stream routes through :mod:`repro.kernels.pack_bits`
  (``pack_backend`` — Pallas on TPU, the NumPy reference elsewhere;
  bytes identical either way), the table policy (``tables``) can pin
  embedded or well-known shared Huffman tables per stream, and
  ``decode_batch`` offers an opt-in process pool for many-core hosts
  where the GIL-bound decode walk caps thread scaling.

The fused kernel reconstructs with the *matched* (adjoint) transform, so it
only serves roundtrips whose semantics agree with it: ``transform="exact"``
(both decode modes coincide) or ``mode="matched"``.  A standards-compliant
decode of a CORDIC stream always takes the staged path.
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import functools
import multiprocessing
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import codec, cordic, metrics
from repro.dist import compat
from repro.launch import mesh as mesh_lib

SHAPE_BUCKET = 64      # ragged H/W round up to this (multiple of the block)


def _n_workers(workers: int | None) -> int:
    """Thread-pool width for the host-edge entropy stage."""
    if workers is not None:
        return max(1, workers)
    return max(1, min(8, os.cpu_count() or 1))


# ---------------------------------------------------------------------------
# Batch containers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompressedGroup:
    """Images sharing one padded bucket shape, compressed together."""
    qcoeffs: jnp.ndarray           # (n, bh/8, bw/8, 8, 8) int32
    indices: tuple                 # positions in the original input order
    orig_shapes: tuple             # per-image (H, W) before padding


@dataclasses.dataclass
class CompressedBatch:
    """Quantised DCT representation of a batch of grayscale images."""
    groups: list
    n_images: int
    quality: int
    transform: str
    cordic_config: cordic.CordicConfig
    stacked: bool                  # input was a single (B, H, W) array
    # (tables_policy, streams) — byte output depends on the table
    # policy but never on the packing or symbolize backend (enforced by
    # the --check-identical gate), so the cache keys on the former only
    _streams: tuple | None = dataclasses.field(
        default=None, repr=False, compare=False)

    def nbytes_estimate(self) -> float:
        """Total compressed size of the batch, in bytes.

        Two regimes, by how much work has been done:

        * **measured** — once :meth:`to_bytes_list` has materialised the
          entropy-coded streams, this returns their exact summed
          ``len()`` (the number every ratio in RESULTS.md is built on);
        * **estimated** — before that, it falls back to the device-side
          :func:`repro.core.quant.estimate_bits` proxy over the
          (bucket-padded) levels — the repo's one surviving size
          estimator, kept exactly for this pre-materialisation
          telemetry — which needs no host transfer or bit packing but
          overstates ragged batches (padding blocks count) and is only
          a model of the entropy coder.

        Callers that need the measured number unconditionally should
        call ``sum(len(s) for s in batch.to_bytes_list())`` and pay for
        the coding.
        """
        if self._streams is not None:
            return float(sum(len(s) for s in self._streams[1]))
        from repro.core import quant
        return sum(float(quant.estimate_bits(g.qcoeffs)) / 8.0
                   for g in self.groups)

    def _image_qcoeffs(self):
        """Per-image (gh, gw, 8, 8) levels in input order, cropped to
        each image's own block grid (ragged buckets carry padding
        blocks that belong to no image)."""
        out = [None] * self.n_images
        for g in self.groups:
            q = np.asarray(jax.device_get(g.qcoeffs))
            for j, (idx, (h, w)) in enumerate(zip(g.indices,
                                                  g.orig_shapes)):
                out[idx] = (q[j, :(h + 7) // 8, :(w + 7) // 8], (h, w))
        return out

    def to_bytes_list(self, pipelined: bool = True,
                      workers: int | None = None,
                      pack_backend: str = "auto",
                      tables: str = "auto",
                      symbolize_backend: str = "auto") -> list:
        """Entropy-code every image: list of ``DCTZ`` streams in input
        order (measured per-image byte sizes via ``len()``).

        In pipelined mode the host edge is overlapped with the device:
        groups are drained in dispatch order, and as soon as one
        group's levels land on the host its images are handed to a
        thread pool (NumPy releases the GIL inside the vectorised
        symbolisation/packing), while jax's async dispatch keeps the
        *next* group's DCT/quant running on the device.  The packing
        stage of every stream routes through the backend resolved once
        per call (:func:`repro.kernels.pack_bits.make_packer`): on TPU
        the workers enqueue the device scatter-pack per bucket so
        payload bytes leave the device ready-made; elsewhere packing is
        the in-worker NumPy reference.  Output bytes are identical
        across pipelining and packing backends; results are cached on
        the batch per table policy, so repeated calls (and
        :meth:`nbytes_estimate` afterwards) are free.

        Args:
            pipelined: overlap device compute with threaded host coding
                (False = the plain serial loop, for debugging/timing).
            workers: thread-pool width (default: up to 8, capped at the
                CPU count).
            pack_backend: bit-packing backend — "auto" (Pallas kernel
                on TPU, NumPy reference elsewhere), "pallas", "numpy".
            tables: Huffman table policy per stream ("auto" /
                "embedded" / "shared"), see
                :func:`repro.core.entropy.encode_qcoeffs`.
            symbolize_backend: symbolisation backend ("auto"/"pallas"/
                "numpy"), see
                :func:`repro.kernels.symbolize.make_symbolizer`.  On
                TPU, "auto" chains symbolise → codeword lookup →
                scatter-pack on device, so only histograms, headers and
                payload bytes cross to the host; elsewhere it is the
                fused dense NumPy pass.
        """
        from repro.core import entropy
        from repro.core.entropy import scan
        from repro.kernels import pack_bits, symbolize
        if self._streams is not None and self._streams[0] == tables:
            return list(self._streams[1])
        packer = pack_bits.make_packer(pack_backend)
        symbolizer = symbolize.make_symbolizer(symbolize_backend)
        if not pipelined:
            self._streams = (tables, [
                entropy.encode_qcoeffs(q, self.quality, self.transform,
                                       shape, tables=tables, packer=packer,
                                       symbolizer=symbolizer)
                for q, shape in self._image_qcoeffs()])
            return list(self._streams[1])
        # dispatch the zig-zag for every bucket up front: jax queues the
        # device work asynchronously, so bucket k+1 computes while the
        # pool below is still coding bucket k's streams
        zs = [scan.zigzag_scan(g.qcoeffs) for g in self.groups]
        jobs: list = [None] * self.n_images
        with concurrent.futures.ThreadPoolExecutor(
                _n_workers(workers)) as pool:
            for g, z in zip(self.groups, zs):
                # blocks only on THIS bucket's device work
                znp = np.asarray(jax.device_get(z))
                for j, (idx, (h, w)) in enumerate(zip(g.indices,
                                                      g.orig_shapes)):
                    gh, gw = (h + 7) // 8, (w + 7) // 8
                    jobs[idx] = pool.submit(
                        entropy.encode_zigzag_host,
                        znp[j, :gh, :gw].reshape(gh * gw, 64),
                        self.quality, self.transform, (h, w),
                        tables=tables, packer=packer,
                        symbolizer=symbolizer)
            self._streams = (tables, [f.result() for f in jobs])
        return list(self._streams[1])


# ---------------------------------------------------------------------------
# Device sharding
# ---------------------------------------------------------------------------

def _n_devices() -> int:
    return jax.local_device_count()


def _pad_rows(n: int, n_dev: int) -> int:
    """Bucketed batch size: next power of two, then up to a device multiple."""
    b = 1
    while b < n:
        b *= 2
    return b + (-b) % n_dev


def _bucket_dim(d: int) -> int:
    return d + (-d) % SHAPE_BUCKET


@functools.partial(jax.jit, static_argnames=("transform", "quality",
                                             "cordic_config", "n_dev"))
def _compress_sharded(imgs, transform, quality, cordic_config, n_dev):
    body = lambda x: codec.compress_batch_blocks(x, transform, quality,
                                                 cordic_config)
    if n_dev == 1:
        return body(imgs)
    return compat.shard_map(body, mesh_lib.make_data_mesh(n_dev),
                            in_specs=P("data"), out_specs=P("data"))(imgs)


@functools.partial(jax.jit, static_argnames=("transform", "quality",
                                             "cordic_config", "n_dev"))
def _decompress_sharded(qcoeffs, transform, quality, cordic_config, n_dev):
    body = lambda q: codec.decompress_batch_blocks(q, transform, quality,
                                                   cordic_config)
    if n_dev == 1:
        return body(qcoeffs)
    return compat.shard_map(body, mesh_lib.make_data_mesh(n_dev),
                            in_specs=P("data"), out_specs=P("data"))(qcoeffs)


@functools.partial(jax.jit, static_argnames=("transform", "quality",
                                             "cordic_config", "n_dev"))
def _fused_roundtrip_sharded(imgs, transform, quality, cordic_config, n_dev):
    from repro.kernels.fused_codec import fused_codec

    def body(x):
        rec, _ = fused_codec(x, quality=quality, transform=transform,
                             config=cordic_config)
        return rec
    if n_dev == 1:
        return body(imgs)
    return compat.shard_map(body, mesh_lib.make_data_mesh(n_dev),
                            in_specs=P("data"), out_specs=P("data"))(imgs)


def _run_batched(fn, arr: jnp.ndarray) -> jnp.ndarray:
    """Pad the leading axis to the batch bucket, run sharded, crop back."""
    n = arr.shape[0]
    n_dev = _n_devices()
    padded_n = _pad_rows(n, n_dev)
    if padded_n != n:
        arr = jnp.concatenate(
            [arr, jnp.zeros((padded_n - n, *arr.shape[1:]), arr.dtype)])
    return fn(arr, n_dev)[:n]


# ---------------------------------------------------------------------------
# Input normalisation (stacked vs ragged)
# ---------------------------------------------------------------------------

def _group_inputs(imgs):
    """Yield (stacked_padded_uint8, indices, orig_shapes) bucket groups.

    A stacked (B, H, W) array is one group padded to the 8-block like the
    single-image API.  A ragged list buckets each image's H/W up to
    SHAPE_BUCKET and groups equal buckets so B mixed sizes cost at most
    O(#distinct buckets) compilations, not O(B).
    """
    if isinstance(imgs, (np.ndarray, jnp.ndarray)):
        arr = jnp.asarray(imgs)
        if arr.ndim != 3:
            raise ValueError(f"stacked batch must be (B, H, W), "
                             f"got {arr.shape}")
        if arr.shape[0] == 0:
            raise ValueError("empty batch: nothing to compress")
        h, w = arr.shape[-2:]
        padded = codec.pad_to_block(arr)
        return [(padded, tuple(range(arr.shape[0])),
                 tuple((h, w) for _ in range(arr.shape[0])))], True

    if not len(imgs):
        raise ValueError("empty batch: nothing to compress")
    buckets: dict = {}
    for i, im in enumerate(imgs):
        im = jnp.asarray(im)
        if im.ndim != 2:
            raise ValueError(f"image {i} must be 2-D (H, W), got {im.shape}")
        h, w = im.shape
        key = (_bucket_dim(h), _bucket_dim(w))
        buckets.setdefault(key, []).append((i, im))

    groups = []
    for (bh, bw), members in buckets.items():
        padded = jnp.stack([
            jnp.pad(im, ((0, bh - im.shape[0]), (0, bw - im.shape[1])),
                    mode="edge") for _, im in members])
        groups.append((padded,
                       tuple(i for i, _ in members),
                       tuple(tuple(im.shape) for _, im in members)))
    return groups, False


def _reassemble(per_group: list, groups: list, n: int, stacked: bool):
    """Scatter per-group outputs back to original input order."""
    out = [None] * n
    for imgs_out, (_, indices, orig_shapes) in zip(per_group, groups):
        for j, (idx, (h, w)) in enumerate(zip(indices, orig_shapes)):
            out[idx] = imgs_out[j, :h, :w]
    if stacked:
        return jnp.stack(out)
    return out


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def compress_batch(imgs, quality: int = 50,
                   transform: codec.Transform = "exact",
                   cordic_config: cordic.CordicConfig = cordic.PAPER_CONFIG
                   ) -> CompressedBatch:
    """Compress a (B, H, W) batch or ragged list of grayscale images.

    Args:
        imgs: either a stacked (B, H, W) uint8/float array (one compiled
            shape) or a list of 2-D (H, W) images of mixed sizes; ragged
            sizes bucket up to multiples of :data:`SHAPE_BUCKET` and
            equal buckets are compressed together.
        quality: JPEG quality factor in [1, 100].
        transform: encoder transform, see :data:`repro.core.codec.Transform`.
        cordic_config: CORDIC config for ``transform == "cordic"``.

    Returns:
        A :class:`CompressedBatch` whose groups hold (n, bh/8, bw/8, 8, 8)
        int32 quantised levels per bucket shape, plus the bookkeeping to
        restore input order and crop back to original sizes.
    """
    groups, stacked = _group_inputs(imgs)
    fn = functools.partial(_compress_sharded, transform=transform,
                           quality=quality, cordic_config=cordic_config)
    out = []
    n = 0
    for padded, indices, orig_shapes in groups:
        q = _run_batched(
            lambda a, nd: fn(a, n_dev=nd), padded)
        out.append(CompressedGroup(qcoeffs=q, indices=indices,
                                   orig_shapes=orig_shapes))
        n += len(indices)
    return CompressedBatch(groups=out, n_images=n, quality=quality,
                           transform=transform, cordic_config=cordic_config,
                           stacked=stacked)


def decompress_batch(cb: CompressedBatch, mode: str = "standard"):
    """Reconstruct every image.  Returns (B, H, W) uint8 when the input was
    stacked, else a list of per-image uint8 arrays in input order.

    ``mode`` follows :func:`repro.core.codec.decompress`: "standard" decodes
    with the exact IDCT, "matched" with the encoder's adjoint.

    Args:
        cb: a :class:`CompressedBatch` from :func:`compress_batch`.
        mode: "standard" (exact IDCT, standards-compliant) or "matched"
            (encoder's adjoint; CORDIC angle error largely cancels).

    Returns:
        (B, H, W) uint8 array when the input was stacked, else a list of
        (H, W) uint8 arrays, each cropped to its original size.
    """
    dec_transform = "exact" if mode == "standard" else cb.transform
    fn = functools.partial(_decompress_sharded, transform=dec_transform,
                           quality=cb.quality,
                           cordic_config=cb.cordic_config)
    per_group = [_run_batched(lambda a, nd: fn(a, n_dev=nd), g.qcoeffs)
                 for g in cb.groups]
    groups = [(None, g.indices, g.orig_shapes) for g in cb.groups]
    return _reassemble(per_group, groups, cb.n_images, cb.stacked)


@functools.partial(jax.jit)
def _psnr_vec(orig: jnp.ndarray, rec: jnp.ndarray) -> jnp.ndarray:
    return jax.vmap(metrics.psnr)(orig, rec)


def _fused_ok(transform: str, mode: str) -> bool:
    return jax.default_backend() == "tpu" and (
        transform == "exact" or mode == "matched")


def roundtrip_batch(imgs, quality: int = 50,
                    transform: codec.Transform = "exact",
                    cordic_config: cordic.CordicConfig = cordic.PAPER_CONFIG,
                    mode: str = "standard", with_psnr: bool = True):
    """Batched form of :func:`repro.core.codec.roundtrip`.

    On TPU the one-pass fused Pallas kernel serves compatible
    (transform, mode) combinations — ``transform == "exact"`` or
    ``mode == "matched"`` (the kernel reconstructs with the matched
    adjoint); the staged compress+decompress path is the CPU fallback
    and the bit-exact reference (docs/architecture.md).

    Args:
        imgs: stacked (B, H, W) array or ragged list of (H, W) images,
            as in :func:`compress_batch`.
        quality: JPEG quality factor in [1, 100].
        transform: encoder transform ("exact"/"cordic"/"loeffler").
        cordic_config: CORDIC config for ``transform == "cordic"``.
        mode: decode mode, see :func:`decompress_batch`.
        with_psnr: also score each reconstruction against its input.

    Returns:
        ``(reconstructed, psnr)``: ``reconstructed`` is (B, H, W) uint8
        for stacked input (a list for ragged input); ``psnr`` is a (B,)
        numpy array of dB values, or None when ``with_psnr=False``.
    """
    if _fused_ok(transform, mode):
        groups, stacked = _group_inputs(imgs)
        fn = functools.partial(_fused_roundtrip_sharded, transform=transform,
                               quality=quality, cordic_config=cordic_config)
        per_group = [_run_batched(lambda a, nd: fn(a, n_dev=nd), padded)
                     for padded, _, _ in groups]
        n = sum(len(g[1]) for g in groups)
        rec = _reassemble(per_group, groups, n, stacked)
    else:
        cb = compress_batch(imgs, quality, transform, cordic_config)
        rec = decompress_batch(cb, mode=mode)

    if not with_psnr:
        return rec, None
    if isinstance(rec, list):
        psnr = np.array([float(metrics.psnr(jnp.asarray(im), r))
                         for im, r in zip(imgs, rec)])
    else:
        psnr = np.asarray(_psnr_vec(jnp.asarray(imgs), rec))
    return rec, psnr


# ---------------------------------------------------------------------------
# Entropy-coded byte path (real bytes per image)
# ---------------------------------------------------------------------------

def encode_batch(imgs, quality: int = 50,
                 transform: codec.Transform = "exact",
                 cordic_config: cordic.CordicConfig = cordic.PAPER_CONFIG,
                 pipelined: bool = True, workers: int | None = None,
                 pack_backend: str = "auto", tables: str = "auto",
                 symbolize_backend: str = "auto") -> list:
    """Compress a batch all the way to entropy-coded ``DCTZ`` streams.

    The array half (DCT + quantise) runs the sharded
    :func:`compress_batch` path unchanged; the per-image entropy stage
    happens at the host edge with its packing stage routed per backend.
    In pipelined mode (default) the two halves are overlapped: jax's
    async dispatch queues *every* bucket's device work up front, and a
    thread pool entropy-codes bucket *k* while the device is still
    crunching bucket *k+1* (:meth:`CompressedBatch.to_bytes_list`).
    Byte output is identical across modes and packing backends.

    Args:
        imgs: stacked (B, H, W) array or ragged list of (H, W) images,
            as in :func:`compress_batch`.
        quality: JPEG quality factor in [1, 100].
        transform: encoder transform ("exact"/"cordic"/"loeffler").
        cordic_config: CORDIC config for ``transform == "cordic"``.
        pipelined: overlap device compute with threaded host coding.
        workers: thread-pool width for the host edge (None = auto).
        pack_backend: bit-packing backend ("auto"/"pallas"/"numpy"),
            see :meth:`CompressedBatch.to_bytes_list`.
        tables: Huffman table policy ("auto"/"embedded"/"shared").
        symbolize_backend: symbolisation backend ("auto"/"pallas"/
            "numpy"), see :meth:`CompressedBatch.to_bytes_list`.  On
            TPU, "auto" keeps encode device-resident from pixels to
            packed bits.

    Returns:
        List of ``bytes`` (one ``DCTZ`` stream per image, input order);
        each is bit-identical to ``core.codec.compress(img).to_bytes()``
        under the same table policy.
    """
    cb = compress_batch(imgs, quality, transform, cordic_config)
    return cb.to_bytes_list(pipelined=pipelined, workers=workers,
                            pack_backend=pack_backend, tables=tables,
                            symbolize_backend=symbolize_backend)


def _hydrate_tables(segments) -> None:
    """Process-pool initializer: re-register shared Huffman tables.

    A spawned worker re-imports :mod:`repro.core.entropy.huffman`,
    which re-creates ``DEFAULT_TABLES`` with only the module's built-in
    ids — any table the parent registered at runtime would be unknown
    there, and v2 streams referencing it would fail to decode.  The
    parent serialises its registry as ``(id, segment)`` pairs
    (:meth:`CanonicalTable.to_segment`); workers re-register whatever
    they are missing.
    """
    from repro.core.entropy import huffman
    for tid, seg in segments:
        if not huffman.DEFAULT_TABLES.known(tid):
            table, _ = huffman.CanonicalTable.from_segment(seg)
            huffman.DEFAULT_TABLES.register(tid, table)


def decode_batch(blobs, mode: str = "standard",
                 pipelined: bool = True,
                 workers: int | None = None,
                 executor: str = "thread",
                 unpack_backend: str = "auto") -> list:
    """Decode a list of ``DCTZ`` streams through the sharded array path.

    Streams are entropy-decoded on the host edge — concurrently, in
    pipelined mode — then grouped by block-grid shape + quality +
    decode transform, and each group runs one sharded ``decompress``
    jit; the byte path re-joins the array path right after the
    bitstream boundary.

    The entropy decode itself routes per ``unpack_backend``, mirroring
    ``encode_batch(pack_backend=)``: on TPU, "auto" resolves to the
    Pallas speculative-decode kernel (:mod:`repro.kernels.unpack_bits`)
    and the pipelined pool overlaps each stream's device unpack with
    the host-side parse/CRC and dequant dispatch of its neighbours;
    elsewhere it keeps the LUT walk.  The pipelined host edge defaults
    to a **thread** pool: the LUT precompute releases the GIL, but the
    per-symbol chain walk is Python, so threads stop scaling once that
    walk dominates.  On many-core hosts, ``executor="process"`` opts
    into a spawn-based process pool instead — each worker decodes whole
    streams in its own interpreter (with the LUT walk,
    ``decode_zigzag_host`` and everything under it import without jax,
    so workers start cheap; runtime-registered shared tables are
    re-registered in each worker on init).  Output is identical across
    all modes and backends; the process pool only pays off when the
    batch is large enough to amortise worker startup.

    Args:
        blobs: iterable of ``DCTZ`` streams (``bytes``).
        mode: "standard" (exact IDCT) or "matched" (stored transform's
            adjoint), as in :func:`decompress_batch`.
        pipelined: entropy-decode streams concurrently instead of
            serially (identical output either way).
        workers: pool width for the host edge (None = auto).
        executor: "thread" (default) or "process" (opt-in GIL-free
            fallback for the Python-bound decode walk).
        unpack_backend: entropy-unpack backend ("auto"/"pallas"/
            "numpy"), see :func:`repro.kernels.unpack_bits.unpack_bits`.
            "auto" keeps the LUT walk off-TPU; "pallas" forces the
            routed kernel (interpret mode off-TPU).

    Returns:
        List of (H, W) uint8 reconstructions in input order, each
        bit-identical to the single-image
        ``codec.decompress(CompressedImage.from_bytes(blob), mode)``.

    Raises:
        repro.core.entropy.BitstreamError: any malformed stream (the
        whole call fails; no partial results).
    """
    from repro.core import entropy
    from repro.core.entropy import huffman, scan
    from repro.kernels import unpack_bits
    if executor not in ("thread", "process"):
        raise ValueError(f"unknown executor {executor!r}; expected "
                         f"'thread' or 'process'")
    unpacker = unpack_bits.make_unpacker(unpack_backend)
    decode_one = entropy.decode_zigzag_host if unpacker is None else \
        functools.partial(entropy.decode_zigzag_host, unpacker=unpacker)
    blobs = list(blobs)
    if not blobs:
        raise ValueError("empty batch: nothing to decode")
    if pipelined and len(blobs) > 1:
        # each stream's entropy decode is independent host/device work
        if executor == "process":
            # spawn, not fork: the parent holds live jax/XLA threads.
            # Workers re-import huffman, so tables registered at
            # runtime must be shipped over and re-registered on init.
            segs = tuple(
                (tid, huffman.DEFAULT_TABLES.get(tid).to_segment())
                for tid in huffman.DEFAULT_TABLES.ids())
            ctx = multiprocessing.get_context("spawn")
            with concurrent.futures.ProcessPoolExecutor(
                    _n_workers(workers), mp_context=ctx,
                    initializer=_hydrate_tables,
                    initargs=(segs,)) as pool:
                decoded = list(pool.map(decode_one, blobs))
        else:
            with concurrent.futures.ThreadPoolExecutor(
                    _n_workers(workers)) as pool:
                decoded = list(pool.map(decode_one, blobs))
    else:
        decoded = [decode_one(b) for b in blobs]

    buckets: dict = {}
    for i, (z, hdr) in enumerate(decoded):
        dec_transform = "exact" if mode == "standard" else hdr["transform"]
        grid = ((hdr["height"] + 7) // 8, (hdr["width"] + 7) // 8)
        key = (grid, hdr["quality"], dec_transform)
        buckets.setdefault(key, []).append(i)

    out = [None] * len(blobs)
    for ((gh, gw), quality, dec_transform), members in buckets.items():
        stackz = jnp.stack([jnp.asarray(decoded[i][0]) for i in members])
        # device half of the inverse: un-zig-zag the whole group at once
        stackq = scan.zigzag_unscan(stackz).reshape(-1, gh, gw, 8, 8)
        fn = functools.partial(_decompress_sharded,
                               transform=dec_transform, quality=quality,
                               cordic_config=cordic.PAPER_CONFIG)
        rec = _run_batched(lambda a, nd: fn(a, n_dev=nd), stackq)
        for j, i in enumerate(members):
            hdr = decoded[i][1]
            out[i] = rec[j, :hdr["height"], :hdr["width"]]
    return out
