"""Resilience primitives for the async codec service (jax-free).

The serving front end (:mod:`repro.serve.service`) survives real
failures with four cooperating mechanisms, all configured through
:class:`ResilienceConfig` and all **disabled by default** so the
baseline service semantics (docs/serving.md) are unchanged until a
deployment opts in:

* **per-attempt timeout** — an engine call that exceeds ``timeout_s``
  is abandoned (the worker thread keeps running; size
  ``engine_concurrency`` accordingly) and treated as a retryable
  failure,
* **bounded retry** (:class:`RetryPolicy`) — failed requests re-enter
  their bucket queue after an exponential backoff with *decorrelated
  jitter* (the AWS architecture-blog variant: each delay is drawn
  uniformly from ``[base, 3 x previous]``, capped), guarded by a
  **token-bucket retry budget** (:class:`TokenBucket`) so a persistent
  outage cannot amplify offered load into a retry storm,
* a **failure-rate circuit breaker** (:class:`CircuitBreaker`) over the
  engine path — ``closed`` counts outcomes in a sliding window and
  trips ``open`` at a failure rate; ``open`` fast-fails submits with a
  typed :class:`CircuitOpen` reject and blocks dispatch until
  ``reset_timeout_s`` elapses; ``half_open`` lets a bounded number of
  probe batches through and closes after consecutive successes (every
  transition is recorded for observability and for the chaos bench's
  CI gate),
* **graceful degradation** (:class:`DegradationController`) — under
  sustained queue pressure the service first *downshifts* quality (a
  cheaper encode drains queues faster and the payload stays useful)
  and shrinks deadline-urgent batches (a smaller batch completes
  sooner), and only sheds load when the existing backpressure bounds
  engage; degrade events are counted in ``ServiceStats`` and
  degraded-served stays a subset of served.

Everything here is pure stdlib (no jax, no numpy) so the property and
unit tests drive thousands of synthetic schedules directly, exactly
like :mod:`repro.serve.queueing`.
"""

from __future__ import annotations

import dataclasses
import math

from repro.serve import admission

# Circuit-breaker states.
CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


class CircuitOpen(admission.RejectedError):
    """Typed reject: the engine-path circuit breaker is open.

    A :class:`~repro.serve.admission.RejectedError` with reason
    :data:`repro.serve.admission.CIRCUIT_OPEN`, so every existing
    conservation invariant (submitted == served + rejected + failed)
    and reject-accounting path treats breaker rejects like any other
    load-shedding decision.
    """

    def __init__(self, detail: str = ""):
        super().__init__(admission.CIRCUIT_OPEN, detail)


class TokenBucket:
    """Deterministic token bucket (the retry budget).

    Refills at ``rate`` tokens/second up to ``burst``; :meth:`take`
    consumes one token if available.  Driven entirely by caller-passed
    clock values so tests are exact.

    Args:
        rate: tokens added per second (<= 0 disables refill).
        burst: bucket capacity (also the initial fill).
    """

    def __init__(self, rate: float, burst: float):
        if burst < 0:
            raise ValueError(f"burst must be >= 0, got {burst}")
        self.rate = rate
        self.burst = burst
        self._tokens = float(burst)
        self._last: float | None = None

    def _refill(self, now: float) -> None:
        if self._last is not None and self.rate > 0 and now > self._last:
            self._tokens = min(self.burst,
                               self._tokens + (now - self._last) * self.rate)
        self._last = now

    def take(self, now: float, n: float = 1.0) -> bool:
        """Consume ``n`` tokens at time ``now``; False = budget empty."""
        self._refill(now)
        if self._tokens >= n:
            self._tokens -= n
            return True
        return False

    def available(self, now: float) -> float:
        """Tokens available at ``now`` (after refill accounting)."""
        self._refill(now)
        return self._tokens


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with decorrelated-jitter backoff and a budget.

    Attributes:
        max_attempts: total attempts per request (1 = retries off).
        backoff_base_s: floor of every backoff draw.
        backoff_cap_s: ceiling of every backoff draw.
        budget_rate: retry-budget tokens per second (a global bound on
            retries/s across all requests, so an outage cannot turn
            offered load into an amplified retry storm).
        budget_burst: retry-budget bucket capacity.
    """
    max_attempts: int = 1
    backoff_base_s: float = 0.005
    backoff_cap_s: float = 0.5
    budget_rate: float = 10.0
    budget_burst: float = 20.0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, "
                             f"got {self.max_attempts}")
        if self.backoff_cap_s < self.backoff_base_s:
            raise ValueError("backoff_cap_s must be >= backoff_base_s")

    @property
    def enabled(self) -> bool:
        return self.max_attempts > 1

    def make_budget(self) -> TokenBucket:
        return TokenBucket(self.budget_rate, self.budget_burst)

    def backoff_s(self, prev_s: float, rng) -> float:
        """Next backoff: decorrelated jitter.

        ``min(cap, uniform(base, 3 x prev))`` — each delay is drawn
        from a range anchored on the *previous* delay, which spreads
        retry times apart (decorrelates clients) while still growing
        exponentially in expectation.

        Args:
            prev_s: the previous delay (pass 0.0 before the first
                retry; the draw then starts at ``backoff_base_s``).
            rng: a ``random.Random`` (seeded by the service).
        """
        hi = max(self.backoff_base_s, 3.0 * prev_s)
        return min(self.backoff_cap_s,
                   rng.uniform(self.backoff_base_s, hi))


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """Knobs for :class:`CircuitBreaker`.

    Attributes:
        window: sliding window of recent engine-call outcomes the
            failure rate is computed over.
        min_calls: volume threshold — never trip on fewer outcomes
            (a single failure out of one call is not a 100% outage).
        failure_threshold: failure rate in (0, 1] that trips open.
        reset_timeout_s: open -> half-open delay.
        half_open_max_calls: concurrent probe calls allowed half-open.
        half_open_successes: consecutive probe successes that close.
    """
    window: int = 16
    min_calls: int = 4
    failure_threshold: float = 0.5
    reset_timeout_s: float = 1.0
    half_open_max_calls: int = 1
    half_open_successes: int = 2

    def __post_init__(self):
        if self.window < 1 or self.min_calls < 1:
            raise ValueError("window and min_calls must be >= 1")
        if not 0.0 < self.failure_threshold <= 1.0:
            raise ValueError(f"failure_threshold must be in (0, 1], "
                             f"got {self.failure_threshold}")
        if self.half_open_max_calls < 1 or self.half_open_successes < 1:
            raise ValueError("half_open_max_calls and "
                             "half_open_successes must be >= 1")


class CircuitBreaker:
    """Failure-rate circuit breaker with an explicit transition log.

    States: :data:`CLOSED` (counting outcomes), :data:`OPEN` (engine
    path blocked until ``reset_timeout_s``), :data:`HALF_OPEN` (bounded
    probes).  All methods take the clock value explicitly and the class
    is event-loop-confined in the service (no locking).

    Attributes:
        transitions: ``(at, from_state, to_state)`` tuples, in order —
            the observable record the chaos bench's CI gate checks the
            ``closed -> open -> half_open -> closed`` cycle against.
    """

    def __init__(self, config: BreakerConfig):
        self.config = config
        self._state = CLOSED
        self._outcomes: list = []        # sliding window, True = failure
        self._opened_at = -math.inf
        self._probes_inflight = 0
        self._probe_successes = 0
        self.transitions: list = []

    # -- state ------------------------------------------------------------

    def state(self, now: float) -> str:
        """Current state, applying a due open -> half-open transition."""
        self._maybe_half_open(now)
        return self._state

    def _transition(self, now: float, to: str) -> None:
        self.transitions.append((now, self._state, to))
        self._state = to

    def _maybe_half_open(self, now: float) -> None:
        if (self._state == OPEN
                and now - self._opened_at >= self.config.reset_timeout_s):
            self._transition(now, HALF_OPEN)
            self._probes_inflight = 0
            self._probe_successes = 0

    # -- admission / dispatch gates ---------------------------------------

    def admission_open(self, now: float) -> bool:
        """May a new request be *admitted*? False only while OPEN.

        Half-open admits (the request queues; the dispatch budget
        below bounds how many reach the engine as probes).
        """
        return self.state(now) != OPEN

    def dispatch_budget(self, now: float) -> int | None:
        """How many engine calls may start now; None = unlimited.

        CLOSED: unlimited.  OPEN: 0 (nothing dispatches; queued work
        waits for half-open or the deadline sweep).  HALF_OPEN: the
        remaining probe slots.
        """
        s = self.state(now)
        if s == CLOSED:
            return None
        if s == OPEN:
            return 0
        return max(0, self.config.half_open_max_calls
                   - self._probes_inflight)

    def retry_after_s(self, now: float) -> float:
        """Seconds until OPEN turns HALF_OPEN (0 when not OPEN)."""
        if self.state(now) != OPEN:
            return 0.0
        return max(0.0, self._opened_at + self.config.reset_timeout_s
                   - now)

    def on_dispatch(self, now: float) -> None:
        """An engine call is starting (counts half-open probes)."""
        if self.state(now) == HALF_OPEN:
            self._probes_inflight += 1

    # -- outcomes ---------------------------------------------------------

    def record_success(self, now: float) -> None:
        if self.state(now) == HALF_OPEN:
            self._probes_inflight = max(0, self._probes_inflight - 1)
            self._probe_successes += 1
            if self._probe_successes >= self.config.half_open_successes:
                self._transition(now, CLOSED)
                self._outcomes = []
            return
        self._push(False)

    def record_failure(self, now: float) -> None:
        s = self.state(now)
        if s == HALF_OPEN:
            # a failed probe re-opens immediately
            self._transition(now, OPEN)
            self._opened_at = now
            return
        if s == OPEN:      # stragglers from before the trip
            return
        self._push(True)
        n = len(self._outcomes)
        if n >= self.config.min_calls:
            rate = sum(self._outcomes) / n
            if rate >= self.config.failure_threshold:
                self._transition(now, OPEN)
                self._opened_at = now

    def _push(self, failed: bool) -> None:
        self._outcomes.append(failed)
        if len(self._outcomes) > self.config.window:
            del self._outcomes[0]

    # -- introspection ----------------------------------------------------

    def snapshot(self, now: float) -> dict:
        """JSON-friendly view (state, window fill, transition count)."""
        return {"state": self.state(now),
                "window_outcomes": len(self._outcomes),
                "window_failures": sum(self._outcomes),
                "transitions": len(self.transitions)}


@dataclasses.dataclass(frozen=True)
class DegradeConfig:
    """Knobs for :class:`DegradationController`.

    Attributes:
        quality_caps: per-level quality ceiling; index 0 is the
            healthy level and must be 100 (no cap).  Length defines
            the number of degradation levels.
        urgent_batch_caps: per-level cap on deadline-*urgent* batch
            sizes (None = no cap).  A smaller urgent batch completes
            sooner, trading occupancy for SLO attainment under
            overload.
        enter_pressure: queue-pressure level (0..1, the fullest
            bucket's depth fraction) that starts escalating.
        exit_pressure: pressure below which levels decay.
        sustain_s: pressure must persist this long before escalating
            one level (debounces bursts).
        cool_s: pressure must stay below ``exit_pressure`` this long
            before de-escalating one level.
    """
    quality_caps: tuple = (100, 60, 35)
    urgent_batch_caps: tuple = (None, 4, 2)
    enter_pressure: float = 0.75
    exit_pressure: float = 0.25
    sustain_s: float = 0.050
    cool_s: float = 0.200

    def __post_init__(self):
        if len(self.quality_caps) != len(self.urgent_batch_caps):
            raise ValueError("quality_caps and urgent_batch_caps must "
                             "have equal length (one entry per level)")
        if not self.quality_caps or self.quality_caps[0] != 100:
            raise ValueError("quality_caps[0] must be 100 (level 0 is "
                             "the undegraded service)")
        if not 0.0 <= self.exit_pressure <= self.enter_pressure <= 1.0:
            raise ValueError("need 0 <= exit_pressure <= enter_pressure "
                             "<= 1")


class DegradationController:
    """Hysteretic overload-level tracker driving graceful degradation.

    :meth:`observe` folds a pressure sample (0..1) in and returns the
    current level; escalation needs pressure >= ``enter_pressure``
    sustained for ``sustain_s``, decay needs pressure <
    ``exit_pressure`` for ``cool_s`` — so a single burst or a single
    idle poll does not flap the level.  Level 0 is the undegraded
    service; each level above caps quality
    (:meth:`quality_cap`) and shrinks deadline-urgent batches
    (:meth:`urgent_cap`).
    """

    def __init__(self, config: DegradeConfig):
        self.config = config
        self.level = 0
        self._hot_since: float | None = None
        self._cool_since: float | None = None
        self.escalations = 0

    @property
    def max_level(self) -> int:
        return len(self.config.quality_caps) - 1

    def observe(self, now: float, pressure: float) -> int:
        """Fold one pressure sample in; returns the (new) level."""
        cfg = self.config
        if pressure >= cfg.enter_pressure:
            self._cool_since = None
            if self._hot_since is None:
                self._hot_since = now
            if (now - self._hot_since >= cfg.sustain_s
                    and self.level < self.max_level):
                self.level += 1
                self.escalations += 1
                self._hot_since = now    # next level needs its own dwell
        elif pressure < cfg.exit_pressure:
            self._hot_since = None
            if self._cool_since is None:
                self._cool_since = now
            if now - self._cool_since >= cfg.cool_s and self.level > 0:
                self.level -= 1
                self._cool_since = now
        else:                            # hysteresis band: hold level
            self._hot_since = None
            self._cool_since = None
        return self.level

    def quality_cap(self) -> int:
        """Quality ceiling at the current level (100 = no cap)."""
        return self.config.quality_caps[self.level]

    def urgent_cap(self) -> int | None:
        """Deadline-urgent batch cap at the current level (None = off)."""
        return self.config.urgent_batch_caps[self.level]


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """The service's resilience envelope; defaults are all no-ops.

    Attributes:
        timeout_s: per-attempt engine-call timeout (None = none).  A
            timed-out attempt is abandoned and counted as a retryable
            failure; its worker thread keeps running until the engine
            returns, so pair timeouts with ``engine_concurrency`` > 1
            when the engine can actually stall.
        retry: :class:`RetryPolicy` (``max_attempts=1`` = off).
        breaker: :class:`BreakerConfig`, or None for no breaker.
        degrade: :class:`DegradeConfig`, or None for no degradation.
        validate_payload: optional ``bytes -> bool`` integrity check
            applied to every engine-produced payload (e.g.
            :func:`repro.serve.chaos.dctz_crc_ok` for ``DCTZ``
            streams); a failing payload is a retryable per-request
            corruption failure, never served.
        seed: RNG seed for backoff jitter.
    """
    timeout_s: float | None = None
    retry: RetryPolicy = RetryPolicy()
    breaker: BreakerConfig | None = None
    degrade: DegradeConfig | None = None
    validate_payload: object = None
    seed: int = 0

    @property
    def enabled(self) -> bool:
        """True when any mechanism is active (used to skip overhead)."""
        return (self.timeout_s is not None or self.retry.enabled
                or self.breaker is not None or self.degrade is not None
                or self.validate_payload is not None)
