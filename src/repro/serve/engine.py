"""Batched serving engine: prefill + decode with KV cache.

``prefill`` writes the prompt into the cache in one pass (the decode-path
dynamic_update_slice with seq>1); ``decode_step`` appends one token for the
whole batch.  Optional DCT KV-cache compression (serve/kv_compress.py)
re-encodes frozen 64-step blocks of the cache in the frequency domain.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import registry


@dataclasses.dataclass
class ServeConfig:
    """Serving-time knobs (decoupled from the architecture config).

    Attributes:
        max_len: KV-cache capacity in tokens; prompt + generated tokens
            must fit.
        temperature: sampling temperature; 0 means greedy argmax.
        kv_dct_keep: DCT KV-cache compression — coefficients kept per
            64-step block (see :mod:`repro.serve.kv_compress`); 0
            disables compression.
    """
    max_len: int = 2048
    temperature: float = 0.0      # 0 => greedy
    kv_dct_keep: int = 0          # 0 => off; else coefficients kept of 64


def make_prefill(cfg: ArchConfig):
    """Build the jitted prefill step for one architecture.

    Prefill writes the whole prompt into the KV cache in one pass (the
    decode path's dynamic_update_slice with seq > 1).

    Args:
        cfg: architecture config (layer count, dims, cache layout).

    Returns:
        ``prefill(params, tokens, cache) -> (last_logits, cache)``:
        ``tokens`` is (B, P) int32; ``last_logits`` is (B, vocab) for
        the final prompt position; ``cache`` holds positions [0, P).
    """
    @jax.jit
    def prefill(params, tokens, cache):
        batch = {"tokens": tokens,
                 "cache_index": jnp.zeros((), jnp.int32)}
        logits, cache, _ = registry.apply(cfg, params, batch, mode="decode",
                                          cache=cache)
        return logits[:, -1], cache
    return prefill


def make_decode_step(cfg: ArchConfig, temperature: float = 0.0):
    """Build the jitted single-token decode step for one architecture.

    Args:
        cfg: architecture config (must match the cache's).
        temperature: sampling temperature baked into the jit; 0 means
            greedy argmax (the ``key`` argument is then unused).

    Returns:
        ``decode_step(params, tokens, cache, cache_index, key) ->
        (next_token, cache)``: ``tokens`` is (B, 1) int32 (the previous
        step's output), ``cache_index`` a scalar int32 write position,
        ``key`` a PRNG key; ``next_token`` is (B,) int32.
    """
    @jax.jit
    def decode_step(params, tokens, cache, cache_index, key):
        batch = {"tokens": tokens, "cache_index": cache_index}
        logits, cache, _ = registry.apply(cfg, params, batch, mode="decode",
                                          cache=cache)
        last = logits[:, -1].astype(jnp.float32)
        if temperature > 0.0:
            nxt = jax.random.categorical(key, last / temperature, axis=-1)
        else:
            nxt = jnp.argmax(last, axis=-1)
        return nxt.astype(jnp.int32), cache
    return decode_step


def generate(cfg: ArchConfig, params, prompts: jnp.ndarray, max_new: int,
             serve_cfg: ServeConfig = ServeConfig(), seed: int = 0):
    """Greedy/temperature generation for a whole batch.

    Runs one prefill over the prompts, then ``max_new - 1`` decode
    steps, all through the jits above (one compile per shape).

    Args:
        cfg: architecture config; selects the model from the registry.
        params: model parameters as produced by
            ``repro.models.registry.init_params(cfg, ...)``.
        prompts: (B, P) int32 prompt tokens (already padded to one
            length).
        max_new: number of tokens to generate, >= 1.
        serve_cfg: serving knobs (cache size, temperature, KV
            compression) — see :class:`ServeConfig`.
        seed: PRNG seed for temperature sampling.

    Returns:
        (B, max_new) int32 generated tokens (prompt not included).
    """
    b, p = prompts.shape
    cache = registry.init_cache(cfg, batch=b, max_len=serve_cfg.max_len)
    prefill = make_prefill(cfg)
    step_fn = make_decode_step(cfg, serve_cfg.temperature)
    logits, cache = prefill(params, prompts, cache)
    nxt = jnp.argmax(logits.astype(jnp.float32), axis=-1).astype(jnp.int32)

    out = [nxt]
    key = jax.random.key(seed)
    for i in range(max_new - 1):
        key, sub = jax.random.split(key)
        nxt, cache = step_fn(params, nxt[:, None], cache,
                             jnp.asarray(p + i, jnp.int32), sub)
        out.append(nxt)
    return jnp.stack(out, axis=1)
