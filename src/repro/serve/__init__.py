"""serve substrate."""
