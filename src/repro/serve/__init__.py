"""Serving layer: batched codec engine + async front end.

* :mod:`repro.serve.codec_engine` — batched/multi-device encode and
  decode over the core codec (shape buckets, pipelined entropy edge,
  device-routed pack/unpack).
* :mod:`repro.serve.service` — asyncio :class:`~repro.serve.service.
  CodecService` with deadline-aware adaptive batching, bounded-queue
  backpressure, per-tenant quality tiers and a hot-stream cache.
* :mod:`repro.serve.queueing` / :mod:`repro.serve.admission` — the
  jax-free planner core (per-bucket FIFO queues, dispatch triggers,
  admission control) the property-test suite drives directly.
* :mod:`repro.serve.resilience` — the jax-free failure-handling
  envelope (per-attempt timeouts, budget-guarded retries with
  decorrelated-jitter backoff, a failure-rate circuit breaker,
  graceful quality degradation), off by default.
* :mod:`repro.serve.chaos` — deterministic seeded fault injection
  (scripted exceptions, latency spikes, payload byte flips, worker
  death) shared by the test suite and the ``service_chaos`` bench.

See docs/serving.md for the serving semantics, SLO knobs and the
failure model.
"""
