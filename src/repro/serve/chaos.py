"""Deterministic fault injection for the codec service (jax-free).

Generalises the ad-hoc ``FlakyEngine`` test stub into a scripted,
seeded fault-plan engine usable both from the unit/property tests
(re-exported as ``tests/helpers/faults.py``) and from the
``service_chaos`` traffic bench (:mod:`repro.bench.cases`):

* a :class:`FaultPlan` is a sequence of :class:`FaultPhase` windows
  indexed by **engine-call number**, not wall time — the i-th engine
  call always sees the same phase and the same RNG draws, so a chaos
  run is bit-reproducible regardless of scheduling jitter,
* :class:`ChaosEngine` wraps any engine callable and, per call, may
  raise a scripted exception (:class:`InjectedFault`), sleep through a
  latency spike, corrupt returned payloads via byte flips (caught
  downstream by the ``DCTZ`` CRC — :func:`dctz_crc_ok`), or kill the
  executor worker with :class:`WorkerKilled` (a ``SystemExit``
  subclass, exercising the service's BaseException containment).

Each injected event is recorded in :attr:`ChaosEngine.events` so tests
and the bench gate can assert that every scripted fault kind actually
fired.
"""

from __future__ import annotations

import dataclasses
import math
import random
import threading
import time


class InjectedFault(RuntimeError):
    """A scripted engine exception (distinct from real engine bugs)."""


class WorkerKilled(SystemExit):
    """Scripted executor-worker death.

    ``SystemExit`` subclasses ``BaseException`` (not ``Exception``), so
    this exercises the service's containment of non-``Exception``
    escapes from the engine thread — ``concurrent.futures`` delivers it
    through the work-item future like any other exception, and the
    dispatch loop must treat it as a failed batch, not crash.
    """


@dataclasses.dataclass(frozen=True)
class FaultPhase:
    """One window of scripted faults over an engine-call index range.

    Applies to calls with ``start <= call_index < stop``.  Rates are
    independent per-call probabilities; draws come from the plan's
    seeded RNG in call-index order, so a given (plan, seed) always
    injects the same events at the same calls.

    Attributes:
        start: first engine-call index the phase covers (inclusive).
        stop: end of the range (exclusive; ``math.inf`` = open-ended).
        fail_rate: probability the call raises ``exc_type``.
        exc_type: exception class raised on a scripted failure.
        latency_s: extra sleep injected on a latency spike.
        latency_rate: probability of a latency spike.
        corrupt_rate: probability each *returned payload* gets one
            byte flipped (``bytes`` results only; non-byte results
            pass through untouched).
        kill_rate: probability the call raises :class:`WorkerKilled`.
    """
    start: int
    stop: float = math.inf
    fail_rate: float = 0.0
    exc_type: type = InjectedFault
    latency_s: float = 0.0
    latency_rate: float = 0.0
    corrupt_rate: float = 0.0
    kill_rate: float = 0.0

    def __post_init__(self):
        if self.start < 0 or self.stop < self.start:
            raise ValueError(f"bad phase range [{self.start}, {self.stop})")
        for name in ("fail_rate", "latency_rate", "corrupt_rate",
                     "kill_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded sequence of fault phases over engine-call indexes.

    Phases may overlap; the *first* phase covering a call index wins.
    Calls covered by no phase run clean.
    """
    phases: tuple
    seed: int = 0

    def for_call(self, idx: int) -> FaultPhase | None:
        for p in self.phases:
            if p.start <= idx < p.stop:
                return p
        return None


class ChaosEngine:
    """Wrap an engine callable with a deterministic fault plan.

    Call signature matches ``codec_engine.encode_batch``:
    ``engine(images, quality, ...) -> list[bytes]``.  Thread-safe: the
    call index is assigned and all RNG draws for that call are made
    under one lock, in call order, so concurrency never perturbs which
    call sees which fault.

    Attributes:
        calls: total engine calls observed.
        events: ``(call_index, kind)`` tuples for every injected event,
            kind in {"fail", "latency", "corrupt", "kill"}.
    """

    def __init__(self, inner, plan: FaultPlan):
        self.inner = inner
        self.plan = plan
        self.calls = 0
        self.events: list = []
        self._rng = random.Random(plan.seed)
        self._lock = threading.Lock()

    def event_counts(self) -> dict:
        """Injected events by kind (for reporting and gates)."""
        counts: dict = {}
        for _, kind in self.events:
            counts[kind] = counts.get(kind, 0) + 1
        return counts

    def __call__(self, images, quality, **kwargs):
        # Assign the call index and make every RNG draw for this call
        # under the lock, so (plan, seed) fully determines the faults
        # even when engine_concurrency > 1.
        with self._lock:
            idx = self.calls
            self.calls += 1
            phase = self.plan.for_call(idx)
            fail = spike = kill = False
            corrupt: list = []
            if phase is not None:
                fail = (phase.fail_rate > 0
                        and self._rng.random() < phase.fail_rate)
                spike = (phase.latency_rate > 0
                         and self._rng.random() < phase.latency_rate)
                kill = (phase.kill_rate > 0
                        and self._rng.random() < phase.kill_rate)
                if phase.corrupt_rate > 0:
                    # one draw per image, plus a position draw per hit —
                    # still strictly call-ordered
                    for i in range(len(images)):
                        if self._rng.random() < phase.corrupt_rate:
                            corrupt.append((i, self._rng.random()))
            if fail:
                self.events.append((idx, "fail"))
            if spike:
                self.events.append((idx, "latency"))
            if kill:
                self.events.append((idx, "kill"))
            for i, _ in corrupt:
                self.events.append((idx, "corrupt"))
        if spike:
            time.sleep(phase.latency_s)
        if kill:
            raise WorkerKilled(f"scripted worker death at call {idx}")
        if fail:
            raise phase.exc_type(f"scripted failure at call {idx}")
        out = self.inner(images, quality, **kwargs)
        if corrupt:
            out = list(out)
            for i, pos_frac in corrupt:
                if i < len(out) and isinstance(out[i], (bytes, bytearray)) \
                        and len(out[i]) > 0:
                    blob = bytearray(out[i])
                    pos = int(pos_frac * len(blob))
                    blob[pos] ^= 0xFF
                    out[i] = bytes(blob)
        return out


def dctz_crc_ok(payload) -> bool:
    """Integrity validator for framed ``DCTZ`` streams.

    True iff ``payload`` parses as a ``DCTZ`` container whose CRC32
    matches — the ``validate_payload`` hook a resilient service uses to
    catch corrupted engine output before serving it.  Imports the
    entropy container lazily so this module stays importable without
    the core package (it is pure-stdlib otherwise).
    """
    from repro.core.entropy import container
    if not isinstance(payload, (bytes, bytearray)):
        return False
    try:
        return container.verify_crc(bytes(payload))
    except container.BitstreamError:
        return False
