"""ckpt substrate."""
