"""Sharded, atomic, async checkpointing with reshard-on-load.

Layout (one directory per step):
    <dir>/step_000123/
        MANIFEST.json        tree structure, shapes, dtypes, step, mesh info
        <escaped-path>.npy   one array file per tree leaf
        COMMITTED            sentinel written last (atomicity marker)

Writes go to ``step_X.tmp`` and are renamed after the sentinel is in place,
so a crash mid-write never corrupts the latest checkpoint; ``latest_step``
only considers committed directories.  ``save_async`` hands the device->host
transfer result to a writer thread (training continues on device).

On load, arrays are ``jax.device_put`` against *target* shardings — which
may belong to a different mesh than the one that saved: this is the elastic
rescaling path (ft/elastic.py, tested by reshard tests).

Multi-host note: in a real multi-controller deployment each host writes the
shards it owns (``jax.experimental.multihost_utils``); this container is
single-process, so leaves are written whole — the manifest format already
carries per-leaf sharding to extend to per-shard files.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import queue as queue_mod

import jax
import numpy as np

_SENTINEL = "COMMITTED"


# Structural separator: model param dicts are FLAT with "/" inside keys
# (e.g. "embed/tokens"), so tree structure joins with "|" instead.
_SEP = "|"


def _escape(path: str) -> str:
    return path.replace("/", "__").replace(_SEP, "___")


def _flatten(tree, prefix=""):
    """Flatten nested dict-of-arrays to {path: array} ("|"-joined)."""
    out = {}
    for k, v in tree.items():
        assert _SEP not in k, k
        p = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(_flatten(v, p + _SEP))
        else:
            out[p] = v
    return out


def _unflatten(flat: dict) -> dict:
    out = {}
    for path, v in flat.items():
        parts = path.split(_SEP)
        cur = out
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return out


def save(ckpt_dir: str, step: int, tree: dict, extra: dict | None = None):
    """Synchronous atomic save."""
    flat = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for path, arr in flat.items():
        host = np.asarray(arr)
        fname = _escape(path) + ".npy"
        np.save(os.path.join(tmp, fname), host)
        manifest["leaves"][path] = {
            "file": fname, "shape": list(host.shape), "dtype": str(host.dtype)}
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, _SENTINEL), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Background-thread writer; device->host copy happens on submit."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: queue_mod.Queue = queue_mod.Queue()
        self._err = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                step, host_tree, extra = item
                save(self.ckpt_dir, step, host_tree, extra)
                self._gc()
            except Exception as e:      # pragma: no cover
                self._err = e
            finally:
                self._q.task_done()

    def _gc(self):
        steps = all_steps(self.ckpt_dir)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def submit(self, step: int, tree: dict, extra: dict | None = None):
        if self._err:
            raise self._err
        host = jax.tree.map(np.asarray, tree)   # sync device->host now
        self._q.put((step, host, extra))

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self._q.put(None)
        self._thread.join(timeout=30)


def all_steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, _SENTINEL)):
                steps.append(int(name[5:]))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def load(ckpt_dir: str, step: int, shardings: dict | None = None) -> tuple:
    """Load (tree, extra).  ``shardings``: optional {path: Sharding} to
    device_put against (reshard-on-load / elastic rescale)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)
    flat = {}
    for path, info in manifest["leaves"].items():
        arr = np.load(os.path.join(d, info["file"]))
        if shardings and path in shardings and shardings[path] is not None:
            flat[path] = jax.device_put(arr, shardings[path])
        else:
            flat[path] = jax.device_put(arr)
    return _unflatten(flat), manifest["extra"]
