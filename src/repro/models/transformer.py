"""Dense decoder-only transformer (llama/qwen family), encoder variant
(HuBERT) and VLM backbone (Qwen2-VL M-RoPE) — one implementation.

Layers are scanned (``lax.scan`` over stacked block params) so HLO size is
O(1) in depth; remat policy is configurable per config.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import constrain
from repro.models import layers
from repro.models.params import ParamSpec, subtree


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def attn_param_specs(cfg: ArchConfig, lead: tuple, lead_axes: tuple,
                     prefix: str) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    sp = {}
    sp[f"{prefix}/wq"] = ParamSpec(lead + (d, h * hd),
                                   lead_axes + ("embed", "heads"))
    sp[f"{prefix}/wk"] = ParamSpec(lead + (d, hkv * hd),
                                   lead_axes + ("embed", "kv_heads"))
    sp[f"{prefix}/wv"] = ParamSpec(lead + (d, hkv * hd),
                                   lead_axes + ("embed", "kv_heads"))
    sp[f"{prefix}/wo"] = ParamSpec(lead + (h * hd, d),
                                   lead_axes + ("heads", "embed"))
    if cfg.qkv_bias:
        sp[f"{prefix}/bq"] = ParamSpec(lead + (h * hd,),
                                       lead_axes + ("heads",), init="zeros")
        sp[f"{prefix}/bk"] = ParamSpec(lead + (hkv * hd,),
                                       lead_axes + ("kv_heads",), init="zeros")
        sp[f"{prefix}/bv"] = ParamSpec(lead + (hkv * hd,),
                                       lead_axes + ("kv_heads",), init="zeros")
    if cfg.qk_norm:
        sp[f"{prefix}/q_norm"] = ParamSpec(lead + (hd,),
                                           lead_axes + (None,), init="ones")
        sp[f"{prefix}/k_norm"] = ParamSpec(lead + (hd,),
                                           lead_axes + (None,), init="ones")
    return sp


def param_specs(cfg: ArchConfig) -> dict:
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    ll = cfg.n_layers
    lead, lax_ = ((ll,), ("layers",)) if cfg.scan_layers else ((), ())
    sp = {}
    if cfg.input_mode != "embeds":
        sp["embed/tokens"] = ParamSpec((v, d), ("vocab", "embed"),
                                       init="embed")
    sp[f"blocks/attn_norm"] = ParamSpec(lead + (d,), lax_ + (None,),
                                        init="ones")
    sp.update(attn_param_specs(cfg, lead, lax_, "blocks/attn"))
    sp["blocks/mlp_norm"] = ParamSpec(lead + (d,), lax_ + (None,),
                                      init="ones")
    if cfg.n_experts:
        from repro.models import moe
        sp.update(moe.param_specs(cfg, lead, lax_, "blocks/moe"))
    elif cfg.is_encoder:
        sp["blocks/mlp/wi"] = ParamSpec(lead + (d, f), lax_ + ("embed", "mlp"))
        sp["blocks/mlp/wo"] = ParamSpec(lead + (f, d), lax_ + ("mlp", "embed"))
    else:
        sp["blocks/mlp/wi_gate"] = ParamSpec(lead + (d, f),
                                             lax_ + ("embed", "mlp"))
        sp["blocks/mlp/wi_up"] = ParamSpec(lead + (d, f),
                                           lax_ + ("embed", "mlp"))
        sp["blocks/mlp/wo"] = ParamSpec(lead + (f, d), lax_ + ("mlp", "embed"))
    sp["final_norm"] = ParamSpec((d,), (None,), init="ones")
    if not cfg.tie_embeddings:
        sp["lm_head"] = ParamSpec((d, v), ("embed", "vocab"))
    return sp


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

def cache_struct(cfg: ArchConfig, batch: int, max_len: int):
    hd, hkv, ll = cfg.resolved_head_dim, cfg.n_kv_heads, cfg.n_layers
    shape = (ll, batch, max_len, hkv, hd)
    return {"k": (shape, cfg.compute_dtype), "v": (shape, cfg.compute_dtype)}


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    return {k: jax.ShapeDtypeStruct(s, d)
            for k, (s, d) in cache_struct(cfg, batch, max_len).items()}


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    return {k: jnp.zeros(s, d)
            for k, (s, d) in cache_struct(cfg, batch, max_len).items()}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _angles(cfg: ArchConfig, batch: dict, b: int, s: int, cache_index):
    hd = cfg.resolved_head_dim
    if cfg.mrope_sections is not None:
        pos3 = batch.get("positions3")
        if pos3 is None:
            base_pos = jnp.arange(s)[None] if cache_index is None else (
                cache_index + jnp.arange(s)[None])
            pos3 = jnp.broadcast_to(base_pos, (3, b, s))
        return layers.mrope_angles(pos3, hd, cfg.mrope_sections,
                                   cfg.rope_base)
    pos = jnp.arange(s)[None] if cache_index is None else (
        cache_index + jnp.arange(s)[None])
    pos = jnp.broadcast_to(pos, (b, s))
    return layers.rope_angles(pos, hd, cfg.rope_base)


def _embed_inputs(cfg: ArchConfig, params: dict, batch: dict):
    if cfg.input_mode == "embeds":
        x = batch["embeds"].astype(cfg.compute_dtype)
    else:
        emb = params["embed/tokens"].astype(cfg.compute_dtype)
        x = emb[batch["tokens"]]
        if cfg.input_mode == "mixed" and "vision_embeds" in batch:
            ve = batch["vision_embeds"].astype(cfg.compute_dtype)
            x = jnp.where(batch["vision_mask"][..., None], ve, x)
    return x


def apply(cfg: ArchConfig, params: dict, batch: dict, *, mode: str = "train",
          cache: dict | None = None):
    """Returns (logits, new_cache, aux)."""
    x = _embed_inputs(cfg, params, batch)
    b, s, _ = x.shape
    cache_index = batch.get("cache_index") if mode == "decode" else None
    cos, sin = _angles(cfg, batch, b, s, cache_index)
    x = constrain(x, "batch", "seq", "embed")

    cast = lambda t: jax.tree.map(
        lambda a: a.astype(cfg.compute_dtype)
        if a.dtype == jnp.float32 else a, t)
    blocks = cast(subtree(params, "blocks"))

    def block_fn(x, layer_p, layer_cache):
        return _run_block(cfg, layer_p, x, cos, sin, layer_cache, cache_index)

    if cfg.remat != "none":
        policy = (jax.checkpoint_policies.nothing_saveable
                  if cfg.remat == "full"
                  else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        block_fn = jax.checkpoint(block_fn, policy=policy,
                                  static_argnums=())

    if cfg.scan_layers:
        def scan_body(carry, xs):
            h, aux_sum = carry
            layer_p, layer_cache = xs
            out, new_c, aux = block_fn(h, layer_p, layer_cache)
            return (out, aux_sum + aux), new_c
        (x, aux_total), new_cache = jax.lax.scan(
            scan_body, (x, jnp.zeros((), jnp.float32)), (blocks, cache))
        if cache is None:
            new_cache = None
    else:
        new_cache = None
        aux_total = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            layer_p = jax.tree.map(lambda a: a[i], blocks)
            layer_cache = (jax.tree.map(lambda a: a[i], cache)
                           if cache is not None else None)
            x, _, aux = block_fn(x, layer_p, layer_cache)
            aux_total = aux_total + aux

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        head = params["embed/tokens"].astype(cfg.compute_dtype).T
    else:
        head = params["lm_head"].astype(cfg.compute_dtype)
    logits = x @ head
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits, new_cache, {"aux_loss": aux_total}


def _run_block(cfg: ArchConfig, p: dict, x, cos, sin, cache, cache_index):
    attn_in = layers.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    h, new_cache = layers.attention(
        subtree(p, "attn"), attn_in,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, cos=cos, sin=sin,
        causal=not cfg.is_encoder, qk_norm=cfg.qk_norm,
        cache=cache, cache_index=cache_index)
    x = x + h
    g = layers.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_experts:
        from repro.models import moe
        y, aux = moe.moe_ffn(cfg, subtree(p, "moe"), g)
        x = x + y
    else:
        mlp = layers.gelu_mlp if cfg.is_encoder else layers.swiglu
        x = x + mlp(subtree(p, "mlp"), g)
    return x, new_cache, aux
