"""Shared model building blocks (pure JAX, flat param dicts).

Conventions:
  * params are flat dicts path -> array; helpers take the relevant subtree.
  * activations carry logical sharding tags via dist.sharding.constrain.
  * compute dtype (bf16 on TPU) is the caller's responsibility: blocks
    compute in the dtype of their inputs; norms accumulate in f32.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray,
             eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm with f32 *row statistics* only: the mean-of-squares reduces
    in f32 via the contraction's accumulator (no x-sized f32 temporaries —
    Perf iteration A2, EXPERIMENTS.md §Perf)."""
    dtype = x.dtype
    sq = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    var = sq[..., None] / x.shape[-1]
    scale = jax.lax.rsqrt(var + eps).astype(dtype)
    return x * scale * weight.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_angles(positions: jnp.ndarray, head_dim: int,
                base: float = 1e6) -> tuple:
    """positions (..., S) -> (cos, sin) of shape (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray
               ) -> jnp.ndarray:
    """x (B, S, H, D) with (cos, sin) (B, S, D/2) — rotate-half convention.

    Rotation runs in the input dtype (angles are precomputed in f32 and cast
    once; rope phases are exactly representable enough in bf16 for training
    — x-sized f32 temporaries removed, Perf iteration A2)."""
    dtype = x.dtype
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :].astype(dtype)
    s = sin[..., None, :].astype(dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def mrope_angles(positions3: jnp.ndarray, head_dim: int,
                 sections: tuple = (16, 24, 24), base: float = 1e6) -> tuple:
    """M-RoPE (Qwen2-VL): positions3 (3, B, S) temporal/height/width.

    Frequency slots are split into ``sections`` (halves of head_dim//2);
    each section takes its angle from the corresponding position stream.
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions3[..., None].astype(jnp.float32) * freqs  # (3, B, S, half)
    parts, start = [], 0
    for i, sec in enumerate(sections):
        parts.append(ang[i, ..., start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)                    # (B, S, half)
    return jnp.cos(ang), jnp.sin(ang)


def stable_softmax(scores: jnp.ndarray, out_dtype) -> jnp.ndarray:
    """Softmax with f32 *row statistics* but score-sized traffic in the
    compute dtype (Perf iteration A, EXPERIMENTS.md §Perf): the S^2-sized
    tensors stay bf16 (the flash-attention accumulator discipline expressed
    at the HLO level); only the rowwise max/sum are f32."""
    m = jnp.max(scores, axis=-1, keepdims=True)        # row max (compute dt)
    e = jnp.exp(scores - m)                             # score-sized, bf16
    z = jnp.sum(e.astype(jnp.float32), axis=-1, keepdims=True)  # f32 rows
    return e * (1.0 / z).astype(out_dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional biases / qk-norm / cache)
# ---------------------------------------------------------------------------

def cache_update(cache: jnp.ndarray, new: jnp.ndarray, index) -> jnp.ndarray:
    """Write ``new`` (B, s, ...) into ``cache`` (B, T, ...) at ``index``.

    Single-token decode uses a one-hot masked select instead of
    dynamic_update_slice: a DUS at a runtime index into a time-sharded
    cache forces GSPMD to all-gather the operand, while the masked select
    is elementwise — every T-shard updates locally (Perf iteration C,
    EXPERIMENTS.md §Perf).  Multi-token writes (prefill) keep the DUS.
    """
    new = new.astype(cache.dtype)
    if new.shape[1] == 1:
        t = cache.shape[1]
        onehot = (jnp.arange(t) == index)
        shape = (1, t) + (1,) * (cache.ndim - 2)
        return jnp.where(onehot.reshape(shape), new, cache)
    idx = (jnp.zeros((), jnp.int32), index) + \
        (jnp.zeros((), jnp.int32),) * (cache.ndim - 2)
    return jax.lax.dynamic_update_slice(cache, new, idx)


def _repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, T, Hkv, D) -> (B, T, Hkv*groups, D)."""
    if groups == 1:
        return k
    b, t, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, t, h, groups, d)
                            ).reshape(b, t, h * groups, d)


def attention(p: dict, x: jnp.ndarray, *, n_heads: int, n_kv_heads: int,
              head_dim: int, cos: jnp.ndarray, sin: jnp.ndarray,
              causal: bool = True, qk_norm: bool = False,
              cache: dict | None = None, cache_index=None,
              q_positions: jnp.ndarray | None = None,
              window: int | None = None) -> tuple:
    """GQA attention.

    p: wq (d, H*hd), wk/wv (d, Hkv*hd), wo (H*hd, d), optional bq/bk/bv,
       optional q_norm/k_norm (hd,).
    cache: {"k","v"} (B, T_max, Hkv, hd) ring/linear cache; cache_index is
       the write position (decode) — returns (out, new_cache).
    """
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, n_heads, head_dim)
    k = k.reshape(b, s, n_kv_heads, head_dim)
    v = v.reshape(b, s, n_kv_heads, head_dim)
    if qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")

    new_cache = None
    if cache is not None:
        assert cache_index is not None
        ck = cache_update(cache["k"], k, cache_index)
        cv = cache_update(cache["v"], v, cache_index)
        new_cache = {"k": ck, "v": cv}
        k, v = ck.astype(x.dtype), cv.astype(x.dtype)

    t = k.shape[1]
    groups = n_heads // n_kv_heads
    # grouped-query einsum: KV heads are never materialised G-wide
    qg = q.reshape(b, s, n_kv_heads, groups, head_dim)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) / math.sqrt(head_dim)
    kpos = jnp.arange(t)
    if q_positions is None:
        q_positions = jnp.arange(s) if cache is None else (
            cache_index + jnp.arange(s))
    mask = None
    if causal:
        mask = kpos[None, :] > q_positions[:, None]             # future
    if cache is not None:
        beyond = kpos[None, :] > (cache_index + s - 1)          # unwritten
        mask = beyond if mask is None else (mask | beyond)
    if window is not None:
        old = kpos[None, :] < (q_positions[:, None] - window + 1)
        mask = old if mask is None else (mask | old)
    if mask is not None:
        scores = jnp.where(mask[None, None, None], -1e30, scores)
    attn = stable_softmax(scores, x.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", attn, v)
    out = constrain(out.reshape(b, s, n_heads, head_dim),
                    "batch", "seq", "heads", "head_dim")
    out = out.reshape(b, s, n_heads * head_dim) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """p: wi_gate, wi_up (d, f), wo (f, d)."""
    gate = x @ p["wi_gate"]
    up = x @ p["wi_up"]
    h = jax.nn.silu(gate) * up
    h = constrain(h, "batch", "seq", "mlp")
    return h @ p["wo"]


def gelu_mlp(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """p: wi (d, f), wo (f, d) — classic encoder FFN (HuBERT)."""
    h = jax.nn.gelu(x @ p["wi"])
    h = constrain(h, "batch", "seq", "mlp")
    return h @ p["wo"]
