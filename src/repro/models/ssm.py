"""Mamba2 (SSD) blocks — chunked parallel scan, TPU-native formulation.

The SSD "state-space duality" algorithm maps naturally onto the MXU: within
a chunk the recurrence is a masked (decay-weighted) attention-like batched
matmul; across chunks a short ``lax.scan`` carries the (H, P, N) state.
Per-token cost is O(P·N + Q·P) — sub-quadratic in sequence length, which is
why the ssm/hybrid archs own the ``long_500k`` cell (DESIGN.md §5).

Decode is the O(1) recurrent update on the carried state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import constrain
from repro.models import layers
from repro.models.params import ParamSpec, subtree


def d_inner(cfg: ArchConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_heads_ssm(cfg: ArchConfig) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def param_specs(cfg: ArchConfig, lead: tuple, lead_axes: tuple,
                prefix: str) -> dict:
    d = cfg.d_model
    di = d_inner(cfg)
    n = cfg.ssm_state
    h = n_heads_ssm(cfg)
    k = cfg.ssm_conv
    conv_ch = di + 2 * n            # xBC channels get the causal conv
    sp = {
        f"{prefix}/in_proj": ParamSpec(
            lead + (d, 2 * di + 2 * n + h), lead_axes + ("embed", "mlp")),
        f"{prefix}/conv_w": ParamSpec(
            lead + (k, conv_ch), lead_axes + ("conv_k", "mlp"), scale=0.1),
        f"{prefix}/conv_b": ParamSpec(
            lead + (conv_ch,), lead_axes + ("mlp",), init="zeros"),
        f"{prefix}/A_log": ParamSpec(
            lead + (h,), lead_axes + ("heads",), init="zeros"),
        f"{prefix}/D": ParamSpec(
            lead + (h,), lead_axes + ("heads",), init="ones"),
        f"{prefix}/dt_bias": ParamSpec(
            lead + (h,), lead_axes + ("heads",), init="zeros"),
        f"{prefix}/norm": ParamSpec(
            lead + (di,), lead_axes + ("mlp",), init="ones"),
        f"{prefix}/out_proj": ParamSpec(
            lead + (di, d), lead_axes + ("mlp", "embed")),
    }
    return sp


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a (..., Q) -> (..., Q, Q) lower-tri matrix L[t,s] = sum_{s<r<=t} a[r]."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a_log, b, c, d_skip, chunk: int):
    """SSD forward.

    x  (B, S, H, P)   inputs per head
    dt (B, S, H)      positive step sizes (softplus already applied)
    a_log (H,)        A = -exp(a_log)
    b, c (B, S, N)    input/output projections (single group)
    d_skip (H,)       skip connection
    Returns y (B, S, H, P), final_state (B, H, P, N).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q
    a = -jnp.exp(a_log.astype(jnp.float32))            # (H,)
    dta = dt.astype(jnp.float32) * a                   # (B, S, H) log-decay
    xb = (x * dt[..., None]).astype(jnp.float32)       # dt-weighted input

    # reshape into chunks
    xc = xb.reshape(bsz, nc, q, h, p)
    dc = dta.reshape(bsz, nc, q, h)
    bc = b.reshape(bsz, nc, q, n).astype(jnp.float32)
    cc = c.reshape(bsz, nc, q, n).astype(jnp.float32)

    # ---- intra-chunk (quadratic within chunk, MXU batched matmul) ---------
    L = _segsum(dc.transpose(0, 1, 3, 2))              # (B, nc, H, Q, Q)
    scores = jnp.einsum("bcqn,bcsn->bcqs", cc, bc)     # (B, nc, Q, Q)
    m = jnp.exp(L) * scores[:, :, None]                # (B, nc, H, Q, Q)
    y_intra = jnp.einsum("bchqs,bcshp->bcqhp", m, xc)

    # ---- chunk states ------------------------------------------------------
    cum = jnp.cumsum(dc, axis=2)                       # (B, nc, Q, H)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)    # (B, nc, Q, H)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", bc, decay_to_end, xc)

    # ---- inter-chunk scan ---------------------------------------------------
    chunk_decay = jnp.exp(cum[:, :, -1, :])            # (B, nc, H)

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry                               # emit state *before*

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B, nc, H, P, N)

    # ---- inter-chunk contribution -------------------------------------------
    in_decay = jnp.exp(cum)                             # (B, nc, Q, H)
    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", cc, prev_states, in_decay)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    y = y + x.astype(jnp.float32) * d_skip[None, None, :, None]
    return y, final


def mamba_block(cfg: ArchConfig, p: dict, x: jnp.ndarray,
                state: dict | None = None):
    """One Mamba2 block.  x (B, S, d).

    state (decode): {"conv": (B, K-1, conv_ch), "ssm": (B, H, P, N)}.
    Returns (out, new_state | None).
    """
    bsz, s, d = x.shape
    di = d_inner(cfg)
    n = cfg.ssm_state
    h = n_heads_ssm(cfg)
    pdim = cfg.ssm_head_dim
    k = cfg.ssm_conv

    proj = x @ p["in_proj"]
    # split: z (di) | xbc (di + 2n) | dt (h)
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * n]
    dt_raw = proj[..., di + di + 2 * n:]

    # causal conv over xbc channels
    conv_w = p["conv_w"]                                # (K, C)
    if state is None:
        pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
        new_conv = None
        conv_out = sum(pad[:, i:i + s] * conv_w[i] for i in range(k))
    else:
        hist = jnp.concatenate([state["conv"], xbc], axis=1)  # (B, K-1+s, C)
        conv_out = sum(hist[:, i:i + s] * conv_w[i] for i in range(k))
        new_conv = hist[:, -(k - 1):]
    xbc = jax.nn.silu(conv_out + p["conv_b"])

    xin = xbc[..., :di].reshape(bsz, s, h, pdim)
    b = xbc[..., di:di + n]
    c = xbc[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    if state is None:
        y, _ = ssd_chunked(xin, dt, p["A_log"], b, c, p["D"], cfg.ssm_chunk)
        new_ssm = None
    else:
        # recurrent decode update (s == 1)
        a = -jnp.exp(p["A_log"].astype(jnp.float32))
        dta = jnp.exp(dt[:, 0] * a)                     # (B, H)
        xbar = xin[:, 0].astype(jnp.float32) * dt[:, 0][..., None]
        upd = jnp.einsum("bn,bhp->bhpn", b[:, 0].astype(jnp.float32), xbar)
        new_ssm = state["ssm"] * dta[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", c[:, 0].astype(jnp.float32), new_ssm)
        y = y + xin[:, 0].astype(jnp.float32) * p["D"][None, :, None]
        y = y[:, None]

    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = layers.rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if state is None:
        return out, None
    return out, {"conv": new_conv, "ssm": new_ssm.astype(jnp.float32)}


def mamba_state_struct(cfg: ArchConfig, batch: int):
    di = d_inner(cfg)
    n = cfg.ssm_state
    h = n_heads_ssm(cfg)
    return {
        "conv": ((batch, cfg.ssm_conv - 1, di + 2 * n), cfg.compute_dtype),
        "ssm": ((batch, h, cfg.ssm_head_dim, n), jnp.float32),
    }
