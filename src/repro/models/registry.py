"""Model registry: ArchConfig -> (param_specs, apply, caches)."""

from __future__ import annotations

import jax

from repro.configs.base import ArchConfig
from repro.models import params as P


def _module(cfg: ArchConfig):
    if cfg.use_mla:
        from repro.models import deepseek
        return deepseek
    if cfg.family == "hybrid":
        from repro.models import zamba
        return zamba
    if cfg.family == "ssm":
        from repro.models import xlstm
        return xlstm
    from repro.models import transformer
    return transformer


def param_specs(cfg: ArchConfig) -> dict:
    return _module(cfg).param_specs(cfg)


def init_params(cfg: ArchConfig, key) -> dict:
    return P.init_params(param_specs(cfg), key)


def abstract_params(cfg: ArchConfig) -> dict:
    return P.abstract_params(param_specs(cfg))


def apply(cfg: ArchConfig, params: dict, batch: dict, *, mode: str = "train",
          cache: dict | None = None):
    return _module(cfg).apply(cfg, params, batch, mode=mode, cache=cache)


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    return _module(cfg).abstract_cache(cfg, batch, max_len)


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    return _module(cfg).init_cache(cfg, batch, max_len)
