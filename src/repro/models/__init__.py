"""Model zoo: one module per architecture family (pure JAX)."""
