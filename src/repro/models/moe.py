"""Mixture-of-Experts FFN with expert parallelism.

Dispatch design (DESIGN.md §5): token-choice top-K routing with *per-expert
top-C capacity selection* — each expert gathers the C highest-probability
tokens among those that selected it.  This is GShard-style capacity
dropping implemented with gather/scatter instead of the O(T·E·C) one-hot
einsum, so peak memory is O(T·E) for the routing table plus O(E·C·d) for
the expert batch — both shardable ("experts" on the model axis, capacity on
the data axis), and expert FLOPs are exactly the active top-K FLOPs (big
MXU-shaped batched matmuls).

The gather across the token axis is what becomes the EP all-to-all under
GSPMD; benchmarks measure it in the dry-run's collective table.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import constrain
from repro.models.params import ParamSpec


def param_specs(cfg: ArchConfig, lead: tuple, lead_axes: tuple,
                prefix: str) -> dict:
    d, fe, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    sp = {}
    sp[f"{prefix}/router"] = ParamSpec(lead + (d, e), lead_axes +
                                       ("embed", None), scale=0.02)
    if cfg.router_type == "sigmoid":
        sp[f"{prefix}/router_bias"] = ParamSpec(lead + (e,),
                                                lead_axes + (None,),
                                                init="zeros")
    sp[f"{prefix}/experts/wi_gate"] = ParamSpec(
        lead + (e, d, fe), lead_axes + ("experts", "embed", None))
    sp[f"{prefix}/experts/wi_up"] = ParamSpec(
        lead + (e, d, fe), lead_axes + ("experts", "embed", None))
    sp[f"{prefix}/experts/wo"] = ParamSpec(
        lead + (e, fe, d), lead_axes + ("experts", None, "embed"))
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        sp[f"{prefix}/shared/wi_gate"] = ParamSpec(lead + (d, fs),
                                                   lead_axes + ("embed", "mlp"))
        sp[f"{prefix}/shared/wi_up"] = ParamSpec(lead + (d, fs),
                                                 lead_axes + ("embed", "mlp"))
        sp[f"{prefix}/shared/wo"] = ParamSpec(lead + (fs, d),
                                              lead_axes + ("mlp", "embed"))
    return sp


def capacity(cfg: ArchConfig, n_tokens: int) -> int:
    c = int(math.ceil(n_tokens * cfg.moe_top_k * cfg.moe_capacity_factor
                      / cfg.n_experts))
    return min(max(8, c), n_tokens)


def moe_ffn(cfg: ArchConfig, p: dict, x: jnp.ndarray) -> tuple:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.moe_top_k
    xf = x.reshape(t, d)
    xf = constrain(xf, "batch", "embed")

    # ---- routing ---------------------------------------------------------
    router = p["router"].astype(jnp.float32)
    logits = xf.astype(jnp.float32) @ router                    # (T, E)
    if cfg.router_type == "sigmoid":
        scores = jax.nn.sigmoid(logits + p["router_bias"])
        probs = scores / (scores.sum(-1, keepdims=True) + 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(probs, k)                  # (T, K)
    topk_w = topk_w / (topk_w.sum(-1, keepdims=True) + 1e-9)

    # dense (T, E) table of the chosen weights (0 where not chosen)
    onehot = jax.nn.one_hot(topk_idx, e, dtype=jnp.float32)     # (T, K, E)
    table = jnp.einsum("tke,tk->te", onehot, topk_w)            # (T, E)
    table = constrain(table, "batch", None)

    # ---- per-expert capacity selection ------------------------------------
    c = capacity(cfg, t)
    masked = jnp.where(table > 0, probs, -1.0)                  # (T, E)
    sel_score, sel_idx = jax.lax.top_k(masked.T, c)             # (E, C)
    valid = sel_score > 0
    gate = jnp.take_along_axis(table.T, sel_idx, axis=-1)       # (E, C)
    gate = jnp.where(valid, gate, 0.0)

    # ---- expert computation (the EP all-to-all happens here) --------------
    xg = jnp.take(xf, sel_idx.reshape(-1), axis=0)              # (E*C, d)
    xg = xg.reshape(e, c, d)
    xg = constrain(xg, "experts", "expert_cap", None)
    wg = p["experts/wi_gate"]
    wu = p["experts/wi_up"]
    wo = p["experts/wo"]
    hidden = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, wg))
    hidden = hidden * jnp.einsum("ecd,edf->ecf", xg, wu)
    hidden = constrain(hidden, "experts", "expert_cap", None)
    y = jnp.einsum("ecf,efd->ecd", hidden, wo)                  # (E, C, d)
    y = y * gate[..., None].astype(y.dtype)

    # ---- scatter-add back to token order -----------------------------------
    out = jnp.zeros((t, d), y.dtype)
    out = out.at[sel_idx.reshape(-1)].add(y.reshape(-1, d),
                                          mode="drop")
    out = constrain(out, "batch", "embed")

    # ---- shared experts -----------------------------------------------------
    if cfg.n_shared_experts:
        gate_s = jax.nn.silu(xf @ p["shared/wi_gate"])
        up_s = xf @ p["shared/wi_up"]
        out = out + (gate_s * up_s) @ p["shared/wo"]

    # ---- load-balance aux loss (Switch-style) -------------------------------
    me = probs.mean(axis=0)                                      # (E,)
    ce = (table > 0).astype(jnp.float32).mean(axis=0) / k        # (E,)
    aux = e * jnp.sum(me * ce) * cfg.router_aux_weight

    return out.reshape(b, s, d), aux
