"""Zamba2-style hybrid: Mamba2 backbone + one weight-shared attention block
invoked every ``shared_attn_every`` layers (arXiv:2411.15242).

The shared block's KV cache is per *invocation site* (the same weights see
different inputs at each site), so the cache leading dim is n_sites, not
n_layers — a 6x cache saving relative to a dense transformer of equal
depth, on top of Mamba's O(1) state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import constrain
from repro.models import layers, ssm, transformer
from repro.models.params import ParamSpec, subtree


def attn_sites(cfg: ArchConfig):
    every = cfg.shared_attn_every
    return [i for i in range(cfg.n_layers) if every and i % every == 0]


def param_specs(cfg: ArchConfig) -> dict:
    d, v, ll = cfg.d_model, cfg.vocab_size, cfg.n_layers
    sp = {"embed/tokens": ParamSpec((v, d), ("vocab", "embed"),
                                    init="embed")}
    sp.update(ssm.param_specs(cfg, (ll,), ("layers",), "mamba"))
    sp["mamba_norm"] = ParamSpec((ll, d), ("layers", None), init="ones")
    # the single shared attention block (weight-tied across sites)
    sp["shared/attn_norm"] = ParamSpec((d,), (None,), init="ones")
    sp.update(transformer.attn_param_specs(cfg, (), (), "shared/attn"))
    sp["shared/mlp_norm"] = ParamSpec((d,), (None,), init="ones")
    sp["shared/mlp/wi_gate"] = ParamSpec((d, cfg.d_ff), ("embed", "mlp"))
    sp["shared/mlp/wi_up"] = ParamSpec((d, cfg.d_ff), ("embed", "mlp"))
    sp["shared/mlp/wo"] = ParamSpec((cfg.d_ff, d), ("mlp", "embed"))
    sp["final_norm"] = ParamSpec((d,), (None,), init="ones")
    sp["lm_head"] = ParamSpec((d, v), ("embed", "vocab"))
    return sp


def cache_struct(cfg: ArchConfig, batch: int, max_len: int):
    sites = len(attn_sites(cfg))
    hd, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
    st = {
        "attn/k": ((sites, batch, max_len, hkv, hd), cfg.compute_dtype),
        "attn/v": ((sites, batch, max_len, hkv, hd), cfg.compute_dtype),
    }
    for name, (shape, dt) in ssm.mamba_state_struct(cfg, batch).items():
        st[f"mamba/{name}"] = ((cfg.n_layers,) + shape, dt)
    return st


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    return {k: jax.ShapeDtypeStruct(s, d)
            for k, (s, d) in cache_struct(cfg, batch, max_len).items()}


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    return {k: jnp.zeros(s, d)
            for k, (s, d) in cache_struct(cfg, batch, max_len).items()}


def _shared_attn_block(cfg: ArchConfig, p: dict, x, cos, sin, cache,
                       cache_index):
    h, new_cache = layers.attention(
        subtree(p, "attn"), layers.rms_norm(x, p["attn_norm"], cfg.norm_eps),
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, cos=cos, sin=sin, causal=True,
        cache=cache, cache_index=cache_index)
    x = x + h
    g = layers.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    return x + layers.swiglu(subtree(p, "mlp"), g), new_cache


def apply(cfg: ArchConfig, params: dict, batch: dict, *, mode: str = "train",
          cache: dict | None = None):
    emb = params["embed/tokens"].astype(cfg.compute_dtype)
    x = emb[batch["tokens"]]
    b, s, _ = x.shape
    decode = mode == "decode"
    cache_index = batch.get("cache_index") if decode else None
    cos, sin = transformer._angles(cfg, batch, b, s, cache_index)
    x = constrain(x, "batch", "seq", "embed")

    cast = lambda t: jax.tree.map(
        lambda a: a.astype(cfg.compute_dtype)
        if a.dtype == jnp.float32 else a, t)
    mparams = cast(subtree(params, "mamba"))
    mnorm = params["mamba_norm"]
    shared = cast(subtree(params, "shared"))
    sites = attn_sites(cfg)
    every = cfg.shared_attn_every
    n_full = cfg.n_layers // every          # scanned [attn + every x mamba]
    tail = list(range(n_full * every, cfg.n_layers))

    new_cache = dict(cache) if cache is not None else None

    def mamba_one(x, lp, norm_w, st):
        h = layers.rms_norm(x, norm_w, cfg.norm_eps)
        y, new_st = ssm.mamba_block(cfg, lp, h, st)
        return x + y, new_st

    # ---- scanned groups: [shared attn, mamba x every] ---------------------
    def group_fn(carry, xs):
        h = carry
        gp, gnorm, g_attn_cache, g_mamba_cache = xs
        h, nc = _shared_attn_block(cfg, shared, h, cos, sin, g_attn_cache,
                                   cache_index)
        for j in range(every):
            lp = jax.tree.map(lambda a, j=j: a[j], gp)
            st = (None if g_mamba_cache is None else
                  jax.tree.map(lambda a, j=j: a[j], g_mamba_cache))
            st = (None if st is None else
                  {"conv": st["conv"], "ssm": st["ssm"]})
            h, new_st = mamba_one(h, lp, gnorm[j], st)
            if new_st is not None:
                g_mamba_cache = jax.tree.map(
                    lambda acc, n, j=j: acc.at[j].set(n),
                    g_mamba_cache, new_st)
        return h, (nc, g_mamba_cache)

    grp = jax.tree.map(
        lambda a: a[:n_full * every].reshape(n_full, every, *a.shape[1:]),
        mparams)
    gnorms = mnorm[:n_full * every].reshape(n_full, every, -1)
    g_attn_cache = None
    g_mamba_cache = None
    if cache is not None:
        g_attn_cache = {"k": cache["attn/k"][:n_full],
                        "v": cache["attn/v"][:n_full]}
        if decode:
            g_mamba_cache = jax.tree.map(
                lambda a: a[:n_full * every].reshape(n_full, every,
                                                     *a.shape[1:]),
                {"conv": cache["mamba/conv"], "ssm": cache["mamba/ssm"]})

    x, (attn_caches, mamba_caches) = jax.lax.scan(
        group_fn, x, (grp, gnorms, g_attn_cache, g_mamba_cache))
    if new_cache is not None and attn_caches is not None:
        new_cache["attn/k"] = new_cache["attn/k"].at[:n_full].set(
            attn_caches["k"])
        new_cache["attn/v"] = new_cache["attn/v"].at[:n_full].set(
            attn_caches["v"])
    if new_cache is not None and mamba_caches is not None:
        for key in ("conv", "ssm"):
            flat = mamba_caches[key].reshape(
                n_full * every, *mamba_caches[key].shape[2:])
            new_cache[f"mamba/{key}"] = \
                new_cache[f"mamba/{key}"].at[:n_full * every].set(flat)

    # ---- tail layers (n_layers % every), incl. a site if aligned ----------
    site_i = n_full
    for i in tail:
        if i in sites:
            attn_cache = None
            if cache is not None:
                attn_cache = {"k": cache["attn/k"][site_i],
                              "v": cache["attn/v"][site_i]}
            x, nc = _shared_attn_block(cfg, shared, x, cos, sin, attn_cache,
                                       cache_index)
            if new_cache is not None and nc is not None:
                new_cache["attn/k"] = new_cache["attn/k"].at[site_i].set(
                    nc["k"])
                new_cache["attn/v"] = new_cache["attn/v"].at[site_i].set(
                    nc["v"])
            site_i += 1
        lp = jax.tree.map(lambda a, i=i: a[i], mparams)
        st = None
        if cache is not None and decode:
            st = {"conv": cache["mamba/conv"][i],
                  "ssm": cache["mamba/ssm"][i]}
        x, new_st = mamba_one(x, lp, mnorm[i], st)
        if new_cache is not None and new_st is not None:
            new_cache["mamba/conv"] = new_cache["mamba/conv"].at[i].set(
                new_st["conv"])
            new_cache["mamba/ssm"] = new_cache["mamba/ssm"].at[i].set(
                new_st["ssm"])

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(cfg.compute_dtype)
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits, new_cache, {}
