"""xLSTM blocks — mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, recurrent scan), per Beck et al. 2024 (arXiv:2405.04517).

mLSTM is computed in the *chunkwise stabilised* form: within a chunk the
gated outer-product recurrence collapses to decay-masked attention-like
batched matmuls (MXU-shaped); across chunks a ``lax.scan`` carries the
(H, dqk, dv) matrix memory C, the normaliser n and the stabiliser m.  The
chunked form is bit-matched against the step recurrence in tests, and the
step recurrence is the decode path.

sLSTM is inherently sequential (the paper's point: true recurrence with
memory mixing) — a ``lax.scan`` over time with block-diagonal per-head
recurrent matrices; input projections are hoisted out of the scan.

Block layout follows the 1.3B config: mostly mLSTM blocks (pre-up-projection
factor 2, no FFN) with sLSTM blocks (post-FFN, proj factor 4/3) every
``slstm_every`` positions.  d_ff=0 in the assignment encodes exactly this
in-block feed-forward structure.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import constrain
from repro.models import layers
from repro.models.params import ParamSpec, subtree


# ---------------------------------------------------------------------------
# dims
# ---------------------------------------------------------------------------

def m_inner(cfg: ArchConfig) -> int:
    return int(cfg.mlstm_proj_factor * cfg.d_model)


def m_qk(cfg: ArchConfig) -> int:
    return int(cfg.mlstm_qk_factor * m_inner(cfg))


def s_ff(cfg: ArchConfig) -> int:
    return int(cfg.slstm_proj_factor * cfg.d_model)


def is_slstm(cfg: ArchConfig, layer_idx: int) -> bool:
    return cfg.slstm_every > 0 and layer_idx % cfg.slstm_every == (
        cfg.slstm_every - 1)


def n_slstm(cfg: ArchConfig) -> int:
    return sum(is_slstm(cfg, i) for i in range(cfg.n_layers))


def n_mlstm(cfg: ArchConfig) -> int:
    return cfg.n_layers - n_slstm(cfg)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def mlstm_param_specs(cfg: ArchConfig, lead, lax_, prefix) -> dict:
    d, di, dqk, h = cfg.d_model, m_inner(cfg), m_qk(cfg), cfg.n_heads
    return {
        f"{prefix}/norm": ParamSpec(lead + (d,), lax_ + (None,), init="ones"),
        f"{prefix}/up": ParamSpec(lead + (d, 2 * di),
                                  lax_ + ("embed", "mlp")),
        f"{prefix}/wq": ParamSpec(lead + (di, dqk), lax_ + ("mlp", None)),
        f"{prefix}/wk": ParamSpec(lead + (di, dqk), lax_ + ("mlp", None)),
        f"{prefix}/wv": ParamSpec(lead + (di, di), lax_ + ("mlp", None)),
        f"{prefix}/wgates": ParamSpec(lead + (di, 2 * h),
                                      lax_ + ("mlp", None), scale=0.02),
        f"{prefix}/gate_bias": ParamSpec(lead + (2 * h,), lax_ + (None,),
                                         init="zeros"),
        f"{prefix}/mnorm": ParamSpec(lead + (di,), lax_ + (None,),
                                     init="ones"),
        f"{prefix}/down": ParamSpec(lead + (di, d), lax_ + ("mlp", "embed")),
    }


def slstm_param_specs(cfg: ArchConfig, lead, lax_, prefix) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    fs = s_ff(cfg)
    return {
        f"{prefix}/norm": ParamSpec(lead + (d,), lax_ + (None,), init="ones"),
        f"{prefix}/wx": ParamSpec(lead + (d, 4 * d), lax_ + ("embed", "mlp")),
        f"{prefix}/r": ParamSpec(lead + (4, h, hd, hd),
                                 lax_ + (None, "heads", None, None),
                                 scale=0.02),
        f"{prefix}/bias": ParamSpec(lead + (4 * d,), lax_ + (None,),
                                    init="zeros"),
        f"{prefix}/gnorm": ParamSpec(lead + (d,), lax_ + (None,),
                                     init="ones"),
        f"{prefix}/ffn_norm": ParamSpec(lead + (d,), lax_ + (None,),
                                        init="ones"),
        f"{prefix}/ffn_up": ParamSpec(lead + (d, 2 * fs),
                                      lax_ + ("embed", "mlp")),
        f"{prefix}/ffn_down": ParamSpec(lead + (fs, d),
                                        lax_ + ("mlp", "embed")),
    }


def param_specs(cfg: ArchConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    nm, ns = n_mlstm(cfg), n_slstm(cfg)
    sp = {"embed/tokens": ParamSpec((v, d), ("vocab", "embed"),
                                    init="embed")}
    sp.update(mlstm_param_specs(cfg, (nm,), ("layers",), "mblocks"))
    if ns:
        sp.update(slstm_param_specs(cfg, (ns,), ("layers",), "sblocks"))
    sp["final_norm"] = ParamSpec((d,), (None,), init="ones")
    sp["lm_head"] = ParamSpec((d, v), ("embed", "vocab"))
    return sp


# ---------------------------------------------------------------------------
# mLSTM chunkwise
# ---------------------------------------------------------------------------

def mlstm_chunked(q, k, v, log_f, log_i, chunk: int, state=None):
    """Chunkwise-stabilised mLSTM.

    q, k (B, S, H, dqk) — q pre-scaled by 1/sqrt(dqk); v (B, S, H, dv);
    log_f, log_i (B, S, H).  state: (C, n, m) or None.
    Returns (h (B, S, H, dv), new_state).
    """
    bsz, s, h, dqk = q.shape
    dv = v.shape[-1]
    qc = min(chunk, s)
    assert s % qc == 0
    nc = s // qc

    def r(x):
        return x.reshape(bsz, nc, qc, *x.shape[2:]).transpose(1, 0, *range(2, x.ndim + 1))

    # chunk-major: (nc, B, Q, H, ...)
    qs, ks, vs = r(q), r(k), r(v)
    fs, is_ = r(log_f.astype(jnp.float32)), r(log_i.astype(jnp.float32))

    if state is None:
        c0 = jnp.zeros((bsz, h, dqk, dv), jnp.float32)
        n0 = jnp.zeros((bsz, h, dqk), jnp.float32)
        m0 = jnp.full((bsz, h), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state

    tri = jnp.tril(jnp.ones((qc, qc), bool))

    def body(carry, xs):
        c, n, m = carry
        qq, kk, vv, ff, ii = xs          # (B, Q, H, ...)
        cum = jnp.cumsum(ff, axis=1)     # (B, Q, H) inclusive
        g = ii - cum                     # (B, Q, H)
        gmax = jax.lax.cummax(g, axis=1)
        m_intra = cum + gmax
        m_t = jnp.maximum(m0_plus(m, cum), m_intra)     # (B, Q, H)
        # intra-chunk decay matrix D[t, s]
        dmat = cum[:, :, None] - cum[:, None] + ii[:, None] - m_t[:, :, None]
        dmat = jnp.where(tri[None, :, :, None], jnp.exp(dmat), 0.0)
        scores = jnp.einsum("bthd,bshd->btsh", qq.astype(jnp.float32),
                            kk.astype(jnp.float32))
        w = scores * dmat                                # (B, T, S, H)
        num_intra = jnp.einsum("btsh,bshv->bthv", w, vv.astype(jnp.float32))
        den_intra = w.sum(axis=2)                        # (B, T, H)
        inter = jnp.exp(m[:, None] + cum - m_t)          # (B, Q, H)
        num_inter = jnp.einsum("bthd,bhdv->bthv",
                               qq.astype(jnp.float32), c) * inter[..., None]
        den_inter = jnp.einsum("bthd,bhd->bth",
                               qq.astype(jnp.float32), n) * inter
        num = num_intra + num_inter
        den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_t))
        out = num / den[..., None]

        # end-of-chunk state
        m_end = m_t[:, -1]                               # (B, H)
        carry_decay = jnp.exp(m + cum[:, -1] - m_end)    # (B, H)
        upd_w = jnp.exp(cum[:, -1:] - cum + ii - m_end[:, None])  # (B,Q,H)
        kw = kk.astype(jnp.float32) * upd_w[..., None]
        c_new = c * carry_decay[..., None, None] + jnp.einsum(
            "bshd,bshv->bhdv", kw, vv.astype(jnp.float32))
        n_new = n * carry_decay[..., None] + kw.sum(axis=1)
        return (c_new, n_new, m_end), out

    (c1, n1, m1), outs = jax.lax.scan(body, (c0, n0, m0),
                                      (qs, ks, vs, fs, is_))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(bsz, s, h, dv)
    return out, (c1, n1, m1)


def m0_plus(m, cum):
    """broadcast m (B, H) over the Q axis of cum (B, Q, H)."""
    return m[:, None] + cum


def mlstm_step(q, k, v, log_f, log_i, state):
    """Single-token recurrent update.  q,k (B,H,dqk); v (B,H,dv);
    log_f, log_i (B,H).  Matches mlstm_chunked exactly (tests assert)."""
    c, n, m = state
    log_f = log_f.astype(jnp.float32)
    log_i = log_i.astype(jnp.float32)
    m_new = jnp.maximum(log_f + m, log_i)
    fdec = jnp.exp(log_f + m - m_new)
    iw = jnp.exp(log_i - m_new)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    c_new = c * fdec[..., None, None] + iw[..., None, None] * (
        kf[..., :, None] * vf[..., None, :])
    n_new = n * fdec[..., None] + iw[..., None] * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhd,bhdv->bhv", qf, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n_new)),
                      jnp.exp(-m_new))
    return num / den[..., None], (c_new, n_new, m_new)


def mlstm_block(cfg: ArchConfig, p: dict, x: jnp.ndarray, state=None,
                decode: bool = False):
    """x (B, S, d) -> (out, new_state | None)."""
    bsz, s, d = x.shape
    di, dqk, h = m_inner(cfg), m_qk(cfg), cfg.n_heads
    hqk, hv = dqk // h, di // h
    xin = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    up = xin @ p["up"]
    xm, z = up[..., :di], up[..., di:]
    q = (xm @ p["wq"]).reshape(bsz, s, h, hqk) * (hqk ** -0.5)
    k = (xm @ p["wk"]).reshape(bsz, s, h, hqk)
    v = (xm @ p["wv"]).reshape(bsz, s, h, hv)
    gates = (xm @ p["wgates"] + p["gate_bias"]).astype(jnp.float32)
    log_i = gates[..., :h]
    log_f = jax.nn.log_sigmoid(gates[..., h:])

    if decode:
        out, new_state = mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                                    log_f[:, 0], log_i[:, 0], state)
        out = out[:, None]
    else:
        out, new_state = mlstm_chunked(q, k, v, log_f, log_i,
                                       cfg.ssm_chunk or 64, state)
    y = out.reshape(bsz, s, di).astype(x.dtype)
    y = layers.rms_norm(y, p["mnorm"], cfg.norm_eps) * jax.nn.silu(z)
    return x + y @ p["down"], (new_state if (decode or state is not None)
                               else None)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_block(cfg: ArchConfig, p: dict, x: jnp.ndarray, state=None,
                decode: bool = False):
    """Recurrent sLSTM block + GeGLU FFN.  x (B, S, d)."""
    bsz, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    xin = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    # input projections hoisted out of the scan: (B, S, 4d)
    xproj = (xin @ p["wx"] + p["bias"]).astype(jnp.float32)
    r = p["r"].astype(jnp.float32)                    # (4, H, hd, hd)

    if state is None:
        hp = jnp.zeros((bsz, d), jnp.float32)
        cp = jnp.zeros((bsz, d), jnp.float32)
        np_ = jnp.ones((bsz, d), jnp.float32)
        mp = jnp.zeros((bsz, d), jnp.float32)
    else:
        hp, cp, np_, mp = state

    def step(carry, xt):
        hprev, c, n, m = carry
        hh = hprev.reshape(bsz, h, hd)
        rec = jnp.einsum("bhd,ghde->bghe", hh, r).reshape(bsz, 4 * d)
        pre = xt + rec
        zr, ir, fr, orr = jnp.split(pre, 4, axis=-1)
        zt = jnp.tanh(zr)
        ot = jax.nn.sigmoid(orr)
        log_f = jax.nn.log_sigmoid(fr)
        m_new = jnp.maximum(log_f + m, ir)
        it = jnp.exp(ir - m_new)
        ft = jnp.exp(log_f + m - m_new)
        c_new = ft * c + it * zt
        n_new = ft * n + it
        h_new = ot * c_new / jnp.maximum(n_new, 1.0)
        return (h_new, c_new, n_new, m_new), h_new

    (hp, cp, np_, mp), hs = jax.lax.scan(step, (hp, cp, np_, mp),
                                         xproj.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype)         # (B, S, d)
    y = layers.rms_norm(y, p["gnorm"], cfg.norm_eps)
    x = x + y
    # GeGLU FFN (proj factor 4/3)
    g = layers.rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    upd = g @ p["ffn_up"]
    fs = upd.shape[-1] // 2
    x = x + (jax.nn.gelu(upd[..., :fs]) * upd[..., fs:]) @ p["ffn_down"]
    new_state = (hp, cp, np_, mp) if (decode or state is not None) else None
    return x, new_state


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def _layer_kinds(cfg: ArchConfig):
    return [("s" if is_slstm(cfg, i) else "m") for i in range(cfg.n_layers)]


def state_struct(cfg: ArchConfig, batch: int):
    di, dqk, h, d = m_inner(cfg), m_qk(cfg), cfg.n_heads, cfg.d_model
    nm, ns = n_mlstm(cfg), n_slstm(cfg)
    st = {
        "m/C": ((nm, batch, h, dqk // h, di // h), jnp.float32),
        "m/n": ((nm, batch, h, dqk // h), jnp.float32),
        "m/m": ((nm, batch, h), jnp.float32),
    }
    if ns:
        st.update({
            "s/h": ((ns, batch, d), jnp.float32),
            "s/c": ((ns, batch, d), jnp.float32),
            "s/n": ((ns, batch, d), jnp.float32),
            "s/m": ((ns, batch, d), jnp.float32),
        })
    return st


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    return {k: jax.ShapeDtypeStruct(s, dt)
            for k, (s, dt) in state_struct(cfg, batch).items()}


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    out = {}
    for kk, (s, dt) in state_struct(cfg, batch).items():
        if kk == "m/m":
            out[kk] = jnp.full(s, -1e30, dt)
        elif kk == "s/n":
            out[kk] = jnp.ones(s, dt)
        else:
            out[kk] = jnp.zeros(s, dt)
    return out


def apply(cfg: ArchConfig, params: dict, batch: dict, *, mode: str = "train",
          cache: dict | None = None):
    emb = params["embed/tokens"].astype(cfg.compute_dtype)
    x = emb[batch["tokens"]]
    decode = mode == "decode"
    kinds = _layer_kinds(cfg)

    cast = lambda t: jax.tree.map(
        lambda a: a.astype(cfg.compute_dtype)
        if a.dtype == jnp.float32 else a, t)
    mparams = cast(subtree(params, "mblocks"))
    sparams = cast(subtree(params, "sblocks")) if n_slstm(cfg) else None

    new_cache = dict(cache) if cache is not None else None
    mi = si = 0
    for kind in kinds:
        if kind == "m":
            lp = jax.tree.map(lambda a, i=mi: a[i], mparams)
            st = None
            if cache is not None:
                st = (cache["m/C"][mi], cache["m/n"][mi], cache["m/m"][mi])
            x, new_st = mlstm_block(cfg, lp, x, st, decode=decode)
            if new_cache is not None and new_st is not None:
                c, n, m = new_st
                new_cache["m/C"] = new_cache["m/C"].at[mi].set(c)
                new_cache["m/n"] = new_cache["m/n"].at[mi].set(n)
                new_cache["m/m"] = new_cache["m/m"].at[mi].set(m)
            mi += 1
        else:
            lp = jax.tree.map(lambda a, i=si: a[i], sparams)
            st = None
            if cache is not None:
                st = (cache["s/h"][si], cache["s/c"][si], cache["s/n"][si],
                      cache["s/m"][si])
            x, new_st = slstm_block(cfg, lp, x, st, decode=decode)
            if new_cache is not None and new_st is not None:
                hh, c, n, m = new_st
                new_cache["s/h"] = new_cache["s/h"].at[si].set(hh)
                new_cache["s/c"] = new_cache["s/c"].at[si].set(c)
                new_cache["s/n"] = new_cache["s/n"].at[si].set(n)
                new_cache["s/m"] = new_cache["s/m"].at[si].set(m)
            si += 1

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(cfg.compute_dtype)
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits, new_cache, {}
