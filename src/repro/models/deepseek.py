"""DeepSeek-V3: Multi-head Latent Attention (MLA) + fine-grained MoE + MTP.

MLA (arXiv:2412.19437): queries/keys/values are produced through low-rank
latents; the KV cache stores only the 512-d compressed latent + the 64-d
decoupled RoPE key per token (vs H*hd*2).  Decode uses the *absorbed*
formulation (W^UK folded into the query, W^UV folded into the output), so
per-step attention works directly on the latent cache — the cache is ~9x
smaller than GQA-128 and the decode step is MQA-like with 576-wide heads.

This synergises with the framework's DCT KV compression (serve/kv_compress):
both attack the same decode-HBM roofline term; the dry-run quantifies each.

MTP: one extra transformer depth predicting token t+2 (shared embedding and
head), used as an auxiliary training loss.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import constrain
from repro.models import layers, moe
from repro.models.params import ParamSpec, subtree


def mla_param_specs(cfg: ArchConfig, lead, lax_, prefix) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        f"{prefix}/wq_a": ParamSpec(lead + (d, qr), lax_ + ("embed", None)),
        f"{prefix}/q_norm": ParamSpec(lead + (qr,), lax_ + (None,),
                                      init="ones"),
        f"{prefix}/wq_b": ParamSpec(lead + (qr, h * (dn + dr)),
                                    lax_ + (None, "heads")),
        f"{prefix}/wkv_a": ParamSpec(lead + (d, kvr + dr),
                                     lax_ + ("embed", None)),
        f"{prefix}/kv_norm": ParamSpec(lead + (kvr,), lax_ + (None,),
                                       init="ones"),
        f"{prefix}/wkv_b": ParamSpec(lead + (kvr, h * (dn + dv)),
                                     lax_ + (None, "heads")),
        f"{prefix}/wo": ParamSpec(lead + (h * dv, d),
                                  lax_ + ("heads", "embed")),
    }


def param_specs(cfg: ArchConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    nd = cfg.first_dense_layers
    nm = cfg.n_layers - nd
    sp = {"embed/tokens": ParamSpec((v, d), ("vocab", "embed"),
                                    init="embed")}
    # first-k dense blocks (unscanned)
    for i in range(nd):
        pre = f"dense{i}"
        sp[f"{pre}/attn_norm"] = ParamSpec((d,), (None,), init="ones")
        sp.update(mla_param_specs(cfg, (), (), f"{pre}/attn"))
        sp[f"{pre}/mlp_norm"] = ParamSpec((d,), (None,), init="ones")
        sp[f"{pre}/mlp/wi_gate"] = ParamSpec((d, cfg.d_ff * 9),
                                             ("embed", "mlp"))
        sp[f"{pre}/mlp/wi_up"] = ParamSpec((d, cfg.d_ff * 9),
                                           ("embed", "mlp"))
        sp[f"{pre}/mlp/wo"] = ParamSpec((cfg.d_ff * 9, d), ("mlp", "embed"))
    # scanned MoE blocks
    lead, lax_ = (nm,), ("layers",)
    sp["blocks/attn_norm"] = ParamSpec(lead + (d,), lax_ + (None,),
                                       init="ones")
    sp.update(mla_param_specs(cfg, lead, lax_, "blocks/attn"))
    sp["blocks/mlp_norm"] = ParamSpec(lead + (d,), lax_ + (None,),
                                      init="ones")
    sp.update(moe.param_specs(cfg, lead, lax_, "blocks/moe"))
    sp["final_norm"] = ParamSpec((d,), (None,), init="ones")
    sp["lm_head"] = ParamSpec((d, v), ("embed", "vocab"))
    if cfg.mtp_depth:
        sp["mtp/norm_in"] = ParamSpec((d,), (None,), init="ones")
        sp["mtp/norm_emb"] = ParamSpec((d,), (None,), init="ones")
        sp["mtp/proj"] = ParamSpec((2 * d, d), (None, "embed"))
        sp["mtp/attn_norm"] = ParamSpec((d,), (None,), init="ones")
        sp.update(mla_param_specs(cfg, (), (), "mtp/attn"))
        sp["mtp/mlp_norm"] = ParamSpec((d,), (None,), init="ones")
        sp["mtp/mlp/wi_gate"] = ParamSpec((d, cfg.d_ff * 9), ("embed", "mlp"))
        sp["mtp/mlp/wi_up"] = ParamSpec((d, cfg.d_ff * 9), ("embed", "mlp"))
        sp["mtp/mlp/wo"] = ParamSpec((cfg.d_ff * 9, d), ("mlp", "embed"))
        sp["mtp/final_norm"] = ParamSpec((d,), (None,), init="ones")
    return sp


# ---------------------------------------------------------------------------
# MLA attention
# ---------------------------------------------------------------------------

def _rope_pair(x, cos, sin):
    """x (B, S, H, dr) — rotate-half RoPE on the decoupled dims."""
    return layers.apply_rope(x, cos, sin)


def mla_attention(cfg: ArchConfig, p: dict, x, cos, sin,
                  cache: dict | None = None, cache_index=None):
    """Returns (out, new_cache).  cache: {"ckv": (B,T,kvr), "krope": (B,T,dr)}."""
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    scale = 1.0 / math.sqrt(dn + dr)

    cq = layers.rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = _rope_pair(q_rope, cos, sin)

    kv_a = x @ p["wkv_a"]                                  # (B, S, kvr+dr)
    ckv = layers.rms_norm(kv_a[..., :kvr], p["kv_norm"], cfg.norm_eps)
    k_rope = _rope_pair(kv_a[..., None, kvr:], cos, sin)   # (B, S, 1, dr)
    k_rope = k_rope[:, :, 0]                               # (B, S, dr)

    new_cache = None
    if cache is not None:
        ckv_c = layers.cache_update(cache["ckv"], ckv, cache_index)
        kr_c = layers.cache_update(cache["krope"], k_rope, cache_index)
        new_cache = {"ckv": ckv_c, "krope": kr_c}
        # ---- absorbed decode path (MQA-like over the latent cache) -------
        wkv_b = p["wkv_b"].reshape(kvr, h, dn + dv)
        wk = wkv_b[..., :dn]                                # (kvr, H, dn)
        wv = wkv_b[..., dn:]                                # (kvr, H, dv)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, wk)    # (B, s, H, kvr)
        ckv_all = ckv_c.astype(x.dtype)                     # (B, T, kvr)
        kr_all = kr_c.astype(x.dtype)                       # (B, T, dr)
        scores = (jnp.einsum("bshr,btr->bhst", q_lat, ckv_all) +
                  jnp.einsum("bshr,btr->bhst", q_rope, kr_all)) * scale
        t = ckv_all.shape[1]
        kpos = jnp.arange(t)
        mask = kpos[None, :] > (cache_index + jnp.arange(s)[:, None])
        scores = jnp.where(mask[None, None], -1e30, scores)
        attn = jax.nn.softmax(scores.astype(jnp.float32), axis=-1
                              ).astype(x.dtype)
        ctx = jnp.einsum("bhst,btr->bshr", attn, ckv_all)   # (B, s, H, kvr)
        out = jnp.einsum("bshr,rhv->bshv", ctx, wv)         # (B, s, H, dv)
        out = out.reshape(b, s, h * dv) @ p["wo"]
        return out, new_cache

    # ---- train/prefill path (full materialisation) ------------------------
    kv = (ckv @ p["wkv_b"]).reshape(b, s, h, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], (b, s, h, dr))],
        axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    qf = constrain(qf, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "heads", "head_dim")
    scores = jnp.einsum("bshd,bthd->bhst", qf, k) * scale
    mask = jnp.arange(s)[None, :] > jnp.arange(s)[:, None]
    scores = jnp.where(mask[None, None], -1e30, scores)
    attn = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bhst,bthd->bshd", attn, v)
    out = constrain(out, "batch", "seq", "heads", "head_dim")
    out = out.reshape(b, s, h * dv) @ p["wo"]
    return out, None


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def cache_struct(cfg: ArchConfig, batch: int, max_len: int):
    ll = cfg.n_layers
    return {
        "ckv": ((ll, batch, max_len, cfg.kv_lora_rank), cfg.compute_dtype),
        "krope": ((ll, batch, max_len, cfg.qk_rope_dim), cfg.compute_dtype),
    }


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    return {k: jax.ShapeDtypeStruct(s, d)
            for k, (s, d) in cache_struct(cfg, batch, max_len).items()}


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    return {k: jnp.zeros(s, d)
            for k, (s, d) in cache_struct(cfg, batch, max_len).items()}


# ---------------------------------------------------------------------------
# blocks / model
# ---------------------------------------------------------------------------

def _dense_block(cfg, p, x, cos, sin, cache, cache_index):
    h, nc = mla_attention(cfg, subtree(p, "attn"),
                          layers.rms_norm(x, p["attn_norm"], cfg.norm_eps),
                          cos, sin, cache, cache_index)
    x = x + h
    g = layers.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    return x + layers.swiglu(subtree(p, "mlp"), g), nc


def _moe_block(cfg, p, x, cos, sin, cache, cache_index):
    h, nc = mla_attention(cfg, subtree(p, "attn"),
                          layers.rms_norm(x, p["attn_norm"], cfg.norm_eps),
                          cos, sin, cache, cache_index)
    x = x + h
    g = layers.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    y, aux = moe.moe_ffn(cfg, subtree(p, "moe"), g)
    return x + y, nc, aux


def apply(cfg: ArchConfig, params: dict, batch: dict, *, mode: str = "train",
          cache: dict | None = None):
    emb = params["embed/tokens"].astype(cfg.compute_dtype)
    x = emb[batch["tokens"]]
    b, s, _ = x.shape
    decode = mode == "decode"
    cache_index = batch.get("cache_index") if decode else None
    pos = (jnp.arange(s)[None] if cache_index is None
           else cache_index + jnp.arange(s)[None])
    pos = jnp.broadcast_to(pos, (b, s))
    cos, sin = layers.rope_angles(pos, cfg.qk_rope_dim, cfg.rope_base)
    x = constrain(x, "batch", "seq", "embed")

    cast = lambda t: jax.tree.map(
        lambda a: a.astype(cfg.compute_dtype)
        if a.dtype == jnp.float32 else a, t)

    nd = cfg.first_dense_layers
    new_cache = dict(cache) if cache is not None else None

    for i in range(nd):
        p = cast(subtree(params, f"dense{i}"))
        lc = None
        if cache is not None:
            lc = {"ckv": cache["ckv"][i], "krope": cache["krope"][i]}
        x, nc = _dense_block(cfg, p, x, cos, sin, lc, cache_index)
        if new_cache is not None and nc is not None:
            new_cache["ckv"] = new_cache["ckv"].at[i].set(nc["ckv"])
            new_cache["krope"] = new_cache["krope"].at[i].set(nc["krope"])

    blocks = cast(subtree(params, "blocks"))

    def block_fn(carry, layer_p, layer_cache):
        h, aux_sum = carry
        if layer_cache is not None:
            lc = {"ckv": layer_cache[0], "krope": layer_cache[1]}
        else:
            lc = None
        out, nc, aux = _moe_block(cfg, layer_p, h, cos, sin, lc, cache_index)
        ys = (nc["ckv"], nc["krope"]) if nc is not None else None
        return (out, aux_sum + aux), ys

    if cfg.remat != "none":
        policy = (jax.checkpoint_policies.nothing_saveable
                  if cfg.remat == "full"
                  else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        block_fn = jax.checkpoint(block_fn, policy=policy)

    moe_cache = None
    if cache is not None:
        moe_cache = (cache["ckv"][nd:], cache["krope"][nd:])

    def scan_body(carry, xs):
        layer_p, layer_cache = xs
        return block_fn(carry, layer_p, layer_cache)

    (x, aux_total), ys = jax.lax.scan(
        scan_body, (x, jnp.zeros((), jnp.float32)), (blocks, moe_cache))
    if new_cache is not None and ys is not None:
        new_cache["ckv"] = new_cache["ckv"].at[nd:].set(ys[0])
        new_cache["krope"] = new_cache["krope"].at[nd:].set(ys[1])

    hidden = x
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["lm_head"].astype(cfg.compute_dtype)
    logits = x @ head
    logits = constrain(logits, "batch", "seq", "vocab")

    aux = {"aux_loss": aux_total}
    # ---- MTP auxiliary head (training only) --------------------------------
    if cfg.mtp_depth and mode == "train" and "tokens" in batch:
        p = cast(subtree(params, "mtp"))
        # combine h_t with embedding of token t+1 to predict token t+2
        nxt = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
        e = emb[nxt]
        hcat = jnp.concatenate(
            [layers.rms_norm(hidden, p["norm_in"], cfg.norm_eps),
             layers.rms_norm(e, p["norm_emb"], cfg.norm_eps)], axis=-1)
        hm = hcat @ p["proj"]
        hm, _ = _dense_block(cfg, p, hm, cos, sin, None, None)
        hm = layers.rms_norm(hm, p["final_norm"], cfg.norm_eps)
        aux["mtp_logits"] = hm @ head
    return logits, new_cache, aux
